//! Quickstart: the smallest end-to-end FedLUAR program.
//!
//! Loads the MLP artifacts, builds a 64-client synthetic federation,
//! and runs 20 rounds of FedLUAR (delta = 2 of 4 layers recycled),
//! printing accuracy and the communication ratio as it goes.
//!
//!     make artifacts && cargo run --release --example quickstart
//!
//! Without the PJRT artifacts (e.g. a plain `cargo run --example
//! quickstart`), the engine cannot load; the example then falls back
//! to an engine-free telemetry demo that drives the production wire /
//! link / async-scheduler / LUAR state machines with synthetic deltas
//! at `obs: level=full`, writing the three telemetry artifact kinds
//! under `results/quickstart/` (the CI `obs-artifacts` job validates
//! them).

use fedluar::comm::CommAccountant;
use fedluar::config::{Method, RecycleMode, RunConfig, SelectionScheme};
use fedluar::fl::{AsyncRuntime, Server, UploadPayload};
use fedluar::luar::LuarState;
use fedluar::model::ModelMeta;
use fedluar::net::{wire, NetCfg, NetSim, Staleness, WireHint};
use fedluar::obs::{self, ObsCfg, ObsLevel};
use fedluar::rng::Rng;
use fedluar::tensor;
use std::path::PathBuf;

fn main() -> anyhow::Result<()> {
    // 1. A paper-aligned benchmark config, scaled down for a demo.
    let mut cfg = RunConfig::benchmark("mlp")?;
    cfg.num_clients = 64;
    cfg.active_clients = 16;
    cfg.rounds = 20;
    cfg.eval_every = 4;
    // 2. The paper's method: recycle the 2 lowest-priority layers.
    cfg.method = Method::luar(2);

    // 3. Run Algorithm 2 (or the telemetry demo when the AOT
    //    artifacts are absent).
    let mut server = match Server::new(cfg) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("engine unavailable ({e:#});");
            eprintln!("running the engine-free telemetry demo instead\n");
            return telemetry_demo();
        }
    };
    println!("platform: {}", server.engine.platform());
    println!(
        "model {} | {} params in {} layers | {} clients ({} active)\n",
        server.meta().model,
        server.meta().dim,
        server.meta().num_layers(),
        server.cfg.num_clients,
        server.cfg.active_clients,
    );
    server.run()?;

    // 4. Inspect the result.
    for r in &server.history.records {
        println!(
            "round {:3}: acc {:5.2}%  comm ratio {:.3}  kappa {:.4}",
            r.round,
            r.test_acc * 100.0,
            r.comm_ratio,
            r.kappa
        );
    }
    println!(
        "\nFedLUAR sent {:.1}% of FedAvg's bytes; recycle set is now {:?}",
        server.comm.comm_ratio() * 100.0,
        server.luar.recycle_set
    );
    Ok(())
}

/// Drive the production (engine-free) subsystems — wire codecs,
/// heterogeneous links, the barrier-free scheduler, LUAR selection,
/// the comm ledger — with synthetic updates, under full telemetry.
fn telemetry_demo() -> anyhow::Result<()> {
    const NUM_CLIENTS: usize = 32;
    const CONCURRENCY: usize = 8;
    const AGG_GOAL: usize = 8;
    const VERSIONS: usize = 12;
    const DELTA: usize = 2;

    let meta = demo_meta()?;
    let num_layers = meta.num_layers();
    obs::init(&ObsCfg {
        level: ObsLevel::Full,
        trace_path: Some("results/quickstart/trace.jsonl".into()),
        metrics_path: Some("results/quickstart/metrics.prom".into()),
        layer_csv: Some("results/quickstart/layers.csv".into()),
        clients_csv: Some("results/quickstart/clients.csv".into()),
    })?;

    let mut luar = LuarState::new(num_layers, meta.dim);
    let mut comm = CommAccountant::new(num_layers);
    let net = NetSim::new(NetCfg::default(), NUM_CLIENTS, 7);
    let mut rt = AsyncRuntime::new(NUM_CLIENTS, CONCURRENCY, AGG_GOAL, Staleness::Poly { a: 0.5 });
    let mut rng = Rng::seed_from_u64(7);
    let mut params: Vec<f32> = (0..meta.dim).map(|_| rng.normal_f32(0.0, 0.1)).collect();

    for version in 0..VERSIONS {
        // Upload set is fixed within a version (selection runs at close).
        let upload_layers = luar.upload_set(num_layers);
        let bcast = wire::encode_broadcast(&params, &meta, &luar.recycle_set)?;
        while !rt.ready() {
            while rt.wants_dispatch() {
                let client = rng.gen_range(0, NUM_CLIENTS);
                let scale = 0.05 / (1.0 + version as f32);
                let mut delta: Vec<f32> =
                    (0..meta.dim).map(|_| rng.normal_f32(0.0, scale)).collect();
                // zero the recycled layers, like a real client upload
                for &l in &luar.recycle_set {
                    let lm = &meta.layers[l];
                    delta[lm.offset..lm.offset + lm.size].fill(0.0);
                }
                let frame = wire::encode_update(&delta, &meta, &upload_layers, &WireHint::Sparse)?;
                let decoded = match wire::decode_update(frame.as_bytes(), &meta)? {
                    wire::Decoded::Vector(v) => v,
                    wire::Decoded::Scalar(_) => delta,
                };
                let secs = net.client_secs(client, bcast.len() as u64, frame.len() as u64);
                let payload = UploadPayload {
                    client,
                    version: rt.version,
                    gen: version as u64,
                    delta: decoded,
                    loss: 1.0 / (1.0 + version as f32),
                    frame_len: frame.len() as u64,
                    bcast_len: bcast.len() as u64,
                };
                rt.dispatch(payload, secs);
            }
            rt.absorb_instant();
        }
        let batch = rt.take_aggregation();
        let n = batch.uploads.len();
        let up_bytes: u64 = batch.uploads.iter().map(|u| u.payload.frame_len).sum();
        let discount =
            batch.uploads.iter().map(|u| u.weight as f64).sum::<f64>() / n.max(1) as f64;
        let mut mean = vec![0.0f32; meta.dim];
        for u in &batch.uploads {
            for (m, d) in mean.iter_mut().zip(&u.payload.delta) {
                *m += (u.weight * d) / n as f32;
            }
        }
        let mut u_ssq = Vec::with_capacity(num_layers);
        let mut w_ssq = Vec::with_capacity(num_layers);
        for lm in &meta.layers {
            let r = lm.offset..lm.offset + lm.size;
            u_ssq.push(tensor::ssq(&mean[r.clone()]) as f32);
            w_ssq.push(tensor::ssq(&params[r]) as f32);
        }
        luar.update_scores(&u_ssq, &w_ssq);
        luar.set_age_step(1 + batch.mean_gap.round() as u32);
        let kappa = luar.compose_update(&mut mean, &meta, RecycleMode::Recycle);
        let grad_norms: Vec<f64> = u_ssq.iter().map(|&s| (s as f64).max(0.0).sqrt()).collect();
        obs::record_layer_round(
            version,
            &meta,
            &upload_layers,
            &luar.scores,
            &luar.staleness,
            up_bytes,
            discount,
        );
        obs::gauge("luar.kappa", kappa);
        obs::snapshot(version as u64);
        luar.select_next(SelectionScheme::Luar, DELTA, &grad_norms, &mut rng);
        comm.record_wire_round(
            n as u64,
            &upload_layers,
            up_bytes,
            wire::dense_frame_len(&meta),
            batch.down_bytes,
        );
        for (p, m) in params.iter_mut().zip(&mean) {
            *p += m;
        }
        println!(
            "version {version:2}: {n} absorbs  gap {:.2}  kappa {:.4}  comm {:.3}  R={:?}",
            batch.mean_gap,
            kappa,
            comm.comm_ratio(),
            luar.recycle_set
        );
    }

    println!(
        "\nlayer upload frequencies (Figure 3): {:?}",
        comm.layer_frequencies().iter().map(|f| (f * 100.0).round() / 100.0).collect::<Vec<_>>()
    );
    for p in obs::finish()? {
        println!("telemetry -> {p}");
    }
    Ok(())
}

fn demo_meta() -> anyhow::Result<ModelMeta> {
    ModelMeta::from_json(
        r#"{
        "model":"demo-mlp","dim":2048,"num_classes":10,
        "input_shape":[64],"input_dtype":"f32",
        "tau":2,"batch":8,"eval_batch":32,"agg_clients":8,"momentum":0.9,
        "layers":[
          {"name":"dense1","kind":"dense","offset":0,"size":1024,"arrays":[]},
          {"name":"dense2","kind":"dense","offset":1024,"size":512,"arrays":[]},
          {"name":"dense3","kind":"dense","offset":1536,"size":384,"arrays":[]},
          {"name":"head","kind":"dense","offset":1920,"size":128,"arrays":[]}
        ],
        "artifacts":{"train":"t","eval":"e","agg":"g","init":"i"},
        "init_sha256":"demo"
    }"#,
        PathBuf::from("artifacts"),
    )
}
