//! Quickstart: the smallest end-to-end FedLUAR program.
//!
//! Loads the MLP artifacts, builds a 64-client synthetic federation,
//! and runs 20 rounds of FedLUAR (delta = 2 of 4 layers recycled),
//! printing accuracy and the communication ratio as it goes.
//!
//!     make artifacts && cargo run --release --example quickstart

use fedluar::config::{Method, RunConfig};
use fedluar::fl::Server;

fn main() -> anyhow::Result<()> {
    // 1. A paper-aligned benchmark config, scaled down for a demo.
    let mut cfg = RunConfig::benchmark("mlp")?;
    cfg.num_clients = 64;
    cfg.active_clients = 16;
    cfg.rounds = 20;
    cfg.eval_every = 4;
    // 2. The paper's method: recycle the 2 lowest-priority layers.
    cfg.method = Method::luar(2);

    // 3. Run Algorithm 2.
    let mut server = Server::new(cfg)?;
    println!("platform: {}", server.engine.platform());
    println!(
        "model {} | {} params in {} layers | {} clients ({} active)\n",
        server.meta().model,
        server.meta().dim,
        server.meta().num_layers(),
        server.cfg.num_clients,
        server.cfg.active_clients,
    );
    server.run()?;

    // 4. Inspect the result.
    for r in &server.history.records {
        println!(
            "round {:3}: acc {:5.2}%  comm ratio {:.3}  kappa {:.4}",
            r.round,
            r.test_acc * 100.0,
            r.comm_ratio,
            r.kappa
        );
    }
    println!(
        "\nFedLUAR sent {:.1}% of FedAvg's bytes; recycle set is now {:?}",
        server.comm.comm_ratio() * 100.0,
        server.luar.recycle_set
    );
    Ok(())
}
