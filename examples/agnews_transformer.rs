//! AG-News-like scenario: federated fine-tuning of the TinyTransformer
//! on non-IID synthetic text, reproducing the paper's headline claim —
//! "nearly the same AG News accuracy as FedAvg, while reducing the
//! communication cost to just 17%" — by sweeping the recycling depth
//! delta and printing accuracy-vs-comm.
//!
//!     make artifacts && cargo run --release --example agnews_transformer

use fedluar::config::{Method, RunConfig};
use fedluar::fl::Server;

fn main() -> anyhow::Result<()> {
    let rounds = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(24);

    println!("AGNews-like transformer, {rounds} rounds, Dirichlet(0.5), 9 LUAR layers\n");
    println!("{:>6} {:>10} {:>7} {:>9}", "delta", "accuracy", "comm", "max-kappa");

    let mut baseline = 0.0;
    for delta in [0usize, 3, 6, 8] {
        let mut cfg = RunConfig::benchmark("transformer")?;
        cfg.rounds = rounds;
        cfg.eval_every = rounds;
        cfg.method = if delta == 0 { Method::FedAvg } else { Method::luar(delta) };
        let mut server = Server::new(cfg)?;
        server.run()?;
        let acc = server.history.final_acc() * 100.0;
        if delta == 0 {
            baseline = acc;
        }
        println!(
            "{:>6} {:>9.2}% {:>7.3} {:>9.4}{}",
            delta,
            acc,
            server.comm.comm_ratio(),
            server.history.max_kappa(),
            if delta > 0 && acc >= baseline - 2.0 {
                "   <- paper's regime: ~FedAvg accuracy, fraction of the bytes"
            } else {
                ""
            }
        );
    }
    Ok(())
}
