//! FEMNIST-like scenario: the paper's CNN benchmark, comparing FedAvg
//! with FedLUAR (delta = 2 of 4 layers) head-to-head on the same
//! federation — the Section 4.1 experiment in miniature, including
//! the per-layer aggregation-count chart of Figure 3.
//!
//!     make artifacts && cargo run --release --example femnist_cnn

use fedluar::config::{Method, RunConfig};
use fedluar::fl::Server;

fn run(method: Method, rounds: usize) -> anyhow::Result<Server> {
    let mut cfg = RunConfig::benchmark("cnn")?;
    cfg.rounds = rounds;
    cfg.eval_every = rounds; // evaluate once at the end
    cfg.method = method;
    let mut server = Server::new(cfg)?;
    server.run()?;
    Ok(server)
}

fn main() -> anyhow::Result<()> {
    let rounds = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(16);

    println!("FEMNIST-like CNN, {rounds} rounds, 128 clients (32 active), Dirichlet(0.1)\n");

    let avg = run(Method::FedAvg, rounds)?;
    let luar = run(Method::luar(2), rounds)?;

    let acc = |s: &Server| s.history.final_acc() * 100.0;
    println!("{:<10} {:>9} {:>7}", "method", "accuracy", "comm");
    println!("{:<10} {:>8.2}% {:>7.3}", "FedAvg", acc(&avg), avg.comm.comm_ratio());
    println!("{:<10} {:>8.2}% {:>7.3}", "FedLUAR", acc(&luar), luar.comm.comm_ratio());

    println!("\nper-layer aggregation counts (Figure 3):");
    println!("{:<8} {:>7} {:>8} {:>8}", "layer", "size%", "FedAvg", "FedLUAR");
    let meta = luar.meta();
    for (l, lm) in meta.layers.iter().enumerate() {
        println!(
            "{:<8} {:>6.1}% {:>8} {:>8}",
            lm.name,
            100.0 * lm.size as f64 / meta.dim as f64,
            avg.comm.layer_upload_rounds[l],
            luar.comm.layer_upload_rounds[l],
        );
    }
    println!(
        "\nthe big fc1 layer ({}% of the model) is recycled most -> most of the saving,",
        (100.0 * meta.layers[2].size as f64 / meta.dim as f64) as u32
    );
    println!("matching the paper's FEMNIST observation.");
    Ok(())
}
