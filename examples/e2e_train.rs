//! End-to-end validation driver (DESIGN.md / EXPERIMENTS.md §E2E):
//! federated training of the transformer on the synthetic text corpus
//! for a few hundred client updates, FedAvg vs FedLUAR on identical
//! seeds, logging the full loss curve to results/e2e_*.csv.
//!
//! This proves all layers compose on a real workload:
//!   L1 Pallas mean-reduce kernel (inside the agg HLO)
//!   L2 jax train/eval graphs (AOT HLO, executed via PJRT)
//!   L3 rust coordinator (sampling, LUAR, optimizer, net sim, accounting)
//!
//!     make artifacts && cargo run --release --example e2e_train [rounds]
//!
//! Every upload travels as a serialized `net::wire` frame over a
//! heterogeneous link fleet, so the Comm column here is measured
//! bytes. The `net:` config block controls the simulation; in a
//! config file or via CLI flags:
//!
//!     link_dist = lognormal:up=10,down=50,sigma=0.75,rtt=0.05
//!     round_mode = deadline:s=2.5     # or: sync | buffered:k=8
//!                                     # or: async:c=8,s=poly,a=0.5
//!     compute_s = 0.25                # mean local-compute seconds
//!     deadline_s = 2.5                # alternative spelling
//!     buffer_k = 8                    # alternative spelling
//!
//! The third run below uses a lognormal edge fleet with a round
//! deadline: stragglers transmit but miss the aggregate (LUAR's
//! survivor path), and sim_seconds stops being bounded by the tail.
//! The fourth run removes the barrier entirely (`async:c=...`): the
//! server keeps a fixed number of clients in flight over a persistent
//! event queue, every upload lands with a measured model-version gap
//! (the `version_gap` CSV column), and stale uploads are discounted
//! polynomially — FedLUAR's recycled layers age by that gap.

#![allow(clippy::disallowed_methods)] // demo driver reports real wall time (lint D2 allowlist)
use fedluar::config::{Method, RunConfig};
use fedluar::fl::Server;
use fedluar::net::{LinkDist, RoundMode, Staleness};

fn run(label: &str, method: Method, rounds: usize) -> anyhow::Result<()> {
    run_with_net(label, method, rounds, None)
}

fn run_with_net(
    label: &str,
    method: Method,
    rounds: usize,
    net: Option<(LinkDist, RoundMode)>,
) -> anyhow::Result<()> {
    let mut cfg = RunConfig::benchmark("transformer")?;
    cfg.rounds = rounds;
    cfg.eval_every = 2;
    cfg.method = method;
    if let Some((dist, mode)) = net {
        cfg.net.link_dist = dist;
        cfg.net.round_mode = mode;
        cfg.net.compute_s = 0.25;
    }
    let mut server = Server::new(cfg)?;
    // lint:allow(D2): demo driver reports real wall time, not simulated time
    let t0 = std::time::Instant::now();
    server.run()?;
    let wall = t0.elapsed().as_secs_f64();
    let stats = server.engine.stats();
    let out = format!("results/e2e_{label}.csv");
    server.history.write_csv(&out)?;

    println!("--- {label} ---");
    println!(
        "{} rounds x {} clients x tau={} local steps = {} client updates",
        server.round,
        server.cfg.active_clients,
        server.meta().tau,
        stats.train_calls
    );
    println!("loss curve:");
    for r in &server.history.records {
        let bar_len = ((2.0 - r.train_loss.min(2.0)) * 20.0) as usize;
        println!(
            "  round {:3}  train {:.4}  test {:.4}  acc {:5.2}%  |{}",
            r.round,
            r.train_loss,
            r.test_loss,
            r.test_acc * 100.0,
            "#".repeat(bar_len)
        );
    }
    println!(
        "final acc {:.2}%  comm ratio {:.3}  wall {:.1}s (train {:.1}s, eval {:.1}s, agg {:.2}s)",
        server.history.final_acc() * 100.0,
        server.comm.comm_ratio(),
        wall,
        stats.train_secs,
        stats.eval_secs,
        stats.agg_secs
    );
    println!(
        "wire: {} bytes up (measured frames), {} stragglers dropped, sim {:.1}s",
        server.comm.up_bytes,
        server.dropped_stragglers,
        server.history.records.last().map(|r| r.sim_seconds).unwrap_or(0.0)
    );
    if !server.history.absorbs.is_empty() {
        let gaps: Vec<u64> = server.history.absorbs.iter().map(|a| a.version_gap).collect();
        let mean_gap = gaps.iter().sum::<u64>() as f64 / gaps.len() as f64;
        let max_gap = gaps.iter().copied().max().unwrap_or(0);
        let absorb_out = format!("results/e2e_{label}_absorbs.csv");
        server.history.write_absorb_csv(&absorb_out)?;
        println!(
            "async: {} absorbs, mean version gap {:.2} (max {}), telemetry -> {absorb_out}",
            gaps.len(),
            mean_gap,
            max_gap
        );
    }
    println!("history -> {out}\n");
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let rounds = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(20);
    println!("== end-to-end federated training (all three layers composed) ==\n");
    run("fedavg", Method::FedAvg, rounds)?;
    run("fedluar", Method::luar(6), rounds)?;
    run_with_net(
        "fedluar_edge_deadline",
        Method::luar(6),
        rounds,
        Some((
            LinkDist::LogNormal { up_mbps: 10.0, down_mbps: 50.0, sigma: 0.75, rtt_s: 0.05 },
            RoundMode::Deadline { deadline_s: 2.5 },
        )),
    )?;
    run_with_net(
        "fedluar_edge_async",
        Method::luar(6),
        rounds,
        Some((
            LinkDist::LogNormal { up_mbps: 10.0, down_mbps: 50.0, sigma: 0.75, rtt_s: 0.05 },
            RoundMode::Async { concurrency: 16, staleness: Staleness::Poly { a: 0.5 } },
        )),
    )?;
    println!("expected shape: both curves converge; FedLUAR's comm ratio ~ 0.3-0.5");
    println!("at delta=6/9 with nearly the FedAvg accuracy (paper Table 12 analog).");
    println!("The deadline run trades a few straggler uploads for bounded round time;");
    println!("the async run removes the barrier entirely — stale uploads arrive with");
    println!("measured version gaps and are staleness-discounted into the aggregate.");
    Ok(())
}
