"""AG-News-style TinyTransformer (DistilBERT stand-in, CPU-sized).

Token embedding + learned positions, two pre-LN-free transformer
blocks (attention + FF), mean-pool, classifier head: 12 LUAR layers.
The embedding layer dominates the parameter count the way DistilBERT's
embeddings do in the paper's AG News runs (where the biggest layer is
the one recycled most often, Fig. 3).

FF layers use the Pallas fused_dense kernel when `use_pallas=True`.
"""

import jax
import jax.numpy as jnp

from .. import nn
from ..kernels import fused_dense as fd
from ..kernels import ref as kref

VOCAB = 256
SEQ = 16
D_MODEL = 32
N_HEADS = 4
D_FF = 64
N_BLOCKS = 2
NUM_CLASSES = 4  # AG News has 4 classes


def build(use_pallas: bool = False) -> nn.ModelSpec:
    layers = [
        nn.LayerSpec(
            "embed",
            "embed",
            (
                nn.ArraySpec("tok", (VOCAB, D_MODEL), "embed", VOCAB),
                nn.ArraySpec("pos", (SEQ, D_MODEL), "embed", SEQ),
            ),
        )
    ]
    for i in range(N_BLOCKS):
        layers += [
            nn.LayerSpec(
                f"blk{i}_attn",
                "attn",
                (
                    nn.ArraySpec("wq", (D_MODEL, D_MODEL), "glorot", D_MODEL),
                    nn.ArraySpec("wk", (D_MODEL, D_MODEL), "glorot", D_MODEL),
                    nn.ArraySpec("wv", (D_MODEL, D_MODEL), "glorot", D_MODEL),
                    nn.ArraySpec("wo", (D_MODEL, D_MODEL), "glorot", D_MODEL),
                ),
            ),
            nn.dense_layer(f"blk{i}_ff1", D_MODEL, D_FF, init="glorot"),
            nn.dense_layer(f"blk{i}_ff2", D_FF, D_MODEL, init="glorot"),
        ]
    layers += [
        nn.dense_layer("head1", D_MODEL, D_MODEL, init="glorot"),
        nn.dense_layer("head2", D_MODEL, NUM_CLASSES, init="glorot"),
    ]

    def dense(x, w, b, act):
        if use_pallas:
            # fused_dense expects 2-D inputs; fold (B, S) when needed.
            if x.ndim == 3:
                bsz, s, k = x.shape
                return fd.fused_dense(x.reshape(bsz * s, k), w, b, act).reshape(
                    bsz, s, -1
                )
            return fd.fused_dense(x, w, b, act)
        return kref.fused_dense_ref(x, w, b, act)

    def attention(h, wq, wk, wv, wo):
        bsz, s, dm = h.shape
        dh = dm // N_HEADS

        def split(x):
            return x.reshape(bsz, s, N_HEADS, dh).transpose(0, 2, 1, 3)

        q, k, v = split(h @ wq), split(h @ wk), split(h @ wv)
        att = jax.nn.softmax(q @ k.transpose(0, 1, 3, 2) / jnp.sqrt(dh), axis=-1)
        y = (att @ v).transpose(0, 2, 1, 3).reshape(bsz, s, dm)
        return y @ wo

    def apply(params, tokens):
        it = iter(params)
        (tok, pos) = next(it)
        h = tok[tokens] + pos[None, :, :]
        for _ in range(N_BLOCKS):
            (wq, wk, wv, wo) = next(it)
            (w1, b1) = next(it)
            (w2, b2) = next(it)
            h = h + attention(h, wq, wk, wv, wo)
            ff = dense(dense(h, w1, b1, "gelu"), w2, b2, "none")
            h = h + ff
        h = h.mean(axis=1)
        (w, b) = next(it)
        h = dense(h, w, b, "relu")
        (w, b) = next(it)
        return kref.fused_dense_ref(h, w, b, "none")

    return nn.ModelSpec(
        name="transformer",
        layers=layers,
        input_shape=(SEQ,),
        input_dtype="i32",
        num_classes=NUM_CLASSES,
        apply_fn=apply,
    )
