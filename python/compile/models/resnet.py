"""CIFAR-style ResNet-8 (CPU-sized stand-in for the paper's ResNet20).

Three residual stages (8/16/32 channels) of one block each, plus stem
and classifier: 10 weight layers, ~20k parameters (CPU-sized).  BatchNorm is
omitted (FL + per-client BN statistics is a known confound the paper
does not study); He init plus the residual topology keeps training
stable at the paper's learning rates.  Input is 16x16x3 synthetic
"CIFAR-like" data (DESIGN.md §Substitutions).
"""

import jax
import jax.numpy as jnp

from .. import nn

IMG = 12
NUM_CLASSES = 10


def build(use_pallas: bool = False) -> nn.ModelSpec:
    del use_pallas  # conv model; dense head is tiny
    layers = [
        nn.conv_layer("stem", 3, 3, 8),
        nn.conv_layer("s1_conv1", 3, 8, 8),
        nn.conv_layer("s1_conv2", 3, 8, 8),
        nn.conv_layer("s2_conv1", 3, 8, 16),
        nn.conv_layer("s2_conv2", 3, 16, 16),
        nn.conv_layer("s2_skip", 1, 8, 16),
        nn.conv_layer("s3_conv1", 3, 16, 32),
        nn.conv_layer("s3_conv2", 3, 32, 32),
        nn.conv_layer("s3_skip", 1, 16, 32),
        nn.dense_layer("fc", 32, NUM_CLASSES),
    ]

    def block(h, p1, p2, skip=None, stride=1):
        y = jax.nn.relu(nn.conv2d(h, *p1, stride=stride))
        y = nn.conv2d(y, *p2)
        s = h if skip is None else nn.conv2d(h, *skip, stride=stride)
        return jax.nn.relu(y + s)

    def apply(params, x):
        (stem, c11, c12, c21, c22, sk2, c31, c32, sk3, fc) = params
        h = jax.nn.relu(nn.conv2d(x, *stem))
        h = block(h, c11, c12)  # 12x12x8
        h = block(h, c21, c22, skip=sk2, stride=2)  # 6x6x16
        h = block(h, c31, c32, skip=sk3, stride=2)  # 3x3x32
        h = h.mean(axis=(1, 2))  # global average pool
        w, b = fc
        return h @ w + b

    return nn.ModelSpec(
        name="resnet8",
        layers=layers,
        input_shape=(IMG, IMG, 3),
        input_dtype="f32",
        num_classes=NUM_CLASSES,
        apply_fn=apply,
    )
