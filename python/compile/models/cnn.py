"""FEMNIST-style CNN: the paper's 4-layer model (2 conv + 2 dense).

Matches the paper's FEMNIST setup where delta=2 of 4 layers is the
sweet spot and the big dense layer is the one most often recycled
(Fig. 3) — the layer-size distribution here reproduces that skew:
fc1 holds ~88% of the parameters.
"""

import jax
import jax.numpy as jnp

from .. import nn
from ..kernels import fused_dense as fd
from ..kernels import ref as kref

IMG = 16
NUM_CLASSES = 10


def build(use_pallas: bool = False) -> nn.ModelSpec:
    layers = [
        nn.conv_layer("conv1", 3, 1, 16),
        nn.conv_layer("conv2", 3, 16, 32),
        nn.dense_layer("fc1", 4 * 4 * 32, 128),
        nn.dense_layer("fc2", 128, NUM_CLASSES),
    ]

    def dense(x, w, b, act):
        if use_pallas:
            return fd.fused_dense(x, w, b, act)
        return kref.fused_dense_ref(x, w, b, act)

    def apply(params, x):
        (w1, b1), (w2, b2), (w3, b3), (w4, b4) = params
        h = jax.nn.relu(nn.conv2d(x, w1, b1))
        h = nn.max_pool(h)  # 8x8
        h = jax.nn.relu(nn.conv2d(h, w2, b2))
        h = nn.max_pool(h)  # 4x4
        h = h.reshape(h.shape[0], -1)
        h = dense(h, w3, b3, "relu")
        return dense(h, w4, b4, "none")

    return nn.ModelSpec(
        name="cnn",
        layers=layers,
        input_shape=(IMG, IMG, 1),
        input_dtype="f32",
        num_classes=NUM_CLASSES,
        apply_fn=apply,
    )
