"""Model zoo: one module per benchmark family.

Each `build()` returns a `nn.ModelSpec`; `REGISTRY` maps the model name
used by `aot.py`, the Makefile and the Rust CLI to its builder.
"""

from . import cnn, mlp, resnet, transformer

REGISTRY = {
    "mlp": mlp.build,
    "cnn": cnn.build,
    "resnet8": resnet.build,
    "transformer": transformer.build,
}


def build(name: str, **kw):
    return REGISTRY[name](**kw)
