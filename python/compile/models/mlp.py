"""Quickstart MLP for the synthetic vector benchmark.

Four dense layers so the LUAR layer table is non-trivial; dense layers
run through the Pallas fused_dense kernel when `use_pallas=True`.
"""

import jax.numpy as jnp

from .. import nn
from ..kernels import fused_dense as fd
from ..kernels import ref as kref

INPUT_DIM = 32
HIDDEN = (128, 64, 32)
NUM_CLASSES = 10


def build(use_pallas: bool = False) -> nn.ModelSpec:
    dims = (INPUT_DIM, *HIDDEN, NUM_CLASSES)
    layers = [
        nn.dense_layer(f"fc{i}", dims[i], dims[i + 1])
        for i in range(len(dims) - 1)
    ]

    def dense(x, w, b, act):
        if use_pallas:
            return fd.fused_dense(x, w, b, act)
        return kref.fused_dense_ref(x, w, b, act)

    def apply(params, x):
        h = x.reshape(x.shape[0], -1)
        n = len(params)
        for i, (w, b) in enumerate(params):
            h = dense(h, w, b, "relu" if i < n - 1 else "none")
        return h

    return nn.ModelSpec(
        name="mlp",
        layers=layers,
        input_shape=(INPUT_DIM,),
        input_dtype="f32",
        num_classes=NUM_CLASSES,
        apply_fn=apply,
    )
