"""Flat-parameter neural-net substrate shared by all L2 model graphs.

A model is a list of *layers*; each layer owns one or more parameter
arrays (e.g. a conv weight plus its bias).  The FedLUAR algorithm
operates layer-wise, so the layer is the unit of recycling, and every
layer's arrays are stored contiguously in the flat f32 parameter vector
that crosses the Rust<->HLO boundary.

The flatten order (layer order, then array order within a layer) is the
single source of truth: `layer_table()` emits the offsets that
`aot.py` writes into `artifacts/<model>.meta.json` and that the Rust
coordinator uses for all per-layer slicing.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ArraySpec:
    """One parameter array inside a layer."""

    name: str
    shape: tuple[int, ...]
    init: str  # "he" | "glorot" | "zeros" | "embed" | "ones"
    fan_in: int

    @property
    def size(self) -> int:
        return int(np.prod(self.shape)) if self.shape else 1


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """A named network layer: the unit of LUAR recycling."""

    name: str
    kind: str  # "conv" | "dense" | "embed" | "attn" | "norm"
    arrays: tuple[ArraySpec, ...]

    @property
    def size(self) -> int:
        return sum(a.size for a in self.arrays)


class ModelSpec:
    """Static description of a model: layers + input/output signature."""

    def __init__(
        self,
        name: str,
        layers: list[LayerSpec],
        input_shape: tuple[int, ...],
        input_dtype: str,
        num_classes: int,
        apply_fn: Callable,
    ):
        self.name = name
        self.layers = layers
        self.input_shape = input_shape
        self.input_dtype = input_dtype  # "f32" or "i32"
        self.num_classes = num_classes
        self._apply = apply_fn

    # -- flat-vector plumbing -------------------------------------------------

    @property
    def dim(self) -> int:
        return sum(l.size for l in self.layers)

    def layer_table(self) -> list[dict]:
        """Offsets for meta.json; mirrors the flatten order exactly."""
        table = []
        off = 0
        for l in self.layers:
            arrays = []
            a_off = off
            for a in l.arrays:
                arrays.append(
                    {
                        "name": a.name,
                        "shape": list(a.shape),
                        "offset": a_off,
                        "size": a.size,
                    }
                )
                a_off += a.size
            table.append(
                {
                    "name": l.name,
                    "kind": l.kind,
                    "offset": off,
                    "size": l.size,
                    "arrays": arrays,
                }
            )
            off += l.size
        assert off == self.dim
        return table

    def unflatten(self, flat: jnp.ndarray) -> list[list[jnp.ndarray]]:
        """Static-slice the flat vector back into per-layer array lists."""
        out = []
        off = 0
        for l in self.layers:
            arrs = []
            for a in l.arrays:
                arrs.append(jax.lax.dynamic_slice_in_dim(flat, off, a.size).reshape(a.shape))
                off += a.size
            out.append(arrs)
        return out

    def flatten(self, params: list[list[jnp.ndarray]]) -> jnp.ndarray:
        leaves = [arr.reshape(-1) for layer in params for arr in layer]
        return jnp.concatenate(leaves)

    # -- init ------------------------------------------------------------------

    def init_flat(self, seed: int) -> np.ndarray:
        """Deterministic initial parameters as a flat float32 numpy vector."""
        rng = np.random.default_rng(seed)
        chunks = []
        for l in self.layers:
            for a in l.arrays:
                if a.init == "zeros":
                    w = np.zeros(a.size, dtype=np.float32)
                elif a.init == "ones":
                    w = np.ones(a.size, dtype=np.float32)
                elif a.init == "he":
                    std = float(np.sqrt(2.0 / max(a.fan_in, 1)))
                    w = rng.normal(0.0, std, size=a.size).astype(np.float32)
                elif a.init == "glorot":
                    std = float(np.sqrt(1.0 / max(a.fan_in, 1)))
                    w = rng.normal(0.0, std, size=a.size).astype(np.float32)
                elif a.init == "embed":
                    w = rng.normal(0.0, 0.02, size=a.size).astype(np.float32)
                else:
                    raise ValueError(f"unknown init {a.init}")
                chunks.append(w)
        flat = np.concatenate(chunks)
        assert flat.size == self.dim
        return flat

    # -- forward ----------------------------------------------------------------

    def apply(self, params: list[list[jnp.ndarray]], x: jnp.ndarray) -> jnp.ndarray:
        """Forward pass: x [B, *input_shape] -> logits [B, num_classes]."""
        return self._apply(params, x)

    def apply_flat(self, flat: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
        return self.apply(self.unflatten(flat), x)


# -- shared layer constructors ---------------------------------------------------


def dense_layer(name: str, d_in: int, d_out: int, init: str = "he") -> LayerSpec:
    return LayerSpec(
        name=name,
        kind="dense",
        arrays=(
            ArraySpec("w", (d_in, d_out), init, d_in),
            ArraySpec("b", (d_out,), "zeros", d_in),
        ),
    )


def conv_layer(name: str, k: int, c_in: int, c_out: int) -> LayerSpec:
    return LayerSpec(
        name=name,
        kind="conv",
        arrays=(
            ArraySpec("w", (k, k, c_in, c_out), "he", k * k * c_in),
            ArraySpec("b", (c_out,), "zeros", k * k * c_in),
        ),
    )


def conv2d(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray, stride: int = 1) -> jnp.ndarray:
    """NHWC conv with SAME padding."""
    y = jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return y + b


def max_pool(x: jnp.ndarray, k: int = 2) -> jnp.ndarray:
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, k, k, 1), (1, k, k, 1), "VALID"
    )


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Mean softmax cross-entropy; labels are int32 class ids."""
    logz = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logz, labels[:, None].astype(jnp.int32), axis=-1)
    return nll.mean()
