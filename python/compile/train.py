"""L2 training / evaluation graphs lowered once per model.

`make_train_fn` builds the client-side local update of Algorithm 2
line 7-10: tau steps of mini-batch SGD with momentum 0.9 over a
`lax.scan`, returning the accumulated update Delta = x_tau - x_0 and
the mean training loss.  Two proximal terms parameterize the advanced
FL optimizers without extra artifacts:

    g_total = g + mu_g (x - anchor_g) - mu_prev (x - anchor_prev) + wd x

* FedAvg:   mu_g = mu_prev = 0
* FedProx:  mu_g = mu,   anchor_g    = broadcast global model
* FedACG:   mu_g = beta, anchor_g    = lookahead-accelerated global
* MOON-lite: mu_g pull to global, mu_prev push from the client's
  previous local model (DESIGN.md §Substitutions)

Everything operates on the flat f32 parameter vector; gradients are
taken w.r.t. the flat vector directly so the update is a single
contiguous buffer for the Rust coordinator.
"""

import jax
import jax.numpy as jnp

from . import nn

MOMENTUM = 0.9


def make_train_fn(spec: nn.ModelSpec):
    """(params[d], anchor_g[d], anchor_prev[d], xs[tau,B,...], ys[tau,B],
    lr[], mu_g[], mu_prev[], wd[]) -> (delta[d], mean_loss[])"""

    def loss_fn(flat, x, y):
        logits = spec.apply_flat(flat, x)
        return nn.cross_entropy(logits, y)

    grad_fn = jax.value_and_grad(loss_fn)

    def train(params, anchor_g, anchor_prev, xs, ys, lr, mu_g, mu_prev, wd):
        def step(carry, batch):
            flat, mom = carry
            x, y = batch
            loss, g = grad_fn(flat, x, y)
            g = g + mu_g * (flat - anchor_g) - mu_prev * (flat - anchor_prev) + wd * flat
            mom = MOMENTUM * mom + g
            flat = flat - lr * mom
            return (flat, mom), loss

        (final, _), losses = jax.lax.scan(step, (params, jnp.zeros_like(params)), (xs, ys))
        return final - params, losses.mean()

    return train


def make_eval_fn(spec: nn.ModelSpec):
    """(params[d], xs[B,...], ys[B]) -> (sum_loss[], correct[] i32)

    Returns the *sum* of per-sample NLL so the Rust side can average
    over arbitrarily many fixed-size chunks exactly.
    """

    def evaluate(params, xs, ys):
        logits = spec.apply_flat(params, xs)
        logz = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logz, ys[:, None].astype(jnp.int32), axis=-1)
        correct = jnp.sum((jnp.argmax(logits, axis=-1) == ys).astype(jnp.int32))
        return jnp.sum(nll), correct

    return evaluate


def example_train_args(spec: nn.ModelSpec, tau: int, batch: int):
    """ShapeDtypeStructs for lowering the train graph."""
    f32, i32 = jnp.float32, jnp.int32
    d = spec.dim
    x_dtype = f32 if spec.input_dtype == "f32" else i32
    return (
        jax.ShapeDtypeStruct((d,), f32),  # params
        jax.ShapeDtypeStruct((d,), f32),  # anchor_g
        jax.ShapeDtypeStruct((d,), f32),  # anchor_prev
        jax.ShapeDtypeStruct((tau, batch, *spec.input_shape), x_dtype),
        jax.ShapeDtypeStruct((tau, batch), i32),
        jax.ShapeDtypeStruct((), f32),  # lr
        jax.ShapeDtypeStruct((), f32),  # mu_g
        jax.ShapeDtypeStruct((), f32),  # mu_prev
        jax.ShapeDtypeStruct((), f32),  # wd
    )


def example_eval_args(spec: nn.ModelSpec, eval_batch: int):
    f32, i32 = jnp.float32, jnp.int32
    x_dtype = f32 if spec.input_dtype == "f32" else i32
    return (
        jax.ShapeDtypeStruct((spec.dim,), f32),
        jax.ShapeDtypeStruct((eval_batch, *spec.input_shape), x_dtype),
        jax.ShapeDtypeStruct((eval_batch,), i32),
    )
