"""AOT pipeline: lower every L2 graph to HLO *text* + metadata.

Run once at build time (`make artifacts`); Python never executes on the
FL request path.  Per model this emits:

    artifacts/<model>.train.hlo.txt   local update (tau SGD steps)
    artifacts/<model>.eval.hlo.txt    loss + correct-count on a chunk
    artifacts/<model>.agg.hlo.txt     Pallas client-mean + layer norms
    artifacts/<model>.init.bin        raw little-endian f32 init params
    artifacts/<model>.meta.json       layer table + graph signatures

HLO text (not `.serialize()`): jax >= 0.5 emits HloModuleProto with
64-bit instruction ids which xla_extension 0.5.1 (the version the
`xla` 0.1.6 crate binds) rejects; the text parser reassigns ids.
See /opt/xla-example/README.md.
"""

import argparse
import hashlib
import json
import os
import sys

import jax
import numpy as np
from jax._src.lib import xla_client as xc

from . import aggregate_graph, models, train

# Paper Table 6 defaults (batch sizes CPU-adjusted, see DESIGN.md).
DEFAULTS = {
    "mlp": dict(tau=10, batch=32, eval_batch=256, agg_clients=32, seed=1),
    "cnn": dict(tau=5, batch=16, eval_batch=256, agg_clients=32, seed=2),
    "resnet8": dict(tau=5, batch=16, eval_batch=256, agg_clients=32, seed=3),
    "transformer": dict(tau=5, batch=16, eval_batch=256, agg_clients=32, seed=4),
}


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_model(name: str, out_dir: str, cfg: dict, use_pallas_dense: bool) -> dict:
    spec = models.build(name, use_pallas=use_pallas_dense)
    tau, batch = cfg["tau"], cfg["batch"]
    eval_batch, a = cfg["eval_batch"], cfg["agg_clients"]

    train_fn = train.make_train_fn(spec)
    eval_fn = train.make_eval_fn(spec)
    agg_fn = aggregate_graph.make_agg_fn(spec, use_pallas=True)

    files = {}
    graphs = {
        "train": (train_fn, train.example_train_args(spec, tau, batch)),
        "eval": (eval_fn, train.example_eval_args(spec, eval_batch)),
        "agg": (agg_fn, aggregate_graph.example_agg_args(spec, a)),
    }
    for kind, (fn, args) in graphs.items():
        text = to_hlo_text(jax.jit(fn).lower(*args))
        fname = f"{name}.{kind}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        files[kind] = fname
        print(f"  {fname}: {len(text)} chars")

    init = spec.init_flat(cfg["seed"])
    init_name = f"{name}.init.bin"
    init.tofile(os.path.join(out_dir, init_name))

    meta = {
        "model": name,
        "dim": spec.dim,
        "num_classes": spec.num_classes,
        "input_shape": list(spec.input_shape),
        "input_dtype": spec.input_dtype,
        "tau": tau,
        "batch": batch,
        "eval_batch": eval_batch,
        "agg_clients": a,
        "momentum": train.MOMENTUM,
        "layers": spec.layer_table(),
        "artifacts": {**files, "init": init_name},
        "init_sha256": hashlib.sha256(init.tobytes()).hexdigest(),
    }
    with open(os.path.join(out_dir, f"{name}.meta.json"), "w") as f:
        json.dump(meta, f, indent=1)
    return meta


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out", default="../artifacts", help="output directory")
    p.add_argument(
        "--models",
        default="mlp,cnn,resnet8,transformer",
        help="comma-separated subset of models to lower",
    )
    p.add_argument("--tau", type=int, default=None, help="override local steps")
    p.add_argument(
        "--pallas-dense",
        action="store_true",
        help="route model dense layers through the Pallas fused_dense kernel "
        "(slower lowered HLO on CPU; see EXPERIMENTS.md §Perf)",
    )
    args = p.parse_args()

    out_dir = args.out
    os.makedirs(out_dir, exist_ok=True)
    for name in args.models.split(","):
        name = name.strip()
        if name not in DEFAULTS:
            sys.exit(f"unknown model {name!r}; known: {sorted(DEFAULTS)}")
        cfg = dict(DEFAULTS[name])
        if args.tau is not None:
            cfg["tau"] = args.tau
        print(f"lowering {name} ...")
        meta = lower_model(name, out_dir, cfg, args.pallas_dense)
        print(f"  d={meta['dim']} layers={len(meta['layers'])}")
    print(f"artifacts written to {os.path.abspath(out_dir)}")


if __name__ == "__main__":
    main()
