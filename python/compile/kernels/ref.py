"""Pure-jnp oracles for the Pallas kernels.

These are the correctness contract: pytest asserts the Pallas kernels
(interpret=True) match these references to float32 tolerance across a
hypothesis-swept grid of shapes.
"""

import jax
import jax.numpy as jnp


def mean_reduce_ref(updates: jnp.ndarray) -> jnp.ndarray:
    """Mean of stacked client updates: [a, d] -> [d]."""
    return jnp.mean(updates, axis=0)


def weighted_mean_reduce_ref(updates: jnp.ndarray, weights: jnp.ndarray) -> jnp.ndarray:
    """Weighted mean of client updates: [a, d], [a] -> [d] (weights sum to 1)."""
    return jnp.einsum("a,ad->d", weights, updates)


def fused_dense_ref(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray, act: str) -> jnp.ndarray:
    """Dense layer y = act(x @ w + b); act in {"relu", "gelu", "none"}."""
    y = x @ w + b
    if act == "relu":
        return jax.nn.relu(y)
    if act == "gelu":
        return jax.nn.gelu(y)
    return y


def layer_ssq_ref(flat: jnp.ndarray, offsets, sizes) -> jnp.ndarray:
    """Per-layer squared L2 norms of a flat vector (static slices)."""
    return jnp.stack(
        [jnp.sum(jax.lax.dynamic_slice_in_dim(flat, o, s) ** 2) for o, s in zip(offsets, sizes)]
    )
