"""Pallas fused dense kernel: y = act(x @ w + b).

This is the MXU-shaped kernel of the model forward pass: an (M, K) x
(K, N) matmul tiled as a 2-D grid over (M/BM, N/BN) with the full K
contraction resident per block (K is small for these models).  Bias add
and activation are fused into the same VMEM tile before writeback, so
the activation never round-trips HBM — the standard TPU fusion the
paper's cuBLAS-based stack gets from XLA on GPU.

The backward pass is a custom_vjp in plain jnp: Pallas kernels have no
automatic AD rule, and the matmul transposes in the VJP are themselves
plain GEMMs XLA fuses well.  This keeps the kernel usable inside the
L2 train graph (jax.grad flows through).

interpret=True everywhere on this CPU testbed; see aggregate.py.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BM = 32  # batch tile
BN = 128  # lane-aligned output tile


def _dense_kernel(x_ref, w_ref, b_ref, o_ref, *, act: str):
    y = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)
    y = y + b_ref[...][None, :]
    if act == "relu":
        y = jnp.maximum(y, 0.0)
    elif act == "gelu":
        y = jax.nn.gelu(y)
    o_ref[...] = y


def _pallas_dense(x, w, b, act: str, interpret: bool):
    m, k = x.shape
    _, n = w.shape
    bm, bn = min(BM, m), min(BN, n)
    m_pad = pl.cdiv(m, bm) * bm
    n_pad = pl.cdiv(n, bn) * bn
    xp = jnp.pad(x, ((0, m_pad - m), (0, 0))) if m_pad != m else x
    wp = jnp.pad(w, ((0, 0), (0, n_pad - n))) if n_pad != n else w
    bp = jnp.pad(b, (0, n_pad - n)) if n_pad != n else b
    out = pl.pallas_call(
        functools.partial(_dense_kernel, act=act),
        grid=(m_pad // bm, n_pad // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m_pad, n_pad), jnp.float32),
        interpret=interpret,
    )(xp, wp, bp)
    return out[:m, :n]


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def fused_dense(x, w, b, act: str = "relu", interpret: bool = True):
    """act(x @ w + b) with the forward pass as a Pallas kernel."""
    return _pallas_dense(x, w, b, act, interpret)


def _fwd(x, w, b, act, interpret):
    y = _pallas_dense(x, w, b, act, interpret)
    return y, (x, w, b, y)


def _bwd(act, interpret, res, gy):
    x, w, b, y = res
    if act == "relu":
        gz = gy * (y > 0.0)
    elif act == "gelu":
        # Recompute the gelu derivative from the pre-activation.
        z = x @ w + b
        gz = gy * jax.grad(lambda t: jax.nn.gelu(t).sum())(z)
    else:
        gz = gy
    gx = gz @ w.T
    gw = x.T @ gz
    gb = gz.sum(axis=0)
    return gx, gw, gb


fused_dense.defvjp(_fwd, _bwd)


def mxu_utilization_estimate(m: int, k: int, n: int) -> float:
    """Fraction of MXU issue slots doing useful work for one tile pass.

    The 128x128 MXU processes a (bm,k)x(k,bn) tile in ceil(bm/128)*
    ceil(k/128)*ceil(bn/128) passes; utilization is useful MACs over
    issued MACs.  Recorded per-model in EXPERIMENTS.md §Perf.
    """
    import math

    bm, bn = min(BM, m), min(BN, n)
    passes = math.ceil(bm / 128) * math.ceil(k / 128) * math.ceil(bn / 128)
    issued = passes * 128 * 128 * 128
    useful = bm * k * bn
    return useful / issued
