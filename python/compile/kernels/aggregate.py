"""Pallas kernel for the FedLUAR server-side aggregation hot path.

The server reduces `a` stacked client updates (f32[a, d]) to their
(weighted) mean (f32[d]).  This is the per-round communication sink the
paper optimizes, so it is the L1 hot-spot of this reproduction.

TPU mapping (DESIGN.md §Hardware-Adaptation): the paper's testbed did
this with MPI_Allreduce over GPUs; on a TPU the natural shape is a grid
over d-blocks with the whole client axis resident in VMEM per block —
each grid step streams an (a, BLOCK) tile HBM->VMEM, reduces over the
client axis on the VPU, and writes a (BLOCK,) tile back.  BLOCK is a
multiple of 128 lanes; with a=32 and BLOCK=512 the working set is
32*512*4 B = 64 KiB, far under the ~16 MiB VMEM budget, leaving room
for double-buffering by the Mosaic pipeliner.

The kernel is bandwidth-bound: 1 FLOP per 4 bytes streamed, so the
roofline is HBM bandwidth; MXU is idle by design (no matmul here).

Kernels are lowered with interpret=True (CPU PJRT cannot execute Mosaic
custom-calls); correctness is asserted against kernels.ref in pytest.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Lane-aligned block width (TPU VPU lane count is 128). 4096 keeps the
# per-step working set at 32*4096*4 = 512 KiB (well under VMEM) while
# cutting the grid length 8x vs the original 512 — §Perf measured a
# ~6x aggregation speedup on the CPU interpret path from exactly this
# (each grid step costs a dynamic-slice + reduce dispatch).
BLOCK = 4096


def _mean_kernel(u_ref, o_ref, *, inv_a: float):
    """One grid step: reduce an (a, BLOCK) tile over the client axis."""
    o_ref[...] = jnp.sum(u_ref[...], axis=0) * inv_a


def _wmean_kernel(u_ref, w_ref, o_ref):
    """Weighted variant: weights [a] broadcast over the tile."""
    o_ref[...] = jnp.sum(u_ref[...] * w_ref[...][:, None], axis=0)


@functools.partial(jax.jit, static_argnames=("interpret",))
def mean_reduce(updates: jnp.ndarray, interpret: bool = True) -> jnp.ndarray:
    """Mean of stacked client updates [a, d] -> [d] via a tiled Pallas kernel.

    d is padded up to a BLOCK multiple (zero pad), reduced blockwise,
    then sliced back; padding contributes nothing to the mean.
    """
    a, d = updates.shape
    d_pad = pl.cdiv(d, BLOCK) * BLOCK
    if d_pad != d:
        updates = jnp.pad(updates, ((0, 0), (0, d_pad - d)))
    out = pl.pallas_call(
        functools.partial(_mean_kernel, inv_a=1.0 / a),
        grid=(d_pad // BLOCK,),
        in_specs=[pl.BlockSpec((a, BLOCK), lambda i: (0, i))],
        out_specs=pl.BlockSpec((BLOCK,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((d_pad,), updates.dtype),
        interpret=interpret,
    )(updates)
    return out[:d]


@functools.partial(jax.jit, static_argnames=("interpret",))
def weighted_mean_reduce(
    updates: jnp.ndarray, weights: jnp.ndarray, interpret: bool = True
) -> jnp.ndarray:
    """Weighted mean over clients: [a, d], [a] -> [d]; weights sum to 1."""
    a, d = updates.shape
    d_pad = pl.cdiv(d, BLOCK) * BLOCK
    if d_pad != d:
        updates = jnp.pad(updates, ((0, 0), (0, d_pad - d)))
    out = pl.pallas_call(
        _wmean_kernel,
        grid=(d_pad // BLOCK,),
        in_specs=[
            pl.BlockSpec((a, BLOCK), lambda i: (0, i)),
            pl.BlockSpec((a,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((BLOCK,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((d_pad,), updates.dtype),
        interpret=interpret,
    )(updates, weights)
    return out[:d]


def vmem_bytes(a: int, block: int = BLOCK) -> int:
    """Working-set estimate per grid step (input tile + output tile)."""
    return 4 * (a * block + block)
