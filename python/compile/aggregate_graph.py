"""L2 server-side aggregation graph (the LUAR metric, Eq. 1, for free).

agg(U[a, d], params[d]) -> (mean[d], u_ssq[L], w_ssq[L])

* `mean` is the FedAvg update, reduced by the L1 Pallas kernel.
* `u_ssq[l]` / `w_ssq[l]` are per-layer squared norms of the mean
  update and of the current global parameters: exactly the inputs to
  s_{t,l} = ||Delta_{t,l}|| / ||x_{t,l}||.  Layer boundaries are static
  at lowering time (the layer table), so these are unrolled static
  slices — no communication, no dynamic indexing, mirroring the
  paper's claim that the metric is measurable server-side for free.
"""

import jax
import jax.numpy as jnp

from . import nn
from .kernels import aggregate as agg_kernel
from .kernels import ref as kref


def make_agg_fn(spec: nn.ModelSpec, use_pallas: bool = True):
    offsets = [l.offset for l in _table(spec)]
    sizes = [l.size for l in _table(spec)]

    def agg(updates, params):
        if use_pallas:
            mean = agg_kernel.mean_reduce(updates)
        else:
            mean = kref.mean_reduce_ref(updates)
        u_ssq = kref.layer_ssq_ref(mean, offsets, sizes)
        w_ssq = kref.layer_ssq_ref(params, offsets, sizes)
        return mean, u_ssq, w_ssq

    return agg


class _Row:
    __slots__ = ("offset", "size")

    def __init__(self, offset, size):
        self.offset = offset
        self.size = size


def _table(spec: nn.ModelSpec):
    return [_Row(r["offset"], r["size"]) for r in spec.layer_table()]


def example_agg_args(spec: nn.ModelSpec, a: int):
    f32 = jnp.float32
    return (
        jax.ShapeDtypeStruct((a, spec.dim), f32),
        jax.ShapeDtypeStruct((spec.dim,), f32),
    )
