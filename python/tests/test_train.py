"""L2 train/eval/aggregate graph semantics (pre-lowering).

These run the exact functions that get lowered to HLO, so agreement
here plus the Rust runtime integration test (which replays the same
seeds through the artifacts) pins the whole AOT bridge.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aggregate_graph, models, train
from compile.kernels import ref as kref

jax.config.update("jax_platform_name", "cpu")


def _batches(spec, tau, batch, seed=0):
    rng = np.random.default_rng(seed)
    if spec.input_dtype == "f32":
        xs = rng.normal(size=(tau, batch, *spec.input_shape)).astype(np.float32)
    else:
        xs = rng.integers(0, 512, size=(tau, batch, *spec.input_shape)).astype(np.int32)
    ys = rng.integers(0, spec.num_classes, size=(tau, batch)).astype(np.int32)
    return jnp.asarray(xs), jnp.asarray(ys)


@pytest.fixture(scope="module")
def mlp():
    return models.build("mlp")


def _zeros(spec):
    return jnp.zeros(spec.dim, dtype=jnp.float32)


def test_train_returns_delta_and_loss(mlp):
    fn = train.make_train_fn(mlp)
    p = jnp.asarray(mlp.init_flat(0))
    xs, ys = _batches(mlp, tau=5, batch=8)
    delta, loss = fn(p, _zeros(mlp), _zeros(mlp), xs, ys, 0.05, 0.0, 0.0, 0.0)
    assert delta.shape == (mlp.dim,)
    assert float(loss) > 0
    assert np.abs(np.asarray(delta)).max() > 0


def test_train_zero_lr_gives_zero_delta(mlp):
    fn = train.make_train_fn(mlp)
    p = jnp.asarray(mlp.init_flat(0))
    xs, ys = _batches(mlp, tau=3, batch=4)
    delta, _ = fn(p, _zeros(mlp), _zeros(mlp), xs, ys, 0.0, 0.0, 0.0, 0.0)
    np.testing.assert_array_equal(np.asarray(delta), 0.0)


def test_train_reduces_loss_over_repeated_rounds(mlp):
    """Applying delta as the server would (x += delta) must learn."""
    fn = jax.jit(train.make_train_fn(mlp))
    p = jnp.asarray(mlp.init_flat(1))
    xs, ys = _batches(mlp, tau=10, batch=16, seed=2)
    losses = []
    for _ in range(5):
        delta, loss = fn(p, _zeros(mlp), _zeros(mlp), xs, ys, 0.05, 0.0, 0.0, 0.0)
        p = p + delta
        losses.append(float(loss))
    assert losses[-1] < losses[0]


def test_prox_term_pulls_toward_anchor(mlp):
    """With a huge mu_g and zero-gradient data the delta points to anchor."""
    fn = train.make_train_fn(mlp)
    p = jnp.asarray(mlp.init_flat(3))
    anchor = p + 1.0
    xs, ys = _batches(mlp, tau=5, batch=4, seed=4)
    d_prox, _ = fn(p, anchor, _zeros(mlp), xs, ys, 0.01, 10.0, 0.0, 0.0)
    d_none, _ = fn(p, anchor, _zeros(mlp), xs, ys, 0.01, 0.0, 0.0, 0.0)
    # prox gradient = mu*(p - anchor) = -mu, so prox delta is more positive
    assert float(jnp.mean(d_prox - d_none)) > 0.1


def test_moon_repulsion_pushes_away(mlp):
    fn = train.make_train_fn(mlp)
    p = jnp.asarray(mlp.init_flat(3))
    prev = p + 1.0
    xs, ys = _batches(mlp, tau=5, batch=4, seed=4)
    d_rep, _ = fn(p, _zeros(mlp), prev, xs, ys, 0.01, 0.0, 5.0, 0.0)
    d_none, _ = fn(p, _zeros(mlp), prev, xs, ys, 0.01, 0.0, 0.0, 0.0)
    # repulsion gradient = -mu_prev*(p - prev) = +mu_prev -> more negative delta
    assert float(jnp.mean(d_rep - d_none)) < -0.1


def test_weight_decay_shrinks_params(mlp):
    fn = train.make_train_fn(mlp)
    p = jnp.asarray(mlp.init_flat(5))
    xs, ys = _batches(mlp, tau=5, batch=4, seed=6)
    d_wd, _ = fn(p, _zeros(mlp), _zeros(mlp), xs, ys, 0.01, 0.0, 0.0, 0.5)
    d0, _ = fn(p, _zeros(mlp), _zeros(mlp), xs, ys, 0.01, 0.0, 0.0, 0.0)
    # wd adds +wd*p to the gradient -> delta difference ~ -lr*wd*p (momentum-scaled)
    corr = float(jnp.vdot(d_wd - d0, -p) / (jnp.linalg.norm(d_wd - d0) * jnp.linalg.norm(p)))
    assert corr > 0.9


def test_eval_counts(mlp):
    fn = train.make_eval_fn(mlp)
    p = jnp.asarray(mlp.init_flat(0))
    rng = np.random.default_rng(9)
    xs = jnp.asarray(rng.normal(size=(64, *mlp.input_shape)).astype(np.float32))
    ys = jnp.asarray(rng.integers(0, mlp.num_classes, size=(64,)).astype(np.int32))
    loss_sum, correct = fn(p, xs, ys)
    assert 0 <= int(correct) <= 64
    assert float(loss_sum) > 0
    # perfect-prediction sanity: labels from argmax give 100% accuracy
    logits = mlp.apply_flat(p, xs)
    ys_perfect = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    _, c2 = fn(p, xs, ys_perfect)
    assert int(c2) == 64


def test_agg_graph_matches_manual(mlp):
    fn = aggregate_graph.make_agg_fn(mlp, use_pallas=True)
    rng = np.random.default_rng(11)
    a = 8
    U = rng.normal(size=(a, mlp.dim)).astype(np.float32)
    p = rng.normal(size=(mlp.dim,)).astype(np.float32)
    mean, u_ssq, w_ssq = fn(jnp.asarray(U), jnp.asarray(p))
    np.testing.assert_allclose(np.asarray(mean), U.mean(axis=0), rtol=1e-4, atol=1e-5)
    table = mlp.layer_table()
    for i, row in enumerate(table):
        sl = slice(row["offset"], row["offset"] + row["size"])
        np.testing.assert_allclose(
            float(u_ssq[i]), (U.mean(axis=0)[sl] ** 2).sum(), rtol=1e-3
        )
        np.testing.assert_allclose(float(w_ssq[i]), (p[sl] ** 2).sum(), rtol=1e-3)


def test_agg_pallas_matches_jnp_path(mlp):
    fn_p = aggregate_graph.make_agg_fn(mlp, use_pallas=True)
    fn_j = aggregate_graph.make_agg_fn(mlp, use_pallas=False)
    rng = np.random.default_rng(12)
    U = jnp.asarray(rng.normal(size=(4, mlp.dim)).astype(np.float32))
    p = jnp.asarray(rng.normal(size=(mlp.dim,)).astype(np.float32))
    for a_, b_ in zip(fn_p(U, p), fn_j(U, p)):
        np.testing.assert_allclose(np.asarray(a_), np.asarray(b_), rtol=1e-4, atol=1e-5)


def test_momentum_matters(mlp):
    """The scan carries momentum: two steps on the same batch move further
    than 2x one step (momentum accumulates)."""
    fn = train.make_train_fn(mlp)
    p = jnp.asarray(mlp.init_flat(13))
    xs, ys = _batches(mlp, tau=1, batch=8, seed=14)
    xs2 = jnp.concatenate([xs, xs])
    ys2 = jnp.concatenate([ys, ys])
    d1, _ = fn(p, _zeros(mlp), _zeros(mlp), xs, ys, 0.01, 0.0, 0.0, 0.0)
    d2, _ = fn(p, _zeros(mlp), _zeros(mlp), xs2, ys2, 0.01, 0.0, 0.0, 0.0)
    assert float(jnp.linalg.norm(d2)) > 2.0 * float(jnp.linalg.norm(d1)) * 0.99
