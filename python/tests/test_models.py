"""L2 model-zoo correctness: shapes, flatten/unflatten round trip,
layer table consistency, gradient flow to every layer, pallas/ref parity.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import models, nn

jax.config.update("jax_platform_name", "cpu")

ALL = ["mlp", "cnn", "resnet8", "transformer"]


def _inputs(spec, batch, seed=0):
    rng = np.random.default_rng(seed)
    if spec.input_dtype == "f32":
        x = rng.normal(size=(batch, *spec.input_shape)).astype(np.float32)
    else:
        x = rng.integers(0, 512, size=(batch, *spec.input_shape)).astype(np.int32)
    y = rng.integers(0, spec.num_classes, size=(batch,)).astype(np.int32)
    return jnp.asarray(x), jnp.asarray(y)


@pytest.fixture(params=ALL)
def spec(request):
    return models.build(request.param)


def test_layer_table_is_contiguous(spec):
    table = spec.layer_table()
    off = 0
    for row in table:
        assert row["offset"] == off
        a_off = off
        for a in row["arrays"]:
            assert a["offset"] == a_off
            assert a["size"] == int(np.prod(a["shape"])) if a["shape"] else 1
            a_off += a["size"]
        assert a_off - off == row["size"]
        off += row["size"]
    assert off == spec.dim


def test_flatten_unflatten_roundtrip(spec):
    flat = jnp.asarray(spec.init_flat(0))
    params = spec.unflatten(flat)
    flat2 = spec.flatten(params)
    np.testing.assert_array_equal(np.asarray(flat), np.asarray(flat2))


def test_forward_shape(spec):
    flat = jnp.asarray(spec.init_flat(1))
    x, _ = _inputs(spec, batch=4)
    logits = spec.apply_flat(flat, x)
    assert logits.shape == (4, spec.num_classes)
    assert np.isfinite(np.asarray(logits)).all()


def test_init_is_deterministic(spec):
    a = spec.init_flat(42)
    b = spec.init_flat(42)
    c = spec.init_flat(43)
    np.testing.assert_array_equal(a, b)
    assert not np.array_equal(a, c)


def test_gradient_reaches_every_layer(spec):
    """No dead layers: every layer's slice of the gradient is non-zero."""
    flat = jnp.asarray(spec.init_flat(2))
    x, y = _inputs(spec, batch=8, seed=3)

    def loss(f):
        return nn.cross_entropy(spec.apply_flat(f, x), y)

    g = np.asarray(jax.grad(loss)(flat))
    for row in spec.layer_table():
        sl = g[row["offset"] : row["offset"] + row["size"]]
        assert np.abs(sl).max() > 0, f"dead layer {row['name']}"


def test_loss_decreases_under_sgd(spec):
    """A few SGD steps on one batch must reduce the loss (learnability)."""
    flat = jnp.asarray(spec.init_flat(4))
    x, y = _inputs(spec, batch=16, seed=5)

    def loss(f):
        return nn.cross_entropy(spec.apply_flat(f, x), y)

    l0 = float(loss(flat))
    lr = 0.05 if spec.input_dtype == "f32" else 0.01
    g = jax.grad(loss)
    for _ in range(10):
        flat = flat - lr * g(flat)
    l1 = float(loss(flat))
    assert l1 < l0, f"{spec.name}: loss {l0} -> {l1}"


@pytest.mark.parametrize("name", ["mlp", "cnn", "transformer"])
def test_pallas_and_ref_paths_agree(name):
    """use_pallas=True must be numerically identical to the jnp path."""
    s_ref = models.build(name, use_pallas=False)
    s_pal = models.build(name, use_pallas=True)
    flat = jnp.asarray(s_ref.init_flat(6))
    x, _ = _inputs(s_ref, batch=4, seed=7)
    a = s_ref.apply_flat(flat, x)
    b = s_pal.apply_flat(flat, x)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-4)


def test_registry_contents():
    assert set(models.REGISTRY) == set(ALL)


def test_cnn_dense_dominates_like_femnist():
    """Paper Fig. 3: FEMNIST's largest layer (fc1) dominates the model."""
    spec = models.build("cnn")
    table = spec.layer_table()
    fc1 = next(r for r in table if r["name"] == "fc1")
    assert fc1["size"] / spec.dim > 0.75
