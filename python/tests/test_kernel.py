"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes (client counts, vector lengths including
non-BLOCK-multiples, matmul dims) and asserts allclose against ref.py.
This is the core correctness signal for the aggregation hot path.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import aggregate, fused_dense, ref

jax.config.update("jax_platform_name", "cpu")


def _rng(seed):
    return np.random.default_rng(seed)


# ---------------------------------------------------------------- mean_reduce


@settings(max_examples=25, deadline=None)
@given(
    a=st.integers(min_value=1, max_value=40),
    d=st.integers(min_value=1, max_value=2000),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_mean_reduce_matches_ref(a, d, seed):
    u = _rng(seed).normal(size=(a, d)).astype(np.float32)
    got = aggregate.mean_reduce(jnp.asarray(u))
    want = ref.mean_reduce_ref(jnp.asarray(u))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_mean_reduce_exact_block_multiple():
    u = _rng(0).normal(size=(32, aggregate.BLOCK * 3)).astype(np.float32)
    got = aggregate.mean_reduce(jnp.asarray(u))
    np.testing.assert_allclose(got, u.mean(axis=0), rtol=1e-5, atol=1e-6)


def test_mean_reduce_single_client_is_identity():
    u = _rng(1).normal(size=(1, 777)).astype(np.float32)
    got = aggregate.mean_reduce(jnp.asarray(u))
    np.testing.assert_allclose(got, u[0], rtol=1e-6, atol=1e-6)


def test_mean_reduce_zeros():
    u = np.zeros((8, 100), dtype=np.float32)
    assert np.all(np.asarray(aggregate.mean_reduce(jnp.asarray(u))) == 0.0)


@settings(max_examples=15, deadline=None)
@given(
    a=st.integers(min_value=2, max_value=33),
    d=st.integers(min_value=1, max_value=1500),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_weighted_mean_reduce_matches_ref(a, d, seed):
    rng = _rng(seed)
    u = rng.normal(size=(a, d)).astype(np.float32)
    w = rng.dirichlet(np.ones(a)).astype(np.float32)
    got = aggregate.weighted_mean_reduce(jnp.asarray(u), jnp.asarray(w))
    want = ref.weighted_mean_reduce_ref(jnp.asarray(u), jnp.asarray(w))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_weighted_uniform_equals_mean():
    u = _rng(2).normal(size=(16, 513)).astype(np.float32)
    w = np.full(16, 1.0 / 16, dtype=np.float32)
    got = aggregate.weighted_mean_reduce(jnp.asarray(u), jnp.asarray(w))
    np.testing.assert_allclose(got, u.mean(axis=0), rtol=1e-4, atol=1e-5)


def test_vmem_estimate_under_budget():
    # a=32, BLOCK=512 must fit VMEM (~16 MiB) with double buffering.
    assert 2 * aggregate.vmem_bytes(32) < 16 * 2**20


# ---------------------------------------------------------------- fused_dense


@settings(max_examples=20, deadline=None)
@given(
    m=st.integers(min_value=1, max_value=70),
    k=st.integers(min_value=1, max_value=96),
    n=st.integers(min_value=1, max_value=200),
    act=st.sampled_from(["relu", "gelu", "none"]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_fused_dense_matches_ref(m, k, n, act, seed):
    rng = _rng(seed)
    x = rng.normal(size=(m, k)).astype(np.float32)
    w = rng.normal(size=(k, n)).astype(np.float32) / np.sqrt(k)
    b = rng.normal(size=(n,)).astype(np.float32)
    got = fused_dense.fused_dense(jnp.asarray(x), jnp.asarray(w), jnp.asarray(b), act)
    want = ref.fused_dense_ref(jnp.asarray(x), jnp.asarray(w), jnp.asarray(b), act)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("act", ["relu", "gelu", "none"])
def test_fused_dense_gradients_match_ref(act):
    rng = _rng(7)
    x = rng.normal(size=(8, 16)).astype(np.float32)
    w = rng.normal(size=(16, 24)).astype(np.float32) / 4.0
    b = rng.normal(size=(24,)).astype(np.float32)

    def loss_pallas(w, b):
        return (fused_dense.fused_dense(x, w, b, act) ** 2).sum()

    def loss_ref(w, b):
        return (ref.fused_dense_ref(x, w, b, act) ** 2).sum()

    gp = jax.grad(loss_pallas, argnums=(0, 1))(jnp.asarray(w), jnp.asarray(b))
    gr = jax.grad(loss_ref, argnums=(0, 1))(jnp.asarray(w), jnp.asarray(b))
    for a_, b_ in zip(gp, gr):
        np.testing.assert_allclose(a_, b_, rtol=1e-3, atol=1e-3)


def test_fused_dense_grad_wrt_input():
    rng = _rng(8)
    x = rng.normal(size=(4, 8)).astype(np.float32)
    w = rng.normal(size=(8, 8)).astype(np.float32)
    b = np.zeros(8, dtype=np.float32)
    gx = jax.grad(lambda x_: fused_dense.fused_dense(x_, w, b, "relu").sum())(jnp.asarray(x))
    gx_ref = jax.grad(lambda x_: ref.fused_dense_ref(x_, w, b, "relu").sum())(jnp.asarray(x))
    np.testing.assert_allclose(gx, gx_ref, rtol=1e-4, atol=1e-4)


def test_mxu_utilization_estimate_sane():
    u = fused_dense.mxu_utilization_estimate(32, 64, 128)
    assert 0.0 < u <= 1.0


# ---------------------------------------------------------------- layer_ssq


@settings(max_examples=15, deadline=None)
@given(
    sizes=st.lists(st.integers(min_value=1, max_value=50), min_size=1, max_size=8),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_layer_ssq_partitions_total(sizes, seed):
    offsets = np.concatenate([[0], np.cumsum(sizes)[:-1]]).tolist()
    d = int(sum(sizes))
    v = _rng(seed).normal(size=d).astype(np.float32)
    ssq = ref.layer_ssq_ref(jnp.asarray(v), offsets, sizes)
    assert ssq.shape == (len(sizes),)
    np.testing.assert_allclose(np.asarray(ssq).sum(), (v**2).sum(), rtol=1e-4)
