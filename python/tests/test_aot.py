"""AOT pipeline: lowering produces loadable HLO text + consistent metadata."""

import json
import os
import tempfile

import numpy as np
import pytest

from compile import aot, models


@pytest.fixture(scope="module")
def lowered():
    """Lower the small mlp once into a temp dir."""
    d = tempfile.mkdtemp(prefix="fedluar_aot_")
    cfg = dict(aot.DEFAULTS["mlp"])
    cfg["tau"] = 3  # keep the artifact small for the test
    meta = aot.lower_model("mlp", d, cfg, use_pallas_dense=False)
    return d, meta


def test_artifacts_exist(lowered):
    d, meta = lowered
    for key in ("train", "eval", "agg", "init"):
        assert os.path.exists(os.path.join(d, meta["artifacts"][key]))


def test_hlo_is_text_with_entry(lowered):
    d, meta = lowered
    for key in ("train", "eval", "agg"):
        text = open(os.path.join(d, meta["artifacts"][key])).read()
        assert "HloModule" in text
        assert "ENTRY" in text
        # jax >= 0.5 64-bit-id protos are the failure mode; text ids must parse
        assert len(text) > 100


def test_meta_layer_table_consistent(lowered):
    _, meta = lowered
    spec = models.build("mlp")
    assert meta["dim"] == spec.dim
    off = 0
    for row in meta["layers"]:
        assert row["offset"] == off
        off += row["size"]
    assert off == meta["dim"]


def test_init_bin_matches_meta(lowered):
    d, meta = lowered
    raw = np.fromfile(os.path.join(d, meta["artifacts"]["init"]), dtype=np.float32)
    assert raw.size == meta["dim"]
    import hashlib

    assert hashlib.sha256(raw.tobytes()).hexdigest() == meta["init_sha256"]


def test_meta_records_signature_fields(lowered):
    _, meta = lowered
    for key in ("tau", "batch", "eval_batch", "agg_clients", "input_dtype", "momentum"):
        assert key in meta


def test_defaults_cover_registry():
    assert set(aot.DEFAULTS) == set(models.REGISTRY)
