//! PJRT execution latency per artifact: literal construction, execute,
//! copy-out — the L3<->L2 boundary cost. Also compares the
//! Pallas-backed aggregation graph against the pure-Rust fallback
//! (EXPERIMENTS.md §Perf tracks this head-to-head).
//!
//! Requires `make artifacts`; skips a model if its artifacts are absent.

use fedluar::bench_harness::Bench;
use fedluar::data::{FedDataset, SynthSpec};
use fedluar::model::{artifacts_dir, ModelMeta};
use fedluar::rng::Rng;
use fedluar::runtime::Engine;
use fedluar::tensor;

fn dataset(eng: &Engine) -> FedDataset {
    let m = &eng.meta;
    let spec = if m.is_text() {
        SynthSpec::text(m.input_shape[0], 256, m.num_classes)
    } else {
        let (h, w, c) = match m.input_shape.len() {
            1 => (m.input_shape[0], 1, 1),
            _ => (m.input_shape[0], m.input_shape[1], m.input_shape[2]),
        };
        SynthSpec::vision(h, w, c, m.num_classes)
    };
    FedDataset::new(spec, 8, 128, 1.0, 512, 7)
}

fn main() {
    for model in ["mlp", "cnn", "resnet8", "transformer"] {
        let Ok(meta) = ModelMeta::load(artifacts_dir(), model) else {
            eprintln!("skip {model}: run `make artifacts`");
            continue;
        };
        let eng = Engine::load(meta).expect("engine");
        let ds = dataset(&eng);
        let params = eng.meta.load_init().unwrap();
        let (feats, labels) = ds.client_batches(0, 0, eng.meta.tau, eng.meta.batch);
        let d = eng.meta.dim;

        let mut b = Bench::new(&format!("{model}_d{d}")).with_times(300, 1200);
        b.bench("train_round(client local update)", None, || {
            std::hint::black_box(
                eng.train_round(&params, None, None, &feats, &labels, 0.01, 0.0, 0.0, 0.0)
                    .unwrap(),
            );
        });
        let (efeats, elabels, _) = ds.test_chunk(0, eng.meta.eval_batch);
        b.bench("eval_chunk", None, || {
            std::hint::black_box(eng.eval_chunk(&params, &efeats, &elabels).unwrap());
        });

        // Pallas agg graph vs pure-Rust mean at the same shape
        let a = eng.meta.agg_clients;
        let mut rng = Rng::seed_from_u64(1);
        let updates: Vec<Vec<f32>> =
            (0..a).map(|_| (0..d).map(|_| rng.normal_f32(0.0, 0.1)).collect()).collect();
        let refs: Vec<&[f32]> = updates.iter().map(|u| u.as_slice()).collect();
        let elems = Some((a * d) as u64);
        b.bench("agg_hlo(pallas mean+norms)", elems, || {
            std::hint::black_box(eng.aggregate(&refs, &params).unwrap());
        });
        let mut out = vec![0.0f32; d];
        b.bench("agg_rust(mean+norms fallback)", elems, || {
            tensor::mean_rows(&refs, &mut out);
            let mut acc = 0.0f64;
            for lm in &eng.meta.layers {
                acc += tensor::ssq(&out[lm.offset..lm.offset + lm.size]);
                acc += tensor::ssq(&params[lm.offset..lm.offset + lm.size]);
            }
            std::hint::black_box(acc);
        });
        b.compare("agg_rust(mean+norms fallback)", "agg_hlo(pallas mean+norms)");
        println!();
    }
}
