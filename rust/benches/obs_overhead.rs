//! Telemetry overhead: the cost of an obs/ instrumentation point at
//! each level. The contract (see `docs/observability.md`) is that a
//! disabled call site is one thread-local byte read plus a branch — no
//! allocation, no clock read — so `--obs off` runs pay effectively
//! nothing for being instrumentable. This bench pins that disabled
//! path and shows what enabling metrics / full tracing buys into.

use fedluar::bench_harness::Bench;
use fedluar::obs::{self, ObsCfg, ObsLevel};

fn main() {
    let mut b = Bench::new("obs_overhead");

    // --- level = off: every instrumentation point must be near-free
    obs::init(&ObsCfg::default()).unwrap();
    b.bench("span_off", None, || {
        let mut s = obs::span("bench.span");
        s.set_sim(1.0);
        std::hint::black_box(&s);
    });
    b.bench("counter_off", None, || obs::counter("bench.count", 1));
    b.bench("observe_off", None, || obs::observe("bench.histo", 1.0));
    assert_eq!(obs::spans_recorded(), 0, "off level must record nothing");
    assert_eq!(obs::counter_value("bench.count"), 0, "off level must record nothing");

    // --- level = metrics: registry updates armed, spans still disarmed
    obs::init(&ObsCfg { level: ObsLevel::Metrics, ..ObsCfg::default() }).unwrap();
    b.bench("counter_metrics", None, || obs::counter("bench.count", 1));
    b.bench("observe_metrics", None, || obs::observe("bench.histo", 1.0));
    b.bench("span_disarmed_metrics", None, || {
        let _s = obs::span("bench.span");
    });
    assert_eq!(obs::spans_recorded(), 0, "spans stay disarmed below level=full");
    obs::finish().unwrap();

    // --- level = full: span guards read the clock and feed the ring +
    //     the per-span duration histogram (no JSONL writer configured)
    obs::init(&ObsCfg { level: ObsLevel::Full, ..ObsCfg::default() }).unwrap();
    b.bench("span_full_ring", None, || {
        let mut s = obs::span("bench.span");
        s.set_sim(1.0);
        std::hint::black_box(&s);
    });
    assert!(obs::spans_recorded() > 0);
    obs::finish().unwrap();

    b.compare("span_off", "span_full_ring");
    b.compare("counter_off", "counter_metrics");
    let off_ns = b
        .results()
        .iter()
        .filter(|(n, _)| n.ends_with("_off"))
        .map(|(_, s)| s.mean_secs() * 1e9)
        .fold(0.0f64, f64::max);
    println!("\n  -> worst disabled call site: {off_ns:.1} ns (budget: a few ns; if this");
    println!("     grows, a gate stopped short-circuiting before the context lookup)");
}
