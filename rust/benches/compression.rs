//! Compression baselines throughput: the per-client upload transform
//! every non-LUAR method pays. FedLUAR's comparative advantage is that
//! its "compression" is free (layer skipping), so these numbers bound
//! the baselines' client-side overhead.

use fedluar::bench_harness::Bench;
use fedluar::compress::{
    Binarize, DropoutAvg, Lbgm, LowRank, Prune, Quantize, TopK, UpdateCompressor,
};
use fedluar::model::ModelMeta;
use fedluar::rng::Rng;
use std::path::PathBuf;

fn synth_meta(layers: usize, layer_size: usize) -> ModelMeta {
    // Build a JSON meta on the fly so the bench needs no artifacts.
    let mut rows = Vec::new();
    for l in 0..layers {
        let off = l * layer_size;
        rows.push(format!(
            r#"{{"name":"l{l}","kind":"dense","offset":{off},"size":{layer_size},
               "arrays":[{{"name":"w","shape":[{r},{c}],"offset":{off},"size":{layer_size}}}]}}"#,
            r = layer_size / 64,
            c = 64
        ));
    }
    let dim = layers * layer_size;
    let doc = format!(
        r#"{{"model":"bench","dim":{dim},"num_classes":10,
            "input_shape":[8],"input_dtype":"f32","tau":5,"batch":16,
            "eval_batch":64,"agg_clients":32,"momentum":0.9,
            "layers":[{}],
            "artifacts":{{"train":"t","eval":"e","agg":"g","init":"i"}},
            "init_sha256":"x"}}"#,
        rows.join(",")
    );
    ModelMeta::from_json(&doc, PathBuf::from("/tmp")).unwrap()
}

fn main() {
    let meta = synth_meta(10, 6400); // 64k params over 10 layers
    let d = meta.dim;
    let mut rng = Rng::seed_from_u64(3);
    let base: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 0.1)).collect();
    let elems = Some(d as u64);

    let mut b = Bench::new(&format!("compress_d{d}"));
    let mut crng = Rng::seed_from_u64(4);
    let mut buf = base.clone();
    let mut round = 0usize;

    let mut q = Quantize::new(16);
    b.bench("quantize16", elems, || {
        buf.copy_from_slice(&base);
        q.compress(0, &mut buf, &meta, round, &mut crng);
        round += 1;
        std::hint::black_box(&buf);
    });

    let mut bin = Binarize::new();
    b.bench("binarize_ef", elems, || {
        buf.copy_from_slice(&base);
        bin.compress(0, &mut buf, &meta, round, &mut crng);
        round += 1;
        std::hint::black_box(&buf);
    });

    let mut p = Prune::new(0.5, 10);
    b.bench("prune_keep50", elems, || {
        buf.copy_from_slice(&base);
        p.compress(0, &mut buf, &meta, round, &mut crng);
        round += 1;
        std::hint::black_box(&buf);
    });

    let mut dr = DropoutAvg::new(0.5);
    b.bench("dropout50", elems, || {
        buf.copy_from_slice(&base);
        dr.compress(0, &mut buf, &meta, round, &mut crng);
        round += 1;
        std::hint::black_box(&buf);
    });

    let mut tk = TopK::new(0.1);
    b.bench("topk10", elems, || {
        buf.copy_from_slice(&base);
        tk.compress(0, &mut buf, &meta, round, &mut crng);
        round += 1;
        std::hint::black_box(&buf);
    });

    let mut lb = Lbgm::new(0.6);
    b.bench("lbgm", elems, || {
        buf.copy_from_slice(&base);
        lb.compress(0, &mut buf, &meta, round, &mut crng);
        round += 1;
        std::hint::black_box(&buf);
    });

    let mut lr = LowRank::new(0.25);
    b.bench("lowrank25", elems, || {
        buf.copy_from_slice(&base);
        lr.compress(0, &mut buf, &meta, round, &mut crng);
        round += 1;
        std::hint::black_box(&buf);
    });

    println!("\nnote: FedLUAR pays none of these — recycling is layer skipping.");
}
