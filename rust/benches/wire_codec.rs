//! Wire codec throughput: the per-client serialization cost every
//! upload now pays. Dense encode is the FedAvg hot path (one memcpy-
//! shaped pass), so it sets the bar — the acceptance target is
//! >= 1 GB/s on a release build; the sparse/quantized/sign flavors
//! trade encode cycles for wire bytes.

use fedluar::bench_harness::Bench;
use fedluar::compress::{Binarize, Quantize, TopK, UpdateCompressor};
use fedluar::model::ModelMeta;
use fedluar::net::wire::{self, WireHint};
use fedluar::rng::Rng;
use std::path::PathBuf;

fn synth_meta(layers: usize, layer_size: usize) -> ModelMeta {
    let mut rows = Vec::new();
    for l in 0..layers {
        let off = l * layer_size;
        rows.push(format!(
            r#"{{"name":"l{l}","kind":"dense","offset":{off},"size":{layer_size},
               "arrays":[{{"name":"w","shape":[{r},{c}],"offset":{off},"size":{layer_size}}}]}}"#,
            r = layer_size / 64,
            c = 64
        ));
    }
    let dim = layers * layer_size;
    let doc = format!(
        r#"{{"model":"bench","dim":{dim},"num_classes":10,
            "input_shape":[8],"input_dtype":"f32","tau":5,"batch":16,
            "eval_batch":64,"agg_clients":32,"momentum":0.9,
            "layers":[{}],
            "artifacts":{{"train":"t","eval":"e","agg":"g","init":"i"}},
            "init_sha256":"x"}}"#,
        rows.join(",")
    );
    ModelMeta::from_json(&doc, PathBuf::from("/tmp")).unwrap()
}

fn main() {
    let meta = synth_meta(16, 65536); // ~1M params over 16 layers
    let d = meta.dim;
    let all: Vec<usize> = (0..meta.num_layers()).collect();
    let mut rng = Rng::seed_from_u64(3);
    let base: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 0.1)).collect();
    let elems = Some(d as u64);
    let mut b = Bench::new(&format!("wire_d{d}"));

    // dense encode: the throughput target (>= 1 GB/s release)
    b.bench("dense_encode", elems, || {
        let f = wire::encode_update(&base, &meta, &all, &WireHint::Dense).unwrap();
        std::hint::black_box(f.len());
    });
    let dense = wire::encode_update(&base, &meta, &all, &WireHint::Dense).unwrap();
    b.bench("dense_decode", elems, || {
        let v = wire::decode_update(dense.as_bytes(), &meta).unwrap();
        std::hint::black_box(&v);
    });

    // sparse: top-k 10% output
    let mut crng = Rng::seed_from_u64(4);
    let mut sparse_buf = base.clone();
    let mut tk = TopK::new(0.1);
    tk.compress(0, &mut sparse_buf, &meta, 0, &mut crng);
    b.bench("sparse_encode_k10", elems, || {
        let f = wire::encode_update(&sparse_buf, &meta, &all, &tk.wire_hint()).unwrap();
        std::hint::black_box(f.len());
    });
    let sparse = wire::encode_update(&sparse_buf, &meta, &all, &tk.wire_hint()).unwrap();
    b.bench("sparse_decode_k10", elems, || {
        let v = wire::decode_update(sparse.as_bytes(), &meta).unwrap();
        std::hint::black_box(&v);
    });

    // quantized: FedPAQ 16 levels (4-bit pack/unpack)
    let mut quant_buf = base.clone();
    let mut q = Quantize::new(16);
    q.compress(0, &mut quant_buf, &meta, 0, &mut crng);
    let qh = q.wire_hint();
    b.bench("quantized16_encode", elems, || {
        let f = wire::encode_update(&quant_buf, &meta, &all, &qh).unwrap();
        std::hint::black_box(f.len());
    });
    let quant = wire::encode_update(&quant_buf, &meta, &all, &qh).unwrap();
    b.bench("quantized16_decode", elems, || {
        let v = wire::decode_update(quant.as_bytes(), &meta).unwrap();
        std::hint::black_box(&v);
    });

    // sign bits: 1-bit pack
    let mut sign_buf = base.clone();
    let mut bin = Binarize::new();
    bin.compress(0, &mut sign_buf, &meta, 0, &mut crng);
    b.bench("signbits_encode", elems, || {
        let f = wire::encode_update(&sign_buf, &meta, &all, &WireHint::SignBits).unwrap();
        std::hint::black_box(f.len());
    });

    // delta: residual framing against a correlated reference (the
    // cross-round regime --delta-frames charges the ledger for)
    let cur: Vec<f32> = base.iter().map(|&r| r * (1.0 + 1e-3)).collect();
    b.bench("delta_encode_correlated", elems, || {
        let f = wire::encode_update_delta(&cur, &meta, &all, &base, 7).unwrap();
        std::hint::black_box(f.len());
    });
    let delta = wire::encode_update_delta(&cur, &meta, &all, &base, 7).unwrap();
    b.bench("delta_decode_correlated", elems, || {
        let v = wire::decode_update_delta(delta.as_bytes(), &meta, &base).unwrap();
        std::hint::black_box(&v);
    });

    b.compare("dense_encode", "quantized16_encode");
    b.compare("dense_encode", "delta_encode_correlated");
    println!(
        "\nwire bytes: dense {} | sparse10 {} | quant16 {} | delta {} — the codec\n\
         overhead the ledger now measures instead of estimating.",
        dense.len(),
        sparse.len(),
        quant.len(),
        delta.len()
    );
}
