//! End-to-end round latency: the whole Algorithm 2 iteration (client
//! sampling, 32 local updates through PJRT, aggregation, LUAR
//! decision, server update, accounting) for FedAvg vs FedLUAR.
//!
//! The paper's claim is that LUAR adds "little to no additional
//! computational cost" — the FedLUAR/FedAvg ratio here is that claim,
//! measured. Requires `make artifacts`.

use fedluar::bench_harness::Bench;
use fedluar::config::{Method, RunConfig};
use fedluar::fl::Server;

fn main() {
    for model in ["mlp", "transformer"] {
        for (label, method, delta) in [
            ("fedavg", Method::FedAvg, 0usize),
            ("fedluar", Method::luar(2), 2),
        ] {
            let mut cfg = match RunConfig::benchmark(model) {
                Ok(c) => c,
                Err(e) => {
                    eprintln!("skip {model}: {e}");
                    continue;
                }
            };
            cfg.method = if delta > 0 {
                Method::luar(if model == "transformer" { 6 } else { 2 })
            } else {
                method.clone()
            };
            cfg.eval_every = 0; // isolate the round loop
            cfg.rounds = usize::MAX; // driven manually
            let mut server = match Server::new(cfg) {
                Ok(s) => s,
                Err(e) => {
                    eprintln!("skip {model}: {e:#}");
                    continue;
                }
            };
            let mut b = Bench::new(&format!("round_{model}")).with_times(500, 2500);
            b.bench(label, None, || {
                server.run_round().unwrap();
            });
        }
        println!();
    }
    println!("note: fedluar/fedavg ~ 1.0 reproduces the paper's 'little to no");
    println!("additional computational cost' claim (the savings are in bytes).");
}
