//! Async scheduler throughput: the per-event cost of the barrier-free
//! runtime. The persistent `AsyncQueue` (binary heap + seq tie-break)
//! and the `AsyncRuntime` dispatch/absorb cycle sit on the server's
//! hot path once rounds disappear — one pop/push pair per upload, so
//! the budget is millions of events per second, with the payload move
//! (the decoded delta `Vec`) dominating at realistic model sizes.

use fedluar::bench_harness::Bench;
use fedluar::fl::{AsyncRuntime, UploadPayload};
use fedluar::model::ModelMeta;
use fedluar::net::sched::{simulate_round, RoundMode};
use fedluar::net::{
    speed_cohort, wire, AsyncQueue, ClientStats, LinkDist, LinkFleet, Staleness,
};
use fedluar::rng::Rng;
use std::path::PathBuf;

fn synth_meta(layers: usize, layer_size: usize) -> ModelMeta {
    let mut rows = Vec::new();
    for l in 0..layers {
        let off = l * layer_size;
        rows.push(format!(
            r#"{{"name":"l{l}","kind":"dense","offset":{off},"size":{layer_size},
               "arrays":[{{"name":"w","shape":[{r},{c}],"offset":{off},"size":{layer_size}}}]}}"#,
            r = layer_size / 64,
            c = 64
        ));
    }
    let dim = layers * layer_size;
    let doc = format!(
        r#"{{"model":"bench","dim":{dim},"num_classes":10,
            "input_shape":[8],"input_dtype":"f32","tau":5,"batch":16,
            "eval_batch":64,"agg_clients":32,"momentum":0.9,
            "layers":[{}],
            "artifacts":{{"train":"t","eval":"e","agg":"g","init":"i"}},
            "init_sha256":"x"}}"#,
        rows.join(",")
    );
    ModelMeta::from_json(&doc, PathBuf::from("/tmp")).unwrap()
}

fn main() {
    let mut b = Bench::new("async_sched");
    let mut rng = Rng::seed_from_u64(17);

    // 1) queue-level churn: 4096 events resident, one pop/push per op
    //    (the steady-state async server with 4096 clients in flight)
    let mut q = AsyncQueue::new();
    let mut seq = 0u64;
    let mut t = 0.0f64;
    for _ in 0..4096 {
        q.push(1.0 + rng.f64(), seq);
        seq += 1;
    }
    let churn = b.bench("queue_pop_push_4096", None, || {
        for (et, _) in q.pop_instant() {
            t = et;
            q.push(t + 1.0 + (seq % 97) as f64 * 1e-3, seq);
            seq += 1;
        }
    });
    println!(
        "  -> {:.2} M events/s through the persistent queue",
        1.0 / churn.mean_secs() / 1e6
    );

    // 2) runtime-level cycle with realistic payloads: dispatch to the
    //    concurrency cap, absorb one instant, aggregate when ready.
    //    dim=16384 => the per-event cost is dominated by moving the
    //    decoded delta into and out of the buffer.
    const DIM: usize = 16_384;
    let delta: Vec<f32> = (0..DIM).map(|i| (i % 31) as f32 * 0.01).collect();
    let mut rt = AsyncRuntime::new(1024, 64, 32, Staleness::Poly { a: 0.5 });
    let mut client = 0usize;
    let cycle = b.bench("runtime_cycle_c64_d16k", Some(DIM as u64), || {
        while rt.wants_dispatch() {
            client = (client + 1) % 1024;
            rt.dispatch(
                UploadPayload {
                    client,
                    version: rt.version,
                    gen: rt.version,
                    delta: delta.clone(),
                    loss: 0.5,
                    frame_len: (DIM * 4) as u64,
                    bcast_len: (DIM * 4) as u64,
                },
                0.5 + (client % 89) as f64 * 1e-3,
            );
        }
        rt.absorb_instant();
        if rt.ready() {
            let batch = rt.take_aggregation();
            std::hint::black_box(batch.uploads.len());
        }
    });
    println!(
        "  -> {:.2} us per absorb cycle at dim {DIM}",
        cycle.mean_secs() * 1e6
    );

    // 3) context: the round-based scheduler's whole-cohort cost (the
    //    path the barrier modes still take, 64 clients per call)
    let times: Vec<f64> = (0..64).map(|i| 0.1 + (i % 13) as f64 * 0.017).collect();
    b.bench("simulate_round_buffered_64", None, || {
        let out = simulate_round(&RoundMode::Buffered { k: 8 }, &times);
        std::hint::black_box(out.aggregated);
    });

    b.compare("queue_pop_push_4096", "simulate_round_buffered_64");

    // 4) broadcast memoization: `Server::dispatch_next_async` used to
    //    re-encode the broadcast frame for every dispatched client even
    //    though the server model only changes when a version closes.
    //    The per-version cache turns the within-version cost from a
    //    full encode into a frame-length read; this pair measures the
    //    spread at a realistic model size (~0.5 M params).
    let meta = synth_meta(8, 65536);
    let params: Vec<f32> = (0..meta.dim).map(|i| (i % 37) as f32 * 0.01).collect();
    let recycle = [2usize, 5];
    let elems = Some(meta.dim as u64);
    b.bench("bcast_encode_per_dispatch", elems, || {
        let f = wire::encode_broadcast(&params, &meta, &recycle).unwrap();
        std::hint::black_box(f.len());
    });
    let cached = wire::encode_broadcast(&params, &meta, &recycle).unwrap();
    b.bench("bcast_cached_reuse", elems, || {
        std::hint::black_box(cached.len());
        std::hint::black_box(cached.as_bytes().first());
    });
    b.compare("bcast_cached_reuse", "bcast_encode_per_dispatch");

    // 5) straggler-aware sampling: the per-round cohort-draw cost of
    //    the telemetry-weighted sampler vs the legacy uniform draw at
    //    fleet scale (256 clients, 32 per cohort), plus the simulated
    //    wall-clock each schedule buys on a bimodal straggler fleet —
    //    the draw costs microseconds, the biased schedule saves
    //    simulated minutes.
    const FLEET: usize = 256;
    const COHORT: usize = 32;
    let fleet = LinkFleet::new(
        &LinkDist::Bimodal {
            fast_frac: 0.75,
            fast_up_mbps: 80.0,
            slow_up_mbps: 1.0,
            down_mbps: 100.0,
            rtt_s: 0.0,
        },
        FLEET,
        42,
    );
    let frame = 1u64 << 20; // 1 MiB upload
    let mut stats = ClientStats::new(FLEET);
    for c in 0..FLEET {
        stats.record_dispatch(c, fleet.link(c).upload_secs(frame), frame);
    }
    let mut round = 0usize;
    b.bench("cohort_uniform_draw_256", None, || {
        let mut r = Rng::seed_from_u64(17 ^ 0xc11e_0000 ^ round as u64);
        std::hint::black_box(r.sample_indices(FLEET, COHORT));
        round += 1;
    });
    let mut round = 0usize;
    b.bench("cohort_speed_draw_256", None, || {
        std::hint::black_box(speed_cohort(&stats, 1.0, round, COHORT, 17));
        round += 1;
    });
    b.compare("cohort_uniform_draw_256", "cohort_speed_draw_256");

    let round_secs = |cohort: &[usize]| {
        cohort.iter().map(|&c| fleet.link(c).upload_secs(frame)).fold(0.0f64, f64::max)
    };
    let (mut uni, mut spd) = (0.0f64, 0.0f64);
    for t in 0..50usize {
        let mut r = Rng::seed_from_u64(17 ^ 0xc11e_0000 ^ t as u64);
        uni += round_secs(&r.sample_indices(FLEET, COHORT));
        spd += round_secs(&speed_cohort(&stats, 1.0, t, COHORT, 17));
    }
    println!(
        "  -> simulated wall-clock over 50 bimodal rounds: \
         uniform {uni:.1}s vs speed:pow=1 {spd:.1}s"
    );
}
