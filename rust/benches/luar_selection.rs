//! LUAR server-side decision costs: Eq. 1 score update, Eq. 2
//! probability computation, weighted sampling, and the full
//! compose+select step at realistic layer counts. The paper claims
//! the metric is measurable "without any extra communications" and
//! negligible compute — these numbers quantify that.

use fedluar::bench_harness::Bench;
use fedluar::config::{RecycleMode, SelectionScheme};
use fedluar::luar::LuarState;
use fedluar::model::ModelMeta;
use fedluar::rng::Rng;
use std::path::PathBuf;

fn synth_meta(layers: usize, layer_size: usize) -> ModelMeta {
    let mut rows = Vec::new();
    for l in 0..layers {
        let off = l * layer_size;
        rows.push(format!(
            r#"{{"name":"l{l}","kind":"dense","offset":{off},"size":{layer_size},"arrays":[]}}"#
        ));
    }
    let dim = layers * layer_size;
    let doc = format!(
        r#"{{"model":"bench","dim":{dim},"num_classes":10,
            "input_shape":[8],"input_dtype":"f32","tau":5,"batch":16,
            "eval_batch":64,"agg_clients":32,"momentum":0.9,
            "layers":[{}],
            "artifacts":{{"train":"t","eval":"e","agg":"g","init":"i"}},
            "init_sha256":"x"}}"#,
        rows.join(",")
    );
    ModelMeta::from_json(&doc, PathBuf::from("/tmp")).unwrap()
}

fn main() {
    for &num_layers in &[10usize, 40, 200] {
        let layer_size = 4096;
        let meta = synth_meta(num_layers, layer_size);
        let d = meta.dim;
        let mut rng = Rng::seed_from_u64(5);
        let u_ssq: Vec<f32> = (0..num_layers).map(|_| rng.f32() + 0.01).collect();
        let w_ssq: Vec<f32> = (0..num_layers).map(|_| rng.f32() * 10.0 + 0.1).collect();
        let mean_template: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 0.1)).collect();

        let mut b = Bench::new(&format!("luar_L{num_layers}"));
        let mut st = LuarState::new(num_layers, d);
        b.bench("update_scores", None, || {
            st.update_scores(&u_ssq, &w_ssq);
            std::hint::black_box(&st.scores);
        });
        b.bench("probabilities", None, || {
            std::hint::black_box(st.probabilities());
        });
        let probs = st.probabilities();
        let mut srng = Rng::seed_from_u64(6);
        let delta = num_layers / 2;
        b.bench("weighted_sample", None, || {
            std::hint::black_box(srng.weighted_sample_without_replacement(&probs, delta));
        });
        let grad_norms: Vec<f64> = u_ssq.iter().map(|&s| (s as f64).sqrt()).collect();
        let mut mean = mean_template.clone();
        b.bench("full_round_decision", Some(d as u64), || {
            mean.copy_from_slice(&mean_template);
            st.update_scores(&u_ssq, &w_ssq);
            std::hint::black_box(st.compose_update(&mut mean, &meta, RecycleMode::Recycle));
            st.select_next(SelectionScheme::Luar, delta, &grad_norms, &mut srng);
        });
    }
    println!("\nnote: full_round_decision is dominated by the d-sized buffer");
    println!("copy in compose_update; the selection math itself is O(L).");
}
