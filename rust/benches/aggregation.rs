//! L3 aggregation hot path: pure-Rust mean reduction (serial vs
//! threaded) and per-layer norm computation over realistic model
//! sizes. This is the server-side cost every round pays; compare with
//! the Pallas-backed HLO aggregation in `runtime_exec`.

use fedluar::bench_harness::Bench;
use fedluar::rng::Rng;
use fedluar::tensor;

fn make_updates(a: usize, d: usize) -> Vec<Vec<f32>> {
    let mut rng = Rng::seed_from_u64(1);
    (0..a).map(|_| (0..d).map(|_| rng.normal_f32(0.0, 0.1)).collect()).collect()
}

fn main() {
    println!("== aggregation (a=32 clients) ==");
    for &d in &[14_890usize, 71_754, 1_000_000] {
        let updates = make_updates(32, d);
        let refs: Vec<&[f32]> = updates.iter().map(|u| u.as_slice()).collect();
        let mut out = vec![0.0f32; d];
        let elems = Some((32 * d) as u64);
        let mut b = Bench::new(&format!("mean_d{d}"));
        b.bench("mean_rows_serial", elems, || {
            tensor::mean_rows(&refs, &mut out);
            std::hint::black_box(&out);
        });
        b.bench("mean_rows_par", elems, || {
            tensor::mean_rows_par(&refs, &mut out);
            std::hint::black_box(&out);
        });
        b.compare("mean_rows_serial", "mean_rows_par");
    }

    println!("\n== per-layer norms (Eq. 1 inputs) ==");
    let d = 206_922; // cnn-scale
    let mut rng = Rng::seed_from_u64(2);
    let v: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    // 10-layer split
    let bounds: Vec<usize> = (0..=10).map(|i| i * d / 10).collect();
    let mut b = Bench::new("layer_ssq");
    b.bench("ssq_10_layers", Some(d as u64), || {
        let mut acc = 0.0f64;
        for w in bounds.windows(2) {
            acc += tensor::ssq(&v[w[0]..w[1]]);
        }
        std::hint::black_box(acc);
    });

    println!("\n== weighted mean (client weighting) ==");
    let updates = make_updates(32, 71_754);
    let refs: Vec<&[f32]> = updates.iter().map(|u| u.as_slice()).collect();
    let w = vec![1.0 / 32.0; 32];
    let mut out = vec![0.0f32; 71_754];
    let mut b = Bench::new("wmean_d71754");
    b.bench("weighted_mean_rows", Some((32 * 71_754) as u64), || {
        tensor::weighted_mean_rows(&refs, &w, &mut out);
        std::hint::black_box(&out);
    });
}
