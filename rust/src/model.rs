//! Model metadata: the layer table emitted by `python/compile/aot.py`.
//!
//! This is the contract between the L2 graphs and the L3 coordinator:
//! layer names, kinds, and flat-vector offsets. Everything LUAR does
//! (scoring, recycling, per-layer communication accounting) consumes
//! this table. Parsed with the in-tree JSON parser (offline build).

use crate::json::Json;
use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};

#[derive(Debug, Clone)]
pub struct ArrayMeta {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub size: usize,
}

#[derive(Debug, Clone)]
pub struct LayerMeta {
    pub name: String,
    pub kind: String,
    pub offset: usize,
    pub size: usize,
    pub arrays: Vec<ArrayMeta>,
}

#[derive(Debug, Clone)]
pub struct ArtifactFiles {
    pub train: String,
    pub eval: String,
    pub agg: String,
    pub init: String,
}

/// Parsed `<model>.meta.json` plus the directory it was loaded from.
#[derive(Debug, Clone)]
pub struct ModelMeta {
    pub model: String,
    pub dim: usize,
    pub num_classes: usize,
    pub input_shape: Vec<usize>,
    pub input_dtype: String, // "f32" | "i32"
    pub tau: usize,
    pub batch: usize,
    pub eval_batch: usize,
    pub agg_clients: usize,
    pub momentum: f32,
    pub layers: Vec<LayerMeta>,
    pub artifacts: ArtifactFiles,
    pub init_sha256: String,
    pub dir: PathBuf,
}

fn usize_arr(j: &Json) -> Result<Vec<usize>> {
    j.as_arr()?.iter().map(|v| v.as_usize()).collect()
}

impl ModelMeta {
    pub fn load(artifacts_dir: impl AsRef<Path>, model: &str) -> Result<Self> {
        let dir = artifacts_dir.as_ref().to_path_buf();
        let path = dir.join(format!("{model}.meta.json"));
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?}; run `make artifacts` first"))?;
        let meta = Self::from_json(&text, dir)?;
        meta.validate()?;
        Ok(meta)
    }

    pub fn from_json(text: &str, dir: PathBuf) -> Result<Self> {
        let j = Json::parse(text)?;
        let mut layers = Vec::new();
        for l in j.get("layers")?.as_arr()? {
            let mut arrays = Vec::new();
            for a in l.get("arrays")?.as_arr()? {
                arrays.push(ArrayMeta {
                    name: a.get("name")?.as_str()?.to_string(),
                    shape: usize_arr(a.get("shape")?)?,
                    offset: a.get("offset")?.as_usize()?,
                    size: a.get("size")?.as_usize()?,
                });
            }
            layers.push(LayerMeta {
                name: l.get("name")?.as_str()?.to_string(),
                kind: l.get("kind")?.as_str()?.to_string(),
                offset: l.get("offset")?.as_usize()?,
                size: l.get("size")?.as_usize()?,
                arrays,
            });
        }
        let arts = j.get("artifacts")?;
        Ok(ModelMeta {
            model: j.get("model")?.as_str()?.to_string(),
            dim: j.get("dim")?.as_usize()?,
            num_classes: j.get("num_classes")?.as_usize()?,
            input_shape: usize_arr(j.get("input_shape")?)?,
            input_dtype: j.get("input_dtype")?.as_str()?.to_string(),
            tau: j.get("tau")?.as_usize()?,
            batch: j.get("batch")?.as_usize()?,
            eval_batch: j.get("eval_batch")?.as_usize()?,
            agg_clients: j.get("agg_clients")?.as_usize()?,
            momentum: j.get("momentum")?.as_f64()? as f32,
            layers,
            artifacts: ArtifactFiles {
                train: arts.get("train")?.as_str()?.to_string(),
                eval: arts.get("eval")?.as_str()?.to_string(),
                agg: arts.get("agg")?.as_str()?.to_string(),
                init: arts.get("init")?.as_str()?.to_string(),
            },
            init_sha256: j.get("init_sha256")?.as_str()?.to_string(),
            dir,
        })
    }

    /// Consistency checks on the layer table (mirrors the pytest side).
    pub fn validate(&self) -> Result<()> {
        let mut off = 0usize;
        for l in &self.layers {
            if l.offset != off {
                bail!("layer {} offset {} != expected {}", l.name, l.offset, off);
            }
            if !l.arrays.is_empty() {
                let arr_total: usize = l.arrays.iter().map(|a| a.size).sum();
                if arr_total != l.size {
                    bail!("layer {} arrays sum {} != size {}", l.name, arr_total, l.size);
                }
            }
            off += l.size;
        }
        if off != self.dim {
            bail!("layer sizes sum {} != dim {}", off, self.dim);
        }
        if self.input_dtype != "f32" && self.input_dtype != "i32" {
            bail!("unsupported input dtype {}", self.input_dtype);
        }
        Ok(())
    }

    pub fn num_layers(&self) -> usize {
        self.layers.len()
    }

    /// Number of scalar input features (product of input_shape).
    pub fn input_elems(&self) -> usize {
        self.input_shape.iter().product()
    }

    pub fn is_text(&self) -> bool {
        self.input_dtype == "i32"
    }

    /// Slice layer `l` out of a flat vector.
    pub fn layer<'a>(&self, flat: &'a [f32], l: usize) -> &'a [f32] {
        let m = &self.layers[l];
        &flat[m.offset..m.offset + m.size]
    }

    pub fn layer_mut<'a>(&self, flat: &'a mut [f32], l: usize) -> &'a mut [f32] {
        let m = &self.layers[l];
        &mut flat[m.offset..m.offset + m.size]
    }

    /// Load `<model>.init.bin` (raw little-endian f32) as the initial
    /// global parameters.
    pub fn load_init(&self) -> Result<Vec<f32>> {
        let path = self.dir.join(&self.artifacts.init);
        let bytes = std::fs::read(&path).with_context(|| format!("reading {path:?}"))?;
        if bytes.len() != self.dim * 4 {
            bail!("init.bin has {} bytes, expected {}", bytes.len(), self.dim * 4);
        }
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    pub fn artifact_path(&self, file: &str) -> PathBuf {
        self.dir.join(file)
    }

    /// Bytes to upload the full model update (f32).
    pub fn full_bytes(&self) -> u64 {
        (self.dim as u64) * 4
    }

    /// Bytes for the given subset of layers.
    pub fn layer_bytes(&self, layers: &[usize]) -> u64 {
        layers.iter().map(|&l| (self.layers[l].size as u64) * 4).sum()
    }
}

/// Default artifacts directory: `$FEDLUAR_ARTIFACTS` or `./artifacts`.
pub fn artifacts_dir() -> PathBuf {
    std::env::var_os("FEDLUAR_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    pub const TOY: &str = r#"{
        "model":"toy","dim":10,"num_classes":2,
        "input_shape":[4],"input_dtype":"f32",
        "tau":2,"batch":3,"eval_batch":8,"agg_clients":4,"momentum":0.9,
        "layers":[
          {"name":"a","kind":"dense","offset":0,"size":6,
           "arrays":[{"name":"w","shape":[2,2],"offset":0,"size":4},
                      {"name":"b","shape":[2],"offset":4,"size":2}]},
          {"name":"b","kind":"dense","offset":6,"size":4,
           "arrays":[{"name":"w","shape":[4],"offset":6,"size":4}]}
        ],
        "artifacts":{"train":"t","eval":"e","agg":"g","init":"i"},
        "init_sha256":"x"
    }"#;

    fn toy_meta() -> ModelMeta {
        ModelMeta::from_json(TOY, PathBuf::from("/tmp")).unwrap()
    }

    #[test]
    fn parse_and_validate_ok() {
        let m = toy_meta();
        m.validate().unwrap();
        assert_eq!(m.model, "toy");
        assert_eq!(m.num_layers(), 2);
        assert_eq!(m.input_elems(), 4);
        assert!(!m.is_text());
        assert_eq!(m.layers[0].arrays[1].shape, vec![2]);
    }

    #[test]
    fn validate_rejects_gap() {
        let mut m = toy_meta();
        m.layers[1].offset = 7;
        assert!(m.validate().is_err());
    }

    #[test]
    fn validate_rejects_bad_total() {
        let mut m = toy_meta();
        m.dim = 11;
        assert!(m.validate().is_err());
    }

    #[test]
    fn validate_rejects_bad_dtype() {
        let mut m = toy_meta();
        m.input_dtype = "f64".into();
        assert!(m.validate().is_err());
    }

    #[test]
    fn layer_slicing() {
        let m = toy_meta();
        let flat: Vec<f32> = (0..10).map(|i| i as f32).collect();
        assert_eq!(m.layer(&flat, 0), &flat[0..6]);
        assert_eq!(m.layer(&flat, 1), &flat[6..10]);
    }

    #[test]
    fn byte_accounting() {
        let m = toy_meta();
        assert_eq!(m.full_bytes(), 40);
        assert_eq!(m.layer_bytes(&[0]), 24);
        assert_eq!(m.layer_bytes(&[0, 1]), 40);
    }

    #[test]
    fn missing_key_is_loud() {
        let broken = TOY.replace("\"dim\":10,", "");
        assert!(ModelMeta::from_json(&broken, PathBuf::from("/tmp")).is_err());
    }
}
