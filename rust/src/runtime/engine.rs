//! The PJRT engine: one compiled executable per lowered graph.

#![allow(clippy::disallowed_methods)] // engine timings are telemetry, not simulation state
use super::literal::{features_literal, i32_literal, scalar_f32, vec_f32_literal};
use crate::data::{FedDataset, Features};
use crate::model::ModelMeta;
use anyhow::{bail, Context, Result};
use std::cell::RefCell;
use std::time::Instant;
use xla::{HloModuleProto, Literal, PjRtClient, PjRtLoadedExecutable, XlaComputation};

/// Result of one client's local-training call (Alg. 2 lines 6-10).
#[derive(Debug, Clone)]
pub struct TrainOutput {
    /// Accumulated local update Delta_t^i = x_tau - x_0 (flat).
    pub delta: Vec<f32>,
    /// Mean training loss across the tau local steps.
    pub loss: f32,
}

/// Result of one eval-chunk call.
#[derive(Debug, Clone, Copy)]
pub struct EvalOutput {
    /// Sum of per-sample NLL over the chunk.
    pub loss_sum: f32,
    /// Number of correct top-1 predictions in the chunk.
    pub correct: i32,
}

/// Result of the Pallas-backed server aggregation call.
#[derive(Debug, Clone)]
pub struct AggOutput {
    /// Mean client update (FedAvg numerator), length d.
    pub mean: Vec<f32>,
    /// Per-layer squared norms of the mean update (Eq. 1 numerator^2).
    pub update_ssq: Vec<f32>,
    /// Per-layer squared norms of the global params (Eq. 1 denominator^2).
    pub weight_ssq: Vec<f32>,
}

/// Cumulative execution statistics (perf instrumentation).
#[derive(Debug, Clone, Copy, Default)]
pub struct ExecStats {
    pub train_calls: u64,
    pub train_secs: f64,
    pub eval_calls: u64,
    pub eval_secs: f64,
    pub agg_calls: u64,
    pub agg_secs: f64,
}

pub struct Engine {
    client: PjRtClient,
    pub meta: ModelMeta,
    train: PjRtLoadedExecutable,
    eval: PjRtLoadedExecutable,
    agg: PjRtLoadedExecutable,
    /// Cached d-length zero literal for unused anchors (hot-path reuse).
    zeros: Literal,
    stats: RefCell<ExecStats>,
}

impl Engine {
    /// Load + compile the model's three artifacts on the PJRT CPU client.
    pub fn load(meta: ModelMeta) -> Result<Self> {
        let client = PjRtClient::cpu().context("creating PJRT CPU client")?;
        let compile = |file: &str| -> Result<PjRtLoadedExecutable> {
            let path = meta.artifact_path(file);
            let proto = HloModuleProto::from_text_file(&path)
                .with_context(|| format!("parsing HLO text {path:?}"))?;
            let comp = XlaComputation::from_proto(&proto);
            client.compile(&comp).with_context(|| format!("compiling {file}"))
        };
        let train = compile(&meta.artifacts.train)?;
        let eval = compile(&meta.artifacts.eval)?;
        let agg = compile(&meta.artifacts.agg)?;
        let zeros = vec_f32_literal(&vec![0.0; meta.dim], &[meta.dim])?;
        Ok(Engine { client, meta, train, eval, agg, zeros, stats: RefCell::default() })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn stats(&self) -> ExecStats {
        *self.stats.borrow()
    }

    fn feat_dims(&self, leading: &[usize]) -> Vec<usize> {
        let mut dims = leading.to_vec();
        dims.extend_from_slice(&self.meta.input_shape);
        dims
    }

    /// Run the lowered local-training graph:
    /// (params, anchor_g, anchor_prev, xs[tau,B,...], ys, lr, mu_g,
    /// mu_prev, wd) -> (delta, mean_loss).
    #[allow(clippy::too_many_arguments)]
    pub fn train_round(
        &self,
        params: &[f32],
        anchor_g: Option<&[f32]>,
        anchor_prev: Option<&[f32]>,
        feats: &Features,
        labels: &[i32],
        lr: f32,
        mu_g: f32,
        mu_prev: f32,
        wd: f32,
    ) -> Result<TrainOutput> {
        let t0 = Instant::now();
        let m = &self.meta;
        let (tau, batch, d) = (m.tau, m.batch, m.dim);
        if labels.len() != tau * batch {
            bail!("labels len {} != tau*batch {}", labels.len(), tau * batch);
        }
        let p_lit = vec_f32_literal(params, &[d])?;
        let ag_lit = match anchor_g {
            Some(a) => Some(vec_f32_literal(a, &[d])?),
            None => None,
        };
        let ap_lit = match anchor_prev {
            Some(a) => Some(vec_f32_literal(a, &[d])?),
            None => None,
        };
        let xs = features_literal(feats, &self.feat_dims(&[tau, batch]))?;
        let ys = i32_literal(labels, &[tau, batch])?;
        let lr_l = scalar_f32(lr);
        let mug_l = scalar_f32(mu_g);
        let mup_l = scalar_f32(mu_prev);
        let wd_l = scalar_f32(wd);
        let args: Vec<&Literal> = vec![
            &p_lit,
            ag_lit.as_ref().unwrap_or(&self.zeros),
            ap_lit.as_ref().unwrap_or(&self.zeros),
            &xs,
            &ys,
            &lr_l,
            &mug_l,
            &mup_l,
            &wd_l,
        ];
        let result = {
            let _sp = crate::obs::span("pjrt.train_exec");
            self.train.execute::<&Literal>(&args)?[0][0].to_literal_sync()?
        };
        let (delta_lit, loss_lit) = result.to_tuple2()?;
        let delta = delta_lit.to_vec::<f32>()?;
        let loss = loss_lit.to_vec::<f32>()?[0];
        let mut s = self.stats.borrow_mut();
        s.train_calls += 1;
        s.train_secs += t0.elapsed().as_secs_f64();
        Ok(TrainOutput { delta, loss })
    }

    /// Run the eval graph on one fixed-size chunk.
    pub fn eval_chunk(&self, params: &[f32], feats: &Features, labels: &[i32]) -> Result<EvalOutput> {
        let t0 = Instant::now();
        let m = &self.meta;
        if labels.len() != m.eval_batch {
            bail!("labels len {} != eval_batch {}", labels.len(), m.eval_batch);
        }
        let p_lit = vec_f32_literal(params, &[m.dim])?;
        let xs = features_literal(feats, &self.feat_dims(&[m.eval_batch]))?;
        let ys = i32_literal(labels, &[m.eval_batch])?;
        let args: Vec<&Literal> = vec![&p_lit, &xs, &ys];
        let result = {
            let _sp = crate::obs::span("pjrt.eval_exec");
            self.eval.execute::<&Literal>(&args)?[0][0].to_literal_sync()?
        };
        let (loss_lit, correct_lit) = result.to_tuple2()?;
        let out = EvalOutput {
            loss_sum: loss_lit.to_vec::<f32>()?[0],
            correct: correct_lit.to_vec::<i32>()?[0],
        };
        let mut s = self.stats.borrow_mut();
        s.eval_calls += 1;
        s.eval_secs += t0.elapsed().as_secs_f64();
        Ok(out)
    }

    /// Evaluate over the whole test split of a dataset; per-sample
    /// exactness via the valid-count masking of the final chunk.
    /// Returns (mean_loss, accuracy).
    pub fn eval_dataset(&self, params: &[f32], ds: &FedDataset) -> Result<(f64, f64)> {
        let chunk = self.meta.eval_batch;
        let total = ds.test_len();
        let full_chunks = total / chunk;
        let mut loss_sum = 0.0f64;
        let mut correct = 0i64;
        for c in 0..full_chunks {
            let (feats, labels, _) = ds.test_chunk(c * chunk, chunk);
            let out = self.eval_chunk(params, &feats, &labels)?;
            loss_sum += out.loss_sum as f64;
            correct += out.correct as i64;
        }
        let tail = total - full_chunks * chunk;
        let mut counted = full_chunks * chunk;
        if tail > 0 && full_chunks > 0 {
            // The eval graph has a fixed batch; shift the final window
            // back so it is fully in-range. The overlap with the last
            // full chunk is double-counted, so weight the shifted
            // window by tail/chunk (samples are iid by construction).
            let (feats, labels, _) = ds.test_chunk(total - chunk, chunk);
            let out = self.eval_chunk(params, &feats, &labels)?;
            let w = tail as f64 / chunk as f64;
            loss_sum += out.loss_sum as f64 * w;
            correct += ((out.correct as f64) * w).round() as i64;
            counted += tail;
        } else if full_chunks == 0 {
            // Dataset smaller than one chunk: wrap-padded single chunk,
            // scaled to the valid fraction.
            let (feats, labels, valid) = ds.test_chunk(0, chunk);
            let out = self.eval_chunk(params, &feats, &labels)?;
            let w = valid as f64 / chunk as f64;
            loss_sum += out.loss_sum as f64 * w;
            correct += ((out.correct as f64) * w).round() as i64;
            counted = valid;
        }
        Ok((loss_sum / counted as f64, correct as f64 / counted as f64))
    }

    /// Run the Pallas-backed aggregation graph. Requires exactly
    /// `meta.agg_clients` updates (the lowered static shape); callers
    /// with a different count use the pure-Rust fallback in
    /// `tensor::mean_rows_par`.
    pub fn aggregate(&self, updates: &[&[f32]], params: &[f32]) -> Result<AggOutput> {
        let t0 = Instant::now();
        let m = &self.meta;
        let a = m.agg_clients;
        if updates.len() != a {
            bail!("agg graph lowered for {} clients, got {}", a, updates.len());
        }
        let mut stacked = Vec::with_capacity(a * m.dim);
        for u in updates {
            if u.len() != m.dim {
                bail!("update len {} != dim {}", u.len(), m.dim);
            }
            stacked.extend_from_slice(u);
        }
        let u_lit = vec_f32_literal(&stacked, &[a, m.dim])?;
        let p_lit = vec_f32_literal(params, &[m.dim])?;
        let args: Vec<&Literal> = vec![&u_lit, &p_lit];
        let result = {
            let _sp = crate::obs::span("pjrt.agg_exec");
            self.agg.execute::<&Literal>(&args)?[0][0].to_literal_sync()?
        };
        let (mean_lit, ussq_lit, wssq_lit) = result.to_tuple3()?;
        let out = AggOutput {
            mean: mean_lit.to_vec::<f32>()?,
            update_ssq: ussq_lit.to_vec::<f32>()?,
            weight_ssq: wssq_lit.to_vec::<f32>()?,
        };
        let mut s = self.stats.borrow_mut();
        s.agg_calls += 1;
        s.agg_secs += t0.elapsed().as_secs_f64();
        Ok(out)
    }
}
