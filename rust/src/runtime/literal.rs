//! Literal construction helpers: host buffers -> shaped XLA literals.

use crate::data::Features;
use anyhow::{bail, Result};
use xla::Literal;

/// f32 buffer -> shaped literal; validates the element count.
pub fn vec_f32_literal(v: &[f32], dims: &[usize]) -> Result<Literal> {
    let want: usize = dims.iter().product();
    if v.len() != want {
        bail!("shape {:?} wants {} elements, got {}", dims, want, v.len());
    }
    let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    Ok(Literal::vec1(v).reshape(&dims_i64)?)
}

/// i32 buffer -> shaped literal.
pub fn i32_literal(v: &[i32], dims: &[usize]) -> Result<Literal> {
    let want: usize = dims.iter().product();
    if v.len() != want {
        bail!("shape {:?} wants {} elements, got {}", dims, want, v.len());
    }
    let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    Ok(Literal::vec1(v).reshape(&dims_i64)?)
}

/// Feature buffer (dtype per model) -> shaped literal.
pub fn features_literal(f: &Features, dims: &[usize]) -> Result<Literal> {
    match f {
        Features::F32(v) => vec_f32_literal(v, dims),
        Features::I32(v) => i32_literal(v, dims),
    }
}

/// f32 scalar literal.
pub fn scalar_f32(v: f32) -> Literal {
    Literal::scalar(v)
}
