//! PJRT runtime: loads the AOT HLO-text artifacts and executes them on
//! the request path. This is the only module that touches the `xla`
//! crate; everything above it sees plain `&[f32]` / `&[i32]` buffers.
//!
//! One `Engine` owns the PJRT CPU client and the three compiled
//! executables per model (train / eval / agg). Compilation happens
//! once at startup; per-call cost is literal construction + execute +
//! copy-out, measured in `benches/runtime_exec.rs`.
//!
//! The `xla` bindings are external and not vendorable, so the real
//! engine is gated behind the `pjrt` cargo feature; default builds use
//! `engine_stub.rs`, which has the identical API but errors at
//! `Engine::load` — every pure-Rust subsystem still builds and tests.

#[cfg(feature = "pjrt")]
mod engine;
#[cfg(feature = "pjrt")]
mod literal;

#[cfg(feature = "pjrt")]
pub use engine::{AggOutput, Engine, EvalOutput, TrainOutput};
#[cfg(feature = "pjrt")]
pub use literal::{features_literal, i32_literal, scalar_f32, vec_f32_literal};

#[cfg(not(feature = "pjrt"))]
mod engine_stub;

#[cfg(not(feature = "pjrt"))]
pub use engine_stub::{AggOutput, Engine, EvalOutput, TrainOutput};

#[cfg(all(test, feature = "pjrt"))]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let v = vec![1.0f32, -2.5, 3.25];
        let lit = vec_f32_literal(&v, &[3]).unwrap();
        assert_eq!(lit.to_vec::<f32>().unwrap(), v);
    }

    #[test]
    fn literal_roundtrip_i32() {
        let v = vec![5i32, -7, 0];
        let lit = i32_literal(&v, &[3]).unwrap();
        assert_eq!(lit.to_vec::<i32>().unwrap(), v);
    }

    #[test]
    fn literal_reshape_checks_count() {
        assert!(vec_f32_literal(&[1.0, 2.0], &[3]).is_err());
    }
}
