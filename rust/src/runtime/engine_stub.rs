//! Stub engine for builds without the `pjrt` feature (no `xla`
//! bindings available). Mirrors `engine.rs`'s public API exactly so
//! every pure-Rust layer — net/, compress/, luar/, comm, config,
//! exp plumbing — builds and tests without PJRT; artifact-executing
//! paths fail loudly at `Engine::load` with a rebuild hint.

use crate::data::{FedDataset, Features};
use crate::model::ModelMeta;
use anyhow::{bail, Result};

/// Result of one client's local-training call (Alg. 2 lines 6-10).
#[derive(Debug, Clone)]
pub struct TrainOutput {
    /// Accumulated local update Delta_t^i = x_tau - x_0 (flat).
    pub delta: Vec<f32>,
    /// Mean training loss across the tau local steps.
    pub loss: f32,
}

/// Result of one eval-chunk call.
#[derive(Debug, Clone, Copy)]
pub struct EvalOutput {
    pub loss_sum: f32,
    pub correct: i32,
}

/// Result of the server aggregation call.
#[derive(Debug, Clone)]
pub struct AggOutput {
    pub mean: Vec<f32>,
    pub update_ssq: Vec<f32>,
    pub weight_ssq: Vec<f32>,
}

/// Cumulative execution statistics (always zero in the stub).
#[derive(Debug, Clone, Copy, Default)]
pub struct ExecStats {
    pub train_calls: u64,
    pub train_secs: f64,
    pub eval_calls: u64,
    pub eval_secs: f64,
    pub agg_calls: u64,
    pub agg_secs: f64,
}

pub struct Engine {
    pub meta: ModelMeta,
}

const UNAVAILABLE: &str =
    "PJRT runtime unavailable: built without the `pjrt` feature (add the `xla` \
     bindings dependency and rebuild with `--features pjrt` to execute AOT artifacts)";

impl Engine {
    pub fn load(meta: ModelMeta) -> Result<Self> {
        let _ = &meta;
        bail!("{UNAVAILABLE}");
    }

    pub fn platform(&self) -> String {
        "stub".to_string()
    }

    pub fn stats(&self) -> ExecStats {
        ExecStats::default()
    }

    #[allow(clippy::too_many_arguments)]
    pub fn train_round(
        &self,
        _params: &[f32],
        _anchor_g: Option<&[f32]>,
        _anchor_prev: Option<&[f32]>,
        _feats: &Features,
        _labels: &[i32],
        _lr: f32,
        _mu_g: f32,
        _mu_prev: f32,
        _wd: f32,
    ) -> Result<TrainOutput> {
        bail!("{UNAVAILABLE}");
    }

    pub fn eval_chunk(
        &self,
        _params: &[f32],
        _feats: &Features,
        _labels: &[i32],
    ) -> Result<EvalOutput> {
        bail!("{UNAVAILABLE}");
    }

    pub fn eval_dataset(&self, _params: &[f32], _ds: &FedDataset) -> Result<(f64, f64)> {
        bail!("{UNAVAILABLE}");
    }

    pub fn aggregate(&self, _updates: &[&[f32]], _params: &[f32]) -> Result<AggOutput> {
        bail!("{UNAVAILABLE}");
    }
}
