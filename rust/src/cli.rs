//! Minimal CLI argument parser (offline build: no clap).
//!
//! Supports `--key value`, `--key=value`, bare flags, and positional
//! arguments, with typed getters and an unknown-flag check.
//!
//! The `run` subcommand's network flags (`--link-dist`, `--round-mode`,
//! `--compute-s`, `--sampler`, `--faults`) configure the `net:`
//! simulation block — see the USAGE/NET SIMULATION section of
//! `main.rs`'s HELP string and `net::NetCfg` for the spec grammar
//! (`uniform | lognormal | bimodal` fleets; `sync | deadline:s=F |
//! buffered:k=N | async:c=N,s=const|poly[,a=F]` round modes — `async`
//! runs the barrier-free server with per-client model versions and
//! staleness-discounted aggregation; `uniform | speed:pow=F |
//! staleness:cap=N` cohort samplers; `off | drop | outage | corrupt |
//! mixed` deterministic fault plans, see `docs/faults.md`).

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;

#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
    used: std::cell::RefCell<std::collections::BTreeSet<String>>,
}

impl Args {
    pub fn parse(argv: impl IntoIterator<Item = String>) -> Result<Self> {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(flag) = arg.strip_prefix("--") {
                if let Some((k, v)) = flag.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    out.flags.insert(flag.to_string(), v);
                } else {
                    out.flags.insert(flag.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(arg);
            }
        }
        Ok(out)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        let v = self.flags.get(key).map(String::as_str);
        if v.is_some() {
            self.used.borrow_mut().insert(key.to_string());
        }
        v
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_parse<T: std::str::FromStr>(&self, key: &str) -> Result<Option<T>>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(key) {
            None => Ok(None),
            Some(v) => match v.parse::<T>() {
                Ok(t) => Ok(Some(t)),
                Err(e) => bail!("--{key} {v}: {e}"),
            },
        }
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        Ok(self.get_parse::<usize>(key)?.unwrap_or(default))
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        Ok(self.get_parse::<f64>(key)?.unwrap_or(default))
    }

    pub fn get_u64(&self, key: &str, default: u64) -> Result<u64> {
        Ok(self.get_parse::<u64>(key)?.unwrap_or(default))
    }

    pub fn has(&self, key: &str) -> bool {
        self.get(key).is_some()
    }

    /// Error on flags nobody consumed (catches typos).
    pub fn check_unused(&self) -> Result<()> {
        let used = self.used.borrow();
        let unknown: Vec<&String> =
            self.flags.keys().filter(|k| !used.contains(k.as_str())).collect();
        if !unknown.is_empty() {
            bail!("unknown flags: {unknown:?}");
        }
        Ok(())
    }

    pub fn positional_at(&self, i: usize) -> Result<&str> {
        self.positional.get(i).map(String::as_str).context("missing positional argument")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn mixed_forms() {
        let a = parse("run sub --rounds 20 --model=cnn --quick");
        assert_eq!(a.positional, vec!["run", "sub"]);
        assert_eq!(a.get_usize("rounds", 0).unwrap(), 20);
        assert_eq!(a.get("model"), Some("cnn"));
        assert!(a.has("quick"));
        assert!(!a.has("nope"));
    }

    #[test]
    fn typed_errors() {
        let a = parse("--rounds abc");
        assert!(a.get_usize("rounds", 0).is_err());
    }

    #[test]
    fn unused_detection() {
        let a = parse("--known 1 --typo 2");
        let _ = a.get("known");
        assert!(a.check_unused().is_err());
        let _ = a.get("typo");
        assert!(a.check_unused().is_ok());
    }

    #[test]
    fn defaults() {
        let a = parse("");
        assert_eq!(a.get_or("x", "d"), "d");
        assert_eq!(a.get_f64("y", 1.5).unwrap(), 1.5);
        assert!(a.positional_at(0).is_err());
    }

    #[test]
    fn flag_before_positional() {
        // a flag followed by a non-flag consumes it as a value
        let a = parse("--mode fast run");
        assert_eq!(a.get("mode"), Some("fast"));
        assert_eq!(a.positional, vec!["run"]);
    }
}
