//! Appendix sweeps: Tables 9–12 (delta sensitivity), 13–14 (Dirichlet
//! alpha), 15–16 (client scaling).

use super::{
    acc_cell, apply_knobs, default_delta, default_rounds, fresh, paper_name, parse_models,
    run_cached, write_rows,
};
use crate::cli::Args;
use crate::config::{Method, RunConfig};
use anyhow::Result;

fn base_cfg(model: &str, args: &Args) -> Result<RunConfig> {
    let mut cfg = RunConfig::benchmark(model)?;
    cfg.rounds = default_rounds(model);
    apply_knobs(&mut cfg, args)?;
    Ok(cfg)
}

/// Delta grid per benchmark, scaled from the paper's tables 9–12 to
/// our layer counts (mlp/cnn 4 layers, resnet8 10, transformer 9).
fn delta_grid(model: &str) -> Vec<usize> {
    match model {
        "mlp" | "cnn" => vec![0, 1, 2, 3],
        "resnet8" => vec![0, 2, 4, 5, 8],
        "transformer" => vec![0, 3, 6, 8],
        _ => vec![0, 1, 2],
    }
}

// ---------------------------------------------------------- Tables 9–12

pub fn delta_sweep(args: &Args) -> Result<()> {
    let models = parse_models(args, &["cnn", "mlp"]);
    let mut rows = vec![];
    for model in &models {
        println!("\nTables 9–12 — {} accuracy/comm vs delta", paper_name(model));
        println!("{:>3} {:>10} {:>7} {:>9}", "d", "Acc", "Comm", "max-kappa");
        for delta in delta_grid(model) {
            let method =
                if delta == 0 { Method::FedAvg } else { Method::luar(delta) };
            let cfg = base_cfg(model, args)?.with_method(method);
            let (h, _) = run_cached(cfg, fresh(args))?;
            println!(
                "{:>3} {:>10} {:>7.2} {:>9.4}",
                delta,
                acc_cell(&h),
                h.final_comm_ratio(),
                h.max_kappa()
            );
            rows.push(format!(
                "{model},{delta},{:.4},{:.4},{:.4}",
                h.tail_acc(2),
                h.final_comm_ratio(),
                h.max_kappa()
            ));
        }
    }
    println!("\npaper shape: flat accuracy until delta approaches the layer");
    println!("count, then a cliff; comm decreases monotonically with delta.");
    write_rows("delta_sweep", "model,delta,acc,comm,max_kappa", &rows)
}

// ---------------------------------------------------------- Tables 13–14

pub fn alpha_sweep(args: &Args) -> Result<()> {
    let models = parse_models(args, &["cnn", "transformer"]);
    let mut rows = vec![];
    for model in &models {
        let delta = default_delta(model);
        println!("\nTables 13–14 — {} robustness to non-IIDness (delta={delta})", paper_name(model));
        println!("{:<9} {:>7} {:>10} {:>10} {:>10}", "Method", "Comm", "a=0.1", "a=0.5", "a=1.0");
        let mut cells: Vec<Vec<String>> = vec![vec![], vec![]];
        let mut comms = [1.0f64, 1.0];
        for alpha in [0.1, 0.5, 1.0] {
            for (i, method) in [Method::FedAvg, Method::luar(delta)].iter().enumerate() {
                let mut cfg = base_cfg(model, args)?.with_method(method.clone());
                cfg.alpha = alpha;
                let (h, _) = run_cached(cfg, fresh(args))?;
                cells[i].push(acc_cell(&h));
                comms[i] = h.final_comm_ratio();
                rows.push(format!(
                    "{model},{},{alpha},{:.4},{:.4}",
                    method.label(),
                    h.tail_acc(2),
                    h.final_comm_ratio()
                ));
            }
        }
        for (i, name) in ["FedAvg", "FedLUAR"].iter().enumerate() {
            println!(
                "{:<9} {:>7.2} {:>10} {:>10} {:>10}",
                name, comms[i], cells[i][0], cells[i][1], cells[i][2]
            );
        }
    }
    println!("\npaper shape: FedLUAR tracks FedAvg at every alpha; both rise");
    println!("with alpha (milder heterogeneity).");
    write_rows("alpha_sweep", "model,method,alpha,acc,comm", &rows)
}

// ---------------------------------------------------------- Tables 15–16

pub fn client_sweep(args: &Args) -> Result<()> {
    let models = parse_models(args, &["cnn", "mlp"]);
    let mut rows = vec![];
    for model in &models {
        let delta = default_delta(model);
        println!(
            "\nTables 15–16 — {} client scaling, a=32 active (delta={delta})",
            paper_name(model)
        );
        println!(
            "{:<9} {:>7} {:>10} {:>10} {:>10}",
            "Method", "Comm", "64 (0.5)", "128 (0.25)", "256 (0.125)"
        );
        let mut cells: Vec<Vec<String>> = vec![vec![], vec![]];
        let mut comms = [1.0f64, 1.0];
        for n in [64usize, 128, 256] {
            for (i, method) in [Method::FedAvg, Method::luar(delta)].iter().enumerate() {
                let mut cfg = base_cfg(model, args)?.with_method(method.clone());
                cfg.num_clients = n;
                // paper keeps a=32 active at every scale
                let (h, _) = run_cached(cfg, fresh(args))?;
                cells[i].push(acc_cell(&h));
                comms[i] = h.final_comm_ratio();
                rows.push(format!(
                    "{model},{},{n},{:.4},{:.4}",
                    method.label(),
                    h.tail_acc(2),
                    h.final_comm_ratio()
                ));
            }
        }
        for (i, name) in ["FedAvg", "FedLUAR"].iter().enumerate() {
            println!(
                "{:<9} {:>7.2} {:>10} {:>10} {:>10}",
                name, comms[i], cells[i][0], cells[i][1], cells[i][2]
            );
        }
    }
    println!("\npaper shape: FedLUAR matches FedAvg at every federation size.");
    write_rows("client_sweep", "model,method,clients,acc,comm", &rows)
}
