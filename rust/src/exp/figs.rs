//! Figures 1, 3, 4/5/6: layer-wise diagnostics and learning curves,
//! rendered as ASCII charts + CSV series.

use super::{apply_knobs, default_delta, default_rounds, fresh, paper_name, parse_models, run_cached, write_rows};
use crate::cli::Args;
use crate::config::{Method, RunConfig};
use crate::fl::Server;
use anyhow::Result;

fn base_cfg(model: &str, args: &Args) -> Result<RunConfig> {
    let mut cfg = RunConfig::benchmark(model)?;
    cfg.rounds = default_rounds(model);
    apply_knobs(&mut cfg, args)?;
    Ok(cfg)
}

fn bar(v: f64, vmax: f64, width: usize) -> String {
    let n = if vmax > 0.0 { ((v / vmax) * width as f64).round() as usize } else { 0 };
    "#".repeat(n.min(width))
}

// -------------------------------------------------------------- Figure 1

/// Layer-wise ||Delta|| vs ||x|| and their ratio after a few FedAvg
/// rounds — the observation that motivates the s_{t,l} metric: the
/// smallest-gradient layers are NOT the smallest-ratio layers.
pub fn fig1(args: &Args) -> Result<()> {
    let models = parse_models(args, &["cnn", "resnet8"]);
    let mut rows = vec![];
    for model in &models {
        let mut cfg = base_cfg(model, args)?;
        cfg.method = Method::FedAvg;
        cfg.rounds = cfg.rounds.min(8);
        cfg.eval_every = 0;
        let mut server = Server::new(cfg)?;
        server.run()?;
        let stats = server.layer_stats();
        let gmax = stats.iter().map(|s| s.1).fold(0.0, f64::max);
        let rmax = stats.iter().map(|s| s.3).fold(0.0, f64::max);
        println!("\nFigure 1 — {} after {} rounds", paper_name(model), server.round);
        println!(
            "{:<12} {:>9} {:>9} {:>9}  {:<20} {:<20}",
            "layer", "|grad|", "|weight|", "ratio", "grad-norm bar", "ratio bar"
        );
        for (name, g, w, r) in &stats {
            println!(
                "{:<12} {:>9.4} {:>9.4} {:>9.5}  {:<20} {:<20}",
                name,
                g,
                w,
                r,
                bar(*g, gmax, 20),
                bar(*r, rmax, 20)
            );
            rows.push(format!("{model},{name},{g},{w},{r}"));
        }
        // the paper's point: argmin over |grad| != argmin over ratio
        // (total_cmp: a NaN norm sorts last instead of panicking — D3)
        let min_g = stats
            .iter()
            .enumerate()
            .min_by(|a, b| a.1 .1.total_cmp(&b.1 .1))
            .map(|(i, s)| (i, s.0.clone()))
            .unwrap();
        let min_r = stats
            .iter()
            .enumerate()
            .min_by(|a, b| a.1 .3.total_cmp(&b.1 .3))
            .map(|(i, s)| (i, s.0.clone()))
            .unwrap();
        println!(
            "smallest |grad|: {} (layer {});  smallest ratio: {} (layer {}){}",
            min_g.1,
            min_g.0,
            min_r.1,
            min_r.0,
            if min_g.0 != min_r.0 { "  <- differ, as in the paper" } else { "" }
        );
    }
    write_rows("fig1", "model,layer,grad_norm,weight_norm,ratio", &rows)
}

// -------------------------------------------------------------- Figure 3

/// Per-layer aggregation counts: FedAvg aggregates every layer every
/// round; FedLUAR's counts dip where updates were recycled.
pub fn fig3(args: &Args) -> Result<()> {
    let models = parse_models(args, &["cnn", "resnet8", "transformer"]);
    let mut rows = vec![];
    for model in &models {
        let delta = default_delta(model);
        let mut cfg = base_cfg(model, args)?;
        cfg.method = Method::luar(delta);
        cfg.eval_every = 0;
        let mut server = Server::new(cfg)?;
        server.run()?;
        let rounds = server.comm.rounds;
        println!(
            "\nFigure 3 — {} aggregations per layer over {} rounds (delta={})",
            paper_name(model),
            rounds,
            delta
        );
        println!("{:<12} {:>6} {:>8} {:>8}  {}", "layer", "aggs", "FedAvg", "size%", "bar");
        let meta = server.meta();
        for (l, lm) in meta.layers.iter().enumerate() {
            let c = server.comm.layer_upload_rounds[l];
            println!(
                "{:<12} {:>6} {:>8} {:>7.1}%  {}",
                lm.name,
                c,
                rounds,
                100.0 * lm.size as f64 / meta.dim as f64,
                bar(c as f64, rounds as f64, 30)
            );
            rows.push(format!("{model},{},{c},{rounds},{}", lm.name, lm.size));
        }
        println!(
            "total comm ratio {:.3} (gap from FedAvg = recycled uploads)",
            server.comm.comm_ratio()
        );
    }
    write_rows("fig3", "model,layer,aggregations,rounds,layer_size", &rows)
}

// ---------------------------------------------------------- Figures 4/5/6

/// Accuracy vs cumulative communication (normalized to FedAvg's total):
/// the paper's learning-curve comparison for 4 representative methods.
pub fn curves(args: &Args) -> Result<()> {
    let models = parse_models(args, &["cnn"]);
    let mut rows = vec![];
    for model in &models {
        let delta = default_delta(model);
        let methods: Vec<Method> = vec![
            Method::FedAvg,
            Method::Quantize { levels: 16 },
            Method::Prune { keep_ratio: 0.5, reconfig_every: 10 },
            Method::luar(delta),
        ];
        println!("\nFigures 4/5/6 — {} accuracy vs relative comm cost", paper_name(model));
        // FedAvg's total upload = x-axis unit
        let mut fedavg_total = 0u64;
        let mut all: Vec<(String, Vec<(f64, f64)>)> = Vec::new();
        for m in methods {
            let mut cfg = base_cfg(model, args)?.with_method(m.clone());
            cfg.eval_every = 2.min(cfg.eval_every.max(1));
            let (h, _) = run_cached(cfg, fresh(args))?;
            if m == Method::FedAvg {
                fedavg_total = h.records.last().map(|r| r.up_bytes).unwrap_or(1);
            }
            let series: Vec<(f64, f64)> = h
                .records
                .iter()
                .map(|r| (r.up_bytes as f64, r.test_acc))
                .collect();
            all.push((m.label(), series));
        }
        let unit = fedavg_total.max(1) as f64;
        for (label, series) in &all {
            let pts: String = series
                .iter()
                .map(|(x, y)| format!("({:.2},{:.1}%)", x / unit, y * 100.0))
                .collect::<Vec<_>>()
                .join(" ");
            println!("{label:<10} {pts}");
            for (x, y) in series {
                rows.push(format!("{model},{label},{:.4},{:.4}", x / unit, y));
            }
        }
        println!("paper shape: FedLUAR reaches FedAvg-level accuracy at a fraction of the x-axis.");
    }
    write_rows("curves", "model,method,rel_comm,acc", &rows)
}
