//! Experiment harness: one sub-command per paper table / figure.
//!
//! Every experiment prints paper-style rows, writes CSV under
//! `results/`, and records enough metadata to be replayed. Runs are
//! cached by config hash (`results/cache/<hash>.csv`), so tables that
//! share a configuration (e.g. the FedAvg baseline) reuse each other's
//! work — re-running a table is incremental.
//!
//! Fidelity knobs shared by all experiments:
//!   --rounds N     override rounds per run (default per-benchmark)
//!   --models a,b   subset of benchmarks
//!   --quick        small federation (fast smoke reproduction)
//!   --fresh        ignore the run cache

#![allow(clippy::disallowed_methods)] // experiment driver reports real wall time per run
mod figs;
mod sweeps;
mod tables;

use crate::cli::Args;
use crate::config::RunConfig;
use crate::fl::Server;
use crate::metrics::History;
use anyhow::Result;
use std::path::PathBuf;

pub fn dispatch(args: &Args) -> Result<()> {
    let sub = args.positional.get(1).map(String::as_str).unwrap_or("list");
    match sub {
        "table1" => tables::table1(args),
        "table2" => tables::table2(args),
        "table3" => tables::table3(args),
        "table4" => tables::table4(args),
        "table5" => tables::table5(args),
        "delta-sweep" => sweeps::delta_sweep(args),
        "alpha-sweep" => sweeps::alpha_sweep(args),
        "client-sweep" => sweeps::client_sweep(args),
        "fig1" => figs::fig1(args),
        "fig3" => figs::fig3(args),
        "curves" => figs::curves(args),
        "all" => {
            for t in ["table1", "fig1", "table2", "table4", "table5", "fig3"] {
                println!("\n================ exp {t} ================");
                let mut argv = vec!["exp".to_string(), t.to_string()];
                for key in ["quick", "rounds", "models", "fresh"] {
                    if let Some(v) = args.get(key) {
                        argv.push(format!("--{key}"));
                        argv.push(v.to_string());
                    }
                }
                dispatch(&Args::parse(argv)?)?;
            }
            Ok(())
        }
        _ => {
            println!(
                "experiments (paper artifact -> command):\n\
                 \x20 Table 1  memory footprint      exp table1\n\
                 \x20 Table 2  8 methods x 4 models  exp table2\n\
                 \x20 Table 3  LUAR + FL optimizers  exp table3\n\
                 \x20 Table 4  selection ablation    exp table4\n\
                 \x20 Table 5  drop vs recycle       exp table5\n\
                 \x20 Tab 9-12 delta sensitivity     exp delta-sweep [--model M]\n\
                 \x20 Tab13-14 Dirichlet alpha       exp alpha-sweep [--model M]\n\
                 \x20 Tab15-16 client scaling        exp client-sweep [--model M]\n\
                 \x20 Fig 1    grad/weight norms     exp fig1 [--model M]\n\
                 \x20 Fig 3    per-layer agg counts  exp fig3 [--model M]\n\
                 \x20 Fig 4-6  acc-vs-comm curves    exp curves [--model M]\n\
                 \x20 all      table1,fig1,2,4,5,fig3 in sequence\n\
                 flags: --rounds N --models a,b --quick --fresh"
            );
            Ok(())
        }
    }
}

// ---------------------------------------------------------------- shared

/// Paper-aligned recycling depth per benchmark: FEMNIST's delta=2/4 is
/// exact; the others keep the paper's recycled-fraction (half for the
/// CIFAR models, ~2/3 for the text model).
pub fn default_delta(model: &str) -> usize {
    match model {
        "mlp" => 2,         // 2 of 4
        "cnn" => 2,         // 2 of 4  (paper FEMNIST: 2 of 4)
        "resnet8" => 5,     // 5 of 10 (paper CIFAR-10: 10 of 20)
        "transformer" => 6, // 6 of 9  (paper AG News: 30 of 40)
        _ => 2,
    }
}

/// Benchmark display name mapping to the paper's datasets.
pub fn paper_name(model: &str) -> &'static str {
    match model {
        "mlp" => "Synth-Vec (MLP)",
        "cnn" => "FEMNIST-like (CNN)",
        "resnet8" => "CIFAR-like (ResNet8)",
        "transformer" => "AGNews-like (Transformer)",
        _ => "?",
    }
}

/// Default rounds per benchmark, balancing fidelity vs the 1-CPU
/// testbed (override with --rounds).
pub fn default_rounds(model: &str) -> usize {
    match model {
        "mlp" => 40,
        "cnn" => 24,
        "resnet8" => 24,
        "transformer" => 30,
        _ => 24,
    }
}

pub fn parse_models(args: &Args, default: &[&str]) -> Vec<String> {
    match args.get("models").or_else(|| args.get("model")) {
        Some(s) => s.split(',').map(|t| t.trim().to_string()).collect(),
        None => default.iter().map(|s| s.to_string()).collect(),
    }
}

/// Apply shared fidelity knobs to a config.
pub fn apply_knobs(cfg: &mut RunConfig, args: &Args) -> Result<()> {
    if args.has("quick") {
        cfg.num_clients = 32;
        cfg.active_clients = 8;
        cfg.per_client = 64;
        cfg.test_size = 512;
        cfg.rounds = cfg.rounds.min(10);
        cfg.eval_every = 5;
    }
    if let Some(r) = args.get_parse::<usize>("rounds")? {
        cfg.rounds = r;
    }
    Ok(())
}

fn cache_key(cfg: &RunConfig) -> String {
    // FNV-1a over the canonical config text
    let text = cfg.save_kv();
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in text.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    format!("{:016x}", h)
}

/// Run a config through the cache: reuse `results/cache/<hash>.csv`
/// when present (unless --fresh), otherwise run and persist.
pub fn run_cached(cfg: RunConfig, fresh: bool) -> Result<(History, f64)> {
    let dir = PathBuf::from("results/cache");
    std::fs::create_dir_all(&dir)?;
    let key = cache_key(&cfg);
    let path = dir.join(format!("{key}.csv"));
    let meta_path = dir.join(format!("{key}.cfg"));
    if !fresh && path.exists() {
        let (h, rep) = History::read_csv_report(&path)?;
        if !rep.is_clean() {
            eprintln!(
                "# warning: cached {} parsed with {} skipped / {} degraded rows \
                 (rerun with --fresh to rebuild)",
                path.display(),
                rep.skipped,
                rep.degraded
            );
        }
        if !h.records.is_empty() {
            return Ok((h, 0.0));
        }
    }
    let t0 = std::time::Instant::now();
    let mut server = Server::new(cfg.clone())?;
    server.run()?;
    let wall = t0.elapsed().as_secs_f64();
    server.history.write_csv(&path)?;
    std::fs::write(&meta_path, cfg.save_kv())?;
    Ok((server.history.clone(), wall))
}

/// Format like the paper's accuracy cells (single run: no +-).
pub fn acc_cell(h: &History) -> String {
    format!("{:5.2}%", h.tail_acc(2) * 100.0)
}

pub fn fresh(args: &Args) -> bool {
    args.has("fresh")
}

/// Append a results block to results/<name>.csv with a header line.
pub fn write_rows(name: &str, header: &str, rows: &[String]) -> Result<()> {
    std::fs::create_dir_all("results")?;
    let path = format!("results/{name}.csv");
    let mut text = String::from(header);
    text.push('\n');
    for r in rows {
        text.push_str(r);
        text.push('\n');
    }
    std::fs::write(&path, text)?;
    println!("(csv -> {path})");
    Ok(())
}
