//! Tables 1–5: the paper's main results, regenerated on the synthetic
//! testbed. Each prints the paper's row layout plus a "paper shape"
//! reminder so paper-vs-measured comparisons are one glance.

use super::{
    acc_cell, apply_knobs, default_delta, default_rounds, fresh, paper_name, parse_models,
    run_cached, write_rows,
};
use crate::cli::Args;
use crate::comm::memory_footprint_bytes;
use crate::config::{ClientOptCfg, Method, RecycleMode, RunConfig, SelectionScheme, ServerOptCfg};
use crate::fl::Server;
use anyhow::Result;

fn base_cfg(model: &str, args: &Args) -> Result<RunConfig> {
    let mut cfg = RunConfig::benchmark(model)?;
    cfg.rounds = default_rounds(model);
    apply_knobs(&mut cfg, args)?;
    Ok(cfg)
}

// ------------------------------------------------------------------ Table 1

/// Memory footprint comparison (paper §3.4): analytic a·(d−k)+k vs a·d,
/// with k measured from a short FedLUAR run's actual recycle set.
pub fn table1(args: &Args) -> Result<()> {
    let models = parse_models(args, &["mlp", "cnn", "resnet8", "transformer"]);
    println!("Table 1 — server memory footprint during aggregation (MB)");
    println!("{:<26} {:<9} {:>3} {:>14} {:>14}", "Benchmark", "Algorithm", "d", "FedAvg", "FedLUAR");
    let mut rows = vec![];
    for model in &models {
        let mut cfg = base_cfg(model, args)?;
        cfg.rounds = 6.min(cfg.rounds);
        cfg.eval_every = 0;
        cfg.method = Method::luar(default_delta(model));
        let mut server = Server::new(cfg)?;
        server.run()?;
        let a = server.cfg.active_clients as u64;
        let full = server.meta().full_bytes();
        let recycled = server.meta().layer_bytes(&server.luar.recycle_set);
        let (favg, fluar) = memory_footprint_bytes(a, full, recycled);
        let mb = |b: u64| b as f64 / (1024.0 * 1024.0);
        println!(
            "{:<26} {:<9} {:>3} {:>13.2}M {:>13.2}M   (recycled {:.0}% of model)",
            paper_name(model),
            "both",
            default_delta(model),
            mb(favg),
            mb(fluar),
            100.0 * recycled as f64 / full as f64,
        );
        rows.push(format!("{model},{},{},{}", default_delta(model), favg, fluar));
    }
    println!("paper shape: FedLUAR < FedAvg on every benchmark (a·(d−k)+k < a·d)");
    write_rows("table1", "model,delta,fedavg_bytes,fedluar_bytes", &rows)
}

// ------------------------------------------------------------------ Table 2

/// The comparative study: 8 methods x benchmarks, accuracy + Comm.
/// Per-method hyper-parameters follow the paper's Table 7 (adapted
/// where our substitutes differ, DESIGN.md).
pub fn table2(args: &Args) -> Result<()> {
    let models = parse_models(args, &["mlp", "cnn", "resnet8", "transformer"]);
    let mut rows = vec![];
    for model in &models {
        let methods: Vec<Method> = vec![
            Method::FedAvg,
            Method::Lbgm { threshold: 0.6 },
            Method::Quantize { levels: if model == "cnn" || model == "transformer" { 8 } else { 16 } },
            Method::LowRank {
                rank_ratio: match model.as_str() {
                    "mlp" => 0.5,
                    "cnn" => 0.2,
                    "resnet8" => 0.5,
                    _ => 0.3,
                },
            },
            Method::Prune {
                keep_ratio: match model.as_str() {
                    "cnn" => 0.2,
                    "transformer" => 0.25,
                    _ => 0.5,
                },
                reconfig_every: 10,
            },
            Method::DropoutAvg { rate: if model == "cnn" { 0.75 } else { 0.5 } },
            Method::Binarize,
            Method::luar(default_delta(model)),
        ];
        println!("\nTable 2 — {} (N=128, a=32, Dirichlet)", paper_name(model));
        println!("{:<10} {:>9} {:>7}", "Method", "Accuracy", "Comm");
        for m in methods {
            let cfg = base_cfg(model, args)?.with_method(m.clone());
            let (h, wall) = run_cached(cfg, fresh(args))?;
            println!(
                "{:<10} {:>9} {:>7.2}{}",
                m.label(),
                acc_cell(&h),
                h.final_comm_ratio(),
                if wall > 0.0 { format!("   [{wall:.0}s]") } else { String::new() }
            );
            rows.push(format!(
                "{model},{},{:.4},{:.4}",
                m.label(),
                h.tail_acc(2),
                h.final_comm_ratio()
            ));
        }
    }
    println!("\npaper shape: FedLUAR ~ FedAvg accuracy at the lowest Comm;");
    println!("FedPAQ/FedBAT cheap but lossy; Prune/FDA/FedPara mid-pack.");
    write_rows("table2", "model,method,acc,comm", &rows)
}

// ------------------------------------------------------------------ Table 3

/// Harmonization with other FL methods: each optimizer with plain
/// periodic averaging vs with LUAR layered on top.
pub fn table3(args: &Args) -> Result<()> {
    let models = parse_models(args, &["resnet8", "cnn"]);
    let mut rows = vec![];
    for model in &models {
        let delta = default_delta(model);
        println!("\nTable 3 — {} (LUAR delta={delta})", paper_name(model));
        println!("{:<9} {:>10} {:>10} {:>7}", "Optimizer", "Periodic", "+LUAR", "Comm");
        // (label, base method, server opt, client opt)
        let variants: Vec<(&str, Method, ServerOptCfg, ClientOptCfg)> = vec![
            (
                "FedProx",
                Method::FedAvg,
                ServerOptCfg::Sgd,
                ClientOptCfg { mu_global: 0.001, mu_prev: 0.0 },
            ),
            ("FedPAQ", Method::Quantize { levels: 16 }, ServerOptCfg::Sgd, ClientOptCfg::default()),
            ("FedOpt", Method::FedAvg, ServerOptCfg::Adam { lr: 0.1 }, ClientOptCfg::default()),
            (
                "MOON",
                Method::FedAvg,
                ServerOptCfg::Sgd,
                ClientOptCfg { mu_global: 0.1, mu_prev: 0.05 },
            ),
            ("FedMut", Method::FedAvg, ServerOptCfg::Mut { alpha: 0.5 }, ClientOptCfg::default()),
            (
                "FedACG",
                Method::FedAvg,
                ServerOptCfg::Acg { lambda: 0.7 },
                ClientOptCfg { mu_global: 0.01, mu_prev: 0.0 },
            ),
            (
                "PruneFL",
                Method::Prune { keep_ratio: 0.5, reconfig_every: 10 },
                ServerOptCfg::Sgd,
                ClientOptCfg::default(),
            ),
        ];
        for (label, base, sopt, copt) in variants {
            // periodic averaging (the optimizer alone)
            let mut cfg = base_cfg(model, args)?.with_method(base.clone());
            cfg.server_opt = sopt.clone();
            cfg.client_opt = copt;
            let (h_plain, _) = run_cached(cfg, fresh(args))?;
            // + LUAR
            let mut cfg = base_cfg(model, args)?.with_method(Method::luar(delta));
            cfg.server_opt = sopt;
            cfg.client_opt = copt;
            if base != Method::FedAvg {
                cfg.luar_compress = Some(base);
            }
            let (h_luar, _) = run_cached(cfg, fresh(args))?;
            println!(
                "{:<9} {:>10} {:>10} {:>7.2}",
                label,
                acc_cell(&h_plain),
                acc_cell(&h_luar),
                h_luar.final_comm_ratio()
            );
            rows.push(format!(
                "{model},{label},{:.4},{:.4},{:.4}",
                h_plain.tail_acc(2),
                h_luar.tail_acc(2),
                h_luar.final_comm_ratio()
            ));
        }
    }
    println!("\npaper shape: +LUAR keeps each optimizer's accuracy while");
    println!("cutting its upload cost by roughly the recycled fraction.");
    write_rows("table3", "model,optimizer,acc_plain,acc_luar,comm_luar", &rows)
}

// ------------------------------------------------------------------ Table 4

/// Layer-selection scheme ablation at fixed delta.
pub fn table4(args: &Args) -> Result<()> {
    let models = parse_models(args, &["cnn", "resnet8", "transformer"]);
    let schemes = [
        SelectionScheme::Random,
        SelectionScheme::Top,
        SelectionScheme::Bottom,
        SelectionScheme::GradNorm,
        SelectionScheme::Deterministic,
        SelectionScheme::Luar,
    ];
    let mut rows = vec![];
    for model in &models {
        let delta = default_delta(model);
        println!("\nTable 4 — {} layer-selection ablation (delta={delta})", paper_name(model));
        println!("{:<15} {:>9} {:>7}", "Scheme", "Acc", "Comm");
        for scheme in schemes {
            let method = Method::Luar { delta, scheme, mode: RecycleMode::Recycle, adaptive: false };
            let cfg = base_cfg(model, args)?.with_method(method);
            let (h, _) = run_cached(cfg, fresh(args))?;
            println!(
                "{:<15} {:>9} {:>7.2}",
                scheme.name(),
                acc_cell(&h),
                h.final_comm_ratio()
            );
            rows.push(format!(
                "{model},{},{:.4},{:.4}",
                scheme.name(),
                h.tail_acc(2),
                h.final_comm_ratio()
            ));
        }
    }
    println!("\npaper shape: LUAR best; deterministic recycling degrades");
    println!("(stale layers never refresh); grad-norm under-performs the ratio metric.");
    write_rows("table4", "model,scheme,acc,comm", &rows)
}

// ------------------------------------------------------------------ Table 5

/// Dropping vs recycling at the same communication budget.
pub fn table5(args: &Args) -> Result<()> {
    let models = parse_models(args, &["cnn", "resnet8", "transformer"]);
    let mut rows = vec![];
    println!("Table 5 — update dropping vs recycling (same comm budget)");
    println!("{:<26} {:>3} {:>10} {:>10} {:>7}", "Benchmark", "d", "Dropping", "Recycling", "Comm");
    for model in &models {
        let delta = default_delta(model);
        let mk = |mode| Method::Luar { delta, scheme: SelectionScheme::Luar, mode, adaptive: false };
        let (h_drop, _) =
            run_cached(base_cfg(model, args)?.with_method(mk(RecycleMode::Drop)), fresh(args))?;
        let (h_rec, _) =
            run_cached(base_cfg(model, args)?.with_method(mk(RecycleMode::Recycle)), fresh(args))?;
        println!(
            "{:<26} {:>3} {:>10} {:>10} {:>7.2}",
            paper_name(model),
            delta,
            acc_cell(&h_drop),
            acc_cell(&h_rec),
            h_rec.final_comm_ratio()
        );
        rows.push(format!(
            "{model},{delta},{:.4},{:.4},{:.4}",
            h_drop.tail_acc(2),
            h_rec.tail_acc(2),
            h_rec.final_comm_ratio()
        ));
    }
    println!("paper shape: Recycling > Dropping at identical Comm.");
    write_rows("table5", "model,delta,acc_drop,acc_recycle,comm", &rows)
}
