//! FedLUAR: Layer-wise Update Aggregation with Recycling
//! (NeurIPS 2025) — a Rust + JAX + Pallas reproduction.
//!
//! Three layers:
//! * L1: Pallas kernels (aggregation mean-reduce, fused dense) —
//!   `python/compile/kernels/`, build time only.
//! * L2: JAX graphs (local training, eval, server aggregation) lowered
//!   once to HLO text — `python/compile/`, build time only.
//! * L3: this crate — the federated-learning coordinator that loads the
//!   AOT artifacts via PJRT and runs the paper's algorithms with Python
//!   never on the request path.

// CI denies clippy warnings. This allow is deliberate: stateful
// compressors (e.g. `Binarize` with its error-feedback residuals) use
// explicit `new()` constructors and gain nothing from a `Default`.
#![allow(clippy::new_without_default)]

pub mod bench_harness;
pub mod cli;
pub mod comm;
pub mod config;
pub mod compress;
pub mod data;
pub mod exp;
pub mod fl;
pub mod json;
pub mod lint;
pub mod luar;
pub mod metrics;
pub mod model;
pub mod net;
pub mod obs;
pub mod optim;
pub mod rng;
pub mod runtime;
pub mod tensor;
