//! Minimal JSON parser (offline build: no serde available).
//!
//! Supports the full JSON grammar the AOT pipeline emits — objects,
//! arrays, strings (with escapes), numbers, booleans, null — which is
//! all `<model>.meta.json` needs. Strict enough to reject truncated
//! or malformed artifacts loudly rather than mis-slicing a model.

use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            bail!("trailing garbage at byte {}", p.pos);
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m.get(key).ok_or_else(|| anyhow!("missing key {key:?}")),
            _ => bail!("not an object (looking up {key:?})"),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("not a number"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let n = self.as_f64()?;
        if n < 0.0 || n.fract() != 0.0 || n > u64::MAX as f64 {
            bail!("not a non-negative integer: {n}");
        }
        Ok(n as usize)
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("not an array"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("not an object"),
        }
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Result<u8> {
        let b = self.peek().ok_or_else(|| anyhow!("unexpected end of input"))?;
        self.pos += 1;
        Ok(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        let got = self.bump()?;
        if got != b {
            bail!("expected {:?} got {:?} at byte {}", b as char, got as char, self.pos - 1);
        }
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek().ok_or_else(|| anyhow!("unexpected end of input"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.literal("true", Json::Bool(true)),
            b'f' => self.literal("false", Json::Bool(false)),
            b'n' => self.literal("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            other => bail!("unexpected byte {:?} at {}", other as char, self.pos),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.pos)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b'}' => return Ok(Json::Obj(m)),
                other => bail!("expected ',' or '}}' got {:?}", other as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b']' => return Ok(Json::Arr(v)),
                other => bail!("expected ',' or ']' got {:?}", other as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump()? {
                b'"' => return Ok(s),
                b'\\' => match self.bump()? {
                    b'"' => s.push('"'),
                    b'\\' => s.push('\\'),
                    b'/' => s.push('/'),
                    b'b' => s.push('\u{0008}'),
                    b'f' => s.push('\u{000C}'),
                    b'n' => s.push('\n'),
                    b'r' => s.push('\r'),
                    b't' => s.push('\t'),
                    b'u' => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let h = self.bump()?;
                            code = code * 16
                                + (h as char).to_digit(16).ok_or_else(|| anyhow!("bad \\u"))?;
                        }
                        s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                    }
                    other => bail!("bad escape \\{}", other as char),
                },
                byte => {
                    // Collect the full UTF-8 sequence starting here.
                    if byte < 0x80 {
                        s.push(byte as char);
                    } else {
                        let len = match byte {
                            0xC0..=0xDF => 2,
                            0xE0..=0xEF => 3,
                            0xF0..=0xF7 => 4,
                            _ => bail!("invalid utf8 lead byte"),
                        };
                        let start = self.pos - 1;
                        for _ in 1..len {
                            self.bump()?;
                        }
                        s.push_str(
                            std::str::from_utf8(&self.bytes[start..self.pos])
                                .map_err(|e| anyhow!("utf8: {e}"))?,
                        );
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(text.parse::<f64>()?))
    }
}

/// Minimal JSON writer for experiment outputs.
pub fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_meta_like_document() {
        let doc = r#"{
            "model": "cnn", "dim": 12345, "momentum": 0.9,
            "layers": [
                {"name": "conv1", "offset": 0, "size": 160,
                 "arrays": [{"shape": [3,3,1,16]}]},
                {"name": "fc", "offset": 160, "size": 100, "arrays": []}
            ],
            "flag": true, "nothing": null
        }"#;
        let j = Json::parse(doc).unwrap();
        assert_eq!(j.get("model").unwrap().as_str().unwrap(), "cnn");
        assert_eq!(j.get("dim").unwrap().as_usize().unwrap(), 12345);
        assert!((j.get("momentum").unwrap().as_f64().unwrap() - 0.9).abs() < 1e-12);
        let layers = j.get("layers").unwrap().as_arr().unwrap();
        assert_eq!(layers.len(), 2);
        assert_eq!(layers[1].get("offset").unwrap().as_usize().unwrap(), 160);
        assert_eq!(j.get("flag").unwrap(), &Json::Bool(true));
        assert_eq!(j.get("nothing").unwrap(), &Json::Null);
    }

    #[test]
    fn parse_numbers() {
        for (s, v) in [("0", 0.0), ("-3", -3.0), ("2.5", 2.5), ("1e3", 1000.0), ("-1.5E-2", -0.015)]
        {
            assert_eq!(Json::parse(s).unwrap(), Json::Num(v), "{s}");
        }
    }

    #[test]
    fn parse_string_escapes() {
        let j = Json::parse(r#""a\nb\t\"c\" A""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "a\nb\t\"c\" A");
    }

    #[test]
    fn parse_unicode_passthrough() {
        let j = Json::parse("\"héllo ✓\"").unwrap();
        assert_eq!(j.as_str().unwrap(), "héllo ✓");
    }

    #[test]
    fn reject_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\":1} x").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn as_usize_rejects_fraction_and_negative() {
        assert!(Json::parse("1.5").unwrap().as_usize().is_err());
        assert!(Json::parse("-2").unwrap().as_usize().is_err());
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(Default::default()));
    }

    #[test]
    fn escape_roundtrip() {
        let s = "line\n\"quoted\"\tend";
        let j = Json::parse(&escape(s)).unwrap();
        assert_eq!(j.as_str().unwrap(), s);
    }
}
