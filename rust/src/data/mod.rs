//! Federated data substrate.
//!
//! The paper evaluates on CIFAR-10/100, FEMNIST and AG News with
//! label-based Dirichlet(alpha) partitions. Those corpora are not
//! available in this environment, so we substitute seeded synthetic
//! generators with the same *federated structure*: K-class data,
//! Dirichlet(alpha) label skew across N clients, imbalanced shard
//! sizes (DESIGN.md §Substitutions). Samples are generated lazily and
//! deterministically from (seed, class, index) so a 128-client
//! federation costs O(indices) memory, not O(pixels).

mod dirichlet;
mod synth;

pub use dirichlet::{dirichlet_partition, label_skew};
pub use synth::{SynthKind, SynthSpec};

use crate::rng::Rng;

/// Feature batch: vision-like models take f32, text-like take i32 tokens.
#[derive(Debug, Clone, PartialEq)]
pub enum Features {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl Features {
    pub fn len(&self) -> usize {
        match self {
            Features::F32(v) => v.len(),
            Features::I32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// One client's shard: sample descriptors, not materialized samples.
#[derive(Debug, Clone, Default)]
pub struct Shard {
    /// (class, per-class sample index) pairs.
    pub samples: Vec<(u16, u32)>,
}

/// A federated dataset: N client shards + a held-out test set, all
/// backed by one deterministic generator.
pub struct FedDataset {
    pub spec: SynthSpec,
    pub shards: Vec<Shard>,
    pub test: Vec<(u16, u32)>,
    seed: u64,
}

impl FedDataset {
    /// Build a federation: `per_client` mean samples per client,
    /// Dirichlet(alpha) label skew, `test_size` held-out samples.
    pub fn new(
        spec: SynthSpec,
        num_clients: usize,
        per_client: usize,
        alpha: f64,
        test_size: usize,
        seed: u64,
    ) -> Self {
        let total = num_clients * per_client;
        let k = spec.num_classes;
        // Roughly balanced class counts in the global pool.
        let per_class = total / k + 1;
        let assignment = dirichlet_partition(k, num_clients, per_class, alpha, seed);
        let mut shards = vec![Shard::default(); num_clients];
        for (class, clients) in assignment.iter().enumerate() {
            let mut next_idx = 0u32;
            for (client, count) in clients.iter().enumerate() {
                for _ in 0..*count {
                    shards[client].samples.push((class as u16, next_idx));
                    next_idx += 1;
                }
            }
        }
        // Shuffle each shard so batches mix classes.
        for (i, shard) in shards.iter_mut().enumerate() {
            let mut rng = Rng::seed_from_u64(seed ^ 0x5e11_0000 ^ i as u64);
            rng.shuffle(&mut shard.samples);
        }
        // Test set: balanced classes, index space disjoint from train
        // (train uses indices < per_class; test uses >= 1<<24).
        let mut test = Vec::with_capacity(test_size);
        for i in 0..test_size {
            test.push(((i % k) as u16, (1 << 24) + (i / k) as u32));
        }
        FedDataset { spec, shards, test, seed }
    }

    pub fn num_clients(&self) -> usize {
        self.shards.len()
    }

    /// Materialize `tau` batches of `batch` samples for one client at a
    /// given round (cycling through the shard deterministically).
    /// Returns features flattened to [tau*batch*feat] and labels
    /// [tau*batch].
    pub fn client_batches(
        &self,
        client: usize,
        round: usize,
        tau: usize,
        batch: usize,
    ) -> (Features, Vec<i32>) {
        let shard = &self.shards[client];
        let n = tau * batch;
        let mut picks = Vec::with_capacity(n);
        if shard.samples.is_empty() {
            // Empty shard (extreme Dirichlet skew): fall back to class-0
            // noise samples so the graph still executes.
            for i in 0..n {
                picks.push((0u16, (1 << 30) + i as u32));
            }
        } else {
            let start = round * n;
            for i in 0..n {
                picks.push(shard.samples[(start + i) % shard.samples.len()]);
            }
        }
        self.materialize(&picks)
    }

    /// Materialize an arbitrary slice of the test set, padding by
    /// wrapping so the chunk is always exactly `chunk` samples.
    /// Returns (features, labels, valid_count).
    pub fn test_chunk(&self, offset: usize, chunk: usize) -> (Features, Vec<i32>, usize) {
        let mut picks = Vec::with_capacity(chunk);
        let valid = chunk.min(self.test.len().saturating_sub(offset));
        for i in 0..chunk {
            let idx = (offset + i) % self.test.len();
            picks.push(self.test[idx]);
        }
        let (f, y) = self.materialize(&picks);
        (f, y, valid)
    }

    pub fn test_len(&self) -> usize {
        self.test.len()
    }

    fn materialize(&self, picks: &[(u16, u32)]) -> (Features, Vec<i32>) {
        let labels: Vec<i32> = picks.iter().map(|&(c, _)| c as i32).collect();
        let feats = self.spec.generate(self.seed, picks);
        (feats, labels)
    }

    /// Empirical label distribution per client (for tests / diagnostics).
    pub fn client_label_hist(&self, client: usize) -> Vec<usize> {
        let mut hist = vec![0usize; self.spec.num_classes];
        for &(c, _) in &self.shards[client].samples {
            hist[c as usize] += 1;
        }
        hist
    }

    /// Average total-variation distance between client label
    /// distributions and the global distribution in [0,1]; higher =
    /// more non-IID.
    pub fn noniidness(&self) -> f64 {
        label_skew(&self.shards.iter().map(|s| self.hist_of(s)).collect::<Vec<_>>())
    }

    fn hist_of(&self, s: &Shard) -> Vec<usize> {
        let mut h = vec![0usize; self.spec.num_classes];
        for &(c, _) in &s.samples {
            h[c as usize] += 1;
        }
        h
    }

    /// Deterministic per-round client subsample (Alg. 2 line 4).
    pub fn sample_clients(&self, round: usize, active: usize, seed: u64) -> Vec<usize> {
        let mut rng = Rng::seed_from_u64(seed ^ 0xc11e_0000 ^ round as u64);
        rng.sample_indices(self.num_clients(), active)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> FedDataset {
        FedDataset::new(SynthSpec::vision(8, 8, 1, 4), 10, 50, 0.5, 64, 7)
    }

    #[test]
    fn shards_cover_clients() {
        let ds = tiny();
        assert_eq!(ds.num_clients(), 10);
        let total: usize = ds.shards.iter().map(|s| s.samples.len()).sum();
        assert!(total >= 10 * 50 / 2, "total {total}");
    }

    #[test]
    fn batches_are_deterministic() {
        let ds = tiny();
        let (f1, y1) = ds.client_batches(3, 2, 4, 8);
        let (f2, y2) = ds.client_batches(3, 2, 4, 8);
        assert_eq!(y1, y2);
        assert_eq!(f1, f2);
        let (_, y3) = ds.client_batches(3, 3, 4, 8);
        assert!(y1 != y3 || ds.shards[3].samples.len() <= 32);
    }

    #[test]
    fn batch_shapes() {
        let ds = tiny();
        let (f, y) = ds.client_batches(0, 0, 3, 5);
        assert_eq!(y.len(), 15);
        match f {
            Features::F32(v) => assert_eq!(v.len(), 15 * 64),
            _ => panic!("vision data must be f32"),
        }
    }

    #[test]
    fn test_chunk_pads_and_counts() {
        let ds = tiny();
        let (_, y, valid) = ds.test_chunk(60, 16);
        assert_eq!(y.len(), 16);
        assert_eq!(valid, 4);
    }

    #[test]
    fn labels_in_range() {
        let ds = tiny();
        let (_, y) = ds.client_batches(1, 0, 2, 8);
        assert!(y.iter().all(|&c| c >= 0 && c < 4));
    }

    #[test]
    fn lower_alpha_is_more_noniid() {
        let spec = SynthSpec::vision(4, 4, 1, 10);
        let iid = FedDataset::new(spec.clone(), 20, 100, 100.0, 10, 3).noniidness();
        let skew = FedDataset::new(spec, 20, 100, 0.1, 10, 3).noniidness();
        assert!(skew > iid + 0.1, "skew {skew} vs iid {iid}");
    }

    #[test]
    fn client_sampling_without_replacement() {
        let ds = tiny();
        let picks = ds.sample_clients(5, 8, 42);
        assert_eq!(picks.len(), 8);
        let mut sorted = picks.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 8);
    }

    #[test]
    fn client_sampling_varies_by_round() {
        let ds = tiny();
        assert_ne!(ds.sample_clients(0, 5, 42), ds.sample_clients(1, 5, 42));
    }
}
