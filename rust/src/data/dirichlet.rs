//! Label-based Dirichlet(alpha) partitioning — the paper's non-IID
//! generator (Section 4, "Data Heterogeneity"). For every class k we
//! draw p ~ Dir(alpha * 1_N) over the N clients and split that class's
//! samples proportionally; small alpha concentrates each class on few
//! clients (alpha=0.1 is the paper's "highly non-IID" setting).

use crate::rng::Rng;
use crate::tensor;

/// For each of `k` classes, the per-client sample counts.
/// Returns `assignment[class][client] = count`, with
/// `sum_client assignment[class] == per_class`.
pub fn dirichlet_partition(
    k: usize,
    num_clients: usize,
    per_class: usize,
    alpha: f64,
    seed: u64,
) -> Vec<Vec<usize>> {
    assert!(alpha > 0.0, "alpha must be positive");
    let mut out = Vec::with_capacity(k);
    for class in 0..k {
        let mut rng = Rng::seed_from_u64(seed ^ 0xd1f1_0000 ^ class as u64);
        let p = rng.dirichlet(alpha, num_clients);
        out.push(largest_remainder(&p, per_class));
    }
    out
}

/// Apportion `total` integer samples to proportions `p` (sums exactly).
fn largest_remainder(p: &[f64], total: usize) -> Vec<usize> {
    let raw: Vec<f64> = p.iter().map(|x| x * total as f64).collect();
    let mut counts: Vec<usize> = raw.iter().map(|&x| tensor::floor_count(x)).collect();
    let assigned: usize = counts.iter().sum();
    let mut remainders: Vec<(usize, f64)> =
        raw.iter().enumerate().map(|(i, x)| (i, x - x.floor())).collect();
    // total_cmp: a NaN proportion can never panic the sort; NaN sorts
    // as the largest remainder, deterministically (same PR 7 bug class
    // as luar/select.rs — see docs/lints.md, rule D3).
    remainders.sort_by(|a, b| b.1.total_cmp(&a.1));
    for (i, _) in remainders.iter().take(total - assigned) {
        counts[*i] += 1;
    }
    counts
}

/// Mean total-variation distance between per-client label histograms
/// and the global histogram; 0 = perfectly IID.
pub fn label_skew(hists: &[Vec<usize>]) -> f64 {
    let k = hists.first().map(|h| h.len()).unwrap_or(0);
    if k == 0 {
        return 0.0;
    }
    let mut global = vec![0usize; k];
    for h in hists {
        for (g, &c) in global.iter_mut().zip(h) {
            *g += c;
        }
    }
    let g_total: usize = global.iter().sum();
    if g_total == 0 {
        return 0.0;
    }
    let g_dist: Vec<f64> = global.iter().map(|&c| c as f64 / g_total as f64).collect();
    let mut acc = 0.0;
    let mut counted = 0usize;
    for h in hists {
        let n: usize = h.iter().sum();
        if n == 0 {
            continue;
        }
        let tv: f64 = h
            .iter()
            .zip(&g_dist)
            .map(|(&c, &g)| (c as f64 / n as f64 - g).abs())
            .sum::<f64>()
            / 2.0;
        acc += tv;
        counted += 1;
    }
    if counted == 0 {
        0.0
    } else {
        acc / counted as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn partition_sums_exactly() {
        let a = dirichlet_partition(5, 16, 1000, 0.1, 1);
        for class in &a {
            assert_eq!(class.iter().sum::<usize>(), 1000);
            assert_eq!(class.len(), 16);
        }
    }

    #[test]
    fn high_alpha_is_balanced() {
        let a = dirichlet_partition(1, 10, 10_000, 1000.0, 2);
        for &c in &a[0] {
            assert!((c as i64 - 1000).abs() < 200, "count {c}");
        }
    }

    #[test]
    fn low_alpha_is_concentrated() {
        let a = dirichlet_partition(1, 10, 10_000, 0.05, 3);
        let max = *a[0].iter().max().unwrap();
        assert!(max > 5_000, "max shard only {max}");
    }

    #[test]
    fn deterministic_by_seed() {
        assert_eq!(
            dirichlet_partition(3, 8, 100, 0.5, 9),
            dirichlet_partition(3, 8, 100, 0.5, 9)
        );
        assert_ne!(
            dirichlet_partition(3, 8, 100, 0.5, 9),
            dirichlet_partition(3, 8, 100, 0.5, 10)
        );
    }

    #[test]
    fn skew_metric_bounds() {
        // perfectly IID
        let iid = vec![vec![10, 10], vec![10, 10]];
        assert!(label_skew(&iid) < 1e-9);
        // fully partitioned
        let apart = vec![vec![20, 0], vec![0, 20]];
        let s = label_skew(&apart);
        assert!(s > 0.49 && s <= 0.5 + 1e-9, "skew {s}");
    }

    #[test]
    fn largest_remainder_exact() {
        let c = largest_remainder(&[0.3333, 0.3333, 0.3334], 10);
        assert_eq!(c.iter().sum::<usize>(), 10);
    }
}
