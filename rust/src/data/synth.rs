//! Deterministic synthetic sample generators.
//!
//! Vision-like: each class has a random spatial prototype; a sample is
//! prototype + Gaussian noise + occasional label-preserving jitter.
//! Difficulty (noise scale) is tuned so federated baselines land in
//! the paper's mid-accuracy regime rather than saturating.
//!
//! Text-like: each class has a topic distribution over the vocab
//! (a boosted subset of topic tokens); a sample is an iid token
//! sequence from that distribution. The transformer must learn
//! embeddings + pooling to separate classes.

use super::Features;
use crate::rng::Rng;

#[derive(Debug, Clone, PartialEq)]
pub enum SynthKind {
    /// h, w, c — f32 images in NHWC.
    Vision { h: usize, w: usize, c: usize },
    /// seq, vocab — i32 token sequences.
    Text { seq: usize, vocab: usize },
}

#[derive(Debug, Clone, PartialEq)]
pub struct SynthSpec {
    pub kind: SynthKind,
    pub num_classes: usize,
    /// Noise std relative to prototype scale (vision) / topic boost (text).
    pub difficulty: f32,
}

impl SynthSpec {
    pub fn vision(h: usize, w: usize, c: usize, num_classes: usize) -> Self {
        SynthSpec { kind: SynthKind::Vision { h, w, c }, num_classes, difficulty: 2.0 }
    }

    pub fn text(seq: usize, vocab: usize, num_classes: usize) -> Self {
        SynthSpec { kind: SynthKind::Text { seq, vocab }, num_classes, difficulty: 2.0 }
    }

    pub fn with_difficulty(mut self, d: f32) -> Self {
        self.difficulty = d;
        self
    }

    pub fn feature_elems(&self) -> usize {
        match self.kind {
            SynthKind::Vision { h, w, c } => h * w * c,
            SynthKind::Text { seq, .. } => seq,
        }
    }

    /// Generate the samples described by `picks` into one flat buffer.
    pub fn generate(&self, seed: u64, picks: &[(u16, u32)]) -> Features {
        match self.kind {
            SynthKind::Vision { .. } => Features::F32(self.gen_vision(seed, picks)),
            SynthKind::Text { .. } => Features::I32(self.gen_text(seed, picks)),
        }
    }

    fn proto_rng(&self, seed: u64, class: u16) -> Rng {
        Rng::seed_from_u64(seed ^ 0x9e37_79b9_7f4a_7c15 ^ ((class as u64) << 32))
    }

    fn sample_rng(&self, seed: u64, class: u16, idx: u32) -> Rng {
        Rng::seed_from_u64(
            seed.wrapping_mul(0x2545_f491_4f6c_dd1d)
                ^ ((class as u64) << 40)
                ^ ((idx as u64).wrapping_mul(0x9e37_79b9)),
        )
    }

    fn gen_vision(&self, seed: u64, picks: &[(u16, u32)]) -> Vec<f32> {
        let elems = self.feature_elems();
        // Cache prototypes per class for this call.
        let mut protos: Vec<Option<Vec<f32>>> = vec![None; self.num_classes];
        let mut out = Vec::with_capacity(picks.len() * elems);
        for &(class, idx) in picks {
            let proto = protos[class as usize].get_or_insert_with(|| {
                let mut rng = self.proto_rng(seed, class);
                (0..elems).map(|_| rng.normal_f32(0.0, 1.0)).collect()
            });
            let mut rng = self.sample_rng(seed, class, idx);
            let sigma = self.difficulty;
            for &p in proto.iter() {
                out.push(p + sigma * rng.normal_f32(0.0, 1.0));
            }
        }
        out
    }

    fn gen_text(&self, seed: u64, picks: &[(u16, u32)]) -> Vec<i32> {
        let (seq, vocab) = match self.kind {
            SynthKind::Text { seq, vocab } => (seq, vocab),
            _ => unreachable!(),
        };
        // Topic tokens: each class boosts `topic_n` tokens of the vocab.
        let topic_n = (vocab / 16).max(4);
        let mut out = Vec::with_capacity(picks.len() * seq);
        // topic probability: p(topic token) = boost / (boost + 1)
        let boost = (4.0 / self.difficulty).max(0.5) as f64;
        let p_topic = boost / (boost + 1.0);
        for &(class, idx) in picks {
            let mut proto_rng = self.proto_rng(seed, class);
            let topics: Vec<i32> =
                (0..topic_n).map(|_| proto_rng.gen_range(0, vocab) as i32).collect();
            let mut rng = self.sample_rng(seed, class, idx);
            for _ in 0..seq {
                if rng.gen_bool(p_topic) {
                    out.push(topics[rng.gen_range(0, topic_n)]);
                } else {
                    out.push(rng.gen_range(0, vocab) as i32);
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vision_shapes_and_determinism() {
        let s = SynthSpec::vision(4, 4, 3, 5);
        let a = s.generate(1, &[(0, 0), (1, 7)]);
        let b = s.generate(1, &[(0, 0), (1, 7)]);
        assert_eq!(a, b);
        assert_eq!(a.len(), 2 * 48);
    }

    #[test]
    fn vision_classes_differ() {
        let s = SynthSpec::vision(8, 8, 1, 3).with_difficulty(0.1);
        let a = match s.generate(2, &[(0, 0)]) {
            Features::F32(v) => v,
            _ => unreachable!(),
        };
        let b = match s.generate(2, &[(1, 0)]) {
            Features::F32(v) => v,
            _ => unreachable!(),
        };
        let d: f32 = a.iter().zip(&b).map(|(x, y)| (x - y).powi(2)).sum();
        assert!(d > 1.0, "class prototypes too close: {d}");
    }

    #[test]
    fn vision_samples_within_class_differ() {
        let s = SynthSpec::vision(8, 8, 1, 3);
        let a = s.generate(2, &[(0, 0)]);
        let b = s.generate(2, &[(0, 1)]);
        assert_ne!(a, b);
    }

    #[test]
    fn text_tokens_in_range() {
        let s = SynthSpec::text(16, 100, 4);
        match s.generate(3, &[(2, 5), (3, 9)]) {
            Features::I32(v) => {
                assert_eq!(v.len(), 32);
                assert!(v.iter().all(|&t| (0..100).contains(&t)));
            }
            _ => panic!("text must be i32"),
        }
    }

    #[test]
    fn text_topic_bias_detectable() {
        // With low difficulty, a class's sequences reuse topic tokens heavily.
        let s = SynthSpec::text(64, 512, 4).with_difficulty(0.5);
        let v = match s.generate(4, &[(1, 0), (1, 1), (1, 2)]) {
            Features::I32(v) => v,
            _ => unreachable!(),
        };
        // BTreeMap keeps even test-side aggregation order-stable (D1).
        let mut hist = std::collections::BTreeMap::new();
        for &t in &v {
            *hist.entry(t).or_insert(0usize) += 1;
        }
        let max = *hist.values().max().unwrap();
        assert!(max >= 4, "no repeated topic tokens, max count {max}");
    }

    #[test]
    fn difficulty_scales_noise() {
        let easy = SynthSpec::vision(6, 6, 1, 2).with_difficulty(0.01);
        let hard = SynthSpec::vision(6, 6, 1, 2).with_difficulty(5.0);
        let p = |s: &SynthSpec| match s.generate(5, &[(0, 0), (0, 1)]) {
            Features::F32(v) => {
                let (a, b) = v.split_at(36);
                a.iter().zip(b).map(|(x, y)| (x - y).powi(2)).sum::<f32>()
            }
            _ => unreachable!(),
        };
        assert!(p(&hard) > 100.0 * p(&easy));
    }
}
