//! Run metrics: per-round history, per-absorb records (async mode),
//! accuracy/loss records, CSV output.

use crate::obs;
use std::io::Write;
use std::path::Path;

/// Parse accounting for the CSV reload paths. The reloaders keep the
/// permissive row handling (a cache reload should salvage what it can)
/// but no longer do it silently: every dropped or patched row is
/// counted here and surfaced via the `history.csv_*` obs counters.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CsvReport {
    /// Data rows parsed into records.
    pub rows: usize,
    /// Rows dropped entirely (unrecognized column count).
    pub skipped: usize,
    /// Rows kept with at least one malformed numeric field replaced by
    /// the NaN/0 placeholder.
    pub degraded: usize,
}

impl CsvReport {
    pub fn is_clean(&self) -> bool {
        self.skipped == 0 && self.degraded == 0
    }
}

// Lenient field parsers for the reload paths: same NaN/0 placeholders
// the reader always used, but malformed fields flip the row's
// `degraded` flag. Module fns (not closures) so both can borrow the
// same flag.
fn lenient_f64(s: &str, degraded: &mut bool) -> f64 {
    s.parse::<f64>().unwrap_or_else(|_| {
        *degraded = true;
        f64::NAN
    })
}

fn lenient_int<T: std::str::FromStr + Default>(s: &str, degraded: &mut bool) -> T {
    s.parse::<T>().unwrap_or_else(|_| {
        *degraded = true;
        T::default()
    })
}

/// One evaluated checkpoint of a run.
#[derive(Debug, Clone)]
pub struct RoundRecord {
    pub round: usize,
    pub train_loss: f64,
    pub test_loss: f64,
    pub test_acc: f64,
    /// Cumulative upload bytes so far.
    pub up_bytes: u64,
    /// Upload cost normalized to FedAvg-so-far.
    pub comm_ratio: f64,
    /// kappa_t = ||recycled-layer update||^2 / ||full update||^2
    /// (Theorem 2 requires < 1/16 for convergence).
    pub kappa: f64,
    /// Simulated communication wall-clock so far (net scheduler).
    pub sim_seconds: f64,
    /// Measured uplink wire bytes this round (sum of frame lengths).
    pub wire_bytes: u64,
    /// Straggler tail this round: slowest arrival minus the median.
    pub tail_s: f64,
    /// Uploads aggregated this round (survivors under deadline/buffered).
    pub arrivals: usize,
    /// Mean model-version gap of the aggregated uploads (async mode;
    /// 0 under the barrier round modes).
    pub version_gap: f64,
}

/// One upload landing on the async server (`round_mode = async:...`):
/// the per-absorb telemetry behind the staleness discounts.
#[derive(Debug, Clone)]
pub struct AbsorbRecord {
    /// Server model version at the moment of absorption.
    pub version: u64,
    pub client: usize,
    /// Absolute simulated arrival time.
    pub t: f64,
    /// Server versions that closed while this upload was in flight.
    pub version_gap: u64,
    /// Staleness-discounted aggregation weight.
    pub weight: f32,
    /// Uploads still in flight after this absorb.
    pub in_flight: usize,
    /// Aggregation-buffer depth after this absorb.
    pub queue_depth: usize,
}

/// Full history of a run plus its terminal summary.
#[derive(Debug, Clone, Default)]
pub struct History {
    pub records: Vec<RoundRecord>,
    /// Per-absorb records (empty under the barrier round modes).
    pub absorbs: Vec<AbsorbRecord>,
}

impl History {
    pub fn push(&mut self, r: RoundRecord) {
        self.records.push(r);
    }

    pub fn final_acc(&self) -> f64 {
        self.records.last().map(|r| r.test_acc).unwrap_or(0.0)
    }

    /// Mean of the last `k` evaluated accuracies (the paper reports
    /// averaged terminal accuracy over repeats; within one run this
    /// smooths evaluation noise).
    pub fn tail_acc(&self, k: usize) -> f64 {
        if self.records.is_empty() {
            return 0.0;
        }
        let n = self.records.len();
        let lo = n.saturating_sub(k);
        let slice = &self.records[lo..];
        slice.iter().map(|r| r.test_acc).sum::<f64>() / slice.len() as f64
    }

    pub fn best_acc(&self) -> f64 {
        self.records.iter().map(|r| r.test_acc).fold(0.0, f64::max)
    }

    pub fn final_comm_ratio(&self) -> f64 {
        self.records.last().map(|r| r.comm_ratio).unwrap_or(0.0)
    }

    pub fn max_kappa(&self) -> f64 {
        self.records.iter().map(|r| r.kappa).fold(0.0, f64::max)
    }

    pub fn write_csv(&self, path: impl AsRef<Path>) -> std::io::Result<()> {
        if let Some(parent) = path.as_ref().parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut f = std::fs::File::create(path)?;
        writeln!(
            f,
            "round,train_loss,test_loss,test_acc,up_bytes,comm_ratio,kappa,sim_seconds,\
             wire_bytes,tail_s,arrivals,version_gap"
        )?;
        for r in &self.records {
            writeln!(
                f,
                "{},{:.6},{:.6},{:.4},{},{:.6},{:.6},{:.3},{},{:.3},{},{:.3}",
                r.round,
                r.train_loss,
                r.test_loss,
                r.test_acc,
                r.up_bytes,
                r.comm_ratio,
                r.kappa,
                r.sim_seconds,
                r.wire_bytes,
                r.tail_s,
                r.arrivals,
                r.version_gap
            )?;
        }
        Ok(())
    }

    /// Write the per-absorb telemetry (async runs) next to the round
    /// CSV: one row per upload landing on the server.
    pub fn write_absorb_csv(&self, path: impl AsRef<Path>) -> std::io::Result<()> {
        if let Some(parent) = path.as_ref().parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut f = std::fs::File::create(path)?;
        writeln!(f, "version,client,t,version_gap,weight,in_flight,queue_depth")?;
        for a in &self.absorbs {
            writeln!(
                f,
                "{},{},{:.6},{},{:.6},{},{}",
                a.version, a.client, a.t, a.version_gap, a.weight, a.in_flight, a.queue_depth
            )?;
        }
        Ok(())
    }
}

impl History {
    /// Parse a CSV written by `write_csv` (run-cache reload path),
    /// surfacing any skipped/degraded rows through the obs counters
    /// `history.csv_rows_skipped` / `history.csv_rows_degraded`. Use
    /// `read_csv_report` to inspect the parse accounting directly.
    pub fn read_csv(path: impl AsRef<Path>) -> std::io::Result<History> {
        let (h, rep) = Self::read_csv_report(path)?;
        obs::counter("history.csv_rows_skipped", rep.skipped as u64);
        obs::counter("history.csv_rows_degraded", rep.degraded as u64);
        Ok(h)
    }

    /// `read_csv` plus the parse report: rows kept, rows dropped for a
    /// wrong column count, and rows kept with NaN/0-patched fields.
    pub fn read_csv_report(path: impl AsRef<Path>) -> std::io::Result<(History, CsvReport)> {
        let text = std::fs::read_to_string(path)?;
        let mut h = History::default();
        let mut rep = CsvReport::default();
        for line in text.lines().skip(1) {
            if line.is_empty() {
                continue;
            }
            let f: Vec<&str> = line.split(',').collect();
            // 8 columns = pre-net CSVs, 11 = PR 1 format, 12 = current
            if f.len() != 8 && f.len() != 11 && f.len() != 12 {
                rep.skipped += 1;
                continue;
            }
            let mut bad = false;
            let b = &mut bad;
            h.push(RoundRecord {
                round: lenient_int(f[0], b),
                train_loss: lenient_f64(f[1], b),
                test_loss: lenient_f64(f[2], b),
                test_acc: lenient_f64(f[3], b),
                up_bytes: lenient_int(f[4], b),
                comm_ratio: lenient_f64(f[5], b),
                kappa: lenient_f64(f[6], b),
                sim_seconds: lenient_f64(f[7], b),
                wire_bytes: if f.len() >= 11 { lenient_int(f[8], b) } else { 0 },
                tail_s: if f.len() >= 11 { lenient_f64(f[9], b) } else { 0.0 },
                arrivals: if f.len() >= 11 { lenient_int(f[10], b) } else { 0 },
                version_gap: if f.len() == 12 { lenient_f64(f[11], b) } else { 0.0 },
            });
            rep.rows += 1;
            if bad {
                rep.degraded += 1;
            }
        }
        Ok((h, rep))
    }

    /// Parse a CSV written by `write_absorb_csv` (the async per-absorb
    /// telemetry), with the same obs-counter surfacing as `read_csv`.
    pub fn read_absorb_csv(path: impl AsRef<Path>) -> std::io::Result<Vec<AbsorbRecord>> {
        let (absorbs, rep) = Self::read_absorb_csv_report(path)?;
        obs::counter("history.csv_rows_skipped", rep.skipped as u64);
        obs::counter("history.csv_rows_degraded", rep.degraded as u64);
        Ok(absorbs)
    }

    /// `read_absorb_csv` plus the parse report.
    pub fn read_absorb_csv_report(
        path: impl AsRef<Path>,
    ) -> std::io::Result<(Vec<AbsorbRecord>, CsvReport)> {
        let text = std::fs::read_to_string(path)?;
        let mut absorbs = Vec::new();
        let mut rep = CsvReport::default();
        for line in text.lines().skip(1) {
            if line.is_empty() {
                continue;
            }
            let f: Vec<&str> = line.split(',').collect();
            if f.len() != 7 {
                rep.skipped += 1;
                continue;
            }
            let mut bad = false;
            let b = &mut bad;
            absorbs.push(AbsorbRecord {
                version: lenient_int(f[0], b),
                client: lenient_int(f[1], b),
                t: lenient_f64(f[2], b),
                version_gap: lenient_int(f[3], b),
                weight: lenient_f64(f[4], b) as f32,
                in_flight: lenient_int(f[5], b),
                queue_depth: lenient_int(f[6], b),
            });
            rep.rows += 1;
            if bad {
                rep.degraded += 1;
            }
        }
        Ok((absorbs, rep))
    }
}

/// Mean and (population) std over repeated-run accuracies, formatted
/// the way the paper's tables report them.
pub fn mean_std(xs: &[f64]) -> (f64, f64) {
    if xs.is_empty() {
        return (0.0, 0.0);
    }
    let m = xs.iter().sum::<f64>() / xs.len() as f64;
    let v = xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / xs.len() as f64;
    (m, v.sqrt())
}

pub fn fmt_acc(mean: f64, std: f64) -> String {
    format!("{:5.2} ± {:.1}%", mean * 100.0, std * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(round: usize, acc: f64) -> RoundRecord {
        RoundRecord {
            round,
            train_loss: 1.0,
            test_loss: 1.0,
            test_acc: acc,
            up_bytes: 10,
            comm_ratio: 0.5,
            kappa: 0.01,
            sim_seconds: 1.0,
            wire_bytes: 10,
            tail_s: 0.2,
            arrivals: 4,
            version_gap: 1.5,
        }
    }

    #[test]
    fn tail_and_best() {
        let mut h = History::default();
        for (i, a) in [0.1, 0.5, 0.4, 0.6].iter().enumerate() {
            h.push(rec(i, *a));
        }
        assert!((h.final_acc() - 0.6).abs() < 1e-12);
        assert!((h.best_acc() - 0.6).abs() < 1e-12);
        assert!((h.tail_acc(2) - 0.5).abs() < 1e-12);
        assert!((h.tail_acc(100) - 0.4).abs() < 1e-12);
    }

    #[test]
    fn empty_history_is_zeroes() {
        let h = History::default();
        assert_eq!(h.final_acc(), 0.0);
        assert_eq!(h.tail_acc(3), 0.0);
        assert_eq!(h.max_kappa(), 0.0);
    }

    #[test]
    fn csv_written() {
        let mut h = History::default();
        h.push(rec(0, 0.3));
        let dir = std::env::temp_dir().join("fedluar_metrics_test");
        let path = dir.join("run.csv");
        h.write_csv(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with("round,"));
        assert!(text
            .lines()
            .next()
            .unwrap()
            .ends_with("wire_bytes,tail_s,arrivals,version_gap"));
        assert_eq!(text.lines().count(), 2);
        let back = History::read_csv(&path).unwrap();
        assert_eq!(back.records.len(), 1);
        assert_eq!(back.records[0].wire_bytes, 10);
        assert_eq!(back.records[0].arrivals, 4);
        assert!((back.records[0].tail_s - 0.2).abs() < 1e-9);
        assert!((back.records[0].version_gap - 1.5).abs() < 1e-9);
    }

    #[test]
    fn read_csv_accepts_pr1_11_column_format() {
        let dir = std::env::temp_dir().join("fedluar_metrics_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("pr1.csv");
        std::fs::write(
            &path,
            "round,train_loss,test_loss,test_acc,up_bytes,comm_ratio,kappa,sim_seconds,\
             wire_bytes,tail_s,arrivals\n\
             2,1.0,1.1,0.5,42,0.5,0.01,2.5,99,0.3,7\n",
        )
        .unwrap();
        let h = History::read_csv(&path).unwrap();
        assert_eq!(h.records.len(), 1);
        assert_eq!(h.records[0].wire_bytes, 99);
        assert_eq!(h.records[0].arrivals, 7);
        assert_eq!(h.records[0].version_gap, 0.0, "PR 1 rows default the async column");
    }

    #[test]
    fn absorb_csv_written() {
        let mut h = History::default();
        h.absorbs.push(AbsorbRecord {
            version: 3,
            client: 11,
            t: 2.25,
            version_gap: 2,
            weight: 0.577,
            in_flight: 4,
            queue_depth: 5,
        });
        let dir = std::env::temp_dir().join("fedluar_metrics_test");
        let path = dir.join("absorbs.csv");
        h.write_absorb_csv(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with("version,client,t,version_gap,weight,in_flight,queue_depth"));
        assert_eq!(text.lines().count(), 2);
        assert!(text.lines().nth(1).unwrap().starts_with("3,11,2.250000,2,0.577"));
    }

    #[test]
    fn read_csv_accepts_pre_net_format() {
        let dir = std::env::temp_dir().join("fedluar_metrics_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("legacy.csv");
        std::fs::write(
            &path,
            "round,train_loss,test_loss,test_acc,up_bytes,comm_ratio,kappa,sim_seconds\n\
             3,1.0,1.1,0.5,42,0.5,0.01,2.5\n",
        )
        .unwrap();
        let h = History::read_csv(&path).unwrap();
        assert_eq!(h.records.len(), 1);
        assert_eq!(h.records[0].up_bytes, 42);
        assert_eq!(h.records[0].wire_bytes, 0, "legacy rows default the net columns");
    }

    #[test]
    fn absorb_csv_round_trips() {
        let mut h = History::default();
        for i in 0..3u64 {
            h.absorbs.push(AbsorbRecord {
                version: i,
                client: (i * 7) as usize,
                t: 0.5 + i as f64,
                version_gap: i,
                weight: 1.0 / (1.0 + i as f32),
                in_flight: 4 - i as usize,
                queue_depth: i as usize + 1,
            });
        }
        let dir = std::env::temp_dir().join("fedluar_metrics_test");
        let path = dir.join("absorbs_rt.csv");
        h.write_absorb_csv(&path).unwrap();
        let (back, rep) = History::read_absorb_csv_report(&path).unwrap();
        assert_eq!(rep, CsvReport { rows: 3, skipped: 0, degraded: 0 });
        assert!(rep.is_clean());
        assert_eq!(back.len(), 3);
        for (a, b) in h.absorbs.iter().zip(&back) {
            assert_eq!(a.version, b.version);
            assert_eq!(a.client, b.client);
            assert_eq!(a.version_gap, b.version_gap);
            assert_eq!(a.in_flight, b.in_flight);
            assert_eq!(a.queue_depth, b.queue_depth);
            assert!((a.t - b.t).abs() < 1e-6);
            assert!((a.weight - b.weight).abs() < 1e-6);
        }
    }

    #[test]
    fn read_csv_report_counts_skipped_and_degraded_rows() {
        let dir = std::env::temp_dir().join("fedluar_metrics_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("dirty.csv");
        // row 1: clean legacy row; row 2: wrong column count (dropped);
        // row 3: malformed numerics (kept, NaN/0-patched).
        std::fs::write(
            &path,
            "round,train_loss,test_loss,test_acc,up_bytes,comm_ratio,kappa,sim_seconds\n\
             3,1.0,1.1,0.5,42,0.5,0.01,2.5\n\
             oops,truncated\n\
             4,xx,1.1,0.5,yy,0.5,0.01,2.5\n",
        )
        .unwrap();
        let (h, rep) = History::read_csv_report(&path).unwrap();
        assert_eq!(rep, CsvReport { rows: 2, skipped: 1, degraded: 1 });
        assert!(!rep.is_clean());
        assert_eq!(h.records.len(), 2);
        assert_eq!(h.records[0].up_bytes, 42);
        // degraded row keeps the old placeholder semantics, just counted
        assert!(h.records[1].train_loss.is_nan());
        assert_eq!(h.records[1].up_bytes, 0);
        assert_eq!(h.records[1].round, 4, "well-formed fields still parse");
    }

    #[test]
    fn read_absorb_csv_report_counts_bad_rows() {
        let dir = std::env::temp_dir().join("fedluar_metrics_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("absorbs_dirty.csv");
        std::fs::write(
            &path,
            "version,client,t,version_gap,weight,in_flight,queue_depth\n\
             1,2,0.500000,0,1.000000,3,1\n\
             not,enough,columns\n\
             2,bad,0.750000,1,0.707000,2,2\n",
        )
        .unwrap();
        let (absorbs, rep) = History::read_absorb_csv_report(&path).unwrap();
        assert_eq!(rep, CsvReport { rows: 2, skipped: 1, degraded: 1 });
        assert_eq!(absorbs.len(), 2);
        assert_eq!(absorbs[0].client, 2);
        assert_eq!(absorbs[1].client, 0, "malformed client falls back to 0");
        assert_eq!(absorbs[1].version, 2);
    }

    #[test]
    fn mean_std_basic() {
        let (m, s) = mean_std(&[0.5, 0.7]);
        assert!((m - 0.6).abs() < 1e-12);
        assert!((s - 0.1).abs() < 1e-12);
        assert_eq!(mean_std(&[]), (0.0, 0.0));
    }

    #[test]
    fn fmt_acc_shape() {
        let s = fmt_acc(0.6123, 0.007);
        assert!(s.contains("61.23"));
        assert!(s.contains("0.7%"));
    }
}
