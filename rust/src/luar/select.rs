//! Layer-selection schemes (Table 4 ablation).
//!
//! `Luar` is the paper's scheme: weighted random sampling without
//! replacement by p ∝ 1/s. The alternatives exist to reproduce the
//! ablation: uniform random, input-side, output-side, smallest
//! gradient norm, and deterministic smallest-s (which the paper shows
//! recycles the same layers until they go stale and diverge).

use crate::config::SelectionScheme;
use crate::rng::Rng;

/// Pick the delta-sized recycle set R_{t+1}.
///
/// * `scores` / `observed` — s_{t,l} and whether it was ever measured;
/// * `probs` — Eq. 2 distribution (zeros if nothing observed yet);
/// * `grad_norms` — per-layer aggregated update norms for `GradNorm`.
pub fn select_layers(
    scheme: SelectionScheme,
    delta: usize,
    scores: &[f64],
    observed: &[bool],
    probs: &[f64],
    grad_norms: &[f64],
    rng: &mut Rng,
) -> Vec<usize> {
    let num_layers = scores.len();
    let delta = delta.min(num_layers);
    if delta == 0 {
        return Vec::new();
    }
    // Before any score is observed nothing may be recycled (round 0).
    if !observed.iter().any(|&o| o) {
        return Vec::new();
    }
    match scheme {
        SelectionScheme::Luar => {
            if probs.iter().sum::<f64>() <= 0.0 {
                return Vec::new();
            }
            rng.weighted_sample_without_replacement(probs, delta)
        }
        SelectionScheme::Random => rng.sample_indices(num_layers, delta),
        SelectionScheme::Top => (0..delta).collect(),
        SelectionScheme::Bottom => (num_layers - delta..num_layers).collect(),
        SelectionScheme::GradNorm => smallest_k(grad_norms, delta),
        SelectionScheme::Deterministic => {
            // smallest observed s deterministically, every round;
            // never more than the observed count
            let masked: Vec<f64> = scores
                .iter()
                .zip(observed)
                .map(|(&s, &o)| if o { s } else { f64::INFINITY })
                .collect();
            let mut sel = smallest_k(&masked, delta);
            sel.retain(|&l| observed[l]);
            sel
        }
    }
}

/// Indices of the k smallest values (stable order by value then index).
///
/// `total_cmp` gives NaN a fixed place at the top of the order (above
/// +inf), so a NaN score — e.g. an all-zero parameter norm — can never
/// silently tie and make the selection depend on layer index order.
fn smallest_k(values: &[f64], k: usize) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..values.len()).collect();
    idx.sort_by(|&a, &b| values[a].total_cmp(&values[b]).then(a.cmp(&b)));
    idx.truncate(k);
    idx
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rng() -> Rng {
        Rng::seed_from_u64(1234)
    }

    #[test]
    fn nothing_recycled_before_first_observation() {
        let mut r = rng();
        let sel = select_layers(
            SelectionScheme::Luar,
            2,
            &[0.0; 4],
            &[false; 4],
            &[0.0; 4],
            &[0.0; 4],
            &mut r,
        );
        assert!(sel.is_empty());
    }

    #[test]
    fn luar_prefers_low_score_layers() {
        let mut r = rng();
        let scores = vec![0.001, 1.0, 1.0, 1.0];
        let observed = vec![true; 4];
        let inv: Vec<f64> = scores.iter().map(|s| 1.0 / s).collect();
        let total: f64 = inv.iter().sum();
        let probs: Vec<f64> = inv.iter().map(|v| v / total).collect();
        let mut hits = 0;
        for _ in 0..100 {
            let sel = select_layers(
                SelectionScheme::Luar,
                1,
                &scores,
                &observed,
                &probs,
                &[0.0; 4],
                &mut r,
            );
            if sel == vec![0] {
                hits += 1;
            }
        }
        assert!(hits > 95, "low-s layer picked {hits}/100");
    }

    #[test]
    fn top_and_bottom_are_positional() {
        let mut r = rng();
        let obs = vec![true; 5];
        let s = vec![1.0; 5];
        let p = vec![0.2; 5];
        assert_eq!(
            select_layers(SelectionScheme::Top, 2, &s, &obs, &p, &[0.0; 5], &mut r),
            vec![0, 1]
        );
        assert_eq!(
            select_layers(SelectionScheme::Bottom, 2, &s, &obs, &p, &[0.0; 5], &mut r),
            vec![3, 4]
        );
    }

    #[test]
    fn gradnorm_picks_smallest_norms() {
        let mut r = rng();
        let obs = vec![true; 4];
        let sel = select_layers(
            SelectionScheme::GradNorm,
            2,
            &[1.0; 4],
            &obs,
            &[0.25; 4],
            &[5.0, 0.1, 3.0, 0.2],
            &mut r,
        );
        assert_eq!(sel, vec![1, 3]);
    }

    #[test]
    fn deterministic_picks_smallest_scores_every_time() {
        let mut r = rng();
        let obs = vec![true, true, false, true];
        let s = vec![0.5, 0.1, 0.0, 2.0];
        let sel1 =
            select_layers(SelectionScheme::Deterministic, 2, &s, &obs, &[0.25; 4], &[0.0; 4], &mut r);
        let sel2 =
            select_layers(SelectionScheme::Deterministic, 2, &s, &obs, &[0.25; 4], &[0.0; 4], &mut r);
        assert_eq!(sel1, vec![1, 0], "unobserved layer 2 must be excluded");
        assert_eq!(sel1, sel2);
    }

    #[test]
    fn nan_scores_sort_last_not_equal() {
        // Regression: partial_cmp(..).unwrap_or(Equal) let a NaN norm
        // tie with everything, so selection degraded to index order
        // and a NaN layer at index 0 was always "smallest".
        let mut r = rng();
        let obs = vec![true; 4];
        let sel = select_layers(
            SelectionScheme::GradNorm,
            2,
            &[1.0; 4],
            &obs,
            &[0.25; 4],
            &[f64::NAN, 0.5, f64::NAN, 0.1],
            &mut r,
        );
        assert_eq!(sel, vec![3, 1], "finite norms must win over NaN");
        // All-NaN input stays deterministic: index order, sized k.
        let all = select_layers(
            SelectionScheme::GradNorm,
            2,
            &[1.0; 4],
            &obs,
            &[0.25; 4],
            &[f64::NAN; 4],
            &mut r,
        );
        assert_eq!(all, vec![0, 1]);
    }

    #[test]
    fn deterministic_scheme_excludes_nan_scores() {
        let mut r = rng();
        let obs = vec![true; 4];
        let s = vec![f64::NAN, 0.3, 0.7, 0.1];
        let sel =
            select_layers(SelectionScheme::Deterministic, 2, &s, &obs, &[0.25; 4], &[0.0; 4], &mut r);
        assert_eq!(sel, vec![3, 1], "NaN score must sort after finite scores");
    }

    #[test]
    fn random_is_distinct_and_sized() {
        let mut r = rng();
        let obs = vec![true; 10];
        let sel =
            select_layers(SelectionScheme::Random, 4, &[1.0; 10], &obs, &[0.1; 10], &[0.0; 10], &mut r);
        assert_eq!(sel.len(), 4);
        let mut d = sel.clone();
        d.sort_unstable();
        d.dedup();
        assert_eq!(d.len(), 4);
    }

    #[test]
    fn delta_clamped_to_layer_count() {
        let mut r = rng();
        let obs = vec![true; 3];
        let sel =
            select_layers(SelectionScheme::Random, 10, &[1.0; 3], &obs, &[0.3; 3], &[0.0; 3], &mut r);
        assert_eq!(sel.len(), 3);
    }

    #[test]
    fn delta_zero_selects_nothing() {
        let mut r = rng();
        let sel = select_layers(
            SelectionScheme::Luar,
            0,
            &[1.0; 3],
            &[true; 3],
            &[0.3; 3],
            &[0.0; 3],
            &mut r,
        );
        assert!(sel.is_empty());
    }
}
