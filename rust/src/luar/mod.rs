//! LUAR — Layer-wise Update Aggregation with Recycling (Algorithm 1).
//!
//! The server-side state of the paper's contribution:
//! * `scores` — s_{t,l} = ||Delta_{t,l}|| / ||x_{t,l}|| (Eq. 1), fed by
//!   the per-layer squared norms the Pallas-backed aggregation graph
//!   returns for free;
//! * `probabilities` — p_{t,l} ∝ 1/s_{t,l} (Eq. 2);
//! * the recycle buffer \hat{Delta}_{t-1} and the composition
//!   \hat{Delta}_t = [r_t, u_t] (Eq. 3–5);
//! * the layer-selection schemes of the Table 4 ablation;
//! * kappa_t — the Theorem 2 noise ratio, logged every round.

mod adaptive;
mod select;

pub use adaptive::DeltaController;
pub use select::select_layers;

use crate::config::{RecycleMode, SelectionScheme};
use crate::model::ModelMeta;
use crate::rng::Rng;
use crate::tensor;

/// Server-side LUAR state across rounds.
#[derive(Debug, Clone)]
pub struct LuarState {
    /// s_{t,l}; starts at +inf priority (score 0 means "never observed",
    /// treated as highest priority so every layer uploads early on).
    pub scores: Vec<f64>,
    /// Whether a layer's score has ever been observed.
    pub observed: Vec<bool>,
    /// \hat{Delta}_{t-1}: the previous composed global update.
    pub prev_update: Vec<f32>,
    /// R_t: layers recycled *this* round (empty at t=0, Alg. 2 line 2).
    pub recycle_set: Vec<usize>,
    /// Aggregations since each layer last uploaded (staleness k in
    /// Eq. 6), advanced by `age_step` per compose.
    pub staleness: Vec<u32>,
    /// How much `compose_update` ages recycled layers: 1 in the
    /// barrier round modes; 1 + the mean model-version gap in async
    /// mode, where recycled information is older than one aggregation.
    /// Set per aggregation via `set_age_step`; not checkpointed (it is
    /// recomputed before every compose).
    pub age_step: u32,
}

impl LuarState {
    pub fn new(num_layers: usize, dim: usize) -> Self {
        LuarState {
            scores: vec![0.0; num_layers],
            observed: vec![false; num_layers],
            prev_update: vec![0.0; dim],
            recycle_set: Vec::new(),
            staleness: vec![0; num_layers],
            age_step: 1,
        }
    }

    /// Set how many aggregation-equivalents the next compose charges
    /// recycled layers (clamped to at least 1).
    pub fn set_age_step(&mut self, step: u32) {
        self.age_step = step.max(1);
    }

    /// Layers the clients must upload this round (complement of R_t).
    pub fn upload_set(&self, num_layers: usize) -> Vec<usize> {
        (0..num_layers).filter(|l| !self.recycle_set.contains(l)).collect()
    }

    /// Update s_{t,l} from the aggregation graph's per-layer squared
    /// norms — only for uploaded layers (recycled layers keep their
    /// stale score; the stochastic sampler is what lets them refresh
    /// later, see the paper's discussion of deterministic recycling).
    pub fn update_scores(&mut self, update_ssq: &[f32], weight_ssq: &[f32]) {
        for l in 0..self.scores.len() {
            if self.recycle_set.contains(&l) {
                continue;
            }
            let w = (weight_ssq[l] as f64).max(1e-24);
            self.scores[l] = ((update_ssq[l] as f64) / w).sqrt();
            self.observed[l] = true;
        }
    }

    /// Eq. 2: p_{t,l} ∝ 1/s_{t,l}. Unobserved layers get probability 0
    /// (they must upload at least once before they can be recycled).
    pub fn probabilities(&self) -> Vec<f64> {
        let inv: Vec<f64> = self
            .scores
            .iter()
            .zip(&self.observed)
            .map(|(&s, &obs)| if obs && s > 0.0 { 1.0 / s } else { 0.0 })
            .collect();
        let total: f64 = inv.iter().sum();
        if total <= 0.0 {
            return vec![0.0; inv.len()];
        }
        inv.iter().map(|v| v / total).collect()
    }

    /// Compose \hat{Delta}_t (Eq. 3–5) into `mean` in place:
    /// uploaded layers keep the fresh aggregate, recycled layers are
    /// overwritten with the previous round's composed update (Recycle)
    /// or zero (the Dropping ablation). Afterwards the buffer holds
    /// \hat{Delta}_t and staleness is advanced.
    ///
    /// Returns kappa_t = ||recycled part||^2 / ||\hat{Delta}_t||^2.
    pub fn compose_update(
        &mut self,
        mean: &mut [f32],
        meta: &ModelMeta,
        mode: RecycleMode,
    ) -> f64 {
        for &l in &self.recycle_set {
            let lm = &meta.layers[l];
            let range = lm.offset..lm.offset + lm.size;
            match mode {
                RecycleMode::Recycle => {
                    mean[range.clone()].copy_from_slice(&self.prev_update[range.clone()]);
                }
                RecycleMode::Drop => {
                    mean[range.clone()].iter_mut().for_each(|v| *v = 0.0);
                }
            }
        }
        // kappa before the buffer swap
        let total = tensor::ssq(mean);
        let recycled: f64 = self
            .recycle_set
            .iter()
            .map(|&l| {
                let lm = &meta.layers[l];
                tensor::ssq(&mean[lm.offset..lm.offset + lm.size])
            })
            .sum();
        let kappa = if total > 0.0 { recycled / total } else { 0.0 };
        self.prev_update.copy_from_slice(mean);
        for l in 0..self.staleness.len() {
            if self.recycle_set.contains(&l) {
                self.staleness[l] += self.age_step;
            } else {
                self.staleness[l] = 0;
            }
        }
        kappa
    }

    /// Alg. 1 lines 6–8: pick R_{t+1}.
    pub fn select_next(
        &mut self,
        scheme: SelectionScheme,
        delta: usize,
        grad_norms: &[f64],
        rng: &mut Rng,
    ) {
        let _sp = crate::obs::span("luar.select");
        self.recycle_set = select_layers(
            scheme,
            delta,
            &self.scores,
            &self.observed,
            &self.probabilities(),
            grad_norms,
            rng,
        );
        crate::obs::counter("luar.selections", 1);
        crate::obs::gauge("luar.recycled_layers", self.recycle_set.len() as f64);
    }

    pub fn max_staleness(&self) -> u32 {
        self.staleness.iter().copied().max().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelMeta;
    use std::path::PathBuf;

    fn meta() -> ModelMeta {
        ModelMeta::from_json(
            r#"{
            "model":"toy","dim":10,"num_classes":2,
            "input_shape":[4],"input_dtype":"f32",
            "tau":2,"batch":3,"eval_batch":8,"agg_clients":4,"momentum":0.9,
            "layers":[
              {"name":"a","kind":"dense","offset":0,"size":6,"arrays":[]},
              {"name":"b","kind":"dense","offset":6,"size":4,"arrays":[]}
            ],
            "artifacts":{"train":"t","eval":"e","agg":"g","init":"i"},
            "init_sha256":"x"
        }"#,
            PathBuf::from("/tmp"),
        )
        .unwrap()
    }

    #[test]
    fn scores_update_skips_recycled() {
        let mut st = LuarState::new(2, 10);
        st.update_scores(&[4.0, 9.0], &[1.0, 1.0]);
        assert!((st.scores[0] - 2.0).abs() < 1e-9);
        assert!((st.scores[1] - 3.0).abs() < 1e-9);
        st.recycle_set = vec![1];
        st.update_scores(&[1.0, 100.0], &[1.0, 1.0]);
        assert!((st.scores[0] - 1.0).abs() < 1e-9);
        assert!((st.scores[1] - 3.0).abs() < 1e-9, "recycled layer score must stay stale");
    }

    #[test]
    fn probabilities_invert_scores() {
        let mut st = LuarState::new(3, 10);
        st.update_scores(&[1.0, 4.0, 16.0], &[1.0, 1.0, 1.0]);
        let p = st.probabilities();
        assert!((p.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        // s = [1, 2, 4] -> 1/s = [1, .5, .25] -> p = [4/7, 2/7, 1/7]
        assert!(p[0] > p[1] && p[1] > p[2]);
        assert!((p[0] - 4.0 / 7.0).abs() < 1e-9);
    }

    #[test]
    fn unobserved_layers_never_sampled() {
        let st = LuarState::new(2, 10);
        assert_eq!(st.probabilities(), vec![0.0, 0.0]);
    }

    #[test]
    fn compose_recycles_previous_update() {
        let m = meta();
        let mut st = LuarState::new(2, 10);
        // round 0: full upload, buffer keeps the composed update
        let mut u0: Vec<f32> = (0..10).map(|i| i as f32).collect();
        let k0 = st.compose_update(&mut u0, &m, RecycleMode::Recycle);
        assert_eq!(k0, 0.0, "no recycled layers at t=0");
        // round 1: layer 1 recycled -> its slice must equal round 0's
        st.recycle_set = vec![1];
        let mut u1 = vec![100.0f32; 10];
        let k1 = st.compose_update(&mut u1, &m, RecycleMode::Recycle);
        assert_eq!(&u1[6..10], &[6.0, 7.0, 8.0, 9.0]);
        assert_eq!(&u1[0..6], &[100.0; 6]);
        assert!(k1 > 0.0 && k1 < 1.0);
        assert_eq!(st.staleness, vec![0, 1]);
    }

    #[test]
    fn compose_drop_zeroes() {
        let m = meta();
        let mut st = LuarState::new(2, 10);
        st.recycle_set = vec![0];
        let mut u = vec![1.0f32; 10];
        st.compose_update(&mut u, &m, RecycleMode::Drop);
        assert_eq!(&u[0..6], &[0.0; 6]);
        assert_eq!(&u[6..10], &[1.0; 4]);
    }

    #[test]
    fn kappa_is_recycled_fraction() {
        let m = meta();
        let mut st = LuarState::new(2, 10);
        let mut u0 = vec![1.0f32; 10];
        st.compose_update(&mut u0, &m, RecycleMode::Recycle);
        st.recycle_set = vec![1];
        let mut u1 = vec![1.0f32; 10];
        let k = st.compose_update(&mut u1, &m, RecycleMode::Recycle);
        // recycled layer slice has ssq 4, total 10
        assert!((k - 0.4).abs() < 1e-9, "kappa {k}");
    }

    #[test]
    fn upload_set_is_complement() {
        let mut st = LuarState::new(4, 10);
        st.recycle_set = vec![1, 3];
        assert_eq!(st.upload_set(4), vec![0, 2]);
    }

    #[test]
    fn age_step_scales_staleness_by_version_gap() {
        let m = meta();
        let mut st = LuarState::new(2, 10);
        st.recycle_set = vec![1];
        let mut u = vec![1.0f32; 10];
        // async aggregation with mean version gap 2: recycled layers
        // age by 3 aggregation-equivalents
        st.set_age_step(3);
        st.compose_update(&mut u, &m, RecycleMode::Recycle);
        assert_eq!(st.staleness, vec![0, 3]);
        // a zero step clamps to the sync behavior
        st.set_age_step(0);
        assert_eq!(st.age_step, 1);
        st.compose_update(&mut u, &m, RecycleMode::Recycle);
        assert_eq!(st.staleness, vec![0, 4]);
    }

    #[test]
    fn staleness_resets_on_upload() {
        let m = meta();
        let mut st = LuarState::new(2, 10);
        st.recycle_set = vec![1];
        let mut u = vec![1.0f32; 10];
        st.compose_update(&mut u, &m, RecycleMode::Recycle);
        st.compose_update(&mut u, &m, RecycleMode::Recycle);
        assert_eq!(st.staleness[1], 2);
        st.recycle_set = vec![];
        st.compose_update(&mut u, &m, RecycleMode::Recycle);
        assert_eq!(st.staleness, vec![0, 0]);
        assert_eq!(st.max_staleness(), 0);
    }
}
