//! Kappa-adaptive recycling depth — the Theorem 2 bound as a policy.
//!
//! Theorem 2 guarantees convergence to a stationary-point neighborhood
//! only while kappa_t = ||recycled update||^2 / ||full update||^2 stays
//! below 1/16. The paper leaves delta as a hand-tuned hyper-parameter;
//! this controller (an extension implementing the paper's own theory)
//! grows delta while the measured kappa has margin and shrinks it when
//! the bound is threatened — "recycle as much as is provably safe".
//!
//! Enabled with `--method luar:delta=auto`.

/// Proportional controller over the recycling depth.
#[derive(Debug, Clone)]
pub struct DeltaController {
    /// Hard ceiling from Theorem 2 (1/16).
    pub kappa_bound: f64,
    /// Grow when the EMA is below this fraction of the bound.
    pub grow_margin: f64,
    pub delta: usize,
    pub min_delta: usize,
    pub max_delta: usize,
    ema: f64,
    /// EMA smoothing (per-round kappa is noisy under sampling).
    beta: f64,
    /// Rounds between adjustments (let the EMA settle).
    cooldown: usize,
    since_change: usize,
}

impl DeltaController {
    /// `num_layers` caps delta at L-1 (recycling everything would stop
    /// all learning).
    pub fn new(num_layers: usize) -> Self {
        DeltaController {
            kappa_bound: 1.0 / 16.0,
            grow_margin: 0.5,
            delta: 1,
            min_delta: 1,
            max_delta: num_layers.saturating_sub(1).max(1),
            ema: 0.0,
            beta: 0.7,
            cooldown: 3,
            since_change: 0,
        }
    }

    pub fn kappa_ema(&self) -> f64 {
        self.ema
    }

    /// Penalty per unit of mean model-version gap applied to the
    /// observed kappa under asynchrony: recycled signal that is
    /// several versions old eats more of the Theorem 2 noise budget,
    /// so the controller treats it as a proportionally larger kappa
    /// and backs the recycling depth off sooner.
    const GAP_PENALTY: f64 = 0.5;

    /// Feed the round's measured kappa; returns the delta for the next
    /// round.
    pub fn observe(&mut self, kappa: f64) -> usize {
        self.observe_stale(kappa, 0.0)
    }

    /// Staleness-aware observation for the async runtime: `mean_gap`
    /// is the aggregation's mean model-version gap. A gap of 0 reduces
    /// exactly to `observe`.
    pub fn observe_stale(&mut self, kappa: f64, mean_gap: f64) -> usize {
        let effective = kappa * (1.0 + Self::GAP_PENALTY * mean_gap);
        self.ema = self.beta * self.ema + (1.0 - self.beta) * effective.clamp(0.0, 1.0);
        self.since_change += 1;
        if self.since_change < self.cooldown {
            return self.delta;
        }
        if self.ema > self.kappa_bound && self.delta > self.min_delta {
            // bound threatened: back off immediately
            self.delta -= 1;
            self.since_change = 0;
        } else if self.ema < self.kappa_bound * self.grow_margin && self.delta < self.max_delta {
            self.delta += 1;
            self.since_change = 0;
        }
        self.delta
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grows_under_low_kappa() {
        let mut c = DeltaController::new(10);
        for _ in 0..40 {
            c.observe(0.001);
        }
        assert!(c.delta > 3, "delta stuck at {}", c.delta);
        assert!(c.delta <= 9);
    }

    #[test]
    fn shrinks_when_bound_exceeded() {
        let mut c = DeltaController::new(10);
        for _ in 0..40 {
            c.observe(0.001);
        }
        let high = c.delta;
        for _ in 0..40 {
            c.observe(0.5);
        }
        assert!(c.delta < high, "did not back off: {} -> {}", high, c.delta);
        assert_eq!(c.delta, c.min_delta);
    }

    #[test]
    fn respects_bounds() {
        let mut c = DeltaController::new(3);
        for _ in 0..100 {
            c.observe(0.0);
        }
        assert_eq!(c.delta, 2); // max = L-1
        for _ in 0..100 {
            c.observe(1.0);
        }
        assert_eq!(c.delta, 1); // min
    }

    #[test]
    fn cooldown_limits_change_rate() {
        let mut c = DeltaController::new(20);
        let d0 = c.delta;
        c.observe(0.0);
        c.observe(0.0);
        assert_eq!(c.delta, d0, "changed before cooldown elapsed");
    }

    #[test]
    fn single_layer_model_is_stable() {
        let mut c = DeltaController::new(1);
        for _ in 0..10 {
            assert_eq!(c.observe(0.0), 1);
        }
    }

    #[test]
    fn stale_observations_back_off_sooner() {
        // Identical kappa stream; the stale controller sees an
        // inflated effective kappa and settles on a smaller delta.
        let mut fresh = DeltaController::new(12);
        let mut stale = DeltaController::new(12);
        for _ in 0..60 {
            fresh.observe(0.03);
            stale.observe_stale(0.03, 4.0);
        }
        assert!(
            stale.delta < fresh.delta,
            "stale {} !< fresh {}",
            stale.delta,
            fresh.delta
        );
        assert!(stale.kappa_ema() > fresh.kappa_ema());
    }

    #[test]
    fn zero_gap_matches_observe_exactly() {
        let mut a = DeltaController::new(8);
        let mut b = DeltaController::new(8);
        for i in 0..30 {
            let k = (i as f64) * 0.002;
            let da = a.observe(k);
            let db = b.observe_stale(k, 0.0);
            assert_eq!(da, db);
        }
        assert_eq!(a.kappa_ema().to_bits(), b.kappa_ema().to_bits());
    }

    #[test]
    fn ema_tracks_kappa() {
        let mut c = DeltaController::new(5);
        for _ in 0..50 {
            c.observe(0.04);
        }
        assert!((c.kappa_ema() - 0.04).abs() < 0.005);
    }
}
