//! fedluar-lint: the repo's in-tree determinism & panic-safety linter.
//! See `docs/lints.md` for the rule catalog and suppression workflow.
//!
//! Exit codes: 0 clean, 1 findings or stale baseline, 2 usage error.

use fedluar::lint;
use fedluar::lint::rules::{ANNOTATION_RULE, CATALOG};
use std::path::PathBuf;

const HELP: &str = "\
fedluar-lint — in-tree determinism & panic-safety lint

USAGE:
    fedluar-lint [OPTIONS]

OPTIONS:
    --root <DIR>        repo root to lint (default: .)
    --baseline <FILE>   baseline file (default: <root>/lint-baseline.txt
                        when present; pass --no-baseline to skip)
    --no-baseline       ignore any baseline file
    --write-baseline    rewrite the baseline from current findings
                        (for grandfathering during large refactors)
    --list-rules        print the rule catalog and exit
    -h, --help          print this help and exit

Walks rust/src, rust/tests, rust/benches, examples/ (skipping
rust/tests/lint_fixtures/). Suppress a single finding with
`// lint:allow(RULE): reason` on or directly above the offending line;
grandfathered findings live in lint-baseline.txt (one `RULE path` per
line) and may only shrink — a stale entry fails the run.

Rules are documented in docs/lints.md; `--list-rules` summarizes them.

EXIT CODES:
    0  clean
    1  findings, malformed annotations, or stale baseline entries
    2  usage or I/O error
";

struct Opts {
    root: PathBuf,
    baseline: Option<PathBuf>,
    no_baseline: bool,
    write_baseline: bool,
    list_rules: bool,
}

fn parse_args(args: &[String]) -> Result<Opts, String> {
    let mut opts = Opts {
        root: PathBuf::from("."),
        baseline: None,
        no_baseline: false,
        write_baseline: false,
        list_rules: false,
    };
    let mut it = args.iter();
    while let Some(a) = it.next() {
        match a.as_str() {
            "--root" => match it.next() {
                Some(v) => opts.root = PathBuf::from(v),
                None => return Err("--root needs a value".to_string()),
            },
            "--baseline" => match it.next() {
                Some(v) => opts.baseline = Some(PathBuf::from(v)),
                None => return Err("--baseline needs a value".to_string()),
            },
            "--no-baseline" => opts.no_baseline = true,
            "--write-baseline" => opts.write_baseline = true,
            "--list-rules" => opts.list_rules = true,
            "-h" | "--help" => {
                print!("{HELP}");
                std::process::exit(0);
            }
            other => return Err(format!("unknown option `{other}` (see --help)")),
        }
    }
    Ok(opts)
}

fn list_rules() {
    println!("fedluar-lint rule catalog (full docs: docs/lints.md)\n");
    for r in CATALOG {
        println!("{}  {}", r.id, r.title);
        println!("    why:  {}", r.rationale);
        println!("    fix:  {}", r.advice);
        let test_note = if r.skip_test_code { "skips #[cfg(test)] code" } else { "applies in tests too" };
        println!("    scope: {:?} minus {:?} ({test_note})\n", r.include, r.exclude);
    }
    println!("{ANNOTATION_RULE}  malformed lint:allow annotation (always on, not suppressible)");
}

fn run(opts: &Opts) -> Result<i32, String> {
    let mut report =
        lint::lint_tree(&opts.root).map_err(|e| format!("{e:#}"))?;

    let baseline_path = match (&opts.baseline, opts.no_baseline) {
        (_, true) => None,
        (Some(p), _) => {
            if !p.is_file() {
                return Err(format!("baseline {} not found", p.display()));
            }
            Some(p.clone())
        }
        (None, _) => {
            let p = opts.root.join("lint-baseline.txt");
            p.is_file().then_some(p)
        }
    };

    if opts.write_baseline {
        let path = opts.root.join("lint-baseline.txt");
        let text = lint::baseline::render(&report.findings);
        std::fs::write(&path, text)
            .map_err(|e| format!("writing {}: {e}", path.display()))?;
        println!(
            "fedluar-lint: wrote {} ({} entries grandfathered)",
            path.display(),
            report.findings.len()
        );
        return Ok(0);
    }

    if let Some(p) = baseline_path {
        let text = std::fs::read_to_string(&p)
            .map_err(|e| format!("reading {}: {e}", p.display()))?;
        lint::apply_baseline(&mut report, &text).map_err(|e| format!("{e:#}"))?;
    }

    for f in &report.findings {
        println!("{}:{}: [{}] {}", f.path, f.line, f.rule, f.msg);
    }
    for s in &report.stale {
        println!("stale baseline entry (site fixed — delete the line): {s}");
    }
    println!(
        "fedluar-lint: {} files, {} findings, {} baselined, {} annotation-suppressed{}",
        report.files,
        report.findings.len(),
        report.baselined,
        report.suppressed,
        if report.stale.is_empty() {
            String::new()
        } else {
            format!(", {} STALE baseline entries", report.stale.len())
        }
    );
    if report.findings.is_empty() && report.stale.is_empty() {
        Ok(0)
    } else {
        Ok(1)
    }
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match parse_args(&args) {
        Err(e) => {
            eprintln!("fedluar-lint: {e}");
            2
        }
        Ok(opts) => {
            if opts.list_rules {
                list_rules();
                0
            } else {
                match run(&opts) {
                    Ok(c) => c,
                    Err(e) => {
                        eprintln!("fedluar-lint: {e}");
                        2
                    }
                }
            }
        }
    };
    std::process::exit(code);
}
