//! Experiment configuration: CLI/file-loadable description of one FL
//! run — benchmark, federation topology, method, and optimizer. The
//! four built-in benchmarks mirror the paper's Table 6 hyper-parameter
//! block (CPU-scaled; DESIGN.md §Substitutions).
//!
//! Offline build: no serde/toml, so config files use a plain
//! `key = value` format parsed in-tree (`RunConfig::load`/`save`);
//! method/optimizer specs use compact strings like `luar:delta=2`.

use crate::data::{SynthKind, SynthSpec};
use crate::net::{FaultsCfg, LinkDist, NetCfg, RoundMode, SamplerCfg};
use crate::obs::{ObsCfg, ObsLevel};
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;

/// Which layer-selection scheme picks the recycling set (Table 4).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SelectionScheme {
    /// Weighted random sampling by 1/s_{t,l} (the paper's LUAR).
    Luar,
    /// Uniform random delta layers.
    Random,
    /// First delta layers (input side).
    Top,
    /// Last delta layers (output side).
    Bottom,
    /// Smallest gradient norm (the baseline the paper argues against).
    GradNorm,
    /// Deterministically the delta smallest s_{t,l} (no resampling).
    Deterministic,
}

impl SelectionScheme {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "luar" => Self::Luar,
            "random" => Self::Random,
            "top" => Self::Top,
            "bottom" => Self::Bottom,
            "grad_norm" | "gradnorm" => Self::GradNorm,
            "deterministic" => Self::Deterministic,
            other => bail!("unknown selection scheme {other}"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::Luar => "luar",
            Self::Random => "random",
            Self::Top => "top",
            Self::Bottom => "bottom",
            Self::GradNorm => "grad_norm",
            Self::Deterministic => "deterministic",
        }
    }
}

/// What to do with the selected layers' updates (Table 5).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RecycleMode {
    /// Re-apply the previous global update for the layer (FedLUAR).
    Recycle,
    /// Apply nothing for the layer (the "Dropping" ablation).
    Drop,
}

/// Communication-efficiency method under test (Table 2 rows).
#[derive(Debug, Clone, PartialEq)]
pub enum Method {
    /// Full model aggregation every round.
    FedAvg,
    /// The paper's contribution (Alg. 1 + 2). `delta = 0` with
    /// `adaptive = true` means the kappa-adaptive controller picks the
    /// recycling depth each round (Theorem 2 bound as policy).
    Luar { delta: usize, scheme: SelectionScheme, mode: RecycleMode, adaptive: bool },
    /// FedPAQ: stochastic uniform quantization to `levels` levels.
    Quantize { levels: u32 },
    /// FedBAT-style sign binarization with per-layer scale + error feedback.
    Binarize,
    /// PruneFL-style magnitude pruning of updates, mask refreshed every
    /// `reconfig_every` rounds.
    Prune { keep_ratio: f32, reconfig_every: usize },
    /// FedDropoutAvg: random parameter dropout at rate `rate`.
    DropoutAvg { rate: f32 },
    /// LBGM: look-back gradient multiplier (send a scalar when the
    /// update stays within `threshold` cosine of the anchor direction).
    Lbgm { threshold: f32 },
    /// Top-k sparsification (classic sketching baseline).
    TopK { keep_ratio: f32 },
    /// FedPara substitute: rank-limited layer updates (DESIGN.md).
    LowRank { rank_ratio: f32 },
}

impl Method {
    pub fn label(&self) -> String {
        match self {
            Method::FedAvg => "FedAvg".into(),
            Method::Luar {
                mode: RecycleMode::Recycle,
                scheme: SelectionScheme::Luar,
                adaptive,
                ..
            } => {
                if *adaptive { "FedLUAR-auto".into() } else { "FedLUAR".into() }
            }
            Method::Luar { mode: RecycleMode::Drop, .. } => "LUAR-Drop".into(),
            Method::Luar { scheme, .. } => format!("LUAR[{}]", scheme.name()),
            Method::Quantize { .. } => "FedPAQ".into(),
            Method::Binarize => "FedBAT".into(),
            Method::Prune { .. } => "PruneFL".into(),
            Method::DropoutAvg { .. } => "FDA".into(),
            Method::Lbgm { .. } => "LBGM".into(),
            Method::TopK { .. } => "TopK".into(),
            Method::LowRank { .. } => "FedPara".into(),
        }
    }

    pub fn luar(delta: usize) -> Self {
        Method::Luar {
            delta,
            scheme: SelectionScheme::Luar,
            mode: RecycleMode::Recycle,
            adaptive: false,
        }
    }

    /// Kappa-adaptive FedLUAR (`luar:delta=auto`).
    pub fn luar_auto() -> Self {
        Method::Luar {
            delta: 1,
            scheme: SelectionScheme::Luar,
            mode: RecycleMode::Recycle,
            adaptive: true,
        }
    }

    /// Parse a compact method spec: `fedavg`, `luar:delta=2`,
    /// `luar:delta=2,scheme=random,mode=drop`, `quantize:levels=16`,
    /// `prune:keep=0.5,every=50`, `dropout:rate=0.5`, `lbgm:thresh=0.95`,
    /// `topk:keep=0.1`, `lowrank:ratio=0.25`, `binarize`.
    pub fn parse(spec: &str) -> Result<Self> {
        let (name, args) = match spec.split_once(':') {
            Some((n, a)) => (n, parse_kv(a)?),
            None => (spec, BTreeMap::new()),
        };
        let getf = |k: &str, d: f32| -> Result<f32> {
            args.get(k).map(|v| v.parse::<f32>().context(k.to_string())).unwrap_or(Ok(d))
        };
        let getu = |k: &str, d: usize| -> Result<usize> {
            args.get(k).map(|v| v.parse::<usize>().context(k.to_string())).unwrap_or(Ok(d))
        };
        Ok(match name {
            "fedavg" => Method::FedAvg,
            "luar" => {
                let scheme = match args.get("scheme") {
                    Some(s) => SelectionScheme::parse(s)?,
                    None => SelectionScheme::Luar,
                };
                let mode = match args.get("mode").map(String::as_str) {
                    Some("drop") => RecycleMode::Drop,
                    Some("recycle") | None => RecycleMode::Recycle,
                    Some(other) => bail!("unknown mode {other}"),
                };
                if args.get("delta").map(String::as_str) == Some("auto") {
                    Method::Luar { delta: 1, scheme, mode, adaptive: true }
                } else {
                    Method::Luar { delta: getu("delta", 2)?, scheme, mode, adaptive: false }
                }
            }
            "quantize" | "fedpaq" => Method::Quantize { levels: getu("levels", 16)? as u32 },
            "binarize" | "fedbat" => Method::Binarize,
            "prune" | "prunefl" => Method::Prune {
                keep_ratio: getf("keep", 0.5)?,
                reconfig_every: getu("every", 50)?,
            },
            "dropout" | "fda" => Method::DropoutAvg { rate: getf("rate", 0.5)? },
            "lbgm" => Method::Lbgm { threshold: getf("thresh", 0.95)? },
            "topk" => Method::TopK { keep_ratio: getf("keep", 0.1)? },
            "lowrank" | "fedpara" => Method::LowRank { rank_ratio: getf("ratio", 0.25)? },
            other => bail!("unknown method {other}"),
        })
    }

    pub fn spec_string(&self) -> String {
        match self {
            Method::FedAvg => "fedavg".into(),
            Method::Luar { delta, scheme, mode, adaptive } => format!(
                "luar:delta={},scheme={},mode={}",
                if *adaptive { "auto".to_string() } else { delta.to_string() },
                scheme.name(),
                if *mode == RecycleMode::Drop { "drop" } else { "recycle" }
            ),
            Method::Quantize { levels } => format!("quantize:levels={levels}"),
            Method::Binarize => "binarize".into(),
            Method::Prune { keep_ratio, reconfig_every } => {
                format!("prune:keep={keep_ratio},every={reconfig_every}")
            }
            Method::DropoutAvg { rate } => format!("dropout:rate={rate}"),
            Method::Lbgm { threshold } => format!("lbgm:thresh={threshold}"),
            Method::TopK { keep_ratio } => format!("topk:keep={keep_ratio}"),
            Method::LowRank { rank_ratio } => format!("lowrank:ratio={rank_ratio}"),
        }
    }
}

fn parse_kv(s: &str) -> Result<BTreeMap<String, String>> {
    let mut m = BTreeMap::new();
    for part in s.split(',') {
        if part.is_empty() {
            continue;
        }
        let (k, v) = part.split_once('=').with_context(|| format!("bad arg {part:?}"))?;
        m.insert(k.trim().to_string(), v.trim().to_string());
    }
    Ok(m)
}

/// Server-side optimizer applied to the aggregated update (Table 3).
#[derive(Debug, Clone, PartialEq)]
pub enum ServerOptCfg {
    /// x += delta (vanilla FedAvg server).
    Sgd,
    /// FedOpt / FedAdam with server learning rate.
    Adam { lr: f32 },
    /// FedACG: lookahead momentum broadcast + momentum accumulation.
    Acg { lambda: f32 },
    /// FedMut: mutate the broadcast model per client by +/- the last
    /// global update scaled by `alpha`.
    Mut { alpha: f32 },
}

impl ServerOptCfg {
    pub fn parse(spec: &str) -> Result<Self> {
        let (name, args) = match spec.split_once(':') {
            Some((n, a)) => (n, parse_kv(a)?),
            None => (spec, BTreeMap::new()),
        };
        let getf = |k: &str, d: f32| -> f32 {
            args.get(k).and_then(|v| v.parse().ok()).unwrap_or(d)
        };
        Ok(match name {
            "sgd" => Self::Sgd,
            "adam" | "fedopt" => Self::Adam { lr: getf("lr", 0.9) },
            "acg" | "fedacg" => Self::Acg { lambda: getf("lambda", 0.7) },
            "mut" | "fedmut" => Self::Mut { alpha: getf("alpha", 0.5) },
            other => bail!("unknown server optimizer {other}"),
        })
    }

    pub fn label(&self) -> &'static str {
        match self {
            Self::Sgd => "SGD",
            Self::Adam { .. } => "FedOpt",
            Self::Acg { .. } => "FedACG",
            Self::Mut { .. } => "FedMut",
        }
    }
}

/// Client-side local objective shaping (FedProx / MOON-lite).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct ClientOptCfg {
    /// Proximal pull toward the broadcast model (FedProx mu; also the
    /// FedACG penalty beta).
    pub mu_global: f32,
    /// MOON-lite repulsion from the client's previous local model.
    pub mu_prev: f32,
}

/// Full description of one FL run.
#[derive(Debug, Clone)]
pub struct RunConfig {
    pub model: String,
    pub rounds: usize,
    pub num_clients: usize,
    pub active_clients: usize,
    /// Dirichlet concentration (paper: 0.1 vision, 0.5 text).
    pub alpha: f64,
    pub per_client: usize,
    pub test_size: usize,
    pub lr: f32,
    pub weight_decay: f32,
    /// Rounds at which lr is multiplied by 0.1 (paper's decay epochs).
    pub lr_decay_rounds: Vec<usize>,
    pub seed: u64,
    pub method: Method,
    /// When `method` is LUAR, optionally apply this baseline's lossy
    /// compression to the *uploaded* layers too (Table 3's
    /// "FedPAQ + LUAR" style composition).
    pub luar_compress: Option<Method>,
    pub server_opt: ServerOptCfg,
    pub client_opt: ClientOptCfg,
    pub eval_every: usize,
    /// Synthetic data difficulty (noise scale).
    pub difficulty: f32,
    /// Per-round probability that an active client fails before
    /// uploading (straggler/failure injection; server aggregates over
    /// survivors).
    pub client_failure_rate: f64,
    /// Network simulation block: link fleet distribution, round-closing
    /// policy, local-compute time, and the cohort-draw policy
    /// (`link_dist`, `round_mode`, `deadline_s`, `buffer_k`,
    /// `compute_s`, `sampler` config keys). Round modes: `sync`,
    /// `deadline:s=F`, `buffered:k=N`, and the barrier-free
    /// `async:c=N,s=const|poly[,a=F]` (`c=all` pins concurrency to
    /// `active_clients`). Samplers: `uniform`, `speed:pow=F`,
    /// `staleness:cap=N`.
    pub net: NetCfg,
    /// Observability block: telemetry level and artifact paths (flat
    /// config keys `obs_level`, `obs_trace`, `obs_metrics`,
    /// `obs_layer_csv`, `obs_clients_csv`; `none` clears a path).
    /// Telemetry never perturbs the simulation — `off` and `full` runs
    /// are bit-identical (`tests/integration_obs.rs`).
    pub obs: ObsCfg,
}

impl RunConfig {
    /// Paper-aligned defaults for each built-in benchmark.
    pub fn benchmark(model: &str) -> Result<Self> {
        let (lr, alpha, per_client, difficulty) = match model {
            "mlp" => (0.05, 0.5, 128, 2.5),
            "cnn" => (0.02, 0.1, 120, 1.5),
            "resnet8" => (0.02, 0.1, 128, 2.0),
            "transformer" => (0.02, 0.5, 128, 5.0),
            other => bail!("unknown benchmark {other}"),
        };
        Ok(RunConfig {
            model: model.to_string(),
            rounds: 60,
            num_clients: 128,
            active_clients: 32,
            alpha,
            per_client,
            test_size: 1024,
            lr,
            weight_decay: 1e-4,
            lr_decay_rounds: vec![],
            seed: 42,
            method: Method::FedAvg,
            luar_compress: None,
            server_opt: ServerOptCfg::Sgd,
            client_opt: ClientOptCfg::default(),
            eval_every: 5,
            difficulty,
            client_failure_rate: 0.0,
            net: NetCfg::default(),
            obs: ObsCfg::default(),
        })
    }

    pub fn with_method(mut self, m: Method) -> Self {
        self.method = m;
        self
    }

    pub fn with_rounds(mut self, r: usize) -> Self {
        self.rounds = r;
        self
    }

    pub fn with_seed(mut self, s: u64) -> Self {
        self.seed = s;
        self
    }

    /// Synthetic data spec matching the model's input signature.
    pub fn synth_spec(
        &self,
        input_shape: &[usize],
        num_classes: usize,
        is_text: bool,
    ) -> SynthSpec {
        if is_text {
            SynthSpec {
                kind: SynthKind::Text { seq: input_shape[0], vocab: 256 },
                num_classes,
                difficulty: self.difficulty,
            }
        } else {
            let (h, w, c) = match input_shape.len() {
                1 => (input_shape[0], 1, 1),
                3 => (input_shape[0], input_shape[1], input_shape[2]),
                _ => panic!("unsupported input rank {}", input_shape.len()),
            };
            SynthSpec {
                kind: SynthKind::Vision { h, w, c },
                num_classes,
                difficulty: self.difficulty,
            }
        }
    }

    /// Learning rate at a given round after staged decay.
    pub fn lr_at(&self, round: usize) -> f32 {
        let mut lr = self.lr;
        for &r in &self.lr_decay_rounds {
            if round >= r {
                lr *= 0.1;
            }
        }
        lr
    }

    /// Serialize to the in-tree `key = value` config format.
    pub fn save_kv(&self) -> String {
        let decay =
            self.lr_decay_rounds.iter().map(|r| r.to_string()).collect::<Vec<_>>().join(" ");
        format!(
            "model = {}\nrounds = {}\nnum_clients = {}\nactive_clients = {}\n\
             alpha = {}\nper_client = {}\ntest_size = {}\nlr = {}\nweight_decay = {}\n\
             lr_decay_rounds = {}\nseed = {}\nmethod = {}\nluar_compress = {}\nserver_opt = {}\n\
             mu_global = {}\nmu_prev = {}\neval_every = {}\ndifficulty = {}\n\
             client_failure_rate = {}\nlink_dist = {}\nround_mode = {}\ncompute_s = {}\n\
             delta_frames = {}\nsampler = {}\nfaults = {}\n\
             obs_level = {}\nobs_trace = {}\nobs_metrics = {}\nobs_layer_csv = {}\n\
             obs_clients_csv = {}\n",
            self.model,
            self.rounds,
            self.num_clients,
            self.active_clients,
            self.alpha,
            self.per_client,
            self.test_size,
            self.lr,
            self.weight_decay,
            decay,
            self.seed,
            self.method.spec_string(),
            self.luar_compress.as_ref().map(|m| m.spec_string()).unwrap_or_else(|| "none".into()),
            match &self.server_opt {
                ServerOptCfg::Sgd => "sgd".to_string(),
                ServerOptCfg::Adam { lr } => format!("adam:lr={lr}"),
                ServerOptCfg::Acg { lambda } => format!("acg:lambda={lambda}"),
                ServerOptCfg::Mut { alpha } => format!("mut:alpha={alpha}"),
            },
            self.client_opt.mu_global,
            self.client_opt.mu_prev,
            self.eval_every,
            self.difficulty,
            self.client_failure_rate,
            self.net.link_dist.spec_string(),
            self.net.round_mode.spec_string(),
            self.net.compute_s,
            self.net.delta_frames,
            self.net.sampler.spec_string(),
            self.net.faults.spec_string(),
            self.obs.level.name(),
            self.obs.trace_path.as_deref().unwrap_or("none"),
            self.obs.metrics_path.as_deref().unwrap_or("none"),
            self.obs.layer_csv.as_deref().unwrap_or("none"),
            self.obs.clients_csv.as_deref().unwrap_or("none"),
        )
    }

    /// Parse the `key = value` format (comments with '#', blank lines ok).
    pub fn load_kv(text: &str) -> Result<Self> {
        let mut kv = BTreeMap::new();
        for (i, line) in text.lines().enumerate() {
            let line = line.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let (k, v) =
                line.split_once('=').with_context(|| format!("line {}: missing '='", i + 1))?;
            kv.insert(k.trim().to_string(), v.trim().to_string());
        }
        let get = |k: &str| -> Result<&String> {
            kv.get(k).with_context(|| format!("missing config key {k}"))
        };
        let mut cfg = RunConfig::benchmark(get("model")?)?;
        macro_rules! set {
            ($field:ident, $key:literal) => {
                if let Some(v) = kv.get($key) {
                    cfg.$field = v.parse().with_context(|| format!("bad {}", $key))?;
                }
            };
        }
        set!(rounds, "rounds");
        set!(num_clients, "num_clients");
        set!(active_clients, "active_clients");
        set!(alpha, "alpha");
        set!(per_client, "per_client");
        set!(test_size, "test_size");
        set!(lr, "lr");
        set!(weight_decay, "weight_decay");
        set!(seed, "seed");
        set!(eval_every, "eval_every");
        set!(difficulty, "difficulty");
        if let Some(v) = kv.get("lr_decay_rounds") {
            cfg.lr_decay_rounds = v
                .split_whitespace()
                .map(|t| t.parse::<usize>().context("bad lr_decay_rounds"))
                .collect::<Result<_>>()?;
        }
        if let Some(v) = kv.get("method") {
            cfg.method = Method::parse(v)?;
        }
        if let Some(v) = kv.get("luar_compress") {
            if v != "none" {
                cfg.luar_compress = Some(Method::parse(v)?);
            }
        }
        if let Some(v) = kv.get("server_opt") {
            cfg.server_opt = ServerOptCfg::parse(v)?;
        }
        if let Some(v) = kv.get("mu_global") {
            cfg.client_opt.mu_global = v.parse().context("bad mu_global")?;
        }
        if let Some(v) = kv.get("mu_prev") {
            cfg.client_opt.mu_prev = v.parse().context("bad mu_prev")?;
        }
        if let Some(v) = kv.get("client_failure_rate") {
            cfg.client_failure_rate = v.parse().context("bad client_failure_rate")?;
        }
        // net: block (flat keys). `deadline_s` / `buffer_k` are
        // alternative spellings that override the round_mode params.
        if let Some(v) = kv.get("link_dist") {
            cfg.net.link_dist = LinkDist::parse(v)?;
        }
        if let Some(v) = kv.get("round_mode") {
            cfg.net.round_mode = RoundMode::parse(v)?;
        }
        if let Some(v) = kv.get("deadline_s") {
            let s: f64 = v.parse().context("bad deadline_s")?;
            cfg.net.round_mode = RoundMode::Deadline { deadline_s: s };
        }
        if let Some(v) = kv.get("buffer_k") {
            let k: usize = v.parse().context("bad buffer_k")?;
            cfg.net.round_mode = RoundMode::Buffered { k };
        }
        if let Some(v) = kv.get("compute_s") {
            cfg.net.compute_s = v.parse().context("bad compute_s")?;
        }
        // Residual framing is opt-in; configs written before the key
        // existed parse as `false`.
        if let Some(v) = kv.get("delta_frames") {
            cfg.net.delta_frames = v.parse().context("bad delta_frames")?;
        }
        // Biased sampling is opt-in; configs written before the key
        // existed parse as `uniform` (the legacy cohort stream).
        if let Some(v) = kv.get("sampler") {
            cfg.net.sampler = SamplerCfg::parse(v)?;
        }
        // Fault injection is opt-in; configs written before the key
        // existed parse as `off` (no faults, bit-identical behavior).
        if let Some(v) = kv.get("faults") {
            cfg.net.faults = FaultsCfg::parse(v)?;
        }
        // obs: block (flat keys); `none` leaves a path unset.
        if let Some(v) = kv.get("obs_level") {
            cfg.obs.level = ObsLevel::parse(v)?;
        }
        let path = |v: &String| if v == "none" { None } else { Some(v.clone()) };
        if let Some(v) = kv.get("obs_trace") {
            cfg.obs.trace_path = path(v);
        }
        if let Some(v) = kv.get("obs_metrics") {
            cfg.obs.metrics_path = path(v);
        }
        if let Some(v) = kv.get("obs_layer_csv") {
            cfg.obs.layer_csv = path(v);
        }
        if let Some(v) = kv.get("obs_clients_csv") {
            cfg.obs.clients_csv = path(v);
        }
        Ok(cfg)
    }

    pub fn load_file(path: &str) -> Result<Self> {
        let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
        Self::load_kv(&text)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn benchmarks_exist() {
        for m in ["mlp", "cnn", "resnet8", "transformer"] {
            RunConfig::benchmark(m).unwrap();
        }
        assert!(RunConfig::benchmark("nope").is_err());
    }

    #[test]
    fn kv_roundtrip() {
        let mut cfg = RunConfig::benchmark("cnn").unwrap().with_method(Method::luar(2));
        cfg.lr_decay_rounds = vec![30, 45];
        cfg.server_opt = ServerOptCfg::Adam { lr: 0.9 };
        cfg.client_opt.mu_global = 0.001;
        cfg.net.link_dist = LinkDist::LogNormal {
            up_mbps: 10.0,
            down_mbps: 50.0,
            sigma: 0.75,
            rtt_s: 0.05,
        };
        cfg.net.round_mode = RoundMode::Deadline { deadline_s: 2.5 };
        cfg.net.compute_s = 0.5;
        cfg.net.delta_frames = true;
        cfg.net.sampler = SamplerCfg::Speed { pow: 1.5 };
        cfg.net.faults =
            FaultsCfg::parse("mixed:drop=0.1,outage=0.05,len=20,corrupt=0.02,quorum=3").unwrap();
        let text = cfg.save_kv();
        let back = RunConfig::load_kv(&text).unwrap();
        assert_eq!(back.method, cfg.method);
        assert_eq!(back.server_opt, cfg.server_opt);
        assert_eq!(back.lr_decay_rounds, cfg.lr_decay_rounds);
        assert_eq!(back.client_opt.mu_global, 0.001);
        assert_eq!(back.net, cfg.net);
    }

    #[test]
    fn obs_block_roundtrip() {
        let mut cfg = RunConfig::benchmark("mlp").unwrap();
        cfg.obs.level = ObsLevel::Full;
        cfg.obs.trace_path = Some("results/t.jsonl".into());
        cfg.obs.layer_csv = Some("results/l.csv".into());
        let back = RunConfig::load_kv(&cfg.save_kv()).unwrap();
        assert_eq!(back.obs, cfg.obs);
        // defaults: off, no paths; `none` stays None through the trip
        let base = RunConfig::benchmark("mlp").unwrap();
        assert_eq!(base.obs.level, ObsLevel::Off);
        let back = RunConfig::load_kv(&base.save_kv()).unwrap();
        assert_eq!(back.obs, base.obs);
        assert!(back.obs.metrics_path.is_none());
        // legacy configs without the obs keys parse fine
        let legacy = "model = mlp\nrounds = 3\n";
        assert_eq!(RunConfig::load_kv(legacy).unwrap().obs.level, ObsLevel::Off);
        assert!(RunConfig::load_kv("model = mlp\nobs_level = loud\n").is_err());
    }

    #[test]
    fn delta_frames_key_parses_and_defaults_off() {
        // legacy configs written before the key existed parse as off
        let legacy = "model = mlp\nrounds = 3\n";
        assert!(!RunConfig::load_kv(legacy).unwrap().net.delta_frames);
        let base = RunConfig::benchmark("mlp").unwrap().save_kv();
        let cfg = RunConfig::load_kv(&format!("{base}delta_frames = true\n")).unwrap();
        assert!(cfg.net.delta_frames);
        assert!(RunConfig::load_kv(&format!("{base}delta_frames = sideways\n")).is_err());
    }

    #[test]
    fn sampler_key_parses_and_defaults_uniform() {
        // legacy configs written before the key existed parse as uniform
        let legacy = "model = mlp\nrounds = 3\n";
        assert_eq!(RunConfig::load_kv(legacy).unwrap().net.sampler, SamplerCfg::Uniform);
        let base = RunConfig::benchmark("mlp").unwrap().save_kv();
        let cfg = RunConfig::load_kv(&format!("{base}sampler = speed:pow=2\n")).unwrap();
        assert_eq!(cfg.net.sampler, SamplerCfg::Speed { pow: 2.0 });
        let cfg = RunConfig::load_kv(&format!("{base}sampler = staleness:cap=3\n")).unwrap();
        assert_eq!(cfg.net.sampler, SamplerCfg::Staleness { cap: 3 });
        assert!(RunConfig::load_kv(&format!("{base}sampler = psychic\n")).is_err());
        // staleness requires its cap; speed rejects nonpositive bias
        assert!(RunConfig::load_kv(&format!("{base}sampler = staleness\n")).is_err());
        assert!(RunConfig::load_kv(&format!("{base}sampler = speed:pow=0\n")).is_err());
    }

    #[test]
    fn faults_key_parses_and_defaults_off() {
        use crate::net::FaultKind;
        // legacy configs written before the key existed parse as off
        let legacy = "model = mlp\nrounds = 3\n";
        assert!(RunConfig::load_kv(legacy).unwrap().net.faults.is_off());
        let base = RunConfig::benchmark("mlp").unwrap().save_kv();
        assert!(base.contains("faults = off\n"), "save_kv must emit the faults key");
        let cfg = RunConfig::load_kv(&format!("{base}faults = drop:p=0.25\n")).unwrap();
        assert_eq!(cfg.net.faults.kind, FaultKind::Drop { p: 0.25 });
        let cfg = RunConfig::load_kv(&format!(
            "{base}faults = outage:p=0.1,len=15,retries=4,quorum=2\n"
        ))
        .unwrap();
        assert_eq!(cfg.net.faults.kind, FaultKind::Outage { p: 0.1, len_s: 15.0 });
        assert_eq!(cfg.net.faults.policy.max_retries, 4);
        assert_eq!(cfg.net.faults.policy.quorum, 2);
        assert!(RunConfig::load_kv(&format!("{base}faults = gremlins\n")).is_err());
        assert!(RunConfig::load_kv(&format!("{base}faults = drop:p=1.5\n")).is_err());
    }

    #[test]
    fn net_block_alternative_keys() {
        let base = RunConfig::benchmark("mlp").unwrap().save_kv();
        let cfg = RunConfig::load_kv(&format!("{base}deadline_s = 3.5\n")).unwrap();
        assert_eq!(cfg.net.round_mode, RoundMode::Deadline { deadline_s: 3.5 });
        let cfg = RunConfig::load_kv(&format!("{base}buffer_k = 4\n")).unwrap();
        assert_eq!(cfg.net.round_mode, RoundMode::Buffered { k: 4 });
        let cfg = RunConfig::load_kv(&format!(
            "{base}link_dist = bimodal:fast_frac=0.9,fast_up=80,slow_up=1,down=100,rtt=0.02\n"
        ))
        .unwrap();
        assert!(matches!(cfg.net.link_dist, LinkDist::Bimodal { .. }));
        assert!(RunConfig::load_kv(&format!("{base}round_mode = warp\n")).is_err());
    }

    #[test]
    fn async_round_mode_in_config() {
        use crate::net::Staleness;
        let base = RunConfig::benchmark("mlp").unwrap().save_kv();
        let cfg =
            RunConfig::load_kv(&format!("{base}round_mode = async:c=4,s=poly,a=0.5\n")).unwrap();
        assert_eq!(
            cfg.net.round_mode,
            RoundMode::Async { concurrency: 4, staleness: Staleness::Poly { a: 0.5 } }
        );
        // full kv round-trip carries the async spec (value holds '='
        // and ',' — only the first '=' splits the key)
        let mut cfg = RunConfig::benchmark("cnn").unwrap();
        cfg.net.round_mode =
            RoundMode::Async { concurrency: 0, staleness: Staleness::Const };
        let back = RunConfig::load_kv(&cfg.save_kv()).unwrap();
        assert_eq!(back.net.round_mode, cfg.net.round_mode);
        assert!(RunConfig::load_kv(&format!("{base}round_mode = async:c=0\n")).is_err());
    }

    #[test]
    fn method_parse_variants() {
        assert_eq!(Method::parse("fedavg").unwrap(), Method::FedAvg);
        assert_eq!(
            Method::parse("luar:delta=5").unwrap(),
            Method::luar(5)
        );
        assert_eq!(
            Method::parse("luar:delta=3,scheme=random,mode=drop").unwrap(),
            Method::Luar {
                delta: 3,
                scheme: SelectionScheme::Random,
                mode: RecycleMode::Drop,
                adaptive: false
            }
        );
        assert_eq!(Method::parse("quantize:levels=8").unwrap(), Method::Quantize { levels: 8 });
        assert!(Method::parse("bogus").is_err());
        assert!(Method::parse("luar:delta=x").is_err());
    }

    #[test]
    fn method_spec_roundtrip() {
        for spec in [
            "fedavg",
            "luar:delta=4,scheme=grad_norm,mode=drop",
            "quantize:levels=16",
            "binarize",
            "prune:keep=0.5,every=50",
            "dropout:rate=0.75",
            "lbgm:thresh=0.95",
            "topk:keep=0.1",
            "lowrank:ratio=0.25",
        ] {
            let m = Method::parse(spec).unwrap();
            let again = Method::parse(&m.spec_string()).unwrap();
            assert_eq!(m, again, "{spec}");
        }
    }

    #[test]
    fn lr_decay_schedule() {
        let mut cfg = RunConfig::benchmark("mlp").unwrap();
        cfg.lr = 1.0;
        cfg.lr_decay_rounds = vec![10, 20];
        assert_eq!(cfg.lr_at(0), 1.0);
        assert!((cfg.lr_at(10) - 0.1).abs() < 1e-6);
        assert!((cfg.lr_at(25) - 0.01).abs() < 1e-7);
    }

    #[test]
    fn method_labels() {
        assert_eq!(Method::FedAvg.label(), "FedAvg");
        assert_eq!(Method::luar(3).label(), "FedLUAR");
        assert_eq!(
            Method::Luar {
                delta: 3,
                scheme: SelectionScheme::Luar,
                mode: RecycleMode::Drop,
                adaptive: false
            }
            .label(),
            "LUAR-Drop"
        );
        assert_eq!(Method::parse("luar:delta=auto").unwrap(), Method::luar_auto());
        assert_eq!(Method::luar_auto().label(), "FedLUAR-auto");
        assert_eq!(Method::parse("luar:scheme=top").unwrap().label(), "LUAR[top]");
    }

    #[test]
    fn synth_spec_from_shapes() {
        let cfg = RunConfig::benchmark("cnn").unwrap();
        let s = cfg.synth_spec(&[28, 28, 1], 10, false);
        assert_eq!(s.feature_elems(), 784);
        let t = cfg.synth_spec(&[32], 4, true);
        assert_eq!(t.feature_elems(), 32);
    }

    #[test]
    fn server_opt_parse() {
        assert_eq!(ServerOptCfg::parse("sgd").unwrap(), ServerOptCfg::Sgd);
        assert_eq!(ServerOptCfg::parse("adam:lr=1.2").unwrap(), ServerOptCfg::Adam { lr: 1.2 });
        assert_eq!(ServerOptCfg::parse("fedmut").unwrap(), ServerOptCfg::Mut { alpha: 0.5 });
        assert!(ServerOptCfg::parse("zzz").is_err());
    }
}
