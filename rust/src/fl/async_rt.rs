//! Barrier-free FL runtime: the dispatch/absorb state machine behind
//! `round_mode = async:c=N,s=...`.
//!
//! The round-based scheduler (`net::sched::simulate_round`) fills and
//! drains a fresh event heap every round, so the server implicitly
//! barriers on each cohort. This runtime removes the barrier:
//!
//! * the completion-event queue (`net::AsyncQueue`) **persists across
//!   dispatches** — uploads from different generations coexist in it;
//! * every dispatch records the server **model version** the client
//!   trained against (`client_version` tracks the last version each
//!   client received), so every absorbed upload carries a measured
//!   `version_gap = server version now − version at dispatch`;
//! * a `Staleness` discount maps that gap to the upload's aggregation
//!   weight;
//! * a **concurrency cap**: the server keeps exactly `concurrency`
//!   uploads in flight, dispatching the next sampled client the moment
//!   a slot frees (over that client's own link — the caller computes
//!   the link time and hands it to `dispatch`);
//! * the server **absorbs one completion instant atomically**: all
//!   arrivals sharing the earliest clock value enter the aggregation
//!   buffer, a version closes if the buffer reached `agg_goal`, and
//!   only then are the freed slots refilled. This ordering is what
//!   makes `async:c=all,s=const` over a homogeneous fleet reproduce
//!   synchronous FedAvg exactly (pinned in
//!   `tests/integration_async.rs`).
//!
//! The runtime is deliberately engine-free: it owns versions, clocks,
//! weights, and byte accounting, while the `Server` supplies trained
//! deltas and link times. That split is what lets the equivalence and
//! determinism suites drive the *production* state machine without the
//! PJRT artifacts.
//!
//! `AsyncState` is the checkpoint view: every field needed to rebuild
//! the runtime exactly — including in-flight payloads and the event
//! queue — so a resumed run replays the remaining schedule bit-for-bit
//! (`fl/checkpoint.rs` format v2).

use crate::net::{AsyncQueue, Staleness};
use crate::obs;
use anyhow::{Context, Result};
use std::collections::BTreeMap;

/// One dispatched upload: everything the server needs when the upload
/// is eventually absorbed.
#[derive(Debug, Clone)]
pub struct UploadPayload {
    pub client: usize,
    /// Server model version the client trained against.
    pub version: u64,
    /// Sample-stream generation (the data-round index used for local
    /// batches and the lr schedule).
    pub gen: u64,
    /// Server-side decoded update (full-dim; zeros in recycled layers).
    pub delta: Vec<f32>,
    pub loss: f32,
    /// Measured uplink wire bytes (`frame.len()`).
    pub frame_len: u64,
    /// Measured downlink broadcast bytes paid at dispatch.
    pub bcast_len: u64,
}

/// An upload after it landed on the server.
#[derive(Debug, Clone)]
pub struct AbsorbedUpload {
    pub payload: UploadPayload,
    /// Absolute simulated arrival time.
    pub t: f64,
    /// Server versions that elapsed while the upload was in flight.
    pub version_gap: u64,
    /// Staleness-discounted aggregation weight.
    pub weight: f32,
}

/// Everything one closed version hands to the aggregation step.
#[derive(Debug, Clone)]
pub struct AggBatch {
    /// Absorbed uploads in arrival order.
    pub uploads: Vec<AbsorbedUpload>,
    /// Wall-clock since the previous aggregation.
    pub round_secs: f64,
    /// Downlink bytes paid by dispatches since the previous aggregation.
    pub down_bytes: u64,
    /// Mean version gap over the aggregated uploads.
    pub mean_gap: f64,
    /// Straggler tail: last absorb minus the median absorb time.
    pub tail_s: f64,
}

/// Checkpoint view of the runtime (format v2 payload): rebuildable via
/// `AsyncRuntime::from_state` into an exact continuation.
#[derive(Debug, Clone, Default)]
pub struct AsyncState {
    pub version: u64,
    pub now: f64,
    pub last_agg_t: f64,
    pub seq: u64,
    pub down_since_agg: u64,
    pub sample_gen: u64,
    pub sample_idx: u64,
    pub client_version: Vec<u64>,
    /// Queued completion events, sorted by (t, seq).
    pub events: Vec<(f64, u64)>,
    /// In-flight payloads keyed by dispatch seq, sorted by seq.
    pub pending: Vec<(u64, UploadPayload)>,
    /// Absorbed-but-not-aggregated uploads, in arrival order.
    pub buffer: Vec<AbsorbedUpload>,
}

/// The async server's scheduling state: persistent event queue,
/// per-client model versions, the staleness-weighted aggregation
/// buffer, and the sample-stream cursor.
#[derive(Debug, Clone)]
pub struct AsyncRuntime {
    /// In-flight cap (resolved; never 0).
    pub concurrency: usize,
    /// Absorbed uploads per aggregation (one server "round").
    pub agg_goal: usize,
    pub staleness: Staleness,
    /// Bounded staleness (`sampler = staleness:cap=N`): uploads with
    /// `version_gap > cap` are held out of the aggregation mean and the
    /// weighted combine (their bytes and clock are still paid). `None`
    /// (the default) keeps every upload in — exactly the legacy
    /// behavior. Config, not state: never serialized.
    pub stale_cap: Option<u64>,
    queue: AsyncQueue,
    pending: BTreeMap<u64, UploadPayload>,
    /// Absorbed uploads waiting for the next aggregation.
    pub buffer: Vec<AbsorbedUpload>,
    /// Server model version (one aggregation = one version).
    pub version: u64,
    /// Simulated clock (absolute).
    pub now: f64,
    last_agg_t: f64,
    /// Last model version each client received.
    pub client_version: Vec<u64>,
    seq: u64,
    down_since_agg: u64,
    /// Sample-stream cursor: cohort generation and position within it
    /// (the caller owns the actual sampling; these just persist the
    /// position across checkpoints).
    pub sample_gen: u64,
    pub sample_idx: u64,
}

impl AsyncRuntime {
    pub fn new(
        num_clients: usize,
        concurrency: usize,
        agg_goal: usize,
        staleness: Staleness,
    ) -> Self {
        AsyncRuntime {
            concurrency: concurrency.max(1),
            agg_goal: agg_goal.max(1),
            staleness,
            stale_cap: None,
            queue: AsyncQueue::new(),
            pending: BTreeMap::new(),
            buffer: Vec::new(),
            version: 0,
            now: 0.0,
            last_agg_t: 0.0,
            client_version: vec![0; num_clients],
            seq: 0,
            down_since_agg: 0,
            sample_gen: 0,
            sample_idx: 0,
        }
    }

    /// Builder: attach a bounded-staleness cap (chainable so every
    /// legacy `new`/`from_state` call site stays unchanged).
    pub fn with_stale_cap(mut self, cap: Option<u64>) -> Self {
        self.stale_cap = cap;
        self
    }

    /// Whether an absorbed upload's gap passes the bounded-staleness
    /// cap (always true without one).
    pub fn within_cap(&self, version_gap: u64) -> bool {
        self.stale_cap.map(|cap| version_gap <= cap).unwrap_or(true)
    }

    /// Uploads currently in flight.
    pub fn in_flight(&self) -> usize {
        self.pending.len()
    }

    /// Total uploads dispatched so far (also the next dispatch seq —
    /// the FedMut broadcast-slot parity source).
    pub fn dispatched(&self) -> u64 {
        self.seq
    }

    /// Whether a slot is free under the concurrency cap.
    pub fn wants_dispatch(&self) -> bool {
        self.pending.len() < self.concurrency
    }

    /// Register a trained upload completing `duration_s` from now over
    /// the client's own link. Records the model version the client
    /// received and charges its downlink bytes.
    pub fn dispatch(&mut self, payload: UploadPayload, duration_s: f64) {
        self.client_version[payload.client] = payload.version;
        self.down_since_agg += payload.bcast_len;
        let seq = self.seq;
        self.seq += 1;
        self.queue.push(self.now + duration_s, seq);
        self.pending.insert(seq, payload);
        obs::counter("async.dispatched", 1);
        obs::gauge("async.in_flight", self.pending.len() as f64);
    }

    /// Absorb every arrival at the next completion instant into the
    /// aggregation buffer, advancing the clock. Returns the index in
    /// `buffer` where the new absorbs start (callers read
    /// `buffer[start..]` for per-absorb metrics); `buffer.len()` if
    /// nothing was in flight.
    ///
    /// Errors (instead of panicking) if a queued completion event has
    /// no matching in-flight payload — a corrupted or hand-edited
    /// checkpoint is the only way to reach that state, and the caller
    /// can surface which one was loaded.
    pub fn absorb_instant(&mut self) -> Result<usize> {
        let mut sp = obs::span("sched.pop");
        let t0 = self.now;
        let start = self.buffer.len();
        for (t, seq) in self.queue.pop_instant() {
            self.now = t;
            let payload = self.pending.remove(&seq).with_context(|| {
                format!(
                    "event queue and pending map out of sync: completion event \
                     (t={t}, seq={seq}) has no in-flight payload ({} pending, \
                     version {}); the async runtime state is corrupt — likely a \
                     damaged checkpoint",
                    self.pending.len(),
                    self.version
                )
            })?;
            let version_gap = self.version - payload.version;
            let weight = self.staleness.weight(version_gap);
            obs::observe("async.version_gap", version_gap as f64);
            self.buffer.push(AbsorbedUpload { payload, t, version_gap, weight });
        }
        sp.set_sim(self.now - t0);
        obs::gauge("sched.queue_depth", self.buffer.len() as f64);
        Ok(start)
    }

    /// Whether the buffer holds enough absorbs to close a version.
    pub fn ready(&self) -> bool {
        self.buffer.len() >= self.agg_goal
    }

    /// Close a version: drain the buffer, advance the model version,
    /// and report the round's timing/byte/staleness aggregates.
    pub fn take_aggregation(&mut self) -> AggBatch {
        obs::counter("async.versions_closed", 1);
        let uploads = std::mem::take(&mut self.buffer);
        let round_secs = self.now - self.last_agg_t;
        self.last_agg_t = self.now;
        self.version += 1;
        let down_bytes = std::mem::take(&mut self.down_since_agg);
        let n = uploads.len();
        // Bounded staleness: the mean is taken over the uploads the cap
        // admits. If the cap holds *every* upload out, fall back to all
        // of them — the caller includes them all too, so an aggregation
        // is never empty.
        let admitted: Vec<f64> = uploads
            .iter()
            .filter(|u| self.within_cap(u.version_gap))
            .map(|u| u.version_gap as f64)
            .collect();
        let mean_gap = if !admitted.is_empty() {
            admitted.iter().sum::<f64>() / admitted.len() as f64
        } else if n > 0 {
            uploads.iter().map(|u| u.version_gap as f64).sum::<f64>() / n as f64
        } else {
            0.0
        };
        let tail_s = if n > 0 {
            let mut ts: Vec<f64> = uploads.iter().map(|u| u.t).collect();
            ts.sort_by(f64::total_cmp);
            (ts[n - 1] - ts[n / 2]).max(0.0)
        } else {
            0.0
        };
        AggBatch { uploads, round_secs, down_bytes, mean_gap, tail_s }
    }

    /// Checkpoint snapshot (clones in-flight deltas; the queue is
    /// serialized sorted so restores are order-independent).
    pub fn state(&self) -> AsyncState {
        AsyncState {
            version: self.version,
            now: self.now,
            last_agg_t: self.last_agg_t,
            seq: self.seq,
            down_since_agg: self.down_since_agg,
            sample_gen: self.sample_gen,
            sample_idx: self.sample_idx,
            client_version: self.client_version.clone(),
            events: self.queue.events_sorted(),
            pending: self.pending.iter().map(|(&s, p)| (s, p.clone())).collect(),
            buffer: self.buffer.clone(),
        }
    }

    /// Rebuild a runtime from a checkpoint snapshot. `concurrency`,
    /// `agg_goal`, and `staleness` come from the run config (they are
    /// not state).
    pub fn from_state(
        concurrency: usize,
        agg_goal: usize,
        staleness: Staleness,
        st: AsyncState,
    ) -> Self {
        AsyncRuntime {
            concurrency: concurrency.max(1),
            agg_goal: agg_goal.max(1),
            staleness,
            stale_cap: None,
            queue: AsyncQueue::from_events(&st.events),
            pending: st.pending.into_iter().collect(),
            buffer: st.buffer,
            version: st.version,
            now: st.now,
            last_agg_t: st.last_agg_t,
            client_version: st.client_version,
            seq: st.seq,
            down_since_agg: st.down_since_agg,
            sample_gen: st.sample_gen,
            sample_idx: st.sample_idx,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn payload(client: usize, version: u64, frame: u64) -> UploadPayload {
        UploadPayload {
            client,
            version,
            gen: version,
            delta: vec![client as f32; 4],
            loss: 0.5,
            frame_len: frame,
            bcast_len: 10,
        }
    }

    #[test]
    fn dispatch_absorb_aggregate_cycle() {
        let mut rt = AsyncRuntime::new(4, 2, 2, Staleness::Const);
        assert!(rt.wants_dispatch());
        rt.dispatch(payload(0, 0, 100), 1.0);
        rt.dispatch(payload(1, 0, 100), 0.5);
        assert!(!rt.wants_dispatch(), "concurrency cap reached");
        assert_eq!(rt.in_flight(), 2);
        assert_eq!(rt.dispatched(), 2);

        // earliest instant: client 1 at t=0.5
        let start = rt.absorb_instant().unwrap();
        assert_eq!(start, 0);
        assert_eq!(rt.buffer.len(), 1);
        assert_eq!(rt.buffer[0].payload.client, 1);
        assert_eq!(rt.now, 0.5);
        assert!(!rt.ready());

        let start = rt.absorb_instant().unwrap();
        assert_eq!(rt.buffer[start].payload.client, 0);
        assert_eq!(rt.now, 1.0);
        assert!(rt.ready());

        let batch = rt.take_aggregation();
        assert_eq!(batch.uploads.len(), 2);
        assert_eq!(batch.round_secs, 1.0);
        assert_eq!(batch.down_bytes, 20);
        assert_eq!(batch.mean_gap, 0.0);
        assert_eq!(rt.version, 1);
        assert!(rt.buffer.is_empty());
        assert_eq!(rt.in_flight(), 0);
    }

    #[test]
    fn version_gap_is_measured_per_upload() {
        let mut rt = AsyncRuntime::new(4, 2, 1, Staleness::Poly { a: 0.5 });
        // client 0 is slow (t=10), client 1 fast (t=1)
        rt.dispatch(payload(0, 0, 100), 10.0);
        rt.dispatch(payload(1, 0, 100), 1.0);
        rt.absorb_instant().unwrap(); // client 1 at t=1
        assert_eq!(rt.buffer[0].version_gap, 0);
        let b = rt.take_aggregation(); // version -> 1
        assert_eq!(b.uploads[0].weight, 1.0);
        // refill: client 2 trained against version 1, arrives before 0
        rt.dispatch(payload(2, rt.version, 100), 2.0);
        rt.absorb_instant().unwrap(); // client 2 at t=3
        assert_eq!(rt.buffer[0].version_gap, 0);
        rt.take_aggregation(); // version -> 2
        rt.absorb_instant().unwrap(); // slow client 0 at t=10: two versions elapsed
        let stale = &rt.buffer[0];
        assert_eq!(stale.payload.client, 0);
        assert_eq!(stale.version_gap, 2);
        let expect = (1.0f64 / 3.0f64.sqrt()) as f32;
        assert!((stale.weight - expect).abs() < 1e-6, "poly weight {}", stale.weight);
        assert_eq!(rt.client_version[0], 0);
        assert_eq!(rt.client_version[2], 1);
    }

    #[test]
    fn equal_instants_absorb_atomically_in_dispatch_order() {
        let mut rt = AsyncRuntime::new(8, 4, 4, Staleness::Const);
        for c in 0..4 {
            rt.dispatch(payload(c, 0, 100), 2.5);
        }
        let start = rt.absorb_instant().unwrap();
        assert_eq!(start, 0);
        assert_eq!(rt.buffer.len(), 4, "one instant absorbs the whole cohort");
        let order: Vec<usize> = rt.buffer.iter().map(|u| u.payload.client).collect();
        assert_eq!(order, vec![0, 1, 2, 3]);
        assert!(rt.ready());
        let batch = rt.take_aggregation();
        assert_eq!(batch.round_secs, 2.5);
        assert_eq!(batch.tail_s, 0.0);
    }

    #[test]
    fn round_secs_measures_inter_aggregation_time() {
        let mut rt = AsyncRuntime::new(2, 1, 1, Staleness::Const);
        rt.dispatch(payload(0, 0, 1), 1.5);
        rt.absorb_instant().unwrap();
        assert_eq!(rt.take_aggregation().round_secs, 1.5);
        rt.dispatch(payload(1, 1, 1), 2.0);
        rt.absorb_instant().unwrap();
        let b = rt.take_aggregation();
        assert_eq!(b.round_secs, 2.0, "second round measures from the last aggregation");
        assert_eq!(rt.now, 3.5);
    }

    #[test]
    fn snapshot_restore_resumes_exactly() {
        let mut rt = AsyncRuntime::new(4, 2, 2, Staleness::Poly { a: 0.5 });
        rt.dispatch(payload(0, 0, 100), 4.0);
        rt.dispatch(payload(1, 0, 50), 1.0);
        rt.absorb_instant().unwrap();
        rt.sample_gen = 3;
        rt.sample_idx = 1;

        let st = rt.state();
        let mut back = AsyncRuntime::from_state(2, 2, Staleness::Poly { a: 0.5 }, st);
        assert_eq!(back.version, rt.version);
        assert_eq!(back.now, rt.now);
        assert_eq!(back.in_flight(), 1);
        assert_eq!(back.sample_gen, 3);
        assert_eq!(back.sample_idx, 1);

        // both copies must replay the remaining schedule identically
        back.absorb_instant().unwrap();
        rt.absorb_instant().unwrap();
        assert_eq!(back.now, rt.now);
        assert_eq!(back.buffer.len(), rt.buffer.len());
        let a = back.take_aggregation();
        let b = rt.take_aggregation();
        assert_eq!(a.round_secs, b.round_secs);
        assert_eq!(a.uploads.len(), b.uploads.len());
        for (x, y) in a.uploads.iter().zip(&b.uploads) {
            assert_eq!(x.payload.client, y.payload.client);
            assert_eq!(x.payload.delta, y.payload.delta);
            assert_eq!(x.t, y.t);
            assert_eq!(x.version_gap, y.version_gap);
            assert_eq!(x.weight, y.weight);
        }
    }

    #[test]
    fn stale_cap_bounds_the_mean_gap() {
        // no cap: legacy behavior, mean over everything
        let mut rt = AsyncRuntime::new(4, 2, 2, Staleness::Const);
        assert!(rt.within_cap(u64::MAX));
        rt.version = 5;
        rt.dispatch(payload(0, 5, 1), 1.0); // gap 0 at absorb
        rt.dispatch(payload(1, 1, 1), 1.0); // gap 4 at absorb
        rt.absorb_instant().unwrap();
        assert_eq!(rt.take_aggregation().mean_gap, 2.0);

        // cap=2 holds the gap-4 upload out of the mean
        let mut rt = AsyncRuntime::new(4, 2, 2, Staleness::Const).with_stale_cap(Some(2));
        assert!(rt.within_cap(2) && !rt.within_cap(3));
        rt.version = 5;
        rt.dispatch(payload(0, 5, 1), 1.0);
        rt.dispatch(payload(1, 1, 1), 1.0);
        rt.absorb_instant().unwrap();
        assert_eq!(rt.take_aggregation().mean_gap, 0.0);

        // all uploads over the cap: fall back to the mean over all
        let mut rt = AsyncRuntime::new(4, 2, 2, Staleness::Const).with_stale_cap(Some(1));
        rt.version = 5;
        rt.dispatch(payload(0, 1, 1), 1.0);
        rt.dispatch(payload(1, 3, 1), 1.0);
        rt.absorb_instant().unwrap();
        assert_eq!(rt.take_aggregation().mean_gap, 3.0);
    }

    #[test]
    fn empty_aggregation_is_safe() {
        let mut rt = AsyncRuntime::new(2, 1, 1, Staleness::Const);
        assert_eq!(rt.absorb_instant().unwrap(), 0, "no in-flight uploads: nothing absorbed");
        let b = rt.take_aggregation();
        assert!(b.uploads.is_empty());
        assert_eq!(b.mean_gap, 0.0);
        assert_eq!(b.tail_s, 0.0);
    }
}
