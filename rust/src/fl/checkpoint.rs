//! Server checkpointing: save/resume a federated run mid-schedule.
//!
//! Binary format (little-endian, versioned): global params, the full
//! LUAR state (scores, observed mask, recycle buffer, recycle set,
//! staleness), server-optimizer buffers, the coordinator RNG, and the
//! communication ledger — everything needed for a resumed run to be
//! bit-identical to an uninterrupted one (asserted in
//! `integration_fl::checkpoint_resume_is_bit_identical`).
//!
//! Format v2 appends the simulated clock (`sim_seconds`), the loss
//! EMA, the failure/straggler counters, and — when the run is async —
//! the full `AsyncRuntime` state: per-client model versions, the
//! persistent event queue, every in-flight upload (including its
//! trained delta), the absorbed-but-unaggregated buffer, and the
//! sample-stream cursor. A resumed async run therefore replays the
//! remaining schedule exactly, in-flight stragglers included. v1
//! checkpoints still load (the appended fields keep their defaults).
//!
//! Format v3 appends the comm ledger's residual-framing counters
//! (`delta_bytes_saved` / `delta_fallbacks`) and, when the run uses
//! `net.delta_frames`, the full `DeltaFrameState`: the broadcast
//! reference ring, per-client last-received versions, and per-client
//! uplink reference snapshots. v1/v2 checkpoints still load; a
//! delta-framed run resumed from one starts with empty references, so
//! its model trajectory is unchanged and every post-resume first
//! contact is counted in `fl.delta_fallbacks` (the documented
//! fallback case). `save_checkpoint_as` writes the older formats so
//! the migration path stays testable.
//!
//! Format v4 appends the per-client sampler telemetry
//! (`Server::sampler_stats`: dispatch/absorb/held counts, upload-time
//! and byte sums) plus the in-progress async cohort memo — under
//! `sampler = speed` the cohort depends on the telemetry at first
//! sampling, so resume must restore rather than resample it. v1–v3
//! checkpoints still load with a cold table: uniform runs are
//! unaffected, a resumed speed run re-warms from scratch.
//!
//! Not captured (documented limits): per-client compressor state
//! (error-feedback residuals, LBGM anchors) and MOON's previous local
//! models — resuming a run that uses those restarts their state, which
//! changes trajectories for FedBAT/LBGM/MOON runs but not for
//! FedAvg/FedLUAR.

use super::{AbsorbedUpload, AsyncRuntime, AsyncState, RefState, Server, UploadPayload};
use anyhow::{bail, Context, Result};
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 4] = b"FLCK";
const VERSION: u32 = 4;

struct Writer {
    buf: Vec<u8>,
}

impl Writer {
    fn new() -> Self {
        Writer { buf: Vec::new() }
    }

    fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn str(&mut self, s: &str) {
        self.u32(s.len() as u32);
        self.buf.extend_from_slice(s.as_bytes());
    }

    fn f32s(&mut self, v: &[f32]) {
        self.u64(v.len() as u64);
        for &x in v {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }

    fn f64s(&mut self, v: &[f64]) {
        self.u64(v.len() as u64);
        for &x in v {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }

    fn u64s(&mut self, v: &[u64]) {
        self.u64(v.len() as u64);
        for &x in v {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }

    fn usizes(&mut self, v: &[usize]) {
        self.u64s(&v.iter().map(|&x| x as u64).collect::<Vec<_>>());
    }

    fn bools(&mut self, v: &[bool]) {
        self.u64(v.len() as u64);
        self.buf.extend(v.iter().map(|&b| b as u8));
    }

    fn u32s(&mut self, v: &[u32]) {
        self.u64(v.len() as u64);
        for &x in v {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }
}

struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            bail!("checkpoint truncated at byte {}", self.pos);
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn str(&mut self) -> Result<String> {
        let n = self.u32()? as usize;
        Ok(String::from_utf8(self.take(n)?.to_vec())?)
    }

    fn f32s(&mut self) -> Result<Vec<f32>> {
        let n = self.u64()? as usize;
        let bytes = self.take(n * 4)?;
        Ok(bytes.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect())
    }

    fn f64s(&mut self) -> Result<Vec<f64>> {
        let n = self.u64()? as usize;
        let bytes = self.take(n * 8)?;
        Ok(bytes.chunks_exact(8).map(|c| f64::from_le_bytes(c.try_into().unwrap())).collect())
    }

    fn u64s(&mut self) -> Result<Vec<u64>> {
        let n = self.u64()? as usize;
        let bytes = self.take(n * 8)?;
        Ok(bytes.chunks_exact(8).map(|c| u64::from_le_bytes(c.try_into().unwrap())).collect())
    }

    fn usizes(&mut self) -> Result<Vec<usize>> {
        Ok(self.u64s()?.into_iter().map(|x| x as usize).collect())
    }

    fn bools(&mut self) -> Result<Vec<bool>> {
        let n = self.u64()? as usize;
        Ok(self.take(n)?.iter().map(|&b| b != 0).collect())
    }

    fn u32s(&mut self) -> Result<Vec<u32>> {
        let n = self.u64()? as usize;
        let bytes = self.take(n * 4)?;
        Ok(bytes.chunks_exact(4).map(|c| u32::from_le_bytes(c.try_into().unwrap())).collect())
    }
}

impl Server {
    /// Write the full resumable state to `path` (current format).
    pub fn save_checkpoint(&self, path: impl AsRef<Path>) -> Result<()> {
        self.save_checkpoint_as(path, VERSION)
    }

    /// Write a checkpoint in an explicit (possibly older) format
    /// version — the migration tests save v2 files and assert this
    /// build still resumes them exactly. Refuses to drop state the
    /// requested format cannot carry (an async runtime needs v2+).
    pub fn save_checkpoint_as(&self, path: impl AsRef<Path>, version: u32) -> Result<()> {
        if version == 0 || version > VERSION {
            bail!("cannot write checkpoint version {version} (this build writes 1..={VERSION})");
        }
        if version < 2 && self.async_rt.is_some() {
            bail!("checkpoint v1 cannot carry async runtime state");
        }
        let mut w = Writer::new();
        w.buf.extend_from_slice(MAGIC);
        w.u32(version);
        w.str(&self.cfg.model);
        w.str(&self.cfg.method.spec_string());
        w.u64(self.round as u64);
        // optimizer
        let (x, m, v, last_delta, step) = self.opt.snapshot();
        w.f32s(x);
        w.f32s(m);
        w.f32s(v);
        w.f32s(last_delta);
        w.u64(step);
        // LUAR
        w.f64s(&self.luar.scores);
        w.bools(&self.luar.observed);
        w.f32s(&self.luar.prev_update);
        w.usizes(&self.luar.recycle_set);
        w.u32s(&self.luar.staleness);
        // comm ledger
        w.u64(self.comm.rounds);
        w.u64(self.comm.up_bytes);
        w.u64(self.comm.down_bytes);
        w.u64(self.comm.fedavg_up_bytes);
        w.u64s(&self.comm.layer_upload_rounds);
        // coordinator rng
        let st = self.rng_state();
        w.u64s(&st);
        if version >= 2 {
            // --- v2: simulated clock + counters -----------------------
            w.f64(self.sim_seconds);
            w.f64(self.train_loss_ema);
            w.u64(self.failed_clients);
            w.u64(self.dropped_stragglers);
            // --- v2: async runtime (in-flight queue included) ---------
            match &self.async_rt {
                None => w.buf.push(0),
                Some(rt) => {
                    w.buf.push(1);
                    write_async_state(&mut w, &rt.state());
                }
            }
        }
        if version >= 3 {
            // --- v3: residual-framing ledger + references -------------
            w.u64(self.comm.delta_bytes_saved);
            w.u64(self.comm.delta_fallbacks);
            match &self.delta_state {
                None => w.buf.push(0),
                Some(st) => {
                    w.buf.push(1);
                    let (bcast_refs, down_versions, up_refs) = st.snapshot();
                    w.u64(bcast_refs.len() as u64);
                    for r in bcast_refs {
                        write_ref_state(&mut w, r);
                    }
                    w.u64s(down_versions);
                    w.u64(up_refs.len() as u64);
                    for r in up_refs {
                        match r {
                            None => w.buf.push(0),
                            Some(r) => {
                                w.buf.push(1);
                                write_ref_state(&mut w, r);
                            }
                        }
                    }
                }
            }
        }
        if version >= 4 {
            // --- v4: per-client sampler telemetry ---------------------
            w.u64s(&self.sampler_stats.dispatches);
            w.u64s(&self.sampler_stats.absorbed);
            w.u64s(&self.sampler_stats.held_stale);
            w.f64s(&self.sampler_stats.upload_secs_sum);
            w.u64s(&self.sampler_stats.up_bytes);
            // In-progress async cohort memo: under `speed` the cohort
            // depends on the telemetry at first sampling, so a resumed
            // run must restore it rather than resample.
            match &self.async_cohort {
                None => w.buf.push(0),
                Some((gen, cohort)) => {
                    w.buf.push(1);
                    w.u64(*gen);
                    w.usizes(cohort);
                }
            }
        }
        if let Some(parent) = path.as_ref().parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut f = std::fs::File::create(&path)
            .with_context(|| format!("creating {:?}", path.as_ref()))?;
        f.write_all(&w.buf)?;
        Ok(())
    }

    /// Restore state saved by `save_checkpoint`. The server must have
    /// been constructed with the *same config* (model, method, seeds).
    pub fn load_checkpoint(&mut self, path: impl AsRef<Path>) -> Result<()> {
        let mut bytes = Vec::new();
        std::fs::File::open(&path)
            .with_context(|| format!("opening {:?}", path.as_ref()))?
            .read_to_end(&mut bytes)?;
        let mut r = Reader { buf: &bytes, pos: 0 };
        if r.take(4)? != MAGIC {
            bail!("not a fedluar checkpoint");
        }
        let version = r.u32()?;
        if version == 0 || version > VERSION {
            bail!("checkpoint version {version} unsupported (this build reads 1..={VERSION})");
        }
        let model = r.str()?;
        if model != self.cfg.model {
            bail!("checkpoint is for model {model}, server runs {}", self.cfg.model);
        }
        let method = r.str()?;
        if method != self.cfg.method.spec_string() {
            bail!("checkpoint method {method} != {}", self.cfg.method.spec_string());
        }
        self.round = r.u64()? as usize;
        let x = r.f32s()?;
        if x.len() != self.meta().dim {
            bail!("checkpoint dim {} != model dim {}", x.len(), self.meta().dim);
        }
        let m = r.f32s()?;
        let v = r.f32s()?;
        let last_delta = r.f32s()?;
        let step = r.u64()?;
        self.opt.restore(x, m, v, last_delta, step);
        self.luar.scores = r.f64s()?;
        self.luar.observed = r.bools()?;
        self.luar.prev_update = r.f32s()?;
        self.luar.recycle_set = r.usizes()?;
        self.luar.staleness = r.u32s()?;
        self.comm.rounds = r.u64()?;
        self.comm.up_bytes = r.u64()?;
        self.comm.down_bytes = r.u64()?;
        self.comm.fedavg_up_bytes = r.u64()?;
        self.comm.layer_upload_rounds = r.u64s()?;
        let st = r.u64s()?;
        if st.len() != 4 {
            bail!("bad rng state");
        }
        self.set_rng_state([st[0], st[1], st[2], st[3]]);
        if version >= 2 {
            self.sim_seconds = r.f64()?;
            self.train_loss_ema = r.f64()?;
            self.failed_clients = r.u64()?;
            self.dropped_stragglers = r.u64()?;
            let has_async = r.take(1)?[0];
            if has_async == 1 {
                let state = read_async_state(&mut r)?;
                let (c, goal, staleness) = self.async_mode_params().ok_or_else(|| {
                    anyhow::anyhow!(
                        "checkpoint holds async runtime state but the server's \
                         round_mode is {}",
                        self.cfg.net.round_mode.spec_string()
                    )
                })?;
                if state.client_version.len() != self.cfg.num_clients {
                    bail!(
                        "checkpoint tracks {} client versions, server has {} clients",
                        state.client_version.len(),
                        self.cfg.num_clients
                    );
                }
                self.async_rt = Some(
                    AsyncRuntime::from_state(c, goal, staleness, state)
                        .with_stale_cap(self.cfg.net.sampler.stale_cap()),
                );
            } else {
                self.async_rt = None;
            }
        }
        // Pre-v3 files carry no references or delta counters: a
        // delta-framed server resumes with empty ones (trajectory
        // unchanged, post-resume first contacts count as fallbacks).
        if let Some(st) = &mut self.delta_state {
            *st = super::DeltaFrameState::new(self.cfg.num_clients);
        }
        self.comm.delta_bytes_saved = 0;
        self.comm.delta_fallbacks = 0;
        if version >= 3 {
            self.comm.delta_bytes_saved = r.u64()?;
            self.comm.delta_fallbacks = r.u64()?;
            let has_delta = r.take(1)?[0];
            if has_delta == 1 {
                let n_bcast = r.u64()? as usize;
                let mut bcast_refs = Vec::with_capacity(n_bcast);
                for _ in 0..n_bcast {
                    bcast_refs.push(read_ref_state(&mut r)?);
                }
                let down_versions = r.u64s()?;
                let n_up = r.u64()? as usize;
                let mut up_refs = Vec::with_capacity(n_up);
                for _ in 0..n_up {
                    match r.take(1)?[0] {
                        0 => up_refs.push(None),
                        _ => up_refs.push(Some(read_ref_state(&mut r)?)),
                    }
                }
                if down_versions.len() != self.cfg.num_clients {
                    bail!(
                        "checkpoint tracks {} delta-framing clients, server has {}",
                        down_versions.len(),
                        self.cfg.num_clients
                    );
                }
                // References are ledger-only: a server running without
                // `net.delta_frames` ignores them (the restored comm
                // counters keep the ledger history either way).
                if let Some(st) = &mut self.delta_state {
                    st.restore(bcast_refs, down_versions, up_refs);
                }
            }
        }
        // Dispatch-side memos are derived state: drop them so the first
        // post-restore dispatch rebuilds against the restored model.
        // (v4 below restores the cohort memo over the cleared value —
        // under `speed` it depends on the telemetry at first sampling
        // and must not be resampled.)
        self.async_bcast = None;
        self.async_cohort = None;
        // Pre-v4 files carry no sampler telemetry: resume with a cold
        // table (uniform runs are unaffected; a resumed speed run
        // re-warms from scratch).
        self.sampler_stats = crate::net::ClientStats::new(self.cfg.num_clients);
        if version >= 4 {
            let dispatches = r.u64s()?;
            let absorbed = r.u64s()?;
            let held_stale = r.u64s()?;
            let upload_secs_sum = r.f64s()?;
            let up_bytes = r.u64s()?;
            if dispatches.len() != self.cfg.num_clients
                || absorbed.len() != self.cfg.num_clients
                || held_stale.len() != self.cfg.num_clients
                || upload_secs_sum.len() != self.cfg.num_clients
                || up_bytes.len() != self.cfg.num_clients
            {
                bail!(
                    "checkpoint tracks sampler telemetry for {} clients, server has {}",
                    dispatches.len(),
                    self.cfg.num_clients
                );
            }
            self.sampler_stats = crate::net::ClientStats {
                dispatches,
                absorbed,
                held_stale,
                upload_secs_sum,
                up_bytes,
            };
            if r.take(1)?[0] == 1 {
                let gen = r.u64()?;
                let cohort = r.usizes()?;
                self.async_cohort = Some((gen, cohort));
            }
        }
        Ok(())
    }
}

fn write_ref_state(w: &mut Writer, r: &RefState) {
    w.u64(r.version);
    w.f32s(&r.data);
    w.u64s(&r.layer_hash);
}

fn read_ref_state(r: &mut Reader) -> Result<RefState> {
    Ok(RefState { version: r.u64()?, data: r.f32s()?, layer_hash: r.u64s()? })
}

fn write_payload(w: &mut Writer, p: &UploadPayload) {
    w.u64(p.client as u64);
    w.u64(p.version);
    w.u64(p.gen);
    w.f32(p.loss);
    w.u64(p.frame_len);
    w.u64(p.bcast_len);
    w.f32s(&p.delta);
}

fn read_payload(r: &mut Reader) -> Result<UploadPayload> {
    Ok(UploadPayload {
        client: r.u64()? as usize,
        version: r.u64()?,
        gen: r.u64()?,
        loss: r.f32()?,
        frame_len: r.u64()?,
        bcast_len: r.u64()?,
        delta: r.f32s()?,
    })
}

fn write_async_state(w: &mut Writer, st: &AsyncState) {
    w.u64(st.version);
    w.f64(st.now);
    w.f64(st.last_agg_t);
    w.u64(st.seq);
    w.u64(st.down_since_agg);
    w.u64(st.sample_gen);
    w.u64(st.sample_idx);
    w.u64s(&st.client_version);
    w.u64(st.events.len() as u64);
    for &(t, seq) in &st.events {
        w.f64(t);
        w.u64(seq);
    }
    w.u64(st.pending.len() as u64);
    for (seq, p) in &st.pending {
        w.u64(*seq);
        write_payload(w, p);
    }
    w.u64(st.buffer.len() as u64);
    for a in &st.buffer {
        write_payload(w, &a.payload);
        w.f64(a.t);
        w.u64(a.version_gap);
        w.f32(a.weight);
    }
}

fn read_async_state(r: &mut Reader) -> Result<AsyncState> {
    let mut st = AsyncState {
        version: r.u64()?,
        now: r.f64()?,
        last_agg_t: r.f64()?,
        seq: r.u64()?,
        down_since_agg: r.u64()?,
        sample_gen: r.u64()?,
        sample_idx: r.u64()?,
        client_version: r.u64s()?,
        ..Default::default()
    };
    let n_events = r.u64()? as usize;
    st.events.reserve(n_events);
    for _ in 0..n_events {
        let t = r.f64()?;
        let seq = r.u64()?;
        st.events.push((t, seq));
    }
    let n_pending = r.u64()? as usize;
    st.pending.reserve(n_pending);
    for _ in 0..n_pending {
        let seq = r.u64()?;
        st.pending.push((seq, read_payload(r)?));
    }
    let n_buf = r.u64()? as usize;
    st.buffer.reserve(n_buf);
    for _ in 0..n_buf {
        let payload = read_payload(r)?;
        let t = r.f64()?;
        let version_gap = r.u64()?;
        let weight = r.f32()?;
        st.buffer.push(AbsorbedUpload { payload, t, version_gap, weight });
    }
    Ok(st)
}
