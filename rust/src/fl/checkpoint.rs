//! Server checkpointing: save/resume a federated run mid-schedule.
//!
//! Binary format (little-endian, versioned): global params, the full
//! LUAR state (scores, observed mask, recycle buffer, recycle set,
//! staleness), server-optimizer buffers, the coordinator RNG, and the
//! communication ledger — everything needed for a resumed run to be
//! bit-identical to an uninterrupted one (asserted in
//! `integration_fl::checkpoint_resume_is_bit_identical`).
//!
//! Format v2 appends the simulated clock (`sim_seconds`), the loss
//! EMA, the failure/straggler counters, and — when the run is async —
//! the full `AsyncRuntime` state: per-client model versions, the
//! persistent event queue, every in-flight upload (including its
//! trained delta), the absorbed-but-unaggregated buffer, and the
//! sample-stream cursor. A resumed async run therefore replays the
//! remaining schedule exactly, in-flight stragglers included. v1
//! checkpoints still load (the appended fields keep their defaults).
//!
//! Format v3 appends the comm ledger's residual-framing counters
//! (`delta_bytes_saved` / `delta_fallbacks`) and, when the run uses
//! `net.delta_frames`, the full `DeltaFrameState`: the broadcast
//! reference ring, per-client last-received versions, and per-client
//! uplink reference snapshots. v1/v2 checkpoints still load; a
//! delta-framed run resumed from one starts with empty references, so
//! its model trajectory is unchanged and every post-resume first
//! contact is counted in `fl.delta_fallbacks` (the documented
//! fallback case). `save_checkpoint_as` writes the older formats so
//! the migration path stays testable.
//!
//! Format v4 appends the per-client sampler telemetry
//! (`Server::sampler_stats`: dispatch/absorb/held counts, upload-time
//! and byte sums) plus the in-progress async cohort memo — under
//! `sampler = speed` the cohort depends on the telemetry at first
//! sampling, so resume must restore rather than resample it. v1–v3
//! checkpoints still load with a cold table: uniform runs are
//! unaffected, a resumed speed run re-warms from scratch.
//!
//! Format v5 appends the fault-injection state: the per-client retry
//! telemetry columns of `ClientStats` and, when `net.faults` is
//! active, the `FaultPlan` cursor (open outage windows, cumulative
//! injection/retry/failure counters, undrained orphan bytes). v1–v4
//! checkpoints still load (retry columns zero, fresh plan). Loading is
//! atomic: the whole file is parsed and validated into locals before
//! any server state changes, so a truncated file fails with a
//! "truncated at field `X`" error and never leaves partial state.
//!
//! Not captured (documented limits): per-client compressor state
//! (error-feedback residuals, LBGM anchors) and MOON's previous local
//! models — resuming a run that uses those restarts their state, which
//! changes trajectories for FedBAT/LBGM/MOON runs but not for
//! FedAvg/FedLUAR.

use super::{AbsorbedUpload, AsyncRuntime, AsyncState, RefState, Server, UploadPayload};
use crate::net::{ClientStats, FaultPlan};
use anyhow::{bail, Context, Result};
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 4] = b"FLCK";
const VERSION: u32 = 5;

struct Writer {
    buf: Vec<u8>,
}

impl Writer {
    fn new() -> Self {
        Writer { buf: Vec::new() }
    }

    fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn str(&mut self, s: &str) {
        self.u32(s.len() as u32);
        self.buf.extend_from_slice(s.as_bytes());
    }

    fn f32s(&mut self, v: &[f32]) {
        self.u64(v.len() as u64);
        for &x in v {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }

    fn f64s(&mut self, v: &[f64]) {
        self.u64(v.len() as u64);
        for &x in v {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }

    fn u64s(&mut self, v: &[u64]) {
        self.u64(v.len() as u64);
        for &x in v {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }

    fn usizes(&mut self, v: &[usize]) {
        self.u64s(&v.iter().map(|&x| x as u64).collect::<Vec<_>>());
    }

    fn bools(&mut self, v: &[bool]) {
        self.u64(v.len() as u64);
        self.buf.extend(v.iter().map(|&b| b as u8));
    }

    fn u32s(&mut self, v: &[u32]) {
        self.u64(v.len() as u64);
        for &x in v {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }
}

struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
    /// Name of the field currently being decoded: truncation errors
    /// report it instead of a bare byte offset.
    field: &'static str,
}

impl<'a> Reader<'a> {
    /// Label the next read(s); chainable: `r.at("luar.scores").f64s()`.
    fn at(&mut self, name: &'static str) -> &mut Self {
        self.field = name;
        self
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            bail!(
                "checkpoint truncated at field `{}` (byte {} of {}, {} more needed); \
                 no state was applied",
                self.field,
                self.pos,
                self.buf.len(),
                self.pos + n - self.buf.len()
            );
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn str(&mut self) -> Result<String> {
        let n = self.u32()? as usize;
        Ok(String::from_utf8(self.take(n)?.to_vec())?)
    }

    fn f32s(&mut self) -> Result<Vec<f32>> {
        let n = self.u64()? as usize;
        let bytes = self.take(n * 4)?;
        Ok(bytes.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect())
    }

    fn f64s(&mut self) -> Result<Vec<f64>> {
        let n = self.u64()? as usize;
        let bytes = self.take(n * 8)?;
        Ok(bytes.chunks_exact(8).map(|c| f64::from_le_bytes(c.try_into().unwrap())).collect())
    }

    fn u64s(&mut self) -> Result<Vec<u64>> {
        let n = self.u64()? as usize;
        let bytes = self.take(n * 8)?;
        Ok(bytes.chunks_exact(8).map(|c| u64::from_le_bytes(c.try_into().unwrap())).collect())
    }

    fn usizes(&mut self) -> Result<Vec<usize>> {
        Ok(self.u64s()?.into_iter().map(|x| x as usize).collect())
    }

    fn bools(&mut self) -> Result<Vec<bool>> {
        let n = self.u64()? as usize;
        Ok(self.take(n)?.iter().map(|&b| b != 0).collect())
    }

    fn u32s(&mut self) -> Result<Vec<u32>> {
        let n = self.u64()? as usize;
        let bytes = self.take(n * 4)?;
        Ok(bytes.chunks_exact(4).map(|c| u32::from_le_bytes(c.try_into().unwrap())).collect())
    }
}

impl Server {
    /// Write the full resumable state to `path` (current format).
    pub fn save_checkpoint(&self, path: impl AsRef<Path>) -> Result<()> {
        self.save_checkpoint_as(path, VERSION)
    }

    /// Write a checkpoint in an explicit (possibly older) format
    /// version — the migration tests save v2 files and assert this
    /// build still resumes them exactly. Refuses to drop state the
    /// requested format cannot carry (an async runtime needs v2+).
    pub fn save_checkpoint_as(&self, path: impl AsRef<Path>, version: u32) -> Result<()> {
        if version == 0 || version > VERSION {
            bail!("cannot write checkpoint version {version} (this build writes 1..={VERSION})");
        }
        if version < 2 && self.async_rt.is_some() {
            bail!("checkpoint v1 cannot carry async runtime state");
        }
        if version < 5 && self.faults.is_some() {
            bail!("checkpoint v{version} cannot carry fault-injection state (needs v5+)");
        }
        let mut w = Writer::new();
        w.buf.extend_from_slice(MAGIC);
        w.u32(version);
        w.str(&self.cfg.model);
        w.str(&self.cfg.method.spec_string());
        w.u64(self.round as u64);
        // optimizer
        let (x, m, v, last_delta, step) = self.opt.snapshot();
        w.f32s(x);
        w.f32s(m);
        w.f32s(v);
        w.f32s(last_delta);
        w.u64(step);
        // LUAR
        w.f64s(&self.luar.scores);
        w.bools(&self.luar.observed);
        w.f32s(&self.luar.prev_update);
        w.usizes(&self.luar.recycle_set);
        w.u32s(&self.luar.staleness);
        // comm ledger
        w.u64(self.comm.rounds);
        w.u64(self.comm.up_bytes);
        w.u64(self.comm.down_bytes);
        w.u64(self.comm.fedavg_up_bytes);
        w.u64s(&self.comm.layer_upload_rounds);
        // coordinator rng
        let st = self.rng_state();
        w.u64s(&st);
        if version >= 2 {
            // --- v2: simulated clock + counters -----------------------
            w.f64(self.sim_seconds);
            w.f64(self.train_loss_ema);
            w.u64(self.failed_clients);
            w.u64(self.dropped_stragglers);
            // --- v2: async runtime (in-flight queue included) ---------
            match &self.async_rt {
                None => w.buf.push(0),
                Some(rt) => {
                    w.buf.push(1);
                    write_async_state(&mut w, &rt.state());
                }
            }
        }
        if version >= 3 {
            // --- v3: residual-framing ledger + references -------------
            w.u64(self.comm.delta_bytes_saved);
            w.u64(self.comm.delta_fallbacks);
            match &self.delta_state {
                None => w.buf.push(0),
                Some(st) => {
                    w.buf.push(1);
                    let (bcast_refs, down_versions, up_refs) = st.snapshot();
                    w.u64(bcast_refs.len() as u64);
                    for r in bcast_refs {
                        write_ref_state(&mut w, r);
                    }
                    w.u64s(down_versions);
                    w.u64(up_refs.len() as u64);
                    for r in up_refs {
                        match r {
                            None => w.buf.push(0),
                            Some(r) => {
                                w.buf.push(1);
                                write_ref_state(&mut w, r);
                            }
                        }
                    }
                }
            }
        }
        if version >= 4 {
            // --- v4: per-client sampler telemetry ---------------------
            w.u64s(&self.sampler_stats.dispatches);
            w.u64s(&self.sampler_stats.absorbed);
            w.u64s(&self.sampler_stats.held_stale);
            w.f64s(&self.sampler_stats.upload_secs_sum);
            w.u64s(&self.sampler_stats.up_bytes);
            // In-progress async cohort memo: under `speed` the cohort
            // depends on the telemetry at first sampling, so a resumed
            // run must restore it rather than resample.
            match &self.async_cohort {
                None => w.buf.push(0),
                Some((gen, cohort)) => {
                    w.buf.push(1);
                    w.u64(*gen);
                    w.usizes(cohort);
                }
            }
        }
        if version >= 5 {
            // --- v5: retry telemetry + fault-plan cursor --------------
            w.u64s(&self.sampler_stats.retries);
            w.f64s(&self.sampler_stats.retry_secs_sum);
            w.u64s(&self.sampler_stats.retry_bytes);
            w.u64s(&self.sampler_stats.failures);
            match &self.faults {
                None => w.buf.push(0),
                Some(plan) => {
                    w.buf.push(1);
                    w.f64s(&plan.down_until);
                    w.u64(plan.drops);
                    w.u64(plan.outages);
                    w.u64(plan.corrupts);
                    w.u64(plan.retries);
                    w.u64(plan.perm_failures);
                    w.u64(plan.quorum_degraded);
                    w.u64(plan.orphan_up_bytes);
                    w.u64(plan.orphan_down_bytes);
                }
            }
        }
        if let Some(parent) = path.as_ref().parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut f = std::fs::File::create(&path)
            .with_context(|| format!("creating {:?}", path.as_ref()))?;
        f.write_all(&w.buf)?;
        Ok(())
    }

    /// Restore state saved by `save_checkpoint`. The server must have
    /// been constructed with the *same config* (model, method, seeds).
    ///
    /// Loading is atomic: the whole file is parsed and validated into
    /// locals first, and server state is only touched once every read
    /// succeeded — a file truncated at field X fails with that field's
    /// name and leaves the server exactly as it was.
    pub fn load_checkpoint(&mut self, path: impl AsRef<Path>) -> Result<()> {
        let mut bytes = Vec::new();
        std::fs::File::open(&path)
            .with_context(|| format!("opening {:?}", path.as_ref()))?
            .read_to_end(&mut bytes)?;
        let mut r = Reader { buf: &bytes, pos: 0, field: "magic" };

        // ---- parse phase: locals only, no server state touched ------
        if r.take(4)? != MAGIC {
            bail!("not a fedluar checkpoint");
        }
        let version = r.at("version").u32()?;
        if version == 0 || version > VERSION {
            bail!("checkpoint version {version} unsupported (this build reads 1..={VERSION})");
        }
        let model = r.at("model").str()?;
        if model != self.cfg.model {
            bail!("checkpoint is for model {model}, server runs {}", self.cfg.model);
        }
        let method = r.at("method").str()?;
        if method != self.cfg.method.spec_string() {
            bail!("checkpoint method {method} != {}", self.cfg.method.spec_string());
        }
        let round = r.at("round").u64()? as usize;
        let x = r.at("opt.params").f32s()?;
        if x.len() != self.meta().dim {
            bail!("checkpoint dim {} != model dim {}", x.len(), self.meta().dim);
        }
        let m = r.at("opt.m").f32s()?;
        let v = r.at("opt.v").f32s()?;
        let last_delta = r.at("opt.last_delta").f32s()?;
        let step = r.at("opt.step").u64()?;
        let luar_scores = r.at("luar.scores").f64s()?;
        let luar_observed = r.at("luar.observed").bools()?;
        let luar_prev_update = r.at("luar.prev_update").f32s()?;
        let luar_recycle_set = r.at("luar.recycle_set").usizes()?;
        let luar_staleness = r.at("luar.staleness").u32s()?;
        let comm_rounds = r.at("comm.rounds").u64()?;
        let comm_up_bytes = r.at("comm.up_bytes").u64()?;
        let comm_down_bytes = r.at("comm.down_bytes").u64()?;
        let comm_fedavg = r.at("comm.fedavg_up_bytes").u64()?;
        let comm_layer_rounds = r.at("comm.layer_upload_rounds").u64s()?;
        let rng_st = r.at("rng").u64s()?;
        if rng_st.len() != 4 {
            bail!("bad rng state");
        }

        let mut v2_scalars: Option<(f64, f64, u64, u64)> = None;
        let mut async_restored: Option<AsyncRuntime> = None;
        if version >= 2 {
            let sim_seconds = r.at("sim_seconds").f64()?;
            let ema = r.at("train_loss_ema").f64()?;
            let failed = r.at("failed_clients").u64()?;
            let dropped = r.at("dropped_stragglers").u64()?;
            v2_scalars = Some((sim_seconds, ema, failed, dropped));
            let has_async = r.at("async.flag").take(1)?[0];
            if has_async == 1 {
                let state = read_async_state(&mut r)?;
                let (c, goal, staleness) = self.async_mode_params().ok_or_else(|| {
                    anyhow::anyhow!(
                        "checkpoint holds async runtime state but the server's \
                         round_mode is {}",
                        self.cfg.net.round_mode.spec_string()
                    )
                })?;
                if state.client_version.len() != self.cfg.num_clients {
                    bail!(
                        "checkpoint tracks {} client versions, server has {} clients",
                        state.client_version.len(),
                        self.cfg.num_clients
                    );
                }
                async_restored = Some(
                    AsyncRuntime::from_state(c, goal, staleness, state)
                        .with_stale_cap(self.cfg.net.sampler.stale_cap()),
                );
            }
        }
        let mut delta_counters = (0u64, 0u64);
        let mut delta_restore: Option<(Vec<RefState>, Vec<u64>, Vec<Option<RefState>>)> = None;
        if version >= 3 {
            delta_counters.0 = r.at("comm.delta_bytes_saved").u64()?;
            delta_counters.1 = r.at("comm.delta_fallbacks").u64()?;
            let has_delta = r.at("delta.flag").take(1)?[0];
            if has_delta == 1 {
                let n_bcast = r.at("delta.bcast_refs").u64()? as usize;
                let mut bcast_refs = Vec::with_capacity(n_bcast);
                for _ in 0..n_bcast {
                    bcast_refs.push(read_ref_state(&mut r)?);
                }
                let down_versions = r.at("delta.down_versions").u64s()?;
                let n_up = r.at("delta.up_refs").u64()? as usize;
                let mut up_refs = Vec::with_capacity(n_up);
                for _ in 0..n_up {
                    match r.at("delta.up_ref_flag").take(1)?[0] {
                        0 => up_refs.push(None),
                        _ => up_refs.push(Some(read_ref_state(&mut r)?)),
                    }
                }
                if down_versions.len() != self.cfg.num_clients {
                    bail!(
                        "checkpoint tracks {} delta-framing clients, server has {}",
                        down_versions.len(),
                        self.cfg.num_clients
                    );
                }
                delta_restore = Some((bcast_refs, down_versions, up_refs));
            }
        }
        let mut stats_restored = ClientStats::new(self.cfg.num_clients);
        let mut cohort_restored: Option<(u64, Vec<usize>)> = None;
        if version >= 4 {
            let dispatches = r.at("sampler.dispatches").u64s()?;
            let absorbed = r.at("sampler.absorbed").u64s()?;
            let held_stale = r.at("sampler.held_stale").u64s()?;
            let upload_secs_sum = r.at("sampler.upload_secs_sum").f64s()?;
            let up_bytes = r.at("sampler.up_bytes").u64s()?;
            if dispatches.len() != self.cfg.num_clients
                || absorbed.len() != self.cfg.num_clients
                || held_stale.len() != self.cfg.num_clients
                || upload_secs_sum.len() != self.cfg.num_clients
                || up_bytes.len() != self.cfg.num_clients
            {
                bail!(
                    "checkpoint tracks sampler telemetry for {} clients, server has {}",
                    dispatches.len(),
                    self.cfg.num_clients
                );
            }
            stats_restored.dispatches = dispatches;
            stats_restored.absorbed = absorbed;
            stats_restored.held_stale = held_stale;
            stats_restored.upload_secs_sum = upload_secs_sum;
            stats_restored.up_bytes = up_bytes;
            if r.at("sampler.cohort_flag").take(1)?[0] == 1 {
                let gen = r.at("sampler.cohort_gen").u64()?;
                let cohort = r.at("sampler.cohort").usizes()?;
                cohort_restored = Some((gen, cohort));
            }
        }
        let mut fault_restore: Option<(Vec<f64>, [u64; 8])> = None;
        if version >= 5 {
            let retries = r.at("sampler.retries").u64s()?;
            let retry_secs_sum = r.at("sampler.retry_secs_sum").f64s()?;
            let retry_bytes = r.at("sampler.retry_bytes").u64s()?;
            let failures = r.at("sampler.failures").u64s()?;
            if retries.len() != self.cfg.num_clients
                || retry_secs_sum.len() != self.cfg.num_clients
                || retry_bytes.len() != self.cfg.num_clients
                || failures.len() != self.cfg.num_clients
            {
                bail!(
                    "checkpoint tracks retry telemetry for {} clients, server has {}",
                    retries.len(),
                    self.cfg.num_clients
                );
            }
            stats_restored.retries = retries;
            stats_restored.retry_secs_sum = retry_secs_sum;
            stats_restored.retry_bytes = retry_bytes;
            stats_restored.failures = failures;
            let has_faults = r.at("faults.flag").take(1)?[0];
            if has_faults == 1 {
                let down_until = r.at("faults.down_until").f64s()?;
                if down_until.len() != self.cfg.num_clients {
                    bail!(
                        "checkpoint tracks outage windows for {} clients, server has {}",
                        down_until.len(),
                        self.cfg.num_clients
                    );
                }
                let mut counters = [0u64; 8];
                for (i, name) in [
                    "faults.drops",
                    "faults.outages",
                    "faults.corrupts",
                    "faults.retries",
                    "faults.perm_failures",
                    "faults.quorum_degraded",
                    "faults.orphan_up_bytes",
                    "faults.orphan_down_bytes",
                ]
                .into_iter()
                .enumerate()
                {
                    counters[i] = r.at(name).u64()?;
                }
                fault_restore = Some((down_until, counters));
            }
        }

        // ---- apply phase: every read succeeded, nothing below fails --
        self.round = round;
        self.opt.restore(x, m, v, last_delta, step);
        self.luar.scores = luar_scores;
        self.luar.observed = luar_observed;
        self.luar.prev_update = luar_prev_update;
        self.luar.recycle_set = luar_recycle_set;
        self.luar.staleness = luar_staleness;
        self.comm.rounds = comm_rounds;
        self.comm.up_bytes = comm_up_bytes;
        self.comm.down_bytes = comm_down_bytes;
        self.comm.fedavg_up_bytes = comm_fedavg;
        self.comm.layer_upload_rounds = comm_layer_rounds;
        self.set_rng_state([rng_st[0], rng_st[1], rng_st[2], rng_st[3]]);
        if let Some((sim_seconds, ema, failed, dropped)) = v2_scalars {
            self.sim_seconds = sim_seconds;
            self.train_loss_ema = ema;
            self.failed_clients = failed;
            self.dropped_stragglers = dropped;
            self.async_rt = async_restored;
        }
        // Pre-v3 files carry no references or delta counters: a
        // delta-framed server resumes with empty ones (trajectory
        // unchanged, post-resume first contacts count as fallbacks).
        if let Some(st) = &mut self.delta_state {
            *st = super::DeltaFrameState::new(self.cfg.num_clients);
        }
        (self.comm.delta_bytes_saved, self.comm.delta_fallbacks) = delta_counters;
        if let Some((bcast_refs, down_versions, up_refs)) = delta_restore {
            // References are ledger-only: a server running without
            // `net.delta_frames` ignores them (the restored comm
            // counters keep the ledger history either way).
            if let Some(st) = &mut self.delta_state {
                st.restore(bcast_refs, down_versions, up_refs);
            }
        }
        // Dispatch-side memos are derived state: drop them so the first
        // post-restore dispatch rebuilds against the restored model.
        // (The cohort memo is the exception — under `speed` it depends
        // on the telemetry at first sampling and must be restored, not
        // resampled.) Pre-v4 files resume with a cold telemetry table.
        self.async_bcast = None;
        self.async_cohort = cohort_restored;
        self.sampler_stats = stats_restored;
        // Fault-plan cursor: rebuilt fresh from the config (same seed,
        // same plan), then the persisted windows/counters land on top.
        // Pre-v5 files (or a checkpoint saved with faults off) resume
        // with a pristine plan; fault state in the file is ignored by a
        // server whose config runs without faults.
        self.consecutive_failed_dispatches = 0;
        if let Some(plan) = &mut self.faults {
            *plan = FaultPlan::new(self.cfg.net.faults, self.cfg.num_clients, self.cfg.seed);
            if let Some((down_until, c)) = fault_restore {
                plan.down_until = down_until;
                plan.drops = c[0];
                plan.outages = c[1];
                plan.corrupts = c[2];
                plan.retries = c[3];
                plan.perm_failures = c[4];
                plan.quorum_degraded = c[5];
                plan.orphan_up_bytes = c[6];
                plan.orphan_down_bytes = c[7];
            }
        }
        Ok(())
    }
}

fn write_ref_state(w: &mut Writer, r: &RefState) {
    w.u64(r.version);
    w.f32s(&r.data);
    w.u64s(&r.layer_hash);
}

fn read_ref_state(r: &mut Reader) -> Result<RefState> {
    r.at("delta.ref");
    Ok(RefState { version: r.u64()?, data: r.f32s()?, layer_hash: r.u64s()? })
}

fn write_payload(w: &mut Writer, p: &UploadPayload) {
    w.u64(p.client as u64);
    w.u64(p.version);
    w.u64(p.gen);
    w.f32(p.loss);
    w.u64(p.frame_len);
    w.u64(p.bcast_len);
    w.f32s(&p.delta);
}

fn read_payload(r: &mut Reader) -> Result<UploadPayload> {
    r.at("async.payload");
    Ok(UploadPayload {
        client: r.u64()? as usize,
        version: r.u64()?,
        gen: r.u64()?,
        loss: r.f32()?,
        frame_len: r.u64()?,
        bcast_len: r.u64()?,
        delta: r.f32s()?,
    })
}

fn write_async_state(w: &mut Writer, st: &AsyncState) {
    w.u64(st.version);
    w.f64(st.now);
    w.f64(st.last_agg_t);
    w.u64(st.seq);
    w.u64(st.down_since_agg);
    w.u64(st.sample_gen);
    w.u64(st.sample_idx);
    w.u64s(&st.client_version);
    w.u64(st.events.len() as u64);
    for &(t, seq) in &st.events {
        w.f64(t);
        w.u64(seq);
    }
    w.u64(st.pending.len() as u64);
    for (seq, p) in &st.pending {
        w.u64(*seq);
        write_payload(w, p);
    }
    w.u64(st.buffer.len() as u64);
    for a in &st.buffer {
        write_payload(w, &a.payload);
        w.f64(a.t);
        w.u64(a.version_gap);
        w.f32(a.weight);
    }
}

fn read_async_state(r: &mut Reader) -> Result<AsyncState> {
    r.at("async.state");
    let mut st = AsyncState {
        version: r.u64()?,
        now: r.f64()?,
        last_agg_t: r.f64()?,
        seq: r.u64()?,
        down_since_agg: r.u64()?,
        sample_gen: r.u64()?,
        sample_idx: r.u64()?,
        client_version: r.u64s()?,
        ..Default::default()
    };
    let n_events = r.u64()? as usize;
    st.events.reserve(n_events);
    for _ in 0..n_events {
        let t = r.f64()?;
        let seq = r.u64()?;
        st.events.push((t, seq));
    }
    let n_pending = r.u64()? as usize;
    st.pending.reserve(n_pending);
    for _ in 0..n_pending {
        let seq = r.u64()?;
        st.pending.push((seq, read_payload(r)?));
    }
    let n_buf = r.u64()? as usize;
    st.buffer.reserve(n_buf);
    for _ in 0..n_buf {
        let payload = read_payload(r)?;
        let t = r.f64()?;
        let version_gap = r.u64()?;
        let weight = r.f32()?;
        st.buffer.push(AbsorbedUpload { payload, t, version_gap, weight });
    }
    Ok(st)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn truncation_errors_name_the_field() {
        let mut w = Writer::new();
        w.str("hello");
        w.u64s(&[1, 2, 3]);
        w.f64(2.5);
        let full = w.buf.clone();
        // the complete buffer parses
        let mut r = Reader { buf: &full, pos: 0, field: "start" };
        assert_eq!(r.at("greeting").str().unwrap(), "hello");
        assert_eq!(r.at("numbers").u64s().unwrap(), vec![1, 2, 3]);
        assert_eq!(r.at("clock").f64().unwrap(), 2.5);
        // every proper prefix fails, naming the field being decoded:
        // greeting = 4-byte len + 5 bytes, numbers = 8-byte count +
        // 24 bytes, clock = 8 bytes
        for cut in 0..full.len() {
            let mut r = Reader { buf: &full[..cut], pos: 0, field: "start" };
            let err = (|| -> Result<()> {
                r.at("greeting").str()?;
                r.at("numbers").u64s()?;
                r.at("clock").f64()?;
                Ok(())
            })()
            .unwrap_err()
            .to_string();
            assert!(err.contains("truncated at field `"), "cut={cut}: {err}");
            let expect =
                if cut < 9 { "`greeting`" } else if cut < 41 { "`numbers`" } else { "`clock`" };
            assert!(err.contains(expect), "cut={cut}: {err}");
        }
    }
}
