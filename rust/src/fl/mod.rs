//! The FL coordinator — Algorithm 2 (FedLUAR) with every baseline
//! method and server optimizer plugged into the same control flow,
//! which is now split into two halves:
//!
//! **Dispatch** (`client_upload`): sample a client, hand it the
//! broadcast (or the optimizer's per-client variant), run tau local
//! SGD steps through the AOT train graph, zero the R_t layers (LUAR)
//! or lossily compress the update (baselines), serialize the upload
//! through `net::wire` (byte-exact frames), and decode it server-side
//! — the ledger counts `frame.len()`, the aggregate consumes the
//! decoded bytes.
//!
//! **Absorb** (`finish_aggregation`): given the uploads that made an
//! aggregate — with their inclusion mask and (staleness-discounted)
//! weights — run the Pallas-backed agg graph (exactly FedAvg's mean,
//! which also returns the Eq. 1 norms for free) or the weighted
//! pure-Rust fallback, compose \hat{Delta}_t (Alg. 1), measure kappa,
//! resample R_{t+1}, apply the server optimizer, and record bytes /
//! wall-clock / staleness metrics.
//!
//! Who drives the halves depends on `net.round_mode`:
//!
//! * `sync` / `deadline` / `buffered` — `run_sync_round`: one cohort
//!   is dispatched, the per-round scheduler (`net::sched`) decides who
//!   makes the aggregate, and one absorb closes the round. Byte-
//!   identical to the pre-split round loop (golden-pinned in
//!   `tests/integration_async.rs`).
//! * `async:c=N,s=...` — `run_async_round`: no barrier at all. An
//!   `AsyncRuntime` (see `async_rt.rs`) keeps N clients in flight over
//!   a persistent event queue; every absorbed upload carries a
//!   measured model-version gap that the staleness discount turns
//!   into its aggregation weight; a version closes every
//!   `active_clients` absorbs, at which point recycled layers age by
//!   the mean version gap (not by round count) and the freed slots
//!   refill immediately with freshly sampled clients.
//!
//! `checkpoint.rs` adds save/resume of the full server state,
//! including the async runtime's in-flight queue (format v2) and the
//! residual-framing references (format v3).
//!
//! With `net.delta_frames` on, both directions re-frame against
//! per-client reference snapshots (`DeltaFrameState`, wire flavor
//! `Delta`): uplinks against the client's previous decoded upload,
//! downlinks against the params the client last received. Framing is
//! lossless and ledger-only — trajectories and the simulated clock are
//! bit-identical to dense runs (see docs/wire.md).

mod async_rt;
mod checkpoint;

pub use async_rt::{AbsorbedUpload, AggBatch, AsyncRuntime, AsyncState, UploadPayload};

use crate::comm::CommAccountant;
use crate::compress::{self, UpdateCompressor};
use crate::config::{Method, RunConfig};
use crate::data::FedDataset;
use crate::luar::{DeltaController, LuarState};
use crate::metrics::{AbsorbRecord, History, RoundRecord};
use crate::model::{artifacts_dir, ModelMeta};
use crate::net::{
    links, sched, wire, ChainOutcome, ClientStats, FaultPlan, NetSim, RoundMode, SamplerCfg,
    Staleness,
};
use crate::obs;
use crate::optim::ServerOpt;
use crate::rng::Rng;
use crate::runtime::Engine;
use crate::tensor;
use anyhow::{Context, Result};

/// Everything one FL run needs; drive with `run()` or `run_round()`.
pub struct Server {
    pub engine: Engine,
    pub cfg: RunConfig,
    pub ds: FedDataset,
    pub opt: ServerOpt,
    pub luar: LuarState,
    compressor: Box<dyn UpdateCompressor>,
    pub comm: CommAccountant,
    /// Per-client links + round-closing policy (the net: block).
    pub net: NetSim,
    pub history: History,
    /// Per-client previous local model (MOON-lite), populated lazily.
    prev_local: Vec<Option<Vec<f32>>>,
    rng: Rng,
    pub round: usize,
    sim_seconds: f64,
    train_loss_ema: f64,
    /// Last per-layer norms (Figure 1 diagnostics).
    pub last_update_ssq: Vec<f32>,
    pub last_weight_ssq: Vec<f32>,
    /// Kappa-adaptive recycling depth (only for `luar:delta=auto`).
    pub delta_ctl: Option<DeltaController>,
    /// Clients that failed before upload (failure injection), total.
    pub failed_clients: u64,
    /// Uplink frame lengths of the most recent round, per active slot
    /// (tests assert ledger == the sum of these).
    pub last_frame_lens: Vec<u64>,
    /// Uploads that transmitted but missed the round close (deadline
    /// mode drops), total.
    pub dropped_stragglers: u64,
    /// Barrier-free scheduling state; `Some` once an async round ran.
    pub async_rt: Option<AsyncRuntime>,
    /// Async dispatch memo (the ROADMAP-flagged hot path): broadcast
    /// params, FedProx anchor, encoded downlink frame, and the upload
    /// set are invariant within a model version — encode them once per
    /// version instead of once per dispatch. Derived state only:
    /// rebuilt lazily, cleared on checkpoint load, never serialized.
    async_bcast: Option<AsyncBcastCache>,
    /// The generation's failure-filtered cohort, sampled once per
    /// generation. Deterministic in (gen, seed) under `uniform`, but
    /// under `speed` it also reads the mutable telemetry table — so
    /// unlike `async_bcast` this memo IS serialized (checkpoint v4)
    /// and restored rather than resampled on resume.
    async_cohort: Option<(u64, Vec<usize>)>,
    /// Residual-framing references (`Some` iff `net.delta_frames`):
    /// per-client uplink snapshots, the broadcast ring, and the round's
    /// savings/fallback/gap accumulators drained by the absorb half.
    pub delta_state: Option<DeltaFrameState>,
    /// Per-client participation + upload-latency telemetry, recorded on
    /// every dispatch/absorb regardless of policy (so `speed` sampling
    /// is measurable before it is enabled). Read by the speed-biased
    /// cohort draw, exported as `*_clients.csv`, persisted in
    /// checkpoint format v4.
    pub sampler_stats: ClientStats,
    /// Deterministic fault injection (`Some` iff `net.faults` is not
    /// `off`): per-(client, version, attempt) seeded fault chains,
    /// open outage windows, and cumulative injection counters.
    /// Persisted in checkpoint format v5; `None` keeps every fault
    /// path unentered so `faults = off` runs bit-identically to builds
    /// without the subsystem.
    pub faults: Option<FaultPlan>,
    /// Async liveness guard: consecutive dispatches whose whole fault
    /// chain failed. Reset on every delivery; bounded so a fault plan
    /// that kills *every* upload surfaces a recoverable error instead
    /// of spinning the dispatch loop forever. Transient, never
    /// serialized.
    consecutive_failed_dispatches: u64,
}

/// Bail out of the async dispatch loop after this many permanently
/// failed chains in a row (no delivery in between): with any
/// survivable fault probability the run would have progressed long
/// before this, so hitting the bound means the plan admits no
/// progress at all.
const MAX_CONSECUTIVE_FAILED_DISPATCHES: u64 = 10_000;

/// Broadcast versions kept as downlink delta references; older clients
/// fall back to self-contained frames.
pub const DELTA_BCAST_RING: usize = 4;
/// Maximum model-version gap an uplink reference may span before the
/// client re-sends self-contained (bounds reference memory staleness).
pub const DELTA_MAX_REF_GAP: u64 = 8;

/// One reference snapshot for residual framing: the values a delta
/// frame is coded against, the model version they belong to, and the
/// per-layer FNV hashes the wire check validates.
#[derive(Debug, Clone, PartialEq)]
pub struct RefState {
    pub version: u64,
    pub data: Vec<f32>,
    pub layer_hash: Vec<u64>,
}

impl RefState {
    pub fn new(version: u64, data: Vec<f32>, meta: &ModelMeta) -> Self {
        let layer_hash = wire::layer_hashes(&data, meta);
        RefState { version, data, layer_hash }
    }
}

/// Server-side residual-framing bookkeeping. Delta framing is
/// *accounting-transparent*: the link schedule is always timed against
/// self-contained frame lengths, so model trajectories and simulated
/// clocks are bit-identical to dense-framed runs — only the comm
/// ledger's bytes shrink (asserted in `tests/integration_delta.rs`).
#[derive(Debug, Clone)]
pub struct DeltaFrameState {
    /// Per-client uplink reference: the client's previous decoded
    /// upload (what both ends can reconstruct without extra traffic).
    up_refs: Vec<Option<RefState>>,
    /// Recent broadcast params keyed by model version (downlink
    /// references), newest last, capped at `DELTA_BCAST_RING`.
    bcast_refs: Vec<RefState>,
    /// Last model version each client received (`u64::MAX` = never).
    down_versions: Vec<u64>,
    /// Ledger lengths already encoded this broadcast version, keyed by
    /// reference version — the broadcast delta is encoded once per
    /// (version, ref_version) pair, not once per client.
    bcast_memo: Option<(u64, Vec<(u64, u64, bool)>)>,
    round_saved: u64,
    round_fallbacks: u64,
    gap_sum: f64,
    gap_count: u64,
}

impl DeltaFrameState {
    pub fn new(num_clients: usize) -> Self {
        DeltaFrameState {
            up_refs: vec![None; num_clients],
            bcast_refs: Vec::new(),
            down_versions: vec![u64::MAX; num_clients],
            bcast_memo: None,
            round_saved: 0,
            round_fallbacks: 0,
            gap_sum: 0.0,
            gap_count: 0,
        }
    }

    /// Snapshot the broadcast params for `version` as a downlink
    /// reference (idempotent per version; evicts the oldest entry).
    pub fn note_bcast(&mut self, version: u64, params: &[f32], meta: &ModelMeta) {
        if self.bcast_refs.iter().any(|r| r.version == version) {
            return;
        }
        self.bcast_refs.push(RefState::new(version, params.to_vec(), meta));
        if self.bcast_refs.len() > DELTA_BCAST_RING {
            self.bcast_refs.remove(0);
        }
        if !matches!(self.bcast_memo, Some((v, _)) if v == version) {
            self.bcast_memo = Some((version, Vec::new()));
        }
    }

    /// Ledger bytes of this client's downlink at `version`: the delta
    /// frame against the params the client last saw when both snapshots
    /// are still in the ring (memoized per reference version), else the
    /// self-contained length `self_len` (counted as a fallback).
    pub fn bcast_ledger_len(
        &mut self,
        client: usize,
        version: u64,
        meta: &ModelMeta,
        recycle_set: &[usize],
        self_len: u64,
    ) -> Result<u64> {
        let ref_version = self.down_versions[client];
        self.down_versions[client] = version;
        let have_refs = ref_version != u64::MAX
            && self.bcast_refs.iter().any(|r| r.version == ref_version)
            && self.bcast_refs.iter().any(|r| r.version == version);
        if !have_refs {
            self.round_fallbacks += 1;
            return Ok(self_len);
        }
        if !matches!(self.bcast_memo, Some((v, _)) if v == version) {
            self.bcast_memo = Some((version, Vec::new()));
        }
        let memo_hit = self
            .bcast_memo
            .as_ref()
            .and_then(|(_, m)| m.iter().find(|&&(rv, _, _)| rv == ref_version).copied());
        let (len, is_delta) = match memo_hit {
            Some((_, len, is_delta)) => (len, is_delta),
            None => {
                let cur = self.bcast_refs.iter().find(|r| r.version == version).unwrap();
                let reference =
                    self.bcast_refs.iter().find(|r| r.version == ref_version).unwrap();
                let frame = wire::encode_broadcast_delta(
                    &cur.data,
                    meta,
                    recycle_set,
                    &reference.data,
                    ref_version,
                )?;
                let dlen = frame.len() as u64;
                let (len, is_delta) =
                    if dlen < self_len { (dlen, true) } else { (self_len, false) };
                if let Some((_, m)) = &mut self.bcast_memo {
                    m.push((ref_version, len, is_delta));
                }
                (len, is_delta)
            }
        };
        if is_delta {
            self.round_saved += self_len - len;
            self.gap_sum += (version - ref_version) as f64;
            self.gap_count += 1;
        } else {
            self.round_fallbacks += 1;
        }
        Ok(len)
    }

    /// The uplink reference version usable for `client` at `version`,
    /// if any (present and within `DELTA_MAX_REF_GAP`).
    pub fn usable_up_ref_version(&self, client: usize, version: u64) -> Option<u64> {
        let r = self.up_refs[client].as_ref()?;
        (version.saturating_sub(r.version) <= DELTA_MAX_REF_GAP).then_some(r.version)
    }

    /// The uplink reference snapshot for `client`.
    pub fn up_ref(&self, client: usize) -> Option<&RefState> {
        self.up_refs[client].as_ref()
    }

    /// Install `update` (the decoded upload at `version`) as the
    /// client's next uplink reference.
    pub fn record_upload(&mut self, client: usize, version: u64, update: &[f32], meta: &ModelMeta) {
        self.up_refs[client] = Some(RefState::new(version, update.to_vec(), meta));
    }

    /// Account one uplink transmission: `self_len` is the dense-subset
    /// baseline; `ledger_len` what the ledger records; `gap` the
    /// reference version gap of a delta frame (`None` = fallback).
    pub fn note_uplink(&mut self, self_len: u64, ledger_len: u64, gap: Option<u64>) {
        match gap {
            Some(g) => {
                self.round_saved += self_len.saturating_sub(ledger_len);
                self.gap_sum += g as f64;
                self.gap_count += 1;
            }
            None => self.round_fallbacks += 1,
        }
    }

    /// Drain the round's accumulators: (bytes saved, fallbacks, mean
    /// reference gap of the round's delta frames).
    pub fn drain_round(&mut self) -> (u64, u64, f64) {
        let saved = std::mem::take(&mut self.round_saved);
        let fallbacks = std::mem::take(&mut self.round_fallbacks);
        let gap =
            if self.gap_count == 0 { 0.0 } else { self.gap_sum / self.gap_count as f64 };
        self.gap_sum = 0.0;
        self.gap_count = 0;
        (saved, fallbacks, gap)
    }

    /// Checkpoint access: broadcast ring, per-client downlink versions,
    /// per-client uplink references.
    pub(crate) fn snapshot(&self) -> (&[RefState], &[u64], &[Option<RefState>]) {
        (&self.bcast_refs, &self.down_versions, &self.up_refs)
    }

    pub(crate) fn restore(
        &mut self,
        bcast_refs: Vec<RefState>,
        down_versions: Vec<u64>,
        up_refs: Vec<Option<RefState>>,
    ) {
        self.bcast_refs = bcast_refs;
        self.down_versions = down_versions;
        self.up_refs = up_refs;
        self.bcast_memo = None;
    }
}

/// Per-model-version dispatch artifacts reused across async dispatches.
struct AsyncBcastCache {
    version: u64,
    /// Shared broadcast params (`None` when the optimizer mutates the
    /// broadcast per client, e.g. FedMut).
    shared: Option<Vec<f32>>,
    /// FedProx global anchor (populated only when mu_global > 0).
    anchor: Option<Vec<f32>>,
    /// Encoded downlink frame (params + the R_t layer-id list).
    frame: wire::WireFrame,
    /// Layers on the wire this version (R_t's complement for LUAR).
    upload_layers: Vec<usize>,
}

impl Server {
    /// Build a server from a config, loading artifacts from the default
    /// directory.
    pub fn new(cfg: RunConfig) -> Result<Self> {
        let meta = ModelMeta::load(artifacts_dir(), &cfg.model)?;
        Self::with_meta(cfg, meta)
    }

    pub fn with_meta(cfg: RunConfig, meta: ModelMeta) -> Result<Self> {
        let engine = Engine::load(meta)?;
        let meta = &engine.meta;
        let spec = cfg.synth_spec(&meta.input_shape, meta.num_classes, meta.is_text());
        let ds = FedDataset::new(
            spec,
            cfg.num_clients,
            cfg.per_client,
            cfg.alpha,
            cfg.test_size,
            cfg.seed,
        );
        let init = meta.load_init()?;
        let opt = ServerOpt::new(cfg.server_opt.clone(), init);
        let luar = LuarState::new(meta.num_layers(), meta.dim);
        let compressor = match (&cfg.method, &cfg.luar_compress) {
            (Method::Luar { .. }, Some(base)) => compress::build(base),
            _ => compress::build(&cfg.method),
        };
        let comm = CommAccountant::new(meta.num_layers());
        let num_layers = meta.num_layers();
        let delta_ctl = match &cfg.method {
            Method::Luar { adaptive: true, .. } => Some(DeltaController::new(num_layers)),
            _ => None,
        };
        let prev_local = vec![None; cfg.num_clients];
        let rng = Rng::seed_from_u64(cfg.seed ^ 0xf1_f1f1);
        let net = NetSim::new(cfg.net.clone(), cfg.num_clients, cfg.seed);
        Ok(Server {
            engine,
            ds,
            opt,
            luar,
            compressor,
            comm,
            net,
            history: History::default(),
            prev_local,
            rng,
            round: 0,
            sim_seconds: 0.0,
            train_loss_ema: f64::NAN,
            last_update_ssq: vec![0.0; num_layers],
            last_weight_ssq: vec![0.0; num_layers],
            delta_ctl,
            failed_clients: 0,
            last_frame_lens: Vec::new(),
            dropped_stragglers: 0,
            async_rt: None,
            async_bcast: None,
            async_cohort: None,
            delta_state: cfg.net.delta_frames.then(|| DeltaFrameState::new(cfg.num_clients)),
            sampler_stats: ClientStats::new(cfg.num_clients),
            faults: (!cfg.net.faults.is_off())
                .then(|| FaultPlan::new(cfg.net.faults, cfg.num_clients, cfg.seed)),
            consecutive_failed_dispatches: 0,
            cfg,
        })
    }

    pub fn meta(&self) -> &ModelMeta {
        &self.engine.meta
    }

    /// Run the full configured schedule; returns the history.
    pub fn run(&mut self) -> Result<&History> {
        while self.round < self.cfg.rounds {
            self.run_round()?;
        }
        Ok(&self.history)
    }

    /// One server aggregation: a communication round (Alg. 2 lines
    /// 4–12) in the barrier modes, one closed model version in async
    /// mode.
    pub fn run_round(&mut self) -> Result<()> {
        match self.cfg.net.round_mode {
            RoundMode::Async { .. } => self.run_async_round(),
            _ => self.run_sync_round(),
        }
    }

    // ------------------------------------------------------------------
    // dispatch half
    // ------------------------------------------------------------------

    /// One client's dispatch: local training through the AOT graph,
    /// LUAR layer skipping / baseline compression, wire encode, and
    /// the server-side decode. Returns (decoded update, ledger frame
    /// bytes, self-contained frame bytes, training loss, sealed frame)
    /// — the two lengths differ only under `net.delta_frames`, where
    /// the ledger counts the residual frame but the link schedule is
    /// still timed against the self-contained one. `t` indexes the
    /// local-batch schedule (the round in barrier modes, the sample
    /// generation in async mode); `version` keys the residual
    /// references (== t in barrier modes, the runtime's model version
    /// in async mode).
    ///
    /// The sealed frame is `Some` only under fault injection: the
    /// self-contained frame plus the `wire` integrity trailer (the
    /// bytes a corruption fault flips), with both returned lengths
    /// grown by `wire::TRAILER_LEN` — without faults the frame bytes
    /// and both lengths are exactly the legacy values.
    #[allow(clippy::too_many_arguments)]
    fn client_upload(
        &mut self,
        client: usize,
        slot: usize,
        t: usize,
        version: u64,
        lr: f32,
        shared_broadcast: Option<&[f32]>,
        anchor_g: Option<&[f32]>,
        upload_layers: &[usize],
        meta: &ModelMeta,
    ) -> Result<(Vec<f32>, u64, u64, f32, Option<Vec<u8>>)> {
        let _sp = obs::span("fl.client_upload");
        let mu_g = self.cfg.client_opt.mu_global;
        let mu_p = self.cfg.client_opt.mu_prev;
        let wd = self.cfg.weight_decay;
        let is_luar = matches!(self.cfg.method, Method::Luar { .. });
        let has_compose = self.cfg.luar_compress.is_some();

        let start = match shared_broadcast {
            Some(b) => b.to_vec(),
            None => self.opt.broadcast(slot),
        };
        let (feats, labels) = self.ds.client_batches(client, t, meta.tau, meta.batch);
        let out = {
            let _t = obs::span("engine.train");
            self.engine.train_round(
                &start,
                anchor_g,
                self.prev_local[client].as_deref().filter(|_| mu_p > 0.0),
                &feats,
                &labels,
                lr,
                mu_g,
                mu_p,
                wd,
            )?
        };
        let mut delta = out.delta;
        if mu_p > 0.0 {
            let mut local = start.clone();
            tensor::axpy(1.0, &delta, &mut local);
            self.prev_local[client] = Some(local);
        }
        let hint;
        if is_luar {
            // Clients omit R_t layers from the upload (Alg. 1 line 2).
            for &l in &self.luar.recycle_set {
                let lm = &meta.layers[l];
                delta[lm.offset..lm.offset + lm.size].iter_mut().for_each(|v| *v = 0.0);
            }
            if has_compose {
                // Table 3 composition: baseline compression on the
                // uploaded layers.
                self.compressor.compress(client, &mut delta, meta, t, &mut self.rng);
                // re-zero recycled layers (compressors like binarize
                // may have produced nonzeros there)
                for &l in &self.luar.recycle_set {
                    let lm = &meta.layers[l];
                    delta[lm.offset..lm.offset + lm.size].iter_mut().for_each(|v| *v = 0.0);
                }
                hint = self.compressor.wire_hint();
            } else {
                hint = wire::WireHint::Dense;
            }
        } else {
            self.compressor.compress(client, &mut delta, meta, t, &mut self.rng);
            hint = self.compressor.wire_hint();
        }
        // Serialize exactly what crosses the wire, then decode it
        // server-side: the ledger counts frame.len() (headers,
        // layer-id lists, and index overheads included), and the
        // aggregate consumes the decoded bytes.
        let frame = wire::encode_update(&delta, meta, upload_layers, &hint)?;
        let mut self_len = frame.len() as u64;
        let mut ledger_len = self_len;
        let mut delta_srv = match wire::decode_update(frame.as_bytes(), meta)? {
            wire::Decoded::Vector(v) => v,
            // LBGM scalar: the server's per-client anchor times the
            // coefficient — which is the in-place reconstruction.
            wire::Decoded::Scalar(_) => delta,
        };
        // Residual framing (delta_frames): re-frame a dense upload
        // against the client's previous decoded upload when that
        // reference is fresh enough, and make the reframed (lossless)
        // decode the aggregated one. Everything else — lossy flavors,
        // first contact, stale references — ships self-contained and
        // counts a fallback.
        if let Some(st) = &self.delta_state {
            let dense = matches!(hint, wire::WireHint::Dense);
            let usable = dense
                .then(|| st.usable_up_ref_version(client, version))
                .flatten();
            if let Some(ref_version) = usable {
                let reference = st.up_ref(client).expect("usable ref exists").data.clone();
                let dframe = wire::encode_update_delta(
                    &delta_srv,
                    meta,
                    upload_layers,
                    &reference,
                    ref_version,
                )?;
                if (dframe.len() as u64) < self_len {
                    let (decoded, _) =
                        wire::decode_update_delta(dframe.as_bytes(), meta, &reference)?;
                    ledger_len = dframe.len() as u64;
                    delta_srv = decoded;
                    let st = self.delta_state.as_mut().expect("checked above");
                    st.note_uplink(self_len, ledger_len, Some(version - ref_version));
                } else {
                    let st = self.delta_state.as_mut().expect("checked above");
                    st.note_uplink(self_len, self_len, None);
                }
            } else {
                let st = self.delta_state.as_mut().expect("checked above");
                st.note_uplink(self_len, self_len, None);
            }
            if dense {
                let st = self.delta_state.as_mut().expect("checked above");
                st.record_upload(client, version, &delta_srv, meta);
            }
        }
        // Fault injection: every upload carries the integrity trailer
        // (length + FNV over the body) so a corruption fault is always
        // caught at decode; both the timed and the ledgered length pay
        // its 12 bytes. `faults = off` never reaches this branch.
        let sealed = if self.faults.is_some() {
            let mut bytes = frame.as_bytes().to_vec();
            wire::seal_trailer(&mut bytes);
            self_len += wire::TRAILER_LEN as u64;
            ledger_len += wire::TRAILER_LEN as u64;
            Some(bytes)
        } else {
            None
        };
        Ok((delta_srv, ledger_len, self_len, out.loss, sealed))
    }

    // ------------------------------------------------------------------
    // absorb half
    // ------------------------------------------------------------------

    /// Aggregate the included uploads, run the LUAR composition and
    /// next selection, apply the server optimizer, and record metrics.
    /// `mean_gap` is the mean model-version gap of the aggregated
    /// uploads (0 in the barrier modes): it ages recycled layers and
    /// feeds the staleness-aware `DeltaController`.
    #[allow(clippy::too_many_arguments)]
    fn finish_aggregation(
        &mut self,
        deltas: &[Vec<f32>],
        included: &[bool],
        weights: &[f32],
        upload_layers: &[usize],
        actives_len: usize,
        loss_sum: f64,
        loss_count: usize,
        up_bytes_total: u64,
        down_total: u64,
        round_secs: f64,
        tail_s: f64,
        arrivals: usize,
        mean_gap: f64,
    ) -> Result<()> {
        let _sp = obs::span("agg.absorb");
        let meta = self.engine.meta.clone();
        let (is_luar, mut luar_delta, luar_scheme, luar_mode) = match self.cfg.method {
            Method::Luar { delta, scheme, mode, .. } => (true, delta, Some(scheme), Some(mode)),
            _ => (false, 0, None, None),
        };
        if let Some(ctl) = &self.delta_ctl {
            luar_delta = ctl.delta;
        }

        // --- aggregation over the included uploads --------------------
        // (Pallas graph when every upload arrived with unit weight and
        // the count matches the lowered shape; weighted pure-Rust
        // fallback for deadline drops and staleness discounts.)
        let mut refs: Vec<&[f32]> = Vec::with_capacity(arrivals);
        let mut agg_weights: Vec<f32> = Vec::with_capacity(arrivals);
        for (slot, d) in deltas.iter().enumerate() {
            if included[slot] {
                refs.push(d.as_slice());
                agg_weights.push(weights[slot]);
            }
        }
        let uniform = agg_weights.iter().all(|&w| w == 1.0);
        let (mut mean, u_ssq, w_ssq) = if uniform && refs.len() == meta.agg_clients {
            let _a = obs::span("engine.agg");
            let out = self.engine.aggregate(&refs, self.opt.params())?;
            (out.mean, out.update_ssq, out.weight_ssq)
        } else {
            // fallback for non-standard client counts / weighted rounds
            let _a = obs::span("agg.fallback");
            let mut mean = vec![0.0f32; meta.dim];
            if uniform {
                tensor::mean_rows_par(&refs, &mut mean);
            } else {
                let wsum: f32 = agg_weights.iter().sum();
                let norm: Vec<f32> = agg_weights.iter().map(|w| w / wsum).collect();
                tensor::weighted_mean_rows(&refs, &norm, &mut mean);
            }
            let params = self.opt.params();
            let mut u_ssq = Vec::with_capacity(meta.num_layers());
            let mut w_ssq = Vec::with_capacity(meta.num_layers());
            for lm in &meta.layers {
                let r = lm.offset..lm.offset + lm.size;
                u_ssq.push(tensor::ssq(&mean[r.clone()]) as f32);
                w_ssq.push(tensor::ssq(&params[r]) as f32);
            }
            (mean, u_ssq, w_ssq)
        };
        self.last_update_ssq = u_ssq.clone();
        self.last_weight_ssq = w_ssq.clone();

        // --- LUAR composition + next selection (Alg. 1) --------------
        let mut kappa = 0.0;
        if is_luar {
            self.luar.update_scores(&u_ssq, &w_ssq);
            // Async absorbs were trained versions ago: recycled layers
            // age by the measured version gap, not by round count.
            self.luar.set_age_step(1 + mean_gap.round() as u32);
            kappa = self.luar.compose_update(&mut mean, &meta, luar_mode.unwrap());
            let next_delta = match &mut self.delta_ctl {
                Some(ctl) => ctl.observe_stale(kappa, mean_gap),
                None => luar_delta,
            };
            let grad_norms: Vec<f64> =
                u_ssq.iter().map(|&s| (s as f64).max(0.0).sqrt()).collect();
            self.luar.select_next(luar_scheme.unwrap(), next_delta, &grad_norms, &mut self.rng);
        }

        // --- residual-framing round accounting ------------------------
        // Drained once per aggregation so the ledger and counters see
        // per-round totals; `delta_ref_gap` is the mean version gap the
        // round's delta frames were coded across (0 without framing).
        let (delta_saved, delta_fallbacks, delta_ref_gap) = match &mut self.delta_state {
            Some(st) => st.drain_round(),
            None => (0, 0, 0.0),
        };
        if delta_saved > 0 {
            obs::counter("fl.delta_bytes_saved", delta_saved);
        }
        if delta_fallbacks > 0 {
            obs::counter("fl.delta_fallbacks", delta_fallbacks);
        }

        // --- per-layer telemetry (Figure 3 / kappa decomposition) -----
        // Scores are the values selection actually used (stale for
        // recycled layers); ages are post-compose; the uploaded flag
        // mirrors the same `upload_layers` the comm ledger records, so
        // layer-CSV upload counts equal `CommAccountant` frequencies.
        if obs::enabled() {
            let wsum: f32 = agg_weights.iter().sum();
            let discount = if agg_weights.is_empty() {
                1.0
            } else {
                (wsum / agg_weights.len() as f32) as f64
            };
            let scores: Vec<f64> = if is_luar {
                self.luar.scores.clone()
            } else {
                u_ssq
                    .iter()
                    .zip(&w_ssq)
                    .map(|(&u, &w)| ((u as f64) / (w as f64).max(1e-24)).sqrt())
                    .collect()
            };
            let ages: Vec<u32> =
                if is_luar { self.luar.staleness.clone() } else { vec![0; meta.num_layers()] };
            obs::record_layer_round(
                self.round,
                &meta,
                upload_layers,
                &scores,
                &ages,
                up_bytes_total,
                discount,
                delta_ref_gap,
            );
            obs::gauge("luar.kappa", kappa);
            obs::observe("agg.mean_gap", mean_gap);
            obs::counter("agg.rounds", 1);
            // Per-client rows (the `*_clients.csv` export): replace the
            // snapshot each aggregation so `obs::finish` writes the
            // final cumulative table.
            obs::record_client_rounds(&self.sampler_stats, &self.net.fleet);
            obs::snapshot(self.round as u64);
        }

        // --- server update --------------------------------------------
        self.opt.apply(&mean);

        // --- accounting -----------------------------------------------
        // Everything measured: the Comm numerator sums uplink frame
        // lengths (dropped stragglers still transmitted — their bytes
        // crossed the wire), the denominator is the measured dense
        // FedAvg frame, and the downlink is the broadcast frame
        // (params + R_t layer-id list) per dispatch.
        let fedavg_frame = wire::dense_frame_len(&meta);
        self.comm.record_wire_round(
            actives_len as u64,
            upload_layers,
            up_bytes_total,
            fedavg_frame,
            down_total,
        );
        self.comm.record_delta(delta_saved, delta_fallbacks);
        self.sim_seconds += round_secs;

        let train_loss = loss_sum / loss_count.max(1) as f64;
        self.train_loss_ema = if self.train_loss_ema.is_nan() {
            train_loss
        } else {
            0.7 * self.train_loss_ema + 0.3 * train_loss
        };

        self.round += 1;
        let last = self.round == self.cfg.rounds;
        if last || (self.cfg.eval_every > 0 && self.round % self.cfg.eval_every == 0) {
            let (test_loss, test_acc) = {
                let _e = obs::span("engine.eval");
                self.engine.eval_dataset(self.opt.params(), &self.ds)?
            };
            self.history.push(RoundRecord {
                round: self.round,
                train_loss,
                test_loss,
                test_acc,
                up_bytes: self.comm.up_bytes,
                comm_ratio: self.comm.comm_ratio(),
                kappa,
                sim_seconds: self.sim_seconds,
                wire_bytes: up_bytes_total,
                tail_s,
                arrivals,
                version_gap: mean_gap,
            });
        }
        Ok(())
    }

    // ------------------------------------------------------------------
    // barrier modes: one cohort, one scheduler round, one absorb
    // ------------------------------------------------------------------

    /// One communication round (Alg. 2 lines 4–12) under the `sync` /
    /// `deadline` / `buffered` round-closing policies.
    fn run_sync_round(&mut self) -> Result<()> {
        let t = self.round;
        let cfg = self.cfg.clone();
        let meta = self.engine.meta.clone();
        let lr = cfg.lr_at(t);
        let a = cfg.active_clients;
        // Cohort draw. `uniform` (and `staleness`, which only shapes
        // async absorption) keep the legacy sample stream literally —
        // the bit-exactness contract the equivalence suite pins. Only
        // `speed` diverges, onto its own salted RNG stream.
        let mut actives = match cfg.net.sampler {
            SamplerCfg::Speed { pow } => {
                crate::net::speed_cohort(&self.sampler_stats, pow, t, a, cfg.seed)
            }
            _ => self.ds.sample_clients(t, a, cfg.seed),
        };
        // Failure injection: each active client independently fails
        // before uploading with the configured probability; the server
        // aggregates over survivors (never fewer than one).
        if cfg.client_failure_rate > 0.0 {
            let mut frng = Rng::seed_from_u64(cfg.seed ^ 0xfa11 ^ ((t as u64) << 16));
            let before = actives.len();
            actives.retain(|_| !frng.gen_bool(cfg.client_failure_rate));
            if actives.is_empty() {
                actives = self.ds.sample_clients(t, 1, cfg.seed ^ 1);
            }
            self.failed_clients += (before - actives.len()) as u64;
        }

        let is_luar = matches!(cfg.method, Method::Luar { .. });

        // --- client phase ---------------------------------------------
        let mu_g = cfg.client_opt.mu_global;
        let anchor_g = if mu_g > 0.0 { Some(self.opt.prox_anchor()) } else { None };
        let shared_broadcast =
            if self.opt.per_client_broadcast() { None } else { Some(self.opt.broadcast(0)) };
        // Layers on the wire this round: R_t's complement for LUAR,
        // everything otherwise. Captured now because select_next will
        // overwrite recycle_set with R_{t+1} in the absorb half.
        let upload_layers: Vec<usize> = if is_luar {
            self.luar.upload_set(meta.num_layers())
        } else {
            (0..meta.num_layers()).collect()
        };
        // Downlink frame: broadcast params + the R_t layer-id list.
        // FedMut's per-client mutations have identical length, so one
        // encode measures every client's download.
        let bcast_params: Vec<f32> = match &shared_broadcast {
            Some(b) => b.clone(),
            None => self.opt.broadcast(0),
        };
        let bcast_frame = wire::encode_broadcast(&bcast_params, &meta, &self.luar.recycle_set)?;
        // Residual framing: snapshot this round's params as a downlink
        // reference, then price each client's download against the
        // params it last received. Ledger-only — the link schedule
        // below is still timed by the self-contained frame.
        let bcast_self_len = bcast_frame.len() as u64;
        let mut down_total = 0u64;
        if let Some(st) = &mut self.delta_state {
            st.note_bcast(t as u64, &bcast_params, &meta);
            for &client in &actives {
                down_total += st.bcast_ledger_len(
                    client,
                    t as u64,
                    &meta,
                    &self.luar.recycle_set,
                    bcast_self_len,
                )?;
            }
        } else {
            down_total = (actives.len() as u64) * bcast_self_len;
        }

        let mut deltas: Vec<Vec<f32>> = Vec::with_capacity(actives.len());
        let mut frame_lens: Vec<u64> = Vec::with_capacity(actives.len());
        let mut timing_lens: Vec<u64> = Vec::with_capacity(actives.len());
        let mut losses: Vec<f64> = Vec::with_capacity(actives.len());
        let mut sealed_frames: Vec<Option<Vec<u8>>> = Vec::with_capacity(actives.len());
        for (slot, &client) in actives.iter().enumerate() {
            let (delta_srv, ledger_len, self_len, loss, sealed) = self.client_upload(
                client,
                slot,
                t,
                t as u64,
                lr,
                shared_broadcast.as_deref(),
                anchor_g.as_deref(),
                &upload_layers,
                &meta,
            )?;
            losses.push(loss as f64);
            frame_lens.push(ledger_len);
            timing_lens.push(self_len);
            deltas.push(delta_srv);
            sealed_frames.push(sealed);
            // Per-client telemetry: the upload latency the link schedule
            // will charge (self-contained length — framing-invariant).
            self.record_dispatch_telemetry(client, self_len);
        }

        // --- network simulation: who makes this round's aggregate? ----
        // Without faults this is exactly the legacy schedule. With a
        // fault plan, each slot's completion time is its whole retry
        // chain collapsed at dispatch time (every per-attempt draw is a
        // pure function of (seed, client, version, attempt), so the
        // chain is known the moment the upload starts): delivered
        // chains arrive at their chain time, permanently failed chains
        // still bound the round's clock (the server waited out their
        // timeouts) but are masked out of the aggregate.
        let mut loss_sum: f64 = losses.iter().sum();
        let mut loss_count = actives.len();
        let mut up_bytes_total: u64 = frame_lens.iter().sum();
        let outcome = if self.faults.is_some() {
            let mut plan = self.faults.take().expect("checked above");
            let mut chains: Vec<ChainOutcome> = Vec::with_capacity(actives.len());
            for (slot, &client) in actives.iter().enumerate() {
                let secs = self.net.client_secs(client, bcast_self_len, timing_lens[slot]);
                let frame = sealed_frames[slot].as_deref().expect("faults imply sealed frames");
                chains.push(plan.attempt_chain(client, t as u64, self.sim_seconds, secs, frame));
            }
            self.faults = Some(plan);
            let times: Vec<f64> = chains.iter().map(|c| c.secs).collect();
            let raw = sched::simulate_round(&cfg.net.round_mode, &times);
            let failed: Vec<bool> = chains.iter().map(|c| !c.survived).collect();
            let outcome = sched::mask_failed_slots(raw, &failed);
            // Re-derive the round's ledger and loss totals from the
            // chains: the final delivery is priced at the ledger frame
            // length, every extra transmitting attempt re-sends the
            // sealed self-contained frame, a chain that never got a
            // byte out (dispatched inside an outage window) pays
            // nothing — and a lost upload's loss value never reaches
            // the server.
            loss_sum = 0.0;
            loss_count = 0;
            up_bytes_total = 0;
            for (slot, ch) in chains.iter().enumerate() {
                let client = actives[slot];
                self.record_chain_telemetry(client, ch);
                if ch.up_bytes > 0 {
                    up_bytes_total += frame_lens[slot] + ch.up_bytes - timing_lens[slot];
                }
                if ch.survived {
                    loss_sum += losses[slot];
                    loss_count += 1;
                }
            }
            let quorum = self.cfg.net.faults.policy.quorum;
            if outcome.aggregated < quorum {
                let plan = self.faults.as_mut().expect("restored above");
                plan.note_quorum_degraded();
                obs::counter("fault.quorum_degraded", 1);
            }
            if outcome.aggregated == 0 {
                // Nothing survived to aggregate: the model stays put,
                // but the round still happened — bytes crossed the
                // wire, the clock ran, and the schedule advances.
                self.last_frame_lens = frame_lens;
                return self.finish_degraded_round(
                    &upload_layers,
                    actives.len(),
                    up_bytes_total,
                    down_total,
                    outcome.round_secs,
                );
            }
            let survivors = failed.iter().filter(|&&f| !f).count();
            self.dropped_stragglers += (survivors - outcome.aggregated) as u64;
            outcome
        } else {
            let outcome = self.net.round(&actives, bcast_self_len, &timing_lens);
            self.dropped_stragglers += (actives.len() - outcome.aggregated) as u64;
            outcome
        };
        for (slot, &client) in actives.iter().enumerate() {
            if outcome.included[slot] {
                self.sampler_stats.record_absorbed(client);
            }
        }
        self.last_frame_lens = frame_lens;

        self.finish_aggregation(
            &deltas,
            &outcome.included,
            &outcome.weights,
            &upload_layers,
            actives.len(),
            loss_sum,
            loss_count,
            up_bytes_total,
            down_total,
            outcome.round_secs,
            outcome.straggler_tail_s,
            outcome.aggregated,
            0.0,
        )
    }

    /// Close a quorum-degraded round in which *no* upload survived its
    /// fault chain: there is nothing to aggregate, so the model, the
    /// server optimizer, and the LUAR selection state stay exactly as
    /// they were (recycled layers age on the next delivered round via
    /// the normal `LuarState` path) — but the round's bytes and clock
    /// are real and the round counter advances so the schedule
    /// terminates.
    fn finish_degraded_round(
        &mut self,
        upload_layers: &[usize],
        actives_len: usize,
        up_bytes_total: u64,
        down_total: u64,
        round_secs: f64,
    ) -> Result<()> {
        let _sp = obs::span("agg.absorb");
        let meta = self.engine.meta.clone();
        let fedavg_frame = wire::dense_frame_len(&meta);
        self.comm.record_wire_round(
            actives_len as u64,
            upload_layers,
            up_bytes_total,
            fedavg_frame,
            down_total,
        );
        self.sim_seconds += round_secs;
        obs::counter("agg.rounds_degraded", 1);
        self.round += 1;
        let last = self.round == self.cfg.rounds;
        if last || (self.cfg.eval_every > 0 && self.round % self.cfg.eval_every == 0) {
            let (test_loss, test_acc) = {
                let _e = obs::span("engine.eval");
                self.engine.eval_dataset(self.opt.params(), &self.ds)?
            };
            let train_loss =
                if self.train_loss_ema.is_nan() { 0.0 } else { self.train_loss_ema };
            self.history.push(RoundRecord {
                round: self.round,
                train_loss,
                test_loss,
                test_acc,
                up_bytes: self.comm.up_bytes,
                comm_ratio: self.comm.comm_ratio(),
                kappa: 0.0,
                sim_seconds: self.sim_seconds,
                wire_bytes: up_bytes_total,
                tail_s: 0.0,
                arrivals: 0,
                version_gap: 0.0,
            });
        }
        Ok(())
    }

    /// Fold one resolved fault chain into the per-client telemetry
    /// table and the obs counters. Retries are recorded separately
    /// from first attempts so `sampler = speed` never double-penalizes
    /// a client for its injected outages.
    fn record_chain_telemetry(&mut self, client: usize, ch: &ChainOutcome) {
        if ch.attempts > 1 {
            self.sampler_stats.record_retries(
                client,
                (ch.attempts - 1) as u64,
                ch.retry_secs,
                ch.retry_up_bytes,
            );
            obs::counter("fault.retries", (ch.attempts - 1) as u64);
        }
        if !ch.survived {
            self.sampler_stats.record_failure(client);
            obs::counter("fault.perm_failures", 1);
        }
        if ch.drops > 0 {
            obs::counter("fault.injected.drop", ch.drops as u64);
        }
        if ch.outages > 0 {
            obs::counter("fault.injected.outage", ch.outages as u64);
        }
        if ch.corrupts > 0 {
            obs::counter("fault.injected.corrupt", ch.corrupts as u64);
        }
    }

    // ------------------------------------------------------------------
    // async mode: persistent queue, per-client versions, no barrier
    // ------------------------------------------------------------------

    /// Drive the barrier-free runtime until one model version closes
    /// (= `active_clients` absorbed uploads). The event loop processes
    /// one completion instant at a time: absorb its arrivals, close a
    /// version if the buffer filled, then refill the freed slots with
    /// freshly sampled clients trained on the newest model.
    fn run_async_round(&mut self) -> Result<()> {
        let (c, goal, staleness) = self.async_mode_params().with_context(|| {
            format!(
                "run_async_round requires the async round mode, got `{}`",
                self.cfg.net.round_mode.name()
            )
        })?;
        if self.async_rt.is_none() {
            if self.cfg.client_failure_rate >= 1.0 {
                anyhow::bail!("async mode cannot progress with client_failure_rate >= 1");
            }
            self.async_rt = Some(
                AsyncRuntime::new(self.cfg.num_clients, c, goal, staleness)
                    .with_stale_cap(self.cfg.net.sampler.stale_cap()),
            );
        }
        loop {
            // Refill to the concurrency cap: each freed slot dispatches
            // the next sampled client immediately over its own link.
            while self.rt()?.wants_dispatch() {
                self.dispatch_next_async()?;
            }
            // Absorb the next completion instant atomically.
            let start = self.rt_mut()?.absorb_instant()?;
            {
                let rt = self.rt()?;
                let in_flight = rt.in_flight();
                let version = rt.version;
                let records: Vec<AbsorbRecord> = rt.buffer[start..]
                    .iter()
                    .enumerate()
                    .map(|(i, u)| AbsorbRecord {
                        version,
                        client: u.payload.client,
                        t: u.t,
                        version_gap: u.version_gap,
                        weight: u.weight,
                        in_flight,
                        queue_depth: start + i + 1,
                    })
                    .collect();
                self.history.absorbs.extend(records);
            }
            if self.rt()?.ready() {
                let batch = self.rt_mut()?.take_aggregation();
                return self.absorb_async_batch(batch);
            }
        }
    }

    /// Close one async model version: unpack the aggregation batch and
    /// run the shared absorb half over it (all uploads included, each
    /// with its staleness weight).
    fn absorb_async_batch(&mut self, batch: AggBatch) -> Result<()> {
        let AggBatch { uploads, round_secs, mut down_bytes, mean_gap, tail_s } = batch;
        let n = uploads.len();
        // Bounded staleness (`sampler = staleness:cap=N`): uploads over
        // the cap are held out of the weighted combine (their bytes and
        // clock are already paid). Without a cap every upload is
        // included — the legacy behavior, bit-exactly. If the cap holds
        // *everything* out, include everything instead: an aggregation
        // is never empty (mirrors `take_aggregation`'s mean fallback).
        let rt = self.rt()?;
        let mut included: Vec<bool> =
            uploads.iter().map(|u| rt.within_cap(u.version_gap)).collect();
        if !included.iter().any(|&i| i) {
            included.iter_mut().for_each(|i| *i = true);
        }
        for (u, &inc) in uploads.iter().zip(&included) {
            if inc {
                self.sampler_stats.record_absorbed(u.payload.client);
            } else {
                self.sampler_stats.record_held(u.payload.client);
                obs::counter("async.held_stale", 1);
            }
        }
        let mut deltas: Vec<Vec<f32>> = Vec::with_capacity(n);
        let mut weights: Vec<f32> = Vec::with_capacity(n);
        let mut frame_lens: Vec<u64> = Vec::with_capacity(n);
        let mut loss_sum = 0.0f64;
        let mut up_bytes_total = 0u64;
        for u in uploads {
            loss_sum += u.payload.loss as f64;
            up_bytes_total += u.payload.frame_len;
            frame_lens.push(u.payload.frame_len);
            weights.push(u.weight);
            deltas.push(u.payload.delta);
        }
        // Orphan bytes: dispatches whose whole fault chain failed since
        // the previous aggregation transmitted real bytes (and received
        // the broadcast) but never landed — the ledger still pays them,
        // in the version that closes next.
        if let Some(plan) = &mut self.faults {
            let (orphan_up, orphan_down) = plan.drain_orphans();
            up_bytes_total += orphan_up;
            down_bytes += orphan_down;
        }
        // Layer bookkeeping uses the upload set at aggregation time;
        // stale uploads encoded an older R and simply carry zeros in
        // the layers recycled since (their frame bytes are measured
        // either way).
        let is_luar = matches!(self.cfg.method, Method::Luar { .. });
        let num_layers = self.engine.meta.num_layers();
        let upload_layers: Vec<usize> = if is_luar {
            self.luar.upload_set(num_layers)
        } else {
            (0..num_layers).collect()
        };
        self.last_frame_lens = frame_lens;
        self.finish_aggregation(
            &deltas,
            &included,
            &weights,
            &upload_layers,
            n,
            loss_sum,
            n,
            up_bytes_total,
            down_bytes,
            round_secs,
            tail_s,
            n,
            mean_gap,
        )
    }

    /// Train and dispatch the next sampled client against the current
    /// model; its completion event lands on the persistent queue after
    /// the client's own link time.
    ///
    /// Broadcast-side state (shared params, prox anchor, encoded
    /// downlink frame, upload set) only changes when a model version
    /// closes (`opt.apply` / `select_next` in `finish_aggregation`), so
    /// it is computed once per version and memoized in `async_bcast`
    /// instead of re-encoded for every dispatch — the ROADMAP-flagged
    /// hot path. FedMut keeps its per-slot broadcast inside
    /// `client_upload` (`shared` stays `None`); only the length-equal
    /// wire frame is shared.
    fn dispatch_next_async(&mut self) -> Result<()> {
        let _sp = obs::span("fl.dispatch");
        let meta = self.engine.meta.clone();
        let (client, gen) = self.next_async_client()?;
        let t = gen as usize;
        let lr = self.cfg.lr_at(t);
        let version = self.rt()?.version;
        let cache_ok = matches!(&self.async_bcast, Some(c) if c.version == version);
        if !cache_ok {
            let mu_g = self.cfg.client_opt.mu_global;
            let anchor = if mu_g > 0.0 { Some(self.opt.prox_anchor()) } else { None };
            let shared =
                if self.opt.per_client_broadcast() { None } else { Some(self.opt.broadcast(0)) };
            let is_luar = matches!(self.cfg.method, Method::Luar { .. });
            let upload_layers: Vec<usize> = if is_luar {
                self.luar.upload_set(meta.num_layers())
            } else {
                (0..meta.num_layers()).collect()
            };
            let bcast_params: Vec<f32> = match &shared {
                Some(b) => b.clone(),
                None => self.opt.broadcast(0),
            };
            let frame = wire::encode_broadcast(&bcast_params, &meta, &self.luar.recycle_set)?;
            obs::counter("fl.bcast_encodes", 1);
            // Residual framing: snapshot this version's params once as
            // a downlink reference (same once-per-version cadence as
            // the encode memo).
            if let Some(st) = &mut self.delta_state {
                st.note_bcast(version, &bcast_params, &meta);
            }
            self.async_bcast =
                Some(AsyncBcastCache { version, shared, anchor, frame, upload_layers });
        }
        // Take/put-back around `client_upload(&mut self)`: an `?` error
        // in between drops the memo, which merely rebuilds next call.
        let cache = self.async_bcast.take().expect("bcast cache populated above");
        // FedMut pairs mutations by parity of the dispatch sequence.
        let slot = self.rt()?.dispatched() as usize;
        let (delta_srv, ledger_len, self_len, loss, sealed) = self.client_upload(
            client,
            slot,
            t,
            version,
            lr,
            cache.shared.as_deref(),
            cache.anchor.as_deref(),
            &cache.upload_layers,
            &meta,
        )?;
        // Downlink ledger bytes for this dispatch (residual framing
        // prices the delta against the client's last-seen version); the
        // link is always timed by the self-contained lengths, so the
        // event schedule is bit-identical to a dense-framed run.
        let bcast_self_len = cache.frame.len() as u64;
        let bcast_ledger = match &mut self.delta_state {
            Some(st) => st.bcast_ledger_len(
                client,
                version,
                &meta,
                &self.luar.recycle_set,
                bcast_self_len,
            )?,
            None => bcast_self_len,
        };
        let secs = self.net.client_secs(client, bcast_self_len, self_len);
        // Per-client telemetry keyed by the same self-contained length
        // the link schedule was just timed with.
        self.record_dispatch_telemetry(client, self_len);
        // Fault chains: the dispatch's whole retry sequence resolves
        // now (pure in (seed, client, version, attempt)). A delivered
        // chain enters the queue with the chain's total seconds and
        // its retransmission bytes on top of the ledger frame; a
        // permanently failed chain never enters the queue — its bytes
        // are booked as orphans for the next aggregation and the slot
        // refills from the sampler stream on the next loop pass.
        let mut duration = secs;
        let mut frame_bytes = ledger_len;
        if self.faults.is_some() {
            let mut plan = self.faults.take().expect("checked above");
            let now = self.rt().map(|rt| rt.now);
            let ch = match now {
                Ok(now) => {
                    let frame = sealed.as_deref().expect("faults imply sealed frames");
                    plan.attempt_chain(client, version, now, secs, frame)
                }
                Err(e) => {
                    self.faults = Some(plan);
                    self.async_bcast = Some(cache);
                    return Err(e);
                }
            };
            self.faults = Some(plan);
            self.record_chain_telemetry(client, &ch);
            // extra transmitting attempts re-send the sealed frame;
            // the successful (or first) one is priced at the ledger
            // length — same accounting as the sync path.
            let transmitted =
                if ch.up_bytes > 0 { ledger_len + ch.up_bytes - self_len } else { 0 };
            if !ch.survived {
                let plan = self.faults.as_mut().expect("restored above");
                plan.note_orphan(transmitted, bcast_ledger);
                self.consecutive_failed_dispatches += 1;
                self.async_bcast = Some(cache);
                if self.consecutive_failed_dispatches > MAX_CONSECUTIVE_FAILED_DISPATCHES {
                    anyhow::bail!(
                        "async dispatch cannot make progress: {} consecutive uploads \
                         exhausted their retry budget under fault plan `{}` — every \
                         chain is failing, so the run would never close another \
                         version",
                        self.consecutive_failed_dispatches,
                        self.cfg.net.faults.spec_string()
                    );
                }
                return Ok(());
            }
            duration = ch.secs;
            frame_bytes = transmitted;
        }
        self.consecutive_failed_dispatches = 0;
        let rt = self.rt_mut()?;
        let payload = UploadPayload {
            client,
            version,
            gen,
            delta: delta_srv,
            loss,
            frame_len: frame_bytes,
            bcast_len: bcast_ledger,
        };
        rt.dispatch(payload, duration);
        self.async_bcast = Some(cache);
        Ok(())
    }

    /// Next client from the deterministic sample stream: generation g
    /// reuses the barrier modes' cohort sampling (and failure
    /// injection — failed clients are skipped at dispatch and the slot
    /// refills from the stream), so `async:c=all` walks exactly the
    /// sync cohorts.
    fn next_async_client(&mut self) -> Result<(usize, u64)> {
        loop {
            let (gen, idx) = {
                let rt = self.rt()?;
                (rt.sample_gen, rt.sample_idx as usize)
            };
            // The post-failure cohort is a pure function of (gen, seed),
            // so it is sampled once per generation and memoized; the old
            // per-call resample walked the same client list `c` times.
            let cached = matches!(&self.async_cohort, Some((g, _)) if *g == gen);
            if !cached {
                let a = self.cfg.active_clients;
                // Same policy split as the sync draw: only `speed`
                // leaves the legacy stream. The memo keys on gen; under
                // `speed` the cohort also depends on the telemetry at
                // first sampling, so checkpoint v4 persists the
                // in-progress cohort to keep resume exact.
                let mut cohort = match self.cfg.net.sampler {
                    SamplerCfg::Speed { pow } => crate::net::speed_cohort(
                        &self.sampler_stats,
                        pow,
                        gen as usize,
                        a,
                        self.cfg.seed,
                    ),
                    _ => self.ds.sample_clients(gen as usize, a, self.cfg.seed),
                };
                if self.cfg.client_failure_rate > 0.0 {
                    let mut frng = Rng::seed_from_u64(self.cfg.seed ^ 0xfa11 ^ (gen << 16));
                    let before = cohort.len();
                    cohort.retain(|_| !frng.gen_bool(self.cfg.client_failure_rate));
                    // Count each generation's failures once, when its
                    // first slot is consumed (a resumed run re-enters
                    // mid-cohort with idx > 0 and must not recount).
                    if idx == 0 {
                        self.failed_clients += (before - cohort.len()) as u64;
                    }
                }
                self.async_cohort = Some((gen, cohort));
            }
            if let Some((_, cohort)) = &self.async_cohort {
                if idx < cohort.len() {
                    let client = cohort[idx];
                    self.rt_mut()?.sample_idx += 1;
                    return Ok((client, gen));
                }
            }
            let rt = self.rt_mut()?;
            rt.sample_gen += 1;
            rt.sample_idx = 0;
        }
    }

    /// The async runtime, or a recoverable error explaining that no
    /// async round has initialized it yet (instead of the old
    /// `unwrap` panics on `async_rt`).
    fn rt(&self) -> Result<&AsyncRuntime> {
        self.async_rt.as_ref().with_context(|| {
            format!(
                "async runtime not initialized (round_mode is `{}`): \
                 `run_async_round` creates it on first use",
                self.cfg.net.round_mode.name()
            )
        })
    }

    fn rt_mut(&mut self) -> Result<&mut AsyncRuntime> {
        let mode = self.cfg.net.round_mode.name();
        self.async_rt.as_mut().with_context(|| {
            format!(
                "async runtime not initialized (round_mode is `{mode}`): \
                 `run_async_round` creates it on first use"
            )
        })
    }

    /// Record one dispatch in the per-client telemetry table and the
    /// link-speed-bucketed upload-latency histograms. Pure arithmetic on
    /// already-computed values — touches no RNG and no clock, so
    /// telemetry-off and telemetry-on runs stay bit-identical.
    fn record_dispatch_telemetry(&mut self, client: usize, self_len: u64) {
        let link = *self.net.fleet.link(client);
        let upload_secs = link.upload_secs(self_len);
        self.sampler_stats.record_dispatch(client, upload_secs, self_len);
        if obs::enabled() {
            let bucket = links::speed_bucket(link.up_bps);
            obs::observe(links::speed_bucket_metric(bucket), upload_secs);
        }
    }

    /// Figure 1 diagnostics: per-layer (name, ||Delta||, ||x||, ratio).
    pub fn layer_stats(&self) -> Vec<(String, f64, f64, f64)> {
        self.engine
            .meta
            .layers
            .iter()
            .enumerate()
            .map(|(l, lm)| {
                let g = (self.last_update_ssq[l] as f64).max(0.0).sqrt();
                let w = (self.last_weight_ssq[l] as f64).max(0.0).sqrt();
                let ratio = if w > 1e-12 { g / w } else { 0.0 };
                (lm.name.clone(), g, w, ratio)
            })
            .collect()
    }

    /// Resolved async-mode parameters (concurrency, aggregation goal,
    /// staleness discount); `None` under the barrier round modes.
    pub(crate) fn async_mode_params(&self) -> Option<(usize, usize, Staleness)> {
        match self.cfg.net.round_mode {
            RoundMode::Async { concurrency, staleness } => {
                let c = if concurrency == 0 { self.cfg.active_clients } else { concurrency };
                Some((c, self.cfg.active_clients, staleness))
            }
            _ => None,
        }
    }

    /// Checkpoint access to the coordinator RNG.
    pub(crate) fn rng_state(&self) -> Vec<u64> {
        self.rng.state().to_vec()
    }

    pub(crate) fn set_rng_state(&mut self, s: [u64; 4]) {
        self.rng = Rng::from_state(s);
    }

    /// Server peak memory model (Table 1): buffers held at aggregation.
    /// Returns (fedavg_bytes, this_method_bytes).
    pub fn memory_footprint(&self) -> (u64, u64) {
        let meta = &self.engine.meta;
        let a = self.cfg.active_clients as u64;
        let full = meta.full_bytes();
        match &self.cfg.method {
            Method::Luar { .. } => {
                let recycled = meta.layer_bytes(&self.luar.recycle_set);
                crate::comm::memory_footprint_bytes(a, full, recycled)
            }
            _ => (a * full, a * full),
        }
    }
}
