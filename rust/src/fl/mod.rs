//! The FL coordinator — Algorithm 2 (FedLUAR) with every baseline
//! method and server optimizer plugged into the same round loop.
//!
//! Round t:
//! 1. sample `a` active clients;
//! 2. broadcast x_t (or the optimizer's per-client variant) + R_t;
//! 3. each client runs tau local SGD steps through the AOT train
//!    graph and returns Delta_t^i; layers in R_t are not uploaded
//!    (LUAR) or the update is lossily compressed (baselines);
//! 4. every upload is serialized through `net::wire` (byte-exact
//!    frames), pushed over the client's own link (`net::links`), and
//!    lands on the server's event queue (`net::sched`); the round mode
//!    decides who makes the aggregate (sync / deadline / buffered);
//! 5. the server decodes the frames and aggregates the survivors via
//!    the Pallas-backed agg graph (exactly FedAvg's mean) which also
//!    returns the Eq. 1 norms for free — or the weighted fallback when
//!    staleness discounts or drop-outs apply;
//! 6. LUAR composes \hat{Delta}_t (Alg. 1), measures kappa, resamples
//!    R_{t+1};
//! 7. the server optimizer applies \hat{Delta}_t;
//! 8. the comm ledger records measured frame bytes; the scheduler's
//!    round time (slowest-survivor semantics) advances sim wall-clock.
//!
//! `checkpoint.rs` adds save/resume of the full server state.

mod checkpoint;

use crate::comm::CommAccountant;
use crate::compress::{self, UpdateCompressor};
use crate::config::{Method, RunConfig};
use crate::data::FedDataset;
use crate::luar::{DeltaController, LuarState};
use crate::metrics::{History, RoundRecord};
use crate::model::{artifacts_dir, ModelMeta};
use crate::net::{wire, NetSim};
use crate::optim::ServerOpt;
use crate::rng::Rng;
use crate::runtime::Engine;
use crate::tensor;
use anyhow::Result;

/// Everything one FL run needs; drive with `run()` or `run_round()`.
pub struct Server {
    pub engine: Engine,
    pub cfg: RunConfig,
    pub ds: FedDataset,
    pub opt: ServerOpt,
    pub luar: LuarState,
    compressor: Box<dyn UpdateCompressor>,
    pub comm: CommAccountant,
    /// Per-client links + round-closing policy (the net: block).
    pub net: NetSim,
    pub history: History,
    /// Per-client previous local model (MOON-lite), populated lazily.
    prev_local: Vec<Option<Vec<f32>>>,
    rng: Rng,
    pub round: usize,
    sim_seconds: f64,
    train_loss_ema: f64,
    /// Last per-layer norms (Figure 1 diagnostics).
    pub last_update_ssq: Vec<f32>,
    pub last_weight_ssq: Vec<f32>,
    /// Kappa-adaptive recycling depth (only for `luar:delta=auto`).
    pub delta_ctl: Option<DeltaController>,
    /// Clients that failed before upload (failure injection), total.
    pub failed_clients: u64,
    /// Uplink frame lengths of the most recent round, per active slot
    /// (tests assert ledger == the sum of these).
    pub last_frame_lens: Vec<u64>,
    /// Uploads that transmitted but missed the round close (deadline
    /// mode drops), total.
    pub dropped_stragglers: u64,
}

impl Server {
    /// Build a server from a config, loading artifacts from the default
    /// directory.
    pub fn new(cfg: RunConfig) -> Result<Self> {
        let meta = ModelMeta::load(artifacts_dir(), &cfg.model)?;
        Self::with_meta(cfg, meta)
    }

    pub fn with_meta(cfg: RunConfig, meta: ModelMeta) -> Result<Self> {
        let engine = Engine::load(meta)?;
        let meta = &engine.meta;
        let spec = cfg.synth_spec(&meta.input_shape, meta.num_classes, meta.is_text());
        let ds = FedDataset::new(
            spec,
            cfg.num_clients,
            cfg.per_client,
            cfg.alpha,
            cfg.test_size,
            cfg.seed,
        );
        let init = meta.load_init()?;
        let opt = ServerOpt::new(cfg.server_opt.clone(), init);
        let luar = LuarState::new(meta.num_layers(), meta.dim);
        let compressor = match (&cfg.method, &cfg.luar_compress) {
            (Method::Luar { .. }, Some(base)) => compress::build(base),
            _ => compress::build(&cfg.method),
        };
        let comm = CommAccountant::new(meta.num_layers());
        let num_layers = meta.num_layers();
        let delta_ctl = match &cfg.method {
            Method::Luar { adaptive: true, .. } => Some(DeltaController::new(num_layers)),
            _ => None,
        };
        let prev_local = vec![None; cfg.num_clients];
        let rng = Rng::seed_from_u64(cfg.seed ^ 0xf1_f1f1);
        let net = NetSim::new(cfg.net.clone(), cfg.num_clients, cfg.seed);
        Ok(Server {
            engine,
            ds,
            opt,
            luar,
            compressor,
            comm,
            net,
            history: History::default(),
            prev_local,
            rng,
            round: 0,
            sim_seconds: 0.0,
            train_loss_ema: f64::NAN,
            last_update_ssq: vec![0.0; num_layers],
            last_weight_ssq: vec![0.0; num_layers],
            delta_ctl,
            failed_clients: 0,
            last_frame_lens: Vec::new(),
            dropped_stragglers: 0,
            cfg,
        })
    }

    pub fn meta(&self) -> &ModelMeta {
        &self.engine.meta
    }

    /// Run the full configured schedule; returns the history.
    pub fn run(&mut self) -> Result<&History> {
        while self.round < self.cfg.rounds {
            self.run_round()?;
        }
        Ok(&self.history)
    }

    /// One communication round (Alg. 2 lines 4–12).
    pub fn run_round(&mut self) -> Result<()> {
        let t = self.round;
        let cfg = self.cfg.clone();
        let meta = self.engine.meta.clone();
        let lr = cfg.lr_at(t);
        let a = cfg.active_clients;
        let mut actives = self.ds.sample_clients(t, a, cfg.seed);
        // Failure injection: each active client independently fails
        // before uploading with the configured probability; the server
        // aggregates over survivors (never fewer than one).
        if cfg.client_failure_rate > 0.0 {
            let mut frng = Rng::seed_from_u64(cfg.seed ^ 0xfa11 ^ (t as u64) << 16);
            let before = actives.len();
            actives.retain(|_| !frng.gen_bool(cfg.client_failure_rate));
            if actives.is_empty() {
                actives = self.ds.sample_clients(t, 1, cfg.seed ^ 1);
            }
            self.failed_clients += (before - actives.len()) as u64;
        }

        let (is_luar, mut luar_delta, luar_scheme, luar_mode) = match cfg.method {
            Method::Luar { delta, scheme, mode, .. } => (true, delta, Some(scheme), Some(mode)),
            _ => (false, 0, None, None),
        };
        if let Some(ctl) = &self.delta_ctl {
            luar_delta = ctl.delta;
        }

        // --- client phase -------------------------------------------------
        let mu_g = cfg.client_opt.mu_global;
        let mu_p = cfg.client_opt.mu_prev;
        let anchor_g = if mu_g > 0.0 { Some(self.opt.prox_anchor()) } else { None };
        let shared_broadcast =
            if self.opt.per_client_broadcast() { None } else { Some(self.opt.broadcast(0)) };
        // Layers on the wire this round: R_t's complement for LUAR,
        // everything otherwise. Captured now because select_next will
        // overwrite recycle_set with R_{t+1} below.
        let upload_layers: Vec<usize> = if is_luar {
            self.luar.upload_set(meta.num_layers())
        } else {
            (0..meta.num_layers()).collect()
        };
        // Downlink frame: broadcast params + the R_t layer-id list.
        // FedMut's per-client mutations have identical length, so one
        // encode measures every client's download.
        let bcast_frame = {
            let tmp;
            let params: &[f32] = match &shared_broadcast {
                Some(b) => b,
                None => {
                    tmp = self.opt.broadcast(0);
                    &tmp
                }
            };
            wire::encode_broadcast(params, &meta, &self.luar.recycle_set)?
        };

        let mut deltas: Vec<Vec<f32>> = Vec::with_capacity(actives.len());
        let mut frame_lens: Vec<u64> = Vec::with_capacity(actives.len());
        let mut loss_sum = 0.0f64;
        let mut up_bytes_total = 0u64;
        for (slot, &client) in actives.iter().enumerate() {
            let start = match &shared_broadcast {
                Some(b) => b.clone(),
                None => self.opt.broadcast(slot),
            };
            let (feats, labels) = self.ds.client_batches(client, t, meta.tau, meta.batch);
            let out = self.engine.train_round(
                &start,
                anchor_g.as_deref(),
                self.prev_local[client].as_deref().filter(|_| mu_p > 0.0),
                &feats,
                &labels,
                lr,
                mu_g,
                mu_p,
                cfg.weight_decay,
            )?;
            loss_sum += out.loss as f64;
            let mut delta = out.delta;
            if mu_p > 0.0 {
                let mut local = start.clone();
                tensor::axpy(1.0, &delta, &mut local);
                self.prev_local[client] = Some(local);
            }
            let hint;
            if is_luar {
                // Clients omit R_t layers from the upload (Alg. 1 line 2).
                for &l in &self.luar.recycle_set {
                    let lm = &meta.layers[l];
                    delta[lm.offset..lm.offset + lm.size].iter_mut().for_each(|v| *v = 0.0);
                }
                if cfg.luar_compress.is_some() {
                    // Table 3 composition: baseline compression on the
                    // uploaded layers.
                    self.compressor.compress(client, &mut delta, &meta, t, &mut self.rng);
                    // re-zero recycled layers (compressors like binarize
                    // may have produced nonzeros there)
                    for &l in &self.luar.recycle_set {
                        let lm = &meta.layers[l];
                        delta[lm.offset..lm.offset + lm.size].iter_mut().for_each(|v| *v = 0.0);
                    }
                    hint = self.compressor.wire_hint();
                } else {
                    hint = wire::WireHint::Dense;
                }
            } else {
                self.compressor.compress(client, &mut delta, &meta, t, &mut self.rng);
                hint = self.compressor.wire_hint();
            }
            // Serialize exactly what crosses the wire, then decode it
            // server-side: the ledger counts frame.len() (headers,
            // layer-id lists, and index overheads included — no more
            // analytic estimates or per-client truncating casts), and
            // the aggregate consumes the decoded bytes.
            let frame = wire::encode_update(&delta, &meta, &upload_layers, &hint)?;
            let delta_srv = match wire::decode_update(frame.as_bytes(), &meta)? {
                wire::Decoded::Vector(v) => v,
                // LBGM scalar: the server's per-client anchor times the
                // coefficient — which is the in-place reconstruction.
                wire::Decoded::Scalar(_) => delta,
            };
            up_bytes_total += frame.len() as u64;
            frame_lens.push(frame.len() as u64);
            deltas.push(delta_srv);
        }
        // --- network simulation: who makes this round's aggregate? ---------
        let outcome = self.net.round(&actives, bcast_frame.len() as u64, &frame_lens);
        self.last_frame_lens = frame_lens;
        self.dropped_stragglers += (actives.len() - outcome.aggregated) as u64;

        // --- aggregation over the round's survivors ------------------------
        // (Pallas graph when every upload arrived with unit weight and
        // the count matches the lowered shape; weighted pure-Rust
        // fallback for deadline drops and buffered staleness discounts.)
        let mut refs: Vec<&[f32]> = Vec::with_capacity(outcome.aggregated);
        let mut agg_weights: Vec<f32> = Vec::with_capacity(outcome.aggregated);
        for (slot, d) in deltas.iter().enumerate() {
            if outcome.included[slot] {
                refs.push(d.as_slice());
                agg_weights.push(outcome.weights[slot]);
            }
        }
        let uniform = agg_weights.iter().all(|&w| w == 1.0);
        let (mut mean, u_ssq, w_ssq) = if uniform && refs.len() == meta.agg_clients {
            let out = self.engine.aggregate(&refs, self.opt.params())?;
            (out.mean, out.update_ssq, out.weight_ssq)
        } else {
            // fallback for non-standard client counts / weighted rounds
            let mut mean = vec![0.0f32; meta.dim];
            if uniform {
                tensor::mean_rows_par(&refs, &mut mean);
            } else {
                let wsum: f32 = agg_weights.iter().sum();
                let norm: Vec<f32> = agg_weights.iter().map(|w| w / wsum).collect();
                tensor::weighted_mean_rows(&refs, &norm, &mut mean);
            }
            let params = self.opt.params();
            let mut u_ssq = Vec::with_capacity(meta.num_layers());
            let mut w_ssq = Vec::with_capacity(meta.num_layers());
            for lm in &meta.layers {
                let r = lm.offset..lm.offset + lm.size;
                u_ssq.push(tensor::ssq(&mean[r.clone()]) as f32);
                w_ssq.push(tensor::ssq(&params[r]) as f32);
            }
            (mean, u_ssq, w_ssq)
        };
        self.last_update_ssq = u_ssq.clone();
        self.last_weight_ssq = w_ssq.clone();

        // --- LUAR composition + next selection (Alg. 1) --------------------
        let mut kappa = 0.0;
        if is_luar {
            self.luar.update_scores(&u_ssq, &w_ssq);
            kappa = self.luar.compose_update(&mut mean, &meta, luar_mode.unwrap());
            let next_delta = match &mut self.delta_ctl {
                Some(ctl) => ctl.observe(kappa),
                None => luar_delta,
            };
            let grad_norms: Vec<f64> =
                u_ssq.iter().map(|&s| (s as f64).max(0.0).sqrt()).collect();
            self.luar.select_next(luar_scheme.unwrap(), next_delta, &grad_norms, &mut self.rng);
        }

        // --- server update --------------------------------------------------
        self.opt.apply(&mean);

        // --- accounting ------------------------------------------------------
        // Everything measured: the Comm numerator sums uplink frame
        // lengths (dropped stragglers still transmitted — their bytes
        // crossed the wire), the denominator is the measured dense
        // FedAvg frame, and the downlink is the broadcast frame
        // (params + R_t layer-id list) per active client.
        let fedavg_frame = wire::dense_frame_len(&meta);
        let down_total = (actives.len() as u64) * bcast_frame.len() as u64;
        self.comm.record_wire_round(
            actives.len() as u64,
            &upload_layers,
            up_bytes_total,
            fedavg_frame,
            down_total,
        );
        // Sync rounds are bound by the slowest active client (the old
        // mean-upload shortcut is gone); deadline/buffered rounds close
        // by their own policy.
        self.sim_seconds += outcome.round_secs;

        let train_loss = loss_sum / actives.len().max(1) as f64;
        self.train_loss_ema = if self.train_loss_ema.is_nan() {
            train_loss
        } else {
            0.7 * self.train_loss_ema + 0.3 * train_loss
        };

        self.round += 1;
        let last = self.round == cfg.rounds;
        if last || (cfg.eval_every > 0 && self.round % cfg.eval_every == 0) {
            let (test_loss, test_acc) = self.engine.eval_dataset(self.opt.params(), &self.ds)?;
            self.history.push(RoundRecord {
                round: self.round,
                train_loss,
                test_loss,
                test_acc,
                up_bytes: self.comm.up_bytes,
                comm_ratio: self.comm.comm_ratio(),
                kappa,
                sim_seconds: self.sim_seconds,
                wire_bytes: up_bytes_total,
                tail_s: outcome.straggler_tail_s,
                arrivals: outcome.aggregated,
            });
        }
        Ok(())
    }

    /// Figure 1 diagnostics: per-layer (name, ||Delta||, ||x||, ratio).
    pub fn layer_stats(&self) -> Vec<(String, f64, f64, f64)> {
        self.engine
            .meta
            .layers
            .iter()
            .enumerate()
            .map(|(l, lm)| {
                let g = (self.last_update_ssq[l] as f64).max(0.0).sqrt();
                let w = (self.last_weight_ssq[l] as f64).max(0.0).sqrt();
                let ratio = if w > 1e-12 { g / w } else { 0.0 };
                (lm.name.clone(), g, w, ratio)
            })
            .collect()
    }

    /// Checkpoint access to the coordinator RNG.
    pub(crate) fn rng_state(&self) -> Vec<u64> {
        self.rng.state().to_vec()
    }

    pub(crate) fn set_rng_state(&mut self, s: [u64; 4]) {
        self.rng = Rng::from_state(s);
    }

    /// Server peak memory model (Table 1): buffers held at aggregation.
    /// Returns (fedavg_bytes, this_method_bytes).
    pub fn memory_footprint(&self) -> (u64, u64) {
        let meta = &self.engine.meta;
        let a = self.cfg.active_clients as u64;
        let full = meta.full_bytes();
        match &self.cfg.method {
            Method::Luar { .. } => {
                let recycled = meta.layer_bytes(&self.luar.recycle_set);
                crate::comm::memory_footprint_bytes(a, full, recycled)
            }
            _ => (a * full, a * full),
        }
    }
}
