//! Communication accounting + the legacy global bandwidth model.
//!
//! The paper's headline metric is "Comm": upload bytes relative to
//! FedAvg (clients skip uploading recycled layers; the download side
//! is the full model either way, plus the delta layer-id list).
//! `CommAccountant` tracks exact bytes per direction and per layer so
//! Figure 3 (per-layer aggregation counts) and every Comm column fall
//! out of the same ledger. The round loop now measures serialized
//! `net::wire` frames and records them via `record_wire_round`; the
//! analytic `record_round`/`record_compressed_round` entry points
//! remain for estimate-style callers. `BandwidthModel` is the legacy
//! homogeneous link model, superseded by `net::links::LinkFleet` for
//! simulated wall-clock.


#[derive(Debug, Clone)]
pub struct CommAccountant {
    pub rounds: u64,
    pub up_bytes: u64,
    pub down_bytes: u64,
    /// Number of rounds each layer's update was actually uploaded
    /// (Figure 3's y-axis, per aggregation).
    pub layer_upload_rounds: Vec<u64>,
    /// Upload bytes if every round had been full FedAvg (denominator
    /// of the Comm column).
    pub fedavg_up_bytes: u64,
    /// Bytes residual (delta) framing shaved off the self-contained
    /// baseline, both directions (0 unless `net.delta_frames`). The
    /// `up_bytes`/`down_bytes` ledgers already record the smaller delta
    /// frames; this tracks the stacked saving explicitly.
    pub delta_bytes_saved: u64,
    /// Transmissions that shipped self-contained while delta framing
    /// was on: first contact, evicted/stale references, checkpoint
    /// resume, non-dense upload flavors, or a delta frame that would
    /// not have been smaller.
    pub delta_fallbacks: u64,
}

impl CommAccountant {
    pub fn new(num_layers: usize) -> Self {
        CommAccountant {
            rounds: 0,
            up_bytes: 0,
            down_bytes: 0,
            layer_upload_rounds: vec![0; num_layers],
            fedavg_up_bytes: 0,
            delta_bytes_saved: 0,
            delta_fallbacks: 0,
        }
    }

    /// Record one aggregation round's residual-framing outcome:
    /// `bytes_saved` versus the self-contained baseline and how many
    /// transmissions fell back to self-contained frames.
    pub fn record_delta(&mut self, bytes_saved: u64, fallbacks: u64) {
        self.delta_bytes_saved += bytes_saved;
        self.delta_fallbacks += fallbacks;
    }

    /// Record one round.
    /// `uploaded_layers`: (layer id, actual bytes uploaded per client).
    /// `full_bytes`: the FedAvg per-client upload for the denominator.
    /// `down_per_client`: broadcast bytes per client.
    pub fn record_round(
        &mut self,
        active_clients: u64,
        uploaded_layers: &[(usize, u64)],
        full_bytes: u64,
        down_per_client: u64,
    ) {
        self.rounds += 1;
        self.down_bytes += active_clients * down_per_client;
        self.fedavg_up_bytes += active_clients * full_bytes;
        for &(layer, bytes) in uploaded_layers {
            self.layer_upload_rounds[layer] += 1;
            self.up_bytes += active_clients * bytes;
        }
    }

    /// Record one round where every layer is uploaded but lossily
    /// compressed (the sketching baselines): `total_up_bytes` is the
    /// exact sum over clients after compression.
    pub fn record_compressed_round(
        &mut self,
        active_clients: u64,
        total_up_bytes: u64,
        full_bytes: u64,
        down_per_client: u64,
    ) {
        self.rounds += 1;
        self.down_bytes += active_clients * down_per_client;
        self.fedavg_up_bytes += active_clients * full_bytes;
        self.up_bytes += total_up_bytes;
        for c in self.layer_upload_rounds.iter_mut() {
            *c += 1;
        }
    }

    /// Record one round from *measured* wire frames: `up_bytes_total`
    /// is the sum of serialized uplink frame lengths over all active
    /// clients (headers, layer-id lists, and index overheads included),
    /// `fedavg_bytes_per_client` the measured dense-frame length that
    /// normalizes the Comm column, `down_bytes_total` the summed
    /// broadcast frame lengths. `uploaded_layers` feeds Figure 3's
    /// per-layer aggregation counts.
    pub fn record_wire_round(
        &mut self,
        active_clients: u64,
        uploaded_layers: &[usize],
        up_bytes_total: u64,
        fedavg_bytes_per_client: u64,
        down_bytes_total: u64,
    ) {
        self.rounds += 1;
        self.down_bytes += down_bytes_total;
        self.fedavg_up_bytes += active_clients * fedavg_bytes_per_client;
        self.up_bytes += up_bytes_total;
        for &l in uploaded_layers {
            self.layer_upload_rounds[l] += 1;
        }
    }

    /// The paper's Comm column: upload cost normalized to FedAvg.
    pub fn comm_ratio(&self) -> f64 {
        if self.fedavg_up_bytes == 0 {
            return 0.0;
        }
        self.up_bytes as f64 / self.fedavg_up_bytes as f64
    }

    /// Per-layer aggregation frequency (Figure 3): uploads / rounds.
    pub fn layer_frequencies(&self) -> Vec<f64> {
        if self.rounds == 0 {
            return vec![0.0; self.layer_upload_rounds.len()];
        }
        self.layer_upload_rounds.iter().map(|&c| c as f64 / self.rounds as f64).collect()
    }
}

/// Asymmetric link model typical of FL edge deployments.
#[derive(Debug, Clone, Copy)]
pub struct BandwidthModel {
    pub up_mbps: f64,
    pub down_mbps: f64,
    pub rtt_s: f64,
}

impl Default for BandwidthModel {
    fn default() -> Self {
        // Modest edge uplink; the regime where the paper's savings matter.
        BandwidthModel { up_mbps: 20.0, down_mbps: 100.0, rtt_s: 0.05 }
    }
}

impl BandwidthModel {
    /// Seconds to complete one round's communication phase, assuming
    /// the slowest active client bounds the round (synchronous FL).
    pub fn round_seconds(&self, up_bytes_per_client: u64, down_bytes_per_client: u64) -> f64 {
        let up = (up_bytes_per_client as f64 * 8.0) / (self.up_mbps * 1e6);
        let down = (down_bytes_per_client as f64 * 8.0) / (self.down_mbps * 1e6);
        up + down + self.rtt_s
    }
}

/// Server memory-footprint model (paper Section 3.4 / Table 1):
/// FedAvg holds `a` full client models; FedLUAR holds `a` partial
/// models plus one recycled-update buffer of the skipped size.
pub fn memory_footprint_bytes(a: u64, model_bytes: u64, recycled_bytes: u64) -> (u64, u64) {
    let fedavg = a * model_bytes;
    let fedluar = a * (model_bytes - recycled_bytes) + recycled_bytes;
    (fedavg, fedluar)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fedavg_ratio_is_one() {
        let mut acc = CommAccountant::new(3);
        for _ in 0..5 {
            acc.record_round(4, &[(0, 40), (1, 40), (2, 20)], 100, 100);
        }
        assert!((acc.comm_ratio() - 1.0).abs() < 1e-12);
        assert_eq!(acc.layer_frequencies(), vec![1.0, 1.0, 1.0]);
    }

    #[test]
    fn skipped_layers_reduce_ratio() {
        let mut acc = CommAccountant::new(2);
        // layer 1 (60 bytes of 100) always skipped
        for _ in 0..10 {
            acc.record_round(2, &[(0, 40)], 100, 100);
        }
        assert!((acc.comm_ratio() - 0.4).abs() < 1e-12);
        assert_eq!(acc.layer_frequencies(), vec![1.0, 0.0]);
    }

    #[test]
    fn down_bytes_tracked() {
        let mut acc = CommAccountant::new(1);
        acc.record_round(3, &[(0, 10)], 10, 50);
        assert_eq!(acc.down_bytes, 150);
        assert_eq!(acc.up_bytes, 30);
    }

    #[test]
    fn wire_round_sums_measured_frames() {
        let mut acc = CommAccountant::new(3);
        // 4 clients, frames of 90/95/100/80 bytes, dense baseline 100,
        // broadcast 120 per client; layer 2 recycled.
        acc.record_wire_round(4, &[0, 1], 90 + 95 + 100 + 80, 100, 4 * 120);
        assert_eq!(acc.up_bytes, 365);
        assert_eq!(acc.fedavg_up_bytes, 400);
        assert_eq!(acc.down_bytes, 480);
        assert_eq!(acc.layer_upload_rounds, vec![1, 1, 0]);
        assert!((acc.comm_ratio() - 365.0 / 400.0).abs() < 1e-12);
    }

    #[test]
    fn delta_ledger_accumulates() {
        let mut acc = CommAccountant::new(2);
        assert_eq!((acc.delta_bytes_saved, acc.delta_fallbacks), (0, 0));
        acc.record_delta(120, 3);
        acc.record_delta(80, 0);
        assert_eq!(acc.delta_bytes_saved, 200);
        assert_eq!(acc.delta_fallbacks, 3);
    }

    #[test]
    fn bandwidth_model_monotone() {
        let bw = BandwidthModel::default();
        assert!(bw.round_seconds(1_000_000, 0) > bw.round_seconds(100_000, 0));
        assert!(bw.round_seconds(0, 0) >= bw.rtt_s);
    }

    #[test]
    fn memory_footprint_matches_paper_formula() {
        // a*(d-k)+k < a*d whenever k>0, a>1
        let (avg, luar) = memory_footprint_bytes(32, 1000, 600);
        assert_eq!(avg, 32_000);
        assert_eq!(luar, 32 * 400 + 600);
        assert!(luar < avg);
    }
}
