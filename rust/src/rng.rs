//! Deterministic PRNG substrate (no external crates available in the
//! offline build): SplitMix64 seeding + xoshiro256** generation, plus
//! the distributions the simulator needs — uniform, Bernoulli, normal
//! (Box–Muller), Gamma (Marsaglia–Tsang), Dirichlet, Fisher–Yates
//! shuffle, and weighted sampling without replacement (the LUAR layer
//! sampler, Alg. 1 line 8).
//!
//! Determinism is a core requirement: every experiment in
//! EXPERIMENTS.md is reproducible from its seed.

/// xoshiro256** seeded via SplitMix64 (Blackman & Vigna).
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9e37_79b9_7f4a_7c15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

impl Rng {
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Snapshot / restore for checkpointing.
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    pub fn from_state(s: [u64; 4]) -> Self {
        Rng { s }
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1) with 53-bit resolution.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [lo, hi) (hi > lo). Lemire-style rejection-free
    /// multiply-shift is fine here; modulo bias at these ranges is
    /// negligible for simulation, but we use rejection to stay exact.
    pub fn gen_range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi > lo, "empty range");
        let span = (hi - lo) as u64;
        // rejection sampling for exactness
        let zone = u64::MAX - (u64::MAX % span);
        loop {
            let v = self.next_u64();
            if v < zone {
                return lo + (v % span) as usize;
            }
        }
    }

    pub fn gen_bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (uses both values).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.f64();
            if u1 > 1e-300 {
                let u2 = self.f64();
                let r = (-2.0 * u1.ln()).sqrt();
                return r * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    pub fn normal_f32(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal() as f32
    }

    /// Gamma(shape, 1) via Marsaglia–Tsang; boosts shape<1 with the
    /// standard u^(1/shape) trick.
    pub fn gamma(&mut self, shape: f64) -> f64 {
        assert!(shape > 0.0);
        if shape < 1.0 {
            let u = self.f64().max(1e-300);
            return self.gamma(shape + 1.0) * u.powf(1.0 / shape);
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u = self.f64();
            if u < 1.0 - 0.0331 * x.powi(4) {
                return d * v;
            }
            if u.ln() < 0.5 * x * x + d * (1.0 - v + v.ln()) {
                return d * v;
            }
        }
    }

    /// Dirichlet(alpha * 1_n) via normalized Gammas.
    pub fn dirichlet(&mut self, alpha: f64, n: usize) -> Vec<f64> {
        let mut v: Vec<f64> = (0..n).map(|_| self.gamma(alpha).max(1e-300)).collect();
        let s: f64 = v.iter().sum();
        v.iter_mut().for_each(|x| *x /= s);
        v
    }

    /// In-place Fisher–Yates.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range(0, i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from 0..n uniformly (partial F-Y).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        let k = k.min(n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = self.gen_range(i, n);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Weighted sampling of `k` distinct indices without replacement
    /// (successive draws with renormalization) — Alg. 1 line 8's
    /// Random_Choice([L], delta, p). Weights must be non-negative and
    /// not all zero.
    pub fn weighted_sample_without_replacement(&mut self, weights: &[f64], k: usize) -> Vec<usize> {
        let n = weights.len();
        let k = k.min(n);
        assert!(weights.iter().all(|&w| w >= 0.0), "negative weight");
        let mut w = weights.to_vec();
        let mut out = Vec::with_capacity(k);
        for _ in 0..k {
            let total: f64 = w.iter().sum();
            if total <= 0.0 {
                // remaining mass exhausted: return fewer picks rather
                // than inventing zero-probability selections
                break;
            }
            let mut t = self.f64() * total;
            let mut pick = n - 1;
            for (i, &wi) in w.iter().enumerate() {
                if t < wi {
                    pick = i;
                    break;
                }
                t -= wi;
            }
            // guard against fp drift picking an exhausted index
            if w[pick] == 0.0 {
                match w.iter().position(|&x| x > 0.0) {
                    Some(i) => pick = i,
                    None => break,
                }
            }
            out.push(pick);
            w[pick] = 0.0;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_by_seed() {
        let mut a = Rng::seed_from_u64(7);
        let mut b = Rng::seed_from_u64(7);
        let mut c = Rng::seed_from_u64(8);
        let xs: Vec<u64> = (0..10).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..10).map(|_| b.next_u64()).collect();
        let zs: Vec<u64> = (0..10).map(|_| c.next_u64()).collect();
        assert_eq!(xs, ys);
        assert_ne!(xs, zs);
    }

    #[test]
    fn uniform_mean() {
        let mut r = Rng::seed_from_u64(1);
        let n = 20_000;
        let m: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((m - 0.5).abs() < 0.01, "mean {m}");
    }

    #[test]
    fn gen_range_bounds() {
        let mut r = Rng::seed_from_u64(2);
        for _ in 0..1000 {
            let v = r.gen_range(3, 9);
            assert!((3..9).contains(&v));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::seed_from_u64(3);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn gamma_mean_matches_shape() {
        let mut r = Rng::seed_from_u64(4);
        for shape in [0.1, 0.5, 1.0, 3.0, 10.0] {
            let n = 20_000;
            let m: f64 = (0..n).map(|_| r.gamma(shape)).sum::<f64>() / n as f64;
            assert!((m - shape).abs() < 0.15 * shape.max(1.0), "shape {shape} mean {m}");
        }
    }

    #[test]
    fn dirichlet_sums_to_one() {
        let mut r = Rng::seed_from_u64(5);
        let p = r.dirichlet(0.1, 16);
        assert!((p.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        assert!(p.iter().all(|&x| x >= 0.0));
    }

    #[test]
    fn low_alpha_dirichlet_concentrates() {
        let mut r = Rng::seed_from_u64(6);
        let mut max_mass = 0.0f64;
        for _ in 0..20 {
            let p = r.dirichlet(0.05, 10);
            max_mass = max_mass.max(p.iter().cloned().fold(0.0, f64::max));
        }
        assert!(max_mass > 0.8, "max mass {max_mass}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::seed_from_u64(7);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::seed_from_u64(8);
        let s = r.sample_indices(100, 30);
        assert_eq!(s.len(), 30);
        let mut d = s.clone();
        d.sort_unstable();
        d.dedup();
        assert_eq!(d.len(), 30);
    }

    #[test]
    fn weighted_sampling_distinct_and_biased() {
        let mut r = Rng::seed_from_u64(9);
        // index 0 has overwhelming weight: it should almost always appear
        let w = vec![1000.0, 1.0, 1.0, 1.0, 1.0];
        let mut count0 = 0;
        for _ in 0..200 {
            let s = r.weighted_sample_without_replacement(&w, 2);
            assert_eq!(s.len(), 2);
            assert_ne!(s[0], s[1]);
            if s.contains(&0) {
                count0 += 1;
            }
        }
        assert!(count0 > 190, "heavy index sampled only {count0}/200");
    }

    #[test]
    fn weighted_sampling_zero_weights_yield_nothing() {
        let mut r = Rng::seed_from_u64(10);
        let s = r.weighted_sample_without_replacement(&[0.0, 0.0, 0.0], 2);
        assert!(s.is_empty(), "zero-mass indices must never be selected");
    }

    #[test]
    fn weighted_sampling_exhausted_mass_returns_fewer() {
        let mut r = Rng::seed_from_u64(12);
        let s = r.weighted_sample_without_replacement(&[1.0, 0.0, 0.0], 3);
        assert_eq!(s, vec![0]);
    }

    #[test]
    fn weighted_sampling_k_equals_n() {
        let mut r = Rng::seed_from_u64(11);
        let mut s = r.weighted_sample_without_replacement(&[1.0, 2.0, 3.0], 3);
        s.sort_unstable();
        assert_eq!(s, vec![0, 1, 2]);
    }
}
