//! Span tracing: a bounded in-memory ring of finished spans plus an
//! optional JSONL event log.
//!
//! Spans are recorded by the RAII guards in `obs::span` — one record
//! per guard drop, carrying the wall-clock duration and (for simulated
//! work like link transit) an attached sim-clock duration. The ring
//! keeps the most recent `RING_CAP` records for in-process inspection;
//! when a `trace_path` is configured every record is also streamed as
//! one JSON object per line. Write errors are swallowed: telemetry
//! must never fail the run it observes.

use std::collections::VecDeque;
use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;

/// Most recent finished spans kept in memory.
pub const RING_CAP: usize = 4096;

/// One finished span.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SpanRecord {
    /// Monotonic per-tracer sequence number.
    pub seq: u64,
    /// Static span name (`fl.client_upload`, `wire.encode`, ...).
    pub name: &'static str,
    /// Measured wall-clock duration.
    pub wall_ns: u64,
    /// Simulated duration attached via `SpanGuard::set_sim` (0 when
    /// the span measured pure wall-clock work).
    pub sim_s: f64,
}

/// The per-thread trace sink behind the span guards.
#[derive(Debug)]
pub struct Tracer {
    ring: VecDeque<SpanRecord>,
    writer: Option<BufWriter<File>>,
    seq: u64,
    /// JSONL lines written so far (diagnostics).
    pub events_written: u64,
}

impl Tracer {
    /// Build a tracer; when `trace_path` is set the JSONL log is
    /// created eagerly (parent directories included) so path problems
    /// surface at init, not at the first span.
    pub fn new(trace_path: Option<&str>) -> std::io::Result<Self> {
        let writer = match trace_path {
            Some(p) => {
                if let Some(parent) = Path::new(p).parent() {
                    if !parent.as_os_str().is_empty() {
                        std::fs::create_dir_all(parent)?;
                    }
                }
                Some(BufWriter::new(File::create(p)?))
            }
            None => None,
        };
        Ok(Tracer { ring: VecDeque::with_capacity(RING_CAP), writer, seq: 0, events_written: 0 })
    }

    /// Record one finished span.
    pub fn record(&mut self, name: &'static str, wall_ns: u64, sim_s: f64) {
        let sim_s = if sim_s.is_finite() { sim_s } else { 0.0 };
        let rec = SpanRecord { seq: self.seq, name, wall_ns, sim_s };
        self.seq += 1;
        if self.ring.len() == RING_CAP {
            self.ring.pop_front();
        }
        self.ring.push_back(rec);
        if let Some(w) = &mut self.writer {
            // Span names are static ASCII identifiers: no escaping.
            let ok = writeln!(
                w,
                "{{\"seq\":{},\"span\":\"{}\",\"wall_ns\":{},\"sim_s\":{}}}",
                rec.seq, rec.name, rec.wall_ns, rec.sim_s
            );
            if ok.is_ok() {
                self.events_written += 1;
            }
        }
    }

    /// Copy of the in-memory ring, oldest first.
    pub fn recent(&self) -> Vec<SpanRecord> {
        self.ring.iter().copied().collect()
    }

    /// Total spans recorded (including ones evicted from the ring).
    pub fn recorded(&self) -> u64 {
        self.seq
    }

    /// Flush the JSONL log (called from `obs::finish`).
    pub fn flush(&mut self) -> std::io::Result<()> {
        match &mut self.writer {
            Some(w) => w.flush(),
            None => Ok(()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_is_bounded_and_ordered() {
        let mut t = Tracer::new(None).unwrap();
        for i in 0..(RING_CAP as u64 + 10) {
            t.record("x", i, 0.0);
        }
        let recent = t.recent();
        assert_eq!(recent.len(), RING_CAP);
        assert_eq!(recent[0].seq, 10, "oldest records evicted first");
        assert_eq!(recent.last().unwrap().seq, RING_CAP as u64 + 9);
        assert_eq!(t.recorded(), RING_CAP as u64 + 10);
    }

    #[test]
    fn jsonl_lines_are_written() {
        let dir = std::env::temp_dir().join("fedluar_obs_trace_test");
        let path = dir.join("trace.jsonl");
        let path_s = path.to_str().unwrap().to_string();
        {
            let mut t = Tracer::new(Some(&path_s)).unwrap();
            t.record("wire.encode", 1234, 0.0);
            t.record("link.transit", 99, 2.5);
            t.flush().unwrap();
            assert_eq!(t.events_written, 2);
        }
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        assert_eq!(lines[0], "{\"seq\":0,\"span\":\"wire.encode\",\"wall_ns\":1234,\"sim_s\":0}");
        assert!(lines[1].contains("\"sim_s\":2.5"));
    }

    #[test]
    fn non_finite_sim_clamps_to_zero() {
        let mut t = Tracer::new(None).unwrap();
        t.record("x", 1, f64::NAN);
        assert_eq!(t.recent()[0].sim_s, 0.0);
    }
}
