//! Metrics registry: named counters, gauges, and fixed-bucket
//! histograms, snapshotted per model version.
//!
//! Everything is keyed by `&'static str` so the enabled hot path does
//! no allocation — a BTreeMap lookup and an integer bump. Histograms
//! use fixed power-of-4 buckets (1, 4, 16, ... then +Inf), wide enough
//! to cover nanosecond span timings up to minutes in 20 buckets.
//!
//! Export formats:
//! * `exposition()` — Prometheus-style text (`fedluar_` prefix, dots
//!   mapped to underscores, cumulative `_bucket{le=...}` lines);
//! * `json_summary()` — a compact JSON object with counters, gauges,
//!   histogram summaries, and the per-version snapshots.

use std::collections::BTreeMap;

/// Histogram bucket count (power-of-4 upper bounds, last is +Inf).
pub const BUCKETS: usize = 20;

fn bucket_bound(i: usize) -> f64 {
    4f64.powi(i as i32)
}

/// Fixed-bucket histogram with count/sum/min/max.
#[derive(Debug, Clone, PartialEq)]
pub struct Histogram {
    pub count: u64,
    pub sum: f64,
    pub min: f64,
    pub max: f64,
    /// Per-bucket (non-cumulative) counts; bucket `i` holds values
    /// `<= 4^i`, the last bucket everything larger.
    pub buckets: [u64; BUCKETS],
}

impl Histogram {
    pub fn new() -> Self {
        Histogram { count: 0, sum: 0.0, min: 0.0, max: 0.0, buckets: [0; BUCKETS] }
    }

    pub fn observe(&mut self, v: f64) {
        if self.count == 0 {
            self.min = v;
            self.max = v;
        } else {
            self.min = self.min.min(v);
            self.max = self.max.max(v);
        }
        self.count += 1;
        self.sum += v;
        let mut idx = BUCKETS - 1;
        for i in 0..BUCKETS - 1 {
            if v <= bucket_bound(i) {
                idx = i;
                break;
            }
        }
        self.buckets[idx] += 1;
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

/// Counter/gauge state frozen at a model-version close.
#[derive(Debug, Clone)]
pub struct Snapshot {
    pub version: u64,
    pub counters: BTreeMap<&'static str, u64>,
    pub gauges: BTreeMap<&'static str, f64>,
}

/// The per-thread metrics store behind the `obs::` free functions.
#[derive(Debug, Default)]
pub struct Registry {
    counters: BTreeMap<&'static str, u64>,
    gauges: BTreeMap<&'static str, f64>,
    histograms: BTreeMap<&'static str, Histogram>,
    /// Span-duration histograms keyed by span name; exported with an
    /// `_ns` suffix (`wire.encode` spans feed `wire.encode_ns`).
    span_ns: BTreeMap<&'static str, Histogram>,
    pub snapshots: Vec<Snapshot>,
}

impl Registry {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn counter(&mut self, name: &'static str, delta: u64) {
        *self.counters.entry(name).or_insert(0) += delta;
    }

    pub fn gauge(&mut self, name: &'static str, v: f64) {
        self.gauges.insert(name, v);
    }

    pub fn observe(&mut self, name: &'static str, v: f64) {
        self.histograms.entry(name).or_default().observe(v);
    }

    pub fn observe_span_ns(&mut self, name: &'static str, wall_ns: u64) {
        self.span_ns.entry(name).or_default().observe(wall_ns as f64);
    }

    /// Freeze the current counters/gauges under a version label.
    pub fn snapshot(&mut self, version: u64) {
        self.snapshots.push(Snapshot {
            version,
            counters: self.counters.clone(),
            gauges: self.gauges.clone(),
        });
    }

    pub fn counter_value(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    pub fn gauge_value(&self, name: &str) -> Option<f64> {
        self.gauges.get(name).copied()
    }

    pub fn histogram(&self, name: &str) -> Option<&Histogram> {
        self.histograms.get(name).or_else(|| self.span_ns.get(name))
    }

    fn sanitized(name: &str, suffix: &str) -> String {
        let base: String =
            name.chars().map(|c| if c.is_ascii_alphanumeric() { c } else { '_' }).collect();
        format!("fedluar_{base}{suffix}")
    }

    /// Prometheus-style text exposition of the full registry.
    pub fn exposition(&self) -> String {
        let mut out = String::new();
        for (name, v) in &self.counters {
            let m = Self::sanitized(name, "");
            out.push_str(&format!("# TYPE {m} counter\n{m} {v}\n"));
        }
        for (name, v) in &self.gauges {
            let m = Self::sanitized(name, "");
            out.push_str(&format!("# TYPE {m} gauge\n{m} {v}\n"));
        }
        let histos = self
            .histograms
            .iter()
            .map(|(n, h)| (Self::sanitized(n, ""), h))
            .chain(self.span_ns.iter().map(|(n, h)| (Self::sanitized(n, "_ns"), h)));
        for (m, h) in histos {
            out.push_str(&format!("# TYPE {m} histogram\n"));
            let mut cum = 0u64;
            for (i, &c) in h.buckets.iter().enumerate() {
                cum += c;
                if i == BUCKETS - 1 {
                    out.push_str(&format!("{m}_bucket{{le=\"+Inf\"}} {cum}\n"));
                } else {
                    out.push_str(&format!("{m}_bucket{{le=\"{}\"}} {cum}\n", bucket_bound(i)));
                }
            }
            out.push_str(&format!("{m}_sum {}\n{m}_count {}\n", h.sum, h.count));
        }
        out
    }

    /// JSON summary: counters, gauges, histogram stats, snapshots.
    /// Names are static identifiers, so no string escaping is needed.
    pub fn json_summary(&self) -> String {
        fn kv_u64(m: &BTreeMap<&'static str, u64>) -> String {
            let inner: Vec<String> = m.iter().map(|(k, v)| format!("\"{k}\":{v}")).collect();
            format!("{{{}}}", inner.join(","))
        }
        fn kv_f64(m: &BTreeMap<&'static str, f64>) -> String {
            let inner: Vec<String> = m.iter().map(|(k, v)| format!("\"{k}\":{v}")).collect();
            format!("{{{}}}", inner.join(","))
        }
        let histos: Vec<String> = self
            .histograms
            .iter()
            .map(|(n, h)| (n.to_string(), h))
            .chain(self.span_ns.iter().map(|(n, h)| (format!("{n}_ns"), h)))
            .map(|(n, h)| {
                format!(
                    "\"{n}\":{{\"count\":{},\"sum\":{},\"min\":{},\"max\":{},\"mean\":{}}}",
                    h.count,
                    h.sum,
                    h.min,
                    h.max,
                    h.mean()
                )
            })
            .collect();
        let snaps: Vec<String> = self
            .snapshots
            .iter()
            .map(|s| {
                format!(
                    "{{\"version\":{},\"counters\":{},\"gauges\":{}}}",
                    s.version,
                    kv_u64(&s.counters),
                    kv_f64(&s.gauges)
                )
            })
            .collect();
        format!(
            "{{\"counters\":{},\"gauges\":{},\"histograms\":{{{}}},\"snapshots\":[{}]}}",
            kv_u64(&self.counters),
            kv_f64(&self.gauges),
            histos.join(","),
            snaps.join(",")
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_gauges_accumulate() {
        let mut r = Registry::new();
        r.counter("a.b", 2);
        r.counter("a.b", 3);
        r.gauge("g", 1.5);
        r.gauge("g", 2.5);
        assert_eq!(r.counter_value("a.b"), 5);
        assert_eq!(r.counter_value("missing"), 0);
        assert_eq!(r.gauge_value("g"), Some(2.5), "gauges keep the last value");
    }

    #[test]
    fn histogram_buckets_power_of_four() {
        let mut h = Histogram::new();
        h.observe(1.0); // bucket 0 (<= 1)
        h.observe(4.0); // bucket 1 (<= 4)
        h.observe(5.0); // bucket 2 (<= 16)
        h.observe(1e30); // overflow -> last bucket
        assert_eq!(h.count, 4);
        assert_eq!(h.buckets[0], 1);
        assert_eq!(h.buckets[1], 1);
        assert_eq!(h.buckets[2], 1);
        assert_eq!(h.buckets[BUCKETS - 1], 1);
        assert_eq!(h.min, 1.0);
        assert_eq!(h.max, 1e30);
    }

    #[test]
    fn exposition_has_prefix_and_cumulative_buckets() {
        let mut r = Registry::new();
        r.counter("wire.frames", 7);
        r.observe("async.version_gap", 2.0);
        r.observe_span_ns("wire.encode", 100);
        let text = r.exposition();
        assert!(text.contains("# TYPE fedluar_wire_frames counter"));
        assert!(text.contains("fedluar_wire_frames 7"));
        assert!(text.contains("fedluar_async_version_gap_count 1"));
        assert!(text.contains("fedluar_wire_encode_ns_count 1"));
        assert!(text.contains("_bucket{le=\"+Inf\"} 1"));
    }

    #[test]
    fn json_summary_parses_with_in_tree_parser() {
        let mut r = Registry::new();
        r.counter("c", 1);
        r.gauge("g", 0.5);
        r.observe("h", 3.0);
        r.observe_span_ns("sp", 42);
        r.snapshot(0);
        r.counter("c", 1);
        r.snapshot(1);
        let js = crate::json::Json::parse(&r.json_summary()).unwrap();
        assert_eq!(js.get("counters").unwrap().get("c").unwrap().as_f64().unwrap(), 2.0);
        let snaps = match js.get("snapshots").unwrap() {
            crate::json::Json::Arr(a) => a,
            other => panic!("snapshots not an array: {other:?}"),
        };
        assert_eq!(snaps.len(), 2);
        assert_eq!(snaps[0].get("counters").unwrap().get("c").unwrap().as_f64().unwrap(), 1.0);
        assert!(js.get("histograms").unwrap().get("sp_ns").is_ok());
    }
}
