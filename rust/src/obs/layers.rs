//! Per-layer LUAR introspection: one record per (round, layer) with
//! the paper's per-layer quantities — the Eq. 1 selection score, the
//! recycled-or-uploaded decision (Figure 3's aggregation frequency is
//! the column sum of `uploaded`), the recycle age (staleness k in
//! Eq. 6), the wire bytes the layer cost, and the staleness discount
//! the round's aggregate was weighted by.
//!
//! Rows accumulate in the obs context and are written as a CSV at
//! `obs::finish` (the `layer_csv` config path). Summing `uploaded` per
//! layer over rounds reproduces `CommAccountant::layer_upload_rounds`
//! exactly — both derive from the same per-round upload set (pinned in
//! `tests/integration_obs.rs`).

use crate::model::ModelMeta;
use std::io::Write;
use std::path::Path;

/// One layer's telemetry for one aggregation round / model version.
#[derive(Debug, Clone, PartialEq)]
pub struct LayerRound {
    pub round: usize,
    pub layer: usize,
    pub name: String,
    /// Selection score s_{t,l} = ||u_l|| / ||w_l|| (Eq. 1). For
    /// recycled layers this is the stale score the selection actually
    /// used — exactly what `LuarState::scores` holds.
    pub score: f64,
    /// Whether the layer was uploaded this round (false = recycled).
    pub uploaded: bool,
    /// Aggregations since the layer last uploaded, after this round's
    /// compose (0 for uploaded layers).
    pub recycle_age: u32,
    /// Measured uplink bytes apportioned to this layer: the round's
    /// total frame bytes split across uploaded layers proportional to
    /// parameter count (headers and index overheads included pro rata);
    /// 0 for recycled layers.
    pub wire_bytes: u64,
    /// Mean staleness-discount weight of the round's aggregate (1.0 in
    /// the barrier modes / `s=const`).
    pub stale_discount: f64,
    /// Mean model-version gap the round's residual (delta) frames were
    /// coded across (0 when `net.delta_frames` is off or every frame
    /// shipped self-contained). Round-level, repeated per layer row.
    pub delta_ref_gap: f64,
}

pub const CSV_HEADER: &str =
    "round,layer,name,score,uploaded,recycle_age,wire_bytes,stale_discount,delta_ref_gap";

/// Build the per-layer rows for one aggregation round.
#[allow(clippy::too_many_arguments)]
pub(crate) fn build_rows(
    round: usize,
    meta: &ModelMeta,
    upload_layers: &[usize],
    scores: &[f64],
    ages: &[u32],
    up_bytes_total: u64,
    stale_discount: f64,
    delta_ref_gap: f64,
) -> Vec<LayerRound> {
    let uploaded_params: u64 =
        upload_layers.iter().map(|&l| meta.layers[l].size as u64).sum();
    meta.layers
        .iter()
        .enumerate()
        .map(|(l, lm)| {
            let uploaded = upload_layers.contains(&l);
            let wire_bytes = if uploaded && uploaded_params > 0 {
                up_bytes_total * lm.size as u64 / uploaded_params
            } else {
                0
            };
            LayerRound {
                round,
                layer: l,
                name: lm.name.clone(),
                score: scores.get(l).copied().unwrap_or(0.0),
                uploaded,
                recycle_age: ages.get(l).copied().unwrap_or(0),
                wire_bytes,
                stale_discount,
                delta_ref_gap,
            }
        })
        .collect()
}

/// Write the accumulated rows as a CSV (`uploaded` as 1/0).
pub(crate) fn write_csv(rows: &[LayerRound], path: impl AsRef<Path>) -> std::io::Result<()> {
    if let Some(parent) = path.as_ref().parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    let mut f = std::fs::File::create(path)?;
    writeln!(f, "{CSV_HEADER}")?;
    for r in rows {
        writeln!(
            f,
            "{},{},{},{:.6},{},{},{},{:.6},{:.6}",
            r.round,
            r.layer,
            r.name,
            r.score,
            u8::from(r.uploaded),
            r.recycle_age,
            r.wire_bytes,
            r.stale_discount,
            r.delta_ref_gap
        )?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn meta() -> ModelMeta {
        ModelMeta::from_json(
            r#"{
            "model":"toy","dim":10,"num_classes":2,
            "input_shape":[4],"input_dtype":"f32",
            "tau":2,"batch":3,"eval_batch":8,"agg_clients":4,"momentum":0.9,
            "layers":[
              {"name":"a","kind":"dense","offset":0,"size":6,"arrays":[]},
              {"name":"b","kind":"dense","offset":6,"size":4,"arrays":[]}
            ],
            "artifacts":{"train":"t","eval":"e","agg":"g","init":"i"},
            "init_sha256":"x"
        }"#,
            PathBuf::from("/tmp"),
        )
        .unwrap()
    }

    #[test]
    fn rows_apportion_bytes_to_uploaded_layers() {
        let m = meta();
        let rows = build_rows(3, &m, &[0], &[0.5, 0.25], &[0, 2], 600, 0.9, 0.0);
        assert_eq!(rows.len(), 2);
        assert!(rows[0].uploaded && !rows[1].uploaded);
        assert_eq!(rows[0].wire_bytes, 600, "only uploaded layers carry bytes");
        assert_eq!(rows[1].wire_bytes, 0);
        assert_eq!(rows[1].recycle_age, 2);
        assert_eq!(rows[0].score, 0.5);
        assert_eq!(rows[1].stale_discount, 0.9);
    }

    #[test]
    fn bytes_split_proportional_to_param_count() {
        let m = meta();
        let rows = build_rows(0, &m, &[0, 1], &[0.0, 0.0], &[0, 0], 1000, 1.0, 0.0);
        assert_eq!(rows[0].wire_bytes, 600); // 6 of 10 params
        assert_eq!(rows[1].wire_bytes, 400);
    }

    #[test]
    fn delta_ref_gap_repeats_per_row() {
        let m = meta();
        let rows = build_rows(2, &m, &[0, 1], &[0.0, 0.0], &[0, 0], 100, 1.0, 1.5);
        assert!(rows.iter().all(|r| r.delta_ref_gap == 1.5));
    }

    #[test]
    fn csv_shape() {
        let m = meta();
        let rows = build_rows(1, &m, &[1], &[0.5, 0.25], &[3, 0], 100, 1.0, 2.0);
        let dir = std::env::temp_dir().join("fedluar_obs_layers_test");
        let path = dir.join("layers.csv");
        write_csv(&rows, &path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines[0], CSV_HEADER);
        assert_eq!(lines.len(), 3);
        for line in &lines[1..] {
            assert_eq!(line.split(',').count(), 9, "{line}");
        }
        assert!(lines[1].starts_with("1,0,a,0.500000,0,3,0,"));
        assert!(lines[2].starts_with("1,1,b,0.250000,1,0,100,"));
        assert!(lines[1].ends_with(",2.000000"));
    }
}
