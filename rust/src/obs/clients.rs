//! Per-client participation and link telemetry: one row per client
//! with the fleet's uplink speed (and its decade bucket — the same
//! bucketing that keys the `client.upload_s.*` histograms), the
//! sampler's dispatch / absorbed / held-stale counts, the measured
//! mean upload latency, the cumulative uplink bytes, and the fault
//! columns (retried attempts and permanently failed uploads — zero
//! unless `net.faults` is active; see docs/faults.md).
//!
//! Unlike the per-layer rows (which accumulate per round), the client
//! table is cumulative: `obs::record_client_rounds` *replaces* the
//! stored rows at each aggregation, so `obs::finish` writes the final
//! totals to the `clients_csv` config path. `dispatches` reconciles
//! exactly against the scheduler's dispatch log, which makes sampler
//! fairness auditable from the CSV alone
//! (`tests/integration_sampler.rs` pins this).

use crate::net::{links, ClientStats, LinkFleet};
use std::io::Write;
use std::path::Path;

/// One client's cumulative telemetry as of the latest aggregation.
#[derive(Debug, Clone, PartialEq)]
pub struct ClientRound {
    pub client: usize,
    /// Uplink bandwidth in Mbps (fixed per run by the link fleet).
    pub up_mbps: f64,
    /// Decade bucket label (`links::speed_bucket_label`).
    pub speed_bucket: &'static str,
    /// Times the scheduler dispatched work to this client.
    pub dispatches: u64,
    /// Uploads that entered an aggregate.
    pub absorbed: u64,
    /// Uploads held out of the async mean by `sampler=staleness:cap=N`.
    pub held_stale: u64,
    /// Mean simulated upload seconds over all dispatches (0 when the
    /// client was never dispatched).
    pub mean_upload_s: f64,
    /// Cumulative uplink bytes across all dispatches.
    pub up_bytes: u64,
    /// Retry attempts injected faults forced on this client's uploads
    /// (counted apart from first attempts so `mean_upload_s` — and the
    /// speed-biased sampler reading it — never double-penalizes an
    /// unlucky client).
    pub retries: u64,
    /// Uploads whose whole retry chain failed (never aggregated).
    pub failures: u64,
}

pub const CSV_HEADER: &str = "client,up_mbps,speed_bucket,dispatches,absorbed,held_stale,\
mean_upload_s,up_bytes,retries,failures";

/// Build one row per client from the sampler telemetry + link fleet.
pub(crate) fn build_rows(stats: &ClientStats, fleet: &LinkFleet) -> Vec<ClientRound> {
    let n = stats.len().min(fleet.len());
    (0..n)
        .map(|c| {
            let up_bps = fleet.link(c).up_bps;
            ClientRound {
                client: c,
                up_mbps: up_bps / 1e6,
                speed_bucket: links::speed_bucket_label(links::speed_bucket(up_bps)),
                dispatches: stats.dispatches[c],
                absorbed: stats.absorbed[c],
                held_stale: stats.held_stale[c],
                mean_upload_s: stats.mean_upload_secs(c).unwrap_or(0.0),
                up_bytes: stats.up_bytes[c],
                retries: stats.retries[c],
                failures: stats.failures[c],
            }
        })
        .collect()
}

/// Write the client table as a CSV.
pub(crate) fn write_csv(rows: &[ClientRound], path: impl AsRef<Path>) -> std::io::Result<()> {
    if let Some(parent) = path.as_ref().parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    let mut f = std::fs::File::create(path)?;
    writeln!(f, "{CSV_HEADER}")?;
    for r in rows {
        writeln!(
            f,
            "{},{:.3},{},{},{},{},{:.6},{},{},{}",
            r.client,
            r.up_mbps,
            r.speed_bucket,
            r.dispatches,
            r.absorbed,
            r.held_stale,
            r.mean_upload_s,
            r.up_bytes,
            r.retries,
            r.failures
        )?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::LinkDist;

    fn fixture() -> (ClientStats, LinkFleet) {
        let fleet = LinkFleet::new(
            &LinkDist::Bimodal {
                fast_frac: 0.5,
                fast_up_mbps: 50.0,
                slow_up_mbps: 2.0,
                down_mbps: 100.0,
                rtt_s: 0.0,
            },
            4,
            5,
        );
        let mut stats = ClientStats::new(4);
        stats.record_dispatch(0, 2.0, 100);
        stats.record_dispatch(0, 4.0, 100);
        stats.record_absorbed(0);
        stats.record_dispatch(2, 1.0, 50);
        stats.record_held(2);
        stats.record_retries(0, 2, 9.0, 300);
        stats.record_failure(3);
        (stats, fleet)
    }

    #[test]
    fn rows_join_stats_with_fleet() {
        let (stats, fleet) = fixture();
        let rows = build_rows(&stats, &fleet);
        assert_eq!(rows.len(), 4);
        assert_eq!(rows[0].dispatches, 2);
        assert_eq!(rows[0].absorbed, 1);
        assert_eq!(rows[0].mean_upload_s, 3.0);
        assert_eq!(rows[0].up_bytes, 200);
        assert_eq!(rows[2].held_stale, 1);
        assert_eq!(rows[1].dispatches, 0);
        assert_eq!(rows[1].mean_upload_s, 0.0, "never dispatched -> 0");
        assert_eq!(rows[0].retries, 2);
        assert_eq!(rows[0].mean_upload_s, 3.0, "retries never skew the mean");
        assert_eq!(rows[3].failures, 1);
        for r in &rows {
            let expect = fleet.link(r.client).up_bps / 1e6;
            assert_eq!(r.up_mbps, expect);
            assert!(["1M-10M", "10M-100M"].contains(&r.speed_bucket), "{}", r.speed_bucket);
        }
    }

    #[test]
    fn csv_shape() {
        let (stats, fleet) = fixture();
        let rows = build_rows(&stats, &fleet);
        let dir = std::env::temp_dir().join("fedluar_obs_clients_test");
        let path = dir.join("clients.csv");
        write_csv(&rows, &path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines[0], CSV_HEADER);
        assert_eq!(lines.len(), 5, "header + one row per client");
        for line in &lines[1..] {
            assert_eq!(line.split(',').count(), 10, "{line}");
        }
        assert!(lines[1].starts_with("0,"));
        assert!(lines[1].ends_with(",2,1,0,3.000000,200,2,0"), "{}", lines[1]);
        assert!(lines[4].ends_with(",0,0,0,0.000000,0,0,1"), "{}", lines[4]);
    }
}
