//! Observability subsystem: span tracing, a metrics registry, and
//! per-layer LUAR introspection for the FL runtime.
//!
//! Zero external dependencies (offline build — the vendored-`anyhow`
//! precedent): pure std, hand-rolled JSONL/exposition/CSV writers.
//!
//! Three pillars:
//! * `trace`   — RAII span guards on the hot paths (`fl.client_upload`,
//!   `wire.encode`/`wire.decode`, `link.transit`, `sched.pop`,
//!   `agg.absorb`, `luar.select`, `engine.train`/`engine.eval`),
//!   recording wall-clock and sim-clock durations to a bounded ring
//!   and an optional JSONL event log;
//! * `metrics` — named counters / gauges / fixed-bucket histograms
//!   (`wire.encode_ns`, `sched.queue_depth`, `async.version_gap`,
//!   `agg.absorb_ns`, ...), snapshotted per model version and exported
//!   as a Prometheus-style text exposition plus a JSON summary;
//! * `layers`  — per-round per-layer records (selection score,
//!   recycled-or-uploaded, recycle age, wire bytes, staleness
//!   discount) written to a `*_layers.csv`: Figure 3 and the kappa
//!   decomposition straight from telemetry.
//!
//! A fourth table, `clients`, joins the sampler's per-client
//! dispatch/absorb/held counts with the link fleet (one cumulative row
//! per client, written to a `*_clients.csv`) — sampler fairness and
//! straggler exposure straight from telemetry.
//!
//! The context is **thread-local**: `cargo test` runs tests on
//! parallel threads in one process, and a global level would bleed
//! telemetry across tests. One run = one thread = one context;
//! `init` installs it, `finish` writes the artifacts and clears it.
//!
//! Disabled cost: every instrumentation point starts with one
//! thread-local byte read and a branch — no allocation, no clock read
//! (`benches/obs_overhead.rs` pins this). Telemetry is read-only with
//! respect to the simulation: it never touches an RNG, the sim clock,
//! or any model state, which is why `level=off` and `level=full` runs
//! are bit-identical (`tests/integration_obs.rs`).

#![allow(clippy::disallowed_methods)] // obs/ is the designated wall-clock module (lint D2 allowlist)
pub mod clients;
pub mod layers;
pub mod metrics;
pub mod trace;

pub use clients::ClientRound;
pub use layers::LayerRound;
pub use metrics::{Histogram, Registry, Snapshot};
pub use trace::{SpanRecord, Tracer};

use crate::model::ModelMeta;
use std::cell::{Cell, RefCell};
use std::io::Write;
use std::time::Instant;

/// How much telemetry to collect.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Default)]
pub enum ObsLevel {
    /// No context installed; every instrumentation point is one
    /// thread-local read + branch.
    #[default]
    Off,
    /// Counters, gauges, histograms, layer records, snapshots.
    Metrics,
    /// Metrics plus span tracing (ring, span histograms, JSONL log).
    Full,
}

impl ObsLevel {
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        Ok(match s {
            "off" => Self::Off,
            "metrics" => Self::Metrics,
            "full" => Self::Full,
            other => anyhow::bail!("unknown obs level {other} (off | metrics | full)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::Off => "off",
            Self::Metrics => "metrics",
            Self::Full => "full",
        }
    }

    fn as_u8(self) -> u8 {
        match self {
            Self::Off => 0,
            Self::Metrics => 1,
            Self::Full => 2,
        }
    }
}

/// The `obs:` config block (flat keys `obs_level`, `obs_trace`,
/// `obs_metrics`, `obs_layer_csv`, `obs_clients_csv`).
#[derive(Debug, Clone, PartialEq, Default)]
pub struct ObsCfg {
    pub level: ObsLevel,
    /// JSONL span log (written during the run, `level=full` only).
    pub trace_path: Option<String>,
    /// Prometheus-style exposition file; a `.json` summary is written
    /// next to it.
    pub metrics_path: Option<String>,
    /// Per-layer LUAR introspection CSV.
    pub layer_csv: Option<String>,
    /// Per-client sampler/link telemetry CSV.
    pub clients_csv: Option<String>,
}

struct Ctx {
    cfg: ObsCfg,
    tracer: Tracer,
    registry: Registry,
    layer_rows: Vec<LayerRound>,
    client_rows: Vec<ClientRound>,
}

thread_local! {
    static LEVEL: Cell<u8> = const { Cell::new(0) };
    static CTX: RefCell<Option<Ctx>> = const { RefCell::new(None) };
}

fn with_ctx<T>(f: impl FnOnce(&mut Ctx) -> T) -> Option<T> {
    CTX.with(|c| c.borrow_mut().as_mut().map(f))
}

/// Install a telemetry context on this thread. `level=off` clears any
/// existing context (and is how `finish`-less callers reset).
pub fn init(cfg: &ObsCfg) -> std::io::Result<()> {
    if cfg.level == ObsLevel::Off {
        CTX.with(|c| *c.borrow_mut() = None);
        LEVEL.with(|l| l.set(0));
        return Ok(());
    }
    let trace_path =
        if cfg.level == ObsLevel::Full { cfg.trace_path.as_deref() } else { None };
    let ctx = Ctx {
        cfg: cfg.clone(),
        tracer: Tracer::new(trace_path)?,
        registry: Registry::new(),
        layer_rows: Vec::new(),
        client_rows: Vec::new(),
    };
    CTX.with(|c| *c.borrow_mut() = Some(ctx));
    LEVEL.with(|l| l.set(cfg.level.as_u8()));
    Ok(())
}

/// The level installed on this thread.
pub fn level() -> ObsLevel {
    match LEVEL.with(|l| l.get()) {
        0 => ObsLevel::Off,
        1 => ObsLevel::Metrics,
        _ => ObsLevel::Full,
    }
}

/// Whether any telemetry is being collected (level >= metrics).
#[inline]
pub fn enabled() -> bool {
    LEVEL.with(|l| l.get()) > 0
}

/// Whether spans are being recorded (level = full).
#[inline]
pub fn tracing() -> bool {
    LEVEL.with(|l| l.get()) >= 2
}

/// RAII span guard: measures wall-clock from construction to drop and
/// records into the tracer + the span-duration histogram. Disarmed
/// (no clock read, nothing recorded) below `level=full`.
pub struct SpanGuard {
    name: &'static str,
    start: Option<Instant>,
    sim_s: f64,
}

impl SpanGuard {
    /// Attach a simulated duration (e.g. link transit seconds) to the
    /// span record. No-op when the span is disarmed.
    pub fn set_sim(&mut self, sim_s: f64) {
        if self.start.is_some() {
            self.sim_s = sim_s;
        }
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if let Some(t0) = self.start.take() {
            let wall_ns = t0.elapsed().as_nanos() as u64;
            let (name, sim_s) = (self.name, self.sim_s);
            with_ctx(|c| {
                c.tracer.record(name, wall_ns, sim_s);
                c.registry.observe_span_ns(name, wall_ns);
            });
        }
    }
}

/// Open a span. `name` must be a static identifier (it crosses into
/// metric names and JSONL unescaped).
pub fn span(name: &'static str) -> SpanGuard {
    let start = if tracing() { Some(Instant::now()) } else { None };
    SpanGuard { name, start, sim_s: 0.0 }
}

/// Bump a named counter.
pub fn counter(name: &'static str, delta: u64) {
    if enabled() {
        with_ctx(|c| c.registry.counter(name, delta));
    }
}

/// Set a named gauge to its latest value.
pub fn gauge(name: &'static str, v: f64) {
    if enabled() {
        with_ctx(|c| c.registry.gauge(name, v));
    }
}

/// Record one observation into a named histogram.
pub fn observe(name: &'static str, v: f64) {
    if enabled() {
        with_ctx(|c| c.registry.observe(name, v));
    }
}

/// Freeze counters/gauges under a model-version label.
pub fn snapshot(version: u64) {
    if enabled() {
        with_ctx(|c| c.registry.snapshot(version));
    }
}

/// Record one aggregation round's per-layer telemetry (see
/// `layers::LayerRound` for the column semantics). `delta_ref_gap` is
/// the round's mean residual-framing reference gap (0 when
/// `net.delta_frames` is off or every frame fell back).
#[allow(clippy::too_many_arguments)]
pub fn record_layer_round(
    round: usize,
    meta: &ModelMeta,
    upload_layers: &[usize],
    scores: &[f64],
    ages: &[u32],
    up_bytes_total: u64,
    stale_discount: f64,
    delta_ref_gap: f64,
) {
    if !enabled() {
        return;
    }
    with_ctx(|c| {
        let rows = layers::build_rows(
            round,
            meta,
            upload_layers,
            scores,
            ages,
            up_bytes_total,
            stale_discount,
            delta_ref_gap,
        );
        c.layer_rows.extend(rows);
    });
}

/// Record the cumulative per-client table as of one aggregation (see
/// `clients::ClientRound` for the column semantics). Totals-so-far
/// replace the previous table, so `finish` writes the final cumulative
/// rows.
pub fn record_client_rounds(stats: &crate::net::ClientStats, fleet: &crate::net::LinkFleet) {
    if !enabled() {
        return;
    }
    with_ctx(|c| c.client_rows = clients::build_rows(stats, fleet));
}

/// Write the configured artifacts (flushing the JSONL log), clear the
/// thread's context, and return the paths written.
pub fn finish() -> std::io::Result<Vec<String>> {
    let ctx = CTX.with(|c| c.borrow_mut().take());
    LEVEL.with(|l| l.set(0));
    let mut written = Vec::new();
    let Some(mut ctx) = ctx else {
        return Ok(written);
    };
    ctx.tracer.flush()?;
    if let Some(p) = &ctx.cfg.trace_path {
        if ctx.cfg.level == ObsLevel::Full {
            written.push(p.clone());
        }
    }
    if let Some(p) = &ctx.cfg.metrics_path {
        write_text(p, &ctx.registry.exposition())?;
        written.push(p.clone());
        let json_path = match p.strip_suffix(".prom") {
            Some(stem) => format!("{stem}.json"),
            None => format!("{p}.json"),
        };
        write_text(&json_path, &ctx.registry.json_summary())?;
        written.push(json_path);
    }
    if let Some(p) = &ctx.cfg.layer_csv {
        layers::write_csv(&ctx.layer_rows, p)?;
        written.push(p.clone());
    }
    if let Some(p) = &ctx.cfg.clients_csv {
        clients::write_csv(&ctx.client_rows, p)?;
        written.push(p.clone());
    }
    Ok(written)
}

fn write_text(path: &str, text: &str) -> std::io::Result<()> {
    if let Some(parent) = std::path::Path::new(path).parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    let mut f = std::fs::File::create(path)?;
    f.write_all(text.as_bytes())
}

// ---------------------------------------------------------------------
// in-process accessors (tests / diagnostics)
// ---------------------------------------------------------------------

/// Current value of a counter (0 when absent or obs is off).
pub fn counter_value(name: &str) -> u64 {
    with_ctx(|c| c.registry.counter_value(name)).unwrap_or(0)
}

/// Latest value of a gauge.
pub fn gauge_value(name: &str) -> Option<f64> {
    with_ctx(|c| c.registry.gauge_value(name)).flatten()
}

/// Copy of the span ring, oldest first (empty when off).
pub fn recent_spans() -> Vec<SpanRecord> {
    with_ctx(|c| c.tracer.recent()).unwrap_or_default()
}

/// Total spans recorded so far on this thread.
pub fn spans_recorded() -> u64 {
    with_ctx(|c| c.tracer.recorded()).unwrap_or(0)
}

/// Copy of the accumulated per-layer rows.
pub fn layer_rows() -> Vec<LayerRound> {
    with_ctx(|c| c.layer_rows.clone()).unwrap_or_default()
}

/// Copy of the latest per-client rows (the cumulative table).
pub fn client_rows() -> Vec<ClientRound> {
    with_ctx(|c| c.client_rows.clone()).unwrap_or_default()
}

/// Render the exposition text for the current registry.
pub fn metrics_exposition() -> String {
    with_ctx(|c| c.registry.exposition()).unwrap_or_default()
}

/// Render the JSON summary for the current registry.
pub fn metrics_json() -> String {
    with_ctx(|c| c.registry.json_summary()).unwrap_or_default()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn full_cfg() -> ObsCfg {
        ObsCfg { level: ObsLevel::Full, ..ObsCfg::default() }
    }

    #[test]
    fn disabled_records_nothing() {
        init(&ObsCfg::default()).unwrap();
        {
            let mut s = span("test.span");
            s.set_sim(1.0);
        }
        counter("test.count", 5);
        observe("test.histo", 1.0);
        assert_eq!(level(), ObsLevel::Off);
        assert!(!enabled());
        assert_eq!(counter_value("test.count"), 0);
        assert!(recent_spans().is_empty());
        assert!(finish().unwrap().is_empty());
    }

    #[test]
    fn full_level_records_spans_and_metrics() {
        init(&full_cfg()).unwrap();
        {
            let mut s = span("test.span");
            s.set_sim(2.0);
        }
        counter("test.count", 3);
        gauge("test.gauge", 7.5);
        observe("test.histo", 10.0);
        snapshot(0);
        let spans = recent_spans();
        assert_eq!(spans.len(), 1);
        assert_eq!(spans[0].name, "test.span");
        assert_eq!(spans[0].sim_s, 2.0);
        assert_eq!(counter_value("test.count"), 3);
        assert_eq!(gauge_value("test.gauge"), Some(7.5));
        let text = metrics_exposition();
        assert!(text.contains("fedluar_test_span_ns_count 1"), "span feeds its _ns histogram");
        assert!(text.contains("fedluar_test_count 3"));
        finish().unwrap();
        assert_eq!(level(), ObsLevel::Off, "finish clears the context");
    }

    #[test]
    fn metrics_level_disarms_spans_but_keeps_counters() {
        init(&ObsCfg { level: ObsLevel::Metrics, ..ObsCfg::default() }).unwrap();
        {
            let _s = span("test.span");
        }
        counter("test.count", 1);
        assert!(enabled());
        assert!(!tracing());
        assert_eq!(spans_recorded(), 0);
        assert_eq!(counter_value("test.count"), 1);
        finish().unwrap();
    }

    #[test]
    fn finish_writes_all_artifacts() {
        let dir = std::env::temp_dir().join("fedluar_obs_finish_test");
        let trace = dir.join("t.jsonl").to_str().unwrap().to_string();
        let prom = dir.join("m.prom").to_str().unwrap().to_string();
        let csv = dir.join("l.csv").to_str().unwrap().to_string();
        let ccsv = dir.join("c.csv").to_str().unwrap().to_string();
        init(&ObsCfg {
            level: ObsLevel::Full,
            trace_path: Some(trace.clone()),
            metrics_path: Some(prom.clone()),
            layer_csv: Some(csv.clone()),
            clients_csv: Some(ccsv.clone()),
        })
        .unwrap();
        {
            let _s = span("x.y");
        }
        counter("c", 1);
        let written = finish().unwrap();
        assert_eq!(written.len(), 5, "trace + prom + json + layer csv + clients csv: {written:?}");
        assert!(std::fs::read_to_string(&trace).unwrap().contains("\"span\":\"x.y\""));
        assert!(std::fs::read_to_string(&prom).unwrap().contains("fedluar_c 1"));
        let json_path = prom.strip_suffix(".prom").unwrap().to_string() + ".json";
        crate::json::Json::parse(&std::fs::read_to_string(json_path).unwrap()).unwrap();
        let csv_text = std::fs::read_to_string(&csv).unwrap();
        assert!(csv_text.starts_with(layers::CSV_HEADER));
        let ccsv_text = std::fs::read_to_string(&ccsv).unwrap();
        assert!(ccsv_text.starts_with(clients::CSV_HEADER));
    }

    #[test]
    fn client_rounds_replace_not_accumulate() {
        use crate::net::{ClientStats, LinkDist, LinkFleet};
        init(&ObsCfg { level: ObsLevel::Metrics, ..ObsCfg::default() }).unwrap();
        let fleet = LinkFleet::new(&LinkDist::default(), 3, 1);
        let mut stats = ClientStats::new(3);
        stats.record_dispatch(1, 1.0, 10);
        record_client_rounds(&stats, &fleet);
        stats.record_dispatch(1, 1.0, 10);
        record_client_rounds(&stats, &fleet);
        let rows = client_rows();
        assert_eq!(rows.len(), 3, "one row per client, not per call");
        assert_eq!(rows[1].dispatches, 2, "latest cumulative totals win");
        finish().unwrap();
    }

    #[test]
    fn init_off_clears_previous_context() {
        init(&full_cfg()).unwrap();
        counter("c", 1);
        init(&ObsCfg::default()).unwrap();
        assert_eq!(counter_value("c"), 0);
    }
}
