//! Flat-tensor substrate: the model crosses the HLO boundary as one
//! contiguous `f32` vector, and everything layer-wise in FedLUAR is
//! offset arithmetic over it. These kernels are the L3 hot path
//! (aggregation fallback, norms, server optimizer updates), written
//! to auto-vectorize and benchmarked in `benches/aggregation.rs`.

/// y += alpha * x
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

/// y = x (memcpy)
pub fn copy(x: &[f32], y: &mut [f32]) {
    y.copy_from_slice(x);
}

/// x *= alpha
pub fn scale(alpha: f32, x: &mut [f32]) {
    for xi in x.iter_mut() {
        *xi *= alpha;
    }
}

/// Sum of squares (single pass, f64 accumulator for stability).
pub fn ssq(x: &[f32]) -> f64 {
    x.iter().map(|&v| (v as f64) * (v as f64)).sum()
}

/// L2 norm.
pub fn norm(x: &[f32]) -> f64 {
    ssq(x).sqrt()
}

/// Dot product with f64 accumulator.
pub fn dot(x: &[f32], y: &[f32]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    x.iter().zip(y).map(|(&a, &b)| (a as f64) * (b as f64)).sum()
}

/// out = mean over rows of `rows` (each of length d). Scalar fallback
/// for when the Pallas-backed HLO aggregator can't be used (e.g. the
/// active-client count differs from the lowered `agg_clients`).
pub fn mean_rows(rows: &[&[f32]], out: &mut [f32]) {
    let a = rows.len();
    assert!(a > 0, "mean over zero rows");
    let inv = 1.0 / a as f32;
    out.copy_from_slice(rows[0]);
    for row in &rows[1..] {
        axpy(1.0, row, out);
    }
    scale(inv, out);
}

/// Blocked + thread-parallel mean over rows: splits `out` into column
/// ranges so each thread reduces its range over all rows with
/// streaming reads (scoped std threads; no external crates offline).
pub fn mean_rows_par(rows: &[&[f32]], out: &mut [f32]) {
    let a = rows.len();
    assert!(a > 0, "mean over zero rows");
    let d = out.len();
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    // Small vectors: threading overhead dominates; stay serial.
    if d < 64 * 1024 || threads < 2 {
        return mean_rows(rows, out);
    }
    let inv = 1.0 / a as f32;
    let chunk = d.div_ceil(threads);
    std::thread::scope(|scope| {
        for (ci, out_chunk) in out.chunks_mut(chunk).enumerate() {
            let rows = &rows;
            scope.spawn(move || {
                let start = ci * chunk;
                let end = start + out_chunk.len();
                out_chunk.copy_from_slice(&rows[0][start..end]);
                for row in &rows[1..] {
                    axpy(1.0, &row[start..end], out_chunk);
                }
                scale(inv, out_chunk);
            });
        }
    });
}

/// Weighted mean: out = sum_i w[i] * rows[i]; w need not sum to 1.
pub fn weighted_mean_rows(rows: &[&[f32]], w: &[f32], out: &mut [f32]) {
    assert_eq!(rows.len(), w.len());
    assert!(!rows.is_empty());
    out.iter_mut().for_each(|v| *v = 0.0);
    for (row, &wi) in rows.iter().zip(w) {
        axpy(wi, row, out);
    }
}

/// Cosine similarity; 0 when either vector is ~zero.
pub fn cosine(x: &[f32], y: &[f32]) -> f64 {
    let nx = norm(x);
    let ny = norm(y);
    if nx < 1e-12 || ny < 1e-12 {
        return 0.0;
    }
    dot(x, y) / (nx * ny)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn axpy_accumulates() {
        let x = [1.0f32, 2.0, 3.0];
        let mut y = [10.0f32, 20.0, 30.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, [12.0, 24.0, 36.0]);
    }

    #[test]
    fn ssq_and_norm() {
        let x = [3.0f32, 4.0];
        assert!((ssq(&x) - 25.0).abs() < 1e-9);
        assert!((norm(&x) - 5.0).abs() < 1e-9);
    }

    #[test]
    fn mean_rows_basic() {
        let r1 = vec![1.0f32; 5];
        let r2 = vec![3.0f32; 5];
        let mut out = vec![0.0f32; 5];
        mean_rows(&[&r1, &r2], &mut out);
        assert!(out.iter().all(|&v| (v - 2.0).abs() < 1e-6));
    }

    #[test]
    fn mean_rows_par_matches_serial() {
        let n = 200_000; // above the parallel threshold
        let rows: Vec<Vec<f32>> = (0..7)
            .map(|i| (0..n).map(|j| ((i * j) % 13) as f32).collect())
            .collect();
        let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
        let mut a = vec![0.0f32; n];
        let mut b = vec![0.0f32; n];
        mean_rows(&refs, &mut a);
        mean_rows_par(&refs, &mut b);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-5);
        }
    }

    #[test]
    fn weighted_mean_uniform_equals_mean() {
        let r1 = vec![1.0f32, 5.0];
        let r2 = vec![3.0f32, 7.0];
        let mut wm = vec![0.0f32; 2];
        weighted_mean_rows(&[&r1, &r2], &[0.5, 0.5], &mut wm);
        assert_eq!(wm, vec![2.0, 6.0]);
    }

    #[test]
    fn cosine_bounds() {
        let x = [1.0f32, 0.0];
        let y = [0.0f32, 1.0];
        assert!(cosine(&x, &x) > 0.999);
        assert!(cosine(&x, &y).abs() < 1e-9);
        assert_eq!(cosine(&x, &[0.0, 0.0]), 0.0);
    }

    #[test]
    #[should_panic]
    fn mean_of_nothing_panics() {
        let mut out = vec![0.0f32; 1];
        mean_rows(&[], &mut out);
    }
}
