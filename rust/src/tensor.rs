//! Flat-tensor substrate: the model crosses the HLO boundary as one
//! contiguous `f32` vector, and everything layer-wise in FedLUAR is
//! offset arithmetic over it. These kernels are the L3 hot path
//! (aggregation fallback, norms, server optimizer updates), written
//! to auto-vectorize and benchmarked in `benches/aggregation.rs`.

/// y += alpha * x
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

/// y = x (memcpy)
pub fn copy(x: &[f32], y: &mut [f32]) {
    y.copy_from_slice(x);
}

/// x *= alpha
pub fn scale(alpha: f32, x: &mut [f32]) {
    for xi in x.iter_mut() {
        *xi *= alpha;
    }
}

/// Sum of squares (single pass, f64 accumulator for stability).
pub fn ssq(x: &[f32]) -> f64 {
    x.iter().map(|&v| (v as f64) * (v as f64)).sum()
}

/// L2 norm.
pub fn norm(x: &[f32]) -> f64 {
    ssq(x).sqrt()
}

/// Dot product with f64 accumulator.
pub fn dot(x: &[f32], y: &[f32]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    x.iter().zip(y).map(|(&a, &b)| (a as f64) * (b as f64)).sum()
}

/// out = mean over rows of `rows` (each of length d). Scalar fallback
/// for when the Pallas-backed HLO aggregator can't be used (e.g. the
/// active-client count differs from the lowered `agg_clients`).
pub fn mean_rows(rows: &[&[f32]], out: &mut [f32]) {
    let a = rows.len();
    assert!(a > 0, "mean over zero rows");
    let inv = 1.0 / a as f32;
    out.copy_from_slice(rows[0]);
    for row in &rows[1..] {
        axpy(1.0, row, out);
    }
    scale(inv, out);
}

/// Blocked + thread-parallel mean over rows: splits `out` into column
/// ranges so each thread reduces its range over all rows with
/// streaming reads (scoped std threads; no external crates offline).
pub fn mean_rows_par(rows: &[&[f32]], out: &mut [f32]) {
    let a = rows.len();
    assert!(a > 0, "mean over zero rows");
    let d = out.len();
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    // Small vectors: threading overhead dominates; stay serial.
    if d < 64 * 1024 || threads < 2 {
        return mean_rows(rows, out);
    }
    let inv = 1.0 / a as f32;
    let chunk = d.div_ceil(threads);
    std::thread::scope(|scope| {
        for (ci, out_chunk) in out.chunks_mut(chunk).enumerate() {
            let rows = &rows;
            scope.spawn(move || {
                let start = ci * chunk;
                let end = start + out_chunk.len();
                out_chunk.copy_from_slice(&rows[0][start..end]);
                for row in &rows[1..] {
                    axpy(1.0, &row[start..end], out_chunk);
                }
                scale(inv, out_chunk);
            });
        }
    });
}

/// Weighted mean: out = sum_i w[i] * rows[i]; w need not sum to 1.
pub fn weighted_mean_rows(rows: &[&[f32]], w: &[f32], out: &mut [f32]) {
    assert_eq!(rows.len(), w.len());
    assert!(!rows.is_empty());
    out.iter_mut().for_each(|v| *v = 0.0);
    for (row, &wi) in rows.iter().zip(w) {
        axpy(wi, row, out);
    }
}

// ------------------------------------------------------- checked casts
//
// Bare `as` float->int casts silently saturate and map NaN to 0, which
// has bitten codec paths before (see docs/lints.md, rule D4). These
// helpers spell the clamping out; fedluar-lint requires them in the
// compress/ and net/ codec paths.

/// `round(total * ratio)` clamped to `[min, total]`, NaN-safe: a NaN
/// ratio yields `min`. Bit-identical to the old
/// `((total as f32) * ratio).round().clamp(min, total) as usize`
/// pattern for finite inputs (top-k keep counts, low-rank target
/// ranks, PruneFL mask sizes).
pub fn scaled_count(total: usize, ratio: f32, min: usize) -> usize {
    let raw = ((total as f32) * ratio).round();
    if !(raw >= min as f32) {
        // NaN and below-min land here; never exceed `total` unless the
        // caller's floor already does.
        return min.min(total.max(min));
    }
    if raw >= total as f32 {
        total
    } else {
        raw as usize
    }
}

/// `floor(x)` as a sample count: negative and NaN inputs yield 0,
/// values beyond `usize::MAX` saturate. Matches the saturating
/// semantics of `x.floor() as usize` exactly, but explicitly.
pub fn floor_count(x: f64) -> usize {
    if !(x > 0.0) {
        return 0;
    }
    let f = x.floor();
    if f >= usize::MAX as f64 {
        usize::MAX
    } else {
        f as usize
    }
}

/// Uniform-grid quantization index: `round((v - lo) / step)` clamped
/// to `[0, qmax]`. NaN and negative offsets map to 0, overshoot maps
/// to `qmax` — the same values the wire codec's old
/// `(((v - lo) / step).round() as i64).clamp(0, qmax as i64) as u32`
/// produced, without a bare float->int `as` cast on the data path.
pub fn quant_grid_index(v: f32, lo: f32, step: f32, qmax: u32) -> u32 {
    let t = ((v - lo) / step).round();
    if !(t > 0.0) {
        return 0;
    }
    if t >= qmax as f32 {
        qmax
    } else {
        t as u32
    }
}

/// Cosine similarity; 0 when either vector is ~zero.
pub fn cosine(x: &[f32], y: &[f32]) -> f64 {
    let nx = norm(x);
    let ny = norm(y);
    if nx < 1e-12 || ny < 1e-12 {
        return 0.0;
    }
    dot(x, y) / (nx * ny)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn axpy_accumulates() {
        let x = [1.0f32, 2.0, 3.0];
        let mut y = [10.0f32, 20.0, 30.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, [12.0, 24.0, 36.0]);
    }

    #[test]
    fn ssq_and_norm() {
        let x = [3.0f32, 4.0];
        assert!((ssq(&x) - 25.0).abs() < 1e-9);
        assert!((norm(&x) - 5.0).abs() < 1e-9);
    }

    #[test]
    fn mean_rows_basic() {
        let r1 = vec![1.0f32; 5];
        let r2 = vec![3.0f32; 5];
        let mut out = vec![0.0f32; 5];
        mean_rows(&[&r1, &r2], &mut out);
        assert!(out.iter().all(|&v| (v - 2.0).abs() < 1e-6));
    }

    #[test]
    fn mean_rows_par_matches_serial() {
        let n = 200_000; // above the parallel threshold
        let rows: Vec<Vec<f32>> = (0..7)
            .map(|i| (0..n).map(|j| ((i * j) % 13) as f32).collect())
            .collect();
        let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
        let mut a = vec![0.0f32; n];
        let mut b = vec![0.0f32; n];
        mean_rows(&refs, &mut a);
        mean_rows_par(&refs, &mut b);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-5);
        }
    }

    #[test]
    fn weighted_mean_uniform_equals_mean() {
        let r1 = vec![1.0f32, 5.0];
        let r2 = vec![3.0f32, 7.0];
        let mut wm = vec![0.0f32; 2];
        weighted_mean_rows(&[&r1, &r2], &[0.5, 0.5], &mut wm);
        assert_eq!(wm, vec![2.0, 6.0]);
    }

    #[test]
    fn cosine_bounds() {
        let x = [1.0f32, 0.0];
        let y = [0.0f32, 1.0];
        assert!(cosine(&x, &x) > 0.999);
        assert!(cosine(&x, &y).abs() < 1e-9);
        assert_eq!(cosine(&x, &[0.0, 0.0]), 0.0);
    }

    #[test]
    #[should_panic]
    fn mean_of_nothing_panics() {
        let mut out = vec![0.0f32; 1];
        mean_rows(&[], &mut out);
    }

    #[test]
    fn scaled_count_matches_legacy_cast() {
        for d in [1usize, 7, 40, 1000] {
            for ratio in [0.0f32, 0.1, 0.25, 0.5, 0.999, 1.0] {
                let legacy = (((d as f32) * ratio).round() as usize).clamp(1, d);
                assert_eq!(scaled_count(d, ratio, 1), legacy, "d={d} ratio={ratio}");
            }
        }
        // NaN ratio degrades to the floor instead of casting NaN to 0
        assert_eq!(scaled_count(40, f32::NAN, 1), 1);
        assert_eq!(scaled_count(40, f32::INFINITY, 1), 40);
        assert_eq!(scaled_count(0, 0.5, 1), 1, "empty total still honors the floor");
    }

    #[test]
    fn floor_count_matches_legacy_cast() {
        for x in [0.0f64, 0.3, 1.0, 2.7, 1e6, 1e6 + 0.999] {
            assert_eq!(floor_count(x), x.floor() as usize, "x={x}");
        }
        assert_eq!(floor_count(-3.2), 0);
        assert_eq!(floor_count(f64::NAN), 0);
        assert_eq!(floor_count(f64::INFINITY), usize::MAX);
    }

    #[test]
    fn quant_grid_index_matches_legacy_cast() {
        let qmax = 15u32;
        for (v, lo, step) in [
            (0.5f32, 0.0f32, 0.1f32),
            (0.0, 0.0, 0.1),
            (-2.0, 0.0, 0.1),
            (100.0, 0.0, 0.1),
            (0.349, 0.3, 0.0033),
        ] {
            let legacy = (((v - lo) / step).round() as i64).clamp(0, qmax as i64) as u32;
            assert_eq!(quant_grid_index(v, lo, step, qmax), legacy, "v={v} lo={lo} step={step}");
        }
        // NaN offsets map to the low grid point, never panic
        assert_eq!(quant_grid_index(f32::NAN, 0.0, 0.1, qmax), 0);
        assert_eq!(quant_grid_index(1.0, 0.0, 0.0, qmax), qmax, "inf/0-step saturates high");
    }
}
