//! `fedluar` — the FedLUAR coordinator CLI.
//!
//! Subcommands:
//!   run        one FL run (model x method x optimizer), CSV history
//!   info       inspect a model's artifacts / layer table
//!   exp        regenerate a paper table or figure (see `exp list`)
//!
//! Examples:
//!   fedluar run --model cnn --method luar:delta=2 --rounds 60
//!   fedluar run --model resnet8 --method quantize:levels=16
//!   fedluar exp table2 --quick
//!   fedluar exp fig1 --model cnn

#![allow(clippy::disallowed_methods)] // CLI driver reports real wall time (lint D2 allowlist)
use anyhow::{bail, Result};
use fedluar::cli::Args;
use fedluar::config::{ClientOptCfg, Method, RunConfig, ServerOptCfg};
use fedluar::exp;
use fedluar::fl::Server;
use fedluar::model::{artifacts_dir, ModelMeta};
use fedluar::net::{FaultsCfg, LinkDist, RoundMode, SamplerCfg};
use fedluar::obs;
use fedluar::obs::ObsLevel;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(argv: Vec<String>) -> Result<()> {
    let args = Args::parse(argv)?;
    match args.positional.first().map(String::as_str) {
        Some("run") => cmd_run(&args),
        Some("info") => cmd_info(&args),
        Some("exp") => exp::dispatch(&args),
        Some(other) => bail!("unknown subcommand {other}; try run | info | exp"),
        None => {
            println!("{}", HELP);
            Ok(())
        }
    }
}

const HELP: &str = "\
fedluar — Layer-wise Update Aggregation with Recycling (NeurIPS 2025 reproduction)

USAGE:
  fedluar run  --model <mlp|cnn|resnet8|transformer> [--method SPEC]
               [--rounds N] [--clients N] [--active N] [--alpha F]
               [--lr F] [--seed N] [--server-opt SPEC] [--mu-global F]
               [--mu-prev F] [--eval-every N] [--out results/run.csv]
               [--link-dist SPEC] [--round-mode SPEC] [--compute-s F]
               [--delta-frames [BOOL]] [--sampler SPEC] [--faults SPEC]
               [--obs off|metrics|full] [--obs-trace FILE]
               [--obs-metrics FILE] [--obs-layer-csv FILE]
               [--obs-clients-csv FILE] [--config FILE]
  fedluar info --model <name>
  fedluar exp  <table1|table2|table3|table4|table5|delta-sweep|alpha-sweep|
                client-sweep|fig1|fig3|curves|list> [--quick] [...]

METHOD SPECS:
  fedavg | luar:delta=2[,scheme=luar|random|top|bottom|grad_norm|deterministic]
  [,mode=recycle|drop] | quantize:levels=16 | binarize | prune:keep=0.5,every=50
  | dropout:rate=0.5 | lbgm:thresh=0.95 | topk:keep=0.1 | lowrank:ratio=0.25

SERVER OPT SPECS:
  sgd | adam:lr=0.9 | acg:lambda=0.7 | mut:alpha=0.5

NET SIMULATION (the net: config block; uploads are serialized wire
frames, so the Comm column measures real bytes):
  --link-dist   uniform:up=20,down=100,rtt=0.05
              | lognormal:up=10,down=50,sigma=0.75,rtt=0.05
              | bimodal:fast_frac=0.8,fast_up=50,slow_up=2,down=100,rtt=0.05
  --round-mode  sync                sync FL: slowest active client bounds the round
              | deadline:s=2.5      close at a time budget, aggregate arrivals
              | buffered:k=8        FedBuff-style: flush every k arrivals,
                                    staleness-discounted
              | async:c=8,s=poly,a=0.5
                                    fully async: c clients in flight (c=all pins
                                    to --active), per-client model versions, each
                                    upload weighted by its version gap (s=const
                                    for no discount; s=poly => (1+gap)^-a); a
                                    round record = one closed model version
  --compute-s   mean local-compute seconds per client per round
  --delta-frames  residual (delta) framing: encode uplinks/broadcasts
                  against per-client reference snapshots, self-contained
                  fallback when no valid reference exists. Lossless and
                  ledger-only — trajectories match dense framing bit for
                  bit, only recorded bytes shrink (docs/wire.md)
  --sampler     uniform             legacy cohort draw, bit-exact (default)
              | speed:pow=1         bias the draw toward clients with lower
                                    measured mean upload latency (weight
                                    mean_upload_s^-pow; unmeasured clients get
                                    the mean weight, a cold table is uniform)
              | staleness:cap=2     bounded staleness: async uploads with
                                    version gap > cap are held out of the
                                    aggregation mean (bytes/clock still paid)
  --faults      off                 no fault injection (default, bit-identical
                                    to a build without the fault layer)
              | drop:p=0.1          lose upload attempts in transit
              | outage:p=0.05,len=30  drop + take the link down for len secs
              | corrupt:p=0.02      flip a byte in the framed payload (always
                                    caught by the wire integrity trailer —
                                    corrupted updates are never aggregated)
              | mixed:drop=F,outage=F,len=S,corrupt=F   all three at once
                every spec also takes retries=N,backoff=S,timeout=S,quorum=N
                (bounded retry w/ exponential backoff; an aggregation closing
                below quorum recycles the missing layers instead of stalling);
                seeded per (client, version, attempt) — reproducible chaos.
                See docs/faults.md
  (config files also accept deadline_s = F, buffer_k = N,
   delta_frames = true|false, sampler = SPEC, and faults = SPEC)

OBSERVABILITY (the obs: config block; telemetry is read-only — an
`--obs full` run is bit-identical to `--obs off`):
  --obs         off       no telemetry, near-zero overhead (default)
              | metrics   counters/gauges/histograms + per-layer CSV
              | full      metrics + span tracing (ring buffer + JSONL)
  --obs-trace     span JSONL path     (default <out-stem>_trace.jsonl, full only)
  --obs-metrics   exposition path     (default <out-stem>_metrics.prom;
                                       a .json summary is written next to it)
  --obs-layer-csv per-layer rounds    (default <out-stem>_layers.csv:
                                       score, uploaded, recycle age, wire
                                       bytes — Figure 3 / kappa decomposition)
  --obs-clients-csv per-client table  (default <out-stem>_clients.csv:
                                       link speed + bucket, dispatches,
                                       absorbed, held_stale, mean upload
                                       seconds, bytes — sampler fairness)
  (config files accept obs_level / obs_trace / obs_metrics / obs_layer_csv /
   obs_clients_csv; the value `none` clears a path)

STATIC ANALYSIS:
  cargo run --release --bin fedluar-lint   in-tree determinism & panic-safety
                                           lints (D1-D4, P1, W1); rule catalog
                                           and suppression workflow in
                                           docs/lints.md
";

fn cmd_run(args: &Args) -> Result<()> {
    let mut cfg = match args.get("config") {
        Some(path) => RunConfig::load_file(path)?,
        None => RunConfig::benchmark(args.get_or("model", "mlp"))?,
    };
    if let Some(m) = args.get("model") {
        if cfg.model != m {
            cfg = RunConfig::benchmark(m)?;
        }
    }
    if let Some(spec) = args.get("method") {
        cfg.method = Method::parse(spec)?;
    }
    if let Some(spec) = args.get("server-opt") {
        cfg.server_opt = ServerOptCfg::parse(spec)?;
    }
    cfg.rounds = args.get_usize("rounds", cfg.rounds)?;
    cfg.num_clients = args.get_usize("clients", cfg.num_clients)?;
    cfg.active_clients = args.get_usize("active", cfg.active_clients)?;
    cfg.alpha = args.get_f64("alpha", cfg.alpha)?;
    cfg.lr = args.get_f64("lr", cfg.lr as f64)? as f32;
    cfg.seed = args.get_u64("seed", cfg.seed)?;
    cfg.eval_every = args.get_usize("eval-every", cfg.eval_every)?;
    cfg.client_opt = ClientOptCfg {
        mu_global: args.get_f64("mu-global", cfg.client_opt.mu_global as f64)? as f32,
        mu_prev: args.get_f64("mu-prev", cfg.client_opt.mu_prev as f64)? as f32,
    };
    if let Some(spec) = args.get("link-dist") {
        cfg.net.link_dist = LinkDist::parse(spec)?;
    }
    if let Some(spec) = args.get("round-mode") {
        cfg.net.round_mode = RoundMode::parse(spec)?;
    }
    cfg.net.compute_s = args.get_f64("compute-s", cfg.net.compute_s)?;
    // Bare `--delta-frames` enables; `--delta-frames false` disables a
    // config-file setting.
    if let Some(v) = args.get_parse::<bool>("delta-frames")? {
        cfg.net.delta_frames = v;
    }
    if let Some(spec) = args.get("sampler") {
        cfg.net.sampler = SamplerCfg::parse(spec)?;
    }
    if let Some(spec) = args.get("faults") {
        cfg.net.faults = FaultsCfg::parse(spec)?;
    }
    if let Some(v) = args.get("obs") {
        cfg.obs.level = ObsLevel::parse(v)?;
    }
    if let Some(v) = args.get("obs-trace") {
        cfg.obs.trace_path = Some(v.to_string());
    }
    if let Some(v) = args.get("obs-metrics") {
        cfg.obs.metrics_path = Some(v.to_string());
    }
    if let Some(v) = args.get("obs-layer-csv") {
        cfg.obs.layer_csv = Some(v.to_string());
    }
    if let Some(v) = args.get("obs-clients-csv") {
        cfg.obs.clients_csv = Some(v.to_string());
    }
    let out = args.get_or("out", "results/run.csv").to_string();
    args.check_unused()?;

    // Default telemetry artifact paths derive from the history CSV so
    // one run's outputs land together.
    if cfg.obs.level != ObsLevel::Off {
        let stem = out.strip_suffix(".csv").unwrap_or(&out).to_string();
        if cfg.obs.metrics_path.is_none() {
            cfg.obs.metrics_path = Some(format!("{stem}_metrics.prom"));
        }
        if cfg.obs.layer_csv.is_none() {
            cfg.obs.layer_csv = Some(format!("{stem}_layers.csv"));
        }
        if cfg.obs.clients_csv.is_none() {
            cfg.obs.clients_csv = Some(format!("{stem}_clients.csv"));
        }
        if cfg.obs.level == ObsLevel::Full && cfg.obs.trace_path.is_none() {
            cfg.obs.trace_path = Some(format!("{stem}_trace.jsonl"));
        }
    }
    obs::init(&cfg.obs)?;

    println!(
        "# fedluar run: {} / {} / {} / net {} over {} / sampler {} / faults {}",
        cfg.model,
        cfg.method.label(),
        cfg.server_opt.label(),
        cfg.net.round_mode.spec_string(),
        cfg.net.link_dist.spec_string(),
        cfg.net.sampler.spec_string(),
        cfg.net.faults.spec_string()
    );
    let mut server = Server::new(cfg)?;
    let t0 = std::time::Instant::now();
    for _ in 0..server.cfg.rounds {
        server.run_round()?;
        if let Some(rec) = server.history.records.last() {
            if rec.round == server.round {
                println!(
                    "round {:4}  train_loss {:.4}  test_acc {:5.2}%  comm {:.3}  kappa {:.4}",
                    rec.round,
                    rec.train_loss,
                    rec.test_acc * 100.0,
                    rec.comm_ratio,
                    rec.kappa
                );
            }
        }
    }
    server.history.write_csv(&out)?;
    if !server.history.absorbs.is_empty() {
        let absorb_out = match out.strip_suffix(".csv") {
            Some(stem) => format!("{stem}_absorbs.csv"),
            None => format!("{out}.absorbs.csv"),
        };
        server.history.write_absorb_csv(&absorb_out)?;
        println!("# per-absorb telemetry -> {absorb_out}");
    }
    let stats = server.engine.stats();
    println!(
        "# done in {:.1}s wall ({} train execs {:.1}s, {} evals {:.1}s, {} aggs {:.2}s)",
        t0.elapsed().as_secs_f64(),
        stats.train_calls,
        stats.train_secs,
        stats.eval_calls,
        stats.eval_secs,
        stats.agg_calls,
        stats.agg_secs,
    );
    println!(
        "# final: acc {:.2}%  comm_ratio {:.3}  max_kappa {:.4} (theorem2 bound 1/16 = 0.0625)",
        server.history.final_acc() * 100.0,
        server.history.final_comm_ratio(),
        server.history.max_kappa()
    );
    println!(
        "# net: {} wire bytes up, {} stragglers dropped, sim wall-clock from slowest survivors",
        server.comm.up_bytes, server.dropped_stragglers
    );
    println!("# history -> {out}");
    for p in obs::finish()? {
        println!("# telemetry -> {p}");
    }
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let model = args.get_or("model", "mlp").to_string();
    args.check_unused()?;
    let meta = ModelMeta::load(artifacts_dir(), &model)?;
    println!("model        {}", meta.model);
    println!("dim          {}", meta.dim);
    println!("layers       {}", meta.num_layers());
    println!("input        {:?} ({})", meta.input_shape, meta.input_dtype);
    println!("classes      {}", meta.num_classes);
    println!("tau/batch    {}/{}", meta.tau, meta.batch);
    println!("agg clients  {}", meta.agg_clients);
    println!("init sha256  {}", &meta.init_sha256[..16]);
    println!("\n{:<14} {:>10} {:>10} {:>8}", "layer", "offset", "size", "share");
    for l in &meta.layers {
        println!(
            "{:<14} {:>10} {:>10} {:>7.2}%",
            l.name,
            l.offset,
            l.size,
            100.0 * l.size as f64 / meta.dim as f64
        );
    }
    Ok(())
}
