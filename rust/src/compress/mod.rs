//! Compression baselines (Table 2 comparators).
//!
//! Each implements `UpdateCompressor`: lossy in-place transformation of
//! one client's local update before upload, returning the bytes that
//! would cross the wire. The server then averages the compressed
//! updates — the same pipeline the original methods use.
//!
//! Substitutions vs. the original papers are documented per-module and
//! in DESIGN.md (FedPara -> randomized low-rank; FedBAT -> sign
//! binarization with error feedback).

mod binarize;
mod dropout;
mod lbgm;
mod lowrank;
mod prune;
mod quantize;
mod topk;

pub use binarize::Binarize;
pub use dropout::DropoutAvg;
pub use lbgm::Lbgm;
pub use lowrank::LowRank;
pub(crate) use lowrank::{lowrank_factor, lowrank_matrix_shape, lowrank_plan};
pub use prune::Prune;
pub use quantize::Quantize;
pub use topk::TopK;

use crate::config::Method;
use crate::model::ModelMeta;
use crate::net::wire::WireHint;
use crate::rng::Rng;

/// One client-update compressor. Implementations may keep per-client
/// state (error feedback, look-back anchors) keyed by `client_id`.
pub trait UpdateCompressor {
    /// Compress `update` in place; return upload bytes for this client.
    /// (The returned analytic estimate predates the wire codecs; the
    /// round loop now measures `net::wire` frame lengths instead.)
    fn compress(
        &mut self,
        client_id: usize,
        update: &mut [f32],
        meta: &ModelMeta,
        round: usize,
        rng: &mut Rng,
    ) -> u64;

    /// How the *most recent* `compress` output should be framed on the
    /// wire (`net::wire::encode_update`). Queried immediately after
    /// `compress`, before the next client's call.
    fn wire_hint(&self) -> WireHint {
        WireHint::Dense
    }

    fn label(&self) -> &'static str;
}

/// Identity compressor (FedAvg): full f32 upload.
pub struct Identity;

impl UpdateCompressor for Identity {
    fn compress(
        &mut self,
        _client: usize,
        update: &mut [f32],
        _meta: &ModelMeta,
        _round: usize,
        _rng: &mut Rng,
    ) -> u64 {
        (update.len() as u64) * 4
    }

    fn label(&self) -> &'static str {
        "identity"
    }
}

/// Build the compressor for a config method (LUAR and FedAvg use
/// Identity; LUAR's savings come from skipped layers, not lossy
/// compression).
pub fn build(method: &Method) -> Box<dyn UpdateCompressor> {
    match method {
        Method::FedAvg | Method::Luar { .. } => Box::new(Identity),
        Method::Quantize { levels } => Box::new(Quantize::new(*levels)),
        Method::Binarize => Box::new(Binarize::new()),
        Method::Prune { keep_ratio, reconfig_every } => {
            Box::new(Prune::new(*keep_ratio, *reconfig_every))
        }
        Method::DropoutAvg { rate } => Box::new(DropoutAvg::new(*rate)),
        Method::Lbgm { threshold } => Box::new(Lbgm::new(*threshold)),
        Method::TopK { keep_ratio } => Box::new(TopK::new(*keep_ratio)),
        Method::LowRank { rank_ratio } => Box::new(LowRank::new(*rank_ratio)),
    }
}

#[cfg(test)]
pub(crate) mod testutil {
    use crate::model::ModelMeta;
    use std::path::PathBuf;

    /// 2-layer toy meta: layer0 = 6x4 dense "matrix" (24+4), layer1 = 12.
    pub fn toy_meta() -> ModelMeta {
        ModelMeta::from_json(
            r#"{
            "model":"toy","dim":40,"num_classes":2,
            "input_shape":[6],"input_dtype":"f32",
            "tau":2,"batch":3,"eval_batch":8,"agg_clients":4,"momentum":0.9,
            "layers":[
              {"name":"fc0","kind":"dense","offset":0,"size":28,
               "arrays":[{"name":"w","shape":[6,4],"offset":0,"size":24},
                          {"name":"b","shape":[4],"offset":24,"size":4}]},
              {"name":"fc1","kind":"dense","offset":28,"size":12,
               "arrays":[{"name":"w","shape":[4,3],"offset":28,"size":12}]}
            ],
            "artifacts":{"train":"t","eval":"e","agg":"g","init":"i"},
            "init_sha256":"x"
        }"#,
            PathBuf::from("/tmp"),
        )
        .unwrap()
    }

    pub fn toy_update(seed: u64, dim: usize) -> Vec<f32> {
        let mut rng = crate::rng::Rng::seed_from_u64(seed);
        (0..dim).map(|_| rng.normal_f32(0.0, 1.0)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::testutil::*;
    use super::*;
    use crate::config::SelectionScheme;

    #[test]
    fn identity_is_lossless_full_cost() {
        let meta = toy_meta();
        let mut u = toy_update(1, meta.dim);
        let orig = u.clone();
        let mut rng = Rng::seed_from_u64(0);
        let bytes = Identity.compress(0, &mut u, &meta, 0, &mut rng);
        assert_eq!(u, orig);
        assert_eq!(bytes, 160);
    }

    #[test]
    fn build_covers_all_methods() {
        let methods = [
            Method::FedAvg,
            Method::luar(1),
            Method::Quantize { levels: 16 },
            Method::Binarize,
            Method::Prune { keep_ratio: 0.5, reconfig_every: 10 },
            Method::DropoutAvg { rate: 0.5 },
            Method::Lbgm { threshold: 0.9 },
            Method::TopK { keep_ratio: 0.1 },
            Method::LowRank { rank_ratio: 0.25 },
        ];
        for m in methods {
            let c = build(&m);
            assert!(!c.label().is_empty());
        }
        let _ = SelectionScheme::Luar;
    }
}
