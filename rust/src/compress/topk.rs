//! Classic top-k update sparsification (Alistarh et al.): keep the
//! `keep_ratio` largest-magnitude coordinates, zero the rest. Cost is
//! k values + k indices (4+4 bytes each).

use super::UpdateCompressor;
use crate::model::ModelMeta;
use crate::net::wire::WireHint;
use crate::rng::Rng;

pub struct TopK {
    keep_ratio: f32,
}

impl TopK {
    pub fn new(keep_ratio: f32) -> Self {
        assert!((0.0..=1.0).contains(&keep_ratio));
        TopK { keep_ratio }
    }
}

impl UpdateCompressor for TopK {
    fn compress(
        &mut self,
        _client: usize,
        update: &mut [f32],
        _meta: &ModelMeta,
        _round: usize,
        _rng: &mut Rng,
    ) -> u64 {
        let d = update.len();
        let k = crate::tensor::scaled_count(d, self.keep_ratio, 1);
        if k == d {
            return (d as u64) * 4;
        }
        // Select the k-th largest |value| via select_nth on a copy.
        // total_cmp: NaN magnitudes order as the largest — the partition
        // never panics and the threshold is deterministic (D3).
        let mut mags: Vec<f32> = update.iter().map(|v| v.abs()).collect();
        let (_, kth, _) = mags.select_nth_unstable_by(d - k, |a, b| a.total_cmp(b));
        let thresh = *kth;
        let mut kept = 0usize;
        for v in update.iter_mut() {
            if v.abs() >= thresh && kept < k {
                kept += 1;
            } else {
                *v = 0.0;
            }
        }
        (kept as u64) * 8
    }

    fn wire_hint(&self) -> WireHint {
        WireHint::Sparse
    }

    fn label(&self) -> &'static str {
        "topk"
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::*;
    use super::*;

    #[test]
    fn keeps_exactly_k_largest() {
        let meta = toy_meta();
        let mut u: Vec<f32> = (0..meta.dim).map(|i| i as f32 - 20.0).collect();
        let mut rng = Rng::seed_from_u64(0);
        let bytes = TopK::new(0.25).compress(0, &mut u, &meta, 0, &mut rng);
        let nz = u.iter().filter(|&&v| v != 0.0).count();
        assert_eq!(nz, 10);
        assert_eq!(bytes, 80);
        // the largest magnitude (-20) survives
        assert!(u.contains(&-20.0));
    }

    #[test]
    fn full_ratio_is_identity() {
        let meta = toy_meta();
        let orig = toy_update(1, meta.dim);
        let mut u = orig.clone();
        let mut rng = Rng::seed_from_u64(1);
        let bytes = TopK::new(1.0).compress(0, &mut u, &meta, 0, &mut rng);
        assert_eq!(u, orig);
        assert_eq!(bytes, 160);
    }

    #[test]
    fn tiny_ratio_keeps_at_least_one() {
        let meta = toy_meta();
        let mut u = toy_update(2, meta.dim);
        let mut rng = Rng::seed_from_u64(2);
        TopK::new(0.0).compress(0, &mut u, &meta, 0, &mut rng);
        assert_eq!(u.iter().filter(|&&v| v != 0.0).count(), 1);
    }

    #[test]
    fn nan_lanes_never_panic_and_zero_out() {
        // Regression for the PR 7 bug class (docs/lints.md, rule D3):
        // partial_cmp().unwrap() panicked when a NaN magnitude hit the
        // selection. With total_cmp the NaN sorts above the threshold,
        // but `NaN.abs() >= thresh` is false, so NaN lanes are zeroed —
        // the frame stays finite and deterministic.
        let meta = toy_meta();
        let run = || {
            let mut u = toy_update(5, meta.dim);
            u[0] = f32::NAN;
            u[17] = f32::NAN;
            let mut rng = Rng::seed_from_u64(4);
            TopK::new(0.25).compress(0, &mut u, &meta, 0, &mut rng);
            u.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        };
        let a = run();
        let b = run();
        assert_eq!(a, b, "same-seed compress must be bit-identical");
        assert!(a.iter().all(|&bits| !f32::from_bits(bits).is_nan()), "NaN leaked into frame");
        let nz = a.iter().filter(|&&bits| f32::from_bits(bits) != 0.0).count();
        assert!(nz <= 10, "kept {nz} > k");
    }
}
