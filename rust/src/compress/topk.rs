//! Classic top-k update sparsification (Alistarh et al.): keep the
//! `keep_ratio` largest-magnitude coordinates, zero the rest. Cost is
//! k values + k indices (4+4 bytes each).

use super::UpdateCompressor;
use crate::model::ModelMeta;
use crate::net::wire::WireHint;
use crate::rng::Rng;

pub struct TopK {
    keep_ratio: f32,
}

impl TopK {
    pub fn new(keep_ratio: f32) -> Self {
        assert!((0.0..=1.0).contains(&keep_ratio));
        TopK { keep_ratio }
    }
}

impl UpdateCompressor for TopK {
    fn compress(
        &mut self,
        _client: usize,
        update: &mut [f32],
        _meta: &ModelMeta,
        _round: usize,
        _rng: &mut Rng,
    ) -> u64 {
        let d = update.len();
        let k = (((d as f32) * self.keep_ratio).round() as usize).clamp(1, d);
        if k == d {
            return (d as u64) * 4;
        }
        // Select the k-th largest |value| via select_nth on a copy.
        let mut mags: Vec<f32> = update.iter().map(|v| v.abs()).collect();
        let (_, kth, _) = mags.select_nth_unstable_by(d - k, |a, b| a.partial_cmp(b).unwrap());
        let thresh = *kth;
        let mut kept = 0usize;
        for v in update.iter_mut() {
            if v.abs() >= thresh && kept < k {
                kept += 1;
            } else {
                *v = 0.0;
            }
        }
        (kept as u64) * 8
    }

    fn wire_hint(&self) -> WireHint {
        WireHint::Sparse
    }

    fn label(&self) -> &'static str {
        "topk"
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::*;
    use super::*;

    #[test]
    fn keeps_exactly_k_largest() {
        let meta = toy_meta();
        let mut u: Vec<f32> = (0..meta.dim).map(|i| i as f32 - 20.0).collect();
        let mut rng = Rng::seed_from_u64(0);
        let bytes = TopK::new(0.25).compress(0, &mut u, &meta, 0, &mut rng);
        let nz = u.iter().filter(|&&v| v != 0.0).count();
        assert_eq!(nz, 10);
        assert_eq!(bytes, 80);
        // the largest magnitude (-20) survives
        assert!(u.contains(&-20.0));
    }

    #[test]
    fn full_ratio_is_identity() {
        let meta = toy_meta();
        let orig = toy_update(1, meta.dim);
        let mut u = orig.clone();
        let mut rng = Rng::seed_from_u64(1);
        let bytes = TopK::new(1.0).compress(0, &mut u, &meta, 0, &mut rng);
        assert_eq!(u, orig);
        assert_eq!(bytes, 160);
    }

    #[test]
    fn tiny_ratio_keeps_at_least_one() {
        let meta = toy_meta();
        let mut u = toy_update(2, meta.dim);
        let mut rng = Rng::seed_from_u64(2);
        TopK::new(0.0).compress(0, &mut u, &meta, 0, &mut rng);
        assert_eq!(u.iter().filter(|&&v| v != 0.0).count(), 1);
    }
}
