//! FedBAT substitute: per-layer sign binarization with error feedback.
//!
//! FedBAT learns its binarization thresholds jointly with training;
//! that coupling needs the training graph. We keep the comm ratio
//! (1 bit/element + one f32 scale per layer ≈ 1/32) and the noise type
//! (sign noise) with the standard signSGD-style compressor: per-layer
//! scale alpha = mean(|x|), q(x) = alpha * sign(x), plus per-client
//! *error feedback* (the residual x - q(x) is added to the next
//! round's update), which is what makes sign compression converge in
//! practice. Documented in DESIGN.md §Substitutions.

use super::UpdateCompressor;
use crate::model::ModelMeta;
use crate::net::wire::WireHint;
use crate::rng::Rng;
use std::collections::BTreeMap;

pub struct Binarize {
    /// Per-client error-feedback residuals. BTreeMap, not HashMap: the
    /// map is keyed per client so lookup order is fixed today, but any
    /// future whole-map iteration (e.g. state snapshots) must already
    /// be sorted to keep frames bit-identical (docs/lints.md, rule D1).
    residuals: BTreeMap<usize, Vec<f32>>,
}

impl Binarize {
    pub fn new() -> Self {
        Binarize { residuals: BTreeMap::new() }
    }
}

impl Default for Binarize {
    fn default() -> Self {
        Self::new()
    }
}

impl UpdateCompressor for Binarize {
    fn compress(
        &mut self,
        client: usize,
        update: &mut [f32],
        meta: &ModelMeta,
        _round: usize,
        _rng: &mut Rng,
    ) -> u64 {
        let res = self.residuals.entry(client).or_insert_with(|| vec![0.0; update.len()]);
        // Carry in last round's residual, then quantize, then store the
        // new residual in one pass per layer.
        for lm in &meta.layers {
            let range = lm.offset..lm.offset + lm.size;
            let sl = &mut update[range.clone()];
            let rs = &mut res[range];
            let mut abs_sum = 0.0f32;
            for (u, r) in sl.iter_mut().zip(rs.iter()) {
                *u += r;
                abs_sum += u.abs();
            }
            let alpha = abs_sum / lm.size as f32;
            for (u, r) in sl.iter_mut().zip(rs.iter_mut()) {
                let q = if *u >= 0.0 { alpha } else { -alpha };
                *r = *u - q;
                *u = q;
            }
        }
        // 1 bit per element + one f32 scale per layer
        ((update.len() as u64) + 7) / 8 + (meta.layers.len() as u64) * 4
    }

    fn wire_hint(&self) -> WireHint {
        // ±alpha per layer: the codec recovers alpha as max |v|.
        WireHint::SignBits
    }

    fn label(&self) -> &'static str {
        "fedbat"
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::*;
    use super::*;

    #[test]
    fn output_is_two_valued_per_layer() {
        let meta = toy_meta();
        let mut u = toy_update(1, meta.dim);
        let mut rng = Rng::seed_from_u64(0);
        Binarize::new().compress(0, &mut u, &meta, 0, &mut rng);
        for lm in &meta.layers {
            let sl = &u[lm.offset..lm.offset + lm.size];
            let mut vals: Vec<f32> = sl.to_vec();
            vals.sort_by(|a, b| a.total_cmp(b));
            vals.dedup();
            assert!(vals.len() <= 2, "layer {} has {} distinct values", lm.name, vals.len());
            if vals.len() == 2 {
                assert!((vals[0] + vals[1]).abs() < 1e-6, "not symmetric: {vals:?}");
            }
        }
    }

    #[test]
    fn error_feedback_accumulates() {
        // Feeding the same update twice: second output must differ
        // because the residual from round 1 is carried in.
        let meta = toy_meta();
        let base = toy_update(2, meta.dim);
        let mut bin = Binarize::new();
        let mut rng = Rng::seed_from_u64(1);
        let mut u1 = base.clone();
        bin.compress(7, &mut u1, &meta, 0, &mut rng);
        let mut u2 = base.clone();
        bin.compress(7, &mut u2, &meta, 1, &mut rng);
        assert_ne!(u1, u2, "residual had no effect");
        // error feedback keeps long-run sum close: sum of quantized over
        // 20 rounds approaches 20x the true update in l2 direction
        let mut acc = vec![0.0f64; meta.dim];
        let mut bin2 = Binarize::new();
        for r in 0..50 {
            let mut u = base.clone();
            bin2.compress(3, &mut u, &meta, r, &mut rng);
            for (a, &v) in acc.iter_mut().zip(&u) {
                *a += v as f64;
            }
        }
        let scale = 50.0;
        let err: f64 = acc
            .iter()
            .zip(&base)
            .map(|(a, &b)| (a / scale - b as f64).powi(2))
            .sum::<f64>()
            .sqrt();
        let norm: f64 = base.iter().map(|&b| (b as f64).powi(2)).sum::<f64>().sqrt();
        assert!(err < 0.35 * norm, "EF long-run error {err} vs norm {norm}");
    }

    #[test]
    fn clients_have_independent_residuals() {
        let meta = toy_meta();
        let base = toy_update(3, meta.dim);
        let mut bin = Binarize::new();
        let mut rng = Rng::seed_from_u64(2);
        let mut a1 = base.clone();
        bin.compress(0, &mut a1, &meta, 0, &mut rng);
        // client 1 first-time compress of same input equals client 0's
        let mut b1 = base.clone();
        bin.compress(1, &mut b1, &meta, 0, &mut rng);
        assert_eq!(a1, b1);
    }

    #[test]
    fn byte_cost_is_about_one_bit_per_param() {
        let meta = toy_meta();
        let mut u = toy_update(4, meta.dim);
        let mut rng = Rng::seed_from_u64(3);
        let bytes = Binarize::new().compress(0, &mut u, &meta, 0, &mut rng);
        assert_eq!(bytes, 40_u64.div_ceil(8) + 2 * 4);
    }

    #[test]
    fn nan_input_never_panics_and_is_deterministic() {
        // Regression for the PR 7 bug class (docs/lints.md, rule D3):
        // the two-valued check above used partial_cmp().unwrap(), which
        // panicked if a NaN update reached the sort. The compressor
        // itself propagates NaN through alpha (sign output stays ±NaN
        // alpha) but must do so identically on every run.
        let meta = toy_meta();
        let run = || {
            let mut bin = Binarize::new();
            let mut rng = Rng::seed_from_u64(9);
            let mut out = Vec::new();
            for round in 0..3 {
                let mut u = toy_update(6, meta.dim);
                u[5] = f32::NAN;
                bin.compress(0, &mut u, &meta, round, &mut rng);
                out = u.iter().map(|v| v.to_bits()).collect::<Vec<_>>();
            }
            out
        };
        assert_eq!(run(), run(), "NaN input must not perturb determinism");
    }
}
