//! PruneFL-style magnitude pruning (Jiang et al.).
//!
//! PruneFL maintains a global pruning mask over model parameters,
//! reconfigured periodically from accumulated importance; clients only
//! train and communicate unpruned coordinates. We reproduce the
//! communication pipeline: a shared mask of the top `keep_ratio`
//! coordinates by accumulated |update| magnitude, refreshed every
//! `reconfig_every` rounds. Because the mask is shared server state,
//! no index transmission is needed — cost = keep_ratio * d * 4 bytes.

use super::UpdateCompressor;
use crate::model::ModelMeta;
use crate::net::wire::WireHint;
use crate::rng::Rng;

pub struct Prune {
    keep_ratio: f32,
    reconfig_every: usize,
    mask: Vec<bool>,
    /// Accumulated |update| importance since the last reconfiguration.
    importance: Vec<f64>,
    last_reconfig: Option<usize>,
}

impl Prune {
    pub fn new(keep_ratio: f32, reconfig_every: usize) -> Self {
        assert!((0.0..=1.0).contains(&keep_ratio));
        Prune {
            keep_ratio,
            reconfig_every: reconfig_every.max(1),
            mask: Vec::new(),
            importance: Vec::new(),
            last_reconfig: None,
        }
    }

    pub fn kept(&self) -> usize {
        self.mask.iter().filter(|&&b| b).count()
    }

    fn reconfigure(&mut self, d: usize) {
        let keep = crate::tensor::scaled_count(d, self.keep_ratio, 1);
        let mut idx: Vec<usize> = (0..d).collect();
        // total_cmp: NaN importance (from NaN updates) sorts as the
        // largest magnitude — deterministic, never a sort panic (D3).
        idx.sort_by(|&a, &b| {
            self.importance[b].total_cmp(&self.importance[a]).then(a.cmp(&b))
        });
        self.mask = vec![false; d];
        for &i in idx.iter().take(keep) {
            self.mask[i] = true;
        }
        self.importance.iter_mut().for_each(|v| *v = 0.0);
    }
}

impl UpdateCompressor for Prune {
    fn compress(
        &mut self,
        _client: usize,
        update: &mut [f32],
        _meta: &ModelMeta,
        round: usize,
        _rng: &mut Rng,
    ) -> u64 {
        let d = update.len();
        if self.importance.len() != d {
            self.importance = vec![0.0; d];
            // first round: keep everything until importance accrues
            self.mask = vec![true; d];
            self.last_reconfig = Some(round);
        }
        for (imp, &u) in self.importance.iter_mut().zip(update.iter()) {
            *imp += u.abs() as f64;
        }
        if round.saturating_sub(self.last_reconfig.unwrap_or(0)) >= self.reconfig_every
            || (self.last_reconfig == Some(round) && round > 0)
        {
            self.reconfigure(d);
            self.last_reconfig = Some(round);
        }
        // First reconfig happens as soon as we have reconfig_every rounds
        // of importance; before that the mask may still be all-true.
        if self.mask.iter().all(|&b| b) && round >= self.reconfig_every {
            self.reconfigure(d);
            self.last_reconfig = Some(round);
        }
        let mut kept = 0u64;
        for (u, &m) in update.iter_mut().zip(&self.mask) {
            if m {
                kept += 1;
            } else {
                *u = 0.0;
            }
        }
        kept * 4
    }

    fn wire_hint(&self) -> WireHint {
        // The shared mask travels as an explicit bitmap (PruneFL's
        // reconfiguration broadcast, amortized onto every frame).
        WireHint::Bitmap
    }

    fn label(&self) -> &'static str {
        "prunefl"
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::*;
    use super::*;

    #[test]
    fn first_rounds_are_dense() {
        let meta = toy_meta();
        let mut p = Prune::new(0.25, 5);
        let mut rng = Rng::seed_from_u64(0);
        let mut u = toy_update(1, meta.dim);
        let bytes = p.compress(0, &mut u, &meta, 0, &mut rng);
        assert_eq!(bytes, 40 * 4, "round 0 should be dense");
    }

    #[test]
    fn mask_sparsifies_after_reconfig() {
        let meta = toy_meta();
        let mut p = Prune::new(0.25, 3);
        let mut rng = Rng::seed_from_u64(1);
        let mut bytes = 0;
        for round in 0..6 {
            let mut u = toy_update(10 + round as u64, meta.dim);
            bytes = p.compress(0, &mut u, &meta, round, &mut rng);
            if round >= 3 {
                let nz = u.iter().filter(|&&v| v != 0.0).count();
                assert_eq!(nz, 10, "round {round}: {nz} nonzeros");
            }
        }
        assert_eq!(bytes, 10 * 4);
        assert_eq!(p.kept(), 10);
    }

    #[test]
    fn mask_keeps_high_importance_coords() {
        let meta = toy_meta();
        let mut p = Prune::new(0.1, 2);
        let mut rng = Rng::seed_from_u64(2);
        for round in 0..5 {
            let mut u = vec![0.01f32; meta.dim];
            // coordinate 7 always large
            u[7] = 10.0;
            p.compress(0, &mut u, &meta, round, &mut rng);
        }
        let mut u = vec![0.01f32; meta.dim];
        u[7] = 10.0;
        p.compress(0, &mut u, &meta, 5, &mut rng);
        assert!(u[7] != 0.0, "dominant coordinate pruned");
    }

    #[test]
    fn nan_importance_never_panics_and_is_deterministic() {
        // Regression for the PR 7 bug class (docs/lints.md, rule D3):
        // partial_cmp().unwrap() panicked on NaN importance. With
        // total_cmp, NaN accrues as the largest importance and the
        // reconfigured mask is identical across runs.
        let meta = toy_meta();
        let run = || {
            let mut p = Prune::new(0.25, 2);
            let mut rng = Rng::seed_from_u64(7);
            let mut last = Vec::new();
            for round in 0..5 {
                let mut u = toy_update(3, meta.dim);
                u[3] = f32::NAN;
                p.compress(0, &mut u, &meta, round, &mut rng);
                last = u.iter().map(|v| v.to_bits()).collect::<Vec<_>>();
            }
            (p.kept(), last)
        };
        let (kept_a, bits_a) = run();
        let (kept_b, bits_b) = run();
        assert_eq!(kept_a, 10, "keep_ratio 0.25 of 40");
        assert_eq!(kept_a, kept_b);
        assert_eq!(bits_a, bits_b, "NaN importance must not perturb determinism");
    }
}
