//! FedDropoutAvg (Gunesli et al.): each client drops a random subset
//! of parameters from its upload at rate `fdr`; the server averages
//! whatever arrives. The dropped set is seeded per (client, round), so
//! the server can reconstruct it — cost = (1-rate) * d * 4 bytes, no
//! index transmission (the shared seed plays the paper's role of the
//! dropout mask agreed between client and server).
//!
//! Dropped coordinates are zeroed (not rescaled): with the server
//! averaging over all clients this matches FedDropoutAvg's model
//! averaging of partially-overlapping submodels in expectation up to
//! the (1-rate) attenuation the original also exhibits per-coordinate;
//! we apply the standard inverse-rate correction to stay unbiased.

use super::UpdateCompressor;
use crate::model::ModelMeta;
use crate::net::wire::WireHint;
use crate::rng::Rng;

pub struct DropoutAvg {
    rate: f32,
    /// Mask seed of the most recent `compress` call (wire flavor: the
    /// server regenerates the mask, no indices transmitted).
    last_seed: u64,
}

impl DropoutAvg {
    pub fn new(rate: f32) -> Self {
        assert!((0.0..1.0).contains(&rate), "dropout rate must be in [0,1)");
        DropoutAvg { rate, last_seed: 0 }
    }
}

impl UpdateCompressor for DropoutAvg {
    fn compress(
        &mut self,
        client: usize,
        update: &mut [f32],
        _meta: &ModelMeta,
        round: usize,
        _rng: &mut Rng,
    ) -> u64 {
        // Seeded mask: reproducible for (client, round)
        let seed = 0xd20_0000 ^ ((client as u64) << 32) ^ round as u64;
        self.last_seed = seed;
        let mut mask_rng = Rng::seed_from_u64(seed);
        let keep_scale = 1.0 / (1.0 - self.rate);
        let mut kept = 0u64;
        for v in update.iter_mut() {
            if mask_rng.f32() < self.rate {
                *v = 0.0;
            } else {
                *v *= keep_scale; // inverted-dropout unbiasedness
                kept += 1;
            }
        }
        kept * 4
    }

    fn wire_hint(&self) -> WireHint {
        WireHint::SeededMask { seed: self.last_seed, rate: self.rate }
    }

    fn label(&self) -> &'static str {
        "fda"
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::*;
    use super::*;

    #[test]
    fn drop_fraction_near_rate() {
        let meta = toy_meta();
        let mut total_zero = 0usize;
        let n_trials = 50;
        let mut rng = Rng::seed_from_u64(0);
        for t in 0..n_trials {
            let mut u = vec![1.0f32; meta.dim];
            DropoutAvg::new(0.5).compress(t, &mut u, &meta, 0, &mut rng);
            total_zero += u.iter().filter(|&&v| v == 0.0).count();
        }
        let frac = total_zero as f64 / (n_trials * meta.dim) as f64;
        assert!((frac - 0.5).abs() < 0.05, "drop fraction {frac}");
    }

    #[test]
    fn kept_coords_are_rescaled() {
        let meta = toy_meta();
        let mut u = vec![1.0f32; meta.dim];
        let mut rng = Rng::seed_from_u64(1);
        DropoutAvg::new(0.5).compress(0, &mut u, &meta, 3, &mut rng);
        for &v in &u {
            assert!(v == 0.0 || (v - 2.0).abs() < 1e-6, "unexpected value {v}");
        }
    }

    #[test]
    fn mask_is_deterministic_per_client_round() {
        let meta = toy_meta();
        let mut rng = Rng::seed_from_u64(2);
        let mut a = toy_update(5, meta.dim);
        let mut b = a.clone();
        DropoutAvg::new(0.75).compress(3, &mut a, &meta, 9, &mut rng);
        DropoutAvg::new(0.75).compress(3, &mut b, &meta, 9, &mut rng);
        assert_eq!(a, b);
        let mut c = toy_update(5, meta.dim);
        DropoutAvg::new(0.75).compress(4, &mut c, &meta, 9, &mut rng);
        assert_ne!(a, c);
    }

    #[test]
    fn unbiased_in_expectation() {
        let meta = toy_meta();
        let base = toy_update(6, meta.dim);
        let mut rng = Rng::seed_from_u64(3);
        let mut acc = vec![0.0f64; meta.dim];
        let n = 600;
        for r in 0..n {
            let mut u = base.clone();
            DropoutAvg::new(0.5).compress(r % 64, &mut u, &meta, r / 64, &mut rng);
            for (a, &v) in acc.iter_mut().zip(&u) {
                *a += v as f64;
            }
        }
        let rmse: f64 = (acc
            .iter()
            .zip(&base)
            .map(|(a, &b)| (a / n as f64 - b as f64).powi(2))
            .sum::<f64>()
            / meta.dim as f64)
            .sqrt();
        assert!(rmse < 0.12, "rmse {rmse}");
    }
}
