//! FedPAQ-style stochastic uniform quantization (Reisizadeh et al.).
//!
//! Per layer: the update is quantized to `levels` uniform levels over
//! its [min, max] range with *stochastic rounding*, which keeps the
//! quantizer unbiased (E[q(x)] = x) — the property FedPAQ's analysis
//! needs. Upload cost: ceil(log2(levels)) bits per element plus the
//! two f32 range scalars per layer.

use super::UpdateCompressor;
use crate::model::ModelMeta;
use crate::net::wire::WireHint;
use crate::rng::Rng;

pub struct Quantize {
    levels: u32,
    /// Per-layer (lo, step) of the most recent `compress` call, in
    /// model-layer order; step 0 marks a degenerate/constant layer.
    /// This is what the wire codec needs to transmit the grid exactly.
    last_ranges: Vec<(f32, f32)>,
}

impl Quantize {
    pub fn new(levels: u32) -> Self {
        assert!(levels >= 2, "need at least 2 quantization levels");
        Quantize { levels, last_ranges: Vec::new() }
    }

    pub fn bits_per_element(&self) -> u32 {
        32 - (self.levels - 1).leading_zeros()
    }
}

impl UpdateCompressor for Quantize {
    fn compress(
        &mut self,
        _client: usize,
        update: &mut [f32],
        meta: &ModelMeta,
        _round: usize,
        rng: &mut Rng,
    ) -> u64 {
        let mut bits: u64 = 0;
        self.last_ranges.clear();
        for lm in &meta.layers {
            let sl = &mut update[lm.offset..lm.offset + lm.size];
            let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
            for &v in sl.iter() {
                lo = lo.min(v);
                hi = hi.max(v);
            }
            if !lo.is_finite() || hi <= lo {
                // constant layer: the wire grid degenerates to `lo`
                self.last_ranges.push((if lo.is_finite() { lo } else { 0.0 }, 0.0));
                bits += 2 * 32;
                continue;
            }
            let step = (hi - lo) / (self.levels - 1) as f32;
            self.last_ranges.push((lo, step));
            for v in sl.iter_mut() {
                let t = (*v - lo) / step;
                let floor = t.floor();
                let frac = t - floor;
                // stochastic rounding: up with probability frac
                let q = if rng.f32() < frac { floor + 1.0 } else { floor };
                *v = lo + q.min((self.levels - 1) as f32) * step;
            }
            bits += (lm.size as u64) * self.bits_per_element() as u64 + 2 * 32;
        }
        bits.div_ceil(8)
    }

    fn wire_hint(&self) -> WireHint {
        WireHint::Quantized { levels: self.levels, ranges: self.last_ranges.clone() }
    }

    fn label(&self) -> &'static str {
        "fedpaq"
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::*;
    use super::*;

    #[test]
    fn bits_per_element_log2() {
        assert_eq!(Quantize::new(2).bits_per_element(), 1);
        assert_eq!(Quantize::new(16).bits_per_element(), 4);
        assert_eq!(Quantize::new(17).bits_per_element(), 5);
        assert_eq!(Quantize::new(256).bits_per_element(), 8);
    }

    #[test]
    fn quantization_is_bounded_by_step() {
        let meta = toy_meta();
        let orig = toy_update(2, meta.dim);
        let mut u = orig.clone();
        let mut rng = Rng::seed_from_u64(1);
        Quantize::new(16).compress(0, &mut u, &meta, 0, &mut rng);
        for lm in &meta.layers {
            let sl = &orig[lm.offset..lm.offset + lm.size];
            let lo = sl.iter().cloned().fold(f32::INFINITY, f32::min);
            let hi = sl.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let step = (hi - lo) / 15.0;
            for (a, b) in u[lm.offset..lm.offset + lm.size].iter().zip(sl) {
                assert!((a - b).abs() <= step + 1e-6, "{a} vs {b} step {step}");
            }
        }
    }

    #[test]
    fn stochastic_rounding_is_unbiased() {
        let meta = toy_meta();
        let orig = toy_update(3, meta.dim);
        let mut rng = Rng::seed_from_u64(2);
        let mut acc = vec![0.0f64; meta.dim];
        let n = 400;
        for _ in 0..n {
            let mut u = orig.clone();
            Quantize::new(4).compress(0, &mut u, &meta, 0, &mut rng);
            for (a, &v) in acc.iter_mut().zip(&u) {
                *a += v as f64;
            }
        }
        // mean of quantized ~= original (unbiasedness), coarse 4 levels
        let mut max_err = 0.0f64;
        for (a, &o) in acc.iter().zip(&orig) {
            max_err = max_err.max((a / n as f64 - o as f64).abs());
        }
        assert!(max_err < 0.15, "bias {max_err}");
    }

    #[test]
    fn byte_cost_scales_with_levels() {
        let meta = toy_meta();
        let mut rng = Rng::seed_from_u64(3);
        let mut u4 = toy_update(4, meta.dim);
        let b4 = Quantize::new(4).compress(0, &mut u4, &meta, 0, &mut rng);
        let mut u256 = toy_update(4, meta.dim);
        let b256 = Quantize::new(256).compress(0, &mut u256, &meta, 0, &mut rng);
        assert!(b4 < b256);
        // 2 bits/elem * 40 + 2 ranges * 2 layers
        assert_eq!(b4, (40 * 2 + 4 * 32_u64).div_ceil(8));
    }

    #[test]
    fn constant_layer_is_passthrough() {
        let meta = toy_meta();
        let mut u = vec![0.5f32; meta.dim];
        let mut rng = Rng::seed_from_u64(4);
        Quantize::new(8).compress(0, &mut u, &meta, 0, &mut rng);
        assert!(u.iter().all(|&v| v == 0.5));
    }
}
