//! LBGM — Look-back Gradient Multiplier (Azam et al., ICLR 2022).
//!
//! The insight the paper builds on: client gradient subspaces are
//! approximately low-rank over time, so when a new update is nearly
//! parallel to the client's previous transmitted direction, it's
//! enough to send the scalar projection ("gradient multiplier")
//! instead of the full vector. We implement the single-anchor variant:
//! each client keeps its last fully-transmitted update as the anchor;
//! if cos^2(update, anchor) >= threshold, only the projection
//! coefficient crosses the wire and the update is replaced by its
//! look-back reconstruction; otherwise the full update is sent and
//! becomes the new anchor.

use super::UpdateCompressor;
use crate::model::ModelMeta;
use crate::net::wire::WireHint;
use crate::rng::Rng;
use crate::tensor;
use std::collections::BTreeMap;

pub struct Lbgm {
    /// cos^2 threshold (the original's delta hyper-parameter).
    threshold: f32,
    /// Per-client anchors. BTreeMap, not HashMap: anchor state shapes
    /// every subsequent frame, so iteration over it must be sorted if
    /// it ever happens (docs/lints.md, rule D1).
    anchors: BTreeMap<usize, Vec<f32>>,
    pub scalar_rounds: u64,
    pub full_rounds: u64,
    /// The look-back coefficient of the most recent `compress` call,
    /// when it took the scalar path (drives the wire flavor).
    last_scalar: Option<f32>,
}

impl Lbgm {
    pub fn new(threshold: f32) -> Self {
        assert!((0.0..=1.0).contains(&threshold));
        Lbgm {
            threshold,
            anchors: BTreeMap::new(),
            scalar_rounds: 0,
            full_rounds: 0,
            last_scalar: None,
        }
    }
}

impl UpdateCompressor for Lbgm {
    fn compress(
        &mut self,
        client: usize,
        update: &mut [f32],
        _meta: &ModelMeta,
        _round: usize,
        _rng: &mut Rng,
    ) -> u64 {
        if let Some(anchor) = self.anchors.get(&client) {
            let a_ssq = tensor::ssq(anchor);
            let u_ssq = tensor::ssq(update);
            if a_ssq > 1e-24 && u_ssq > 1e-24 {
                let d = tensor::dot(update, anchor);
                let cos2 = (d * d) / (a_ssq * u_ssq);
                if cos2 >= self.threshold as f64 {
                    // look-back: u <- (u . a / ||a||^2) a, send one scalar
                    let coef = (d / a_ssq) as f32;
                    for (u, &a) in update.iter_mut().zip(anchor.iter()) {
                        *u = coef * a;
                    }
                    self.scalar_rounds += 1;
                    self.last_scalar = Some(coef);
                    return 4;
                }
            }
        }
        self.anchors.insert(client, update.to_vec());
        self.full_rounds += 1;
        self.last_scalar = None;
        (update.len() as u64) * 4
    }

    fn wire_hint(&self) -> WireHint {
        // Scalar frames carry only the coefficient; the server-side
        // anchor (mirrored per client) reconstructs the vector.
        match self.last_scalar {
            Some(coef) => WireHint::Scalar { coef },
            None => WireHint::Dense,
        }
    }

    fn label(&self) -> &'static str {
        "lbgm"
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::*;
    use super::*;

    #[test]
    fn first_round_sends_full() {
        let meta = toy_meta();
        let mut l = Lbgm::new(0.9);
        let mut rng = Rng::seed_from_u64(0);
        let mut u = toy_update(1, meta.dim);
        let bytes = l.compress(0, &mut u, &meta, 0, &mut rng);
        assert_eq!(bytes, 160);
        assert_eq!(l.full_rounds, 1);
    }

    #[test]
    fn parallel_update_sends_scalar() {
        let meta = toy_meta();
        let mut l = Lbgm::new(0.9);
        let mut rng = Rng::seed_from_u64(1);
        let base = toy_update(2, meta.dim);
        let mut u0 = base.clone();
        l.compress(0, &mut u0, &meta, 0, &mut rng);
        // second update = 0.5 * base (perfectly parallel)
        let mut u1: Vec<f32> = base.iter().map(|v| 0.5 * v).collect();
        let bytes = l.compress(0, &mut u1, &meta, 1, &mut rng);
        assert_eq!(bytes, 4);
        assert_eq!(l.scalar_rounds, 1);
        // reconstruction equals the true update here
        for (a, b) in u1.iter().zip(base.iter().map(|v| 0.5 * v)) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn orthogonal_update_sends_full_and_rebases() {
        let meta = toy_meta();
        let mut l = Lbgm::new(0.5);
        let mut rng = Rng::seed_from_u64(2);
        let mut u0 = vec![0.0f32; meta.dim];
        u0[0] = 1.0;
        l.compress(0, &mut u0, &meta, 0, &mut rng);
        let mut u1 = vec![0.0f32; meta.dim];
        u1[1] = 1.0; // orthogonal
        let bytes = l.compress(0, &mut u1, &meta, 1, &mut rng);
        assert_eq!(bytes, 160);
        assert_eq!(u1[1], 1.0, "full path must not modify the update");
        // now parallel to the new anchor
        let mut u2 = vec![0.0f32; meta.dim];
        u2[1] = 3.0;
        assert_eq!(l.compress(0, &mut u2, &meta, 2, &mut rng), 4);
    }

    #[test]
    fn anchors_are_per_client() {
        let meta = toy_meta();
        let mut l = Lbgm::new(0.9);
        let mut rng = Rng::seed_from_u64(3);
        let base = toy_update(4, meta.dim);
        let mut u0 = base.clone();
        l.compress(0, &mut u0, &meta, 0, &mut rng);
        // different client, parallel update: still full (no anchor yet)
        let mut u1 = base.clone();
        assert_eq!(l.compress(1, &mut u1, &meta, 1, &mut rng), 160);
    }

    #[test]
    fn zero_update_goes_full_path() {
        let meta = toy_meta();
        let mut l = Lbgm::new(0.9);
        let mut rng = Rng::seed_from_u64(4);
        let mut u0 = toy_update(5, meta.dim);
        l.compress(0, &mut u0, &meta, 0, &mut rng);
        let mut z = vec![0.0f32; meta.dim];
        assert_eq!(l.compress(0, &mut z, &meta, 1, &mut rng), 160);
    }
}
