//! FedPara substitute: randomized low-rank projection of layer updates.
//!
//! FedPara re-parameterizes weights as low-rank Hadamard products,
//! changing the architecture itself. That rewiring is orthogonal to
//! the aggregation question this repo studies, so we reproduce the
//! communication/noise profile instead: each matrix-shaped layer
//! update M (m x n) is replaced by its rank-r randomized rangefinder
//! approximation  M ≈ Q (Qᵀ M), Q = orth(M G), G seeded per round and
//! shared with the server — upload cost r*(m+n)*4 bytes per layer.
//! Vector-shaped arrays (biases) pass through untouched.

use super::UpdateCompressor;
use crate::model::ModelMeta;
use crate::net::wire::WireHint;
use crate::rng::Rng;

pub struct LowRank {
    rank_ratio: f32,
}

impl LowRank {
    pub fn new(rank_ratio: f32) -> Self {
        assert!(rank_ratio > 0.0 && rank_ratio <= 1.0);
        LowRank { rank_ratio }
    }
}

/// Gram–Schmidt orthonormalization of the columns of `y` (m x r,
/// column-major stored row-major as m rows of r). Degenerate columns
/// are zeroed.
fn orthonormalize(y: &mut [f32], m: usize, r: usize) {
    for j in 0..r {
        // subtract projections on previous columns
        for p in 0..j {
            let mut dot = 0.0f64;
            for i in 0..m {
                dot += (y[i * r + j] as f64) * (y[i * r + p] as f64);
            }
            for i in 0..m {
                y[i * r + j] -= (dot as f32) * y[i * r + p];
            }
        }
        let mut nrm = 0.0f64;
        for i in 0..m {
            nrm += (y[i * r + j] as f64).powi(2);
        }
        let nrm = nrm.sqrt();
        if nrm > 1e-12 {
            let inv = (1.0 / nrm) as f32;
            for i in 0..m {
                y[i * r + j] *= inv;
            }
        } else {
            for i in 0..m {
                y[i * r + j] = 0.0;
            }
        }
    }
}

/// Rank-r rangefinder factorization of `mat` (m x n, row-major):
/// returns (Q: m x r with orthonormal columns, B = Qᵀ M: r x n), so
/// Q B approximates M (exactly, up to float rounding, when M already
/// has rank <= r). Shared with the wire codec, which re-factorizes the
/// client's reconstructed matrix to put genuine factors on the wire.
pub(crate) fn lowrank_factor(
    mat: &[f32],
    m: usize,
    n: usize,
    r: usize,
    rng: &mut Rng,
) -> (Vec<f32>, Vec<f32>) {
    // Y = M G, G ~ N(0,1) n x r
    let g: Vec<f32> = (0..n * r).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let mut y = vec![0.0f32; m * r];
    for i in 0..m {
        for k in 0..n {
            let v = mat[i * n + k];
            if v != 0.0 {
                let grow = &g[k * r..k * r + r];
                let yrow = &mut y[i * r..i * r + r];
                for j in 0..r {
                    yrow[j] += v * grow[j];
                }
            }
        }
    }
    orthonormalize(&mut y, m, r);
    // B = Qᵀ M  (r x n)
    let mut b = vec![0.0f32; r * n];
    for i in 0..m {
        for j in 0..r {
            let q = y[i * r + j];
            if q != 0.0 {
                for k in 0..n {
                    b[j * n + k] += q * mat[i * n + k];
                }
            }
        }
    }
    (y, b)
}

/// Rank-r approximation of `mat` (m x n, row-major) in place.
fn lowrank_approx(mat: &mut [f32], m: usize, n: usize, r: usize, rng: &mut Rng) {
    if r >= m.min(n) {
        return;
    }
    let (q, b) = lowrank_factor(mat, m, n, r, rng);
    // M <- Q B
    for i in 0..m {
        for k in 0..n {
            let mut acc = 0.0f32;
            for j in 0..r {
                acc += q[i * r + j] * b[j * n + k];
            }
            mat[i * n + k] = acc;
        }
    }
}

/// View an array's shape as a matrix: dense (m,n) stays; conv
/// (kh,kw,ci,co) folds to (kh*kw*ci, co); vectors return None.
pub(crate) fn lowrank_matrix_shape(shape: &[usize]) -> Option<(usize, usize)> {
    match shape.len() {
        2 => Some((shape[0], shape[1])),
        4 => Some((shape[0] * shape[1] * shape[2], shape[3])),
        _ => None,
    }
}

/// The compressor's (and codec's) shared decision: factor an array of
/// this shape at `rank_ratio`? `Some((m, n, r))` means "transmit rank-r
/// factors"; `None` means dense passthrough (vectors, tiny matrices,
/// or a requested rank that is already full).
pub(crate) fn lowrank_plan(shape: &[usize], rank_ratio: f32) -> Option<(usize, usize, usize)> {
    let (m, n) = lowrank_matrix_shape(shape)?;
    if m.min(n) <= 1 {
        return None;
    }
    let full_rank = m.min(n);
    let r = crate::tensor::scaled_count(full_rank, rank_ratio, 1);
    if r < full_rank {
        Some((m, n, r))
    } else {
        None
    }
}

impl UpdateCompressor for LowRank {
    fn compress(
        &mut self,
        client: usize,
        update: &mut [f32],
        meta: &ModelMeta,
        round: usize,
        _rng: &mut Rng,
    ) -> u64 {
        let mut bytes = 0u64;
        for lm in &meta.layers {
            for am in &lm.arrays {
                let sl = &mut update[am.offset..am.offset + am.size];
                match lowrank_plan(&am.shape, self.rank_ratio) {
                    Some((m, n, r)) => {
                        // projection seed shared with server
                        let mut prng = Rng::seed_from_u64(
                            0x10_a11c ^ ((client as u64) << 32) ^ ((round as u64) << 8),
                        );
                        lowrank_approx(sl, m, n, r, &mut prng);
                        bytes += (r * (m + n)) as u64 * 4;
                    }
                    None => {
                        bytes += (am.size as u64) * 4;
                    }
                }
            }
        }
        bytes
    }

    fn wire_hint(&self) -> WireHint {
        WireHint::LowRank { rank_ratio: self.rank_ratio }
    }

    fn label(&self) -> &'static str {
        "fedpara"
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::*;
    use super::*;

    #[test]
    fn exact_for_rank_one_matrix() {
        // M = u vᵀ is rank 1; a rank-1 rangefinder recovers it exactly.
        let (m, n) = (6, 4);
        let u: Vec<f32> = (1..=6).map(|i| i as f32).collect();
        let v: Vec<f32> = (1..=4).map(|i| i as f32 * 0.5).collect();
        let mut mat = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                mat[i * n + j] = u[i] * v[j];
            }
        }
        let orig = mat.clone();
        let mut rng = Rng::seed_from_u64(7);
        lowrank_approx(&mut mat, m, n, 1, &mut rng);
        for (a, b) in mat.iter().zip(&orig) {
            assert!((a - b).abs() < 1e-3, "{a} vs {b}");
        }
    }

    #[test]
    fn full_rank_request_is_identity() {
        let mut mat = toy_update(1, 24);
        let orig = mat.clone();
        let mut rng = Rng::seed_from_u64(8);
        lowrank_approx(&mut mat, 6, 4, 4, &mut rng);
        assert_eq!(mat, orig);
    }

    #[test]
    fn approximation_reduces_energy_but_not_to_zero() {
        let mut mat = toy_update(2, 6 * 4);
        let orig_ssq: f64 = mat.iter().map(|&v| (v as f64).powi(2)).sum();
        let mut rng = Rng::seed_from_u64(9);
        lowrank_approx(&mut mat, 6, 4, 2, &mut rng);
        let new_ssq: f64 = mat.iter().map(|&v| (v as f64).powi(2)).sum();
        assert!(new_ssq > 0.1 * orig_ssq, "too much energy lost");
        assert!(new_ssq <= orig_ssq * 1.001, "projection must not add energy");
    }

    #[test]
    fn compressor_touches_only_matrix_arrays() {
        let meta = toy_meta();
        let orig = toy_update(3, meta.dim);
        let mut u = orig.clone();
        let mut rng = Rng::seed_from_u64(10);
        let bytes = LowRank::new(0.25).compress(0, &mut u, &meta, 0, &mut rng);
        // bias (offset 24..28) untouched
        assert_eq!(&u[24..28], &orig[24..28]);
        // rank-1 of 6x4: 1*(6+4)*4 = 40 bytes; fc1 4x3 rank1: (4+3)*4=28;
        // bias 4*4 = 16
        assert_eq!(bytes, 40 + 28 + 16);
    }

    #[test]
    fn deterministic_per_client_round() {
        let meta = toy_meta();
        let mut rng = Rng::seed_from_u64(11);
        let base = toy_update(4, meta.dim);
        let mut a = base.clone();
        let mut b = base.clone();
        LowRank::new(0.25).compress(2, &mut a, &meta, 5, &mut rng);
        LowRank::new(0.25).compress(2, &mut b, &meta, 5, &mut rng);
        assert_eq!(a, b);
    }
}
