//! Tiny criterion-style micro-benchmark harness (offline build: no
//! external crates). Warms up, auto-scales iteration counts to a
//! target measurement time, and reports mean/min/stddev per iteration.
//!
//! Used by all `rust/benches/*.rs` (harness = false) binaries; their
//! output is captured into `bench_output.txt` and EXPERIMENTS.md §Perf.

#![allow(clippy::disallowed_methods)] // a benchmark harness is nothing but wall-clock reads
use std::time::{Duration, Instant};

pub struct Bench {
    name: String,
    warmup: Duration,
    measure: Duration,
    results: Vec<(String, Stats)>,
}

#[derive(Debug, Clone, Copy)]
pub struct Stats {
    pub iters: u64,
    pub mean_ns: f64,
    pub min_ns: f64,
    pub std_ns: f64,
    /// Optional throughput denominator (elements per iteration).
    pub elems: Option<u64>,
}

impl Stats {
    pub fn mean_secs(&self) -> f64 {
        self.mean_ns / 1e9
    }

    pub fn throughput_gbps(&self) -> Option<f64> {
        self.elems.map(|e| (e as f64 * 4.0) / self.mean_ns)
    }
}

impl Bench {
    pub fn new(name: &str) -> Self {
        Bench {
            name: name.to_string(),
            warmup: Duration::from_millis(200),
            measure: Duration::from_millis(700),
            results: Vec::new(),
        }
    }

    pub fn with_times(mut self, warmup_ms: u64, measure_ms: u64) -> Self {
        self.warmup = Duration::from_millis(warmup_ms);
        self.measure = Duration::from_millis(measure_ms);
        self
    }

    /// Benchmark a closure; `elems` enables GB/s throughput reporting
    /// (f32 elements touched per iteration).
    pub fn bench<F: FnMut()>(&mut self, label: &str, elems: Option<u64>, mut f: F) -> Stats {
        // Warmup + estimate per-iter cost.
        let w0 = Instant::now();
        let mut iters_warm = 0u64;
        while w0.elapsed() < self.warmup {
            f();
            iters_warm += 1;
        }
        let per_iter = self.warmup.as_secs_f64() / iters_warm.max(1) as f64;
        // Sample in batches sized to ~20 samples over the measure window.
        let batch = ((self.measure.as_secs_f64() / 20.0 / per_iter).ceil() as u64).max(1);
        let mut samples: Vec<f64> = Vec::new();
        let m0 = Instant::now();
        let mut total_iters = 0u64;
        while m0.elapsed() < self.measure || samples.len() < 5 {
            let t = Instant::now();
            for _ in 0..batch {
                f();
            }
            samples.push(t.elapsed().as_secs_f64() * 1e9 / batch as f64);
            total_iters += batch;
            if samples.len() > 2000 {
                break;
            }
        }
        let mean = samples.iter().sum::<f64>() / samples.len() as f64;
        let min = samples.iter().cloned().fold(f64::INFINITY, f64::min);
        let var =
            samples.iter().map(|s| (s - mean).powi(2)).sum::<f64>() / samples.len() as f64;
        let stats = Stats { iters: total_iters, mean_ns: mean, min_ns: min, std_ns: var.sqrt(), elems };
        let tput = stats
            .throughput_gbps()
            .map(|t| format!("  {:7.2} GB/s", t))
            .unwrap_or_default();
        println!(
            "{}/{:<32} {:>12.0} ns/iter (min {:>12.0}, sd {:>10.0}, n={}){}",
            self.name, label, mean, min, var.sqrt(), total_iters, tput
        );
        self.results.push((label.to_string(), stats));
        stats
    }

    pub fn results(&self) -> &[(String, Stats)] {
        &self.results
    }

    /// Print a ratio line comparing two recorded labels.
    pub fn compare(&self, base: &str, other: &str) {
        let find = |l: &str| self.results.iter().find(|(n, _)| n == l).map(|(_, s)| *s);
        if let (Some(b), Some(o)) = (find(base), find(other)) {
            println!(
                "{}: {} / {} = {:.2}x",
                self.name,
                other,
                base,
                o.mean_ns / b.mean_ns
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let mut b = Bench::new("t").with_times(10, 30);
        let mut acc = 0u64;
        let s = b.bench("noop-ish", Some(1024), || {
            acc = acc.wrapping_add(std::hint::black_box(1));
        });
        assert!(s.mean_ns > 0.0);
        assert!(s.iters > 0);
        assert!(s.throughput_gbps().unwrap() > 0.0);
    }

    #[test]
    fn compare_does_not_panic() {
        let mut b = Bench::new("t").with_times(5, 15);
        b.bench("a", None, || {
            std::hint::black_box(1 + 1);
        });
        b.bench("b", None, || {
            std::hint::black_box((0..10).sum::<u64>());
        });
        b.compare("a", "b");
        assert_eq!(b.results().len(), 2);
    }
}
