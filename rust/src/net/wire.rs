//! Framed wire codecs: byte-exact encode/decode for every upload
//! flavor the repo produces. The Comm column stops being an analytic
//! estimate — `CommAccountant` records `frame.len()`, so headers,
//! layer-id lists, sparse indices, range scalars, and factor shapes
//! all count.
//!
//! Frame layout (little-endian):
//!
//! ```text
//! magic       u16  0xFED1
//! version     u8   1
//! flavor      u8   Flavor discriminant
//! dim         u32  full flat-model length (sanity check on decode)
//! n_layers    u16  number of layer ids that follow
//! reserved    u16  0
//! payload_len u32  bytes after the layer-id list
//! layer_ids   n_layers x u16
//! payload     flavor-specific, payload_len bytes
//! ```
//!
//! Flavor payloads (all operating on the *listed* layers only, which
//! is how LUAR's partial uploads and the Table 3 compositions get
//! exact byte counts with no scaling heuristics):
//!
//! * `Dense`     — raw f32 slice per listed layer.
//! * `Sparse`    — u32 nnz, then nnz x (u32 global index, f32 value);
//!   lossless for top-k / pruned / dropped-out updates.
//! * `Quantized` — u32 levels, then per layer (f32 lo, f32 step,
//!   bit-packed level indices); reproduces FedPAQ grid points exactly.
//! * `SignBits`  — per layer (f32 alpha, 1 sign bit per element);
//!   exact for the binarizer's ±alpha outputs.
//! * `LowRank`   — per array: dense passthrough or (u16 r, Q m x r,
//!   B r x n) factors; decode reconstructs QB (float-tolerance lossy,
//!   bounded in tests).
//! * `Scalar`    — one f32 look-back coefficient (LBGM); the server
//!   reconstructs from its per-client anchor, which in this simulator
//!   is the client's in-place reconstruction.
//! * `SeededMask`— FedDropoutAvg: u64 mask seed + f32 rate + kept
//!   values in position order; the decoder regenerates the dropout
//!   mask from the shared seed, so no indices cross the wire.
//! * `Bitmap`    — PruneFL: a 1-bit-per-parameter mask bitmap plus the
//!   kept values (the bitmap stands in for PruneFL's periodic mask
//!   reconfiguration broadcast).
//! * `Broadcast` — downlink frame: full f32 params, with the delta
//!   layer-id list (R_t) riding in the header's layer-id slot — the
//!   bytes the paper's §3.2 broadcast actually pays.
//! * `Delta`     — cross-round residual framing (uplink or downlink):
//!   each coded layer is either raw f32s or an XOR-vs-reference byte
//!   stream, whichever is smaller, against a reference snapshot keyed
//!   by model version and guarded by an FNV hash of the reference.
//!   Lossless by construction (XOR of f32 bit patterns), so
//!   delta-framed runs are bit-identical to dense-framed ones — only
//!   the byte counts differ. See `docs/wire.md`.

use crate::model::ModelMeta;
use crate::obs;
use anyhow::{bail, Result};

pub const MAGIC: u16 = 0xFED1;
pub const VERSION: u8 = 1;
/// Fixed header bytes before the layer-id list.
pub const HEADER_LEN: usize = 16;

/// Wire flavor discriminants (header byte 3).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum Flavor {
    Dense = 0,
    Sparse = 1,
    Quantized = 2,
    SignBits = 3,
    LowRank = 4,
    Scalar = 5,
    Broadcast = 6,
    SeededMask = 7,
    Bitmap = 8,
    Delta = 9,
}

impl Flavor {
    fn from_u8(v: u8) -> Result<Self> {
        Ok(match v {
            0 => Flavor::Dense,
            1 => Flavor::Sparse,
            2 => Flavor::Quantized,
            3 => Flavor::SignBits,
            4 => Flavor::LowRank,
            5 => Flavor::Scalar,
            6 => Flavor::Broadcast,
            7 => Flavor::SeededMask,
            8 => Flavor::Bitmap,
            9 => Flavor::Delta,
            other => bail!("unknown wire flavor {other}"),
        })
    }
}

/// How a compressor's most recent in-place output should be framed.
/// Returned by `UpdateCompressor::wire_hint` right after `compress`.
#[derive(Debug, Clone)]
pub enum WireHint {
    /// Raw f32 per listed layer (identity / LUAR partial uploads).
    Dense,
    /// Index/value pairs of the nonzeros (top-k, prune, dropout).
    Sparse,
    /// FedPAQ grid: `ranges[l] = (lo, step)` per *model* layer, as the
    /// quantizer computed them (step 0 marks a degenerate/constant
    /// layer encoded as lo).
    Quantized { levels: u32, ranges: Vec<(f32, f32)> },
    /// ±alpha sign binarization; alpha recovered as max |v| per layer.
    SignBits,
    /// Randomized rangefinder factors at `rank_ratio` per matrix array.
    LowRank { rank_ratio: f32 },
    /// LBGM look-back coefficient.
    Scalar { coef: f32 },
    /// FedDropoutAvg: the (client, round)-seeded mask is regenerated
    /// server-side, so only kept values travel.
    SeededMask { seed: u64, rate: f32 },
    /// PruneFL: mask bitmap + kept values (server-shared mask
    /// represented explicitly on the wire).
    Bitmap,
}

/// One encoded frame; `len()` is the exact wire cost in bytes.
#[derive(Debug, Clone)]
pub struct WireFrame {
    bytes: Vec<u8>,
}

impl WireFrame {
    pub fn len(&self) -> usize {
        self.bytes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.bytes.is_empty()
    }

    pub fn as_bytes(&self) -> &[u8] {
        &self.bytes
    }

    pub fn into_bytes(self) -> Vec<u8> {
        self.bytes
    }

    pub fn flavor(&self) -> Result<Flavor> {
        if self.bytes.len() < HEADER_LEN {
            bail!("frame shorter than header");
        }
        Flavor::from_u8(self.bytes[3])
    }
}

/// Server-side view of a decoded upload.
#[derive(Debug, Clone)]
pub enum Decoded {
    /// Full-dim vector (zeros in unlisted layers).
    Vector(Vec<f32>),
    /// LBGM coefficient; the caller reconstructs from its anchor.
    Scalar(f32),
}

// ---------------------------------------------------------------- helpers

fn push_u16(out: &mut Vec<u8>, v: u16) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn push_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn push_f32(out: &mut Vec<u8>, v: f32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn push_f32s(out: &mut Vec<u8>, xs: &[f32]) {
    out.reserve(xs.len() * 4);
    for v in xs {
        out.extend_from_slice(&v.to_le_bytes());
    }
}

/// Bounds-checked little-endian reader over a frame.
struct Cur<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cur<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            bail!("frame truncated at byte {} (wanted {n} more)", self.pos);
        }
        // lint:allow(W1): the length check above is exactly the bound
        // this slice needs; every other decode slice routes through here.
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    /// `take(N)` as a fixed-size array, without a `try_into().unwrap()`
    /// on the decode path: `take` already guarantees the length.
    fn array<const N: usize>(&mut self) -> Result<[u8; N]> {
        let mut a = [0u8; N];
        a.copy_from_slice(self.take(N)?);
        Ok(a)
    }

    fn u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(self.array()?))
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.array()?))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.array()?))
    }

    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.array()?))
    }

    fn f32s(&mut self, n: usize) -> Result<Vec<f32>> {
        let bytes = self.take(n * 4)?;
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }
}

/// Pack `bits`-wide values little-endian-first into bytes.
fn pack_bits(values: impl Iterator<Item = u32>, bits: u32, out: &mut Vec<u8>) {
    debug_assert!((1..=32).contains(&bits));
    let mut acc: u64 = 0;
    let mut nbits: u32 = 0;
    for v in values {
        acc |= (v as u64) << nbits;
        nbits += bits;
        while nbits >= 8 {
            out.push((acc & 0xff) as u8);
            acc >>= 8;
            nbits -= 8;
        }
    }
    if nbits > 0 {
        out.push((acc & 0xff) as u8);
    }
}

/// Inverse of `pack_bits`: read `count` values of `bits` width.
fn unpack_bits(cur: &mut Cur, bits: u32, count: usize) -> Result<Vec<u32>> {
    let total_bits = (count as u64) * bits as u64;
    let nbytes = total_bits.div_ceil(8) as usize;
    let bytes = cur.take(nbytes)?;
    let mut vals = Vec::with_capacity(count);
    let mut acc: u64 = 0;
    let mut nbits: u32 = 0;
    let mut bi = 0usize;
    let mask: u64 = if bits == 32 { u32::MAX as u64 } else { (1u64 << bits) - 1 };
    for _ in 0..count {
        while nbits < bits {
            acc |= (bytes[bi] as u64) << nbits;
            bi += 1;
            nbits += 8;
        }
        vals.push((acc & mask) as u32);
        acc >>= bits;
        nbits -= bits;
    }
    Ok(vals)
}

fn header(flavor: Flavor, dim: usize, layer_ids: &[usize]) -> Result<Vec<u8>> {
    if dim > u32::MAX as usize {
        bail!("model dim {dim} exceeds wire format limit");
    }
    if layer_ids.len() > u16::MAX as usize {
        bail!("{} layer ids exceed wire format limit", layer_ids.len());
    }
    let mut out = Vec::with_capacity(HEADER_LEN + 2 * layer_ids.len());
    push_u16(&mut out, MAGIC);
    out.push(VERSION);
    out.push(flavor as u8);
    push_u32(&mut out, dim as u32);
    push_u16(&mut out, layer_ids.len() as u16);
    push_u16(&mut out, 0); // reserved
    push_u32(&mut out, 0); // payload_len backpatched by seal()
    for &l in layer_ids {
        if l > u16::MAX as usize {
            bail!("layer id {l} exceeds wire format limit");
        }
        push_u16(&mut out, l as u16);
    }
    Ok(out)
}

/// Backpatch payload_len once the payload is appended.
fn seal(mut frame: Vec<u8>, n_layers: usize) -> WireFrame {
    let body = HEADER_LEN + 2 * n_layers;
    let payload_len = (frame.len() - body) as u32;
    // lint:allow(W1): encode side — `header()` wrote HEADER_LEN bytes
    // before any payload, so bytes 12..16 always exist here.
    frame[12..16].copy_from_slice(&payload_len.to_le_bytes());
    WireFrame { bytes: frame }
}

/// Exact wire bytes of a full dense upload — the FedAvg baseline the
/// Comm-column denominator uses (header + layer-id list + f32 body).
pub fn dense_frame_len(meta: &ModelMeta) -> u64 {
    (HEADER_LEN + 2 * meta.num_layers() + 4 * meta.dim) as u64
}

/// Exact wire bytes of a self-contained `Dense` upload of the listed
/// layers — the baseline a delta uplink frame is measured (and the
/// link schedule timed) against.
pub fn dense_subset_len(meta: &ModelMeta, layers: &[usize]) -> u64 {
    let body: usize = layers.iter().map(|&l| meta.layers[l].size).sum();
    (HEADER_LEN + 2 * layers.len() + 4 * body) as u64
}

/// Exact wire bytes of a self-contained `Broadcast` frame carrying
/// `n_ids` recycle-set layer ids — the downlink delta baseline.
pub fn broadcast_frame_len(meta: &ModelMeta, n_ids: usize) -> u64 {
    (HEADER_LEN + 2 * n_ids + 4 * meta.dim) as u64
}

// ----------------------------------------------------------- delta coding

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

fn fnv1a_bytes(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h = (h ^ b as u64).wrapping_mul(FNV_PRIME);
    }
    h
}

// ------------------------------------------------------ integrity trailer

/// Bytes appended by [`seal_trailer`]: u32 LE body length + u64 LE
/// FNV-1a over the body.
pub const TRAILER_LEN: usize = 12;

/// Append the corruption-detection trailer to a sealed frame: the
/// frame's byte length (u32 LE) followed by FNV-1a over every
/// preceding byte (u64 LE). Only fault-injected runs seal trailers —
/// with `faults = off` frames stay byte-identical to the pre-trailer
/// wire format, and every pinned exact-length function excludes it.
///
/// A single flipped byte anywhere in the trailer-bearing frame is
/// always detected: each FNV-1a step `h' = (h ^ b) * prime` is
/// injective in the state (odd multiplier, invertible mod 2^64), so
/// distinct bytes at any position yield distinct final hashes; a flip
/// inside the trailer itself breaks the length or hash comparison
/// directly. `prop_fault_trailer_detects_any_single_byte_flip` sweeps
/// this over every flavor and every byte position.
pub fn seal_trailer(frame: &mut Vec<u8>) {
    let len = frame.len() as u32;
    let hash = fnv1a_bytes(FNV_OFFSET, frame);
    frame.extend_from_slice(&len.to_le_bytes());
    frame.extend_from_slice(&hash.to_le_bytes());
}

/// Verify a trailer-bearing frame and return the body with the trailer
/// stripped; errors on any mismatch (the frame was corrupted in
/// transit) so a flipped byte can never reach the decoder silently.
pub fn check_trailer(frame: &[u8]) -> Result<&[u8]> {
    if frame.len() < TRAILER_LEN {
        bail!("frame shorter than its integrity trailer ({} bytes)", frame.len());
    }
    let body_end = frame.len() - TRAILER_LEN;
    // split_at / Cur keep every slice bounds-derived — no raw indexing
    // or try_into().expect() on this decode path (rules W1/P1).
    let (body, trailer) = frame.split_at(body_end);
    let mut cur = Cur { buf: trailer, pos: 0 };
    let len = cur.u32()?;
    if len as usize != body_end {
        bail!("integrity trailer length mismatch: trailer says {len}, body is {body_end} bytes");
    }
    let want = cur.u64()?;
    let got = fnv1a_bytes(FNV_OFFSET, body);
    if want != got {
        bail!("integrity trailer FNV mismatch: frame corrupted in transit");
    }
    Ok(body)
}

/// Per-layer FNV-1a hashes over the f32 bit patterns of `values` —
/// what `fl::RefState` stores to validate a reference snapshot without
/// keeping a second copy.
pub fn layer_hashes(values: &[f32], meta: &ModelMeta) -> Vec<u64> {
    meta.layers
        .iter()
        .map(|lm| {
            let mut h = FNV_OFFSET;
            for &x in &values[lm.offset..lm.offset + lm.size] {
                h = fnv1a_bytes(h, &x.to_bits().to_le_bytes());
            }
            h
        })
        .collect()
}

/// Combine per-layer hashes over the coded layer set into the single
/// reference check a delta frame carries.
pub fn combine_layer_hashes(hashes: &[u64], layers: &[usize]) -> u64 {
    let mut h = FNV_OFFSET;
    for &l in layers {
        h = fnv1a_bytes(h, &hashes[l].to_le_bytes());
    }
    h
}

/// Significant-byte classes for one XOR residual word: 2-bit code ->
/// {0, 2, 3, 4} little-endian bytes on the wire. Values close to their
/// reference zero the sign/exponent byte (and usually the top mantissa
/// bits), so the workhorse class is 3 bytes; identical values cost two
/// bits.
const DELTA_CODE_BYTES: [usize; 4] = [0, 2, 3, 4];

fn delta_code_of(d: u32) -> u32 {
    if d == 0 {
        0
    } else if d < 1 << 16 {
        1
    } else if d < 1 << 24 {
        2
    } else {
        3
    }
}

/// Wire bytes the XOR-residual stream would cost for one layer.
fn delta_coded_len(cur: &[f32], reference: &[f32]) -> usize {
    let mut n = cur.len().div_ceil(4); // packed 2-bit codes
    for (&c, &r) in cur.iter().zip(reference) {
        n += DELTA_CODE_BYTES[delta_code_of(c.to_bits() ^ r.to_bits()) as usize];
    }
    n
}

/// One coded layer: a tag byte picking raw f32s or the XOR-residual
/// stream, whichever is smaller — so a delta frame never exceeds its
/// self-contained baseline by more than the tag + payload prefix.
fn delta_code_layer(cur: &[f32], reference: &[f32], out: &mut Vec<u8>) {
    if delta_coded_len(cur, reference) < 4 * cur.len() {
        out.push(1);
        pack_bits(
            cur.iter().zip(reference).map(|(&c, &r)| delta_code_of(c.to_bits() ^ r.to_bits())),
            2,
            out,
        );
        for (&c, &r) in cur.iter().zip(reference) {
            let d = c.to_bits() ^ r.to_bits();
            let n = DELTA_CODE_BYTES[delta_code_of(d) as usize];
            out.extend_from_slice(&d.to_le_bytes()[..n]);
        }
    } else {
        out.push(0);
        push_f32s(out, cur);
    }
}

fn delta_decode_layer(cur: &mut Cur, reference: &[f32], out: &mut [f32]) -> Result<()> {
    match cur.take(1)?[0] {
        0 => {
            let vals = cur.f32s(out.len())?;
            out.copy_from_slice(&vals);
        }
        1 => {
            let codes = unpack_bits(cur, 2, reference.len())?;
            for ((slot, &r), code) in out.iter_mut().zip(reference).zip(codes) {
                let n = DELTA_CODE_BYTES[code as usize];
                let mut b = [0u8; 4];
                b[..n].copy_from_slice(cur.take(n)?);
                *slot = f32::from_bits(u32::from_le_bytes(b) ^ r.to_bits());
            }
        }
        other => bail!("unknown delta layer tag {other}"),
    }
    Ok(())
}

/// Number of bits per quantized element for `levels` levels.
fn level_bits(levels: u32) -> u32 {
    32 - (levels.max(2) - 1).leading_zeros()
}

/// Per-position membership in the listed layers.
fn layer_membership(meta: &ModelMeta, layers: &[usize]) -> Vec<bool> {
    let mut m = vec![false; meta.dim];
    for &l in layers {
        let lm = &meta.layers[l];
        m[lm.offset..lm.offset + lm.size].iter_mut().for_each(|b| *b = true);
    }
    m
}

// ---------------------------------------------------------------- encode

/// Encode one client upload. `layers` lists the layer ids present on
/// the wire (LUAR's upload set, or all layers); `hint` selects the
/// flavor from the compressor that produced `update` in place.
pub fn encode_update(
    update: &[f32],
    meta: &ModelMeta,
    layers: &[usize],
    hint: &WireHint,
) -> Result<WireFrame> {
    let _sp = obs::span("wire.encode");
    if update.len() != meta.dim {
        bail!("update len {} != model dim {}", update.len(), meta.dim);
    }
    for &l in layers {
        if l >= meta.num_layers() {
            bail!("layer id {l} out of range");
        }
    }
    let mut out;
    match hint {
        WireHint::Dense => {
            out = header(Flavor::Dense, meta.dim, layers)?;
            for &l in layers {
                push_f32s(&mut out, meta.layer(update, l));
            }
        }
        WireHint::Sparse => {
            out = header(Flavor::Sparse, meta.dim, layers)?;
            let nnz_at = out.len();
            push_u32(&mut out, 0);
            let mut nnz = 0u32;
            for &l in layers {
                let lm = &meta.layers[l];
                for (i, &v) in update[lm.offset..lm.offset + lm.size].iter().enumerate() {
                    if v != 0.0 {
                        push_u32(&mut out, (lm.offset + i) as u32);
                        push_f32(&mut out, v);
                        nnz += 1;
                    }
                }
            }
            out[nnz_at..nnz_at + 4].copy_from_slice(&nnz.to_le_bytes());
        }
        WireHint::Quantized { levels, ranges } => {
            if ranges.len() != meta.num_layers() {
                bail!(
                    "quantizer ranges cover {} layers, model has {}",
                    ranges.len(),
                    meta.num_layers()
                );
            }
            // A single-level grid cannot represent anything but its
            // own lo; the degenerate-layer contract is `step == 0.0`
            // on a >= 2-level grid, so reject the hint outright rather
            // than encode indices that alias every value to lo.
            if *levels < 2 {
                bail!("quantized flavor needs >= 2 levels, got {levels}");
            }
            let bits = level_bits(*levels);
            out = header(Flavor::Quantized, meta.dim, layers)?;
            push_u32(&mut out, *levels);
            for &l in layers {
                let (lo, step) = ranges[l];
                if step.is_nan() || step < 0.0 || !lo.is_finite() {
                    bail!("quantized layer {l} has invalid range (lo {lo}, step {step})");
                }
                push_f32(&mut out, lo);
                push_f32(&mut out, step);
                let sl = meta.layer(update, l);
                let qmax = levels.saturating_sub(1);
                pack_bits(
                    sl.iter().map(|&v| {
                        if step > 0.0 {
                            crate::tensor::quant_grid_index(v, lo, step, qmax)
                        } else {
                            0
                        }
                    }),
                    bits,
                    &mut out,
                );
            }
        }
        WireHint::SignBits => {
            out = header(Flavor::SignBits, meta.dim, layers)?;
            for &l in layers {
                let sl = meta.layer(update, l);
                let alpha = sl.iter().fold(0.0f32, |a, &v| a.max(v.abs()));
                push_f32(&mut out, alpha);
                pack_bits(sl.iter().map(|&v| (v < 0.0) as u32), 1, &mut out);
            }
        }
        WireHint::LowRank { rank_ratio } => {
            out = header(Flavor::LowRank, meta.dim, layers)?;
            for &l in layers {
                for am in &meta.layers[l].arrays {
                    let sl = &update[am.offset..am.offset + am.size];
                    match crate::compress::lowrank_plan(&am.shape, *rank_ratio) {
                        Some((m, n, r)) => {
                            out.push(1); // factored
                            push_u16(&mut out, r as u16);
                            // The slice is already (numerically) rank r,
                            // so a fresh seeded rangefinder recovers its
                            // column space; the seed only needs to be
                            // deterministic, not shared with the client.
                            let mut rng =
                                crate::rng::Rng::seed_from_u64(0x5eed ^ (am.size as u64));
                            let (q, b) = crate::compress::lowrank_factor(sl, m, n, r, &mut rng);
                            push_f32s(&mut out, &q);
                            push_f32s(&mut out, &b);
                        }
                        None => {
                            out.push(0); // dense passthrough
                            push_f32s(&mut out, sl);
                        }
                    }
                }
            }
        }
        WireHint::Scalar { coef } => {
            // The coefficient references no layer data (the server's
            // anchor reconstructs everything), so no layer-id list is
            // paid — a scalar round really is header + 4 bytes.
            out = header(Flavor::Scalar, meta.dim, &[])?;
            push_f32(&mut out, *coef);
            return Ok(seal(out, 0));
        }
        WireHint::SeededMask { seed, rate } => {
            out = header(Flavor::SeededMask, meta.dim, layers)?;
            out.extend_from_slice(&seed.to_le_bytes());
            push_f32(&mut out, *rate);
            let kept_at = out.len();
            push_u32(&mut out, 0);
            // Regenerate the mask exactly as the compressor drew it
            // (the rng must step over every position to stay aligned);
            // kept slots in *listed* layers ship even when 0.0.
            let listed = layer_membership(meta, layers);
            let mut mask_rng = crate::rng::Rng::seed_from_u64(*seed);
            let mut kept = 0u32;
            for (i, &v) in update.iter().enumerate() {
                if mask_rng.f32() >= *rate && listed[i] {
                    push_f32(&mut out, v);
                    kept += 1;
                }
            }
            out[kept_at..kept_at + 4].copy_from_slice(&kept.to_le_bytes());
        }
        WireHint::Bitmap => {
            out = header(Flavor::Bitmap, meta.dim, layers)?;
            let kept: u32 = update.iter().filter(|&&v| v != 0.0).count() as u32;
            push_u32(&mut out, kept);
            pack_bits(update.iter().map(|&v| (v != 0.0) as u32), 1, &mut out);
            for &v in update {
                if v != 0.0 {
                    push_f32(&mut out, v);
                }
            }
        }
    }
    Ok(seal(out, layers.len()))
}

/// Encode the downlink broadcast: full params + the delta layer-id
/// list (R_t). Per-client broadcast variants (FedMut mutations) have
/// identical length, so one encode measures the whole round's downlink.
pub fn encode_broadcast(
    params: &[f32],
    meta: &ModelMeta,
    recycle_set: &[usize],
) -> Result<WireFrame> {
    let _sp = obs::span("wire.encode_bcast");
    if params.len() != meta.dim {
        bail!("params len {} != model dim {}", params.len(), meta.dim);
    }
    let mut out = header(Flavor::Broadcast, meta.dim, recycle_set)?;
    push_f32s(&mut out, params);
    Ok(seal(out, recycle_set.len()))
}

/// Delta payload prefix: inner flavor (u8) + reference version (u64) +
/// reference hash (u64). With one tag byte per coded layer this bounds
/// a delta frame at `self-contained + 17 + n_coded_layers` bytes.
pub const DELTA_PREFIX_LEN: usize = 1 + 8 + 8;

fn delta_prefix(
    out: &mut Vec<u8>,
    inner: Flavor,
    reference: &[f32],
    meta: &ModelMeta,
    coded_layers: &[usize],
    ref_version: u64,
) {
    out.push(inner as u8);
    out.extend_from_slice(&ref_version.to_le_bytes());
    let check = combine_layer_hashes(&layer_hashes(reference, meta), coded_layers);
    out.extend_from_slice(&check.to_le_bytes());
}

/// Encode an uplink update as a `Delta` frame: each listed layer coded
/// against the same layer of `reference` (the previous decoded upload
/// this client's `RefState` tracks, at model version `ref_version`).
/// Lossless: decode with the same reference reproduces `update`
/// bit-exactly. Callers fall back to a self-contained `Dense` frame
/// (and count `fl.delta_fallbacks`) when no valid reference exists.
pub fn encode_update_delta(
    update: &[f32],
    meta: &ModelMeta,
    layers: &[usize],
    reference: &[f32],
    ref_version: u64,
) -> Result<WireFrame> {
    let _sp = obs::span("wire.encode");
    if update.len() != meta.dim {
        bail!("update len {} != model dim {}", update.len(), meta.dim);
    }
    if reference.len() != meta.dim {
        bail!("reference len {} != model dim {}", reference.len(), meta.dim);
    }
    for &l in layers {
        if l >= meta.num_layers() {
            bail!("layer id {l} out of range");
        }
    }
    let mut out = header(Flavor::Delta, meta.dim, layers)?;
    delta_prefix(&mut out, Flavor::Dense, reference, meta, layers, ref_version);
    for &l in layers {
        delta_code_layer(meta.layer(update, l), meta.layer(reference, l), &mut out);
    }
    Ok(seal(out, layers.len()))
}

/// Decode a delta uplink frame against the local reference snapshot.
/// Returns the full-dim update (zeros in unlisted layers) and the
/// reference version the frame was coded against. Fails loudly if the
/// local reference hashes differently from the encoder's.
pub fn decode_update_delta(
    frame: &[u8],
    meta: &ModelMeta,
    reference: &[f32],
) -> Result<(Vec<f32>, u64)> {
    let _sp = obs::span("wire.decode");
    let Parsed { flavor, layer_ids, mut cur } = parse_header(frame, meta)?;
    if flavor != Flavor::Delta {
        bail!("expected delta frame, got {flavor:?}");
    }
    if reference.len() != meta.dim {
        bail!("reference len {} != model dim {}", reference.len(), meta.dim);
    }
    let inner = Flavor::from_u8(cur.take(1)?[0])?;
    if inner != Flavor::Dense {
        bail!("delta frame carries {inner:?}, expected a Dense uplink");
    }
    let ref_version = cur.u64()?;
    let check = cur.u64()?;
    let local = combine_layer_hashes(&layer_hashes(reference, meta), &layer_ids);
    if check != local {
        bail!("delta reference mismatch (frame {check:#018x}, local {local:#018x})");
    }
    let mut v = vec![0.0f32; meta.dim];
    for &l in &layer_ids {
        let lm = &meta.layers[l];
        let (rs, re) = (lm.offset, lm.offset + lm.size);
        let mut sl = vec![0.0f32; lm.size];
        delta_decode_layer(&mut cur, &reference[rs..re], &mut sl)?;
        v[rs..re].copy_from_slice(&sl);
    }
    Ok((v, ref_version))
}

/// Encode the downlink broadcast as a `Delta` frame against the params
/// the receiving client last saw (`reference`, at `ref_version`). All
/// model layers are coded; the recycle-set ids ride in the header's
/// layer-id slot exactly as in a self-contained `Broadcast` frame.
pub fn encode_broadcast_delta(
    params: &[f32],
    meta: &ModelMeta,
    recycle_set: &[usize],
    reference: &[f32],
    ref_version: u64,
) -> Result<WireFrame> {
    let _sp = obs::span("wire.encode_bcast");
    if params.len() != meta.dim {
        bail!("params len {} != model dim {}", params.len(), meta.dim);
    }
    if reference.len() != meta.dim {
        bail!("reference len {} != model dim {}", reference.len(), meta.dim);
    }
    let all: Vec<usize> = (0..meta.num_layers()).collect();
    let mut out = header(Flavor::Delta, meta.dim, recycle_set)?;
    delta_prefix(&mut out, Flavor::Broadcast, reference, meta, &all, ref_version);
    for &l in &all {
        delta_code_layer(meta.layer(params, l), meta.layer(reference, l), &mut out);
    }
    Ok(seal(out, recycle_set.len()))
}

/// Decode a delta downlink frame: (params, recycle layer-id list,
/// reference version).
pub fn decode_broadcast_delta(
    frame: &[u8],
    meta: &ModelMeta,
    reference: &[f32],
) -> Result<(Vec<f32>, Vec<usize>, u64)> {
    let Parsed { flavor, layer_ids, mut cur } = parse_header(frame, meta)?;
    if flavor != Flavor::Delta {
        bail!("expected delta frame, got {flavor:?}");
    }
    if reference.len() != meta.dim {
        bail!("reference len {} != model dim {}", reference.len(), meta.dim);
    }
    let inner = Flavor::from_u8(cur.take(1)?[0])?;
    if inner != Flavor::Broadcast {
        bail!("delta frame carries {inner:?}, expected a Broadcast downlink");
    }
    let ref_version = cur.u64()?;
    let check = cur.u64()?;
    let all: Vec<usize> = (0..meta.num_layers()).collect();
    let local = combine_layer_hashes(&layer_hashes(reference, meta), &all);
    if check != local {
        bail!("delta reference mismatch (frame {check:#018x}, local {local:#018x})");
    }
    let mut params = vec![0.0f32; meta.dim];
    for lm in &meta.layers {
        let (rs, re) = (lm.offset, lm.offset + lm.size);
        let mut sl = vec![0.0f32; lm.size];
        delta_decode_layer(&mut cur, &reference[rs..re], &mut sl)?;
        params[rs..re].copy_from_slice(&sl);
    }
    Ok((params, layer_ids, ref_version))
}

// ---------------------------------------------------------------- decode

struct Parsed<'a> {
    flavor: Flavor,
    layer_ids: Vec<usize>,
    cur: Cur<'a>,
}

fn parse_header<'a>(frame: &'a [u8], meta: &ModelMeta) -> Result<Parsed<'a>> {
    let mut cur = Cur { buf: frame, pos: 0 };
    if cur.u16()? != MAGIC {
        bail!("bad wire magic");
    }
    let ver = cur.take(1)?[0];
    if ver != VERSION {
        bail!("wire version {ver} != {VERSION}");
    }
    let flavor = Flavor::from_u8(cur.take(1)?[0])?;
    let dim = cur.u32()? as usize;
    if dim != meta.dim {
        bail!("frame dim {dim} != model dim {}", meta.dim);
    }
    let n_layers = cur.u16()? as usize;
    let _reserved = cur.u16()?;
    let payload_len = cur.u32()? as usize;
    let mut layer_ids = Vec::with_capacity(n_layers);
    for _ in 0..n_layers {
        let l = cur.u16()? as usize;
        if l >= meta.num_layers() {
            bail!("frame layer id {l} out of range");
        }
        layer_ids.push(l);
    }
    if cur.pos + payload_len != frame.len() {
        bail!("frame length {} != header-declared {}", frame.len(), cur.pos + payload_len);
    }
    Ok(Parsed { flavor, layer_ids, cur })
}

/// Decode an uplink frame back into a full-dim vector (or the LBGM
/// scalar). The round-trip invariants per flavor are pinned in tests:
/// dense/sparse/quantized/signbits are exact, low-rank is bounded.
pub fn decode_update(frame: &[u8], meta: &ModelMeta) -> Result<Decoded> {
    let _sp = obs::span("wire.decode");
    let Parsed { flavor, layer_ids, mut cur } = parse_header(frame, meta)?;
    let mut v = vec![0.0f32; meta.dim];
    match flavor {
        Flavor::Dense => {
            for &l in &layer_ids {
                let lm = &meta.layers[l];
                let vals = cur.f32s(lm.size)?;
                v[lm.offset..lm.offset + lm.size].copy_from_slice(&vals);
            }
        }
        Flavor::Sparse => {
            let nnz = cur.u32()? as usize;
            for _ in 0..nnz {
                let idx = cur.u32()? as usize;
                let val = cur.f32()?;
                if idx >= meta.dim {
                    bail!("sparse index {idx} out of range");
                }
                v[idx] = val;
            }
        }
        Flavor::Quantized => {
            let levels = cur.u32()?;
            if levels < 2 {
                bail!("quantized frame declares {levels} levels (needs >= 2)");
            }
            let bits = level_bits(levels);
            for &l in &layer_ids {
                let lm = &meta.layers[l];
                let lo = cur.f32()?;
                let step = cur.f32()?;
                if step.is_nan() || step < 0.0 || !lo.is_finite() {
                    bail!("quantized layer {l} has invalid range (lo {lo}, step {step})");
                }
                let qs = unpack_bits(&mut cur, bits, lm.size)?;
                // A degenerate (constant) layer encodes all-zero
                // indices; anything else means the frame and range
                // disagree, so fail loudly instead of aliasing to lo.
                if step == 0.0 && qs.iter().any(|&q| q != 0) {
                    bail!("degenerate quantized layer {l} carries nonzero indices");
                }
                for (slot, q) in v[lm.offset..lm.offset + lm.size].iter_mut().zip(qs) {
                    *slot = if step > 0.0 { lo + (q as f32) * step } else { lo };
                }
            }
        }
        Flavor::SignBits => {
            for &l in &layer_ids {
                let lm = &meta.layers[l];
                let alpha = cur.f32()?;
                let signs = unpack_bits(&mut cur, 1, lm.size)?;
                for (slot, s) in v[lm.offset..lm.offset + lm.size].iter_mut().zip(signs) {
                    *slot = if s == 1 { -alpha } else { alpha };
                }
            }
        }
        Flavor::LowRank => {
            for &l in &layer_ids {
                for am in &meta.layers[l].arrays {
                    let tag = cur.take(1)?[0];
                    match tag {
                        0 => {
                            let vals = cur.f32s(am.size)?;
                            v[am.offset..am.offset + am.size].copy_from_slice(&vals);
                        }
                        1 => {
                            let r = cur.u16()? as usize;
                            let (m, n) = crate::compress::lowrank_matrix_shape(&am.shape)
                                .ok_or_else(|| anyhow::anyhow!("factored non-matrix array"))?;
                            if r == 0 || r > m.min(n) {
                                bail!("factor rank {r} invalid for {m}x{n}");
                            }
                            let q = cur.f32s(m * r)?;
                            let b = cur.f32s(r * n)?;
                            let sl = &mut v[am.offset..am.offset + am.size];
                            for i in 0..m {
                                for k in 0..n {
                                    let mut acc = 0.0f32;
                                    for j in 0..r {
                                        acc += q[i * r + j] * b[j * n + k];
                                    }
                                    sl[i * n + k] = acc;
                                }
                            }
                        }
                        other => bail!("unknown low-rank array tag {other}"),
                    }
                }
            }
        }
        Flavor::Scalar => {
            return Ok(Decoded::Scalar(cur.f32()?));
        }
        Flavor::SeededMask => {
            let seed = cur.u64()?;
            let rate = cur.f32()?;
            let kept = cur.u32()? as usize;
            let vals = cur.f32s(kept)?;
            let listed = layer_membership(meta, &layer_ids);
            let mut mask_rng = crate::rng::Rng::seed_from_u64(seed);
            let mut vi = 0usize;
            for (i, slot) in v.iter_mut().enumerate() {
                if mask_rng.f32() >= rate && listed[i] {
                    if vi >= vals.len() {
                        bail!("seeded-mask frame shorter than its mask");
                    }
                    *slot = vals[vi];
                    vi += 1;
                }
            }
            if vi != vals.len() {
                bail!("seeded-mask frame carries {} extra values", vals.len() - vi);
            }
        }
        Flavor::Bitmap => {
            let kept = cur.u32()? as usize;
            let mask = unpack_bits(&mut cur, 1, meta.dim)?;
            let vals = cur.f32s(kept)?;
            let mut vi = 0usize;
            for (slot, m) in v.iter_mut().zip(mask) {
                if m == 1 {
                    if vi >= vals.len() {
                        bail!("bitmap frame shorter than its mask");
                    }
                    *slot = vals[vi];
                    vi += 1;
                }
            }
            if vi != vals.len() {
                bail!("bitmap frame carries {} extra values", vals.len() - vi);
            }
        }
        Flavor::Broadcast => bail!("broadcast frame on the uplink"),
        Flavor::Delta => bail!("delta frame needs a reference; use decode_update_delta"),
    }
    Ok(Decoded::Vector(v))
}

/// Decode a downlink frame: (params, recycle layer-id list).
pub fn decode_broadcast(frame: &[u8], meta: &ModelMeta) -> Result<(Vec<f32>, Vec<usize>)> {
    let Parsed { flavor, layer_ids, mut cur } = parse_header(frame, meta)?;
    if flavor != Flavor::Broadcast {
        bail!("expected broadcast frame, got {flavor:?}");
    }
    let params = cur.f32s(meta.dim)?;
    Ok((params, layer_ids))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::testutil::{toy_meta, toy_update};

    fn all_layers(meta: &ModelMeta) -> Vec<usize> {
        (0..meta.num_layers()).collect()
    }

    fn vec_of(d: &Decoded) -> &[f32] {
        match d {
            Decoded::Vector(v) => v,
            Decoded::Scalar(_) => panic!("expected vector"),
        }
    }

    #[test]
    fn dense_full_roundtrip_exact() {
        let meta = toy_meta();
        let u = toy_update(1, meta.dim);
        let f = encode_update(&u, &meta, &all_layers(&meta), &WireHint::Dense).unwrap();
        assert_eq!(f.len(), dense_frame_len(&meta) as usize);
        assert_eq!(f.flavor().unwrap(), Flavor::Dense);
        let d = decode_update(f.as_bytes(), &meta).unwrap();
        assert_eq!(vec_of(&d), u.as_slice());
    }

    #[test]
    fn dense_subset_zero_fills_missing_layers() {
        let meta = toy_meta();
        let u = toy_update(2, meta.dim);
        // upload only layer 1 (LUAR recycling layer 0)
        let f = encode_update(&u, &meta, &[1], &WireHint::Dense).unwrap();
        let lm = &meta.layers[1];
        assert_eq!(f.len(), HEADER_LEN + 2 + 4 * lm.size);
        let d = decode_update(f.as_bytes(), &meta).unwrap();
        let v = vec_of(&d);
        assert_eq!(&v[lm.offset..lm.offset + lm.size], &u[lm.offset..lm.offset + lm.size]);
        assert!(v[..lm.offset].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn sparse_roundtrip_exact_and_counts_index_overhead() {
        let meta = toy_meta();
        let mut u = vec![0.0f32; meta.dim];
        u[3] = 1.5;
        u[29] = -2.25;
        let f = encode_update(&u, &meta, &all_layers(&meta), &WireHint::Sparse).unwrap();
        // header + ids + nnz + 2 * (index + value)
        assert_eq!(f.len(), HEADER_LEN + 2 * 2 + 4 + 2 * 8);
        let d = decode_update(f.as_bytes(), &meta).unwrap();
        assert_eq!(vec_of(&d), u.as_slice());
    }

    #[test]
    fn quantized_roundtrip_reproduces_grid_points() {
        let meta = toy_meta();
        let mut u = toy_update(3, meta.dim);
        let mut q = crate::compress::Quantize::new(16);
        let mut rng = crate::rng::Rng::seed_from_u64(9);
        use crate::compress::UpdateCompressor;
        q.compress(0, &mut u, &meta, 0, &mut rng);
        let hint = q.wire_hint();
        let f = encode_update(&u, &meta, &all_layers(&meta), &hint).unwrap();
        let d = decode_update(f.as_bytes(), &meta).unwrap();
        assert_eq!(vec_of(&d), u.as_slice(), "quantized grid must round-trip bit-exactly");
        // 4 bits/elem beats dense
        assert!(f.len() < dense_frame_len(&meta) as usize);
    }

    #[test]
    fn quantized_constant_layer_roundtrips() {
        let meta = toy_meta();
        let mut u = vec![0.75f32; meta.dim];
        let mut q = crate::compress::Quantize::new(8);
        let mut rng = crate::rng::Rng::seed_from_u64(10);
        use crate::compress::UpdateCompressor;
        q.compress(0, &mut u, &meta, 0, &mut rng);
        let f = encode_update(&u, &meta, &all_layers(&meta), &q.wire_hint()).unwrap();
        let d = decode_update(f.as_bytes(), &meta).unwrap();
        assert_eq!(vec_of(&d), u.as_slice());
    }

    #[test]
    fn signbits_roundtrip_exact() {
        let meta = toy_meta();
        let mut u = toy_update(4, meta.dim);
        let mut b = crate::compress::Binarize::new();
        let mut rng = crate::rng::Rng::seed_from_u64(11);
        use crate::compress::UpdateCompressor;
        b.compress(0, &mut u, &meta, 0, &mut rng);
        let f = encode_update(&u, &meta, &all_layers(&meta), &b.wire_hint()).unwrap();
        let d = decode_update(f.as_bytes(), &meta).unwrap();
        assert_eq!(vec_of(&d), u.as_slice());
        // ~1 bit/elem: far below dense
        assert!(f.len() < (meta.dim + HEADER_LEN + 2 * meta.num_layers()));
    }

    #[test]
    fn lowrank_roundtrip_within_bound() {
        let meta = toy_meta();
        let mut u = toy_update(5, meta.dim);
        let mut lr = crate::compress::LowRank::new(0.25);
        let mut rng = crate::rng::Rng::seed_from_u64(12);
        use crate::compress::UpdateCompressor;
        lr.compress(0, &mut u, &meta, 0, &mut rng);
        let f = encode_update(&u, &meta, &all_layers(&meta), &lr.wire_hint()).unwrap();
        let d = decode_update(f.as_bytes(), &meta).unwrap();
        let v = vec_of(&d);
        let err: f64 = v.iter().zip(&u).map(|(a, b)| ((a - b) as f64).powi(2)).sum::<f64>().sqrt();
        let norm: f64 = u.iter().map(|&x| (x as f64).powi(2)).sum::<f64>().sqrt();
        assert!(err <= 1e-3 * norm.max(1e-9), "factor round-trip error {err} vs norm {norm}");
    }

    #[test]
    fn scalar_roundtrip() {
        let meta = toy_meta();
        let u = vec![0.0f32; meta.dim];
        let f = encode_update(&u, &meta, &[], &WireHint::Scalar { coef: 0.375 }).unwrap();
        assert_eq!(f.len(), HEADER_LEN + 4);
        match decode_update(f.as_bytes(), &meta).unwrap() {
            Decoded::Scalar(c) => assert_eq!(c, 0.375),
            Decoded::Vector(_) => panic!("expected scalar"),
        }
        // the layer list is irrelevant to a scalar frame and never paid
        let f2 =
            encode_update(&u, &meta, &all_layers(&meta), &WireHint::Scalar { coef: 0.375 })
                .unwrap();
        assert_eq!(f2.len(), HEADER_LEN + 4);
    }

    #[test]
    fn broadcast_carries_delta_layer_ids() {
        let meta = toy_meta();
        let params = toy_update(6, meta.dim);
        let empty = encode_broadcast(&params, &meta, &[]).unwrap();
        let with_ids = encode_broadcast(&params, &meta, &[0, 1]).unwrap();
        // the R_t id list costs 2 bytes per layer on the downlink
        assert_eq!(with_ids.len(), empty.len() + 2 * 2);
        let (p, ids) = decode_broadcast(with_ids.as_bytes(), &meta).unwrap();
        assert_eq!(p, params);
        assert_eq!(ids, vec![0, 1]);
    }

    #[test]
    fn seeded_mask_roundtrip_exact() {
        let meta = toy_meta();
        let mut u = toy_update(8, meta.dim);
        let mut dr = crate::compress::DropoutAvg::new(0.5);
        let mut rng = crate::rng::Rng::seed_from_u64(13);
        use crate::compress::UpdateCompressor;
        dr.compress(4, &mut u, &meta, 7, &mut rng);
        let hint = dr.wire_hint();
        let f = encode_update(&u, &meta, &all_layers(&meta), &hint).unwrap();
        let d = decode_update(f.as_bytes(), &meta).unwrap();
        assert_eq!(vec_of(&d), u.as_slice());
        // no indices on the wire: cost ~ seed + rate + kept values
        let kept = u.iter().filter(|&&v| v != 0.0).count();
        assert!(f.len() <= HEADER_LEN + 2 * meta.num_layers() + 8 + 4 + 4 + 4 * (kept + 2));
    }

    #[test]
    fn bitmap_roundtrip_exact() {
        let meta = toy_meta();
        let mut u = toy_update(9, meta.dim);
        for (i, v) in u.iter_mut().enumerate() {
            if i % 3 != 0 {
                *v = 0.0;
            }
        }
        let f = encode_update(&u, &meta, &all_layers(&meta), &WireHint::Bitmap).unwrap();
        let d = decode_update(f.as_bytes(), &meta).unwrap();
        assert_eq!(vec_of(&d), u.as_slice());
        let kept = u.iter().filter(|&&v| v != 0.0).count();
        assert_eq!(
            f.len(),
            HEADER_LEN + 2 * meta.num_layers() + 4 + meta.dim.div_ceil(8) + 4 * kept
        );
    }

    #[test]
    fn quantized_single_level_hint_rejected_both_sides() {
        let meta = toy_meta();
        let u = vec![0.5f32; meta.dim];
        for levels in [0u32, 1] {
            let hint =
                WireHint::Quantized { levels, ranges: vec![(0.0, 0.0); meta.num_layers()] };
            assert!(
                encode_update(&u, &meta, &all_layers(&meta), &hint).is_err(),
                "levels={levels} must be rejected on encode"
            );
        }
        // A frame that *declares* < 2 levels must be rejected on
        // decode too: craft one from a valid frame by patching the
        // levels word (first payload u32 after header + 2 ids).
        let mut q = crate::compress::Quantize::new(8);
        let mut rng = crate::rng::Rng::seed_from_u64(21);
        use crate::compress::UpdateCompressor;
        let mut w = toy_update(21, meta.dim);
        q.compress(0, &mut w, &meta, 0, &mut rng);
        let f = encode_update(&w, &meta, &all_layers(&meta), &q.wire_hint()).unwrap();
        let mut bytes = f.as_bytes().to_vec();
        let levels_at = HEADER_LEN + 2 * meta.num_layers();
        bytes[levels_at..levels_at + 4].copy_from_slice(&1u32.to_le_bytes());
        assert!(decode_update(&bytes, &meta).is_err(), "1-level frame must be rejected");
    }

    #[test]
    fn quantized_degenerate_layer_with_nonzero_indices_rejected() {
        // A constant layer encodes step == 0.0 and all-zero indices;
        // flip an index bit and the decoder must refuse to alias it.
        let meta = toy_meta();
        let mut u = vec![0.25f32; meta.dim];
        let mut q = crate::compress::Quantize::new(8);
        let mut rng = crate::rng::Rng::seed_from_u64(22);
        use crate::compress::UpdateCompressor;
        q.compress(0, &mut u, &meta, 0, &mut rng);
        let f = encode_update(&u, &meta, &all_layers(&meta), &q.wire_hint()).unwrap();
        assert_eq!(
            decode_update(f.as_bytes(), &meta).map(|d| vec_of(&d).to_vec()).unwrap(),
            u,
            "constant layers must round-trip before corruption"
        );
        let mut bytes = f.as_bytes().to_vec();
        // layer 0 payload: levels u32, lo f32, step f32, then packed
        // indices — set the first packed byte.
        let idx_at = HEADER_LEN + 2 * meta.num_layers() + 4 + 8;
        bytes[idx_at] = 0xff;
        assert!(
            decode_update(&bytes, &meta).is_err(),
            "nonzero indices under step == 0.0 must be rejected"
        );
    }

    #[test]
    fn delta_uplink_correlated_roundtrip_saves_bytes() {
        let meta = toy_meta();
        let reference = toy_update(30, meta.dim);
        // A few elements move slightly, the rest are unchanged — the
        // cross-round correlation delta framing exists to exploit.
        let mut cur_up = reference.clone();
        for (i, v) in cur_up.iter_mut().enumerate() {
            if i % 7 == 0 {
                *v *= 1.0 + 1e-3;
            }
        }
        let f =
            encode_update_delta(&cur_up, &meta, &all_layers(&meta), &reference, 4).unwrap();
        assert_eq!(f.flavor().unwrap(), Flavor::Delta);
        assert!(
            (f.len() as u64) < dense_subset_len(&meta, &all_layers(&meta)),
            "correlated delta frame {} must beat dense {}",
            f.len(),
            dense_subset_len(&meta, &all_layers(&meta))
        );
        let (v, ref_version) = decode_update_delta(f.as_bytes(), &meta, &reference).unwrap();
        assert_eq!(v, cur_up, "delta round-trip must be bit-exact");
        assert_eq!(ref_version, 4);
    }

    #[test]
    fn delta_uplink_uncorrelated_bounded_and_exact() {
        let meta = toy_meta();
        let reference = toy_update(31, meta.dim);
        let cur_up = toy_update(32, meta.dim); // unrelated to reference
        let layers = all_layers(&meta);
        let f = encode_update_delta(&cur_up, &meta, &layers, &reference, 9).unwrap();
        let bound = dense_subset_len(&meta, &layers) as usize + DELTA_PREFIX_LEN + layers.len();
        assert!(f.len() <= bound, "delta frame {} exceeds bound {bound}", f.len());
        let (v, _) = decode_update_delta(f.as_bytes(), &meta, &reference).unwrap();
        assert_eq!(v, cur_up);
    }

    #[test]
    fn delta_subset_zero_fills_missing_layers() {
        let meta = toy_meta();
        let reference = toy_update(33, meta.dim);
        let mut cur_up = reference.clone();
        for v in cur_up.iter_mut() {
            *v += 1e-4;
        }
        let f = encode_update_delta(&cur_up, &meta, &[1], &reference, 2).unwrap();
        let (v, _) = decode_update_delta(f.as_bytes(), &meta, &reference).unwrap();
        let lm = &meta.layers[1];
        assert_eq!(&v[lm.offset..lm.offset + lm.size], &cur_up[lm.offset..lm.offset + lm.size]);
        assert!(v[..lm.offset].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn delta_reference_mismatch_rejected() {
        let meta = toy_meta();
        let reference = toy_update(34, meta.dim);
        let cur_up = toy_update(35, meta.dim);
        let f =
            encode_update_delta(&cur_up, &meta, &all_layers(&meta), &reference, 1).unwrap();
        let mut wrong = reference.clone();
        wrong[0] += 1.0;
        assert!(
            decode_update_delta(f.as_bytes(), &meta, &wrong).is_err(),
            "a drifted reference must be refused, never silently mis-decoded"
        );
        // plain decode_update must refuse delta frames outright
        assert!(decode_update(f.as_bytes(), &meta).is_err());
    }

    #[test]
    fn delta_broadcast_roundtrip_carries_recycle_ids() {
        let meta = toy_meta();
        let reference = toy_update(36, meta.dim);
        let mut params = reference.clone();
        for v in params.iter_mut() {
            *v *= 1.0 + 1e-3; // one small relative server step
        }
        let f = encode_broadcast_delta(&params, &meta, &[0], &reference, 7).unwrap();
        assert!(
            (f.len() as u64) < broadcast_frame_len(&meta, 1),
            "delta broadcast {} must beat dense {}",
            f.len(),
            broadcast_frame_len(&meta, 1)
        );
        let (p, ids, ref_version) =
            decode_broadcast_delta(f.as_bytes(), &meta, &reference).unwrap();
        assert_eq!(p, params, "broadcast delta must be bit-exact");
        assert_eq!(ids, vec![0]);
        assert_eq!(ref_version, 7);
        // an uplink-flavored delta frame must be refused on the downlink
        let up = encode_update_delta(&params, &meta, &all_layers(&meta), &reference, 7).unwrap();
        assert!(decode_broadcast_delta(up.as_bytes(), &meta, &reference).is_err());
    }

    #[test]
    fn corrupt_frames_rejected() {
        let meta = toy_meta();
        let u = toy_update(7, meta.dim);
        let f = encode_update(&u, &meta, &all_layers(&meta), &WireHint::Dense).unwrap();
        let mut bad_magic = f.as_bytes().to_vec();
        bad_magic[0] ^= 0xff;
        assert!(decode_update(&bad_magic, &meta).is_err());
        let truncated = &f.as_bytes()[..f.len() - 3];
        assert!(decode_update(truncated, &meta).is_err());
        let mut bad_dim = f.as_bytes().to_vec();
        bad_dim[4] ^= 0x01;
        assert!(decode_update(&bad_dim, &meta).is_err());
        assert!(decode_broadcast(f.as_bytes(), &meta).is_err(), "uplink frame on downlink");
    }

    #[test]
    fn trailer_roundtrip_and_exhaustive_flip_detection() {
        let meta = toy_meta();
        let u = toy_update(11, meta.dim);
        let f = encode_update(&u, &meta, &all_layers(&meta), &WireHint::Dense).unwrap();
        let mut sealed = f.as_bytes().to_vec();
        seal_trailer(&mut sealed);
        assert_eq!(sealed.len(), f.len() + TRAILER_LEN);
        let body = check_trailer(&sealed).unwrap();
        assert_eq!(body, f.as_bytes(), "trailer strips back to the original frame");
        let d = decode_update(body, &meta).unwrap();
        assert_eq!(vec_of(&d), u.as_slice());
        // every single-byte flip — body, length field, hash field — is
        // rejected, for every flip mask bit
        for pos in 0..sealed.len() {
            for bit in 0..8u8 {
                let mut bad = sealed.clone();
                bad[pos] ^= 1 << bit;
                assert!(
                    check_trailer(&bad).is_err(),
                    "flip at byte {pos} bit {bit} went undetected"
                );
            }
        }
        // truncation below the trailer is rejected too
        assert!(check_trailer(&sealed[..TRAILER_LEN - 1]).is_err());
    }

    #[test]
    fn bit_packing_roundtrip() {
        for bits in [1u32, 3, 4, 7, 8, 13, 32] {
            let vals: Vec<u32> = (0..97u32)
                .map(|i| if bits == 32 { i.wrapping_mul(0x9e3779b9) } else { i % (1 << bits) })
                .collect();
            let mut buf = Vec::new();
            pack_bits(vals.iter().copied(), bits, &mut buf);
            let mut cur = Cur { buf: &buf, pos: 0 };
            let back = unpack_bits(&mut cur, bits, vals.len()).unwrap();
            assert_eq!(back, vals, "bits={bits}");
        }
    }
}
