//! Pluggable client samplers for the round/async schedulers, plus the
//! per-client telemetry table the speed-biased sampler reads.
//!
//! Three policies (`net.sampler` config key / `--sampler` flag):
//!
//! * `uniform`         — the legacy cohort draw, untouched: exactly the
//!   `DataSim::sample_clients` stream, bit-for-bit (the equivalence
//!   suite and `prop_sampler_uniform_matches_legacy` pin this);
//! * `speed:pow=F`     — bias the draw by measured mean upload latency:
//!   weight `w_i = mean_upload_secs_i^(-pow)` (Konečný et al., 2016's
//!   straggler-aware lever). Clients never yet measured get the fleet
//!   mean of the measured clients, so cold starts stay near-uniform and
//!   every client keeps positive mass — starvation-free by
//!   construction;
//! * `staleness:cap=N` — cohort draw stays uniform; async absorption
//!   holds uploads with version gap > N out of the aggregation mean
//!   (bounded staleness; see `fl::AsyncRuntime::stale_cap`).
//!
//! The telemetry (`ClientStats`) is recorded on every dispatch from the
//! *self-contained* frame length — the same length the link schedule is
//! timed against — so residual (delta) framing never perturbs the
//! sampler, and a `speed` run composes with `delta_frames` unchanged.

use super::parse_kv;
use crate::rng::Rng;
use anyhow::{bail, Result};

/// RNG salt for the speed-biased cohort draw. Deliberately distinct
/// from the legacy `0xc11e_0000` sample-stream salt so the two streams
/// never collide; the golden_sampler.csv generator replicates it.
pub const SPEED_SAMPLER_SALT: u64 = 0x5eed_0000;

/// Floor on a measured mean latency (seconds) before weighting, so a
/// zero-latency degenerate link cannot produce an infinite weight.
const MIN_MEAN_SECS: f64 = 1e-9;

/// Which policy draws each round's cohort (`net.sampler`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SamplerCfg {
    /// Legacy uniform draw (default; bit-identical to pre-sampler runs).
    Uniform,
    /// Bias the draw by measured mean upload latency to the power `-pow`.
    Speed { pow: f64 },
    /// Uniform draw + hold async uploads with version gap > `cap` out
    /// of the aggregation mean.
    Staleness { cap: u64 },
}

impl Default for SamplerCfg {
    fn default() -> Self {
        SamplerCfg::Uniform
    }
}

impl SamplerCfg {
    /// Parse a compact sampler spec: `uniform`, `speed:pow=1`,
    /// `staleness:cap=4`.
    pub fn parse(spec: &str) -> Result<Self> {
        let (name, args) = match spec.split_once(':') {
            Some((n, a)) => (n, parse_kv(a)?),
            None => (spec, Default::default()),
        };
        let cfg = match name {
            "uniform" => SamplerCfg::Uniform,
            "speed" => {
                let pow = match args.get("pow") {
                    Some(v) => match v.parse::<f64>() {
                        Ok(x) => x,
                        Err(e) => bail!("sampler pow={v}: {e}"),
                    },
                    None => 1.0,
                };
                if !(pow.is_finite() && pow > 0.0) {
                    bail!("sampler speed:pow must be finite and > 0, got {pow}");
                }
                SamplerCfg::Speed { pow }
            }
            "staleness" => {
                let cap = match args.get("cap") {
                    Some(v) => match v.parse::<u64>() {
                        Ok(x) => x,
                        Err(e) => bail!("sampler cap={v}: {e}"),
                    },
                    None => bail!("sampler staleness requires cap=N"),
                };
                SamplerCfg::Staleness { cap }
            }
            other => bail!("unknown sampler {other}"),
        };
        Ok(cfg)
    }

    pub fn spec_string(&self) -> String {
        match self {
            SamplerCfg::Uniform => "uniform".into(),
            SamplerCfg::Speed { pow } => format!("speed:pow={pow}"),
            SamplerCfg::Staleness { cap } => format!("staleness:cap={cap}"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            SamplerCfg::Uniform => "uniform",
            SamplerCfg::Speed { .. } => "speed",
            SamplerCfg::Staleness { .. } => "staleness",
        }
    }

    /// The bounded-staleness cap, when this policy sets one.
    pub fn stale_cap(&self) -> Option<u64> {
        match self {
            SamplerCfg::Staleness { cap } => Some(*cap),
            _ => None,
        }
    }
}

/// Per-client participation + link telemetry, updated on every dispatch
/// and absorb. This is the table the `speed` sampler reads, the
/// `*_clients.csv` export serializes, and checkpoint v4 persists.
#[derive(Debug, Clone, PartialEq)]
pub struct ClientStats {
    /// Times the client was handed work (sync: made the cohort; async:
    /// a dispatch started). Reconciles exactly against the scheduler's
    /// dispatch log — the fairness observable.
    pub dispatches: Vec<u64>,
    /// Uploads actually folded into an aggregation.
    pub absorbed: Vec<u64>,
    /// Async uploads held out of the mean by `staleness:cap=N`.
    pub held_stale: Vec<u64>,
    /// Sum of simulated upload seconds over dispatches (self-contained
    /// frame lengths; see module docs).
    pub upload_secs_sum: Vec<f64>,
    /// Sum of self-contained upload bytes over dispatches.
    pub up_bytes: Vec<u64>,
    /// Fault-policy retry attempts (beyond each first attempt). Kept
    /// **separate** from `dispatches`/`upload_secs_sum` so
    /// `speed:pow=F` never double-penalizes a client whose injected
    /// outages forced retries — the speed weights read only
    /// first-attempt latency. Zero-filled when faults are off (and
    /// when loading a pre-v5 checkpoint), so `uniform` runs stay
    /// bit-identical.
    pub retries: Vec<u64>,
    /// Simulated seconds spent on retries (backoffs + retry attempts).
    pub retry_secs_sum: Vec<f64>,
    /// Uplink bytes paid by retries.
    pub retry_bytes: Vec<u64>,
    /// Dispatches whose every attempt failed (permanent failures).
    pub failures: Vec<u64>,
}

impl ClientStats {
    pub fn new(num_clients: usize) -> Self {
        ClientStats {
            dispatches: vec![0; num_clients],
            absorbed: vec![0; num_clients],
            held_stale: vec![0; num_clients],
            upload_secs_sum: vec![0.0; num_clients],
            up_bytes: vec![0; num_clients],
            retries: vec![0; num_clients],
            retry_secs_sum: vec![0.0; num_clients],
            retry_bytes: vec![0; num_clients],
            failures: vec![0; num_clients],
        }
    }

    pub fn len(&self) -> usize {
        self.dispatches.len()
    }

    pub fn is_empty(&self) -> bool {
        self.dispatches.is_empty()
    }

    pub fn record_dispatch(&mut self, client: usize, upload_secs: f64, bytes: u64) {
        self.dispatches[client] += 1;
        self.upload_secs_sum[client] += upload_secs;
        self.up_bytes[client] += bytes;
    }

    pub fn record_absorbed(&mut self, client: usize) {
        self.absorbed[client] += 1;
    }

    pub fn record_held(&mut self, client: usize) {
        self.held_stale[client] += 1;
    }

    /// Book `n` retry attempts (their clock and bytes) against a
    /// client, without touching the first-attempt columns the speed
    /// sampler reads.
    pub fn record_retries(&mut self, client: usize, n: u64, secs: f64, bytes: u64) {
        self.retries[client] += n;
        self.retry_secs_sum[client] += secs;
        self.retry_bytes[client] += bytes;
    }

    /// Book a dispatch whose every attempt failed.
    pub fn record_failure(&mut self, client: usize) {
        self.failures[client] += 1;
    }

    /// Mean measured upload latency, `None` until the first dispatch.
    pub fn mean_upload_secs(&self, client: usize) -> Option<f64> {
        if self.dispatches[client] == 0 {
            None
        } else {
            Some(self.upload_secs_sum[client] / self.dispatches[client] as f64)
        }
    }
}

/// Speed-sampler weights: a valid probability distribution over the
/// fleet — finite, non-negative, summing to 1 — for *any* telemetry
/// state (`prop_sampler_weights_are_a_distribution` sweeps this).
/// Unmeasured clients get the mean of the measured means; with nothing
/// measured the distribution is exactly uniform.
pub fn speed_weights(stats: &ClientStats, pow: f64) -> Vec<f64> {
    let n = stats.len();
    if n == 0 {
        return Vec::new();
    }
    let uniform = vec![1.0 / n as f64; n];
    let means: Vec<Option<f64>> = (0..n)
        .map(|c| stats.mean_upload_secs(c).map(|m| m.max(MIN_MEAN_SECS)))
        .collect();
    let measured: Vec<f64> = means.iter().filter_map(|m| *m).collect();
    if measured.is_empty() {
        return uniform;
    }
    let fill = measured.iter().sum::<f64>() / measured.len() as f64;
    let weights: Vec<f64> = means.iter().map(|m| m.unwrap_or(fill).powf(-pow)).collect();
    let total: f64 = weights.iter().sum();
    if !total.is_finite() || total <= 0.0 || weights.iter().any(|w| !w.is_finite()) {
        // pathological telemetry (overflow/underflow): fail safe to
        // uniform rather than feeding garbage to the weighted draw
        return uniform;
    }
    weights.into_iter().map(|w| w / total).collect()
}

/// Draw one speed-biased cohort. Seeded per round with a salt distinct
/// from the legacy stream; always returns `active.min(n)` distinct
/// clients because `speed_weights` keeps every client's mass positive.
pub fn speed_cohort(
    stats: &ClientStats,
    pow: f64,
    round: usize,
    active: usize,
    seed: u64,
) -> Vec<usize> {
    let weights = speed_weights(stats, pow);
    let mut rng = Rng::seed_from_u64(seed ^ SPEED_SAMPLER_SALT ^ round as u64);
    rng.weighted_sample_without_replacement(&weights, active)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_spec_roundtrip() {
        for spec in ["uniform", "speed:pow=1", "speed:pow=2.5", "staleness:cap=4"] {
            let s = SamplerCfg::parse(spec).unwrap();
            let again = SamplerCfg::parse(&s.spec_string()).unwrap();
            assert_eq!(s, again, "{spec}");
        }
        assert_eq!(SamplerCfg::parse("uniform").unwrap(), SamplerCfg::default());
        assert_eq!(SamplerCfg::parse("speed").unwrap(), SamplerCfg::Speed { pow: 1.0 });
        assert!(SamplerCfg::parse("warp").is_err());
        assert!(SamplerCfg::parse("speed:pow=0").is_err());
        assert!(SamplerCfg::parse("speed:pow=abc").is_err());
        assert!(SamplerCfg::parse("staleness").is_err(), "cap is required");
        assert!(SamplerCfg::parse("staleness:cap=x").is_err());
    }

    #[test]
    fn stale_cap_only_for_staleness() {
        assert_eq!(SamplerCfg::Uniform.stale_cap(), None);
        assert_eq!(SamplerCfg::Speed { pow: 1.0 }.stale_cap(), None);
        assert_eq!(SamplerCfg::Staleness { cap: 3 }.stale_cap(), Some(3));
    }

    #[test]
    fn cold_stats_give_uniform_weights() {
        let stats = ClientStats::new(8);
        let w = speed_weights(&stats, 1.0);
        assert_eq!(w, vec![1.0 / 8.0; 8]);
    }

    #[test]
    fn slow_clients_lose_mass() {
        let mut stats = ClientStats::new(3);
        stats.record_dispatch(0, 1.0, 1000);
        stats.record_dispatch(1, 10.0, 1000);
        // client 2 unmeasured -> mean of {1, 10} = 5.5
        let w = speed_weights(&stats, 1.0);
        assert!((w.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert!(w[0] > w[2] && w[2] > w[1], "ordering fast > unmeasured > slow: {w:?}");
        // higher pow sharpens the bias
        let sharp = speed_weights(&stats, 2.0);
        assert!(sharp[0] / sharp[1] > w[0] / w[1]);
    }

    #[test]
    fn mean_latency_averages_over_dispatches() {
        let mut stats = ClientStats::new(2);
        assert_eq!(stats.mean_upload_secs(0), None);
        stats.record_dispatch(0, 2.0, 10);
        stats.record_dispatch(0, 4.0, 30);
        assert_eq!(stats.mean_upload_secs(0), Some(3.0));
        assert_eq!(stats.up_bytes[0], 40);
        assert_eq!(stats.dispatches[0], 2);
    }

    #[test]
    fn retries_never_perturb_speed_weights() {
        // the double-penalty guard: a client that suffered injected
        // outages is already slower on the wall clock — its retry
        // telemetry must not also shift its cohort weight
        let mut clean = ClientStats::new(4);
        let mut faulted = ClientStats::new(4);
        for c in 0..4 {
            clean.record_dispatch(c, 1.0 + c as f64, 500);
            faulted.record_dispatch(c, 1.0 + c as f64, 500);
        }
        faulted.record_retries(1, 3, 90.0, 1500);
        faulted.record_failure(1);
        assert_eq!(speed_weights(&clean, 1.0), speed_weights(&faulted, 1.0));
        assert_eq!(faulted.mean_upload_secs(1), clean.mean_upload_secs(1));
        assert_eq!(faulted.retries[1], 3);
        assert_eq!(faulted.retry_bytes[1], 1500);
        assert_eq!(faulted.failures[1], 1);
    }

    #[test]
    fn speed_cohort_is_deterministic_and_distinct() {
        let mut stats = ClientStats::new(16);
        for c in 0..16 {
            stats.record_dispatch(c, 0.5 + c as f64, 100);
        }
        let a = speed_cohort(&stats, 1.0, 3, 6, 42);
        let b = speed_cohort(&stats, 1.0, 3, 6, 42);
        assert_eq!(a, b);
        assert_eq!(a.len(), 6);
        let mut d = a.clone();
        d.sort_unstable();
        d.dedup();
        assert_eq!(d.len(), 6, "cohort must be distinct clients");
        // different round or seed -> different stream
        assert_ne!(speed_cohort(&stats, 1.0, 4, 6, 42), a);
    }

    #[test]
    fn fast_clients_dominate_participation() {
        let mut stats = ClientStats::new(8);
        for c in 0..8 {
            // clients 0..4 are 20x faster than 4..8
            let secs = if c < 4 { 0.1 } else { 2.0 };
            stats.record_dispatch(c, secs, 100);
        }
        let mut fast = 0usize;
        let mut total = 0usize;
        for round in 0..200 {
            for &c in &speed_cohort(&stats, 1.0, round, 4, 7) {
                total += 1;
                if c < 4 {
                    fast += 1;
                }
            }
        }
        assert_eq!(total, 800);
        assert!(fast > 550, "fast cohort drew only {fast}/800");
    }
}
