//! Network simulation subsystem: wire transport, heterogeneous links,
//! and the event-driven round scheduler.
//!
//! * `wire`  — framed, byte-exact codecs for every upload flavor; the
//!   comm ledger records `frame.len()`, not analytic estimates.
//! * `links` — per-client up/down bandwidth + RTT + compute speed,
//!   drawn from configurable fleet distributions.
//! * `sched` — binary-heap event queue simulating broadcast → local
//!   compute → upload per client, with `sync` / `deadline` /
//!   `buffered` round-closing policies plus the barrier-free `async`
//!   mode (`AsyncQueue` persists completion events across dispatches;
//!   `Staleness` maps version gaps to aggregation weights; the control
//!   flow lives in `fl::AsyncRuntime`).
//!
//! * `sampler` — pluggable cohort-draw policies (`uniform` /
//!   `speed:pow=F` / `staleness:cap=N`) plus the per-client telemetry
//!   table (`ClientStats`) the speed-biased policy reads.
//!
//! * `faults` — deterministic fault injection (`drop` / `outage` /
//!   `corrupt` / `mixed`, seeded per `(client, version, attempt)`)
//!   plus the server-side `FailurePolicy` (bounded retry with
//!   exponential backoff, per-attempt timeout, quorum-degraded
//!   close). `off` is bit-identical to a build without the module;
//!   see `docs/faults.md`.
//!
//! `NetCfg` is the `net:` block of a run config (flat keys
//! `link_dist`, `round_mode`, `deadline_s`, `buffer_k`, `compute_s`,
//! `sampler`, `faults`); `NetSim` is the per-run instance the FL
//! server drives each round.

pub mod faults;
pub mod links;
pub mod sampler;
pub mod sched;
pub mod wire;

pub use faults::{ChainOutcome, FailurePolicy, FaultKind, FaultPlan, FaultsCfg};
pub use links::{ClientLink, LinkDist, LinkFleet};
pub use sampler::{speed_cohort, speed_weights, ClientStats, SamplerCfg};
pub use sched::{Arrival, AsyncQueue, RoundMode, RoundOutcome, Staleness};
pub use wire::{Decoded, WireFrame, WireHint};

use anyhow::{Context, Result};
use std::collections::BTreeMap;

/// Parse `k=v,k=v` argument lists for the net spec strings.
pub(crate) fn parse_kv(s: &str) -> Result<BTreeMap<String, String>> {
    let mut m = BTreeMap::new();
    for part in s.split(',') {
        if part.is_empty() {
            continue;
        }
        let (k, v) = part.split_once('=').with_context(|| format!("bad net arg {part:?}"))?;
        m.insert(k.trim().to_string(), v.trim().to_string());
    }
    Ok(m)
}

/// The `net:` configuration block of one FL run.
#[derive(Debug, Clone, PartialEq)]
pub struct NetCfg {
    pub link_dist: LinkDist,
    pub round_mode: RoundMode,
    /// Mean local-compute seconds per client per round (scaled by each
    /// client's `compute_mult`); 0 models communication-bound rounds.
    pub compute_s: f64,
    /// Residual (delta) framing: encode uplink updates and downlink
    /// broadcasts against per-client reference snapshots
    /// (`wire::Flavor::Delta`), falling back to self-contained frames
    /// when no valid reference exists. Lossless and ledger-only: model
    /// trajectories and the link schedule are bit-identical to dense
    /// framing — only the recorded bytes shrink (see docs/wire.md).
    pub delta_frames: bool,
    /// Cohort-draw policy (`uniform` keeps the legacy stream
    /// bit-exactly; `speed:pow=F` biases by measured upload latency;
    /// `staleness:cap=N` bounds the async aggregation mean).
    pub sampler: SamplerCfg,
    /// Deterministic fault injection + failure policy (`off` keeps
    /// the fault path unentered and runs bit-identical to builds
    /// without it; configs written before the key existed parse as
    /// `off`).
    pub faults: FaultsCfg,
}

impl Default for NetCfg {
    fn default() -> Self {
        NetCfg {
            link_dist: LinkDist::default(),
            round_mode: RoundMode::Sync,
            compute_s: 0.0,
            delta_frames: false,
            sampler: SamplerCfg::Uniform,
            faults: FaultsCfg::default(),
        }
    }
}

/// Per-run network simulator: a fixed link fleet plus the round policy.
#[derive(Debug, Clone)]
pub struct NetSim {
    pub cfg: NetCfg,
    pub fleet: LinkFleet,
}

impl NetSim {
    pub fn new(cfg: NetCfg, num_clients: usize, seed: u64) -> Self {
        let fleet = LinkFleet::new(&cfg.link_dist, num_clients, seed);
        NetSim { cfg, fleet }
    }

    /// Per-slot completion time: download the broadcast, compute, push
    /// the upload frame.
    pub fn client_secs(&self, client: usize, bcast_bytes: u64, frame_bytes: u64) -> f64 {
        let mut sp = crate::obs::span("link.transit");
        let l = self.fleet.link(client);
        let secs = l.download_secs(bcast_bytes)
            + self.cfg.compute_s * l.compute_mult
            + l.upload_secs(frame_bytes);
        sp.set_sim(secs);
        crate::obs::observe("link.transit_s", secs);
        secs
    }

    /// Simulate one round for `actives[i]` uploading `frame_bytes[i]`
    /// after a `bcast_bytes` broadcast.
    pub fn round(&self, actives: &[usize], bcast_bytes: u64, frame_bytes: &[u64]) -> RoundOutcome {
        assert_eq!(actives.len(), frame_bytes.len());
        let times: Vec<f64> = actives
            .iter()
            .zip(frame_bytes)
            .map(|(&c, &fb)| self.client_secs(c, bcast_bytes, fb))
            .collect();
        sched::simulate_round(&self.cfg.round_mode, &times)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_cfg_matches_legacy_semantics() {
        let cfg = NetCfg::default();
        assert_eq!(cfg.round_mode, RoundMode::Sync);
        assert_eq!(cfg.link_dist, LinkDist::default());
        assert_eq!(cfg.compute_s, 0.0);
        assert!(!cfg.delta_frames, "delta framing is opt-in");
        assert_eq!(cfg.sampler, SamplerCfg::Uniform, "biased sampling is opt-in");
        assert!(cfg.faults.is_off(), "fault injection is opt-in");
    }

    #[test]
    fn sim_round_uses_per_client_links() {
        // fast_frac 0.75 keeps the median inside the fast cohort with
        // overwhelming probability, so the straggler tail is visible.
        let cfg = NetCfg {
            link_dist: LinkDist::Bimodal {
                fast_frac: 0.75,
                fast_up_mbps: 80.0,
                slow_up_mbps: 1.0,
                down_mbps: 100.0,
                rtt_s: 0.0,
            },
            round_mode: RoundMode::Sync,
            compute_s: 0.0,
            delta_frames: false,
            sampler: SamplerCfg::Uniform,
            faults: FaultsCfg::default(),
        };
        let sim = NetSim::new(cfg, 64, 9);
        let actives: Vec<usize> = (0..64).collect();
        let frames = vec![1_000_000u64; 64];
        let out = sim.round(&actives, 500_000, &frames);
        // slowest = a slow-cohort client: 8Mb / 1Mbps = 8s upload
        let slowest = actives
            .iter()
            .map(|&c| sim.client_secs(c, 500_000, 1_000_000))
            .fold(0.0f64, f64::max);
        assert_eq!(out.round_secs, slowest);
        assert!(out.straggler_tail_s > 0.0, "bimodal fleet must show a tail");
    }

    #[test]
    fn compute_time_scales_with_multiplier() {
        let cfg = NetCfg {
            link_dist: LinkDist::default(),
            round_mode: RoundMode::Sync,
            compute_s: 2.0,
            delta_frames: false,
            sampler: SamplerCfg::Uniform,
            faults: FaultsCfg::default(),
        };
        let sim = NetSim::new(cfg, 4, 1);
        let with = sim.client_secs(0, 0, 0);
        assert!((with - (2.0 + 0.05)).abs() < 1e-12); // compute + rtt
    }
}
