//! Deterministic fault injection for the simulated network, plus the
//! server-side failure policy that survives it.
//!
//! Real FL fleets drop uploads, lose links for minutes at a time, and
//! deliver corrupted bytes; the simulator injects all three, seeded
//! per `(client, model_version, attempt)` so a faulted run is exactly
//! reproducible and `off` is bit-identical to a build without this
//! module. One config key drives it (`net.faults` / `--faults`):
//!
//! * `off`                  — no faults (default);
//! * `drop:p=F`             — each upload attempt is lost in transit
//!   with probability `p` (bytes were sent; the server times out);
//! * `outage:p=F,len=S`     — like `drop`, but the client's link also
//!   goes down for `S` sim-seconds; attempts started inside the window
//!   fail without transmitting;
//! * `corrupt:p=F`          — the framed payload arrives with one byte
//!   flipped. Detected **always** by the `wire` integrity trailer
//!   (length + FNV-1a over the sealed frame), so a corrupted update is
//!   never silently aggregated;
//! * `mixed:drop=F,outage=F,len=S,corrupt=F` — all three at once.
//!
//! Every spec also accepts the failure-policy knobs
//! `retries=N,backoff=S,timeout=S,quorum=N`: a failed attempt is
//! retried up to `retries` times with exponential backoff
//! (`backoff * 2^k`), an undelivered attempt costs the server its
//! per-attempt `timeout` of simulated clock, and an aggregation that
//! closes with fewer than `quorum` surviving uploads is counted as
//! quorum-degraded (the server aggregates what arrived and LUAR's
//! recycling covers the rest — it never stalls or crashes).
//!
//! The whole retry chain for one dispatch is resolved by
//! [`FaultPlan::attempt_chain`]: because every per-attempt draw is a
//! pure function of `(seed, client, version, attempt)`, the chain's
//! outcome is fixed at dispatch time, and both the real server and the
//! engine-free test fixture collapse it into one (secs, bytes,
//! survived) tuple. Retries pay real bytes and real clock in the
//! ledger; see `docs/faults.md` for the full fault model.

use super::{parse_kv, wire};
use crate::rng::Rng;
use anyhow::{bail, Result};
use std::collections::BTreeMap;

/// RNG salt for fault draws — distinct from the cohort (`0xc11e_0000`),
/// speed-sampler (`0x5eed_0000`), and legacy failure (`0xfa11`) salts.
pub const FAULT_SALT: u64 = 0xfa17_0000;

/// Which faults are injected, and how often.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultKind {
    /// No injection; the fault path is never entered (bit-identical to
    /// a build without it).
    Off,
    /// Lose each upload attempt in transit with probability `p`.
    Drop { p: f64 },
    /// Lose the attempt with probability `p` and take the client's
    /// link down for `len_s` sim-seconds.
    Outage { p: f64, len_s: f64 },
    /// Deliver the attempt with one flipped byte with probability `p`.
    Corrupt { p: f64 },
    /// Independent per-attempt probabilities for all three faults
    /// (at most one fires per attempt, drawn from a single uniform).
    Mixed { drop: f64, outage: f64, len_s: f64, corrupt: f64 },
}

/// How the server responds to failed upload attempts.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FailurePolicy {
    /// Retries after the first attempt; `0` = fail fast.
    pub max_retries: u32,
    /// Base backoff before retry `k` is `backoff_s * 2^(k-1)` seconds.
    pub backoff_s: f64,
    /// Simulated seconds the server waits before declaring an
    /// undelivered attempt lost.
    pub timeout_s: f64,
    /// Minimum surviving uploads per aggregation before the close is
    /// counted as quorum-degraded.
    pub quorum: usize,
}

impl Default for FailurePolicy {
    fn default() -> Self {
        FailurePolicy { max_retries: 2, backoff_s: 0.5, timeout_s: 30.0, quorum: 1 }
    }
}

/// The `net.faults` config value: injected fault kind + failure policy.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultsCfg {
    pub kind: FaultKind,
    pub policy: FailurePolicy,
}

impl Default for FaultsCfg {
    fn default() -> Self {
        FaultsCfg { kind: FaultKind::Off, policy: FailurePolicy::default() }
    }
}

fn parse_prob(args: &BTreeMap<String, String>, key: &str, default: f64) -> Result<f64> {
    match args.get(key) {
        Some(v) => match v.parse::<f64>() {
            Ok(p) if p.is_finite() && (0.0..1.0).contains(&p) => Ok(p),
            _ => bail!("faults {key}={v} must be a probability in [0, 1)"),
        },
        None => Ok(default),
    }
}

fn parse_secs(args: &BTreeMap<String, String>, key: &str, default: f64) -> Result<f64> {
    match args.get(key) {
        Some(v) => match v.parse::<f64>() {
            Ok(s) if s.is_finite() && s > 0.0 => Ok(s),
            _ => bail!("faults {key}={v} must be a positive number of seconds"),
        },
        None => Ok(default),
    }
}

impl FaultsCfg {
    /// Parse a compact fault spec: `off`, `drop:p=0.1`,
    /// `outage:p=0.05,len=20`, `corrupt:p=0.02`,
    /// `mixed:drop=0.1,outage=0.05,len=20,corrupt=0.02` — each
    /// optionally followed by policy keys
    /// `retries=N,backoff=S,timeout=S,quorum=N`.
    pub fn parse(spec: &str) -> Result<Self> {
        let (name, args) = match spec.split_once(':') {
            Some((n, a)) => (n, parse_kv(a)?),
            None => (spec, Default::default()),
        };
        let kind = match name {
            "off" => {
                if !args.is_empty() {
                    bail!("faults off takes no arguments");
                }
                FaultKind::Off
            }
            "drop" => FaultKind::Drop { p: parse_prob(&args, "p", 0.1)? },
            "outage" => FaultKind::Outage {
                p: parse_prob(&args, "p", 0.1)?,
                len_s: parse_secs(&args, "len", 30.0)?,
            },
            "corrupt" => FaultKind::Corrupt { p: parse_prob(&args, "p", 0.1)? },
            "mixed" => {
                let drop = parse_prob(&args, "drop", 0.0)?;
                let outage = parse_prob(&args, "outage", 0.0)?;
                let corrupt = parse_prob(&args, "corrupt", 0.0)?;
                if drop + outage + corrupt >= 1.0 {
                    bail!("faults mixed: drop+outage+corrupt must sum below 1");
                }
                FaultKind::Mixed { drop, outage, len_s: parse_secs(&args, "len", 30.0)?, corrupt }
            }
            other => bail!("unknown faults kind {other}"),
        };
        let d = FailurePolicy::default();
        let policy = FailurePolicy {
            max_retries: match args.get("retries") {
                Some(v) => match v.parse::<u32>() {
                    Ok(x) => x,
                    Err(e) => bail!("faults retries={v}: {e}"),
                },
                None => d.max_retries,
            },
            backoff_s: parse_secs(&args, "backoff", d.backoff_s)?,
            timeout_s: parse_secs(&args, "timeout", d.timeout_s)?,
            quorum: match args.get("quorum") {
                Some(v) => match v.parse::<usize>() {
                    Ok(x) if x >= 1 => x,
                    _ => bail!("faults quorum={v} must be a positive integer"),
                },
                None => d.quorum,
            },
        };
        if kind == FaultKind::Off && policy != d {
            bail!("faults off takes no arguments");
        }
        Ok(FaultsCfg { kind, policy })
    }

    /// Inverse of `parse` (f64 Display is shortest-roundtrip, so the
    /// round-trip is exact; `prop_fault_spec_roundtrips` pins it).
    pub fn spec_string(&self) -> String {
        let p = &self.policy;
        let policy = format!(
            "retries={},backoff={},timeout={},quorum={}",
            p.max_retries, p.backoff_s, p.timeout_s, p.quorum
        );
        match self.kind {
            FaultKind::Off => "off".into(),
            FaultKind::Drop { p } => format!("drop:p={p},{policy}"),
            FaultKind::Outage { p, len_s } => format!("outage:p={p},len={len_s},{policy}"),
            FaultKind::Corrupt { p } => format!("corrupt:p={p},{policy}"),
            FaultKind::Mixed { drop, outage, len_s, corrupt } => {
                format!("mixed:drop={drop},outage={outage},len={len_s},corrupt={corrupt},{policy}")
            }
        }
    }

    pub fn name(&self) -> &'static str {
        match self.kind {
            FaultKind::Off => "off",
            FaultKind::Drop { .. } => "drop",
            FaultKind::Outage { .. } => "outage",
            FaultKind::Corrupt { .. } => "corrupt",
            FaultKind::Mixed { .. } => "mixed",
        }
    }

    pub fn is_off(&self) -> bool {
        self.kind == FaultKind::Off
    }
}

/// What one attempt's fault draw injected.
#[derive(Debug, Clone, Copy, PartialEq)]
enum Injected {
    Drop,
    Outage { len_s: f64 },
    Corrupt,
}

/// Resolution of one dispatch's full retry chain.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ChainOutcome {
    /// Did any attempt deliver an intact frame?
    pub survived: bool,
    /// Attempts made (1 = clean first try).
    pub attempts: u32,
    /// Total simulated seconds from dispatch to resolution (attempt
    /// costs + backoffs).
    pub secs: f64,
    /// Uplink bytes paid across all attempts.
    pub up_bytes: u64,
    /// Bytes beyond the first attempt (the retry surcharge).
    pub retry_up_bytes: u64,
    /// Simulated seconds beyond the first attempt.
    pub retry_secs: f64,
    pub drops: u32,
    pub outages: u32,
    pub corrupts: u32,
}

/// Mutable fault state for one run: outage windows, cumulative failure
/// counters, and the bytes paid by permanently failed uploads that
/// still owe the ledger. Checkpoint v5 persists all of it; the draws
/// themselves are stateless (pure functions of the seed), so resume is
/// exact.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultPlan {
    pub cfg: FaultsCfg,
    seed: u64,
    /// Per-client "link is down until this sim-second" horizon.
    pub down_until: Vec<f64>,
    /// Injected-fault counters (mirrored into obs as
    /// `fault.injected.*`).
    pub drops: u64,
    pub outages: u64,
    pub corrupts: u64,
    /// Retry attempts made (`fault.retries`).
    pub retries: u64,
    /// Dispatches whose every attempt failed (`fault.perm_failures`).
    pub perm_failures: u64,
    /// Aggregations that closed below quorum (`fault.quorum_degraded`).
    pub quorum_degraded: u64,
    /// Ledger bytes paid by permanently failed uploads, drained into
    /// the next aggregation's accounting.
    pub orphan_up_bytes: u64,
    pub orphan_down_bytes: u64,
}

impl FaultPlan {
    pub fn new(cfg: FaultsCfg, num_clients: usize, seed: u64) -> Self {
        FaultPlan {
            cfg,
            seed,
            down_until: vec![0.0; num_clients],
            drops: 0,
            outages: 0,
            corrupts: 0,
            retries: 0,
            perm_failures: 0,
            quorum_degraded: 0,
            orphan_up_bytes: 0,
            orphan_down_bytes: 0,
        }
    }

    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// The per-attempt RNG: a pure function of
    /// `(seed, client, version, attempt)`, so every draw is replayable
    /// regardless of evaluation order or checkpoint resume.
    fn attempt_rng(&self, client: usize, version: u64, attempt: u32) -> Rng {
        Rng::seed_from_u64(
            self.seed
                ^ FAULT_SALT
                ^ (client as u64).wrapping_mul(0x9e37_79b9)
                ^ version.wrapping_mul(0x85eb_ca6b)
                ^ (attempt as u64 + 1).wrapping_mul(0xc2b2_ae35),
        )
    }

    /// One uniform decides which fault (if any) fires this attempt.
    fn draw(&self, rng: &mut Rng) -> Option<Injected> {
        let (drop, outage, len_s, corrupt) = match self.cfg.kind {
            FaultKind::Off => return None,
            FaultKind::Drop { p } => (p, 0.0, 0.0, 0.0),
            FaultKind::Outage { p, len_s } => (0.0, p, len_s, 0.0),
            FaultKind::Corrupt { p } => (0.0, 0.0, 0.0, p),
            FaultKind::Mixed { drop, outage, len_s, corrupt } => (drop, outage, len_s, corrupt),
        };
        let u = rng.f64();
        if u < drop {
            Some(Injected::Drop)
        } else if u < drop + outage {
            Some(Injected::Outage { len_s })
        } else if u < drop + outage + corrupt {
            Some(Injected::Corrupt)
        } else {
            None
        }
    }

    /// Resolve the full retry chain for one dispatch of `client` at
    /// model `version`, starting at sim-time `t0`. `attempt_secs` is
    /// the clean per-attempt link time (broadcast + compute + upload);
    /// `frame` is the trailer-sealed uplink frame actually sent.
    ///
    /// Per attempt: a clean delivery costs `attempt_secs`; a corrupted
    /// delivery costs `attempt_secs` (the flip is caught by
    /// `wire::check_trailer` the instant the frame lands); an
    /// undelivered attempt (drop, or outage window) costs the policy's
    /// `timeout_s` — the server cannot observe a loss any earlier.
    /// Bytes are paid for every attempt that transmitted (drops and
    /// corruptions included); attempts started inside an outage window
    /// transmit nothing.
    pub fn attempt_chain(
        &mut self,
        client: usize,
        version: u64,
        t0: f64,
        attempt_secs: f64,
        frame: &[u8],
    ) -> ChainOutcome {
        let policy = self.cfg.policy;
        let frame_len = frame.len() as u64;
        let mut out = ChainOutcome {
            survived: false,
            attempts: 0,
            secs: 0.0,
            up_bytes: 0,
            retry_up_bytes: 0,
            retry_secs: 0.0,
            drops: 0,
            outages: 0,
            corrupts: 0,
        };
        let mut t = t0;
        for attempt in 0..=policy.max_retries {
            if attempt > 0 {
                let backoff = policy.backoff_s * 2f64.powi(attempt as i32 - 1);
                t += backoff;
                out.secs += backoff;
                out.retry_secs += backoff;
                self.retries += 1;
            }
            out.attempts = attempt + 1;
            // an open outage window fails the attempt without a draw
            // (and without transmitting); otherwise one seeded uniform
            // decides the attempt's fate
            let injected = if t < self.down_until[client] {
                self.outages += 1;
                out.outages += 1;
                Some(Injected::Drop) // semantically: undelivered, 0 bytes
            } else {
                let mut rng = self.attempt_rng(client, version, attempt);
                match self.draw(&mut rng) {
                    Some(Injected::Corrupt) => {
                        self.corrupts += 1;
                        out.corrupts += 1;
                        // flip one byte of the sealed frame; the
                        // integrity trailer must reject it at decode
                        let mut bad = frame.to_vec();
                        let pos = rng.gen_range(0, bad.len());
                        let mask = rng.gen_range(1, 256) as u8;
                        bad[pos] ^= mask;
                        if wire::check_trailer(&bad).is_ok() {
                            // single-byte flips always change the FNV
                            // state, so this cannot happen — but if the
                            // detector ever passed, honesty demands the
                            // frame count as delivered
                            None
                        } else {
                            Some(Injected::Corrupt)
                        }
                    }
                    Some(Injected::Outage { len_s }) => {
                        self.outages += 1;
                        out.outages += 1;
                        self.down_until[client] = (t + len_s).max(self.down_until[client]);
                        Some(Injected::Outage { len_s })
                    }
                    Some(Injected::Drop) => {
                        self.drops += 1;
                        out.drops += 1;
                        Some(Injected::Drop)
                    }
                    None => None,
                }
            };
            let was_down = t < self.down_until[client] && injected == Some(Injected::Drop);
            let (cost, bytes, delivered) = match injected {
                None => (attempt_secs, frame_len, true),
                Some(Injected::Corrupt) => (attempt_secs, frame_len, false),
                Some(Injected::Outage { .. }) => (policy.timeout_s, frame_len, false),
                Some(Injected::Drop) => {
                    (policy.timeout_s, if was_down { 0 } else { frame_len }, false)
                }
            };
            t += cost;
            out.secs += cost;
            out.up_bytes += bytes;
            if attempt > 0 {
                out.retry_up_bytes += bytes;
                out.retry_secs += cost;
            }
            if delivered {
                out.survived = true;
                break;
            }
        }
        if !out.survived {
            self.perm_failures += 1;
        }
        out
    }

    /// Book the ledger bytes a permanently failed upload paid; drained
    /// into the next aggregation's accounting.
    pub fn note_orphan(&mut self, up_bytes: u64, down_bytes: u64) {
        self.orphan_up_bytes += up_bytes;
        self.orphan_down_bytes += down_bytes;
    }

    /// Take the orphaned bytes accumulated since the last aggregation.
    pub fn drain_orphans(&mut self) -> (u64, u64) {
        (std::mem::take(&mut self.orphan_up_bytes), std::mem::take(&mut self.orphan_down_bytes))
    }

    /// Count an aggregation that closed with `survivors < quorum`.
    pub fn note_quorum_degraded(&mut self) {
        self.quorum_degraded += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sealed_frame(n: usize) -> Vec<u8> {
        let mut f: Vec<u8> = (0..n).map(|i| (i * 31 % 251) as u8).collect();
        wire::seal_trailer(&mut f);
        f
    }

    #[test]
    fn parse_spec_roundtrip() {
        for spec in [
            "off",
            "drop:p=0.1",
            "drop:p=0.25,retries=4,backoff=0.25,timeout=10,quorum=3",
            "outage:p=0.05,len=20",
            "corrupt:p=0.02",
            "mixed:drop=0.1,outage=0.05,len=20,corrupt=0.02",
        ] {
            let c = FaultsCfg::parse(spec).unwrap();
            assert_eq!(FaultsCfg::parse(&c.spec_string()).unwrap(), c, "{spec}");
        }
        assert_eq!(FaultsCfg::parse("off").unwrap(), FaultsCfg::default());
        assert!(FaultsCfg::default().is_off());
        assert_eq!(FaultsCfg::parse("drop").unwrap().kind, FaultKind::Drop { p: 0.1 });
        assert_eq!(
            FaultsCfg::parse("outage").unwrap().kind,
            FaultKind::Outage { p: 0.1, len_s: 30.0 }
        );
        assert!(FaultsCfg::parse("drop:p=1").is_err(), "p=1 would loop forever");
        assert!(FaultsCfg::parse("drop:p=-0.1").is_err());
        assert!(FaultsCfg::parse("drop:p=nan").is_err());
        assert!(FaultsCfg::parse("outage:p=0.1,len=0").is_err());
        assert!(FaultsCfg::parse("mixed:drop=0.6,outage=0.5").is_err(), "over-unit mass");
        assert!(FaultsCfg::parse("off:retries=3").is_err(), "off takes no arguments");
        assert!(FaultsCfg::parse("drop:p=0.1,quorum=0").is_err());
        assert!(FaultsCfg::parse("chaos").is_err());
    }

    #[test]
    fn chain_is_deterministic() {
        let cfg = FaultsCfg::parse("mixed:drop=0.2,outage=0.1,len=5,corrupt=0.1").unwrap();
        let frame = sealed_frame(200);
        let mut a = FaultPlan::new(cfg, 8, 42);
        let mut b = FaultPlan::new(cfg, 8, 42);
        for v in 0..50u64 {
            for c in 0..8usize {
                let oa = a.attempt_chain(c, v, v as f64, 1.0, &frame);
                let ob = b.attempt_chain(c, v, v as f64, 1.0, &frame);
                assert_eq!(oa, ob, "client {c} version {v}");
            }
        }
        assert_eq!(a, b);
        assert!(a.drops + a.outages + a.corrupts > 0, "chaos plan must inject something");
        // a different seed gives a different fault stream
        let mut c = FaultPlan::new(cfg, 8, 43);
        let mut differs = false;
        for v in 0..50u64 {
            for cl in 0..8usize {
                if c.attempt_chain(cl, v, v as f64, 1.0, &frame)
                    != a.attempt_chain(cl, v + 1000, v as f64, 1.0, &frame)
                {
                    differs = true;
                }
            }
        }
        assert!(differs);
    }

    #[test]
    fn off_plan_never_injects() {
        let mut plan = FaultPlan::new(FaultsCfg::default(), 4, 7);
        let frame = sealed_frame(64);
        for v in 0..100u64 {
            let out = plan.attempt_chain(v as usize % 4, v, 0.0, 2.5, &frame);
            assert!(out.survived);
            assert_eq!(out.attempts, 1);
            assert_eq!(out.secs, 2.5);
            assert_eq!(out.up_bytes, frame.len() as u64);
            assert_eq!(out.retry_up_bytes, 0);
        }
        assert_eq!((plan.drops, plan.outages, plan.corrupts, plan.retries), (0, 0, 0, 0));
        assert_eq!(plan.perm_failures, 0);
    }

    #[test]
    fn drop_chain_pays_timeout_backoff_and_retry_bytes() {
        // p just under 1 so every draw fires: all attempts drop
        let cfg = FaultsCfg::parse("drop:p=0.999999999999,retries=2,backoff=1,timeout=10").unwrap();
        let frame = sealed_frame(100);
        let mut plan = FaultPlan::new(cfg, 2, 1);
        let out = plan.attempt_chain(0, 0, 0.0, 3.0, &frame);
        assert!(!out.survived);
        assert_eq!(out.attempts, 3);
        assert_eq!(out.drops, 3);
        // 3 timeouts + backoffs 1 and 2
        assert_eq!(out.secs, 10.0 + 1.0 + 10.0 + 2.0 + 10.0);
        assert_eq!(out.up_bytes, 3 * frame.len() as u64, "dropped frames still paid bytes");
        assert_eq!(out.retry_up_bytes, 2 * frame.len() as u64);
        assert_eq!(plan.retries, 2);
        assert_eq!(plan.perm_failures, 1);
    }

    #[test]
    fn outage_window_blocks_attempts_without_bytes() {
        let cfg = FaultsCfg::parse("outage:p=0.999999999999,len=1000,retries=1,timeout=5").unwrap();
        let frame = sealed_frame(100);
        let mut plan = FaultPlan::new(cfg, 2, 1);
        let out = plan.attempt_chain(0, 0, 0.0, 2.0, &frame);
        assert!(!out.survived);
        assert_eq!(out.outages, 2, "second attempt fails inside the window");
        // first attempt transmitted (outage mid-transfer), second did not
        assert_eq!(out.up_bytes, frame.len() as u64);
        assert!(plan.down_until[0] >= 1000.0);
        assert_eq!(plan.down_until[1], 0.0, "other links stay up");
        // a later dispatch after the window heals succeeds (p only
        // fires on the draw; make it off to isolate the window)
        let mut healed = plan.clone();
        healed.cfg = FaultsCfg::default();
        let late = healed.attempt_chain(0, 1, 2000.0, 2.0, &frame);
        assert!(late.survived);
        let blocked = healed.attempt_chain(0, 2, 10.0, 2.0, &frame);
        assert!(!blocked.survived, "attempts inside the window must fail");
        assert_eq!(blocked.up_bytes, 0, "dead link transmits nothing");
    }

    #[test]
    fn corrupt_chain_is_always_detected() {
        let cfg = FaultsCfg::parse("corrupt:p=0.999999999999,retries=0").unwrap();
        let frame = sealed_frame(300);
        let mut plan = FaultPlan::new(cfg, 4, 9);
        for v in 0..200u64 {
            let out = plan.attempt_chain(v as usize % 4, v, 0.0, 1.0, &frame);
            assert!(!out.survived, "version {v}: corrupted frame slipped through");
            assert_eq!(out.corrupts, 1);
            assert_eq!(out.secs, 1.0, "corruption is caught at arrival, not at timeout");
        }
        assert_eq!(plan.corrupts, 200);
        assert_eq!(plan.perm_failures, 200);
    }

    #[test]
    fn orphan_bytes_drain_once() {
        let mut plan = FaultPlan::new(FaultsCfg::default(), 2, 1);
        plan.note_orphan(100, 40);
        plan.note_orphan(10, 2);
        assert_eq!(plan.drain_orphans(), (110, 42));
        assert_eq!(plan.drain_orphans(), (0, 0));
    }
}
