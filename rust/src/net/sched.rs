//! Event-driven round scheduler.
//!
//! Each active client runs broadcast-download → local compute → upload
//! on its own link; the per-client completion times feed a binary-heap
//! event queue, and the round mode decides when the server aggregates:
//!
//! * `sync`     — the server waits for every active client, so the
//!   slowest one bounds the round (the semantics the old
//!   `BandwidthModel` documented but did not implement — it charged
//!   the *mean* upload; the regression is pinned here and in
//!   `tests/integration_net.rs`);
//! * `deadline` — the server closes the round at a wall-clock budget
//!   and aggregates whatever arrived (LUAR's survivor path); if
//!   nothing arrived it waits for the first upload;
//! * `buffered` — FedBuff-style semi-async: the server flushes its
//!   buffer every K arrivals and the round closes at the *last full
//!   flush*, so stragglers past the final k-boundary spill out of the
//!   round (their bytes were still paid; in a real deployment they
//!   land in the next buffer) and the wall-clock decouples from the
//!   slowest client. A client whose upload lands after `s` completed
//!   flushes is discounted by 1/sqrt(1+s) (the staleness weight
//!   FedBuff suggests).
//!
//! * `async`    — no rounds at all: a **persistent** event queue
//!   (`AsyncQueue`) survives across dispatches, the server keeps a
//!   fixed number of clients in flight, and every absorbed upload
//!   carries a measured model-version gap that a `Staleness` discount
//!   turns into an aggregation weight. The queue lives here; the
//!   dispatch/absorb control flow is `fl::AsyncRuntime`.
//!
//! Specs: `sync`, `deadline:s=2.5`, `buffered:k=8`,
//! `async:c=8,s=poly,a=0.5` (`c=all` pins concurrency to the active
//! count; `s=const` is the zero-discount setting that reproduces sync
//! FedAvg when `c=all`). For the three round-based modes the min-heap
//! is drained once per round by `simulate_round`; the async mode keeps
//! events across dispatches and pops one completion *instant* at a
//! time (ties on the clock break by dispatch sequence, so replays are
//! exact).

use super::parse_kv;
use anyhow::{bail, Result};
use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Staleness discount applied to an absorbed upload's aggregation
/// weight as a function of its model-version gap (FedAsync's weighting
/// families).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Staleness {
    /// Zero discount: weight 1 regardless of the gap.
    Const,
    /// Polynomial discount: weight = (1 + gap)^-a.
    Poly { a: f64 },
}

impl Staleness {
    /// Aggregation weight for an upload trained `gap` versions ago.
    pub fn weight(&self, gap: u64) -> f32 {
        match *self {
            Staleness::Const => 1.0,
            Staleness::Poly { a } => (1.0 + gap as f64).powf(-a) as f32,
        }
    }

    pub fn spec_string(&self) -> String {
        match self {
            Staleness::Const => "s=const".into(),
            Staleness::Poly { a } => format!("s=poly,a={a}"),
        }
    }
}

/// When the server closes a round over the arrival stream.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum RoundMode {
    Sync,
    Deadline { deadline_s: f64 },
    Buffered { k: usize },
    /// Fully-async server: `concurrency` clients in flight at all
    /// times (0 = "all": resolved to the active-client count at run
    /// start), `staleness` maps each upload's version gap to its
    /// aggregation weight. Driven by `fl::AsyncRuntime`, not by
    /// `simulate_round`.
    Async { concurrency: usize, staleness: Staleness },
}

impl Default for RoundMode {
    fn default() -> Self {
        RoundMode::Sync
    }
}

impl RoundMode {
    pub fn parse(spec: &str) -> Result<Self> {
        let (name, args) = match spec.split_once(':') {
            Some((n, a)) => (n, parse_kv(a)?),
            None => (spec, Default::default()),
        };
        Ok(match name {
            "sync" => RoundMode::Sync,
            "deadline" => {
                let s = match args.get("s") {
                    Some(v) => match v.parse::<f64>() {
                        Ok(x) if x > 0.0 => x,
                        _ => bail!("deadline:s={v} must be a positive number"),
                    },
                    None => 5.0,
                };
                RoundMode::Deadline { deadline_s: s }
            }
            "buffered" => {
                let k = match args.get("k") {
                    Some(v) => match v.parse::<usize>() {
                        Ok(x) if x > 0 => x,
                        _ => bail!("buffered:k={v} must be a positive integer"),
                    },
                    None => 8,
                };
                RoundMode::Buffered { k }
            }
            "async" => {
                let concurrency = match args.get("c").map(String::as_str) {
                    Some("all") | None => 0,
                    Some(v) => match v.parse::<usize>() {
                        Ok(x) if x > 0 => x,
                        _ => bail!("async:c={v} must be a positive integer or `all`"),
                    },
                };
                let staleness = match args.get("s").map(String::as_str) {
                    Some("const") => Staleness::Const,
                    Some("poly") | None => {
                        let a = match args.get("a") {
                            Some(v) => match v.parse::<f64>() {
                                Ok(x) if x >= 0.0 => x,
                                _ => bail!("async:a={v} must be a non-negative number"),
                            },
                            None => 0.5,
                        };
                        Staleness::Poly { a }
                    }
                    Some(other) => bail!("unknown staleness discount {other}"),
                };
                RoundMode::Async { concurrency, staleness }
            }
            other => bail!("unknown round mode {other}"),
        })
    }

    pub fn spec_string(&self) -> String {
        match self {
            RoundMode::Sync => "sync".into(),
            RoundMode::Deadline { deadline_s } => format!("deadline:s={deadline_s}"),
            RoundMode::Buffered { k } => format!("buffered:k={k}"),
            RoundMode::Async { concurrency, staleness } => {
                let c = if *concurrency == 0 {
                    "all".to_string()
                } else {
                    concurrency.to_string()
                };
                format!("async:c={c},{}", staleness.spec_string())
            }
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            RoundMode::Sync => "sync",
            RoundMode::Deadline { .. } => "deadline",
            RoundMode::Buffered { .. } => "buffered",
            RoundMode::Async { .. } => "async",
        }
    }
}

/// One upload landing at the server.
#[derive(Debug, Clone, Copy)]
pub struct Arrival {
    /// Index into the round's active-client list.
    pub slot: usize,
    pub t: f64,
}

/// What one simulated round did, per active slot.
#[derive(Debug, Clone)]
pub struct RoundOutcome {
    /// Wall-clock until the server's aggregation is complete.
    pub round_secs: f64,
    /// Straggler tail: slowest arrival minus the median arrival.
    pub straggler_tail_s: f64,
    /// Per slot: did this upload make it into the aggregate?
    pub included: Vec<bool>,
    /// Per slot: aggregation weight (1.0 unless staleness-discounted).
    pub weights: Vec<f32>,
    /// Arrivals in event order (the server's actual receive sequence).
    pub arrivals: Vec<Arrival>,
    /// Number of uploads aggregated this round.
    pub aggregated: usize,
}

/// Min-heap key: arrival time then slot (total order over f64 via
/// `total_cmp`; times are finite by construction).
#[derive(Debug, PartialEq)]
struct Ev(f64, usize);

impl Eq for Ev {}

impl PartialOrd for Ev {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Ev {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.0.total_cmp(&other.0).then(self.1.cmp(&other.1))
    }
}

/// Run one round's event loop over per-slot completion times.
pub fn simulate_round(mode: &RoundMode, times: &[f64]) -> RoundOutcome {
    let mut sp = crate::obs::span("sched.round");
    let n = times.len();
    assert!(n > 0, "round with no active clients");
    let mut heap: BinaryHeap<Reverse<Ev>> = times
        .iter()
        .enumerate()
        .map(|(slot, &t)| Reverse(Ev(t, slot)))
        .collect();
    let mut arrivals = Vec::with_capacity(n);
    while let Some(Reverse(Ev(t, slot))) = heap.pop() {
        arrivals.push(Arrival { slot, t });
    }
    let t_max = arrivals.last().map(|a| a.t).unwrap_or(0.0);
    let mut included = vec![false; n];
    let mut weights = vec![0.0f32; n];

    let round_secs = match *mode {
        RoundMode::Sync => {
            for a in &arrivals {
                included[a.slot] = true;
                weights[a.slot] = 1.0;
            }
            t_max
        }
        RoundMode::Deadline { deadline_s } => {
            let mut any = false;
            for a in &arrivals {
                if a.t <= deadline_s {
                    included[a.slot] = true;
                    weights[a.slot] = 1.0;
                    any = true;
                }
            }
            if any {
                // close early if everyone made it, else at the deadline
                if t_max <= deadline_s {
                    t_max
                } else {
                    deadline_s
                }
            } else {
                // nothing arrived in budget: wait for the first upload
                let first = arrivals[0];
                included[first.slot] = true;
                weights[first.slot] = 1.0;
                first.t
            }
        }
        RoundMode::Buffered { k } => {
            let k = k.clamp(1, n);
            // The round ends at the last full k-flush; the partial
            // buffer past it spills into the next round (those uploads
            // are not aggregated here, though their bytes were paid).
            let n_flushed = (n / k) * k;
            let mut flushes = 0usize;
            for (i, a) in arrivals.iter().enumerate().take(n_flushed) {
                // staleness = completed buffer flushes since this client
                // pulled the model at t=0
                included[a.slot] = true;
                weights[a.slot] = (1.0 / (1.0 + flushes as f64).sqrt()) as f32;
                if (i + 1) % k == 0 {
                    flushes += 1;
                }
            }
            arrivals[n_flushed - 1].t
        }
        RoundMode::Async { .. } => {
            // The async mode has no per-round barrier to simulate; the
            // server must drive `fl::AsyncRuntime` over an `AsyncQueue`
            // instead of calling the round-based scheduler.
            panic!("async round mode has no per-round simulation; use fl::AsyncRuntime")
        }
    };

    let median = {
        let mut ts: Vec<f64> = times.to_vec();
        ts.sort_by(f64::total_cmp);
        ts[n / 2]
    };
    let aggregated = included.iter().filter(|&&b| b).count();
    sp.set_sim(round_secs);
    crate::obs::gauge("sched.aggregated", aggregated as f64);
    RoundOutcome {
        round_secs,
        straggler_tail_s: (t_max - median).max(0.0),
        included,
        weights,
        arrivals,
        aggregated,
    }
}

/// Remove permanently failed slots from a round outcome: a client
/// whose every upload attempt failed still bounded the round's clock
/// (the server waited out its timeouts), but its update must never
/// reach the aggregation. Failed slots are force-excluded, their
/// weights zeroed, and `aggregated` recomputed — the quorum-degraded
/// close in `fl::Server` compares the survivor count against
/// `FailurePolicy::quorum` afterwards.
pub fn mask_failed_slots(mut outcome: RoundOutcome, failed: &[bool]) -> RoundOutcome {
    assert_eq!(outcome.included.len(), failed.len());
    for (slot, &f) in failed.iter().enumerate() {
        if f {
            outcome.included[slot] = false;
            outcome.weights[slot] = 0.0;
        }
    }
    outcome.aggregated = outcome.included.iter().filter(|&&b| b).count();
    outcome
}

/// Persistent event queue for the fully-async server: completion
/// events survive across dispatches (unlike `simulate_round`, which
/// fills and drains a fresh heap every round). Keys are (completion
/// time, dispatch sequence number); the sequence tie-break makes
/// replays and checkpoint resumes exactly reproducible even when two
/// uploads land on the same simulated instant.
#[derive(Debug, Clone, Default)]
pub struct AsyncQueue {
    heap: BinaryHeap<Reverse<QEv>>,
}

/// Heap key: (completion time, dispatch seq). Same total order trick
/// as `Ev` (times are finite by construction).
#[derive(Debug, Clone, Copy, PartialEq)]
struct QEv(f64, u64);

impl Eq for QEv {}

impl PartialOrd for QEv {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for QEv {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.0.total_cmp(&other.0).then(self.1.cmp(&other.1))
    }
}

impl AsyncQueue {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Schedule the upload dispatched as `seq` to complete at absolute
    /// simulated time `t`.
    pub fn push(&mut self, t: f64, seq: u64) {
        self.heap.push(Reverse(QEv(t, seq)));
    }

    /// Next completion time, if any upload is in flight.
    pub fn peek_t(&self) -> Option<f64> {
        self.heap.peek().map(|Reverse(QEv(t, _))| *t)
    }

    /// Pop every event sharing the earliest completion instant, in
    /// dispatch order. The server processes one instant atomically —
    /// absorb all of its arrivals, close a version if the buffer
    /// filled, then refill the freed slots — which is what makes
    /// `async:c=all,s=const` over a homogeneous fleet reproduce sync
    /// FedAvg exactly.
    pub fn pop_instant(&mut self) -> Vec<(f64, u64)> {
        let mut out = Vec::new();
        let first_t = match self.peek_t() {
            Some(t) => t,
            None => return out,
        };
        while let Some(&Reverse(QEv(t, _))) = self.heap.peek() {
            if t != first_t {
                break;
            }
            let Reverse(QEv(t, seq)) = self.heap.pop().unwrap();
            out.push((t, seq));
        }
        out
    }

    /// Snapshot the queued events sorted by (t, seq) — the checkpoint
    /// serialization order.
    pub fn events_sorted(&self) -> Vec<(f64, u64)> {
        let mut v: Vec<(f64, u64)> = self.heap.iter().map(|Reverse(QEv(t, s))| (*t, *s)).collect();
        v.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
        v
    }

    /// Rebuild a queue from a checkpoint snapshot.
    pub fn from_events(events: &[(f64, u64)]) -> Self {
        let mut q = AsyncQueue::new();
        for &(t, seq) in events {
            q.push(t, seq);
        }
        q
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sync_round_is_bounded_by_slowest_client() {
        // Regression for the mean-vs-max timing bug: the old
        // BandwidthModel charged the mean upload; sync semantics
        // require the max.
        let times = [0.4, 2.0, 0.6, 0.5];
        let mean = times.iter().sum::<f64>() / times.len() as f64;
        let out = simulate_round(&RoundMode::Sync, &times);
        assert_eq!(out.round_secs, 2.0, "sync must wait for the slowest client");
        assert!(out.round_secs > mean, "regression: mean-upload timing resurfaced");
        assert_eq!(out.aggregated, 4);
        assert!(out.included.iter().all(|&b| b));
        assert!(out.weights.iter().all(|&w| w == 1.0));
    }

    #[test]
    fn arrivals_pop_in_time_order() {
        let out = simulate_round(&RoundMode::Sync, &[0.9, 0.1, 0.5]);
        let order: Vec<usize> = out.arrivals.iter().map(|a| a.slot).collect();
        assert_eq!(order, vec![1, 2, 0]);
        assert!((out.straggler_tail_s - 0.4).abs() < 1e-12); // 0.9 - median 0.5
    }

    #[test]
    fn deadline_drops_stragglers_and_closes_at_budget() {
        let out = simulate_round(&RoundMode::Deadline { deadline_s: 1.0 }, &[0.5, 3.0, 0.8]);
        assert_eq!(out.round_secs, 1.0);
        assert_eq!(out.included, vec![true, false, true]);
        assert_eq!(out.aggregated, 2);
    }

    #[test]
    fn deadline_closes_early_when_all_arrive() {
        let out = simulate_round(&RoundMode::Deadline { deadline_s: 10.0 }, &[0.5, 0.7]);
        assert_eq!(out.round_secs, 0.7);
        assert_eq!(out.aggregated, 2);
    }

    #[test]
    fn deadline_never_aggregates_zero_clients() {
        let out = simulate_round(&RoundMode::Deadline { deadline_s: 0.1 }, &[2.0, 5.0]);
        assert_eq!(out.aggregated, 1);
        assert_eq!(out.included, vec![true, false]);
        assert_eq!(out.round_secs, 2.0, "server waits for the first upload");
    }

    #[test]
    fn buffered_discounts_stale_arrivals_and_closes_at_last_flush() {
        // k=2 over 5 clients: flushes complete after arrivals 2 and 4;
        // the 5th upload spills to the next round's buffer.
        let times = [0.1, 0.2, 0.3, 0.4, 0.5];
        let out = simulate_round(&RoundMode::Buffered { k: 2 }, &times);
        assert_eq!(out.round_secs, 0.4, "round closes at the last full flush, not t_max");
        assert_eq!(out.aggregated, 4);
        assert!(!out.included[4], "partial-buffer straggler spills out of the round");
        assert_eq!(out.weights[0], 1.0);
        assert_eq!(out.weights[1], 1.0);
        let w2 = 1.0 / (2.0f64).sqrt();
        assert!((out.weights[2] as f64 - w2).abs() < 1e-6);
        assert!((out.weights[3] as f64 - w2).abs() < 1e-6);
    }

    #[test]
    fn buffered_decouples_round_time_from_stragglers() {
        // The whole point of FedBuff: one 100s straggler must not
        // bound the round (it would under sync).
        let times = [0.1, 0.2, 0.3, 100.0];
        let out = simulate_round(&RoundMode::Buffered { k: 3 }, &times);
        assert_eq!(out.round_secs, 0.3);
        assert_eq!(out.aggregated, 3);
        assert_eq!(simulate_round(&RoundMode::Sync, &times).round_secs, 100.0);
    }

    #[test]
    fn buffered_k_clamped_to_fleet() {
        let out = simulate_round(&RoundMode::Buffered { k: 100 }, &[0.1, 0.2]);
        assert!(out.weights.iter().all(|&w| w == 1.0), "k > n degrades to sync weights");
        assert_eq!(out.aggregated, 2);
        assert_eq!(out.round_secs, 0.2);
    }

    #[test]
    fn mode_specs_roundtrip() {
        for spec in [
            "sync",
            "deadline:s=2.5",
            "buffered:k=8",
            "async:c=all,s=const",
            "async:c=4,s=poly,a=0.5",
        ] {
            let m = RoundMode::parse(spec).unwrap();
            assert_eq!(RoundMode::parse(&m.spec_string()).unwrap(), m, "{spec}");
        }
        assert_eq!(RoundMode::parse("deadline").unwrap(), RoundMode::Deadline { deadline_s: 5.0 });
        assert_eq!(RoundMode::parse("buffered").unwrap(), RoundMode::Buffered { k: 8 });
        assert!(RoundMode::parse("deadline:s=-1").is_err());
        assert!(RoundMode::parse("buffered:k=0").is_err());
    }

    #[test]
    fn async_spec_parses_with_defaults() {
        assert_eq!(
            RoundMode::parse("async").unwrap(),
            RoundMode::Async { concurrency: 0, staleness: Staleness::Poly { a: 0.5 } }
        );
        assert_eq!(
            RoundMode::parse("async:c=16").unwrap(),
            RoundMode::Async { concurrency: 16, staleness: Staleness::Poly { a: 0.5 } }
        );
        assert_eq!(
            RoundMode::parse("async:c=all,s=const").unwrap(),
            RoundMode::Async { concurrency: 0, staleness: Staleness::Const }
        );
        assert_eq!(RoundMode::parse("async").unwrap().name(), "async");
        assert!(RoundMode::parse("async:c=0").is_err());
        assert!(RoundMode::parse("async:s=hinge").is_err());
        assert!(RoundMode::parse("async:s=poly,a=-1").is_err());
    }

    #[test]
    fn staleness_weights() {
        assert_eq!(Staleness::Const.weight(0), 1.0);
        assert_eq!(Staleness::Const.weight(100), 1.0);
        let p = Staleness::Poly { a: 0.5 };
        assert_eq!(p.weight(0), 1.0, "zero gap must be undiscounted");
        let w1 = p.weight(1) as f64;
        assert!((w1 - 1.0 / 2.0f64.sqrt()).abs() < 1e-6);
        assert!(p.weight(3) < p.weight(1), "discount must decrease with the gap");
        // a = 0 degenerates to no discount
        assert_eq!(Staleness::Poly { a: 0.0 }.weight(7), 1.0);
    }

    #[test]
    fn mask_failed_slots_excludes_and_recounts() {
        let out = simulate_round(&RoundMode::Sync, &[0.4, 2.0, 0.6]);
        assert_eq!(out.aggregated, 3);
        let masked = mask_failed_slots(out, &[false, true, false]);
        assert_eq!(masked.included, vec![true, false, true]);
        assert_eq!(masked.weights[1], 0.0);
        assert_eq!(masked.aggregated, 2);
        // the failed straggler still bounded the clock (server waited
        // out its attempts before closing)
        assert_eq!(masked.round_secs, 2.0);
        // masking nothing is the identity
        let out = simulate_round(&RoundMode::Sync, &[0.1, 0.2]);
        let same = mask_failed_slots(out.clone(), &[false, false]);
        assert_eq!(same.included, out.included);
        assert_eq!(same.aggregated, out.aggregated);
    }

    #[test]
    fn async_queue_pops_instants_in_seq_order() {
        let mut q = AsyncQueue::new();
        q.push(2.0, 0);
        q.push(1.0, 1);
        q.push(1.0, 2);
        q.push(3.0, 3);
        assert_eq!(q.len(), 4);
        assert_eq!(q.peek_t(), Some(1.0));
        // both t=1.0 events pop together, ordered by dispatch seq
        assert_eq!(q.pop_instant(), vec![(1.0, 1), (1.0, 2)]);
        assert_eq!(q.pop_instant(), vec![(2.0, 0)]);
        assert_eq!(q.pop_instant(), vec![(3.0, 3)]);
        assert!(q.pop_instant().is_empty());
        assert!(q.is_empty());
    }

    #[test]
    fn async_queue_snapshot_roundtrip() {
        let mut q = AsyncQueue::new();
        q.push(0.5, 3);
        q.push(0.25, 7);
        q.push(0.5, 1);
        let events = q.events_sorted();
        assert_eq!(events, vec![(0.25, 7), (0.5, 1), (0.5, 3)]);
        let mut back = AsyncQueue::from_events(&events);
        assert_eq!(back.pop_instant(), vec![(0.25, 7)]);
        assert_eq!(back.pop_instant(), vec![(0.5, 1), (0.5, 3)]);
    }

    #[test]
    #[should_panic(expected = "async round mode")]
    fn simulate_round_rejects_async_mode() {
        let mode = RoundMode::Async { concurrency: 0, staleness: Staleness::Const };
        simulate_round(&mode, &[1.0]);
    }
}
