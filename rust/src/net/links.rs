//! Heterogeneous per-client link models.
//!
//! Replaces the single global `BandwidthModel`: each client gets its
//! own up/down bandwidth, RTT, and a compute-speed multiplier, drawn
//! deterministically from a configurable fleet distribution. The three
//! families cover the regimes the communication-efficiency literature
//! (Konečný et al.; Le et al.) studies:
//!
//! * `uniform`   — every client identical (the legacy model; with the
//!   default parameters, sync-round timing matches the old
//!   `BandwidthModel` exactly when uploads are homogeneous);
//! * `lognormal` — heavy-tailed edge fleet: bandwidth medians with a
//!   log-scale sigma, compute multiplier drawn with sigma/2;
//! * `bimodal`   — a fast cohort and a slow cohort (wifi vs cellular),
//!   slow clients also compute 2x slower.
//!
//! Specs parse from compact strings, e.g.
//! `uniform:up=20,down=100,rtt=0.05`,
//! `lognormal:up=10,down=50,sigma=0.75,rtt=0.05`,
//! `bimodal:fast_frac=0.8,fast_up=50,slow_up=2,down=100,rtt=0.05`.

use super::parse_kv;
use crate::rng::Rng;
use anyhow::{bail, Result};

/// Fleet-level distribution the per-client links are drawn from.
#[derive(Debug, Clone, PartialEq)]
pub enum LinkDist {
    Uniform { up_mbps: f64, down_mbps: f64, rtt_s: f64 },
    LogNormal { up_mbps: f64, down_mbps: f64, sigma: f64, rtt_s: f64 },
    Bimodal { fast_frac: f64, fast_up_mbps: f64, slow_up_mbps: f64, down_mbps: f64, rtt_s: f64 },
}

impl Default for LinkDist {
    fn default() -> Self {
        // The legacy BandwidthModel's modest edge uplink.
        LinkDist::Uniform { up_mbps: 20.0, down_mbps: 100.0, rtt_s: 0.05 }
    }
}

impl LinkDist {
    pub fn parse(spec: &str) -> Result<Self> {
        let (name, args) = match spec.split_once(':') {
            Some((n, a)) => (n, parse_kv(a)?),
            None => (spec, Default::default()),
        };
        let getf = |k: &str, d: f64| -> Result<f64> {
            match args.get(k) {
                Some(v) => match v.parse::<f64>() {
                    Ok(x) => Ok(x),
                    Err(e) => bail!("link_dist {k}={v}: {e}"),
                },
                None => Ok(d),
            }
        };
        let dist = match name {
            "uniform" => LinkDist::Uniform {
                up_mbps: getf("up", 20.0)?,
                down_mbps: getf("down", 100.0)?,
                rtt_s: getf("rtt", 0.05)?,
            },
            "lognormal" => LinkDist::LogNormal {
                up_mbps: getf("up", 10.0)?,
                down_mbps: getf("down", 50.0)?,
                sigma: getf("sigma", 0.75)?,
                rtt_s: getf("rtt", 0.05)?,
            },
            "bimodal" => LinkDist::Bimodal {
                fast_frac: getf("fast_frac", 0.8)?,
                fast_up_mbps: getf("fast_up", 50.0)?,
                slow_up_mbps: getf("slow_up", 2.0)?,
                down_mbps: getf("down", 100.0)?,
                rtt_s: getf("rtt", 0.05)?,
            },
            other => bail!("unknown link distribution {other}"),
        };
        dist.validate()?;
        Ok(dist)
    }

    fn validate(&self) -> Result<()> {
        let ok = match self {
            LinkDist::Uniform { up_mbps, down_mbps, rtt_s } => {
                *up_mbps > 0.0 && *down_mbps > 0.0 && *rtt_s >= 0.0
            }
            LinkDist::LogNormal { up_mbps, down_mbps, sigma, rtt_s } => {
                *up_mbps > 0.0 && *down_mbps > 0.0 && *sigma >= 0.0 && *rtt_s >= 0.0
            }
            LinkDist::Bimodal { fast_frac, fast_up_mbps, slow_up_mbps, down_mbps, rtt_s } => {
                (0.0..=1.0).contains(fast_frac)
                    && *fast_up_mbps > 0.0
                    && *slow_up_mbps > 0.0
                    && *down_mbps > 0.0
                    && *rtt_s >= 0.0
            }
        };
        if !ok {
            bail!("invalid link distribution parameters: {self:?}");
        }
        Ok(())
    }

    pub fn spec_string(&self) -> String {
        match self {
            LinkDist::Uniform { up_mbps, down_mbps, rtt_s } => {
                format!("uniform:up={up_mbps},down={down_mbps},rtt={rtt_s}")
            }
            LinkDist::LogNormal { up_mbps, down_mbps, sigma, rtt_s } => {
                format!("lognormal:up={up_mbps},down={down_mbps},sigma={sigma},rtt={rtt_s}")
            }
            LinkDist::Bimodal { fast_frac, fast_up_mbps, slow_up_mbps, down_mbps, rtt_s } => {
                format!(
                    "bimodal:fast_frac={fast_frac},fast_up={fast_up_mbps},slow_up={slow_up_mbps},down={down_mbps},rtt={rtt_s}"
                )
            }
        }
    }
}

/// Number of fixed link-speed buckets the per-client upload-latency
/// histograms are keyed by.
pub const SPEED_BUCKETS: usize = 5;

/// Metric names must be `&'static str` for the registry, so the
/// per-bucket histogram series is a fixed array (decade buckets on
/// uplink bandwidth; the docs/observability.md catalog mirrors this).
const SPEED_BUCKET_METRICS: [&str; SPEED_BUCKETS] = [
    "client.upload_s.up_lt_1m",
    "client.upload_s.up_1m_10m",
    "client.upload_s.up_10m_100m",
    "client.upload_s.up_100m_1g",
    "client.upload_s.up_ge_1g",
];

/// Short bucket labels for the `*_clients.csv` `speed_bucket` column.
const SPEED_BUCKET_LABELS: [&str; SPEED_BUCKETS] =
    ["<1M", "1M-10M", "10M-100M", "100M-1G", ">=1G"];

/// Decade bucket index for an uplink bandwidth in bits/second:
/// `<1 Mbps, 1–10, 10–100, 100–1000, >=1000`.
pub fn speed_bucket(up_bps: f64) -> usize {
    if up_bps < 1e6 {
        0
    } else if up_bps < 1e7 {
        1
    } else if up_bps < 1e8 {
        2
    } else if up_bps < 1e9 {
        3
    } else {
        4
    }
}

/// Histogram metric name for a bucket index.
pub fn speed_bucket_metric(bucket: usize) -> &'static str {
    SPEED_BUCKET_METRICS[bucket]
}

/// Human label for a bucket index (CSV column value).
pub fn speed_bucket_label(bucket: usize) -> &'static str {
    SPEED_BUCKET_LABELS[bucket]
}

/// One client's link: fixed for the whole run (heterogeneity is
/// per-device, not per-round).
#[derive(Debug, Clone, Copy)]
pub struct ClientLink {
    pub up_bps: f64,
    pub down_bps: f64,
    pub rtt_s: f64,
    /// Multiplier on the configured local-compute time.
    pub compute_mult: f64,
}

impl ClientLink {
    /// Seconds to push `bytes` upstream (half the RTT charged per
    /// direction so a full round pays one RTT, like the legacy model).
    pub fn upload_secs(&self, bytes: u64) -> f64 {
        self.rtt_s * 0.5 + (bytes as f64 * 8.0) / self.up_bps
    }

    pub fn download_secs(&self, bytes: u64) -> f64 {
        self.rtt_s * 0.5 + (bytes as f64 * 8.0) / self.down_bps
    }
}

/// All clients' links, drawn once per run from the fleet distribution.
#[derive(Debug, Clone)]
pub struct LinkFleet {
    links: Vec<ClientLink>,
}

impl LinkFleet {
    pub fn new(dist: &LinkDist, num_clients: usize, seed: u64) -> Self {
        let mut rng = Rng::seed_from_u64(seed ^ 0x11f1_ee7);
        let links = (0..num_clients)
            .map(|_| match *dist {
                LinkDist::Uniform { up_mbps, down_mbps, rtt_s } => ClientLink {
                    up_bps: up_mbps * 1e6,
                    down_bps: down_mbps * 1e6,
                    rtt_s,
                    compute_mult: 1.0,
                },
                LinkDist::LogNormal { up_mbps, down_mbps, sigma, rtt_s } => ClientLink {
                    up_bps: up_mbps * 1e6 * (sigma * rng.normal()).exp(),
                    down_bps: down_mbps * 1e6 * (sigma * rng.normal()).exp(),
                    rtt_s,
                    compute_mult: (0.5 * sigma * rng.normal()).exp(),
                },
                LinkDist::Bimodal {
                    fast_frac,
                    fast_up_mbps,
                    slow_up_mbps,
                    down_mbps,
                    rtt_s,
                } => {
                    let fast = rng.gen_bool(fast_frac);
                    ClientLink {
                        up_bps: if fast { fast_up_mbps } else { slow_up_mbps } * 1e6,
                        down_bps: down_mbps * 1e6,
                        rtt_s,
                        compute_mult: if fast { 1.0 } else { 2.0 },
                    }
                }
            })
            .collect();
        let fleet = LinkFleet { links };
        // Fleet profile at construction: one gauge per run, so the
        // metrics summary records what hardware the telemetry describes.
        if crate::obs::enabled() && !fleet.is_empty() {
            let n = fleet.links.len() as f64;
            let mean_up: f64 = fleet.links.iter().map(|l| l.up_bps).sum::<f64>() / n;
            let min_up =
                fleet.links.iter().map(|l| l.up_bps).fold(f64::INFINITY, f64::min);
            crate::obs::gauge("links.clients", n);
            crate::obs::gauge("links.mean_up_bps", mean_up);
            crate::obs::gauge("links.min_up_bps", min_up);
        }
        fleet
    }

    pub fn len(&self) -> usize {
        self.links.len()
    }

    pub fn is_empty(&self) -> bool {
        self.links.is_empty()
    }

    pub fn link(&self, client: usize) -> &ClientLink {
        &self.links[client]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_fleet_is_identical_and_matches_legacy_timing() {
        let fleet = LinkFleet::new(&LinkDist::default(), 8, 42);
        let l0 = fleet.link(0);
        for c in 1..8 {
            let l = fleet.link(c);
            assert_eq!(l.up_bps, l0.up_bps);
            assert_eq!(l.compute_mult, 1.0);
        }
        // legacy BandwidthModel::round_seconds(up, down) = up/2.5MBps + down/12.5MBps + rtt
        let legacy = (1_000_000.0 * 8.0) / 20e6 + (2_000_000.0 * 8.0) / 100e6 + 0.05;
        let now = l0.upload_secs(1_000_000) + l0.download_secs(2_000_000);
        assert!((legacy - now).abs() < 1e-12);
    }

    #[test]
    fn fleets_are_deterministic_per_seed() {
        let d = LinkDist::LogNormal { up_mbps: 10.0, down_mbps: 50.0, sigma: 0.75, rtt_s: 0.05 };
        let a = LinkFleet::new(&d, 16, 7);
        let b = LinkFleet::new(&d, 16, 7);
        let c = LinkFleet::new(&d, 16, 8);
        for i in 0..16 {
            assert_eq!(a.link(i).up_bps, b.link(i).up_bps);
        }
        assert!((0..16).any(|i| a.link(i).up_bps != c.link(i).up_bps));
    }

    #[test]
    fn lognormal_spreads_around_median() {
        let d = LinkDist::LogNormal { up_mbps: 10.0, down_mbps: 50.0, sigma: 0.75, rtt_s: 0.0 };
        let fleet = LinkFleet::new(&d, 512, 3);
        let ups: Vec<f64> = (0..512).map(|i| fleet.link(i).up_bps).collect();
        let above = ups.iter().filter(|&&u| u > 10e6).count();
        // median ~ half above, half below
        assert!((150..=362).contains(&above), "above-median count {above}");
        let spread = ups.iter().cloned().fold(0.0f64, f64::max)
            / ups.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(spread > 3.0, "lognormal fleet too homogeneous: {spread}");
    }

    #[test]
    fn bimodal_has_two_cohorts() {
        let d = LinkDist::Bimodal {
            fast_frac: 0.5,
            fast_up_mbps: 50.0,
            slow_up_mbps: 2.0,
            down_mbps: 100.0,
            rtt_s: 0.0,
        };
        let fleet = LinkFleet::new(&d, 256, 5);
        let fast = (0..256).filter(|&i| fleet.link(i).up_bps == 50e6).count();
        let slow = (0..256).filter(|&i| fleet.link(i).up_bps == 2e6).count();
        assert_eq!(fast + slow, 256);
        assert!(fast > 64 && slow > 64, "cohorts {fast}/{slow}");
        // slow cohort also computes slower
        let i = (0..256).find(|&i| fleet.link(i).up_bps == 2e6).unwrap();
        assert_eq!(fleet.link(i).compute_mult, 2.0);
    }

    #[test]
    fn speed_buckets_partition_the_decades() {
        assert_eq!(speed_bucket(0.0), 0);
        assert_eq!(speed_bucket(999_999.0), 0);
        assert_eq!(speed_bucket(1e6), 1);
        assert_eq!(speed_bucket(2e6), 1);
        assert_eq!(speed_bucket(20e6), 2);
        assert_eq!(speed_bucket(80e6), 2);
        assert_eq!(speed_bucket(100e6), 3);
        assert_eq!(speed_bucket(1e9), 4);
        // every bucket has a distinct metric name and label
        let names: std::collections::BTreeSet<_> =
            (0..SPEED_BUCKETS).map(speed_bucket_metric).collect();
        assert_eq!(names.len(), SPEED_BUCKETS);
        let labels: std::collections::BTreeSet<_> =
            (0..SPEED_BUCKETS).map(speed_bucket_label).collect();
        assert_eq!(labels.len(), SPEED_BUCKETS);
    }

    #[test]
    fn parse_spec_roundtrip() {
        for spec in [
            "uniform:up=20,down=100,rtt=0.05",
            "lognormal:up=10,down=50,sigma=0.75,rtt=0.05",
            "bimodal:fast_frac=0.8,fast_up=50,slow_up=2,down=100,rtt=0.05",
        ] {
            let d = LinkDist::parse(spec).unwrap();
            let again = LinkDist::parse(&d.spec_string()).unwrap();
            assert_eq!(d, again, "{spec}");
        }
        assert_eq!(LinkDist::parse("uniform").unwrap(), LinkDist::default());
        assert!(LinkDist::parse("warp").is_err());
        assert!(LinkDist::parse("uniform:up=0").is_err());
        assert!(LinkDist::parse("uniform:up=abc").is_err());
    }
}
