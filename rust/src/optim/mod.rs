//! Server-side optimizers (the Table 3 "harmonization" targets).
//!
//! All of them consume the composed global update \hat{Delta}_t that
//! LUAR (or plain averaging) produced — LUAR is agnostic to the
//! optimizer, which is exactly the paper's Section 4.2 claim.
//!
//! * `Sgd`   — FedAvg server: x += delta.
//! * `Adam`  — FedOpt/FedAdam (Reddi et al.): delta as pseudo-gradient.
//! * `Acg`   — FedACG (Kim et al., CVPR'24): server keeps momentum m;
//!   broadcasts the lookahead x + lambda*m; m <- lambda*m + delta;
//!   x <- x + m. Clients add a proximal penalty toward the broadcast.
//! * `Mut`   — FedMut (Hu et al., AAAI'24): broadcasts per-client
//!   mutations x ± alpha*delta_prev (paired so mutations cancel in
//!   aggregate), which searches a flatter region around x.

use crate::config::ServerOptCfg;
use crate::tensor;

pub struct ServerOpt {
    cfg: ServerOptCfg,
    /// Global model x_t.
    x: Vec<f32>,
    /// Adam first/second moments or ACG momentum (lazily sized).
    m: Vec<f32>,
    v: Vec<f32>,
    /// Last composed update (for FedMut mutations).
    last_delta: Vec<f32>,
    step: u64,
}

impl ServerOpt {
    pub fn new(cfg: ServerOptCfg, init: Vec<f32>) -> Self {
        let d = init.len();
        let needs_m = !matches!(cfg, ServerOptCfg::Sgd);
        let needs_v = matches!(cfg, ServerOptCfg::Adam { .. });
        ServerOpt {
            cfg,
            x: init,
            m: if needs_m { vec![0.0; d] } else { Vec::new() },
            v: if needs_v { vec![0.0; d] } else { Vec::new() },
            last_delta: Vec::new(),
            step: 0,
        }
    }

    /// Current global parameters.
    pub fn params(&self) -> &[f32] {
        &self.x
    }

    /// Checkpoint snapshot: (x, m, v, last_delta, step).
    pub fn snapshot(&self) -> (&[f32], &[f32], &[f32], &[f32], u64) {
        (&self.x, &self.m, &self.v, &self.last_delta, self.step)
    }

    /// Restore a snapshot taken with the same optimizer config.
    pub fn restore(&mut self, x: Vec<f32>, m: Vec<f32>, v: Vec<f32>, last_delta: Vec<f32>, step: u64) {
        self.x = x;
        self.m = m;
        self.v = v;
        self.last_delta = last_delta;
        self.step = step;
    }

    /// The model broadcast to client in slot `slot` this round
    /// (Alg. 2 line 5). Most optimizers broadcast x; ACG broadcasts the
    /// lookahead; FedMut broadcasts paired mutations.
    pub fn broadcast(&self, slot: usize) -> Vec<f32> {
        match &self.cfg {
            ServerOptCfg::Acg { lambda } => {
                let mut out = self.x.clone();
                if !self.m.is_empty() {
                    tensor::axpy(*lambda, &self.m, &mut out);
                }
                out
            }
            ServerOptCfg::Mut { alpha } => {
                let mut out = self.x.clone();
                if !self.last_delta.is_empty() {
                    let sign = if slot % 2 == 0 { 1.0 } else { -1.0 };
                    tensor::axpy(sign * alpha, &self.last_delta, &mut out);
                }
                out
            }
            _ => self.x.clone(),
        }
    }

    /// Whether clients should measure their local deltas against the
    /// broadcast (true for FedMut, whose broadcasts differ per client).
    pub fn per_client_broadcast(&self) -> bool {
        matches!(self.cfg, ServerOptCfg::Mut { .. })
    }

    /// The anchor for the FedProx/FedACG proximal term.
    pub fn prox_anchor(&self) -> Vec<f32> {
        // For ACG the penalty is toward the broadcast lookahead.
        self.broadcast(0)
    }

    /// Apply the composed global update \hat{Delta}_t (Alg. 2 line 12).
    pub fn apply(&mut self, delta: &[f32]) {
        self.step += 1;
        match self.cfg.clone() {
            ServerOptCfg::Sgd => {
                tensor::axpy(1.0, delta, &mut self.x);
            }
            ServerOptCfg::Adam { lr } => {
                const B1: f32 = 0.9;
                const B2: f32 = 0.99;
                const EPS: f32 = 1e-3; // FedOpt's tau adaptivity term
                let t = self.step as i32;
                let bc1 = 1.0 - B1.powi(t);
                let bc2 = 1.0 - B2.powi(t);
                for i in 0..self.x.len() {
                    let g = delta[i];
                    self.m[i] = B1 * self.m[i] + (1.0 - B1) * g;
                    self.v[i] = B2 * self.v[i] + (1.0 - B2) * g * g;
                    let mh = self.m[i] / bc1;
                    let vh = self.v[i] / bc2;
                    self.x[i] += lr * mh / (vh.sqrt() + EPS);
                }
            }
            ServerOptCfg::Acg { lambda } => {
                for i in 0..self.x.len() {
                    self.m[i] = lambda * self.m[i] + delta[i];
                    self.x[i] += self.m[i];
                }
            }
            ServerOptCfg::Mut { .. } => {
                tensor::axpy(1.0, delta, &mut self.x);
                self.last_delta = delta.to_vec();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn delta(d: usize, v: f32) -> Vec<f32> {
        vec![v; d]
    }

    #[test]
    fn sgd_adds_delta() {
        let mut o = ServerOpt::new(ServerOptCfg::Sgd, vec![1.0; 4]);
        o.apply(&delta(4, 0.5));
        assert_eq!(o.params(), &[1.5; 4]);
        assert_eq!(o.broadcast(0), vec![1.5; 4]);
    }

    #[test]
    fn adam_moves_toward_delta_sign() {
        let mut o = ServerOpt::new(ServerOptCfg::Adam { lr: 0.1 }, vec![0.0; 4]);
        for _ in 0..5 {
            o.apply(&delta(4, 1.0));
        }
        assert!(o.params()[0] > 0.0);
        let mut o2 = ServerOpt::new(ServerOptCfg::Adam { lr: 0.1 }, vec![0.0; 4]);
        for _ in 0..5 {
            o2.apply(&delta(4, -1.0));
        }
        assert!(o2.params()[0] < 0.0);
    }

    #[test]
    fn adam_is_scale_adaptive() {
        // Adam normalizes by sqrt(v): tiny deltas still move x measurably.
        let mut small = ServerOpt::new(ServerOptCfg::Adam { lr: 0.1 }, vec![0.0; 1]);
        for _ in 0..20 {
            small.apply(&[1e-4]);
        }
        let mut big = ServerOpt::new(ServerOptCfg::Adam { lr: 0.1 }, vec![0.0; 1]);
        for _ in 0..20 {
            big.apply(&[1.0]);
        }
        let ratio = big.params()[0] / small.params()[0];
        assert!(ratio < 50.0, "adam not adaptive: ratio {ratio}");
    }

    #[test]
    fn acg_broadcast_is_lookahead() {
        let mut o = ServerOpt::new(ServerOptCfg::Acg { lambda: 0.5 }, vec![0.0; 2]);
        o.apply(&[1.0, 1.0]); // m = [1,1], x = [1,1]
        assert_eq!(o.params(), &[1.0, 1.0]);
        assert_eq!(o.broadcast(0), vec![1.5, 1.5]); // x + 0.5*m
        o.apply(&[1.0, 1.0]); // m = 0.5*1+1 = 1.5, x = 2.5
        assert_eq!(o.params(), &[2.5, 2.5]);
    }

    #[test]
    fn acg_momentum_accelerates() {
        let mut acg = ServerOpt::new(ServerOptCfg::Acg { lambda: 0.9 }, vec![0.0; 1]);
        let mut sgd = ServerOpt::new(ServerOptCfg::Sgd, vec![0.0; 1]);
        for _ in 0..10 {
            acg.apply(&[1.0]);
            sgd.apply(&[1.0]);
        }
        assert!(acg.params()[0] > sgd.params()[0]);
    }

    #[test]
    fn mut_broadcasts_paired_mutations() {
        let mut o = ServerOpt::new(ServerOptCfg::Mut { alpha: 0.5 }, vec![0.0; 2]);
        // first round: no previous delta, broadcasts are identical
        assert_eq!(o.broadcast(0), o.broadcast(1));
        o.apply(&[2.0, 2.0]);
        let b0 = o.broadcast(0);
        let b1 = o.broadcast(1);
        assert_eq!(b0, vec![3.0, 3.0]); // x=2 + 0.5*2
        assert_eq!(b1, vec![1.0, 1.0]); // x=2 - 0.5*2
        // mutations cancel pairwise around x
        let mid: Vec<f32> = b0.iter().zip(&b1).map(|(a, b)| (a + b) / 2.0).collect();
        assert_eq!(mid, o.params());
        assert!(o.per_client_broadcast());
    }
}
