//! A lightweight Rust tokenizer — just enough lexical structure for
//! the fedluar-lint rule matchers (see `rules.rs`): identifiers,
//! punctuation, and line numbers, with comments captured separately
//! (annotations live in line comments) and string/char/lifetime
//! literals consumed so their contents can never fake a match. This is
//! deliberately NOT a full lexer; it only has to be conservative
//! enough that rule matchers see real code tokens.

/// One lexical token. `in_test` is set by [`mark_test_code`] for
/// tokens inside `#[cfg(test)]` / `#[test]` items.
#[derive(Debug, Clone)]
pub struct Tok {
    pub text: String,
    pub is_ident: bool,
    pub line: usize,
    pub in_test: bool,
}

/// A `//` line comment (text after the slashes, line it starts on).
/// Block comments are consumed but not recorded: `lint:allow`
/// annotations are only honored in line comments.
#[derive(Debug, Clone)]
pub struct Comment {
    pub line: usize,
    pub text: String,
}

/// Placeholder text for consumed string literals: keeps the token
/// stream shape without exposing literal contents to the matchers.
pub const STR_TOK: &str = "\u{1}str";

pub fn tokenize(src: &str) -> (Vec<Tok>, Vec<Comment>) {
    let b: Vec<char> = src.chars().collect();
    let n = b.len();
    let mut toks: Vec<Tok> = Vec::new();
    let mut comments: Vec<Comment> = Vec::new();
    let mut line = 1usize;
    let mut i = 0usize;
    while i < n {
        let c = b[i];
        if c == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c.is_whitespace() {
            i += 1;
            continue;
        }
        // ---- line comment
        if c == '/' && i + 1 < n && b[i + 1] == '/' {
            let start = i + 2;
            let mut j = start;
            while j < n && b[j] != '\n' {
                j += 1;
            }
            comments.push(Comment { line, text: b[start..j].iter().collect() });
            i = j;
            continue;
        }
        // ---- block comment (Rust block comments nest)
        if c == '/' && i + 1 < n && b[i + 1] == '*' {
            let mut depth = 1usize;
            let mut j = i + 2;
            while j < n && depth > 0 {
                if b[j] == '\n' {
                    line += 1;
                    j += 1;
                } else if b[j] == '/' && j + 1 < n && b[j + 1] == '*' {
                    depth += 1;
                    j += 2;
                } else if b[j] == '*' && j + 1 < n && b[j + 1] == '/' {
                    depth -= 1;
                    j += 2;
                } else {
                    j += 1;
                }
            }
            i = j;
            continue;
        }
        // ---- string-likes: "...", r"...", r#"..."#, b"...", br#"..."#
        if let Some(end) = string_like_end(&b, i) {
            let tline = line;
            for &ch in b.get(i..end).into_iter().flatten() {
                if ch == '\n' {
                    line += 1;
                }
            }
            toks.push(Tok { text: STR_TOK.to_string(), is_ident: false, line: tline, in_test: false });
            i = end;
            continue;
        }
        // ---- char literal vs lifetime
        if c == '\'' {
            if i + 1 < n && b[i + 1] == '\\' {
                // escaped char literal: '\n', '\'', '\u{..}'
                let mut j = i + 3;
                while j < n && b[j] != '\'' {
                    j += 1;
                }
                toks.push(Tok { text: "'c'".to_string(), is_ident: false, line, in_test: false });
                i = (j + 1).min(n);
                continue;
            }
            if i + 2 < n && (b[i + 1].is_alphanumeric() || b[i + 1] == '_') && b[i + 2] == '\'' {
                toks.push(Tok { text: "'c'".to_string(), is_ident: false, line, in_test: false });
                i += 3;
                continue;
            }
            // lifetime: consume the quote and the ident; emit nothing
            // (no matcher keys on lifetimes).
            let mut j = i + 1;
            while j < n && (b[j].is_alphanumeric() || b[j] == '_') {
                j += 1;
            }
            i = j.max(i + 1);
            continue;
        }
        // ---- number
        if c.is_ascii_digit() {
            let start = i;
            let mut j = i;
            while j < n && (b[j].is_alphanumeric() || b[j] == '_') {
                j += 1;
            }
            // fractional part: consume '.' only when a digit follows
            // (so `12..16` stays `12`, `.`, `.`, `16`).
            if j + 1 < n && b[j] == '.' && b[j + 1].is_ascii_digit() {
                j += 1;
                while j < n && (b[j].is_alphanumeric() || b[j] == '_') {
                    j += 1;
                }
            }
            let text: String = b[start..j].iter().collect();
            toks.push(Tok { text, is_ident: false, line, in_test: false });
            i = j;
            continue;
        }
        // ---- identifier / keyword
        if c.is_alphabetic() || c == '_' {
            let start = i;
            let mut j = i;
            while j < n && (b[j].is_alphanumeric() || b[j] == '_') {
                j += 1;
            }
            let text: String = b[start..j].iter().collect();
            toks.push(Tok { text, is_ident: true, line, in_test: false });
            i = j;
            continue;
        }
        // ---- single-char punctuation
        toks.push(Tok { text: c.to_string(), is_ident: false, line, in_test: false });
        i += 1;
    }
    (toks, comments)
}

/// If a string literal starts at `i`, return the index one past its
/// closing quote. Handles `"`, `b"`, and raw forms `r#*"` / `br#*"`.
fn string_like_end(b: &[char], i: usize) -> Option<usize> {
    let n = b.len();
    let c = b[i];
    if c == '"' {
        return Some(plain_string_end(b, i + 1));
    }
    if c == 'b' && i + 1 < n && b[i + 1] == '"' {
        return Some(plain_string_end(b, i + 2));
    }
    // raw strings: r"..." / r#"..."# / br"..." / br#"..."#
    let mut k = i;
    if c == 'b' && i + 1 < n && b[i + 1] == 'r' {
        k = i + 2;
    } else if c == 'r' {
        k = i + 1;
    } else {
        return None;
    }
    let mut hashes = 0usize;
    while k < n && b[k] == '#' {
        hashes += 1;
        k += 1;
    }
    if k >= n || b[k] != '"' {
        return None; // raw identifier (r#fn) or plain ident starting r/b
    }
    // scan for `"` followed by `hashes` hash marks
    let mut j = k + 1;
    while j < n {
        if b[j] == '"' {
            let mut h = 0usize;
            while j + 1 + h < n && b[j + 1 + h] == '#' && h < hashes {
                h += 1;
            }
            if h == hashes {
                return Some(j + 1 + hashes);
            }
        }
        j += 1;
    }
    Some(n)
}

/// End of a non-raw string body starting just after the opening quote.
fn plain_string_end(b: &[char], mut j: usize) -> usize {
    let n = b.len();
    while j < n {
        if b[j] == '\\' {
            j += 2;
        } else if b[j] == '"' {
            return j + 1;
        } else {
            j += 1;
        }
    }
    n
}

/// Mark tokens inside `#[cfg(test)]` / `#[test]` items with
/// `in_test = true`, so rules with `skip_test_code` ignore them.
/// Recognizes the attribute, skips any further attributes, then marks
/// through the item's brace block (or to the `;` of a block-less item).
pub fn mark_test_code(toks: &mut [Tok]) {
    let n = toks.len();
    let mut i = 0usize;
    while i < n {
        if toks[i].text == "#" && i + 1 < n && toks[i + 1].text == "[" {
            if let Some((attr_end, is_test)) = attr_span(toks, i + 1) {
                if is_test {
                    mark_item(toks, i, attr_end + 1);
                }
                i = attr_end + 1;
                continue;
            }
        }
        i += 1;
    }
}

/// Given the index of an attribute's `[`, return (index of matching
/// `]`, whether the attribute marks test-only code). Test markers are
/// `#[test]` and `#[cfg(..test..)]` without a `not`.
fn attr_span(toks: &[Tok], open: usize) -> Option<(usize, bool)> {
    let n = toks.len();
    let mut depth = 0usize;
    let mut idents: Vec<&str> = Vec::new();
    let mut j = open;
    while j < n {
        match toks[j].text.as_str() {
            "[" => depth += 1,
            "]" => {
                depth -= 1;
                if depth == 0 {
                    let is_test = match idents.first() {
                        Some(&"test") => idents.len() == 1,
                        Some(&"cfg") => {
                            idents.iter().any(|&s| s == "test")
                                && !idents.iter().any(|&s| s == "not")
                        }
                        _ => false,
                    };
                    return Some((j, is_test));
                }
            }
            _ => {
                if toks[j].is_ident {
                    idents.push(&toks[j].text);
                }
            }
        }
        j += 1;
    }
    None
}

/// Mark from `start` (the `#` of the test attribute) through the end
/// of the annotated item: skip further attributes, then the first `{`
/// opens the item body (mark to its matching `}`); a `;` first means a
/// block-less item.
fn mark_item(toks: &mut [Tok], start: usize, mut k: usize) {
    let n = toks.len();
    // skip stacked attributes (#[test] #[ignore] fn ...)
    while k + 1 < n && toks[k].text == "#" && toks[k + 1].text == "[" {
        match attr_span(toks, k + 1) {
            Some((end, _)) => k = end + 1,
            None => return,
        }
    }
    let mut j = k;
    while j < n {
        if toks[j].text == ";" {
            break;
        }
        if toks[j].text == "{" {
            let mut depth = 0usize;
            while j < n {
                match toks[j].text.as_str() {
                    "{" => depth += 1,
                    "}" => {
                        depth -= 1;
                        if depth == 0 {
                            break;
                        }
                    }
                    _ => {}
                }
                j += 1;
            }
            break;
        }
        j += 1;
    }
    for t in toks.iter_mut().take((j + 1).min(n)).skip(start) {
        t.in_test = true;
    }
}
