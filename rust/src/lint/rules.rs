//! The fedluar-lint rule catalog. Data-driven: a rule is a scope
//! (path prefixes), a token matcher, and documentation strings; adding
//! a rule for a future PR means adding one entry to [`CATALOG`] (and a
//! section to `docs/lints.md` — `integration_lint` cross-checks that
//! every catalog id is documented).

use super::tokens::Tok;

/// One lint rule. Paths are repo-relative with forward slashes; a file
/// is in scope when it starts with any `include` prefix and no
/// `exclude` prefix. `skip_test_code` drops matches inside
/// `#[cfg(test)]` / `#[test]` items.
pub struct Rule {
    pub id: &'static str,
    pub title: &'static str,
    pub rationale: &'static str,
    pub advice: &'static str,
    pub include: &'static [&'static str],
    pub exclude: &'static [&'static str],
    pub skip_test_code: bool,
    pub matcher: Matcher,
}

/// Pseudo-rule id reported for malformed `lint:allow` annotations
/// (bad syntax, unknown rule, missing reason). Not itself suppressible.
pub const ANNOTATION_RULE: &str = "A1";

pub enum Matcher {
    /// Any identifier in the list (D1: unordered collections).
    AnyIdent(&'static [&'static str]),
    /// `Instant::now` call paths or any `SystemTime` mention (D2).
    WallClock,
    /// `partial_cmp(..)` chained into `unwrap`/`unwrap_or`/
    /// `unwrap_or_else`/`expect` (D3). `fn partial_cmp` trait impls
    /// are exempt.
    PartialCmpUnwrap,
    /// `.round()/.floor()/.ceil()/.trunc()` immediately cast with
    /// `as <int>` (D4) — the saturating-cast footgun.
    FloatRoundCast,
    /// `.unwrap(` / `.expect(` and the `panic!`/`unreachable!`/
    /// `todo!`/`unimplemented!` macros (P1).
    PanicFamily,
    /// One of the listed identifiers directly indexed with `[` (W1:
    /// raw slicing of undecoded frame bytes).
    RawIndex(&'static [&'static str]),
}

pub const CATALOG: &[Rule] = &[
    Rule {
        id: "D1",
        title: "unordered collection in a determinism-critical module",
        rationale: "HashMap/HashSet iteration order is randomized per process; any walk \
                    of one that reaches the comm ledger, history CSVs, RNG draws, or wire \
                    frames breaks the repo's bit-exact equivalence suites.",
        advice: "use BTreeMap/BTreeSet, or collect + sort before iterating; annotate \
                 `// lint:allow(D1): <why order cannot leak>` for keyed-lookup-only maps",
        include: &[
            "rust/src/net/",
            "rust/src/compress/",
            "rust/src/data/",
            "rust/src/luar/",
            "rust/src/fl/",
            "rust/src/exp/",
            "rust/src/obs/",
            "rust/src/comm.rs",
            "rust/src/metrics.rs",
            "rust/src/rng.rs",
        ],
        exclude: &[],
        skip_test_code: true,
        matcher: Matcher::AnyIdent(&["HashMap", "HashSet"]),
    },
    Rule {
        id: "D2",
        title: "wall clock outside the allowlisted modules",
        rationale: "simulation code must use the sim clock (net/sched.rs); an \
                    Instant::now/SystemTime read on a simulated path makes schedules, \
                    stragglers, and CSVs machine-dependent.",
        advice: "thread the sim clock in; wall-clock reads belong in obs/, \
                 bench_harness.rs, runtime/engine.rs, main.rs, exp/mod.rs",
        include: &[""],
        exclude: &[
            "rust/src/obs/",
            "rust/src/bench_harness.rs",
            "rust/src/runtime/engine.rs",
            "rust/src/main.rs",
            "rust/src/exp/mod.rs",
            "rust/benches/",
        ],
        skip_test_code: true,
        matcher: Matcher::WallClock,
    },
    Rule {
        id: "D3",
        title: "NaN-unsafe float ordering (the PR 7 bug class)",
        rationale: "partial_cmp(..).unwrap() panics on NaN and unwrap_or(Equal) makes \
                    NaN compare equal to everything, so sort results depend on NaN \
                    position; total_cmp gives a deterministic total order.",
        advice: "use f32::total_cmp / f64::total_cmp (applies in test code too — \
                 test sorts panic the same way)",
        include: &[""],
        exclude: &[],
        skip_test_code: false,
        matcher: Matcher::PartialCmpUnwrap,
    },
    Rule {
        id: "D4",
        title: "bare float->int cast on a codec/quantizer path",
        rationale: "`as` saturates silently and maps NaN to 0, which turns a bad range \
                    into wrong-but-plausible wire indices; the clamping helpers make \
                    the degenerate cases explicit.",
        advice: "use tensor::scaled_count / tensor::floor_count / \
                 tensor::quant_grid_index (or add a helper there)",
        include: &["rust/src/compress/", "rust/src/net/", "rust/src/data/"],
        exclude: &[],
        skip_test_code: true,
        matcher: Matcher::FloatRoundCast,
    },
    Rule {
        id: "P1",
        title: "panic path in non-test library code",
        rationale: "a panic in library code kills a whole federated run (and under the \
                    fault-injection harness, masks the fault being tested); library \
                    paths must return Result or justify the invariant.",
        advice: "return Result, or annotate `// lint:allow(P1): <invariant>`; \
                 grandfathered sites live in lint-baseline.txt and may only shrink",
        include: &["rust/src/"],
        exclude: &[],
        skip_test_code: true,
        matcher: Matcher::PanicFamily,
    },
    Rule {
        id: "W1",
        title: "unchecked frame slicing in the wire decoder",
        rationale: "decode paths handle attacker-shaped (fault-injected) bytes; every \
                    slice of the raw frame must be length-checked first or a truncated \
                    frame panics instead of erroring.",
        advice: "route reads through Cur::take/array (already bounds-checked); \
                 annotate the checked choke points with `// lint:allow(W1): <check>`",
        include: &["rust/src/net/wire.rs"],
        exclude: &[],
        skip_test_code: true,
        matcher: Matcher::RawIndex(&["frame", "buf"]),
    },
];

pub fn rule_by_id(id: &str) -> Option<&'static Rule> {
    CATALOG.iter().find(|r| r.id == id)
}

pub fn in_scope(rule: &Rule, path: &str) -> bool {
    rule.include.iter().any(|p| path.starts_with(p))
        && !rule.exclude.iter().any(|p| path.starts_with(p))
}

/// Run a matcher over the token stream; returns (token index, message)
/// per raw match. Test-code and annotation filtering happen in the
/// engine, which owns the per-line context.
pub fn run_matcher(m: &Matcher, toks: &[Tok]) -> Vec<(usize, String)> {
    let mut out = Vec::new();
    let n = toks.len();
    match m {
        Matcher::AnyIdent(names) => {
            for (i, t) in toks.iter().enumerate() {
                if t.is_ident && names.contains(&t.text.as_str()) {
                    out.push((i, format!("`{}` has unordered iteration", t.text)));
                }
            }
        }
        Matcher::WallClock => {
            for i in 0..n {
                if !toks[i].is_ident {
                    continue;
                }
                if toks[i].text == "SystemTime" {
                    out.push((i, "`SystemTime` read".to_string()));
                } else if toks[i].text == "Instant"
                    && i + 3 < n
                    && toks[i + 1].text == ":"
                    && toks[i + 2].text == ":"
                    && toks[i + 3].text == "now"
                {
                    out.push((i, "`Instant::now()` on a simulated path".to_string()));
                }
            }
        }
        Matcher::PartialCmpUnwrap => {
            const SINKS: [&str; 4] = ["unwrap", "unwrap_or", "unwrap_or_else", "expect"];
            for i in 0..n {
                if !(toks[i].is_ident && toks[i].text == "partial_cmp") {
                    continue;
                }
                if i > 0 && toks[i - 1].text == "fn" {
                    continue; // a PartialOrd impl, not a call site
                }
                if i + 1 >= n || toks[i + 1].text != "(" {
                    continue;
                }
                let Some(close) = match_paren(toks, i + 1) else { continue };
                if close + 2 < n
                    && toks[close + 1].text == "."
                    && SINKS.contains(&toks[close + 2].text.as_str())
                {
                    out.push((
                        i,
                        format!(
                            "`partial_cmp(..).{}(..)` — NaN panics or aliases; use `total_cmp`",
                            toks[close + 2].text
                        ),
                    ));
                }
            }
        }
        Matcher::FloatRoundCast => {
            const ROUNDERS: [&str; 4] = ["round", "floor", "ceil", "trunc"];
            const INTS: [&str; 10] =
                ["u8", "u16", "u32", "u64", "usize", "i8", "i16", "i32", "i64", "isize"];
            for i in 0..n.saturating_sub(5) {
                if toks[i].text == "."
                    && ROUNDERS.contains(&toks[i + 1].text.as_str())
                    && toks[i + 2].text == "("
                    && toks[i + 3].text == ")"
                    && toks[i + 4].text == "as"
                    && INTS.contains(&toks[i + 5].text.as_str())
                {
                    out.push((
                        i + 1,
                        format!(
                            "`.{}() as {}` saturating cast on a codec path",
                            toks[i + 1].text,
                            toks[i + 5].text
                        ),
                    ));
                }
            }
        }
        Matcher::PanicFamily => {
            const MACROS: [&str; 4] = ["panic", "unreachable", "todo", "unimplemented"];
            for i in 0..n {
                if !toks[i].is_ident {
                    continue;
                }
                let t = toks[i].text.as_str();
                if (t == "unwrap" || t == "expect")
                    && i > 0
                    && toks[i - 1].text == "."
                    && i + 1 < n
                    && toks[i + 1].text == "("
                {
                    out.push((i, format!("`.{t}()` on a library path")));
                } else if MACROS.contains(&t) && i + 1 < n && toks[i + 1].text == "!" {
                    out.push((i, format!("`{t}!` on a library path")));
                }
            }
        }
        Matcher::RawIndex(names) => {
            for i in 0..n.saturating_sub(1) {
                if toks[i].is_ident
                    && names.contains(&toks[i].text.as_str())
                    && toks[i + 1].text == "["
                {
                    out.push((
                        i,
                        format!("raw `{}[..]` slice without a visible bounds check", toks[i].text),
                    ));
                }
            }
        }
    }
    out
}

/// Index of the `)` matching the `(` at `open`, if any.
fn match_paren(toks: &[Tok], open: usize) -> Option<usize> {
    let mut depth = 0usize;
    for (j, t) in toks.iter().enumerate().skip(open) {
        match t.text.as_str() {
            "(" => depth += 1,
            ")" => {
                depth -= 1;
                if depth == 0 {
                    return Some(j);
                }
            }
            _ => {}
        }
    }
    None
}
