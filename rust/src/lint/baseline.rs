//! `lint-baseline.txt` handling: grandfathered findings, one line per
//! `RULE path` pair (forward slashes, `#` comments and blank lines
//! allowed). An entry suppresses every finding of RULE in that file;
//! an entry that matches nothing is *stale* and fails the run, so the
//! baseline can only shrink as sites are fixed.

use super::Finding;
use super::rules;
use anyhow::{Result, bail};

/// Parse baseline text into (rule, path) entries, validating rule ids.
pub fn parse(src: &str) -> Result<Vec<(String, String)>> {
    let mut entries = Vec::new();
    for (n, raw) in src.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut parts = line.split_whitespace();
        let (Some(rule), Some(path), None) = (parts.next(), parts.next(), parts.next()) else {
            bail!("baseline line {}: expected `RULE path`, got `{line}`", n + 1);
        };
        if rules::rule_by_id(rule).is_none() {
            bail!("baseline line {}: unknown rule `{rule}`", n + 1);
        }
        entries.push((rule.to_string(), path.to_string()));
    }
    Ok(entries)
}

/// Remove baselined findings. Returns (count removed, stale entries —
/// baseline lines that matched no finding and must be deleted).
pub fn apply(
    findings: &mut Vec<Finding>,
    entries: &[(String, String)],
) -> (usize, Vec<String>) {
    let mut baselined = 0usize;
    let mut stale = Vec::new();
    for (rule, path) in entries {
        let before = findings.len();
        findings.retain(|f| !(&f.rule == rule && &f.path == path));
        let matched = before - findings.len();
        if matched == 0 {
            stale.push(format!("{rule} {path}"));
        }
        baselined += matched;
    }
    (baselined, stale)
}

/// Render findings back into baseline format (sorted, deduplicated) —
/// what `fedluar-lint --write-baseline` emits. A1 (malformed
/// annotation) findings are never grandfathered: fix the annotation.
pub fn render(findings: &[Finding]) -> String {
    let mut lines: Vec<String> = findings
        .iter()
        .filter(|f| f.rule != rules::ANNOTATION_RULE)
        .map(|f| format!("{} {}", f.rule, f.path))
        .collect();
    lines.sort();
    lines.dedup();
    let mut out = String::from(
        "# fedluar-lint baseline: grandfathered findings, `RULE path` per line.\n\
         # Entries may only be removed (a stale entry fails the lint run).\n\
         # See docs/lints.md.\n",
    );
    for l in &lines {
        out.push_str(l);
        out.push('\n');
    }
    out
}
