//! fedluar-lint — in-tree static analysis for the repo's determinism
//! and panic-safety discipline (binary: `cargo run --bin fedluar-lint`).
//!
//! Every equivalence claim in this repro (recycling reproduces Fig. 3,
//! `off` faults are bit-identical, async `c=all` == sync FedAvg) rests
//! on invariants no general linter can check: no unordered iteration
//! upstream of frames/CSVs/RNG, no wall clock on simulated paths, no
//! NaN-unsafe float orderings, no saturating casts in codecs, no
//! panics on library paths. This module mechanizes them as a
//! data-driven rule catalog ([`rules::CATALOG`]) over a lightweight
//! tokenizer ([`tokens`]), with inline `// lint:allow(RULE): reason`
//! annotations and a shrinking [`baseline`] for grandfathered sites.
//! The full catalog is documented in `docs/lints.md`.

pub mod baseline;
pub mod rules;
pub mod tokens;

use anyhow::{Context, Result};
use rules::{ANNOTATION_RULE, CATALOG, in_scope, run_matcher};
use std::collections::BTreeSet;
use std::path::{Path, PathBuf};

/// One lint violation.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    pub rule: String,
    pub path: String,
    pub line: usize,
    pub msg: String,
}

/// Result of linting one source file.
#[derive(Debug, Default)]
pub struct FileLint {
    pub findings: Vec<Finding>,
    /// Matches silenced by a valid inline annotation.
    pub suppressed: usize,
}

/// Result of linting the whole tree.
#[derive(Debug, Default)]
pub struct Report {
    pub findings: Vec<Finding>,
    pub suppressed: usize,
    pub baselined: usize,
    /// Baseline entries that matched nothing (must be deleted).
    pub stale: Vec<String>,
    pub files: usize,
}

/// A parsed `// lint:allow(RULE): reason` annotation, or the error
/// that makes it malformed (reported as pseudo-rule A1).
struct Annotation {
    line: usize,
    rule: String,
    error: Option<String>,
}

/// Lint one file's source. `path_rel` is the repo-relative path with
/// forward slashes — it selects which rules are in scope.
pub fn lint_source(path_rel: &str, src: &str) -> FileLint {
    let (mut toks, comments) = tokens::tokenize(src);
    tokens::mark_test_code(&mut toks);

    let mut out = FileLint::default();
    let anns = parse_annotations(&comments);

    // A valid annotation covers its own line (trailing-comment style)
    // and the first following line that has any token.
    let mut covered: BTreeSet<(String, usize)> = BTreeSet::new();
    for a in &anns {
        match &a.error {
            Some(e) => out.findings.push(Finding {
                rule: ANNOTATION_RULE.to_string(),
                path: path_rel.to_string(),
                line: a.line,
                msg: format!("malformed lint:allow annotation: {e}"),
            }),
            None => {
                covered.insert((a.rule.clone(), a.line));
                if let Some(next) =
                    toks.iter().map(|t| t.line).filter(|&l| l > a.line).min()
                {
                    covered.insert((a.rule.clone(), next));
                }
            }
        }
    }

    for rule in CATALOG {
        if !in_scope(rule, path_rel) {
            continue;
        }
        for (idx, msg) in run_matcher(&rule.matcher, &toks) {
            let Some(tok) = toks.get(idx) else { continue };
            if rule.skip_test_code && tok.in_test {
                continue;
            }
            if covered.contains(&(rule.id.to_string(), tok.line)) {
                out.suppressed += 1;
                continue;
            }
            out.findings.push(Finding {
                rule: rule.id.to_string(),
                path: path_rel.to_string(),
                line: tok.line,
                msg,
            });
        }
    }
    out.findings.sort_by(|a, b| (a.line, &a.rule).cmp(&(b.line, &b.rule)));
    out
}

fn parse_annotations(comments: &[tokens::Comment]) -> Vec<Annotation> {
    const KEY: &str = "lint:allow";
    let mut out = Vec::new();
    for c in comments {
        // The key must lead the comment (modulo whitespace): prose
        // that merely *mentions* the annotation syntax — including
        // `///` doc comments, whose text starts with `/` — never
        // parses as one.
        let t = c.text.trim_start();
        if !t.starts_with(KEY) {
            continue;
        }
        let rest = &t[KEY.len()..];
        let mut ann =
            Annotation { line: c.line, rule: String::new(), error: None };
        if !rest.starts_with('(') {
            ann.error = Some("expected `(RULE)` after lint:allow".to_string());
            out.push(ann);
            continue;
        }
        let Some(close) = rest.find(')') else {
            ann.error = Some("unclosed `(` in lint:allow".to_string());
            out.push(ann);
            continue;
        };
        let rule = rest[1..close].trim();
        if rules::rule_by_id(rule).is_none() {
            ann.error = Some(format!("unknown rule `{rule}`"));
            out.push(ann);
            continue;
        }
        let after = &rest[close + 1..];
        let reason = after.strip_prefix(':').map(str::trim).unwrap_or("");
        if reason.is_empty() {
            ann.error =
                Some(format!("lint:allow({rule}) needs `: <reason>`"));
            out.push(ann);
            continue;
        }
        ann.rule = rule.to_string();
        out.push(ann);
    }
    out
}

/// The directories fedluar-lint walks, relative to the repo root.
pub const WALK_ROOTS: [&str; 4] = ["rust/src", "rust/tests", "rust/benches", "examples"];

/// Lint every `.rs` file under the walk roots (sorted, recursive).
/// `rust/tests/lint_fixtures/` is skipped — its files are violations
/// on purpose. No baseline is applied here; see [`apply_baseline`].
pub fn lint_tree(root: &Path) -> Result<Report> {
    let mut files: Vec<PathBuf> = Vec::new();
    for r in WALK_ROOTS {
        collect_rs(&root.join(r), &mut files)?;
    }
    files.sort();
    let mut report = Report::default();
    for f in &files {
        let rel = f
            .strip_prefix(root)
            .unwrap_or(f.as_path())
            .to_string_lossy()
            .replace('\\', "/");
        if rel.contains("lint_fixtures") {
            continue;
        }
        let src = std::fs::read_to_string(f)
            .with_context(|| format!("reading {rel}"))?;
        let fl = lint_source(&rel, &src);
        report.findings.extend(fl.findings);
        report.suppressed += fl.suppressed;
        report.files += 1;
    }
    report
        .findings
        .sort_by(|a, b| (&a.path, a.line, &a.rule).cmp(&(&b.path, b.line, &b.rule)));
    Ok(report)
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
    if !dir.is_dir() {
        return Ok(()); // tolerate absent roots (e.g. no examples/)
    }
    let entries = std::fs::read_dir(dir)
        .with_context(|| format!("listing {}", dir.display()))?;
    for entry in entries {
        let path = entry?.path();
        if path.is_dir() {
            collect_rs(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// Apply `lint-baseline.txt` text to a tree report: grandfathered
/// findings are removed and counted, entries that matched nothing are
/// recorded as stale (the caller must treat stale as failure).
pub fn apply_baseline(report: &mut Report, baseline_src: &str) -> Result<()> {
    let entries = baseline::parse(baseline_src)?;
    let (n, stale) = baseline::apply(&mut report.findings, &entries);
    report.baselined += n;
    report.stale.extend(stale);
    Ok(())
}
