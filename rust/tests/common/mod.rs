//! Shared engine-free test fixtures: the miniature `fl::Server` mirror
//! the integration suites drive, plus the artifact-gated helpers.
//!
//! One `SimServer` replaces the three near-identical copies that used
//! to live in `integration_async.rs`, `integration_delta.rs`, and the
//! new `integration_sampler.rs`. The fixture keeps every seed, salt,
//! and dataflow of the originals so the pinned trajectories and golden
//! files are unchanged:
//!
//! * `SimServer::new` — the async-suite flavor: model `asim`,
//!   per-(client, gen) independent synthetic deltas, round-robin
//!   cohorts, dense framing (`NetSim` seed 42, `compute_s = 0.1`,
//!   fixture rng salt `0xc0ffee`);
//! * `SimServer::new_delta` — the delta-suite flavor: model `dsim`,
//!   cross-round-correlated deltas (per-client base draw, generation
//!   noise XORed into the low 16 mantissa bits), residual framing
//!   optional;
//! * `with_sampler` — switches the cohort schedule from the fixture's
//!   round-robin rotation to the seeded stream `fl::Server` draws
//!   (`legacy_cohort` for `uniform`/`staleness`, `net::speed_cohort`
//!   for `speed`), arms the bounded-staleness absorb mask, and is what
//!   `integration_sampler.rs` runs.
//!
//! Per-client telemetry (`net::ClientStats`) and the dispatch log are
//! recorded unconditionally — pure arithmetic on already-computed
//! values, so legacy runs stay bit-identical while sampler tests can
//! reconcile participation counts against the log.

#![allow(dead_code)]

use fedluar::comm::CommAccountant;
use fedluar::config::{Method, RecycleMode, RunConfig, SelectionScheme};
use fedluar::fl::{AsyncRuntime, DeltaFrameState, UploadPayload};
use fedluar::luar::LuarState;
use fedluar::metrics::{AbsorbRecord, History, RoundRecord};
use fedluar::model::{artifacts_dir, ModelMeta};
use fedluar::net::{
    sched, wire, ChainOutcome, ClientStats, FaultPlan, FaultsCfg, LinkDist, NetCfg, NetSim,
    RoundMode, SamplerCfg, Staleness,
};
use fedluar::rng::Rng;
use fedluar::tensor;
use std::path::PathBuf;

pub const LAYERS: usize = 6;
pub const LAYER_SIZE: usize = 512;
pub const NUM_CLIENTS: usize = 16;
pub const ACTIVE: usize = 8;

/// 6-layer synthetic model (8x64 matrices), no artifacts needed.
pub fn synth_meta(model: &str) -> ModelMeta {
    let mut rows = Vec::new();
    for l in 0..LAYERS {
        let off = l * LAYER_SIZE;
        rows.push(format!(
            r#"{{"name":"l{l}","kind":"dense","offset":{off},"size":{LAYER_SIZE},
               "arrays":[{{"name":"w","shape":[8,64],"offset":{off},"size":{LAYER_SIZE}}}]}}"#
        ));
    }
    let dim = LAYERS * LAYER_SIZE;
    let doc = format!(
        r#"{{"model":"{model}","dim":{dim},"num_classes":10,
            "input_shape":[8],"input_dtype":"f32","tau":5,"batch":16,
            "eval_batch":64,"agg_clients":8,"momentum":0.9,
            "layers":[{}],
            "artifacts":{{"train":"t","eval":"e","agg":"g","init":"i"}},
            "init_sha256":"x"}}"#,
        rows.join(",")
    );
    ModelMeta::from_json(&doc, PathBuf::from("/tmp")).unwrap()
}

/// Which synthetic-training stand-in generates client deltas.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DeltaFlavor {
    /// Fresh draw per (client, generation) — the async-suite regime.
    Independent,
    /// Per-client base vector with per-generation noise confined to the
    /// low 16 bits of each f32 — the regime residual framing exploits.
    Correlated,
}

/// Deterministic stand-in for one client's local training at a given
/// sample generation: the only piece of the pipeline that is synthetic.
pub fn fake_delta(
    flavor: DeltaFlavor,
    seed: u64,
    client: usize,
    gen: u64,
    dim: usize,
) -> (Vec<f32>, f32) {
    match flavor {
        DeltaFlavor::Independent => {
            let mut rng = Rng::seed_from_u64(
                seed ^ (client as u64).wrapping_mul(0x9e37_79b9) ^ gen.wrapping_mul(0x85eb_ca6b),
            );
            let delta: Vec<f32> = (0..dim).map(|_| rng.normal_f32(0.0, 0.05)).collect();
            let loss = 1.0 + rng.f32();
            (delta, loss)
        }
        DeltaFlavor::Correlated => {
            let mut base = Rng::seed_from_u64(seed ^ (client as u64).wrapping_mul(0x9e37_79b9));
            let mut noise = Rng::seed_from_u64(
                seed ^ (client as u64).wrapping_mul(0x9e37_79b9) ^ gen.wrapping_mul(0x85eb_ca6b),
            );
            let delta: Vec<f32> = (0..dim)
                .map(|_| {
                    let b = base.normal_f32(0.0, 0.05);
                    f32::from_bits(b.to_bits() ^ (noise.next_u64() as u32 & 0xffff))
                })
                .collect();
            let loss = 1.0 + noise.f32();
            (delta, loss)
        }
    }
}

/// How the fixture picks each generation's cohort.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CohortPolicy {
    /// Deterministic rotation — the legacy fixture schedule (the
    /// schedule, not the data, is under test in the async/delta suites).
    RoundRobin,
    /// Mirror `fl::Server`'s draw: `legacy_cohort` for
    /// `uniform`/`staleness`, `net::speed_cohort` for `speed`.
    Sampled,
}

/// The exact legacy cohort stream (`DataSet::sample_clients` since
/// PR 1): seeded partial Fisher-Yates under the `0xc11e_0000` salt.
pub fn legacy_cohort(num_clients: usize, active: usize, seed: u64, round: u64) -> Vec<usize> {
    let mut rng = Rng::seed_from_u64(seed ^ 0xc11e_0000 ^ round);
    rng.sample_indices(num_clients, active)
}

/// Miniature mirror of `fl::Server` for FedAvg / FedLUAR with an SGD
/// server optimizer: same dispatch half (LUAR layer zeroing, dense
/// wire codec, per-client links, optional residual-framing ledger),
/// same absorb half (weighted mean, Eq. 1 score update, version-gap
/// aging, compose, select-next, measured byte accounting, bounded
/// staleness), with `fake_delta` in place of the AOT train graph.
/// `test_loss` doubles as a model-trajectory probe (ssq of the params)
/// so histories pin the parameter path.
pub struct SimServer {
    pub meta: ModelMeta,
    pub seed: u64,
    /// `Some(delta)` = FedLUAR at that recycling depth; `None` = FedAvg.
    pub luar_delta: Option<usize>,
    pub net: NetSim,
    pub luar: LuarState,
    pub params: Vec<f32>,
    pub comm: CommAccountant,
    pub history: History,
    pub rng: Rng,
    pub round: usize,
    pub sim_seconds: f64,
    pub rt: Option<AsyncRuntime>,
    pub delta: Option<DeltaFrameState>,
    pub flavor: DeltaFlavor,
    pub cohorts: CohortPolicy,
    pub sampler: SamplerCfg,
    /// Per-client telemetry, recorded on every dispatch/absorb exactly
    /// as `Server` records it.
    pub sampler_stats: ClientStats,
    /// Every dispatched client in order — the scheduler's dispatch log
    /// the sampler tests reconcile participation counts against.
    pub dispatch_log: Vec<usize>,
    /// Per-generation cohort memo, mirroring `Server::async_cohort`
    /// (under `speed` the draw reads mutable telemetry, so it must be
    /// sampled once per generation, not once per dispatch).
    async_cohort: Option<(u64, Vec<usize>)>,
    /// `Some` iff fault injection is armed — the same per-(client,
    /// version, attempt) seeded chains `fl::Server` resolves, so the
    /// chaos suites exercise the identical fault model engine-free.
    pub faults: Option<FaultPlan>,
}

impl SimServer {
    /// The async-suite flavor: independent deltas, round-robin cohorts,
    /// dense framing over the given fleet.
    pub fn new(mode: RoundMode, dist: LinkDist, luar_delta: Option<usize>, seed: u64) -> Self {
        Self::build(mode, dist, luar_delta, seed, false, DeltaFlavor::Independent, "asim")
    }

    /// The delta-suite flavor: correlated deltas over the default
    /// (homogeneous) fleet, residual framing optional.
    pub fn new_delta(
        mode: RoundMode,
        luar_delta: Option<usize>,
        seed: u64,
        delta_frames: bool,
    ) -> Self {
        Self::build(
            mode,
            LinkDist::default(),
            luar_delta,
            seed,
            delta_frames,
            DeltaFlavor::Correlated,
            "dsim",
        )
    }

    fn build(
        mode: RoundMode,
        dist: LinkDist,
        luar_delta: Option<usize>,
        seed: u64,
        delta_frames: bool,
        flavor: DeltaFlavor,
        model: &str,
    ) -> Self {
        let meta = synth_meta(model);
        let net = NetSim::new(
            NetCfg {
                link_dist: dist,
                round_mode: mode,
                compute_s: 0.1,
                delta_frames,
                sampler: SamplerCfg::Uniform,
                faults: FaultsCfg::default(),
            },
            NUM_CLIENTS,
            42,
        );
        let dim = meta.dim;
        let layers = meta.num_layers();
        SimServer {
            meta,
            seed,
            luar_delta,
            net,
            luar: LuarState::new(layers, dim),
            params: vec![0.0; dim],
            comm: CommAccountant::new(layers),
            history: History::default(),
            rng: Rng::seed_from_u64(seed ^ 0xc0ffee),
            round: 0,
            sim_seconds: 0.0,
            rt: None,
            delta: delta_frames.then(|| DeltaFrameState::new(NUM_CLIENTS)),
            flavor,
            cohorts: CohortPolicy::RoundRobin,
            sampler: SamplerCfg::Uniform,
            sampler_stats: ClientStats::new(NUM_CLIENTS),
            dispatch_log: Vec::new(),
            async_cohort: None,
            faults: None,
        }
    }

    /// Switch to `Server`'s sampled cohort stream under the given
    /// policy (and arm the bounded-staleness cap when `staleness:cap`).
    pub fn with_sampler(mut self, sampler: SamplerCfg) -> Self {
        self.sampler = sampler;
        self.cohorts = CohortPolicy::Sampled;
        self
    }

    /// Arm deterministic fault injection, exactly as `Server::with_meta`
    /// does: the plan is seeded with the fixture seed and `off` leaves
    /// the fault path unentered (bit-identical to an unarmed fixture).
    pub fn with_faults(mut self, cfg: FaultsCfg) -> Self {
        self.net.cfg.faults = cfg;
        self.faults = (!cfg.is_off()).then(|| FaultPlan::new(cfg, NUM_CLIENTS, self.seed));
        self
    }

    /// The generation's cohort under the configured policy.
    pub fn cohort(&self, gen: u64) -> Vec<usize> {
        match self.cohorts {
            CohortPolicy::RoundRobin => {
                (0..ACTIVE).map(|i| ((gen as usize) * ACTIVE + i) % NUM_CLIENTS).collect()
            }
            CohortPolicy::Sampled => match self.sampler {
                SamplerCfg::Speed { pow } => fedluar::net::speed_cohort(
                    &self.sampler_stats,
                    pow,
                    gen as usize,
                    ACTIVE,
                    self.seed,
                ),
                _ => legacy_cohort(NUM_CLIENTS, ACTIVE, self.seed, gen),
            },
        }
    }

    pub fn upload_layers(&self) -> Vec<usize> {
        if self.luar_delta.is_some() {
            self.luar.upload_set(self.meta.num_layers())
        } else {
            (0..self.meta.num_layers()).collect()
        }
    }

    /// One client's uplink at model `version`: train (fake), zero R_t,
    /// dense encode/decode (self-contained length times the link), then
    /// the residual path decides the ledger length — exactly
    /// `Server::client_upload`. Returns (decoded update, loss,
    /// ledger bytes, self-contained bytes, sealed frame when faults
    /// are armed — both byte counts then include the integrity
    /// trailer, exactly as `Server` grows them).
    pub fn upload(
        &mut self,
        client: usize,
        gen: u64,
        version: u64,
        upload_layers: &[usize],
    ) -> (Vec<f32>, f32, u64, u64, Option<Vec<u8>>) {
        let (mut delta_v, loss) = fake_delta(self.flavor, self.seed, client, gen, self.meta.dim);
        for &l in &self.luar.recycle_set {
            let lm = &self.meta.layers[l];
            delta_v[lm.offset..lm.offset + lm.size].iter_mut().for_each(|v| *v = 0.0);
        }
        let frame =
            wire::encode_update(&delta_v, &self.meta, upload_layers, &wire::WireHint::Dense)
                .unwrap();
        let mut decoded = match wire::decode_update(frame.as_bytes(), &self.meta).unwrap() {
            wire::Decoded::Vector(v) => v,
            wire::Decoded::Scalar(_) => unreachable!("dense flavor only"),
        };
        let self_len = frame.len() as u64;
        let mut ledger_len = self_len;
        if let Some(st) = &self.delta {
            if let Some(ref_version) = st.usable_up_ref_version(client, version) {
                let reference = st.up_ref(client).expect("usable ref exists").data.clone();
                let dframe = wire::encode_update_delta(
                    &decoded,
                    &self.meta,
                    upload_layers,
                    &reference,
                    ref_version,
                )
                .unwrap();
                if (dframe.len() as u64) < self_len {
                    let (dd, _) =
                        wire::decode_update_delta(dframe.as_bytes(), &self.meta, &reference)
                            .unwrap();
                    ledger_len = dframe.len() as u64;
                    decoded = dd;
                    let st = self.delta.as_mut().expect("checked above");
                    st.note_uplink(self_len, ledger_len, Some(version - ref_version));
                } else {
                    let st = self.delta.as_mut().expect("checked above");
                    st.note_uplink(self_len, self_len, None);
                }
            } else {
                let st = self.delta.as_mut().expect("checked above");
                st.note_uplink(self_len, self_len, None);
            }
            let st = self.delta.as_mut().expect("checked above");
            st.record_upload(client, version, &decoded, &self.meta);
        }
        let sealed = if self.faults.is_some() {
            let mut bytes = frame.as_bytes().to_vec();
            wire::seal_trailer(&mut bytes);
            Some(bytes)
        } else {
            None
        };
        let trailer = sealed.is_some() as u64 * wire::TRAILER_LEN as u64;
        (decoded, loss, ledger_len + trailer, self_len + trailer, sealed)
    }

    /// Record one dispatch in the telemetry table and log — the same
    /// arithmetic as `Server::record_dispatch_telemetry` (no RNG, no
    /// clock: trajectory-neutral).
    fn record_dispatch(&mut self, client: usize, self_len: u64) {
        let upload_secs = self.net.fleet.link(client).upload_secs(self_len);
        self.sampler_stats.record_dispatch(client, upload_secs, self_len);
        self.dispatch_log.push(client);
    }

    /// Absorb half: mirrors `Server::finish_aggregation` (weighted
    /// mean, LUAR with version-gap aging, SGD apply, ledger including
    /// the drained residual counters, record).
    #[allow(clippy::too_many_arguments)]
    pub fn finish(
        &mut self,
        deltas: &[Vec<f32>],
        included: &[bool],
        weights: &[f32],
        upload_layers: &[usize],
        actives_len: usize,
        loss_sum: f64,
        loss_count: usize,
        up_bytes_total: u64,
        down_total: u64,
        round_secs: f64,
        tail_s: f64,
        arrivals: usize,
        mean_gap: f64,
    ) {
        let mut refs: Vec<&[f32]> = Vec::with_capacity(arrivals);
        let mut agg_weights: Vec<f32> = Vec::with_capacity(arrivals);
        for (slot, d) in deltas.iter().enumerate() {
            if included[slot] {
                refs.push(d.as_slice());
                agg_weights.push(weights[slot]);
            }
        }
        assert!(!refs.is_empty(), "aggregation must never be empty");
        let uniform = agg_weights.iter().all(|&w| w == 1.0);
        let mut mean = vec![0.0f32; self.meta.dim];
        if uniform {
            tensor::mean_rows_par(&refs, &mut mean);
        } else {
            let wsum: f32 = agg_weights.iter().sum();
            let norm: Vec<f32> = agg_weights.iter().map(|w| w / wsum).collect();
            tensor::weighted_mean_rows(&refs, &norm, &mut mean);
        }
        let mut u_ssq = Vec::with_capacity(self.meta.num_layers());
        let mut w_ssq = Vec::with_capacity(self.meta.num_layers());
        for lm in &self.meta.layers {
            let r = lm.offset..lm.offset + lm.size;
            u_ssq.push(tensor::ssq(&mean[r.clone()]) as f32);
            w_ssq.push(tensor::ssq(&self.params[r]) as f32);
        }
        let mut kappa = 0.0;
        if let Some(delta_sel) = self.luar_delta {
            self.luar.update_scores(&u_ssq, &w_ssq);
            self.luar.set_age_step(1 + mean_gap.round() as u32);
            kappa = self.luar.compose_update(&mut mean, &self.meta, RecycleMode::Recycle);
            let grad_norms: Vec<f64> =
                u_ssq.iter().map(|&s| (s as f64).max(0.0).sqrt()).collect();
            self.luar.select_next(SelectionScheme::Luar, delta_sel, &grad_norms, &mut self.rng);
        }
        tensor::axpy(1.0, &mean, &mut self.params);
        self.comm.record_wire_round(
            actives_len as u64,
            upload_layers,
            up_bytes_total,
            wire::dense_frame_len(&self.meta),
            down_total,
        );
        let (saved, fallbacks, _gap) = match &mut self.delta {
            Some(st) => st.drain_round(),
            None => (0, 0, 0.0),
        };
        self.comm.record_delta(saved, fallbacks);
        self.sim_seconds += round_secs;
        let train_loss = loss_sum / loss_count.max(1) as f64;
        self.round += 1;
        self.history.push(RoundRecord {
            round: self.round,
            train_loss,
            test_loss: tensor::ssq(&self.params),
            test_acc: self.params[0] as f64,
            up_bytes: self.comm.up_bytes,
            comm_ratio: self.comm.comm_ratio(),
            kappa,
            sim_seconds: self.sim_seconds,
            wire_bytes: up_bytes_total,
            tail_s,
            arrivals,
            version_gap: mean_gap,
        });
    }

    pub fn run_sync_round(&mut self) {
        let t = self.round as u64;
        let actives = self.cohort(t);
        let upload_layers = self.upload_layers();
        let bcast =
            wire::encode_broadcast(&self.params, &self.meta, &self.luar.recycle_set).unwrap();
        let bcast_self = bcast.len() as u64;
        let mut down_total = 0u64;
        if self.delta.is_some() {
            let params = self.params.clone();
            let recycle = self.luar.recycle_set.clone();
            let st = self.delta.as_mut().expect("checked above");
            st.note_bcast(t, &params, &self.meta);
            for &client in &actives {
                down_total +=
                    st.bcast_ledger_len(client, t, &self.meta, &recycle, bcast_self).unwrap();
            }
        } else {
            down_total = actives.len() as u64 * bcast_self;
        }
        let mut deltas: Vec<Vec<f32>> = Vec::with_capacity(actives.len());
        let mut frame_lens: Vec<u64> = Vec::with_capacity(actives.len());
        let mut timing_lens: Vec<u64> = Vec::with_capacity(actives.len());
        let mut losses: Vec<f64> = Vec::with_capacity(actives.len());
        let mut sealed_frames: Vec<Option<Vec<u8>>> = Vec::with_capacity(actives.len());
        for &client in &actives {
            let (d, loss, ledger_len, self_len, sealed) =
                self.upload(client, t, t, &upload_layers);
            losses.push(loss as f64);
            frame_lens.push(ledger_len);
            timing_lens.push(self_len);
            deltas.push(d);
            sealed_frames.push(sealed);
            self.record_dispatch(client, self_len);
        }
        // the schedule is always timed against self-contained lengths;
        // with a fault plan each slot's time is its collapsed retry
        // chain and failed slots are masked out — `Server`'s exact path
        let mut loss_sum: f64 = losses.iter().sum();
        let mut loss_count = actives.len();
        let mut up_total: u64 = frame_lens.iter().sum();
        let outcome = if self.faults.is_some() {
            let mut plan = self.faults.take().expect("checked above");
            let mut chains: Vec<ChainOutcome> = Vec::with_capacity(actives.len());
            for (slot, &client) in actives.iter().enumerate() {
                let secs = self.net.client_secs(client, bcast_self, timing_lens[slot]);
                let frame = sealed_frames[slot].as_deref().expect("faults imply sealed frames");
                chains.push(plan.attempt_chain(client, t, self.sim_seconds, secs, frame));
            }
            self.faults = Some(plan);
            let times: Vec<f64> = chains.iter().map(|c| c.secs).collect();
            let raw = sched::simulate_round(&self.net.cfg.round_mode, &times);
            let failed: Vec<bool> = chains.iter().map(|c| !c.survived).collect();
            let outcome = sched::mask_failed_slots(raw, &failed);
            loss_sum = 0.0;
            loss_count = 0;
            up_total = 0;
            for (slot, ch) in chains.iter().enumerate() {
                self.record_chain(actives[slot], ch);
                if ch.up_bytes > 0 {
                    up_total += frame_lens[slot] + ch.up_bytes - timing_lens[slot];
                }
                if ch.survived {
                    loss_sum += losses[slot];
                    loss_count += 1;
                }
            }
            if outcome.aggregated < self.net.cfg.faults.policy.quorum {
                self.faults.as_mut().expect("restored above").note_quorum_degraded();
            }
            if outcome.aggregated == 0 {
                self.finish_degraded(
                    &upload_layers,
                    actives.len(),
                    up_total,
                    down_total,
                    outcome.round_secs,
                );
                return;
            }
            outcome
        } else {
            self.net.round(&actives, bcast_self, &timing_lens)
        };
        for (slot, &client) in actives.iter().enumerate() {
            if outcome.included[slot] {
                self.sampler_stats.record_absorbed(client);
            }
        }
        self.finish(
            &deltas,
            &outcome.included,
            &outcome.weights,
            &upload_layers,
            actives.len(),
            loss_sum,
            loss_count,
            up_total,
            down_total,
            outcome.round_secs,
            outcome.straggler_tail_s,
            outcome.aggregated,
            0.0,
        );
    }

    /// Fold one resolved chain into the telemetry table — the stats
    /// half of `Server::record_chain_telemetry` (obs counters are the
    /// real server's concern).
    fn record_chain(&mut self, client: usize, ch: &ChainOutcome) {
        if ch.attempts > 1 {
            self.sampler_stats.record_retries(
                client,
                (ch.attempts - 1) as u64,
                ch.retry_secs,
                ch.retry_up_bytes,
            );
        }
        if !ch.survived {
            self.sampler_stats.record_failure(client);
        }
    }

    /// `Server::finish_degraded_round`: nothing survived, so the model
    /// and LUAR state stay put, but bytes, clock, and the round counter
    /// advance.
    fn finish_degraded(
        &mut self,
        upload_layers: &[usize],
        actives_len: usize,
        up_bytes_total: u64,
        down_total: u64,
        round_secs: f64,
    ) {
        self.comm.record_wire_round(
            actives_len as u64,
            upload_layers,
            up_bytes_total,
            wire::dense_frame_len(&self.meta),
            down_total,
        );
        self.sim_seconds += round_secs;
        self.round += 1;
        self.history.push(RoundRecord {
            round: self.round,
            train_loss: 0.0,
            test_loss: tensor::ssq(&self.params),
            test_acc: self.params[0] as f64,
            up_bytes: self.comm.up_bytes,
            comm_ratio: self.comm.comm_ratio(),
            kappa: 0.0,
            sim_seconds: self.sim_seconds,
            wire_bytes: up_bytes_total,
            tail_s: 0.0,
            arrivals: 0,
            version_gap: 0.0,
        });
    }

    pub fn dispatch_next(&mut self) {
        let (mut gen, mut idx) = {
            let rt = self.rt.as_ref().unwrap();
            (rt.sample_gen, rt.sample_idx as usize)
        };
        if idx >= ACTIVE {
            gen += 1;
            idx = 0;
        }
        // sample each generation's cohort once (under `speed` the draw
        // reads the mutable telemetry table, exactly like `Server`)
        let cached = matches!(&self.async_cohort, Some((g, _)) if *g == gen);
        if !cached {
            let cohort = self.cohort(gen);
            self.async_cohort = Some((gen, cohort));
        }
        let client = self.async_cohort.as_ref().unwrap().1[idx];
        {
            let rt = self.rt.as_mut().unwrap();
            rt.sample_gen = gen;
            rt.sample_idx = (idx + 1) as u64;
        }
        let version = self.rt.as_ref().unwrap().version;
        let upload_layers = self.upload_layers();
        let bcast =
            wire::encode_broadcast(&self.params, &self.meta, &self.luar.recycle_set).unwrap();
        let bcast_self = bcast.len() as u64;
        let bcast_ledger = if self.delta.is_some() {
            let params = self.params.clone();
            let recycle = self.luar.recycle_set.clone();
            let st = self.delta.as_mut().expect("checked above");
            st.note_bcast(version, &params, &self.meta);
            st.bcast_ledger_len(client, version, &self.meta, &recycle, bcast_self).unwrap()
        } else {
            bcast_self
        };
        let (delta, loss, ledger_len, self_len, sealed) =
            self.upload(client, gen, version, &upload_layers);
        // timing against self-contained lengths, ledger gets the delta
        let secs = self.net.client_secs(client, bcast_self, self_len);
        self.record_dispatch(client, self_len);
        // fault chain resolves at dispatch time, like
        // `Server::dispatch_next_async`: a failed chain never enters
        // the queue, its bytes are orphaned, the slot refills from the
        // sampler stream on the caller's next pass
        let mut duration = secs;
        let mut frame_bytes = ledger_len;
        if self.faults.is_some() {
            let mut plan = self.faults.take().expect("checked above");
            let now = self.rt.as_ref().unwrap().now;
            let frame = sealed.as_deref().expect("faults imply sealed frames");
            let ch = plan.attempt_chain(client, version, now, secs, frame);
            self.faults = Some(plan);
            self.record_chain(client, &ch);
            let transmitted =
                if ch.up_bytes > 0 { ledger_len + ch.up_bytes - self_len } else { 0 };
            if !ch.survived {
                self.faults.as_mut().expect("restored above").note_orphan(transmitted, bcast_ledger);
                return;
            }
            duration = ch.secs;
            frame_bytes = transmitted;
        }
        let rt = self.rt.as_mut().unwrap();
        let payload = UploadPayload {
            client,
            version,
            gen,
            delta,
            loss,
            frame_len: frame_bytes,
            bcast_len: bcast_ledger,
        };
        rt.dispatch(payload, duration);
    }

    pub fn run_async_round(&mut self, c: usize, staleness: Staleness) {
        if self.rt.is_none() {
            self.rt = Some(
                AsyncRuntime::new(NUM_CLIENTS, c, ACTIVE, staleness)
                    .with_stale_cap(self.sampler.stale_cap()),
            );
        }
        loop {
            while self.rt.as_ref().unwrap().wants_dispatch() {
                self.dispatch_next();
            }
            let start = self.rt.as_mut().unwrap().absorb_instant().unwrap();
            {
                let rt = self.rt.as_ref().unwrap();
                let in_flight = rt.in_flight();
                let version = rt.version;
                for (i, u) in rt.buffer[start..].iter().enumerate() {
                    self.history.absorbs.push(AbsorbRecord {
                        version,
                        client: u.payload.client,
                        t: u.t,
                        version_gap: u.version_gap,
                        weight: u.weight,
                        in_flight,
                        queue_depth: start + i + 1,
                    });
                }
            }
            if self.rt.as_ref().unwrap().ready() {
                let batch = self.rt.as_mut().unwrap().take_aggregation();
                let n = batch.uploads.len();
                // bounded staleness: the same include-or-hold mask as
                // `Server::absorb_async_batch` (all-true without a cap)
                let mut included: Vec<bool> = {
                    let rt = self.rt.as_ref().unwrap();
                    batch.uploads.iter().map(|u| rt.within_cap(u.version_gap)).collect()
                };
                if !included.iter().any(|&i| i) {
                    included.iter_mut().for_each(|i| *i = true);
                }
                for (u, &inc) in batch.uploads.iter().zip(&included) {
                    if inc {
                        self.sampler_stats.record_absorbed(u.payload.client);
                    } else {
                        self.sampler_stats.record_held(u.payload.client);
                    }
                }
                let mut deltas: Vec<Vec<f32>> = Vec::with_capacity(n);
                let mut weights: Vec<f32> = Vec::with_capacity(n);
                let mut loss_sum = 0.0f64;
                let mut up_total = 0u64;
                for u in batch.uploads {
                    loss_sum += u.payload.loss as f64;
                    up_total += u.payload.frame_len;
                    weights.push(u.weight);
                    deltas.push(u.payload.delta);
                }
                let mut down_bytes = batch.down_bytes;
                // permanently failed dispatches since the last close
                // still paid bytes — drain them into this ledger, like
                // `Server::absorb_async_batch`
                if let Some(plan) = &mut self.faults {
                    let (orphan_up, orphan_down) = plan.drain_orphans();
                    up_total += orphan_up;
                    down_bytes += orphan_down;
                }
                let upload_layers = self.upload_layers();
                self.finish(
                    &deltas,
                    &included,
                    &weights,
                    &upload_layers,
                    n,
                    loss_sum,
                    n,
                    up_total,
                    down_bytes,
                    batch.round_secs,
                    batch.tail_s,
                    n,
                    batch.mean_gap,
                );
                return;
            }
        }
    }

    pub fn run(&mut self, rounds: usize) {
        while self.round < rounds {
            match self.net.cfg.round_mode {
                RoundMode::Async { concurrency, staleness } => {
                    let c = if concurrency == 0 { ACTIVE } else { concurrency };
                    self.run_async_round(c, staleness);
                }
                _ => self.run_sync_round(),
            }
        }
    }
}

/// Heavy-tailed edge fleet shared by the async tests.
pub fn edge_fleet() -> LinkDist {
    LinkDist::LogNormal { up_mbps: 10.0, down_mbps: 50.0, sigma: 0.75, rtt_s: 0.05 }
}

/// The bimodal straggler fleet the wall-clock tests run on (rtt 0 so
/// round times separate cleanly into fast/slow cohorts).
pub fn bimodal_fleet() -> LinkDist {
    LinkDist::Bimodal {
        fast_frac: 0.75,
        fast_up_mbps: 80.0,
        slow_up_mbps: 1.0,
        down_mbps: 100.0,
        rtt_s: 0.0,
    }
}

/// Bit-exact history comparison (rounds + absorbs).
pub fn assert_history_identical(a: &History, b: &History, what: &str) {
    assert_eq!(a.records.len(), b.records.len(), "{what}: record count");
    for (x, y) in a.records.iter().zip(&b.records) {
        assert_eq!(x.round, y.round, "{what}");
        assert_eq!(x.train_loss.to_bits(), y.train_loss.to_bits(), "{what} round {}", x.round);
        assert_eq!(x.test_loss.to_bits(), y.test_loss.to_bits(), "{what} round {}", x.round);
        assert_eq!(x.kappa.to_bits(), y.kappa.to_bits(), "{what} round {}", x.round);
        assert_eq!(x.up_bytes, y.up_bytes, "{what} round {}", x.round);
        assert_eq!(x.wire_bytes, y.wire_bytes, "{what} round {}", x.round);
        assert_eq!(x.arrivals, y.arrivals, "{what} round {}", x.round);
        assert_eq!(
            x.sim_seconds.to_bits(),
            y.sim_seconds.to_bits(),
            "{what} round {}",
            x.round
        );
        assert_eq!(
            x.version_gap.to_bits(),
            y.version_gap.to_bits(),
            "{what} round {}",
            x.round
        );
    }
    assert_eq!(a.absorbs.len(), b.absorbs.len(), "{what}: absorb count");
    for (x, y) in a.absorbs.iter().zip(&b.absorbs) {
        assert_eq!(x.version, y.version, "{what}");
        assert_eq!(x.client, y.client, "{what}");
        assert_eq!(x.t.to_bits(), y.t.to_bits(), "{what}");
        assert_eq!(x.version_gap, y.version_gap, "{what}");
        assert_eq!(x.weight.to_bits(), y.weight.to_bits(), "{what}");
        assert_eq!(x.in_flight, y.in_flight, "{what}");
        assert_eq!(x.queue_depth, y.queue_depth, "{what}");
    }
}

/// Every field of the round history that reflects the model path, the
/// simulated clock, or the scheduler — everything except bytes — must
/// be bit-identical between a dense-framed and a delta-framed run.
pub fn assert_trajectories_identical(dense: &History, framed: &History, tag: &str) {
    assert_eq!(dense.records.len(), framed.records.len(), "{tag}: round counts");
    for (d, f) in dense.records.iter().zip(&framed.records) {
        assert_eq!(d.round, f.round, "{tag}");
        let r = d.round;
        assert_eq!(d.train_loss.to_bits(), f.train_loss.to_bits(), "{tag} round {r}");
        assert_eq!(d.test_loss.to_bits(), f.test_loss.to_bits(), "{tag} round {r}");
        assert_eq!(d.test_acc.to_bits(), f.test_acc.to_bits(), "{tag} round {r}");
        assert_eq!(d.kappa.to_bits(), f.kappa.to_bits(), "{tag} round {r}");
        assert_eq!(d.sim_seconds.to_bits(), f.sim_seconds.to_bits(), "{tag} round {r}");
        assert_eq!(d.tail_s.to_bits(), f.tail_s.to_bits(), "{tag} round {r}");
        assert_eq!(d.arrivals, f.arrivals, "{tag} round {r}");
        assert_eq!(d.version_gap.to_bits(), f.version_gap.to_bits(), "{tag} round {r}");
    }
}

/// Whether the real model artifacts exist (the artifact-gated suites
/// skip with a hint otherwise).
pub fn have_artifacts() -> bool {
    if ModelMeta::load(artifacts_dir(), "mlp").is_ok() {
        true
    } else {
        eprintln!("SKIP: run `make artifacts`");
        false
    }
}

/// Sub-second MLP benchmark config the artifact-gated suites run.
pub fn quick_cfg(method: Method) -> RunConfig {
    let mut cfg = RunConfig::benchmark("mlp").unwrap();
    cfg.num_clients = 24;
    cfg.active_clients = 6;
    cfg.per_client = 64;
    cfg.test_size = 256;
    cfg.rounds = 8;
    cfg.eval_every = 4;
    cfg.method = method;
    cfg
}
