//! Property-based tests (in-tree harness; proptest is unavailable in
//! the offline build). Each property is checked over a seeded sweep of
//! randomized cases; failures print the offending seed so a case can
//! be replayed exactly.

use fedluar::comm::CommAccountant;
use fedluar::compress::{Binarize, DropoutAvg, Lbgm, LowRank, Quantize, UpdateCompressor};
use fedluar::config::{RecycleMode, SelectionScheme};
use fedluar::data::{FedDataset, SynthSpec};
use fedluar::fl::{DeltaFrameState, DELTA_MAX_REF_GAP};
use fedluar::luar::{select_layers, LuarState};
use fedluar::model::ModelMeta;
use fedluar::net::wire::{self, WireHint};
use fedluar::net::{speed_weights, ClientStats, FailurePolicy, FaultKind, FaultsCfg, SamplerCfg};
use fedluar::rng::Rng;
use fedluar::tensor;
use std::path::PathBuf;

const CASES: u64 = 200;

fn rand_meta(rng: &mut Rng) -> ModelMeta {
    let layers = rng.gen_range(1, 12);
    let mut rows = Vec::new();
    let mut off = 0usize;
    for l in 0..layers {
        let size = rng.gen_range(1, 64);
        rows.push(format!(
            r#"{{"name":"l{l}","kind":"dense","offset":{off},"size":{size},"arrays":[]}}"#
        ));
        off += size;
    }
    let doc = format!(
        r#"{{"model":"prop","dim":{off},"num_classes":3,
            "input_shape":[4],"input_dtype":"f32","tau":2,"batch":4,
            "eval_batch":8,"agg_clients":4,"momentum":0.9,
            "layers":[{}],
            "artifacts":{{"train":"t","eval":"e","agg":"g","init":"i"}},
            "init_sha256":"x"}}"#,
        rows.join(",")
    );
    let meta = ModelMeta::from_json(&doc, PathBuf::from("/tmp")).unwrap();
    meta.validate().unwrap();
    meta
}

/// Random meta whose layers each hold one matrix array (so the
/// low-rank flavor has factorable shapes).
fn rand_meta_arrays(rng: &mut Rng) -> ModelMeta {
    let layers = rng.gen_range(1, 6);
    let mut rows = Vec::new();
    let mut off = 0usize;
    for l in 0..layers {
        let r = rng.gen_range(2, 9);
        let c = rng.gen_range(2, 17);
        let size = r * c;
        rows.push(format!(
            r#"{{"name":"l{l}","kind":"dense","offset":{off},"size":{size},
               "arrays":[{{"name":"w","shape":[{r},{c}],"offset":{off},"size":{size}}}]}}"#
        ));
        off += size;
    }
    let doc = format!(
        r#"{{"model":"prop","dim":{off},"num_classes":3,
            "input_shape":[4],"input_dtype":"f32","tau":2,"batch":4,
            "eval_batch":8,"agg_clients":4,"momentum":0.9,
            "layers":[{}],
            "artifacts":{{"train":"t","eval":"e","agg":"g","init":"i"}},
            "init_sha256":"x"}}"#,
        rows.join(",")
    );
    let meta = ModelMeta::from_json(&doc, PathBuf::from("/tmp")).unwrap();
    meta.validate().unwrap();
    meta
}

// ---------------------------------------------------------------- sampling

#[test]
fn prop_weighted_sampling_is_distinct_and_in_range() {
    for seed in 0..CASES {
        let mut rng = Rng::seed_from_u64(seed);
        let n = rng.gen_range(1, 30);
        let k = rng.gen_range(0, n + 1);
        let w: Vec<f64> = (0..n).map(|_| rng.f64()).collect();
        let picks = rng.weighted_sample_without_replacement(&w, k);
        assert_eq!(picks.len(), k.min(n), "seed {seed}");
        let mut sorted = picks.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), picks.len(), "seed {seed}: duplicates");
        assert!(picks.iter().all(|&i| i < n), "seed {seed}: out of range");
    }
}

#[test]
fn prop_selection_schemes_return_valid_sets() {
    let schemes = [
        SelectionScheme::Luar,
        SelectionScheme::Random,
        SelectionScheme::Top,
        SelectionScheme::Bottom,
        SelectionScheme::GradNorm,
        SelectionScheme::Deterministic,
    ];
    for seed in 0..CASES {
        let mut rng = Rng::seed_from_u64(seed);
        let n = rng.gen_range(1, 20);
        let delta = rng.gen_range(0, n + 3); // may exceed n
        let scores: Vec<f64> = (0..n).map(|_| rng.f64() + 1e-6).collect();
        let observed: Vec<bool> = (0..n).map(|_| rng.gen_bool(0.8)).collect();
        let inv_sum: f64 = scores
            .iter()
            .zip(&observed)
            .map(|(&s, &o)| if o { 1.0 / s } else { 0.0 })
            .sum();
        let probs: Vec<f64> = scores
            .iter()
            .zip(&observed)
            .map(|(&s, &o)| if o && inv_sum > 0.0 { (1.0 / s) / inv_sum } else { 0.0 })
            .collect();
        let grads: Vec<f64> = (0..n).map(|_| rng.f64()).collect();
        for scheme in schemes {
            let sel = select_layers(scheme, delta, &scores, &observed, &probs, &grads, &mut rng);
            assert!(sel.len() <= delta.min(n), "seed {seed} {scheme:?}");
            let mut sorted = sel.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), sel.len(), "seed {seed} {scheme:?}: dupes");
            assert!(sel.iter().all(|&l| l < n), "seed {seed} {scheme:?}");
            // LUAR/deterministic never pick a never-observed layer
            if matches!(scheme, SelectionScheme::Luar | SelectionScheme::Deterministic)
                && observed.iter().any(|&o| o)
            {
                assert!(
                    sel.iter().all(|&l| observed[l]),
                    "seed {seed} {scheme:?}: picked unobserved layer"
                );
            }
        }
    }
}

// ---------------------------------------------------------------- LUAR state

#[test]
fn prop_compose_preserves_uploaded_layers_and_buffers_match() {
    for seed in 0..CASES {
        let mut rng = Rng::seed_from_u64(1000 + seed);
        let meta = rand_meta(&mut rng);
        let n = meta.num_layers();
        let d = meta.dim;
        let mut st = LuarState::new(n, d);
        // round 0: full upload
        let u0: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let mut buf = u0.clone();
        st.compose_update(&mut buf, &meta, RecycleMode::Recycle);
        // round 1: random recycle set
        let k = rng.gen_range(0, n + 1);
        st.recycle_set = rng.sample_indices(n, k);
        let u1: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let mut buf1 = u1.clone();
        let kappa = st.compose_update(&mut buf1, &meta, RecycleMode::Recycle);
        assert!((0.0..=1.0 + 1e-9).contains(&kappa), "seed {seed}: kappa {kappa}");
        for l in 0..n {
            let lm = &meta.layers[l];
            let r = lm.offset..lm.offset + lm.size;
            if st.staleness[l] > 0 {
                assert_eq!(&buf1[r.clone()], &u0[r], "seed {seed}: recycled layer {l} wrong");
            } else {
                assert_eq!(&buf1[r.clone()], &u1[r], "seed {seed}: uploaded layer {l} mangled");
            }
        }
        // buffer now holds the composed update exactly
        assert_eq!(st.prev_update, buf1, "seed {seed}");
    }
}

#[test]
fn prop_probabilities_are_distribution() {
    for seed in 0..CASES {
        let mut rng = Rng::seed_from_u64(2000 + seed);
        let n = rng.gen_range(1, 30);
        let mut st = LuarState::new(n, 8);
        let u: Vec<f32> = (0..n).map(|_| rng.f32() + 1e-4).collect();
        let w: Vec<f32> = (0..n).map(|_| rng.f32() + 1e-4).collect();
        st.update_scores(&u, &w);
        let p = st.probabilities();
        assert!((p.iter().sum::<f64>() - 1.0).abs() < 1e-9, "seed {seed}");
        assert!(p.iter().all(|&x| x >= 0.0), "seed {seed}");
        // lower score -> higher probability (monotone check on a pair)
        if n >= 2 {
            let (i, j) = (0, 1);
            let si = st.scores[i];
            let sj = st.scores[j];
            if si < sj {
                assert!(p[i] >= p[j], "seed {seed}: p not inverse-monotone");
            }
        }
    }
}

// ---------------------------------------------------------------- tensor

#[test]
fn prop_mean_rows_par_equals_serial() {
    for seed in 0..40 {
        let mut rng = Rng::seed_from_u64(3000 + seed);
        let a = rng.gen_range(1, 8);
        let d = rng.gen_range(1, 80_000);
        let rows: Vec<Vec<f32>> =
            (0..a).map(|_| (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect()).collect();
        let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
        let mut s = vec![0.0f32; d];
        let mut p = vec![0.0f32; d];
        tensor::mean_rows(&refs, &mut s);
        tensor::mean_rows_par(&refs, &mut p);
        for (i, (x, y)) in s.iter().zip(&p).enumerate() {
            assert!((x - y).abs() < 1e-5, "seed {seed} idx {i}: {x} vs {y}");
        }
    }
}

#[test]
fn prop_ssq_additive_over_partition() {
    for seed in 0..CASES {
        let mut rng = Rng::seed_from_u64(4000 + seed);
        let d = rng.gen_range(1, 500);
        let v: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let cut = rng.gen_range(0, d + 1);
        let total = tensor::ssq(&v);
        let parts = tensor::ssq(&v[..cut]) + tensor::ssq(&v[cut..]);
        assert!((total - parts).abs() < 1e-6 * total.max(1.0), "seed {seed}");
    }
}

// ---------------------------------------------------------------- comm

#[test]
fn prop_comm_ratio_bounded_by_upload_fraction() {
    for seed in 0..CASES {
        let mut rng = Rng::seed_from_u64(5000 + seed);
        let layers = rng.gen_range(1, 10);
        let sizes: Vec<u64> = (0..layers).map(|_| rng.gen_range(1, 100) as u64 * 4).collect();
        let full: u64 = sizes.iter().sum();
        let mut acc = CommAccountant::new(layers);
        let rounds = rng.gen_range(1, 20);
        for r in 0..rounds {
            let uploaded: Vec<(usize, u64)> = (0..layers)
                .filter(|_| rng.gen_bool(0.7))
                .map(|l| (l, sizes[l]))
                .collect();
            acc.record_round(4, &uploaded, full, full);
            let _ = r;
        }
        let ratio = acc.comm_ratio();
        assert!((0.0..=1.0 + 1e-12).contains(&ratio), "seed {seed}: ratio {ratio}");
        // frequencies in [0,1]
        assert!(acc
            .layer_frequencies()
            .iter()
            .all(|&f| (0.0..=1.0 + 1e-12).contains(&f)));
    }
}

// ---------------------------------------------------------------- wire codecs

/// All eight uplink frame flavors round-trip over randomized shapes,
/// seeds, and listed-layer subsets: dense / sparse / quantized /
/// sign-bit / low-rank / scalar / seeded-mask / bitmap. Exact payload
/// recovery (low-rank: bounded) and ledger bytes == summed
/// `frame.len()` — the byte-exact accounting invariant.
#[test]
fn prop_all_wire_flavors_roundtrip_with_exact_ledger() {
    for seed in 0..40u64 {
        let mut rng = Rng::seed_from_u64(7000 + seed);
        let meta = rand_meta_arrays(&mut rng);
        let n = meta.num_layers();
        let k = rng.gen_range(1, n + 1);
        let mut subset = rng.sample_indices(n, k);
        subset.sort_unstable();
        let all: Vec<usize> = (0..n).collect();
        let base: Vec<f32> = (0..meta.dim).map(|_| rng.normal_f32(0.0, 0.5)).collect();

        let masked = |u: &[f32], layers: &[usize]| -> Vec<f32> {
            let mut v = vec![0.0f32; meta.dim];
            for &l in layers {
                let lm = &meta.layers[l];
                v[lm.offset..lm.offset + lm.size]
                    .copy_from_slice(&u[lm.offset..lm.offset + lm.size]);
            }
            v
        };
        let decode_vec = |frame: &wire::WireFrame| -> Vec<f32> {
            match wire::decode_update(frame.as_bytes(), &meta).unwrap() {
                wire::Decoded::Vector(v) => v,
                wire::Decoded::Scalar(_) => panic!("seed {seed}: unexpected scalar"),
            }
        };
        let mut frames: Vec<(&'static str, wire::WireFrame)> = Vec::new();

        // 1. dense (LUAR partial uploads)
        let f = wire::encode_update(&base, &meta, &subset, &WireHint::Dense).unwrap();
        assert_eq!(decode_vec(&f), masked(&base, &subset), "seed {seed}: dense");
        frames.push(("dense", f));

        // 2. sparse (top-k / prune / dropout shapes)
        let sparse_u: Vec<f32> =
            base.iter().map(|&v| if rng.gen_bool(0.6) { 0.0 } else { v }).collect();
        let f = wire::encode_update(&sparse_u, &meta, &subset, &WireHint::Sparse).unwrap();
        assert_eq!(decode_vec(&f), masked(&sparse_u, &subset), "seed {seed}: sparse");
        frames.push(("sparse", f));

        // 3. quantized (FedPAQ grid points round-trip bit-exactly)
        let levels = [2u32, 4, 16, 256][rng.gen_range(0, 4)];
        let mut quant_u = base.clone();
        let mut q = Quantize::new(levels);
        q.compress(0, &mut quant_u, &meta, 0, &mut rng);
        let f = wire::encode_update(&quant_u, &meta, &subset, &q.wire_hint()).unwrap();
        assert_eq!(
            decode_vec(&f),
            masked(&quant_u, &subset),
            "seed {seed}: quantized levels={levels}"
        );
        frames.push(("quantized", f));

        // 4. sign bits (±alpha per layer)
        let mut sign_u = base.clone();
        let mut b = Binarize::new();
        b.compress(0, &mut sign_u, &meta, 0, &mut rng);
        let f = wire::encode_update(&sign_u, &meta, &subset, &b.wire_hint()).unwrap();
        assert_eq!(decode_vec(&f), masked(&sign_u, &subset), "seed {seed}: signbits");
        frames.push(("signbits", f));

        // 5. low rank (bounded reconstruction over factorable arrays)
        let mut lr_u = base.clone();
        let mut lr = LowRank::new(0.25);
        lr.compress(0, &mut lr_u, &meta, 0, &mut rng);
        let f = wire::encode_update(&lr_u, &meta, &all, &lr.wire_hint()).unwrap();
        let back = decode_vec(&f);
        let err: f64 =
            back.iter().zip(&lr_u).map(|(a, b)| ((a - b) as f64).powi(2)).sum::<f64>().sqrt();
        let norm: f64 = lr_u.iter().map(|&x| (x as f64).powi(2)).sum::<f64>().sqrt();
        assert!(
            err <= 1e-3 * norm.max(1e-9),
            "seed {seed}: lowrank err {err} vs norm {norm}"
        );
        frames.push(("lowrank", f));

        // 6. scalar (LBGM look-back coefficient)
        let coef = rng.f32();
        let f = wire::encode_update(&base, &meta, &all, &WireHint::Scalar { coef }).unwrap();
        assert_eq!(f.len(), wire::HEADER_LEN + 4, "seed {seed}: scalar frame size");
        match wire::decode_update(f.as_bytes(), &meta).unwrap() {
            wire::Decoded::Scalar(c) => {
                assert_eq!(c.to_bits(), coef.to_bits(), "seed {seed}: scalar")
            }
            wire::Decoded::Vector(_) => panic!("seed {seed}: expected scalar"),
        }
        frames.push(("scalar", f));

        // 7. seeded mask (FedDropoutAvg: mask regenerated server-side)
        let mut drop_u = base.clone();
        let mut dr = DropoutAvg::new(0.5);
        let client = rng.gen_range(0, 8);
        let round = rng.gen_range(0, 20);
        dr.compress(client, &mut drop_u, &meta, round, &mut rng);
        let f = wire::encode_update(&drop_u, &meta, &subset, &dr.wire_hint()).unwrap();
        assert_eq!(decode_vec(&f), masked(&drop_u, &subset), "seed {seed}: seeded mask");
        frames.push(("seeded_mask", f));

        // 8. bitmap (PruneFL: full-dim mask + kept values)
        let bitmap_u: Vec<f32> =
            base.iter().map(|&v| if rng.gen_bool(0.66) { 0.0 } else { v }).collect();
        let f = wire::encode_update(&bitmap_u, &meta, &all, &WireHint::Bitmap).unwrap();
        assert_eq!(decode_vec(&f), bitmap_u, "seed {seed}: bitmap");
        frames.push(("bitmap", f));

        // the downlink frame rides along: params + R_t id list
        let bf = wire::encode_broadcast(&base, &meta, &subset).unwrap();
        let (params, ids) = wire::decode_broadcast(bf.as_bytes(), &meta).unwrap();
        assert_eq!(params, base, "seed {seed}: broadcast params");
        assert_eq!(ids, subset, "seed {seed}: broadcast ids");

        // ledger bytes == summed frame.len(), flavor by flavor
        let mut acc = CommAccountant::new(n);
        let mut expected = 0u64;
        for (_, f) in &frames {
            acc.record_wire_round(1, &[], f.len() as u64, wire::dense_frame_len(&meta), 0);
            expected += f.len() as u64;
        }
        assert_eq!(
            acc.up_bytes, expected,
            "seed {seed}: ledger must equal summed wire-frame bytes"
        );
        for (name, f) in &frames {
            assert!(f.len() >= wire::HEADER_LEN, "seed {seed}: {name} under-sized");
        }
    }
}

/// `Flavor::Delta` uplink frames round-trip bit-exactly over
/// randomized shapes, layer subsets, reference gaps, and correlation
/// regimes; the frame is bounded by its self-contained baseline plus
/// the delta prefix and per-layer tags; a drifted reference is
/// rejected loudly.
#[test]
fn prop_delta_uplink_roundtrip_over_shapes_and_gaps() {
    for seed in 0..60u64 {
        let mut rng = Rng::seed_from_u64(8000 + seed);
        let meta = rand_meta(&mut rng);
        let n = meta.num_layers();
        let k = rng.gen_range(1, n + 1);
        let mut subset = rng.sample_indices(n, k);
        subset.sort_unstable();
        let reference: Vec<f32> = (0..meta.dim).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        // half the cases are round-over-round correlated (the regime
        // delta framing exists for), half are fresh draws
        let correlated = rng.gen_bool(0.5);
        let cur: Vec<f32> = reference
            .iter()
            .map(|&r| {
                if correlated {
                    r * (1.0 + 1e-3 * rng.normal_f32(0.0, 1.0))
                } else {
                    rng.normal_f32(0.0, 1.0)
                }
            })
            .collect();
        let gap = rng.gen_range(1, DELTA_MAX_REF_GAP as usize + 1) as u64;
        let version = 100u64;
        let f =
            wire::encode_update_delta(&cur, &meta, &subset, &reference, version - gap).unwrap();
        let self_len = wire::dense_subset_len(&meta, &subset);
        assert!(
            f.len() as u64 <= self_len + wire::DELTA_PREFIX_LEN as u64 + subset.len() as u64,
            "seed {seed}: delta frame {} vs bound {self_len}+",
            f.len()
        );
        let (back, rv) = wire::decode_update_delta(f.as_bytes(), &meta, &reference).unwrap();
        assert_eq!(rv, version - gap, "seed {seed}: reference version");
        for l in 0..n {
            let lm = &meta.layers[l];
            let r = lm.offset..lm.offset + lm.size;
            if subset.contains(&l) {
                let same = back[r.clone()]
                    .iter()
                    .zip(&cur[r.clone()])
                    .all(|(a, b)| a.to_bits() == b.to_bits());
                assert!(same, "seed {seed}: layer {l} not bit-exact");
            } else {
                assert!(back[r].iter().all(|&x| x == 0.0), "seed {seed}: layer {l} not zero");
            }
        }
        // corrupting the reference inside a coded layer must be caught
        let lm = &meta.layers[subset[0]];
        let mut drifted = reference.clone();
        drifted[lm.offset] += 1.0;
        assert!(
            wire::decode_update_delta(f.as_bytes(), &meta, &drifted).is_err(),
            "seed {seed}: drifted reference must be rejected"
        );
    }
}

/// Downlink `Flavor::Delta` frames carry the recycle-set ids and
/// reproduce the params bit-exactly against the matching reference.
#[test]
fn prop_delta_broadcast_roundtrip_over_shapes() {
    for seed in 0..60u64 {
        let mut rng = Rng::seed_from_u64(8500 + seed);
        let meta = rand_meta(&mut rng);
        let n = meta.num_layers();
        let k = rng.gen_range(0, n + 1);
        let mut recycle = rng.sample_indices(n, k);
        recycle.sort_unstable();
        let reference: Vec<f32> = (0..meta.dim).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let params: Vec<f32> =
            reference.iter().map(|&r| r * (1.0 + 1e-3 * rng.normal_f32(0.0, 1.0))).collect();
        let f = wire::encode_broadcast_delta(&params, &meta, &recycle, &reference, 7).unwrap();
        let (back, ids, rv) =
            wire::decode_broadcast_delta(f.as_bytes(), &meta, &reference).unwrap();
        assert_eq!(rv, 7, "seed {seed}");
        assert_eq!(ids, recycle, "seed {seed}: recycle ids");
        let same = back.iter().zip(&params).all(|(a, b)| a.to_bits() == b.to_bits());
        assert!(same, "seed {seed}: params not bit-exact");
        let self_len = wire::broadcast_frame_len(&meta, recycle.len());
        assert!(
            f.len() as u64 <= self_len + wire::DELTA_PREFIX_LEN as u64 + n as u64,
            "seed {seed}: delta broadcast {} vs bound {self_len}+",
            f.len()
        );
        // correlated broadcasts beat the self-contained baseline once
        // the model is big enough to amortize the 17-byte prefix
        if meta.dim >= 64 {
            assert!(
                (f.len() as u64) < self_len,
                "seed {seed}: correlated broadcast must save bytes"
            );
        }
    }
}

/// `DeltaFrameState` policy: first contact always falls back, a usable
/// reference within `DELTA_MAX_REF_GAP` engages, savings never exceed
/// the self-contained baseline, and `drain_round` zeroes the ledger.
#[test]
fn prop_delta_refstate_fallbacks_and_savings() {
    for seed in 0..60u64 {
        let mut rng = Rng::seed_from_u64(9000 + seed);
        // dim >= 64 so a correlated broadcast always amortizes the
        // delta prefix and the warm path reliably engages
        let meta = loop {
            let m = rand_meta(&mut rng);
            if m.dim >= 64 {
                break m;
            }
        };
        let clients = rng.gen_range(2, 6);
        let mut st = DeltaFrameState::new(clients);
        // uplink: no reference yet -> None; in-gap reference -> Some
        assert!(st.usable_up_ref_version(0, 5).is_none(), "seed {seed}: first contact");
        let u: Vec<f32> = (0..meta.dim).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        st.record_upload(0, 5, &u, &meta);
        assert_eq!(st.usable_up_ref_version(0, 5 + DELTA_MAX_REF_GAP), Some(5));
        assert!(
            st.usable_up_ref_version(0, 6 + DELTA_MAX_REF_GAP).is_none(),
            "seed {seed}: stale reference must not engage"
        );
        assert!(st.usable_up_ref_version(1, 5).is_none(), "seed {seed}: other client");
        // downlink: version 0 broadcast is everyone's first contact
        let p0: Vec<f32> = (0..meta.dim).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let self_len = wire::broadcast_frame_len(&meta, 0);
        st.note_bcast(0, &p0, &meta);
        for c in 0..clients {
            let len = st.bcast_ledger_len(c, 0, &meta, &[], self_len).unwrap();
            assert_eq!(len, self_len, "seed {seed}: first contact ships self-contained");
        }
        let (saved, fallbacks, gap) = st.drain_round();
        assert_eq!((saved, fallbacks), (0, clients as u64), "seed {seed}");
        assert_eq!(gap, 0.0, "seed {seed}");
        // next version: every client has the v0 reference
        let p1: Vec<f32> = p0.iter().map(|&x| x * (1.0 + 1e-3)).collect();
        st.note_bcast(1, &p1, &meta);
        let mut total = 0u64;
        for c in 0..clients {
            let len = st.bcast_ledger_len(c, 1, &meta, &[], self_len).unwrap();
            assert!(len <= self_len, "seed {seed}: ledger never exceeds baseline");
            total += len;
        }
        let (saved, fallbacks, gap) = st.drain_round();
        assert_eq!(fallbacks, 0, "seed {seed}: warm references must engage");
        assert_eq!(saved, clients as u64 * self_len - total, "seed {seed}: saved arithmetic");
        assert!(saved > 0, "seed {seed}: correlated broadcast saves bytes");
        assert_eq!(gap, 1.0, "seed {seed}: one-version reference gap");
        // drained: a second drain reports nothing
        assert_eq!(st.drain_round(), (0, 0, 0.0), "seed {seed}");
    }
}

// ---------------------------------------------------------------- sampler

/// Speed-sampler weights form a valid distribution over randomized
/// fleets: every weight finite and non-negative, the total exactly
/// sums to one, and a weighted draw over them is always a full cohort
/// — across cold, degenerate-zero-latency, and heavily-measured
/// telemetry mixes at every supported bias exponent.
#[test]
fn prop_sampler_weights_are_a_distribution() {
    for seed in 0..CASES {
        let mut rng = Rng::seed_from_u64(10_000 + seed);
        let n = rng.gen_range(1, 40);
        let mut stats = ClientStats::new(n);
        for c in 0..n {
            match rng.gen_range(0, 4) {
                0 => {} // never dispatched: weight comes from the fill value
                1 => stats.record_dispatch(c, 0.0, 0), // degenerate zero latency
                2 => stats.record_dispatch(c, rng.f64() * 1e6, rng.next_u64() % 1_000_000),
                _ => {
                    for _ in 0..rng.gen_range(1, 5) {
                        stats.record_dispatch(c, rng.f64() * 10.0, 1000);
                    }
                }
            }
        }
        let pow = [0.25, 0.5, 1.0, 2.0, 4.0][rng.gen_range(0, 5)];
        let w = speed_weights(&stats, pow);
        assert_eq!(w.len(), n, "seed {seed}");
        assert!(
            w.iter().all(|x| x.is_finite() && *x >= 0.0),
            "seed {seed}: non-finite or negative weight in {w:?}"
        );
        let total: f64 = w.iter().sum();
        assert!((total - 1.0).abs() < 1e-9, "seed {seed}: weights sum to {total}");
        let k = rng.gen_range(0, n + 1);
        let picks = rng.weighted_sample_without_replacement(&w, k);
        assert_eq!(picks.len(), k.min(n), "seed {seed}: short cohort");
        // an entirely cold table degrades to exactly uniform
        let cold = speed_weights(&ClientStats::new(n), pow);
        assert!(
            cold.iter().all(|x| *x == 1.0 / n as f64),
            "seed {seed}: cold table must be uniform, got {cold:?}"
        );
    }
}

/// The uniform sampler is the legacy cohort draw, bit for bit, across
/// randomized fleet shapes, run seeds, and rounds: the production
/// `FedDataset::sample_clients` stream equals an inline replication of
/// the seeded Fisher-Yates under the `0xc11e_0000` salt.
#[test]
fn prop_sampler_uniform_matches_legacy_cohort_draw() {
    for seed in 0..40u64 {
        let mut rng = Rng::seed_from_u64(11_000 + seed);
        let n = rng.gen_range(1, 30);
        let active = rng.gen_range(1, n + 1);
        let run_seed = rng.next_u64();
        let ds = FedDataset::new(SynthSpec::vision(8, 8, 1, 4), n, 8, 0.5, 16, 7);
        for round in 0..20usize {
            let legacy = ds.sample_clients(round, active, run_seed);
            let mut draw = Rng::seed_from_u64(run_seed ^ 0xc11e_0000 ^ round as u64);
            assert_eq!(
                draw.sample_indices(n, active),
                legacy,
                "seed {seed} round {round}: uniform draw must equal the legacy stream"
            );
        }
    }
}

/// Every sampler spec round-trips through its config string (the
/// checkpoint/config persistence path), and rejected specs stay
/// rejected.
#[test]
fn prop_sampler_spec_roundtrips() {
    for seed in 0..CASES {
        let mut rng = Rng::seed_from_u64(12_000 + seed);
        let cfg = match rng.gen_range(0, 3) {
            0 => SamplerCfg::Uniform,
            1 => SamplerCfg::Speed { pow: rng.f64() * 4.0 + 0.01 },
            _ => SamplerCfg::Staleness { cap: rng.next_u64() % 1000 },
        };
        // f64 Display is shortest-roundtrip, so equality is exact
        let parsed = SamplerCfg::parse(&cfg.spec_string()).unwrap();
        assert_eq!(cfg, parsed, "seed {seed}: {}", cfg.spec_string());
    }
}

// ---------------------------------------------------------------- faults

/// Every fault spec round-trips through its config string (the
/// checkpoint/config persistence path): randomized kinds,
/// probabilities, window lengths, and failure-policy knobs all come
/// back exactly (f64 Display is shortest-roundtrip).
#[test]
fn prop_fault_spec_roundtrips() {
    for seed in 0..CASES {
        let mut rng = Rng::seed_from_u64(13_000 + seed);
        let policy = FailurePolicy {
            max_retries: (rng.next_u64() % 8) as u32,
            backoff_s: rng.f64() * 4.0 + 0.01,
            timeout_s: rng.f64() * 60.0 + 0.1,
            quorum: rng.gen_range(1, 12),
        };
        let cfg = match rng.gen_range(0, 5) {
            // `off` carries no knobs, so only the default policy
            // round-trips — exactly what `parse("off")` produces
            0 => FaultsCfg::default(),
            1 => FaultsCfg { kind: FaultKind::Drop { p: rng.f64() * 0.999 }, policy },
            2 => FaultsCfg {
                kind: FaultKind::Outage {
                    p: rng.f64() * 0.999,
                    len_s: rng.f64() * 100.0 + 0.01,
                },
                policy,
            },
            3 => FaultsCfg { kind: FaultKind::Corrupt { p: rng.f64() * 0.999 }, policy },
            _ => FaultsCfg {
                kind: FaultKind::Mixed {
                    drop: rng.f64() * 0.33,
                    outage: rng.f64() * 0.33,
                    len_s: rng.f64() * 100.0 + 0.01,
                    corrupt: rng.f64() * 0.33,
                },
                policy,
            },
        };
        let parsed = FaultsCfg::parse(&cfg.spec_string()).unwrap();
        assert_eq!(cfg, parsed, "seed {seed}: {}", cfg.spec_string());
    }
}

/// Corruption-detector soundness across the wire surface: any single
/// byte flip — any position, any non-zero mask — of any sealed frame
/// flavor is rejected by the integrity trailer, and the unflipped
/// frame always passes with its body intact.
#[test]
fn prop_fault_trailer_detects_any_single_byte_flip() {
    for seed in 0..40u64 {
        let mut rng = Rng::seed_from_u64(14_000 + seed);
        let meta = rand_meta(&mut rng);
        let n = meta.num_layers();
        let k = rng.gen_range(1, n + 1);
        let mut subset = rng.sample_indices(n, k);
        subset.sort_unstable();
        let all: Vec<usize> = (0..n).collect();
        let base: Vec<f32> = (0..meta.dim).map(|_| rng.normal_f32(0.0, 0.5)).collect();
        let sparse_u: Vec<f32> =
            base.iter().map(|&v| if rng.gen_bool(0.6) { 0.0 } else { v }).collect();

        let mut frames: Vec<(&'static str, Vec<u8>)> = vec![
            (
                "dense",
                wire::encode_update(&base, &meta, &subset, &WireHint::Dense)
                    .unwrap()
                    .as_bytes()
                    .to_vec(),
            ),
            (
                "sparse",
                wire::encode_update(&sparse_u, &meta, &subset, &WireHint::Sparse)
                    .unwrap()
                    .as_bytes()
                    .to_vec(),
            ),
            (
                "bitmap",
                wire::encode_update(&sparse_u, &meta, &all, &WireHint::Bitmap)
                    .unwrap()
                    .as_bytes()
                    .to_vec(),
            ),
            (
                "scalar",
                wire::encode_update(&base, &meta, &all, &WireHint::Scalar { coef: rng.f32() })
                    .unwrap()
                    .as_bytes()
                    .to_vec(),
            ),
            (
                "broadcast",
                wire::encode_broadcast(&base, &meta, &subset).unwrap().as_bytes().to_vec(),
            ),
        ];
        for (name, frame) in &mut frames {
            let body_len = frame.len();
            wire::seal_trailer(frame);
            assert_eq!(frame.len(), body_len + wire::TRAILER_LEN, "seed {seed}: {name}");
            let body = wire::check_trailer(frame).unwrap();
            assert_eq!(body.len(), body_len, "seed {seed}: {name} body mangled");
            for _ in 0..50 {
                let pos = rng.gen_range(0, frame.len());
                let mask = rng.gen_range(1, 256) as u8;
                let mut bad = frame.clone();
                bad[pos] ^= mask;
                assert!(
                    wire::check_trailer(&bad).is_err(),
                    "seed {seed}: {name}: flip at byte {pos} (mask {mask:#04x}) slipped through"
                );
            }
            // truncation is caught too, not just flips
            assert!(
                wire::check_trailer(&frame[..frame.len() - 1]).is_err(),
                "seed {seed}: {name}: truncated frame slipped through"
            );
        }
    }
}

#[test]
fn prop_staleness_counts_consecutive_recycles() {
    for seed in 0..60 {
        let mut rng = Rng::seed_from_u64(6000 + seed);
        let meta = rand_meta(&mut rng);
        let n = meta.num_layers();
        let mut st = LuarState::new(n, meta.dim);
        let mut expected = vec![0u32; n];
        for _ in 0..10 {
            let k = rng.gen_range(0, n + 1);
            st.recycle_set = rng.sample_indices(n, k);
            for l in 0..n {
                if st.recycle_set.contains(&l) {
                    expected[l] += 1;
                } else {
                    expected[l] = 0;
                }
            }
            let mut buf: Vec<f32> = (0..meta.dim).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            st.compose_update(&mut buf, &meta, RecycleMode::Recycle);
            assert_eq!(st.staleness, expected, "seed {seed}");
        }
    }
}

// ------------------------------------------- stateful compressor replay

/// The per-client compressor state maps (Binarize error-feedback
/// residuals, LBGM anchors) are BTreeMap-keyed by client id (rule D1,
/// docs/lints.md). Pin the property that motivated the switch: two
/// same-seed replays of a multi-round schedule — with the cohort
/// *visited in reversed order* on the second replay of every round —
/// produce bit-identical compressed updates per (client, round), and
/// NaN-poisoned lanes never panic the orderings inside.
#[test]
fn prop_stateful_compressors_replay_bit_identical() {
    for seed in 0..24u64 {
        let mut mrng = Rng::seed_from_u64(9_000 + seed);
        let meta = rand_meta(&mut mrng);
        let clients: Vec<usize> = vec![7, 3, 11, 0, 5];

        // One deterministic update per (round, client), NaN in one
        // lane every third round to exercise the total_cmp paths.
        let updates: Vec<Vec<Vec<f32>>> = (0..6)
            .map(|round| {
                clients
                    .iter()
                    .map(|&c| {
                        let mut r = Rng::seed_from_u64(
                            seed * 1_000_003 + (round as u64) * 1_009 + c as u64,
                        );
                        let mut u: Vec<f32> =
                            (0..meta.dim).map(|_| r.normal_f32(0.0, 1.0)).collect();
                        if round % 3 == 0 {
                            u[round % meta.dim] = f32::NAN;
                        }
                        u
                    })
                    .collect()
            })
            .collect();

        let replay = |reverse_within_round: bool| -> Vec<Vec<u32>> {
            let mut bin = Binarize::new();
            let mut lbgm = Lbgm::new(0.5);
            let mut out = Vec::new();
            for (round, per_client) in updates.iter().enumerate() {
                let mut order: Vec<usize> = (0..clients.len()).collect();
                if reverse_within_round {
                    order.reverse();
                }
                let mut bits_by_slot: Vec<Vec<u32>> = vec![Vec::new(); clients.len()];
                for slot in order {
                    let cid = clients[slot];
                    let mut rng = Rng::seed_from_u64(seed * 31 + round as u64);
                    let mut b = per_client[slot].clone();
                    bin.compress(cid, &mut b, &meta, round, &mut rng);
                    let mut l = per_client[slot].clone();
                    lbgm.compress(cid, &mut l, &meta, round, &mut rng);
                    bits_by_slot[slot] =
                        b.iter().chain(l.iter()).map(|v| v.to_bits()).collect();
                }
                out.extend(bits_by_slot);
            }
            out
        };

        let forward = replay(false);
        let reversed = replay(true);
        assert_eq!(
            forward, reversed,
            "seed {seed}: per-client compressor state must not depend on cohort visit order"
        );
        for bits in &forward {
            assert!(!bits.is_empty(), "seed {seed}: every slot compressed");
        }
    }
}
