//! Property-based tests (in-tree harness; proptest is unavailable in
//! the offline build). Each property is checked over a seeded sweep of
//! randomized cases; failures print the offending seed so a case can
//! be replayed exactly.

use fedluar::comm::CommAccountant;
use fedluar::config::{RecycleMode, SelectionScheme};
use fedluar::luar::{select_layers, LuarState};
use fedluar::model::ModelMeta;
use fedluar::rng::Rng;
use fedluar::tensor;
use std::path::PathBuf;

const CASES: u64 = 200;

fn rand_meta(rng: &mut Rng) -> ModelMeta {
    let layers = rng.gen_range(1, 12);
    let mut rows = Vec::new();
    let mut off = 0usize;
    for l in 0..layers {
        let size = rng.gen_range(1, 64);
        rows.push(format!(
            r#"{{"name":"l{l}","kind":"dense","offset":{off},"size":{size},"arrays":[]}}"#
        ));
        off += size;
    }
    let doc = format!(
        r#"{{"model":"prop","dim":{off},"num_classes":3,
            "input_shape":[4],"input_dtype":"f32","tau":2,"batch":4,
            "eval_batch":8,"agg_clients":4,"momentum":0.9,
            "layers":[{}],
            "artifacts":{{"train":"t","eval":"e","agg":"g","init":"i"}},
            "init_sha256":"x"}}"#,
        rows.join(",")
    );
    let meta = ModelMeta::from_json(&doc, PathBuf::from("/tmp")).unwrap();
    meta.validate().unwrap();
    meta
}

// ---------------------------------------------------------------- sampling

#[test]
fn prop_weighted_sampling_is_distinct_and_in_range() {
    for seed in 0..CASES {
        let mut rng = Rng::seed_from_u64(seed);
        let n = rng.gen_range(1, 30);
        let k = rng.gen_range(0, n + 1);
        let w: Vec<f64> = (0..n).map(|_| rng.f64()).collect();
        let picks = rng.weighted_sample_without_replacement(&w, k);
        assert_eq!(picks.len(), k.min(n), "seed {seed}");
        let mut sorted = picks.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), picks.len(), "seed {seed}: duplicates");
        assert!(picks.iter().all(|&i| i < n), "seed {seed}: out of range");
    }
}

#[test]
fn prop_selection_schemes_return_valid_sets() {
    let schemes = [
        SelectionScheme::Luar,
        SelectionScheme::Random,
        SelectionScheme::Top,
        SelectionScheme::Bottom,
        SelectionScheme::GradNorm,
        SelectionScheme::Deterministic,
    ];
    for seed in 0..CASES {
        let mut rng = Rng::seed_from_u64(seed);
        let n = rng.gen_range(1, 20);
        let delta = rng.gen_range(0, n + 3); // may exceed n
        let scores: Vec<f64> = (0..n).map(|_| rng.f64() + 1e-6).collect();
        let observed: Vec<bool> = (0..n).map(|_| rng.gen_bool(0.8)).collect();
        let inv_sum: f64 = scores
            .iter()
            .zip(&observed)
            .map(|(&s, &o)| if o { 1.0 / s } else { 0.0 })
            .sum();
        let probs: Vec<f64> = scores
            .iter()
            .zip(&observed)
            .map(|(&s, &o)| if o && inv_sum > 0.0 { (1.0 / s) / inv_sum } else { 0.0 })
            .collect();
        let grads: Vec<f64> = (0..n).map(|_| rng.f64()).collect();
        for scheme in schemes {
            let sel = select_layers(scheme, delta, &scores, &observed, &probs, &grads, &mut rng);
            assert!(sel.len() <= delta.min(n), "seed {seed} {scheme:?}");
            let mut sorted = sel.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), sel.len(), "seed {seed} {scheme:?}: dupes");
            assert!(sel.iter().all(|&l| l < n), "seed {seed} {scheme:?}");
            // LUAR/deterministic never pick a never-observed layer
            if matches!(scheme, SelectionScheme::Luar | SelectionScheme::Deterministic)
                && observed.iter().any(|&o| o)
            {
                assert!(
                    sel.iter().all(|&l| observed[l]),
                    "seed {seed} {scheme:?}: picked unobserved layer"
                );
            }
        }
    }
}

// ---------------------------------------------------------------- LUAR state

#[test]
fn prop_compose_preserves_uploaded_layers_and_buffers_match() {
    for seed in 0..CASES {
        let mut rng = Rng::seed_from_u64(1000 + seed);
        let meta = rand_meta(&mut rng);
        let n = meta.num_layers();
        let d = meta.dim;
        let mut st = LuarState::new(n, d);
        // round 0: full upload
        let u0: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let mut buf = u0.clone();
        st.compose_update(&mut buf, &meta, RecycleMode::Recycle);
        // round 1: random recycle set
        let k = rng.gen_range(0, n + 1);
        st.recycle_set = rng.sample_indices(n, k);
        let u1: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let mut buf1 = u1.clone();
        let kappa = st.compose_update(&mut buf1, &meta, RecycleMode::Recycle);
        assert!((0.0..=1.0 + 1e-9).contains(&kappa), "seed {seed}: kappa {kappa}");
        for l in 0..n {
            let lm = &meta.layers[l];
            let r = lm.offset..lm.offset + lm.size;
            if st.staleness[l] > 0 {
                assert_eq!(&buf1[r.clone()], &u0[r], "seed {seed}: recycled layer {l} wrong");
            } else {
                assert_eq!(&buf1[r.clone()], &u1[r], "seed {seed}: uploaded layer {l} mangled");
            }
        }
        // buffer now holds the composed update exactly
        assert_eq!(st.prev_update, buf1, "seed {seed}");
    }
}

#[test]
fn prop_probabilities_are_distribution() {
    for seed in 0..CASES {
        let mut rng = Rng::seed_from_u64(2000 + seed);
        let n = rng.gen_range(1, 30);
        let mut st = LuarState::new(n, 8);
        let u: Vec<f32> = (0..n).map(|_| rng.f32() + 1e-4).collect();
        let w: Vec<f32> = (0..n).map(|_| rng.f32() + 1e-4).collect();
        st.update_scores(&u, &w);
        let p = st.probabilities();
        assert!((p.iter().sum::<f64>() - 1.0).abs() < 1e-9, "seed {seed}");
        assert!(p.iter().all(|&x| x >= 0.0), "seed {seed}");
        // lower score -> higher probability (monotone check on a pair)
        if n >= 2 {
            let (i, j) = (0, 1);
            let si = st.scores[i];
            let sj = st.scores[j];
            if si < sj {
                assert!(p[i] >= p[j], "seed {seed}: p not inverse-monotone");
            }
        }
    }
}

// ---------------------------------------------------------------- tensor

#[test]
fn prop_mean_rows_par_equals_serial() {
    for seed in 0..40 {
        let mut rng = Rng::seed_from_u64(3000 + seed);
        let a = rng.gen_range(1, 8);
        let d = rng.gen_range(1, 80_000);
        let rows: Vec<Vec<f32>> =
            (0..a).map(|_| (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect()).collect();
        let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
        let mut s = vec![0.0f32; d];
        let mut p = vec![0.0f32; d];
        tensor::mean_rows(&refs, &mut s);
        tensor::mean_rows_par(&refs, &mut p);
        for (i, (x, y)) in s.iter().zip(&p).enumerate() {
            assert!((x - y).abs() < 1e-5, "seed {seed} idx {i}: {x} vs {y}");
        }
    }
}

#[test]
fn prop_ssq_additive_over_partition() {
    for seed in 0..CASES {
        let mut rng = Rng::seed_from_u64(4000 + seed);
        let d = rng.gen_range(1, 500);
        let v: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let cut = rng.gen_range(0, d + 1);
        let total = tensor::ssq(&v);
        let parts = tensor::ssq(&v[..cut]) + tensor::ssq(&v[cut..]);
        assert!((total - parts).abs() < 1e-6 * total.max(1.0), "seed {seed}");
    }
}

// ---------------------------------------------------------------- comm

#[test]
fn prop_comm_ratio_bounded_by_upload_fraction() {
    for seed in 0..CASES {
        let mut rng = Rng::seed_from_u64(5000 + seed);
        let layers = rng.gen_range(1, 10);
        let sizes: Vec<u64> = (0..layers).map(|_| rng.gen_range(1, 100) as u64 * 4).collect();
        let full: u64 = sizes.iter().sum();
        let mut acc = CommAccountant::new(layers);
        let rounds = rng.gen_range(1, 20);
        for r in 0..rounds {
            let uploaded: Vec<(usize, u64)> = (0..layers)
                .filter(|_| rng.gen_bool(0.7))
                .map(|l| (l, sizes[l]))
                .collect();
            acc.record_round(4, &uploaded, full, full);
            let _ = r;
        }
        let ratio = acc.comm_ratio();
        assert!((0.0..=1.0 + 1e-12).contains(&ratio), "seed {seed}: ratio {ratio}");
        // frequencies in [0,1]
        assert!(acc
            .layer_frequencies()
            .iter()
            .all(|&f| (0.0..=1.0 + 1e-12).contains(&f)));
    }
}

#[test]
fn prop_staleness_counts_consecutive_recycles() {
    for seed in 0..60 {
        let mut rng = Rng::seed_from_u64(6000 + seed);
        let meta = rand_meta(&mut rng);
        let n = meta.num_layers();
        let mut st = LuarState::new(n, meta.dim);
        let mut expected = vec![0u32; n];
        for _ in 0..10 {
            let k = rng.gen_range(0, n + 1);
            st.recycle_set = rng.sample_indices(n, k);
            for l in 0..n {
                if st.recycle_set.contains(&l) {
                    expected[l] += 1;
                } else {
                    expected[l] = 0;
                }
            }
            let mut buf: Vec<f32> = (0..meta.dim).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            st.compose_update(&mut buf, &meta, RecycleMode::Recycle);
            assert_eq!(st.staleness, expected, "seed {seed}");
        }
    }
}
