//! Chaos suite: deterministic fault injection end to end.
//!
//! Everything here runs against the engine-free `SimServer` fixture
//! (which resolves the identical per-(client, version, attempt) fault
//! chains as `fl::Server`) except the checkpoint tests, which need the
//! real server and are artifact-gated like the rest of the heavy
//! suites. What the suite pins:
//!
//! * **off is free** — `faults = off` runs bit-identically to a
//!   fixture that never armed the fault path, sync and async;
//! * **chaos is reproducible** — a seeded `mixed` plan over 50 async
//!   versions completes, and two invocations agree bit-for-bit on the
//!   history, the telemetry table, and every fault counter;
//! * **the ledger reconciles** — retries pay real bytes (in drop mode,
//!   exactly `sealed_len × attempts`), orphaned bytes from permanently
//!   failed dispatches drain into the next aggregation, and the
//!   cumulative `up_bytes` equals the sum of per-round wire bytes;
//! * **corruption is never aggregated** — a corrupt-everything plan
//!   leaves the model untouched for the whole run;
//! * **quorum-degraded rounds recycle, not stall** — with every chain
//!   failing, rounds still advance clock/bytes/round-counter while the
//!   params and LUAR state stay put;
//! * **checkpoint v5** round-trips the fault-plan cursor, v4 refuses
//!   to drop it, and a truncated file fails atomically with a
//!   "truncated at field" error.

mod common;

use common::{
    assert_history_identical, bimodal_fleet, edge_fleet, have_artifacts, quick_cfg, SimServer,
    ACTIVE,
};
use fedluar::config::Method;
use fedluar::fl::Server;
use fedluar::net::{wire, FaultsCfg, RoundMode, Staleness};

fn async_mode() -> RoundMode {
    RoundMode::Async { concurrency: 4, staleness: Staleness::Poly { a: 0.5 } }
}

fn chaos() -> FaultsCfg {
    FaultsCfg::parse("mixed:drop=0.2,outage=0.15,len=5,corrupt=0.15,retries=2,backoff=0.5,timeout=3")
        .unwrap()
}

/// `faults = off` must leave the fault path unentered: no trailer, no
/// chains, bit-identical histories to a fixture that never heard of
/// fault injection — in both round modes.
#[test]
fn faults_off_is_bit_identical() {
    let off = FaultsCfg::parse("off").unwrap();
    let mut plain = SimServer::new(RoundMode::Sync, bimodal_fleet(), Some(2), 7);
    plain.run(12);
    let mut armed = SimServer::new(RoundMode::Sync, bimodal_fleet(), Some(2), 7).with_faults(off);
    armed.run(12);
    assert_history_identical(&plain.history, &armed.history, "sync faults=off");

    let mut plain = SimServer::new(async_mode(), edge_fleet(), Some(2), 7);
    plain.run(12);
    let mut armed = SimServer::new(async_mode(), edge_fleet(), Some(2), 7).with_faults(off);
    armed.run(12);
    assert_history_identical(&plain.history, &armed.history, "async faults=off");
}

/// The seeded chaos soak: 50 async versions under a `mixed` plan that
/// injects all three fault kinds. The run completes, every fault kind
/// actually fired, retries are visible in the plan and the per-client
/// telemetry, the cumulative ledger reconciles with the per-round wire
/// bytes — and a second invocation with the same seed agrees
/// bit-for-bit on all of it.
#[test]
fn seeded_chaos_soak_is_deterministic() {
    let run = |seed: u64| {
        let mut s = SimServer::new(async_mode(), edge_fleet(), Some(2), seed).with_faults(chaos());
        s.run(50);
        s
    };
    let a = run(21);
    let b = run(21);
    assert_eq!(a.round, 50, "chaos run must complete");
    assert_history_identical(&a.history, &b.history, "same-seed chaos");
    assert_eq!(a.faults, b.faults, "fault cursor/counters must replay exactly");
    assert_eq!(a.sampler_stats, b.sampler_stats, "telemetry must replay exactly");

    let plan = a.faults.as_ref().unwrap();
    assert!(plan.drops > 0, "mixed plan never dropped");
    assert!(plan.outages > 0, "mixed plan never cut a link");
    assert!(plan.corrupts > 0, "mixed plan never corrupted");
    assert!(plan.retries > 0, "no retry ever fired");
    assert_eq!(
        a.sampler_stats.retries.iter().sum::<u64>(),
        plan.retries,
        "per-client retry telemetry must reconcile with the plan"
    );
    assert!(plan.perm_failures > 0, "soak should exhaust some retry budgets");
    assert_eq!(
        a.sampler_stats.failures.iter().sum::<u64>(),
        plan.perm_failures,
        "per-client failure telemetry must reconcile with the plan"
    );
    // the cumulative uplink ledger is exactly the sum of what each
    // aggregation booked (orphans included, because they drain into
    // the next close)
    let wire_sum: u64 = a.history.records.iter().map(|r| r.wire_bytes).sum();
    assert_eq!(a.history.records.last().unwrap().up_bytes, wire_sum);
}

/// Drop-mode byte accounting is exact: every attempt (first try,
/// retry, or permanently failed) transmits the sealed self-contained
/// frame, so the cumulative uplink ledger is `sealed_len` times the
/// total attempt count — and the retry surcharge lands in the separate
/// telemetry columns, never in the first-attempt averages.
#[test]
fn sync_retries_pay_exact_bytes() {
    let cfg = FaultsCfg::parse("drop:p=0.25,retries=3,backoff=0.5,timeout=4").unwrap();
    let mut s = SimServer::new(RoundMode::Sync, edge_fleet(), None, 11).with_faults(cfg);
    s.run(10);
    let plan = s.faults.as_ref().unwrap();
    assert!(plan.retries > 0, "p=0.25 over 80 dispatches must retry");
    let sealed_len = wire::dense_frame_len(&s.meta) + wire::TRAILER_LEN as u64;
    let dispatches = 10 * ACTIVE as u64;
    assert_eq!(
        s.comm.up_bytes,
        sealed_len * (dispatches + plan.retries),
        "drop mode: every attempt pays one sealed frame"
    );
    assert_eq!(s.sampler_stats.up_bytes.iter().sum::<u64>(), sealed_len * dispatches);
    assert_eq!(s.sampler_stats.retry_bytes.iter().sum::<u64>(), sealed_len * plan.retries);
}

/// Async orphan accounting: with retries off, every delivered chain
/// books one sealed frame when its aggregation closes, every failed
/// chain orphans one sealed frame that drains into the next close —
/// so ledger + undrained orphans = sealed_len × (absorbed + failed).
#[test]
fn async_orphan_bytes_drain_into_the_ledger() {
    let cfg = FaultsCfg::parse("drop:p=0.3,retries=0,timeout=5").unwrap();
    let mut s = SimServer::new(async_mode(), edge_fleet(), None, 5).with_faults(cfg);
    s.run(20);
    let plan = s.faults.as_ref().unwrap();
    assert!(plan.perm_failures > 0, "p=0.3 with no retries must fail some dispatches");
    let sealed_len = wire::dense_frame_len(&s.meta) + wire::TRAILER_LEN as u64;
    let absorbed: u64 = s.sampler_stats.absorbed.iter().sum();
    assert_eq!(
        s.comm.up_bytes + plan.orphan_up_bytes,
        sealed_len * (absorbed + plan.perm_failures),
        "every transmitted frame must land in the ledger or the orphan buffer"
    );
}

/// A corrupt-everything plan: the integrity trailer catches every
/// flipped frame at decode, so nothing is ever aggregated and the
/// model never moves — yet the run completes and pays for the bytes.
#[test]
fn corrupted_frames_are_never_aggregated() {
    let cfg = FaultsCfg::parse("corrupt:p=0.999999999999,retries=0").unwrap();
    let mut s = SimServer::new(RoundMode::Sync, edge_fleet(), None, 9).with_faults(cfg);
    s.run(4);
    assert_eq!(s.round, 4, "all-corrupt run must still terminate");
    let plan = s.faults.as_ref().unwrap();
    assert_eq!(plan.corrupts, 4 * ACTIVE as u64, "every upload must be corrupted");
    assert_eq!(plan.perm_failures, 4 * ACTIVE as u64);
    assert!(s.params.iter().all(|&p| p == 0.0), "a corrupted update reached the model");
    assert!(s.comm.up_bytes > 0, "corrupted frames still crossed the wire");
    for r in &s.history.records {
        assert_eq!(r.arrivals, 0, "round {}: no corrupt frame may count as an arrival", r.round);
    }
}

/// Every chain fails: rounds close quorum-degraded with zero
/// survivors, the model and LUAR selection stay exactly as they were,
/// but the clock, the byte ledger, and the round counter all advance —
/// the server recycles, it does not stall or crash.
#[test]
fn zero_survivor_rounds_advance_without_touching_the_model() {
    let cfg =
        FaultsCfg::parse("drop:p=0.999999999999,retries=1,backoff=1,timeout=5,quorum=4").unwrap();
    let mut s = SimServer::new(RoundMode::Sync, edge_fleet(), Some(2), 3).with_faults(cfg);
    let recycle_before = s.luar.recycle_set.clone();
    s.run(6);
    assert_eq!(s.round, 6, "degraded rounds must still advance the schedule");
    let plan = s.faults.as_ref().unwrap();
    assert_eq!(plan.quorum_degraded, 6, "every round closed below quorum");
    assert_eq!(plan.perm_failures, 6 * ACTIVE as u64);
    assert_eq!(plan.retries, 6 * ACTIVE as u64, "one retry per dispatch");
    assert!(s.params.iter().all(|&p| p == 0.0), "no survivors, yet the model moved");
    assert_eq!(s.luar.recycle_set, recycle_before, "LUAR selection must not churn");
    assert!(s.sim_seconds > 0.0, "timeouts and backoffs must cost simulated clock");
    assert_eq!(s.history.records.len(), 6);
    for r in &s.history.records {
        assert_eq!(r.arrivals, 0);
        assert_eq!(r.kappa, 0.0);
        assert!(r.wire_bytes > 0, "dropped frames still paid uplink bytes");
    }
}

/// A moderate drop rate with a full-cohort quorum: most rounds close
/// degraded (fewer than 8 survivors) but still aggregate what arrived,
/// so the model learns from the survivors.
#[test]
fn partial_quorum_aggregates_survivors() {
    let cfg = FaultsCfg::parse("drop:p=0.4,retries=0,timeout=5,quorum=8").unwrap();
    let mut s = SimServer::new(RoundMode::Sync, edge_fleet(), Some(2), 17).with_faults(cfg);
    s.run(10);
    let plan = s.faults.as_ref().unwrap();
    assert!(plan.quorum_degraded > 0, "p=0.4 under quorum=8 must degrade some rounds");
    assert!(plan.perm_failures > 0);
    assert!(
        s.params.iter().any(|&p| p != 0.0),
        "surviving uploads must still be aggregated"
    );
    let survived_rounds =
        s.history.records.iter().filter(|r| r.arrivals > 0).count();
    assert!(survived_rounds > 0, "some rounds must have closed with survivors");
}

// ---------------------------------------------------------------------
// real-server checkpoint tests (artifact-gated)
// ---------------------------------------------------------------------

fn faulted_cfg(rounds: usize) -> fedluar::config::RunConfig {
    let mut cfg = quick_cfg(Method::luar(2));
    cfg.rounds = rounds;
    cfg.net.faults = FaultsCfg::parse(
        "mixed:drop=0.15,outage=0.05,len=3,corrupt=0.05,retries=2,backoff=0.25,timeout=2",
    )
    .unwrap();
    cfg
}

/// Checkpoint v5 carries the fault-plan cursor (outage windows,
/// counters, orphan bytes) and the retry telemetry: a run interrupted
/// mid-chaos and resumed is bit-identical to the uninterrupted one.
#[test]
fn checkpoint_v5_roundtrips_fault_state() {
    if !have_artifacts() {
        return;
    }
    let mut full = Server::new(faulted_cfg(8)).unwrap();
    full.run().unwrap();

    let mut first = Server::new(faulted_cfg(4)).unwrap();
    first.run().unwrap();
    let path = std::env::temp_dir().join("fedluar_ckpt_faults.bin");
    first.save_checkpoint(&path).unwrap();

    let mut resumed = Server::new(faulted_cfg(8)).unwrap();
    resumed.load_checkpoint(&path).unwrap();
    assert_eq!(resumed.round, 4);
    assert_eq!(resumed.faults, first.faults, "fault cursor must survive the round-trip");
    assert_eq!(resumed.sampler_stats, first.sampler_stats, "retry telemetry must round-trip");
    resumed.run().unwrap();
    assert_eq!(resumed.comm.up_bytes, full.comm.up_bytes, "resumed ledger diverged");
    assert_eq!(resumed.faults, full.faults, "resumed fault stream diverged");
    let (xa, ..) = resumed.opt.snapshot();
    let (xb, ..) = full.opt.snapshot();
    assert_eq!(xa, xb, "resumed params diverged from straight-through chaos run");
}

/// Older formats cannot carry the fault state, and say so instead of
/// silently dropping it.
#[test]
fn checkpoint_v4_refuses_fault_state() {
    if !have_artifacts() {
        return;
    }
    let mut s = Server::new(faulted_cfg(2)).unwrap();
    s.run().unwrap();
    let path = std::env::temp_dir().join("fedluar_ckpt_faults_v4.bin");
    let err = s.save_checkpoint_as(&path, 4).unwrap_err().to_string();
    assert!(
        err.contains("cannot carry fault-injection state"),
        "unexpected error: {err}"
    );
}

/// Progressive truncation: every proper prefix of a real checkpoint
/// fails to load with a "truncated at field" error naming the field,
/// and — loading being parse-then-apply — leaves the server exactly
/// as it was. The intact file still loads afterwards.
#[test]
fn truncated_checkpoint_fails_atomically() {
    if !have_artifacts() {
        return;
    }
    let mut first = Server::new(faulted_cfg(2)).unwrap();
    first.run().unwrap();
    let path = std::env::temp_dir().join("fedluar_ckpt_trunc.bin");
    first.save_checkpoint(&path).unwrap();
    let bytes = std::fs::read(&path).unwrap();

    let mut resumed = Server::new(faulted_cfg(2)).unwrap();
    let params_before: Vec<f32> = resumed.opt.snapshot().0.to_vec();
    let tpath = std::env::temp_dir().join("fedluar_ckpt_trunc_cut.bin");
    // ~200 evenly spaced cuts plus the edges; every one must fail with
    // the truncation error and leave the server untouched
    let step = (bytes.len() / 200).max(1);
    let cuts: Vec<usize> =
        (0..bytes.len()).step_by(step).chain([1, 3, bytes.len() - 1]).collect();
    for cut in cuts {
        std::fs::write(&tpath, &bytes[..cut]).unwrap();
        let err = resumed.load_checkpoint(&tpath).unwrap_err().to_string();
        assert!(
            err.contains("truncated at field `"),
            "cut={cut}: expected a field-naming truncation error, got: {err}"
        );
        assert_eq!(resumed.round, 0, "cut={cut}: partial state was applied");
    }
    let params_after: Vec<f32> = resumed.opt.snapshot().0.to_vec();
    assert_eq!(params_before, params_after, "a failed load must not touch the params");

    resumed.load_checkpoint(&path).unwrap();
    assert_eq!(resumed.round, 2, "the intact checkpoint must still load");
}
