//! Network-subsystem integration: the full communication pipeline —
//! compressor → wire codec → heterogeneous links → event-driven
//! scheduler → comm ledger → LUAR composition — with no PJRT/artifact
//! dependency (client deltas are synthetic; everything the net layer
//! touches is real).
//!
//! Pins the acceptance invariants:
//! * FedAvg and FedLUAR rounds complete in all three round modes;
//! * the ledger's upload bytes equal the independently summed wire
//!   frame lengths (byte-exact accounting, no truncating casts);
//! * sync-mode wall-clock equals the slowest active client's time
//!   (the mean-upload timing bug stays dead);
//! * the broadcast side includes the delta layer-id list bytes.

use fedluar::comm::CommAccountant;
use fedluar::compress::{Quantize, UpdateCompressor};
use fedluar::config::{RecycleMode, SelectionScheme};
use fedluar::luar::LuarState;
use fedluar::model::ModelMeta;
use fedluar::net::{wire, LinkDist, NetCfg, NetSim, RoundMode};
use fedluar::rng::Rng;
use fedluar::tensor;
use std::path::PathBuf;

const LAYERS: usize = 6;
const LAYER_SIZE: usize = 512;

/// 6-layer synthetic model (8x64 matrices), no artifacts needed.
fn synth_meta() -> ModelMeta {
    let mut rows = Vec::new();
    for l in 0..LAYERS {
        let off = l * LAYER_SIZE;
        rows.push(format!(
            r#"{{"name":"l{l}","kind":"dense","offset":{off},"size":{LAYER_SIZE},
               "arrays":[{{"name":"w","shape":[8,64],"offset":{off},"size":{LAYER_SIZE}}}]}}"#
        ));
    }
    let dim = LAYERS * LAYER_SIZE;
    let doc = format!(
        r#"{{"model":"netsim","dim":{dim},"num_classes":10,
            "input_shape":[8],"input_dtype":"f32","tau":5,"batch":16,
            "eval_batch":64,"agg_clients":8,"momentum":0.9,
            "layers":[{}],
            "artifacts":{{"train":"t","eval":"e","agg":"g","init":"i"}},
            "init_sha256":"x"}}"#,
        rows.join(",")
    );
    ModelMeta::from_json(&doc, PathBuf::from("/tmp")).unwrap()
}

struct CommRun {
    acc: CommAccountant,
    /// Independently collected frame lengths, all rounds all clients.
    frame_lens_total: u64,
    sim_seconds: f64,
    aggregated_min: usize,
    rounds: usize,
}

/// Drive `rounds` communication rounds of the net pipeline for either
/// FedAvg (luar = false) or FedLUAR delta=2 (luar = true), optionally
/// composing a FedPAQ quantizer on the uploaded layers.
fn run_comm_rounds(
    luar: bool,
    quantize: bool,
    mode: RoundMode,
    dist: LinkDist,
    rounds: usize,
) -> CommRun {
    let meta = synth_meta();
    let num_clients = 16usize;
    let active = 8usize;
    let sim = NetSim::new(
        NetCfg { link_dist: dist, round_mode: mode, compute_s: 0.1, ..NetCfg::default() },
        num_clients,
        42,
    );
    let mut acc = CommAccountant::new(meta.num_layers());
    let mut luar_state = LuarState::new(meta.num_layers(), meta.dim);
    let mut compressor = Quantize::new(16);
    let mut rng = Rng::seed_from_u64(7);
    let mut frame_lens_total = 0u64;
    let mut sim_seconds = 0.0f64;
    let mut aggregated_min = usize::MAX;

    for t in 0..rounds {
        let actives: Vec<usize> = (0..active).map(|i| (t * active + i) % num_clients).collect();
        let upload_layers: Vec<usize> = if luar {
            luar_state.upload_set(meta.num_layers())
        } else {
            (0..meta.num_layers()).collect()
        };
        let params = vec![0.1f32; meta.dim];
        let bcast = wire::encode_broadcast(&params, &meta, &luar_state.recycle_set).unwrap();

        let mut deltas: Vec<Vec<f32>> = Vec::new();
        let mut frame_lens: Vec<u64> = Vec::new();
        let mut up_total = 0u64;
        for &client in &actives {
            let mut delta: Vec<f32> =
                (0..meta.dim).map(|_| rng.normal_f32(0.0, 0.05)).collect();
            for &l in &luar_state.recycle_set {
                let lm = &meta.layers[l];
                delta[lm.offset..lm.offset + lm.size].iter_mut().for_each(|v| *v = 0.0);
            }
            let hint = if quantize {
                compressor.compress(client, &mut delta, &meta, t, &mut rng);
                for &l in &luar_state.recycle_set {
                    let lm = &meta.layers[l];
                    delta[lm.offset..lm.offset + lm.size].iter_mut().for_each(|v| *v = 0.0);
                }
                compressor.wire_hint()
            } else {
                wire::WireHint::Dense
            };
            let frame = wire::encode_update(&delta, &meta, &upload_layers, &hint).unwrap();
            let decoded = match wire::decode_update(frame.as_bytes(), &meta).unwrap() {
                wire::Decoded::Vector(v) => v,
                wire::Decoded::Scalar(_) => unreachable!("no scalar flavors here"),
            };
            assert_eq!(decoded, delta, "codec round-trip must be exact for this flavor");
            up_total += frame.len() as u64;
            frame_lens_total += frame.len() as u64;
            frame_lens.push(frame.len() as u64);
            deltas.push(decoded);
        }

        let outcome = sim.round(&actives, bcast.len() as u64, &frame_lens);
        sim_seconds += outcome.round_secs;
        aggregated_min = aggregated_min.min(outcome.aggregated);

        // aggregate the survivors (weighted for buffered staleness)
        let mut refs: Vec<&[f32]> = Vec::new();
        let mut ws: Vec<f32> = Vec::new();
        for (slot, d) in deltas.iter().enumerate() {
            if outcome.included[slot] {
                refs.push(d.as_slice());
                ws.push(outcome.weights[slot]);
            }
        }
        assert!(!refs.is_empty(), "round must never aggregate zero clients");
        let wsum: f32 = ws.iter().sum();
        let norm: Vec<f32> = ws.iter().map(|w| w / wsum).collect();
        let mut mean = vec![0.0f32; meta.dim];
        tensor::weighted_mean_rows(&refs, &norm, &mut mean);

        if luar {
            let u_ssq: Vec<f32> = meta
                .layers
                .iter()
                .map(|lm| tensor::ssq(&mean[lm.offset..lm.offset + lm.size]) as f32)
                .collect();
            let w_ssq = vec![1.0f32; meta.num_layers()];
            luar_state.update_scores(&u_ssq, &w_ssq);
            luar_state.compose_update(&mut mean, &meta, RecycleMode::Recycle);
            let grad_norms: Vec<f64> =
                u_ssq.iter().map(|&s| (s as f64).max(0.0).sqrt()).collect();
            luar_state.select_next(SelectionScheme::Luar, 2, &grad_norms, &mut rng);
        }

        acc.record_wire_round(
            actives.len() as u64,
            &upload_layers,
            up_total,
            wire::dense_frame_len(&meta),
            (actives.len() as u64) * bcast.len() as u64,
        );
    }
    CommRun { acc, frame_lens_total, sim_seconds, aggregated_min, rounds }
}

fn all_modes() -> [RoundMode; 3] {
    [
        RoundMode::Sync,
        RoundMode::Deadline { deadline_s: 2.0 },
        RoundMode::Buffered { k: 3 },
    ]
}

#[test]
fn fedavg_completes_in_all_round_modes_with_exact_ledger() {
    for mode in all_modes() {
        let run = run_comm_rounds(false, false, mode, LinkDist::default(), 10);
        assert_eq!(run.acc.rounds as usize, run.rounds, "{mode:?}");
        assert_eq!(
            run.acc.up_bytes, run.frame_lens_total,
            "{mode:?}: ledger must equal summed wire-frame bytes"
        );
        // dense frames == the measured FedAvg baseline, so Comm == 1
        assert!(
            (run.acc.comm_ratio() - 1.0).abs() < 1e-12,
            "{mode:?}: FedAvg measured ratio {}",
            run.acc.comm_ratio()
        );
        assert!(run.sim_seconds > 0.0);
    }
}

#[test]
fn fedluar_completes_in_all_round_modes_and_reduces_comm() {
    for mode in all_modes() {
        let run = run_comm_rounds(true, false, mode, LinkDist::default(), 10);
        assert_eq!(run.acc.up_bytes, run.frame_lens_total, "{mode:?}");
        let ratio = run.acc.comm_ratio();
        assert!(ratio < 0.95, "{mode:?}: LUAR must reduce measured comm, got {ratio}");
        assert!(ratio > 0.05, "{mode:?}: ratio suspiciously low {ratio}");
        // Figure 3 bookkeeping intact: some layer skipped some round
        assert!(run.acc.layer_frequencies().iter().any(|&f| f < 1.0), "{mode:?}");
    }
}

#[test]
fn luar_quantize_composition_has_no_truncation() {
    // Regression for the per-client `as u64` truncation: with measured
    // frames the ledger equals the byte-exact sum, every round, and
    // the composition is cheaper than LUAR alone.
    let comp = run_comm_rounds(true, true, RoundMode::Sync, LinkDist::default(), 10);
    assert_eq!(comp.acc.up_bytes, comp.frame_lens_total);
    let plain = run_comm_rounds(true, false, RoundMode::Sync, LinkDist::default(), 10);
    assert!(
        comp.acc.up_bytes < plain.acc.up_bytes,
        "quantized composition {} !< plain {}",
        comp.acc.up_bytes,
        plain.acc.up_bytes
    );
}

#[test]
fn sync_wall_clock_is_slowest_active_client() {
    // Heterogeneous fleet where the mean-vs-max distinction is stark.
    let dist = LinkDist::Bimodal {
        fast_frac: 0.5,
        fast_up_mbps: 100.0,
        slow_up_mbps: 1.0,
        down_mbps: 100.0,
        rtt_s: 0.0,
    };
    let meta = synth_meta();
    let sim = NetSim::new(
        NetCfg { link_dist: dist, round_mode: RoundMode::Sync, compute_s: 0.0, ..NetCfg::default() },
        16,
        42,
    );
    let actives: Vec<usize> = (0..8).collect();
    let frame = wire::dense_frame_len(&meta);
    let frames = vec![frame; 8];
    let bcast = frame + 64;
    let outcome = sim.round(&actives, bcast, &frames);
    let per_client: Vec<f64> =
        actives.iter().map(|&c| sim.client_secs(c, bcast, frame)).collect();
    let slowest = per_client.iter().cloned().fold(0.0f64, f64::max);
    let fastest = per_client.iter().cloned().fold(f64::INFINITY, f64::min);
    let mean = per_client.iter().sum::<f64>() / per_client.len() as f64;
    assert_eq!(outcome.round_secs, slowest, "sync round must wait for the slowest client");
    if fastest < slowest {
        // both cohorts present: the old mean-upload shortcut would
        // have under-reported the round
        assert!(
            outcome.round_secs > mean,
            "regression: round time {} fell to the mean {mean}",
            outcome.round_secs
        );
    }
}

#[test]
fn deadline_mode_drops_stragglers_but_never_everyone() {
    let dist = LinkDist::Bimodal {
        fast_frac: 0.5,
        fast_up_mbps: 100.0,
        slow_up_mbps: 0.05,
        down_mbps: 100.0,
        rtt_s: 0.0,
    };
    let run = run_comm_rounds(false, false, RoundMode::Deadline { deadline_s: 0.5 }, dist, 10);
    assert!(run.aggregated_min >= 1);
    assert!(
        run.aggregated_min < 8,
        "slow cohort should miss a 0.5s deadline at 0.05 Mbps"
    );
    // dropped clients still paid their bytes
    assert_eq!(run.acc.up_bytes, run.frame_lens_total);
}

#[test]
fn broadcast_ledger_includes_delta_layer_id_bytes() {
    let meta = synth_meta();
    let params = vec![0.0f32; meta.dim];
    let plain = wire::encode_broadcast(&params, &meta, &[]).unwrap();
    let with_rt = wire::encode_broadcast(&params, &meta, &[1, 4]).unwrap();
    assert_eq!(with_rt.len(), plain.len() + 2 * 2);

    let mut acc = CommAccountant::new(meta.num_layers());
    acc.record_wire_round(4, &[0, 2, 3, 5], 1000, 2000, 4 * with_rt.len() as u64);
    assert_eq!(acc.down_bytes, 4 * with_rt.len() as u64);
    assert!(acc.down_bytes > 4 * (meta.dim as u64 * 4), "header + id list must be counted");
}
