//! FL-loop integration: real artifacts + the full Algorithm 2 round
//! loop, pinning the system-level invariants the paper relies on.
//! Uses the small MLP benchmark (sub-second rounds); skips when
//! artifacts are missing.

use fedluar::config::{
    ClientOptCfg, Method, RecycleMode, RunConfig, SelectionScheme, ServerOptCfg,
};
use fedluar::fl::Server;
use fedluar::model::{artifacts_dir, ModelMeta};

fn have_artifacts() -> bool {
    if ModelMeta::load(artifacts_dir(), "mlp").is_ok() {
        true
    } else {
        eprintln!("SKIP: run `make artifacts`");
        false
    }
}

fn quick_cfg(method: Method) -> RunConfig {
    let mut cfg = RunConfig::benchmark("mlp").unwrap();
    cfg.num_clients = 24;
    cfg.active_clients = 6;
    cfg.per_client = 64;
    cfg.test_size = 256;
    cfg.rounds = 8;
    cfg.eval_every = 4;
    cfg.method = method;
    cfg
}

#[test]
fn fedavg_learns_and_counts_full_comm() {
    if !have_artifacts() {
        return;
    }
    let mut s = Server::new(quick_cfg(Method::FedAvg)).unwrap();
    s.run().unwrap();
    assert!((s.comm.comm_ratio() - 1.0).abs() < 1e-9, "FedAvg comm must be 1.0");
    assert!(s.history.final_acc() > 0.25, "acc {}", s.history.final_acc());
    assert_eq!(s.comm.rounds, 8);
    // every layer uploaded every round
    assert!(s.comm.layer_frequencies().iter().all(|&f| (f - 1.0).abs() < 1e-9));
}

#[test]
fn fedluar_reduces_comm_and_still_learns() {
    if !have_artifacts() {
        return;
    }
    let mut s = Server::new(quick_cfg(Method::luar(2))).unwrap();
    s.run().unwrap();
    let ratio = s.comm.comm_ratio();
    assert!(ratio < 0.95, "LUAR must reduce comm, got {ratio}");
    assert!(ratio > 0.05, "comm ratio suspiciously low: {ratio}");
    assert!(s.history.final_acc() > 0.2);
    // some layer was recycled at least once
    let freqs = s.comm.layer_frequencies();
    assert!(freqs.iter().any(|&f| f < 1.0), "no layer ever recycled: {freqs:?}");
}

#[test]
fn fedluar_runs_are_deterministic() {
    if !have_artifacts() {
        return;
    }
    let mut a = Server::new(quick_cfg(Method::luar(2))).unwrap();
    a.run().unwrap();
    let mut b = Server::new(quick_cfg(Method::luar(2))).unwrap();
    b.run().unwrap();
    assert_eq!(a.history.records.len(), b.history.records.len());
    for (ra, rb) in a.history.records.iter().zip(&b.history.records) {
        assert_eq!(ra.test_acc, rb.test_acc, "round {} acc differs", ra.round);
        assert_eq!(ra.up_bytes, rb.up_bytes);
    }
}

#[test]
fn kappa_logged_only_for_luar() {
    if !have_artifacts() {
        return;
    }
    let mut avg = Server::new(quick_cfg(Method::FedAvg)).unwrap();
    avg.run().unwrap();
    assert_eq!(avg.history.max_kappa(), 0.0);
    let mut luar = Server::new(quick_cfg(Method::luar(2))).unwrap();
    luar.run().unwrap();
    assert!(luar.history.max_kappa() > 0.0);
    assert!(luar.history.max_kappa() <= 1.0);
}

#[test]
fn drop_mode_has_same_comm_as_recycle() {
    if !have_artifacts() {
        return;
    }
    let mk = |mode| Method::Luar { delta: 2, scheme: SelectionScheme::Luar, mode, adaptive: false };
    let mut rec = Server::new(quick_cfg(mk(RecycleMode::Recycle))).unwrap();
    rec.run().unwrap();
    let mut drop = Server::new(quick_cfg(mk(RecycleMode::Drop))).unwrap();
    drop.run().unwrap();
    // identical seeds -> identical selection -> identical bytes
    assert_eq!(rec.comm.up_bytes, drop.comm.up_bytes);
}

#[test]
fn compressed_baselines_run_and_save_bytes() {
    if !have_artifacts() {
        return;
    }
    for (method, max_ratio) in [
        (Method::Quantize { levels: 16 }, 0.2),
        (Method::Binarize, 0.05),
        (Method::TopK { keep_ratio: 0.1 }, 0.25),
        (Method::DropoutAvg { rate: 0.5 }, 0.6),
    ] {
        let mut s = Server::new(quick_cfg(method.clone())).unwrap();
        s.run().unwrap();
        let r = s.comm.comm_ratio();
        assert!(r < max_ratio, "{} ratio {r} > {max_ratio}", method.label());
        assert!(s.history.final_acc() > 0.15, "{} collapsed", method.label());
    }
}

#[test]
fn server_optimizers_run() {
    if !have_artifacts() {
        return;
    }
    for sopt in [
        ServerOptCfg::Adam { lr: 0.05 },
        ServerOptCfg::Acg { lambda: 0.5 },
        ServerOptCfg::Mut { alpha: 0.5 },
    ] {
        let mut cfg = quick_cfg(Method::luar(2));
        cfg.server_opt = sopt.clone();
        if matches!(sopt, ServerOptCfg::Acg { .. }) {
            cfg.client_opt = ClientOptCfg { mu_global: 0.01, mu_prev: 0.0 };
        }
        let mut s = Server::new(cfg).unwrap();
        s.run().unwrap();
        assert!(
            s.history.final_acc() > 0.15,
            "{} collapsed: {}",
            sopt.label(),
            s.history.final_acc()
        );
    }
}

#[test]
fn moon_lite_tracks_prev_local_models() {
    if !have_artifacts() {
        return;
    }
    let mut cfg = quick_cfg(Method::FedAvg);
    cfg.client_opt = ClientOptCfg { mu_global: 0.1, mu_prev: 0.05 };
    let mut s = Server::new(cfg).unwrap();
    s.run().unwrap();
    assert!(s.history.final_acc() > 0.15);
}

#[test]
fn luar_compose_with_quantization() {
    if !have_artifacts() {
        return;
    }
    let mut cfg = quick_cfg(Method::luar(2));
    cfg.luar_compress = Some(Method::Quantize { levels: 16 });
    let mut s = Server::new(cfg).unwrap();
    s.run().unwrap();
    // composition must be cheaper than LUAR alone
    let mut plain = Server::new(quick_cfg(Method::luar(2))).unwrap();
    plain.run().unwrap();
    assert!(s.comm.up_bytes < plain.comm.up_bytes);
    assert!(s.history.final_acc() > 0.15);
}

#[test]
fn layer_stats_are_populated() {
    if !have_artifacts() {
        return;
    }
    let mut s = Server::new(quick_cfg(Method::FedAvg)).unwrap();
    s.run().unwrap();
    let stats = s.layer_stats();
    assert_eq!(stats.len(), s.meta().num_layers());
    assert!(stats.iter().all(|(_, g, w, r)| *g > 0.0 && *w > 0.0 && *r > 0.0));
}

#[test]
fn memory_footprint_shrinks_for_luar() {
    if !have_artifacts() {
        return;
    }
    let mut s = Server::new(quick_cfg(Method::luar(2))).unwrap();
    s.run().unwrap();
    let (avg, luar) = s.memory_footprint();
    assert!(luar < avg, "LUAR footprint {luar} !< FedAvg {avg}");
}

#[test]
fn nonstandard_active_count_uses_rust_fallback() {
    if !have_artifacts() {
        return;
    }
    // active=6 != agg_clients=32 -> pure-Rust aggregation path.
    let mut s = Server::new(quick_cfg(Method::FedAvg)).unwrap();
    s.run().unwrap();
    assert_eq!(s.engine.stats().agg_calls, 0);
}

#[test]
fn checkpoint_resume_is_bit_identical() {
    if !have_artifacts() {
        return;
    }
    // straight-through run: 8 rounds
    let mut full = Server::new(quick_cfg(Method::luar(2))).unwrap();
    full.run().unwrap();
    // interrupted run: 4 rounds, checkpoint, fresh server, resume 4 more
    let mut cfg = quick_cfg(Method::luar(2));
    cfg.rounds = 4;
    let mut first = Server::new(cfg).unwrap();
    first.run().unwrap();
    let path = std::env::temp_dir().join("fedluar_ckpt_test.bin");
    first.save_checkpoint(&path).unwrap();
    let mut resumed = Server::new(quick_cfg(Method::luar(2))).unwrap();
    resumed.load_checkpoint(&path).unwrap();
    assert_eq!(resumed.round, 4);
    resumed.run().unwrap();
    // terminal state must match the uninterrupted run exactly
    assert_eq!(resumed.comm.up_bytes, full.comm.up_bytes);
    assert_eq!(resumed.luar.recycle_set, full.luar.recycle_set);
    let (xa, ..) = resumed.opt.snapshot();
    let (xb, ..) = full.opt.snapshot();
    assert_eq!(xa, xb, "resumed params diverged from straight-through run");
}

#[test]
fn checkpoint_rejects_mismatched_config() {
    if !have_artifacts() {
        return;
    }
    let mut cfg = quick_cfg(Method::luar(2));
    cfg.rounds = 2;
    let mut s = Server::new(cfg).unwrap();
    s.run().unwrap();
    let path = std::env::temp_dir().join("fedluar_ckpt_mismatch.bin");
    s.save_checkpoint(&path).unwrap();
    // wrong method
    let mut other = Server::new(quick_cfg(Method::FedAvg)).unwrap();
    assert!(other.load_checkpoint(&path).is_err());
}

#[test]
fn client_failures_thin_the_round() {
    if !have_artifacts() {
        return;
    }
    let mut cfg = quick_cfg(Method::FedAvg);
    cfg.client_failure_rate = 0.5;
    let mut s = Server::new(cfg).unwrap();
    s.run().unwrap();
    assert!(s.failed_clients > 0, "no failures injected");
    // still learns from survivors
    assert!(s.history.final_acc() > 0.2, "acc {}", s.history.final_acc());
}

#[test]
fn adaptive_delta_respects_theorem_bound() {
    if !have_artifacts() {
        return;
    }
    let mut cfg = quick_cfg(Method::luar_auto());
    cfg.rounds = 12;
    let mut s = Server::new(cfg).unwrap();
    s.run().unwrap();
    let ctl = s.delta_ctl.as_ref().expect("controller present");
    assert!(ctl.delta >= 1);
    // comm must be below FedAvg
    assert!(s.comm.comm_ratio() < 1.0);
    // the EMA the controller converged to stays near/below the bound
    assert!(
        ctl.kappa_ema() < 4.0 * ctl.kappa_bound,
        "kappa ema {} far above bound",
        ctl.kappa_ema()
    );
}
