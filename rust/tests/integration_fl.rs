//! FL-loop integration: real artifacts + the full Algorithm 2 round
//! loop, pinning the system-level invariants the paper relies on.
//! Uses the small MLP benchmark (sub-second rounds); skips when
//! artifacts are missing.

mod common;

use common::{have_artifacts, quick_cfg};
use fedluar::config::{ClientOptCfg, Method, RecycleMode, SelectionScheme, ServerOptCfg};
use fedluar::fl::Server;

#[test]
fn fedavg_learns_and_counts_full_comm() {
    if !have_artifacts() {
        return;
    }
    let mut s = Server::new(quick_cfg(Method::FedAvg)).unwrap();
    s.run().unwrap();
    assert!((s.comm.comm_ratio() - 1.0).abs() < 1e-9, "FedAvg comm must be 1.0");
    assert!(s.history.final_acc() > 0.25, "acc {}", s.history.final_acc());
    assert_eq!(s.comm.rounds, 8);
    // every layer uploaded every round
    assert!(s.comm.layer_frequencies().iter().all(|&f| (f - 1.0).abs() < 1e-9));
}

#[test]
fn fedluar_reduces_comm_and_still_learns() {
    if !have_artifacts() {
        return;
    }
    let mut s = Server::new(quick_cfg(Method::luar(2))).unwrap();
    s.run().unwrap();
    let ratio = s.comm.comm_ratio();
    assert!(ratio < 0.95, "LUAR must reduce comm, got {ratio}");
    assert!(ratio > 0.05, "comm ratio suspiciously low: {ratio}");
    assert!(s.history.final_acc() > 0.2);
    // some layer was recycled at least once
    let freqs = s.comm.layer_frequencies();
    assert!(freqs.iter().any(|&f| f < 1.0), "no layer ever recycled: {freqs:?}");
}

#[test]
fn fedluar_runs_are_deterministic() {
    if !have_artifacts() {
        return;
    }
    let mut a = Server::new(quick_cfg(Method::luar(2))).unwrap();
    a.run().unwrap();
    let mut b = Server::new(quick_cfg(Method::luar(2))).unwrap();
    b.run().unwrap();
    assert_eq!(a.history.records.len(), b.history.records.len());
    for (ra, rb) in a.history.records.iter().zip(&b.history.records) {
        assert_eq!(ra.test_acc, rb.test_acc, "round {} acc differs", ra.round);
        assert_eq!(ra.up_bytes, rb.up_bytes);
    }
}

#[test]
fn kappa_logged_only_for_luar() {
    if !have_artifacts() {
        return;
    }
    let mut avg = Server::new(quick_cfg(Method::FedAvg)).unwrap();
    avg.run().unwrap();
    assert_eq!(avg.history.max_kappa(), 0.0);
    let mut luar = Server::new(quick_cfg(Method::luar(2))).unwrap();
    luar.run().unwrap();
    assert!(luar.history.max_kappa() > 0.0);
    assert!(luar.history.max_kappa() <= 1.0);
}

#[test]
fn drop_mode_has_same_comm_as_recycle() {
    if !have_artifacts() {
        return;
    }
    let mk = |mode| Method::Luar { delta: 2, scheme: SelectionScheme::Luar, mode, adaptive: false };
    let mut rec = Server::new(quick_cfg(mk(RecycleMode::Recycle))).unwrap();
    rec.run().unwrap();
    let mut drop = Server::new(quick_cfg(mk(RecycleMode::Drop))).unwrap();
    drop.run().unwrap();
    // identical seeds -> identical selection -> identical bytes
    assert_eq!(rec.comm.up_bytes, drop.comm.up_bytes);
}

#[test]
fn compressed_baselines_run_and_save_bytes() {
    if !have_artifacts() {
        return;
    }
    for (method, max_ratio) in [
        (Method::Quantize { levels: 16 }, 0.2),
        (Method::Binarize, 0.05),
        (Method::TopK { keep_ratio: 0.1 }, 0.25),
        (Method::DropoutAvg { rate: 0.5 }, 0.6),
    ] {
        let mut s = Server::new(quick_cfg(method.clone())).unwrap();
        s.run().unwrap();
        let r = s.comm.comm_ratio();
        assert!(r < max_ratio, "{} ratio {r} > {max_ratio}", method.label());
        assert!(s.history.final_acc() > 0.15, "{} collapsed", method.label());
    }
}

#[test]
fn server_optimizers_run() {
    if !have_artifacts() {
        return;
    }
    for sopt in [
        ServerOptCfg::Adam { lr: 0.05 },
        ServerOptCfg::Acg { lambda: 0.5 },
        ServerOptCfg::Mut { alpha: 0.5 },
    ] {
        let mut cfg = quick_cfg(Method::luar(2));
        cfg.server_opt = sopt.clone();
        if matches!(sopt, ServerOptCfg::Acg { .. }) {
            cfg.client_opt = ClientOptCfg { mu_global: 0.01, mu_prev: 0.0 };
        }
        let mut s = Server::new(cfg).unwrap();
        s.run().unwrap();
        assert!(
            s.history.final_acc() > 0.15,
            "{} collapsed: {}",
            sopt.label(),
            s.history.final_acc()
        );
    }
}

#[test]
fn moon_lite_tracks_prev_local_models() {
    if !have_artifacts() {
        return;
    }
    let mut cfg = quick_cfg(Method::FedAvg);
    cfg.client_opt = ClientOptCfg { mu_global: 0.1, mu_prev: 0.05 };
    let mut s = Server::new(cfg).unwrap();
    s.run().unwrap();
    assert!(s.history.final_acc() > 0.15);
}

#[test]
fn luar_compose_with_quantization() {
    if !have_artifacts() {
        return;
    }
    let mut cfg = quick_cfg(Method::luar(2));
    cfg.luar_compress = Some(Method::Quantize { levels: 16 });
    let mut s = Server::new(cfg).unwrap();
    s.run().unwrap();
    // composition must be cheaper than LUAR alone
    let mut plain = Server::new(quick_cfg(Method::luar(2))).unwrap();
    plain.run().unwrap();
    assert!(s.comm.up_bytes < plain.comm.up_bytes);
    assert!(s.history.final_acc() > 0.15);
}

#[test]
fn layer_stats_are_populated() {
    if !have_artifacts() {
        return;
    }
    let mut s = Server::new(quick_cfg(Method::FedAvg)).unwrap();
    s.run().unwrap();
    let stats = s.layer_stats();
    assert_eq!(stats.len(), s.meta().num_layers());
    assert!(stats.iter().all(|(_, g, w, r)| *g > 0.0 && *w > 0.0 && *r > 0.0));
}

#[test]
fn memory_footprint_shrinks_for_luar() {
    if !have_artifacts() {
        return;
    }
    let mut s = Server::new(quick_cfg(Method::luar(2))).unwrap();
    s.run().unwrap();
    let (avg, luar) = s.memory_footprint();
    assert!(luar < avg, "LUAR footprint {luar} !< FedAvg {avg}");
}

#[test]
fn nonstandard_active_count_uses_rust_fallback() {
    if !have_artifacts() {
        return;
    }
    // active=6 != agg_clients=32 -> pure-Rust aggregation path.
    let mut s = Server::new(quick_cfg(Method::FedAvg)).unwrap();
    s.run().unwrap();
    assert_eq!(s.engine.stats().agg_calls, 0);
}

#[test]
fn checkpoint_resume_is_bit_identical() {
    if !have_artifacts() {
        return;
    }
    // straight-through run: 8 rounds
    let mut full = Server::new(quick_cfg(Method::luar(2))).unwrap();
    full.run().unwrap();
    // interrupted run: 4 rounds, checkpoint, fresh server, resume 4 more
    let mut cfg = quick_cfg(Method::luar(2));
    cfg.rounds = 4;
    let mut first = Server::new(cfg).unwrap();
    first.run().unwrap();
    let path = std::env::temp_dir().join("fedluar_ckpt_test.bin");
    first.save_checkpoint(&path).unwrap();
    let mut resumed = Server::new(quick_cfg(Method::luar(2))).unwrap();
    resumed.load_checkpoint(&path).unwrap();
    assert_eq!(resumed.round, 4);
    resumed.run().unwrap();
    // terminal state must match the uninterrupted run exactly
    assert_eq!(resumed.comm.up_bytes, full.comm.up_bytes);
    assert_eq!(resumed.luar.recycle_set, full.luar.recycle_set);
    let (xa, ..) = resumed.opt.snapshot();
    let (xb, ..) = full.opt.snapshot();
    assert_eq!(xa, xb, "resumed params diverged from straight-through run");
}

#[test]
fn checkpoint_rejects_mismatched_config() {
    if !have_artifacts() {
        return;
    }
    let mut cfg = quick_cfg(Method::luar(2));
    cfg.rounds = 2;
    let mut s = Server::new(cfg).unwrap();
    s.run().unwrap();
    let path = std::env::temp_dir().join("fedluar_ckpt_mismatch.bin");
    s.save_checkpoint(&path).unwrap();
    // wrong method
    let mut other = Server::new(quick_cfg(Method::FedAvg)).unwrap();
    assert!(other.load_checkpoint(&path).is_err());
}

#[test]
fn client_failures_thin_the_round() {
    if !have_artifacts() {
        return;
    }
    let mut cfg = quick_cfg(Method::FedAvg);
    cfg.client_failure_rate = 0.5;
    let mut s = Server::new(cfg).unwrap();
    s.run().unwrap();
    assert!(s.failed_clients > 0, "no failures injected");
    // still learns from survivors
    assert!(s.history.final_acc() > 0.2, "acc {}", s.history.final_acc());
}

/// Residual framing on the real round loop is ledger-only: FedAvg and
/// FedLUAR runs with `delta_frames` on finish in the identical model
/// state as their dense twins, with strictly fewer uplink ledger bytes
/// and every fallback counted.
#[test]
fn delta_framing_matches_dense_run_exactly() {
    if !have_artifacts() {
        return;
    }
    for method in [Method::FedAvg, Method::luar(2)] {
        let mut dense = Server::new(quick_cfg(method.clone())).unwrap();
        dense.run().unwrap();
        let mut cfg = quick_cfg(method.clone());
        cfg.net.delta_frames = true;
        let mut framed = Server::new(cfg).unwrap();
        framed.run().unwrap();
        let (xa, ..) = dense.opt.snapshot();
        let (xb, ..) = framed.opt.snapshot();
        assert_eq!(xa, xb, "{method:?}: delta framing must not move the model");
        assert_eq!(dense.luar.recycle_set, framed.luar.recycle_set, "{method:?}");
        for (d, f) in dense.history.records.iter().zip(&framed.history.records) {
            assert_eq!(d.train_loss.to_bits(), f.train_loss.to_bits(), "{method:?}");
            assert_eq!(d.sim_seconds.to_bits(), f.sim_seconds.to_bits(), "{method:?}");
        }
        // per direction the ledger can only shrink (the codec falls
        // back per frame); across both it must strictly shrink
        assert!(framed.comm.up_bytes <= dense.comm.up_bytes, "{method:?}");
        assert!(framed.comm.down_bytes <= dense.comm.down_bytes, "{method:?}");
        let gap = (dense.comm.up_bytes - framed.comm.up_bytes)
            + (dense.comm.down_bytes - framed.comm.down_bytes);
        assert!(
            gap > 0,
            "{method:?}: delta framing saved nothing over {} dense bytes",
            dense.comm.up_bytes + dense.comm.down_bytes
        );
        assert_eq!(framed.comm.delta_bytes_saved, gap, "{method:?}: saved-bytes ledger");
        // round 1 alone is active_clients first contacts per direction
        assert!(
            framed.comm.delta_fallbacks >= 2 * framed.cfg.active_clients as u64,
            "{method:?}: first-contact fallbacks uncounted"
        );
        assert_eq!(dense.comm.delta_fallbacks, 0, "{method:?}");
    }
}

/// Migration: a v2 checkpoint (no residual-framing section) loads into
/// a delta-framed build and resumes onto the exact model trajectory —
/// the reference state starts cold, so the post-resume first contacts
/// are counted as fallbacks rather than breaking the run.
#[test]
fn checkpoint_v2_migrates_into_delta_framed_run() {
    if !have_artifacts() {
        return;
    }
    let framed_cfg = || {
        let mut cfg = quick_cfg(Method::luar(2));
        cfg.net.delta_frames = true;
        cfg
    };
    let mut full = Server::new(framed_cfg()).unwrap();
    full.run().unwrap();
    let mut cfg = framed_cfg();
    cfg.rounds = 4;
    let mut first = Server::new(cfg).unwrap();
    first.run().unwrap();
    let path = std::env::temp_dir().join("fedluar_ckpt_v2_migrate.bin");
    first.save_checkpoint_as(&path, 2).unwrap();
    let mut resumed = Server::new(framed_cfg()).unwrap();
    resumed.load_checkpoint(&path).unwrap();
    assert_eq!(resumed.round, 4);
    assert_eq!(resumed.comm.delta_fallbacks, 0, "v2 carries no residual counters");
    resumed.run().unwrap();
    let (xa, ..) = resumed.opt.snapshot();
    let (xb, ..) = full.opt.snapshot();
    assert_eq!(xa, xb, "v2-resumed params diverged from straight-through run");
    assert_eq!(resumed.luar.recycle_set, full.luar.recycle_set);
    assert!(
        resumed.comm.delta_fallbacks >= resumed.cfg.active_clients as u64,
        "cold post-resume references must be counted as fallbacks"
    );
}

/// A v3 checkpoint persists the reference state and residual counters:
/// resume is exact down to the comm ledger, not just the trajectory.
#[test]
fn checkpoint_v3_resumes_delta_ledger_exactly() {
    if !have_artifacts() {
        return;
    }
    let framed_cfg = || {
        let mut cfg = quick_cfg(Method::luar(2));
        cfg.net.delta_frames = true;
        cfg
    };
    let mut full = Server::new(framed_cfg()).unwrap();
    full.run().unwrap();
    let mut cfg = framed_cfg();
    cfg.rounds = 4;
    let mut first = Server::new(cfg).unwrap();
    first.run().unwrap();
    let path = std::env::temp_dir().join("fedluar_ckpt_v3_delta.bin");
    first.save_checkpoint(&path).unwrap();
    let mut resumed = Server::new(framed_cfg()).unwrap();
    resumed.load_checkpoint(&path).unwrap();
    resumed.run().unwrap();
    let (xa, ..) = resumed.opt.snapshot();
    let (xb, ..) = full.opt.snapshot();
    assert_eq!(xa, xb, "v3-resumed params diverged from straight-through run");
    assert_eq!(resumed.comm.up_bytes, full.comm.up_bytes, "uplink ledger must be exact");
    assert_eq!(resumed.comm.down_bytes, full.comm.down_bytes, "downlink ledger must be exact");
    assert_eq!(resumed.comm.delta_bytes_saved, full.comm.delta_bytes_saved);
    assert_eq!(resumed.comm.delta_fallbacks, full.comm.delta_fallbacks);
}

#[test]
fn adaptive_delta_respects_theorem_bound() {
    if !have_artifacts() {
        return;
    }
    let mut cfg = quick_cfg(Method::luar_auto());
    cfg.rounds = 12;
    let mut s = Server::new(cfg).unwrap();
    s.run().unwrap();
    let ctl = s.delta_ctl.as_ref().expect("controller present");
    assert!(ctl.delta >= 1);
    // comm must be below FedAvg
    assert!(s.comm.comm_ratio() < 1.0);
    // the EMA the controller converged to stays near/below the bound
    assert!(
        ctl.kappa_ema() < 4.0 * ctl.kappa_bound,
        "kappa ema {} far above bound",
        ctl.kappa_ema()
    );
}
