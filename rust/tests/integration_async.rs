//! Async-runtime integration (engine-free): the barrier-free
//! dispatch/absorb machinery — `fl::AsyncRuntime` over the persistent
//! `net::AsyncQueue`, per-client model versions, staleness-discounted
//! weights, LUAR version-gap aging — driven end to end with synthetic
//! client deltas (the PJRT train graph is the only faked piece; every
//! scheduling, codec, link, accounting, and LUAR step is the real
//! library code, exactly as `Server` wires it).
//!
//! Pins the acceptance invariants:
//! * **equivalence** — `async:c=all,s=const` (full concurrency, zero
//!   staleness discount) over a homogeneous fleet reproduces the sync
//!   FedAvg *and* FedLUAR histories to within 1e-6 per round;
//! * **golden** — the `sync` / `deadline` / `buffered` scheduler
//!   outputs are bit-identical to the PR 1 semantics pinned in
//!   `tests/data/golden_sched.csv` (regenerate with
//!   `UPDATE_GOLDENS=1`, which fails the run so CI can never refresh
//!   it silently);
//! * **determinism** — two async runs with one seed produce identical
//!   histories, and a run snapshotted at round 5 through the
//!   checkpoint-v2 state path (`AsyncRuntime::state`/`from_state`,
//!   in-flight uploads included) resumes bit-identically;
//! * **e2e** — `async:c=N` completes FedAvg and FedLUAR runs over a
//!   heterogeneous fleet with measured per-upload `version_gap`s in
//!   the round CSV and per-absorb telemetry in the absorb CSV.

use fedluar::comm::CommAccountant;
use fedluar::config::{RecycleMode, SelectionScheme};
use fedluar::fl::{AsyncRuntime, UploadPayload};
use fedluar::luar::LuarState;
use fedluar::metrics::{AbsorbRecord, History, RoundRecord};
use fedluar::model::ModelMeta;
use fedluar::net::{sched, wire, LinkDist, NetCfg, NetSim, RoundMode, Staleness};
use fedluar::rng::Rng;
use fedluar::tensor;
use std::path::PathBuf;

const LAYERS: usize = 6;
const LAYER_SIZE: usize = 512;
const NUM_CLIENTS: usize = 16;
const ACTIVE: usize = 8;

/// 6-layer synthetic model (8x64 matrices), no artifacts needed.
fn synth_meta() -> ModelMeta {
    let mut rows = Vec::new();
    for l in 0..LAYERS {
        let off = l * LAYER_SIZE;
        rows.push(format!(
            r#"{{"name":"l{l}","kind":"dense","offset":{off},"size":{LAYER_SIZE},
               "arrays":[{{"name":"w","shape":[8,64],"offset":{off},"size":{LAYER_SIZE}}}]}}"#
        ));
    }
    let dim = LAYERS * LAYER_SIZE;
    let doc = format!(
        r#"{{"model":"asim","dim":{dim},"num_classes":10,
            "input_shape":[8],"input_dtype":"f32","tau":5,"batch":16,
            "eval_batch":64,"agg_clients":8,"momentum":0.9,
            "layers":[{}],
            "artifacts":{{"train":"t","eval":"e","agg":"g","init":"i"}},
            "init_sha256":"x"}}"#,
        rows.join(",")
    );
    ModelMeta::from_json(&doc, PathBuf::from("/tmp")).unwrap()
}

/// Deterministic stand-in for one client's local training at a given
/// sample generation: the only piece of the pipeline that is synthetic.
fn fake_delta(seed: u64, client: usize, gen: u64, dim: usize) -> (Vec<f32>, f32) {
    let mut rng = Rng::seed_from_u64(
        seed ^ (client as u64).wrapping_mul(0x9e37_79b9) ^ gen.wrapping_mul(0x85eb_ca6b),
    );
    let delta: Vec<f32> = (0..dim).map(|_| rng.normal_f32(0.0, 0.05)).collect();
    let loss = 1.0 + rng.f32();
    (delta, loss)
}

/// Miniature mirror of `fl::Server` for FedAvg / FedLUAR with an SGD
/// server optimizer: same dispatch half (LUAR layer zeroing, dense
/// wire codec, per-client links), same absorb half (weighted mean,
/// Eq. 1 score update, version-gap aging, compose, select-next,
/// measured byte accounting), with `fake_delta` in place of the AOT
/// train graph. `test_loss` doubles as a model-trajectory probe
/// (ssq of the params) so histories pin the parameter path.
struct SimServer {
    meta: ModelMeta,
    seed: u64,
    /// `Some(delta)` = FedLUAR at that recycling depth; `None` = FedAvg.
    luar_delta: Option<usize>,
    net: NetSim,
    luar: LuarState,
    params: Vec<f32>,
    comm: CommAccountant,
    history: History,
    rng: Rng,
    round: usize,
    sim_seconds: f64,
    rt: Option<AsyncRuntime>,
}

impl SimServer {
    fn new(mode: RoundMode, dist: LinkDist, luar_delta: Option<usize>, seed: u64) -> Self {
        let meta = synth_meta();
        let net = NetSim::new(
            NetCfg { link_dist: dist, round_mode: mode, compute_s: 0.1, delta_frames: false },
            NUM_CLIENTS,
            42,
        );
        let dim = meta.dim;
        let layers = meta.num_layers();
        SimServer {
            meta,
            seed,
            luar_delta,
            net,
            luar: LuarState::new(layers, dim),
            params: vec![0.0; dim],
            comm: CommAccountant::new(layers),
            history: History::default(),
            rng: Rng::seed_from_u64(seed ^ 0xc0ffee),
            round: 0,
            sim_seconds: 0.0,
            rt: None,
        }
    }

    /// Deterministic round-robin cohorts (the schedule, not the data,
    /// is under test; both drivers share it, mirroring how `Server`'s
    /// async sample stream walks the sync cohorts).
    fn cohort(&self, gen: u64) -> Vec<usize> {
        (0..ACTIVE).map(|i| ((gen as usize) * ACTIVE + i) % NUM_CLIENTS).collect()
    }

    fn upload_layers(&self) -> Vec<usize> {
        if self.luar_delta.is_some() {
            self.luar.upload_set(self.meta.num_layers())
        } else {
            (0..self.meta.num_layers()).collect()
        }
    }

    /// Dispatch half for one client: train (fake), zero R_t, encode,
    /// decode server-side. Returns (decoded update, loss, frame bytes).
    fn upload(&self, client: usize, gen: u64, upload_layers: &[usize]) -> (Vec<f32>, f32, u64) {
        let (mut delta, loss) = fake_delta(self.seed, client, gen, self.meta.dim);
        for &l in &self.luar.recycle_set {
            let lm = &self.meta.layers[l];
            delta[lm.offset..lm.offset + lm.size].iter_mut().for_each(|v| *v = 0.0);
        }
        let frame =
            wire::encode_update(&delta, &self.meta, upload_layers, &wire::WireHint::Dense)
                .unwrap();
        let decoded = match wire::decode_update(frame.as_bytes(), &self.meta).unwrap() {
            wire::Decoded::Vector(v) => v,
            wire::Decoded::Scalar(_) => unreachable!("dense flavor only"),
        };
        (decoded, loss, frame.len() as u64)
    }

    /// Absorb half: mirrors `Server::finish_aggregation` (weighted
    /// mean, LUAR with version-gap aging, SGD apply, ledger, record).
    #[allow(clippy::too_many_arguments)]
    fn finish(
        &mut self,
        deltas: &[Vec<f32>],
        included: &[bool],
        weights: &[f32],
        upload_layers: &[usize],
        actives_len: usize,
        loss_sum: f64,
        loss_count: usize,
        up_bytes_total: u64,
        down_total: u64,
        round_secs: f64,
        tail_s: f64,
        arrivals: usize,
        mean_gap: f64,
    ) {
        let mut refs: Vec<&[f32]> = Vec::with_capacity(arrivals);
        let mut agg_weights: Vec<f32> = Vec::with_capacity(arrivals);
        for (slot, d) in deltas.iter().enumerate() {
            if included[slot] {
                refs.push(d.as_slice());
                agg_weights.push(weights[slot]);
            }
        }
        assert!(!refs.is_empty(), "aggregation must never be empty");
        let uniform = agg_weights.iter().all(|&w| w == 1.0);
        let mut mean = vec![0.0f32; self.meta.dim];
        if uniform {
            tensor::mean_rows_par(&refs, &mut mean);
        } else {
            let wsum: f32 = agg_weights.iter().sum();
            let norm: Vec<f32> = agg_weights.iter().map(|w| w / wsum).collect();
            tensor::weighted_mean_rows(&refs, &norm, &mut mean);
        }
        let mut u_ssq = Vec::with_capacity(self.meta.num_layers());
        let mut w_ssq = Vec::with_capacity(self.meta.num_layers());
        for lm in &self.meta.layers {
            let r = lm.offset..lm.offset + lm.size;
            u_ssq.push(tensor::ssq(&mean[r.clone()]) as f32);
            w_ssq.push(tensor::ssq(&self.params[r]) as f32);
        }
        let mut kappa = 0.0;
        if let Some(delta_sel) = self.luar_delta {
            self.luar.update_scores(&u_ssq, &w_ssq);
            self.luar.set_age_step(1 + mean_gap.round() as u32);
            kappa = self.luar.compose_update(&mut mean, &self.meta, RecycleMode::Recycle);
            let grad_norms: Vec<f64> =
                u_ssq.iter().map(|&s| (s as f64).max(0.0).sqrt()).collect();
            self.luar.select_next(SelectionScheme::Luar, delta_sel, &grad_norms, &mut self.rng);
        }
        tensor::axpy(1.0, &mean, &mut self.params);
        self.comm.record_wire_round(
            actives_len as u64,
            upload_layers,
            up_bytes_total,
            wire::dense_frame_len(&self.meta),
            down_total,
        );
        self.sim_seconds += round_secs;
        let train_loss = loss_sum / loss_count.max(1) as f64;
        self.round += 1;
        self.history.push(RoundRecord {
            round: self.round,
            train_loss,
            test_loss: tensor::ssq(&self.params),
            test_acc: self.params[0] as f64,
            up_bytes: self.comm.up_bytes,
            comm_ratio: self.comm.comm_ratio(),
            kappa,
            sim_seconds: self.sim_seconds,
            wire_bytes: up_bytes_total,
            tail_s,
            arrivals,
            version_gap: mean_gap,
        });
    }

    fn run_sync_round(&mut self) {
        let t = self.round as u64;
        let actives = self.cohort(t);
        let upload_layers = self.upload_layers();
        let bcast =
            wire::encode_broadcast(&self.params, &self.meta, &self.luar.recycle_set).unwrap();
        let mut deltas: Vec<Vec<f32>> = Vec::with_capacity(actives.len());
        let mut frame_lens: Vec<u64> = Vec::with_capacity(actives.len());
        let mut loss_sum = 0.0f64;
        let mut up_total = 0u64;
        for &client in &actives {
            let (d, loss, flen) = self.upload(client, t, &upload_layers);
            loss_sum += loss as f64;
            up_total += flen;
            frame_lens.push(flen);
            deltas.push(d);
        }
        let outcome = self.net.round(&actives, bcast.len() as u64, &frame_lens);
        let down = actives.len() as u64 * bcast.len() as u64;
        self.finish(
            &deltas,
            &outcome.included,
            &outcome.weights,
            &upload_layers,
            actives.len(),
            loss_sum,
            actives.len(),
            up_total,
            down,
            outcome.round_secs,
            outcome.straggler_tail_s,
            outcome.aggregated,
            0.0,
        );
    }

    fn dispatch_next(&mut self) {
        let (mut gen, mut idx) = {
            let rt = self.rt.as_ref().unwrap();
            (rt.sample_gen, rt.sample_idx as usize)
        };
        if idx >= ACTIVE {
            gen += 1;
            idx = 0;
        }
        let client = self.cohort(gen)[idx];
        {
            let rt = self.rt.as_mut().unwrap();
            rt.sample_gen = gen;
            rt.sample_idx = (idx + 1) as u64;
        }
        let upload_layers = self.upload_layers();
        let bcast =
            wire::encode_broadcast(&self.params, &self.meta, &self.luar.recycle_set).unwrap();
        let (delta, loss, frame_len) = self.upload(client, gen, &upload_layers);
        let secs = self.net.client_secs(client, bcast.len() as u64, frame_len);
        let rt = self.rt.as_mut().unwrap();
        let payload = UploadPayload {
            client,
            version: rt.version,
            gen,
            delta,
            loss,
            frame_len,
            bcast_len: bcast.len() as u64,
        };
        rt.dispatch(payload, secs);
    }

    fn run_async_round(&mut self, c: usize, staleness: Staleness) {
        if self.rt.is_none() {
            self.rt = Some(AsyncRuntime::new(NUM_CLIENTS, c, ACTIVE, staleness));
        }
        loop {
            while self.rt.as_ref().unwrap().wants_dispatch() {
                self.dispatch_next();
            }
            let start = self.rt.as_mut().unwrap().absorb_instant();
            {
                let rt = self.rt.as_ref().unwrap();
                let in_flight = rt.in_flight();
                let version = rt.version;
                for (i, u) in rt.buffer[start..].iter().enumerate() {
                    self.history.absorbs.push(AbsorbRecord {
                        version,
                        client: u.payload.client,
                        t: u.t,
                        version_gap: u.version_gap,
                        weight: u.weight,
                        in_flight,
                        queue_depth: start + i + 1,
                    });
                }
            }
            if self.rt.as_ref().unwrap().ready() {
                let batch = self.rt.as_mut().unwrap().take_aggregation();
                let n = batch.uploads.len();
                let mut deltas: Vec<Vec<f32>> = Vec::with_capacity(n);
                let mut weights: Vec<f32> = Vec::with_capacity(n);
                let mut loss_sum = 0.0f64;
                let mut up_total = 0u64;
                for u in batch.uploads {
                    loss_sum += u.payload.loss as f64;
                    up_total += u.payload.frame_len;
                    weights.push(u.weight);
                    deltas.push(u.payload.delta);
                }
                let included = vec![true; n];
                let upload_layers = self.upload_layers();
                self.finish(
                    &deltas,
                    &included,
                    &weights,
                    &upload_layers,
                    n,
                    loss_sum,
                    n,
                    up_total,
                    batch.down_bytes,
                    batch.round_secs,
                    batch.tail_s,
                    n,
                    batch.mean_gap,
                );
                return;
            }
        }
    }

    fn run(&mut self, rounds: usize) {
        while self.round < rounds {
            match self.net.cfg.round_mode {
                RoundMode::Async { concurrency, staleness } => {
                    let c = if concurrency == 0 { ACTIVE } else { concurrency };
                    self.run_async_round(c, staleness);
                }
                _ => self.run_sync_round(),
            }
        }
    }
}

fn edge_fleet() -> LinkDist {
    LinkDist::LogNormal { up_mbps: 10.0, down_mbps: 50.0, sigma: 0.75, rtt_s: 0.05 }
}

// ------------------------------------------------------------------ tests

/// `async:c=all` with the zero staleness discount reproduces the sync
/// FedAvg and FedLUAR histories to within 1e-6 per round (the ISSUE's
/// equivalence criterion): full concurrency over a homogeneous fleet
/// degenerates the barrier-free loop into lock-step generations.
#[test]
fn async_c_all_zero_discount_matches_sync() {
    for luar in [None, Some(2)] {
        let mut sync = SimServer::new(RoundMode::Sync, LinkDist::default(), luar, 42);
        sync.run(12);
        let amode = RoundMode::Async { concurrency: 0, staleness: Staleness::Const };
        let mut asn = SimServer::new(amode, LinkDist::default(), luar, 42);
        asn.run(12);

        assert_eq!(sync.history.records.len(), asn.history.records.len(), "{luar:?}");
        for (s, a) in sync.history.records.iter().zip(&asn.history.records) {
            assert_eq!(s.round, a.round);
            assert!(
                (s.test_loss - a.test_loss).abs() <= 1e-6 * s.test_loss.abs().max(1.0),
                "{luar:?} round {}: model trajectory diverged: {} vs {}",
                s.round,
                s.test_loss,
                a.test_loss
            );
            assert!((s.train_loss - a.train_loss).abs() < 1e-9, "{luar:?} round {}", s.round);
            assert!((s.kappa - a.kappa).abs() < 1e-9, "{luar:?} round {}", s.round);
            assert_eq!(s.up_bytes, a.up_bytes, "{luar:?} round {}", s.round);
            assert_eq!(s.wire_bytes, a.wire_bytes, "{luar:?} round {}", s.round);
            assert_eq!(s.arrivals, a.arrivals, "{luar:?} round {}", s.round);
            assert!(
                (s.sim_seconds - a.sim_seconds).abs() < 1e-9,
                "{luar:?} round {}: clock diverged: {} vs {}",
                s.round,
                s.sim_seconds,
                a.sim_seconds
            );
            assert_eq!(a.version_gap, 0.0, "full concurrency => no version gaps");
        }
        for (i, (x, y)) in sync.params.iter().zip(&asn.params).enumerate() {
            assert!(
                (x - y).abs() <= 1e-6,
                "{luar:?} param {i}: {x} vs {y} (sync vs async)"
            );
        }
        assert_eq!(sync.comm.up_bytes, asn.comm.up_bytes, "{luar:?}");
        assert_eq!(sync.comm.down_bytes, asn.comm.down_bytes, "{luar:?}");
        if luar.is_some() {
            assert_eq!(sync.luar.recycle_set, asn.luar.recycle_set, "{luar:?}");
        }
    }
}

/// `sync` / `deadline` / `buffered` scheduler outputs are bit-identical
/// to their PR 1 golden file (regenerate with `UPDATE_GOLDENS=1`).
#[test]
fn barrier_modes_match_pr1_golden_sched() {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/rust/tests/data/golden_sched.csv");
    let mut lines =
        vec!["mode,round,round_secs_bits,aggregated,included,weight_bits,tail_bits".to_string()];
    let n = 8usize;
    for r in 0..6usize {
        let times: Vec<f64> = (0..n).map(|i| (((i * 7 + r * 3) % 11) + 1) as f64 * 0.25).collect();
        for (mode, name) in [
            (RoundMode::Sync, "sync"),
            (RoundMode::Deadline { deadline_s: 1.25 }, "deadline"),
            (RoundMode::Buffered { k: 3 }, "buffered"),
        ] {
            let out = sched::simulate_round(&mode, &times);
            lines.push(format!(
                "{},{},{:016x},{},{},{},{:016x}",
                name,
                r,
                out.round_secs.to_bits(),
                out.aggregated,
                out.included.iter().map(|&b| if b { '1' } else { '0' }).collect::<String>(),
                out.weights
                    .iter()
                    .map(|w| format!("{:08x}", w.to_bits()))
                    .collect::<Vec<_>>()
                    .join(";"),
                out.straggler_tail_s.to_bits(),
            ));
        }
    }
    let mine = lines.join("\n") + "\n";
    if std::env::var("UPDATE_GOLDENS").is_ok() {
        std::fs::write(path, &mine).unwrap();
        panic!("golden file regenerated; rerun without UPDATE_GOLDENS");
    }
    let golden = std::fs::read_to_string(path).expect("tests/data/golden_sched.csv checked in");
    assert_eq!(
        mine, golden,
        "deadline/buffered/sync scheduler semantics drifted from the PR 1 golden"
    );
}

/// Two async runs with one seed are bit-identical, and a run
/// checkpointed at round 5 — through the same `AsyncRuntime`
/// state snapshot the v2 checkpoint serializes, in-flight uploads and
/// all — resumes into the identical history (the ISSUE's determinism
/// regression test).
#[test]
fn async_runs_are_deterministic_and_resume_exactly() {
    let mode = RoundMode::Async { concurrency: 3, staleness: Staleness::Poly { a: 0.5 } };
    let mk = || SimServer::new(mode, edge_fleet(), Some(2), 7);

    let mut a = mk();
    a.run(10);
    let mut b = mk();
    b.run(10);
    assert_history_identical(&a.history, &b.history, "same-seed rerun");

    // interrupted run: 5 rounds, snapshot, rebuild, 5 more
    let mut first = mk();
    first.run(5);
    let st = first.rt.as_ref().unwrap().state();
    assert!(
        !st.pending.is_empty(),
        "checkpoint must capture in-flight uploads (c=3 keeps slots busy)"
    );
    let mut resumed = mk();
    resumed.params = first.params.clone();
    resumed.luar = first.luar.clone();
    resumed.comm = first.comm.clone();
    resumed.rng = first.rng.clone();
    resumed.round = first.round;
    resumed.sim_seconds = first.sim_seconds;
    resumed.history = first.history.clone();
    resumed.rt = Some(AsyncRuntime::from_state(3, ACTIVE, Staleness::Poly { a: 0.5 }, st));
    resumed.run(10);

    assert_history_identical(&a.history, &resumed.history, "checkpoint resume");
    for (i, (x, y)) in a.params.iter().zip(&resumed.params).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "param {i} diverged after resume");
    }
}

fn assert_history_identical(a: &History, b: &History, what: &str) {
    assert_eq!(a.records.len(), b.records.len(), "{what}: record count");
    for (x, y) in a.records.iter().zip(&b.records) {
        assert_eq!(x.round, y.round, "{what}");
        assert_eq!(x.train_loss.to_bits(), y.train_loss.to_bits(), "{what} round {}", x.round);
        assert_eq!(x.test_loss.to_bits(), y.test_loss.to_bits(), "{what} round {}", x.round);
        assert_eq!(x.kappa.to_bits(), y.kappa.to_bits(), "{what} round {}", x.round);
        assert_eq!(x.up_bytes, y.up_bytes, "{what} round {}", x.round);
        assert_eq!(x.wire_bytes, y.wire_bytes, "{what} round {}", x.round);
        assert_eq!(x.arrivals, y.arrivals, "{what} round {}", x.round);
        assert_eq!(
            x.sim_seconds.to_bits(),
            y.sim_seconds.to_bits(),
            "{what} round {}",
            x.round
        );
        assert_eq!(
            x.version_gap.to_bits(),
            y.version_gap.to_bits(),
            "{what} round {}",
            x.round
        );
    }
    assert_eq!(a.absorbs.len(), b.absorbs.len(), "{what}: absorb count");
    for (x, y) in a.absorbs.iter().zip(&b.absorbs) {
        assert_eq!(x.version, y.version, "{what}");
        assert_eq!(x.client, y.client, "{what}");
        assert_eq!(x.t.to_bits(), y.t.to_bits(), "{what}");
        assert_eq!(x.version_gap, y.version_gap, "{what}");
        assert_eq!(x.weight.to_bits(), y.weight.to_bits(), "{what}");
        assert_eq!(x.in_flight, y.in_flight, "{what}");
        assert_eq!(x.queue_depth, y.queue_depth, "{what}");
    }
}

/// `async:c=N` completes an e2e run for FedAvg and FedLUAR over a
/// heterogeneous fleet: measured per-upload version gaps appear in the
/// round CSV (and round-trip through the parser), staleness discounts
/// bite, the concurrency cap holds, and the ledger equals the summed
/// aggregated frame bytes.
#[test]
fn async_e2e_fedavg_and_fedluar_with_measured_gaps() {
    for luar in [None, Some(2)] {
        let mode = RoundMode::Async { concurrency: 4, staleness: Staleness::Poly { a: 0.5 } };
        let mut s = SimServer::new(mode, edge_fleet(), luar, 11);
        s.run(10);
        assert_eq!(s.history.records.len(), 10, "{luar:?}");
        assert_eq!(s.round, 10, "{luar:?}");

        // every aggregation absorbed at least the goal
        assert!(s.history.absorbs.len() >= 10 * ACTIVE, "{luar:?}");
        // the concurrency cap held at every absorb
        assert!(s.history.absorbs.iter().all(|a| a.in_flight <= 4), "{luar:?}");
        // with c < agg goal, later rounds must see stale uploads...
        assert!(
            s.history.records.iter().skip(1).any(|r| r.version_gap > 0.0),
            "{luar:?}: no version gaps measured"
        );
        // ...and the polynomial discount must bite on them
        assert!(
            s.history.absorbs.iter().any(|a| a.version_gap > 0 && a.weight < 1.0),
            "{luar:?}: staleness discount never applied"
        );
        // simulated clock advances monotonically
        for w in s.history.records.windows(2) {
            assert!(w[1].sim_seconds > w[0].sim_seconds, "{luar:?}: clock went backwards");
        }
        // ledger == summed aggregated frame bytes
        let wire_sum: u64 = s.history.records.iter().map(|r| r.wire_bytes).sum();
        assert_eq!(s.comm.up_bytes, wire_sum, "{luar:?}");

        if luar.is_some() {
            let ratio = s.comm.comm_ratio();
            assert!(ratio < 0.95, "{luar:?}: LUAR must reduce measured comm, got {ratio}");
            assert!(ratio > 0.05, "{luar:?}: ratio suspiciously low {ratio}");
            assert!(s.history.records.iter().any(|r| r.kappa > 0.0), "{luar:?}");
        } else {
            assert!((s.comm.comm_ratio() - 1.0).abs() < 1e-12, "{luar:?}");
        }

        // the CSVs carry the async telemetry and parse back
        let dir = std::env::temp_dir().join("fedluar_async_test");
        let tag = if luar.is_some() { "luar" } else { "avg" };
        let round_csv = dir.join(format!("rounds_{tag}.csv"));
        let absorb_csv = dir.join(format!("absorbs_{tag}.csv"));
        s.history.write_csv(&round_csv).unwrap();
        s.history.write_absorb_csv(&absorb_csv).unwrap();
        let head = std::fs::read_to_string(&round_csv).unwrap();
        assert!(head.lines().next().unwrap().ends_with("version_gap"), "{luar:?}");
        let back = History::read_csv(&round_csv).unwrap();
        assert_eq!(back.records.len(), 10, "{luar:?}");
        for (orig, parsed) in s.history.records.iter().zip(&back.records) {
            assert!(
                (orig.version_gap - parsed.version_gap).abs() < 5e-4,
                "{luar:?}: version_gap lost in CSV round-trip"
            );
        }
        let absorbs = std::fs::read_to_string(&absorb_csv).unwrap();
        assert_eq!(absorbs.lines().count(), s.history.absorbs.len() + 1, "{luar:?}");
    }
}

/// The fully-async mode decouples wall-clock from stragglers: over a
/// bimodal fleet, closing versions at the buffer goal with c=all must
/// be faster than sync rounds that barrier on the slow cohort.
#[test]
fn async_decouples_wall_clock_from_stragglers() {
    let dist = LinkDist::Bimodal {
        fast_frac: 0.75,
        fast_up_mbps: 80.0,
        slow_up_mbps: 1.0,
        down_mbps: 100.0,
        rtt_s: 0.0,
    };
    let mut sync = SimServer::new(RoundMode::Sync, dist.clone(), None, 3);
    sync.run(8);
    let amode = RoundMode::Async { concurrency: 2 * ACTIVE, staleness: Staleness::Poly { a: 0.5 } };
    let mut asn = SimServer::new(amode, dist, None, 3);
    asn.run(8);
    let sync_t = sync.history.records.last().unwrap().sim_seconds;
    let async_t = asn.history.records.last().unwrap().sim_seconds;
    assert!(
        async_t < sync_t,
        "async {async_t:.2}s should beat sync {sync_t:.2}s on a bimodal fleet"
    );
}
