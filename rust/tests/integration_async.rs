//! Async-runtime integration (engine-free): the barrier-free
//! dispatch/absorb machinery — `fl::AsyncRuntime` over the persistent
//! `net::AsyncQueue`, per-client model versions, staleness-discounted
//! weights, LUAR version-gap aging — driven end to end with synthetic
//! client deltas (the PJRT train graph is the only faked piece; every
//! scheduling, codec, link, accounting, and LUAR step is the real
//! library code, exactly as `Server` wires it). The `SimServer`
//! fixture lives in `tests/common/mod.rs`, shared with the delta and
//! sampler suites.
//!
//! Pins the acceptance invariants:
//! * **equivalence** — `async:c=all,s=const` (full concurrency, zero
//!   staleness discount) over a homogeneous fleet reproduces the sync
//!   FedAvg *and* FedLUAR histories to within 1e-6 per round;
//! * **golden** — the `sync` / `deadline` / `buffered` scheduler
//!   outputs are bit-identical to the PR 1 semantics pinned in
//!   `tests/data/golden_sched.csv` (regenerate with
//!   `UPDATE_GOLDENS=1`, which fails the run so CI can never refresh
//!   it silently);
//! * **determinism** — two async runs with one seed produce identical
//!   histories, and a run snapshotted at round 5 through the
//!   checkpoint-v2 state path (`AsyncRuntime::state`/`from_state`,
//!   in-flight uploads included) resumes bit-identically;
//! * **e2e** — `async:c=N` completes FedAvg and FedLUAR runs over a
//!   heterogeneous fleet with measured per-upload `version_gap`s in
//!   the round CSV and per-absorb telemetry in the absorb CSV.

mod common;

use common::{assert_history_identical, bimodal_fleet, edge_fleet, SimServer, ACTIVE};
use fedluar::fl::AsyncRuntime;
use fedluar::metrics::History;
use fedluar::net::{sched, LinkDist, RoundMode, Staleness};

// ------------------------------------------------------------------ tests

/// `async:c=all` with the zero staleness discount reproduces the sync
/// FedAvg and FedLUAR histories to within 1e-6 per round (the ISSUE's
/// equivalence criterion): full concurrency over a homogeneous fleet
/// degenerates the barrier-free loop into lock-step generations.
#[test]
fn async_c_all_zero_discount_matches_sync() {
    for luar in [None, Some(2)] {
        let mut sync = SimServer::new(RoundMode::Sync, LinkDist::default(), luar, 42);
        sync.run(12);
        let amode = RoundMode::Async { concurrency: 0, staleness: Staleness::Const };
        let mut asn = SimServer::new(amode, LinkDist::default(), luar, 42);
        asn.run(12);

        assert_eq!(sync.history.records.len(), asn.history.records.len(), "{luar:?}");
        for (s, a) in sync.history.records.iter().zip(&asn.history.records) {
            assert_eq!(s.round, a.round);
            assert!(
                (s.test_loss - a.test_loss).abs() <= 1e-6 * s.test_loss.abs().max(1.0),
                "{luar:?} round {}: model trajectory diverged: {} vs {}",
                s.round,
                s.test_loss,
                a.test_loss
            );
            assert!((s.train_loss - a.train_loss).abs() < 1e-9, "{luar:?} round {}", s.round);
            assert!((s.kappa - a.kappa).abs() < 1e-9, "{luar:?} round {}", s.round);
            assert_eq!(s.up_bytes, a.up_bytes, "{luar:?} round {}", s.round);
            assert_eq!(s.wire_bytes, a.wire_bytes, "{luar:?} round {}", s.round);
            assert_eq!(s.arrivals, a.arrivals, "{luar:?} round {}", s.round);
            assert!(
                (s.sim_seconds - a.sim_seconds).abs() < 1e-9,
                "{luar:?} round {}: clock diverged: {} vs {}",
                s.round,
                s.sim_seconds,
                a.sim_seconds
            );
            assert_eq!(a.version_gap, 0.0, "full concurrency => no version gaps");
        }
        for (i, (x, y)) in sync.params.iter().zip(&asn.params).enumerate() {
            assert!(
                (x - y).abs() <= 1e-6,
                "{luar:?} param {i}: {x} vs {y} (sync vs async)"
            );
        }
        assert_eq!(sync.comm.up_bytes, asn.comm.up_bytes, "{luar:?}");
        assert_eq!(sync.comm.down_bytes, asn.comm.down_bytes, "{luar:?}");
        if luar.is_some() {
            assert_eq!(sync.luar.recycle_set, asn.luar.recycle_set, "{luar:?}");
        }
    }
}

/// `sync` / `deadline` / `buffered` scheduler outputs are bit-identical
/// to their PR 1 golden file (regenerate with `UPDATE_GOLDENS=1`).
#[test]
fn barrier_modes_match_pr1_golden_sched() {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/rust/tests/data/golden_sched.csv");
    let mut lines =
        vec!["mode,round,round_secs_bits,aggregated,included,weight_bits,tail_bits".to_string()];
    let n = 8usize;
    for r in 0..6usize {
        let times: Vec<f64> = (0..n).map(|i| (((i * 7 + r * 3) % 11) + 1) as f64 * 0.25).collect();
        for (mode, name) in [
            (RoundMode::Sync, "sync"),
            (RoundMode::Deadline { deadline_s: 1.25 }, "deadline"),
            (RoundMode::Buffered { k: 3 }, "buffered"),
        ] {
            let out = sched::simulate_round(&mode, &times);
            lines.push(format!(
                "{},{},{:016x},{},{},{},{:016x}",
                name,
                r,
                out.round_secs.to_bits(),
                out.aggregated,
                out.included.iter().map(|&b| if b { '1' } else { '0' }).collect::<String>(),
                out.weights
                    .iter()
                    .map(|w| format!("{:08x}", w.to_bits()))
                    .collect::<Vec<_>>()
                    .join(";"),
                out.straggler_tail_s.to_bits(),
            ));
        }
    }
    let mine = lines.join("\n") + "\n";
    if std::env::var("UPDATE_GOLDENS").is_ok() {
        std::fs::write(path, &mine).unwrap();
        panic!("golden file regenerated; rerun without UPDATE_GOLDENS");
    }
    let golden = std::fs::read_to_string(path).expect("tests/data/golden_sched.csv checked in");
    assert_eq!(
        mine, golden,
        "deadline/buffered/sync scheduler semantics drifted from the PR 1 golden"
    );
}

/// Two async runs with one seed are bit-identical, and a run
/// checkpointed at round 5 — through the same `AsyncRuntime`
/// state snapshot the v2 checkpoint serializes, in-flight uploads and
/// all — resumes into the identical history (the ISSUE's determinism
/// regression test).
#[test]
fn async_runs_are_deterministic_and_resume_exactly() {
    let mode = RoundMode::Async { concurrency: 3, staleness: Staleness::Poly { a: 0.5 } };
    let mk = || SimServer::new(mode, edge_fleet(), Some(2), 7);

    let mut a = mk();
    a.run(10);
    let mut b = mk();
    b.run(10);
    assert_history_identical(&a.history, &b.history, "same-seed rerun");

    // interrupted run: 5 rounds, snapshot, rebuild, 5 more
    let mut first = mk();
    first.run(5);
    let st = first.rt.as_ref().unwrap().state();
    assert!(
        !st.pending.is_empty(),
        "checkpoint must capture in-flight uploads (c=3 keeps slots busy)"
    );
    let mut resumed = mk();
    resumed.params = first.params.clone();
    resumed.luar = first.luar.clone();
    resumed.comm = first.comm.clone();
    resumed.rng = first.rng.clone();
    resumed.round = first.round;
    resumed.sim_seconds = first.sim_seconds;
    resumed.history = first.history.clone();
    resumed.rt = Some(AsyncRuntime::from_state(3, ACTIVE, Staleness::Poly { a: 0.5 }, st));
    resumed.run(10);

    assert_history_identical(&a.history, &resumed.history, "checkpoint resume");
    for (i, (x, y)) in a.params.iter().zip(&resumed.params).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "param {i} diverged after resume");
    }
}

/// `async:c=N` completes an e2e run for FedAvg and FedLUAR over a
/// heterogeneous fleet: measured per-upload version gaps appear in the
/// round CSV (and round-trip through the parser), staleness discounts
/// bite, the concurrency cap holds, and the ledger equals the summed
/// aggregated frame bytes.
#[test]
fn async_e2e_fedavg_and_fedluar_with_measured_gaps() {
    for luar in [None, Some(2)] {
        let mode = RoundMode::Async { concurrency: 4, staleness: Staleness::Poly { a: 0.5 } };
        let mut s = SimServer::new(mode, edge_fleet(), luar, 11);
        s.run(10);
        assert_eq!(s.history.records.len(), 10, "{luar:?}");
        assert_eq!(s.round, 10, "{luar:?}");

        // every aggregation absorbed at least the goal
        assert!(s.history.absorbs.len() >= 10 * ACTIVE, "{luar:?}");
        // the concurrency cap held at every absorb
        assert!(s.history.absorbs.iter().all(|a| a.in_flight <= 4), "{luar:?}");
        // with c < agg goal, later rounds must see stale uploads...
        assert!(
            s.history.records.iter().skip(1).any(|r| r.version_gap > 0.0),
            "{luar:?}: no version gaps measured"
        );
        // ...and the polynomial discount must bite on them
        assert!(
            s.history.absorbs.iter().any(|a| a.version_gap > 0 && a.weight < 1.0),
            "{luar:?}: staleness discount never applied"
        );
        // simulated clock advances monotonically
        for w in s.history.records.windows(2) {
            assert!(w[1].sim_seconds > w[0].sim_seconds, "{luar:?}: clock went backwards");
        }
        // ledger == summed aggregated frame bytes
        let wire_sum: u64 = s.history.records.iter().map(|r| r.wire_bytes).sum();
        assert_eq!(s.comm.up_bytes, wire_sum, "{luar:?}");

        if luar.is_some() {
            let ratio = s.comm.comm_ratio();
            assert!(ratio < 0.95, "{luar:?}: LUAR must reduce measured comm, got {ratio}");
            assert!(ratio > 0.05, "{luar:?}: ratio suspiciously low {ratio}");
            assert!(s.history.records.iter().any(|r| r.kappa > 0.0), "{luar:?}");
        } else {
            assert!((s.comm.comm_ratio() - 1.0).abs() < 1e-12, "{luar:?}");
        }

        // the CSVs carry the async telemetry and parse back
        let dir = std::env::temp_dir().join("fedluar_async_test");
        let tag = if luar.is_some() { "luar" } else { "avg" };
        let round_csv = dir.join(format!("rounds_{tag}.csv"));
        let absorb_csv = dir.join(format!("absorbs_{tag}.csv"));
        s.history.write_csv(&round_csv).unwrap();
        s.history.write_absorb_csv(&absorb_csv).unwrap();
        let head = std::fs::read_to_string(&round_csv).unwrap();
        assert!(head.lines().next().unwrap().ends_with("version_gap"), "{luar:?}");
        let back = History::read_csv(&round_csv).unwrap();
        assert_eq!(back.records.len(), 10, "{luar:?}");
        for (orig, parsed) in s.history.records.iter().zip(&back.records) {
            assert!(
                (orig.version_gap - parsed.version_gap).abs() < 5e-4,
                "{luar:?}: version_gap lost in CSV round-trip"
            );
        }
        let absorbs = std::fs::read_to_string(&absorb_csv).unwrap();
        assert_eq!(absorbs.lines().count(), s.history.absorbs.len() + 1, "{luar:?}");
    }
}

/// The fully-async mode decouples wall-clock from stragglers: over a
/// bimodal fleet, closing versions at the buffer goal with c=all must
/// be faster than sync rounds that barrier on the slow cohort.
#[test]
fn async_decouples_wall_clock_from_stragglers() {
    let dist = bimodal_fleet();
    let mut sync = SimServer::new(RoundMode::Sync, dist.clone(), None, 3);
    sync.run(8);
    let amode = RoundMode::Async { concurrency: 2 * ACTIVE, staleness: Staleness::Poly { a: 0.5 } };
    let mut asn = SimServer::new(amode, dist, None, 3);
    asn.run(8);
    let sync_t = sync.history.records.last().unwrap().sim_seconds;
    let async_t = asn.history.records.last().unwrap().sim_seconds;
    assert!(
        async_t < sync_t,
        "async {async_t:.2}s should beat sync {sync_t:.2}s on a bimodal fleet"
    );
}
