//! Straggler-aware client sampling (engine-free): the pluggable
//! `net.sampler` tier driven through the shared `SimServer` fixture,
//! pinning the ISSUE's acceptance invariants:
//!
//! * **uniform equivalence** — `sampler = uniform` reproduces the
//!   legacy cohort stream bit-exactly (same seeded Fisher-Yates under
//!   the `0xc11e_0000` salt), and a `staleness:cap=N` run whose cap
//!   never bites is bit-identical to a uniform run end to end;
//! * **wall-clock win** — on a bimodal straggler fleet, `speed:pow=1`
//!   strictly reduces simulated wall-clock at an equal absorbed-upload
//!   count, with per-client participation counts reconciling exactly
//!   against the scheduler's dispatch log;
//! * **bounded staleness** — `staleness:cap=0` holds every stale
//!   upload out of the aggregation mean (the round's recorded mean
//!   version gap is exactly zero) without ever emptying a batch;
//! * **pinned trace** — the seeded biased-cohort stream matches
//!   `tests/data/golden_sampler.csv` (regenerate with
//!   `UPDATE_GOLDENS=1`), so weight math and the weighted draw cannot
//!   drift silently;
//! * **persistence** — checkpoint v4 round-trips the telemetry table
//!   (a resumed speed run is bit-identical to an uninterrupted one)
//!   while v3 files still load with a cold table.

mod common;

use common::{
    assert_history_identical, bimodal_fleet, edge_fleet, have_artifacts, legacy_cohort,
    quick_cfg, SimServer, ACTIVE, NUM_CLIENTS,
};
use fedluar::config::Method;
use fedluar::fl::Server;
use fedluar::net::{speed_cohort, speed_weights, ClientStats, RoundMode, SamplerCfg, Staleness};
use fedluar::obs::{self, ObsCfg, ObsLevel};
use fedluar::rng::Rng;

// ------------------------------------------------------------------ tests

/// `sampler = uniform` is the legacy draw, not merely statistically
/// similar to it: the sampled cohort stream equals `legacy_cohort` and
/// an inline replication of the seeded Fisher-Yates for every round.
#[test]
fn uniform_sampler_reproduces_the_legacy_cohort_stream() {
    for seed in [3u64, 11, 29] {
        let s = SimServer::new(RoundMode::Sync, edge_fleet(), Some(2), seed)
            .with_sampler(SamplerCfg::Uniform);
        for round in 0..32u64 {
            let got = s.cohort(round);
            assert_eq!(
                got,
                legacy_cohort(NUM_CLIENTS, ACTIVE, seed, round),
                "seed {seed} round {round}"
            );
            let mut rng = Rng::seed_from_u64(seed ^ 0xc11e_0000 ^ round);
            assert_eq!(
                got,
                rng.sample_indices(NUM_CLIENTS, ACTIVE),
                "inline replication, seed {seed} round {round}"
            );
        }
    }
}

/// A `staleness:cap` large enough never to bite must be bit-identical
/// to `uniform` — same cohorts, same histories, same parameters, same
/// telemetry — because the two specs share one code path until the cap
/// actually holds something.
#[test]
fn generous_staleness_cap_is_bit_identical_to_uniform() {
    let amode = RoundMode::Async { concurrency: 3, staleness: Staleness::Poly { a: 0.5 } };
    let mut uniform =
        SimServer::new(amode, edge_fleet(), Some(2), 11).with_sampler(SamplerCfg::Uniform);
    uniform.run(12);
    let mut capped = SimServer::new(amode, edge_fleet(), Some(2), 11)
        .with_sampler(SamplerCfg::Staleness { cap: 1_000_000 });
    capped.run(12);
    assert_history_identical(&uniform.history, &capped.history, "generous cap vs uniform");
    for (i, (x, y)) in uniform.params.iter().zip(&capped.params).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "param {i} diverged");
    }
    assert_eq!(uniform.dispatch_log, capped.dispatch_log, "dispatch order");
    assert_eq!(uniform.sampler_stats, capped.sampler_stats, "telemetry tables");
    assert_eq!(
        capped.sampler_stats.held_stale.iter().sum::<u64>(),
        0,
        "a generous cap must hold nothing"
    );
}

/// The tentpole acceptance test: on a bimodal fleet (fast 80 Mbps vs
/// slow 1 Mbps uplinks), `speed:pow=1` strictly reduces simulated
/// wall-clock at an equal absorbed-upload count, and the per-client
/// participation counts reconcile exactly against the dispatch log.
#[test]
fn speed_sampling_strictly_cuts_wall_clock_on_a_bimodal_fleet() {
    let rounds = 10;
    let mut uniform = SimServer::new(RoundMode::Sync, bimodal_fleet(), None, 13)
        .with_sampler(SamplerCfg::Uniform);
    uniform.run(rounds);
    let mut speed = SimServer::new(RoundMode::Sync, bimodal_fleet(), None, 13)
        .with_sampler(SamplerCfg::Speed { pow: 1.0 });
    speed.run(rounds);

    // equal absorbed work: sync rounds absorb the full cohort
    let absorbed = |s: &SimServer| s.sampler_stats.absorbed.iter().sum::<u64>();
    assert_eq!(absorbed(&uniform), (rounds * ACTIVE) as u64);
    assert_eq!(absorbed(&speed), absorbed(&uniform), "absorbed-upload counts must match");

    // ... in strictly less simulated time
    assert!(
        speed.sim_seconds < uniform.sim_seconds,
        "speed-biased sampling must beat uniform on a bimodal fleet: {} !< {}",
        speed.sim_seconds,
        uniform.sim_seconds
    );

    // participation counts reconcile exactly against the dispatch log
    for (tag, s) in [("uniform", &uniform), ("speed", &speed)] {
        assert_eq!(s.dispatch_log.len(), rounds * ACTIVE, "{tag}: dispatch count");
        let mut counts = vec![0u64; NUM_CLIENTS];
        for &c in &s.dispatch_log {
            counts[c] += 1;
        }
        assert_eq!(
            counts, s.sampler_stats.dispatches,
            "{tag}: telemetry participation vs dispatch log"
        );
    }

    // the bias visibly moves dispatches off the slow mode
    let slow_dispatches = |s: &SimServer| -> u64 {
        (0..NUM_CLIENTS)
            .filter(|&c| s.net.fleet.link(c).up_bps < 2e6)
            .map(|c| s.sampler_stats.dispatches[c])
            .sum()
    };
    assert!(
        slow_dispatches(&speed) < slow_dispatches(&uniform),
        "speed bias must shift participation away from slow links ({} !< {})",
        slow_dispatches(&speed),
        slow_dispatches(&uniform)
    );

    // every biased cohort is still ACTIVE distinct clients
    for round in 0..rounds as u64 {
        let cohort = fedluar::net::speed_cohort(
            &speed.sampler_stats,
            1.0,
            round as usize,
            ACTIVE,
            speed.seed,
        );
        assert_eq!(cohort.len(), ACTIVE);
        let mut sorted = cohort.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), ACTIVE, "round {round}: cohort must be distinct");
    }
}

/// `staleness:cap=0` holds every stale upload out of the mean: the
/// dispatch schedule is untouched (the cap acts at absorb time only),
/// every recorded mean version gap is exactly zero, held + absorbed
/// accounts for every arrival, and the model trajectory actually moves
/// (the excluded uploads changed the aggregate).
#[test]
fn staleness_cap_holds_stale_uploads_out_of_the_mean() {
    let amode = RoundMode::Async { concurrency: 4, staleness: Staleness::Poly { a: 0.5 } };
    let mut uniform =
        SimServer::new(amode, edge_fleet(), None, 11).with_sampler(SamplerCfg::Uniform);
    uniform.run(12);
    let mut capped = SimServer::new(amode, edge_fleet(), None, 11)
        .with_sampler(SamplerCfg::Staleness { cap: 0 });
    capped.run(12);

    // the cap never touches dispatch: both runs see the same arrivals
    assert_eq!(uniform.dispatch_log, capped.dispatch_log, "dispatch schedule");
    assert_eq!(uniform.history.absorbs.len(), capped.history.absorbs.len());
    for (x, y) in uniform.history.absorbs.iter().zip(&capped.history.absorbs) {
        assert_eq!(
            (x.version, x.client, x.version_gap),
            (y.version, y.client, y.version_gap),
            "arrival streams must be identical"
        );
        assert_eq!(x.t.to_bits(), y.t.to_bits());
    }

    let stale_arrivals =
        uniform.history.absorbs.iter().filter(|a| a.version_gap > 0).count() as u64;
    assert!(stale_arrivals > 0, "fixture must generate staleness for the cap to bite");
    assert_eq!(uniform.sampler_stats.held_stale.iter().sum::<u64>(), 0);

    // cap=0: exactly the stale arrivals are held, the rest absorbed
    let held: u64 = capped.sampler_stats.held_stale.iter().sum();
    let absorbed: u64 = capped.sampler_stats.absorbed.iter().sum();
    assert_eq!(held, stale_arrivals, "every stale arrival must be held");
    assert_eq!(
        held + absorbed,
        capped.history.absorbs.len() as u64,
        "held + absorbed must account for every arrival"
    );

    // the recorded mean gap is computed over admitted uploads only —
    // with cap=0 it is exactly zero every round (a batch always holds
    // fresh in-window uploads, so the all-held fallback never fires)
    for r in &capped.history.records {
        assert_eq!(
            r.version_gap.to_bits(),
            0f64.to_bits(),
            "round {}: admitted mean gap must be exactly zero",
            r.round
        );
    }

    // holding stale uploads must actually change the aggregate
    assert!(
        uniform
            .history
            .records
            .iter()
            .zip(&capped.history.records)
            .any(|(a, b)| a.test_loss.to_bits() != b.test_loss.to_bits()),
        "the cap must change the aggregated mean"
    );
}

/// The per-client CSV is the fairness observable: one row per client
/// whose participation counts reconcile exactly with the dispatch log,
/// written through `obs::finish` with the pinned 10-column header.
#[test]
fn per_client_csv_reconciles_with_the_dispatch_log() {
    let dir = std::env::temp_dir().join("fedluar_sampler_csv_test");
    let path = dir.join("clients.csv").to_str().unwrap().to_string();
    obs::init(&ObsCfg {
        level: ObsLevel::Metrics,
        clients_csv: Some(path.clone()),
        ..ObsCfg::default()
    })
    .unwrap();

    let mut s = SimServer::new(RoundMode::Sync, bimodal_fleet(), None, 13)
        .with_sampler(SamplerCfg::Speed { pow: 1.0 });
    s.run(10);
    obs::record_client_rounds(&s.sampler_stats, &s.net.fleet);

    let rows = obs::client_rows();
    assert_eq!(rows.len(), NUM_CLIENTS, "one row per client");
    let mut counts = vec![0u64; NUM_CLIENTS];
    for &c in &s.dispatch_log {
        counts[c] += 1;
    }
    for (c, row) in rows.iter().enumerate() {
        assert_eq!(row.client, c);
        assert_eq!(row.dispatches, counts[c], "client {c}: participation vs dispatch log");
        assert_eq!(row.absorbed, s.sampler_stats.absorbed[c]);
        assert_eq!(row.held_stale, s.sampler_stats.held_stale[c]);
        assert_eq!(row.up_bytes, s.sampler_stats.up_bytes[c]);
    }
    assert_eq!(
        rows.iter().map(|r| r.dispatches).sum::<u64>(),
        s.dispatch_log.len() as u64,
        "total participation must equal total dispatches"
    );

    let written = obs::finish().unwrap();
    assert!(written.contains(&path), "finish must write the clients CSV: {written:?}");
    let text = std::fs::read_to_string(&path).unwrap();
    let mut lines = text.lines();
    assert_eq!(
        lines.next().unwrap(),
        "client,up_mbps,speed_bucket,dispatches,absorbed,held_stale,mean_upload_s,up_bytes,retries,failures"
    );
    assert_eq!(text.lines().count(), 1 + NUM_CLIENTS);
    for line in text.lines().skip(1) {
        assert_eq!(line.split(',').count(), 10, "{line}");
    }
}

/// The seeded biased-cohort trace is pinned: normalized speed weights
/// (as f64 bits) and ten weighted draws over a synthetic telemetry
/// table must match `tests/data/golden_sampler.csv` exactly.
/// Regenerate with `UPDATE_GOLDENS=1 cargo test speed_cohort_trace`.
#[test]
fn speed_cohort_trace_matches_golden() {
    let mut stats = ClientStats::new(16);
    for c in 0..16usize {
        // exact powers of two, so the weight math is bit-portable
        let secs = [0.125, 0.25, 0.5, 1.0][c % 4];
        stats.record_dispatch(c, secs, 100 * (c as u64 + 1));
    }
    let weights = speed_weights(&stats, 1.0);
    let mut lines = vec!["kind,round,value".to_string()];
    lines.push(format!(
        "weights,-,{}",
        weights.iter().map(|w| format!("{:016x}", w.to_bits())).collect::<Vec<_>>().join(";")
    ));
    for round in 0..10usize {
        let cohort = speed_cohort(&stats, 1.0, round, 6, 0x5A17);
        lines.push(format!(
            "cohort,{round},{}",
            cohort.iter().map(|c| c.to_string()).collect::<Vec<_>>().join(";")
        ));
    }
    let got = lines.join("\n") + "\n";

    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/rust/tests/data/golden_sampler.csv");
    if std::env::var("UPDATE_GOLDENS").is_ok() {
        std::fs::write(path, &got).unwrap();
        panic!("golden_sampler.csv regenerated; re-run without UPDATE_GOLDENS");
    }
    let want = std::fs::read_to_string(path)
        .expect("golden_sampler.csv missing (UPDATE_GOLDENS=1 to create)");
    assert_eq!(got, want, "seeded speed-sampler trace drifted from the golden file");
}

/// Checkpoint v4 persists the telemetry table: a speed-sampled run
/// interrupted at the halfway point resumes onto the exact trajectory
/// of an uninterrupted one (the biased draws depend on the restored
/// per-client means).
#[test]
fn checkpoint_v4_round_trips_speed_sampler_state() {
    if !have_artifacts() {
        return;
    }
    let speed_cfg = || {
        let mut cfg = quick_cfg(Method::FedAvg);
        cfg.net.sampler = SamplerCfg::Speed { pow: 1.0 };
        cfg
    };
    let mut full = Server::new(speed_cfg()).unwrap();
    full.run().unwrap();
    let mut cfg = speed_cfg();
    cfg.rounds = 4;
    let mut first = Server::new(cfg).unwrap();
    first.run().unwrap();
    assert!(
        first.sampler_stats.dispatches.iter().sum::<u64>() > 0,
        "speed run must record telemetry"
    );
    let path = std::env::temp_dir().join("fedluar_ckpt_v4_sampler.bin");
    first.save_checkpoint(&path).unwrap();
    let mut resumed = Server::new(speed_cfg()).unwrap();
    resumed.load_checkpoint(&path).unwrap();
    assert_eq!(resumed.round, 4);
    assert_eq!(resumed.sampler_stats, first.sampler_stats, "v4 must restore the table");
    resumed.run().unwrap();
    let (xa, ..) = resumed.opt.snapshot();
    let (xb, ..) = full.opt.snapshot();
    assert_eq!(xa, xb, "speed-sampled resume diverged from straight-through run");
    assert_eq!(resumed.sampler_stats, full.sampler_stats, "terminal telemetry");
}

/// Older checkpoints still load: a v3 file carries no sampler section,
/// so the table comes back cold and a speed run simply re-warms from
/// uniform weights.
#[test]
fn checkpoint_v3_loads_with_cold_sampler_state() {
    if !have_artifacts() {
        return;
    }
    let mut cfg = quick_cfg(Method::FedAvg);
    cfg.net.sampler = SamplerCfg::Speed { pow: 1.0 };
    cfg.rounds = 4;
    let mut first = Server::new(cfg).unwrap();
    first.run().unwrap();
    let path = std::env::temp_dir().join("fedluar_ckpt_v3_sampler.bin");
    first.save_checkpoint_as(&path, 3).unwrap();

    let mut cfg = quick_cfg(Method::FedAvg);
    cfg.net.sampler = SamplerCfg::Speed { pow: 1.0 };
    let mut resumed = Server::new(cfg).unwrap();
    resumed.load_checkpoint(&path).unwrap();
    assert_eq!(resumed.round, 4);
    assert!(
        resumed.sampler_stats.dispatches.iter().all(|&d| d == 0),
        "v3 carries no sampler telemetry"
    );
    assert!(resumed.sampler_stats.upload_secs_sum.iter().all(|&s| s == 0.0));
    resumed.run().unwrap();
    assert_eq!(resumed.round, 8, "cold-table resume must still complete");
    assert!(resumed.sampler_stats.dispatches.iter().sum::<u64>() > 0);
}
