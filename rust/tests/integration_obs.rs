//! Observability integration (engine-free): the obs/ telemetry
//! subsystem driven through the production wire / link / async-runtime
//! / LUAR code paths, pinning the ISSUE's acceptance invariants:
//!
//! * **read-only telemetry** — an `obs: level=full` run produces a
//!   bit-identical History (and parameter vector) to a `level=off`
//!   run: instrumentation never touches an RNG, the sim clock, or
//!   model state;
//! * **Figure 3 agreement** — per-layer upload counts summed from the
//!   layer telemetry rows equal `CommAccountant::layer_upload_rounds`
//!   exactly, and the derived frequencies equal `layer_frequencies`;
//! * **artifacts** — a full-level run emits all three artifact kinds
//!   (span JSONL whose every line parses, a non-empty Prometheus-style
//!   exposition plus JSON summary, and the 8-column layer CSV).
//!
//! The obs context is thread-local and each #[test] runs on its own
//! thread, so tests cannot bleed telemetry into each other.

use fedluar::comm::CommAccountant;
use fedluar::config::{RecycleMode, SelectionScheme};
use fedluar::fl::{AsyncRuntime, UploadPayload};
use fedluar::json::Json;
use fedluar::luar::LuarState;
use fedluar::metrics::{History, RoundRecord};
use fedluar::model::ModelMeta;
use fedluar::net::{wire, LinkDist, NetCfg, NetSim, RoundMode, Staleness};
use fedluar::obs::{self, ObsCfg, ObsLevel};
use fedluar::rng::Rng;
use fedluar::tensor;
use std::path::PathBuf;

const LAYERS: usize = 6;
const LAYER_SIZE: usize = 512;
const NUM_CLIENTS: usize = 16;
const ACTIVE: usize = 8;

fn synth_meta() -> ModelMeta {
    let mut rows = Vec::new();
    for l in 0..LAYERS {
        let off = l * LAYER_SIZE;
        rows.push(format!(
            r#"{{"name":"l{l}","kind":"dense","offset":{off},"size":{LAYER_SIZE},
               "arrays":[{{"name":"w","shape":[8,64],"offset":{off},"size":{LAYER_SIZE}}}]}}"#
        ));
    }
    let dim = LAYERS * LAYER_SIZE;
    let doc = format!(
        r#"{{"model":"osim","dim":{dim},"num_classes":10,
            "input_shape":[8],"input_dtype":"f32","tau":5,"batch":16,
            "eval_batch":64,"agg_clients":8,"momentum":0.9,
            "layers":[{}],
            "artifacts":{{"train":"t","eval":"e","agg":"g","init":"i"}},
            "init_sha256":"x"}}"#,
        rows.join(",")
    );
    ModelMeta::from_json(&doc, PathBuf::from("/tmp")).unwrap()
}

fn fake_delta(seed: u64, client: usize, gen: u64, dim: usize) -> (Vec<f32>, f32) {
    let mut rng = Rng::seed_from_u64(
        seed ^ (client as u64).wrapping_mul(0x9e37_79b9) ^ gen.wrapping_mul(0x85eb_ca6b),
    );
    let delta: Vec<f32> = (0..dim).map(|_| rng.normal_f32(0.0, 0.05)).collect();
    let loss = 1.0 + rng.f32();
    (delta, loss)
}

/// Trimmed mirror of `fl::Server`'s async FedLUAR loop (same shape as
/// `tests/integration_async.rs`), including the per-layer telemetry
/// call `Server::finish_aggregation` makes — so the layer rows, the
/// comm ledger, and the history all flow from one `upload_layers`.
struct SimServer {
    meta: ModelMeta,
    seed: u64,
    delta_sel: usize,
    net: NetSim,
    luar: LuarState,
    params: Vec<f32>,
    comm: CommAccountant,
    history: History,
    rng: Rng,
    round: usize,
    sim_seconds: f64,
    rt: Option<AsyncRuntime>,
}

fn edge_fleet() -> LinkDist {
    LinkDist::LogNormal { up_mbps: 10.0, down_mbps: 50.0, sigma: 0.75, rtt_s: 0.05 }
}

impl SimServer {
    fn new(seed: u64) -> Self {
        let meta = synth_meta();
        let mode = RoundMode::Async { concurrency: 4, staleness: Staleness::Poly { a: 0.5 } };
        let net = NetSim::new(
            NetCfg {
                link_dist: edge_fleet(),
                round_mode: mode,
                compute_s: 0.1,
                ..NetCfg::default()
            },
            NUM_CLIENTS,
            42,
        );
        let dim = meta.dim;
        let layers = meta.num_layers();
        SimServer {
            meta,
            seed,
            delta_sel: 2,
            net,
            luar: LuarState::new(layers, dim),
            params: vec![0.0; dim],
            comm: CommAccountant::new(layers),
            history: History::default(),
            rng: Rng::seed_from_u64(seed ^ 0xc0ffee),
            round: 0,
            sim_seconds: 0.0,
            rt: None,
        }
    }

    fn cohort(&self, gen: u64) -> Vec<usize> {
        (0..ACTIVE).map(|i| ((gen as usize) * ACTIVE + i) % NUM_CLIENTS).collect()
    }

    fn dispatch_next(&mut self) {
        let (mut gen, mut idx) = {
            let rt = self.rt.as_ref().unwrap();
            (rt.sample_gen, rt.sample_idx as usize)
        };
        if idx >= ACTIVE {
            gen += 1;
            idx = 0;
        }
        let client = self.cohort(gen)[idx];
        {
            let rt = self.rt.as_mut().unwrap();
            rt.sample_gen = gen;
            rt.sample_idx = (idx + 1) as u64;
        }
        let upload_layers = self.luar.upload_set(self.meta.num_layers());
        let bcast =
            wire::encode_broadcast(&self.params, &self.meta, &self.luar.recycle_set).unwrap();
        let (mut delta, loss) = fake_delta(self.seed, client, gen, self.meta.dim);
        for &l in &self.luar.recycle_set {
            let lm = &self.meta.layers[l];
            delta[lm.offset..lm.offset + lm.size].iter_mut().for_each(|v| *v = 0.0);
        }
        let frame =
            wire::encode_update(&delta, &self.meta, &upload_layers, &wire::WireHint::Dense)
                .unwrap();
        let decoded = match wire::decode_update(frame.as_bytes(), &self.meta).unwrap() {
            wire::Decoded::Vector(v) => v,
            wire::Decoded::Scalar(_) => unreachable!("dense flavor only"),
        };
        let secs = self.net.client_secs(client, bcast.len() as u64, frame.len() as u64);
        let rt = self.rt.as_mut().unwrap();
        let payload = UploadPayload {
            client,
            version: rt.version,
            gen,
            delta: decoded,
            loss,
            frame_len: frame.len() as u64,
            bcast_len: bcast.len() as u64,
        };
        rt.dispatch(payload, secs);
    }

    fn run_async_round(&mut self) {
        if self.rt.is_none() {
            self.rt = Some(AsyncRuntime::new(NUM_CLIENTS, 4, ACTIVE, Staleness::Poly { a: 0.5 }));
        }
        loop {
            while self.rt.as_ref().unwrap().wants_dispatch() {
                self.dispatch_next();
            }
            self.rt.as_mut().unwrap().absorb_instant().unwrap();
            if self.rt.as_ref().unwrap().ready() {
                let batch = self.rt.as_mut().unwrap().take_aggregation();
                let n = batch.uploads.len();
                let mut refs_owned: Vec<Vec<f32>> = Vec::with_capacity(n);
                let mut weights: Vec<f32> = Vec::with_capacity(n);
                let mut loss_sum = 0.0f64;
                let mut up_total = 0u64;
                for u in batch.uploads {
                    loss_sum += u.payload.loss as f64;
                    up_total += u.payload.frame_len;
                    weights.push(u.weight);
                    refs_owned.push(u.payload.delta);
                }
                let upload_layers = self.luar.upload_set(self.meta.num_layers());
                self.finish(
                    &refs_owned,
                    &weights,
                    &upload_layers,
                    loss_sum,
                    up_total,
                    batch.down_bytes,
                    batch.round_secs,
                    batch.mean_gap,
                );
                return;
            }
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn finish(
        &mut self,
        deltas: &[Vec<f32>],
        weights: &[f32],
        upload_layers: &[usize],
        loss_sum: f64,
        up_bytes_total: u64,
        down_total: u64,
        round_secs: f64,
        mean_gap: f64,
    ) {
        let refs: Vec<&[f32]> = deltas.iter().map(|d| d.as_slice()).collect();
        let uniform = weights.iter().all(|&w| w == 1.0);
        let mut mean = vec![0.0f32; self.meta.dim];
        if uniform {
            tensor::mean_rows_par(&refs, &mut mean);
        } else {
            let wsum: f32 = weights.iter().sum();
            let norm: Vec<f32> = weights.iter().map(|w| w / wsum).collect();
            tensor::weighted_mean_rows(&refs, &norm, &mut mean);
        }
        let mut u_ssq = Vec::with_capacity(self.meta.num_layers());
        let mut w_ssq = Vec::with_capacity(self.meta.num_layers());
        for lm in &self.meta.layers {
            let r = lm.offset..lm.offset + lm.size;
            u_ssq.push(tensor::ssq(&mean[r.clone()]) as f32);
            w_ssq.push(tensor::ssq(&self.params[r]) as f32);
        }
        self.luar.update_scores(&u_ssq, &w_ssq);
        self.luar.set_age_step(1 + mean_gap.round() as u32);
        let kappa = self.luar.compose_update(&mut mean, &self.meta, RecycleMode::Recycle);
        let grad_norms: Vec<f64> = u_ssq.iter().map(|&s| (s as f64).max(0.0).sqrt()).collect();
        self.luar.select_next(SelectionScheme::Luar, self.delta_sel, &grad_norms, &mut self.rng);

        // The same per-layer telemetry call `Server::finish_aggregation`
        // makes, fed by the same upload_layers the comm ledger records.
        if obs::enabled() {
            let wsum: f32 = weights.iter().sum();
            let discount = (wsum / weights.len().max(1) as f32) as f64;
            obs::record_layer_round(
                self.round,
                &self.meta,
                upload_layers,
                &self.luar.scores,
                &self.luar.staleness,
                up_bytes_total,
                discount,
                0.0,
            );
            obs::gauge("luar.kappa", kappa);
            obs::snapshot(self.round as u64);
        }

        tensor::axpy(1.0, &mean, &mut self.params);
        self.comm.record_wire_round(
            deltas.len() as u64,
            upload_layers,
            up_bytes_total,
            wire::dense_frame_len(&self.meta),
            down_total,
        );
        self.sim_seconds += round_secs;
        self.round += 1;
        self.history.push(RoundRecord {
            round: self.round,
            train_loss: loss_sum / deltas.len().max(1) as f64,
            test_loss: tensor::ssq(&self.params),
            test_acc: self.params[0] as f64,
            up_bytes: self.comm.up_bytes,
            comm_ratio: self.comm.comm_ratio(),
            kappa,
            sim_seconds: self.sim_seconds,
            wire_bytes: up_bytes_total,
            tail_s: 0.0,
            arrivals: deltas.len(),
            version_gap: mean_gap,
        });
    }

    fn run(&mut self, rounds: usize) {
        while self.round < rounds {
            self.run_async_round();
        }
    }
}

fn assert_bit_identical(a: &SimServer, b: &SimServer, what: &str) {
    assert_eq!(a.history.records.len(), b.history.records.len(), "{what}");
    for (x, y) in a.history.records.iter().zip(&b.history.records) {
        assert_eq!(x.round, y.round, "{what}");
        assert_eq!(x.train_loss.to_bits(), y.train_loss.to_bits(), "{what} round {}", x.round);
        assert_eq!(x.test_loss.to_bits(), y.test_loss.to_bits(), "{what} round {}", x.round);
        assert_eq!(x.kappa.to_bits(), y.kappa.to_bits(), "{what} round {}", x.round);
        assert_eq!(x.up_bytes, y.up_bytes, "{what} round {}", x.round);
        assert_eq!(x.wire_bytes, y.wire_bytes, "{what} round {}", x.round);
        assert_eq!(
            x.sim_seconds.to_bits(),
            y.sim_seconds.to_bits(),
            "{what} round {}",
            x.round
        );
        assert_eq!(
            x.version_gap.to_bits(),
            y.version_gap.to_bits(),
            "{what} round {}",
            x.round
        );
    }
    for (i, (x, y)) in a.params.iter().zip(&b.params).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: param {i} diverged");
    }
    assert_eq!(a.luar.recycle_set, b.luar.recycle_set, "{what}");
    assert_eq!(a.comm.layer_upload_rounds, b.comm.layer_upload_rounds, "{what}");
}

// ------------------------------------------------------------------ tests

/// `obs: level=off` vs `level=full`: telemetry must be read-only, so
/// the History, the parameter vector, the recycle set, and the comm
/// ledger are all bit-identical (the ISSUE's acceptance criterion).
#[test]
fn off_vs_full_runs_are_bit_identical() {
    obs::init(&ObsCfg::default()).unwrap();
    let mut off = SimServer::new(7);
    off.run(10);

    let dir = std::env::temp_dir().join("fedluar_obs_equiv_test");
    obs::init(&ObsCfg {
        level: ObsLevel::Full,
        trace_path: Some(dir.join("trace.jsonl").to_str().unwrap().to_string()),
        ..ObsCfg::default()
    })
    .unwrap();
    let mut full = SimServer::new(7);
    full.run(10);
    assert!(obs::spans_recorded() > 0, "full run must actually trace");
    assert!(obs::counter_value("async.dispatched") > 0);
    obs::finish().unwrap();

    assert_bit_identical(&off, &full, "off vs full");
}

/// The layer telemetry reproduces Figure 3 exactly: per-layer upload
/// counts summed over the rows equal `CommAccountant`'s
/// `layer_upload_rounds`, and the derived frequencies equal
/// `layer_frequencies`.
#[test]
fn layer_rows_agree_with_comm_accountant_exactly() {
    obs::init(&ObsCfg { level: ObsLevel::Metrics, ..ObsCfg::default() }).unwrap();
    let mut s = SimServer::new(11);
    s.run(12);

    let rows = obs::layer_rows();
    assert_eq!(rows.len(), 12 * LAYERS, "one row per (round, layer)");
    let mut uploads = vec![0u64; LAYERS];
    let mut bytes = vec![0u64; LAYERS];
    for r in &rows {
        if r.uploaded {
            uploads[r.layer] += 1;
            assert_eq!(r.recycle_age, 0, "uploaded layers carry age 0");
        } else {
            assert_eq!(r.wire_bytes, 0, "recycled layers cost no wire bytes");
        }
        bytes[r.layer] += r.wire_bytes;
    }
    assert_eq!(uploads, s.comm.layer_upload_rounds, "Figure 3 counts must agree exactly");
    let freqs = s.comm.layer_frequencies();
    for (l, &u) in uploads.iter().enumerate() {
        let f = u as f64 / s.comm.rounds as f64;
        assert!((f - freqs[l]).abs() < 1e-12, "layer {l} frequency {f} vs {}", freqs[l]);
    }
    // recycling actually happened, so the counts are non-trivial
    assert!(uploads.iter().any(|&u| u < 12), "some layer must have been recycled");
    assert!(bytes.iter().sum::<u64>() > 0);
    obs::finish().unwrap();
}

/// A full-level run emits all three artifact kinds, each well-formed:
/// JSONL trace (every line parses), non-empty exposition + JSON
/// summary, and the 8-column layer CSV.
#[test]
fn full_run_emits_wellformed_artifacts() {
    let dir = std::env::temp_dir().join("fedluar_obs_artifacts_test");
    let trace = dir.join("trace.jsonl").to_str().unwrap().to_string();
    let prom = dir.join("metrics.prom").to_str().unwrap().to_string();
    let csv = dir.join("layers.csv").to_str().unwrap().to_string();
    obs::init(&ObsCfg {
        level: ObsLevel::Full,
        trace_path: Some(trace.clone()),
        metrics_path: Some(prom.clone()),
        layer_csv: Some(csv.clone()),
        ..ObsCfg::default()
    })
    .unwrap();
    let mut s = SimServer::new(3);
    s.run(6);
    let written = obs::finish().unwrap();
    assert_eq!(written.len(), 4, "trace + prom + json + layer csv: {written:?}");

    let trace_text = std::fs::read_to_string(&trace).unwrap();
    assert!(trace_text.lines().count() > 0, "trace must hold spans");
    for line in trace_text.lines() {
        let j = Json::parse(line).unwrap_or_else(|e| panic!("bad JSONL line {line:?}: {e}"));
        j.get("span").unwrap().as_str().unwrap();
        j.get("wall_ns").unwrap().as_f64().unwrap();
    }
    // the traced spans cover the instrumented hot paths
    for name in ["wire.encode", "wire.decode", "link.transit", "sched.pop", "luar.select"] {
        assert!(trace_text.contains(&format!("\"span\":\"{name}\"")), "missing span {name}");
    }

    let prom_text = std::fs::read_to_string(&prom).unwrap();
    assert!(!prom_text.is_empty());
    assert!(prom_text.contains("fedluar_async_dispatched"));
    assert!(prom_text.contains("fedluar_async_version_gap_bucket"));
    assert!(prom_text.contains("fedluar_wire_encode_ns_count"));

    let json_path = prom.strip_suffix(".prom").unwrap().to_string() + ".json";
    let summary = Json::parse(&std::fs::read_to_string(&json_path).unwrap()).unwrap();
    summary.get("counters").unwrap();
    summary.get("histograms").unwrap();

    let csv_text = std::fs::read_to_string(&csv).unwrap();
    let mut lines = csv_text.lines();
    assert_eq!(lines.next().unwrap().split(',').count(), 9, "9-column layer CSV");
    for line in lines {
        assert_eq!(line.split(',').count(), 9, "{line}");
    }
    assert_eq!(csv_text.lines().count(), 1 + 6 * LAYERS);
}
