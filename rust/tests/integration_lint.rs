//! fedluar-lint end-to-end: every catalog rule firing and suppressed
//! (fixtures under lint_fixtures/), annotation handling, baseline
//! round-trip + staleness, and — the enforcement test — the real tree
//! linting clean against the checked-in lint-baseline.txt.

use fedluar::lint::{self, Finding, baseline, lint_source, lint_tree, rules};
use std::path::Path;

const FIX_D1: &str = include_str!("lint_fixtures/fixture_d1.rs");
const FIX_D2: &str = include_str!("lint_fixtures/fixture_d2.rs");
const FIX_D3: &str = include_str!("lint_fixtures/fixture_d3.rs");
const FIX_D4: &str = include_str!("lint_fixtures/fixture_d4.rs");
const FIX_P1: &str = include_str!("lint_fixtures/fixture_p1.rs");
const FIX_W1: &str = include_str!("lint_fixtures/fixture_w1.rs");

/// (rule, line) pairs of a file's findings, in report order.
fn keys(findings: &[Finding]) -> Vec<(String, usize)> {
    findings.iter().map(|f| (f.rule.clone(), f.line)).collect()
}

// ------------------------------------------------ per-rule fixtures

#[test]
fn d1_fires_and_suppresses() {
    let r = lint_source("rust/src/net/fixture_d1.rs", FIX_D1);
    assert_eq!(
        keys(&r.findings),
        vec![("D1".to_string(), 5), ("D1".to_string(), 8)],
        "{:?}",
        r.findings
    );
    assert_eq!(r.suppressed, 1, "annotated HashSet alias");
}

#[test]
fn d1_out_of_scope_module_is_ignored() {
    // Same source under a path outside D1's scope: no findings.
    let r = lint_source("rust/src/runtime/fixture_d1.rs", FIX_D1);
    assert!(keys(&r.findings).iter().all(|(rule, _)| rule != "D1"), "{:?}", r.findings);
}

#[test]
fn d2_fires_and_suppresses() {
    let r = lint_source("rust/src/fl/fixture_d2.rs", FIX_D2);
    assert_eq!(keys(&r.findings), vec![("D2".to_string(), 6)], "{:?}", r.findings);
    assert_eq!(r.suppressed, 1, "annotated SystemTime read");
}

#[test]
fn d2_allowlisted_module_is_exempt() {
    let r = lint_source("rust/src/obs/fixture_d2.rs", FIX_D2);
    assert!(r.findings.is_empty(), "{:?}", r.findings);
}

#[test]
fn d3_fires_in_tests_too_and_skips_trait_impls() {
    let r = lint_source("rust/tests/fixture_d3.rs", FIX_D3);
    // line 6: library sort; line 34: #[cfg(test)] sort — D3 applies in
    // test code as well. The `fn partial_cmp` impl (18) and its inner
    // non-unwrapped call (19) must not fire.
    assert_eq!(
        keys(&r.findings),
        vec![("D3".to_string(), 6), ("D3".to_string(), 34)],
        "{:?}",
        r.findings
    );
    assert_eq!(r.suppressed, 1, "annotated unwrap_or(Equal) form");
}

#[test]
fn d4_fires_and_suppresses() {
    let r = lint_source("rust/src/compress/fixture_d4.rs", FIX_D4);
    assert_eq!(keys(&r.findings), vec![("D4".to_string(), 6)], "{:?}", r.findings);
    assert_eq!(r.suppressed, 1, "annotated floor cast");
}

#[test]
fn p1_fires_skips_test_code_and_reports_bad_annotations() {
    let r = lint_source("rust/src/fl/fixture_p1.rs", FIX_P1);
    assert_eq!(
        keys(&r.findings),
        vec![
            ("P1".to_string(), 6),  // unwrap on library path
            ("P1".to_string(), 11), // panic! on library path
            ("A1".to_string(), 20), // unknown rule ZZ9
            ("A1".to_string(), 23), // missing `: reason`
        ],
        "{:?}",
        r.findings
    );
    assert_eq!(r.suppressed, 1, "annotated unwrap in head_allowed");
    // the #[cfg(test)] unwrap at line 31 must not appear
    assert!(r.findings.iter().all(|f| f.line != 31));
}

#[test]
fn w1_fires_and_suppresses() {
    let r = lint_source("rust/src/net/wire.rs", FIX_W1);
    assert_eq!(keys(&r.findings), vec![("W1".to_string(), 6)], "{:?}", r.findings);
    assert_eq!(r.suppressed, 1, "annotated bounds-checked index");
}

// ------------------------------------------------- annotation corner

#[test]
fn annotation_covers_same_line_trailing_comment() {
    let src = "pub fn f(xs: &[u32]) -> u32 {\n    *xs.first().unwrap() // lint:allow(P1): fixture\n}\n";
    let r = lint_source("rust/src/fl/inline.rs", src);
    assert!(r.findings.is_empty(), "{:?}", r.findings);
    assert_eq!(r.suppressed, 1);
}

#[test]
fn annotation_only_covers_next_token_line() {
    // A blank line between annotation and violation still suppresses
    // (first *token* line after the comment), but a second violation
    // two statements later does not ride along.
    let src = "// lint:allow(P1): first only\n\n\
               pub fn f(a: &[u32]) -> u32 { *a.first().unwrap() }\n\
               pub fn g(a: &[u32]) -> u32 { *a.first().unwrap() }\n";
    let r = lint_source("rust/src/fl/next.rs", src);
    assert_eq!(keys(&r.findings), vec![("P1".to_string(), 4)], "{:?}", r.findings);
    assert_eq!(r.suppressed, 1);
}

#[test]
fn strings_and_comments_never_match() {
    let src = "pub fn f() -> &'static str {\n    // HashMap unwrap() panic! Instant::now in a comment\n    \"HashMap unwrap() partial_cmp(x).unwrap() Instant::now\"\n}\n";
    let r = lint_source("rust/src/net/strings.rs", src);
    assert!(r.findings.is_empty(), "{:?}", r.findings);
}

// --------------------------------------------------------- baseline

#[test]
fn baseline_round_trip_and_staleness() {
    let mut findings = lint_source("rust/src/fl/fixture_p1.rs", FIX_P1).findings;
    let entries = baseline::parse(
        "# comment line\n\nP1 rust/src/fl/fixture_p1.rs\nD1 rust/src/fl/fixture_p1.rs\n",
    )
    .expect("baseline parses");
    let (baselined, stale) = baseline::apply(&mut findings, &entries);
    assert_eq!(baselined, 2, "both P1 findings grandfathered");
    assert_eq!(stale, vec!["D1 rust/src/fl/fixture_p1.rs".to_string()], "no D1 finding => stale");
    // A1 annotation findings are never baselined away
    assert_eq!(
        keys(&findings),
        vec![("A1".to_string(), 20), ("A1".to_string(), 23)],
        "{:?}",
        findings
    );
}

#[test]
fn baseline_rejects_unknown_rules_and_bad_lines() {
    assert!(baseline::parse("Q9 rust/src/foo.rs\n").is_err(), "unknown rule id");
    assert!(baseline::parse("P1 rust/src/foo.rs extra-field\n").is_err(), "three fields");
    assert!(baseline::parse("just-one-field\n").is_err(), "one field");
}

#[test]
fn baseline_render_parses_back() {
    let findings = lint_source("rust/src/fl/fixture_p1.rs", FIX_P1).findings;
    let text = baseline::render(&findings);
    let entries = baseline::parse(&text).expect("rendered baseline parses");
    // fixture has P1 and A1 findings; render dedups per (rule, path)
    // and drops A1 (malformed annotations are never grandfathered), so
    // exactly one entry survives and it round-trips through parse.
    assert_eq!(entries, vec![("P1".to_string(), "rust/src/fl/fixture_p1.rs".to_string())]);
}

// ---------------------------------------------- whole-tree contract

#[test]
fn tree_is_clean_under_checked_in_baseline() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let mut report = lint_tree(root).expect("tree lints");
    assert!(report.files > 30, "walker found only {} files", report.files);
    assert!(
        report.findings.iter().all(|f| !f.path.contains("lint_fixtures")),
        "fixtures must be skipped by the walker"
    );
    let baseline_text = std::fs::read_to_string(root.join("lint-baseline.txt"))
        .expect("lint-baseline.txt is checked in");
    lint::apply_baseline(&mut report, &baseline_text).expect("baseline applies");
    assert!(
        report.findings.is_empty(),
        "non-baselined findings:\n{}",
        report
            .findings
            .iter()
            .map(|f| format!("{}:{}: [{}] {}", f.path, f.line, f.rule, f.msg))
            .collect::<Vec<_>>()
            .join("\n")
    );
    assert!(report.stale.is_empty(), "stale baseline entries: {:?}", report.stale);
}

// ------------------------------------------------- catalog hygiene

#[test]
fn catalog_ids_unique_and_documented() {
    let mut ids: Vec<&str> = rules::CATALOG.iter().map(|r| r.id).collect();
    let n = ids.len();
    ids.sort();
    ids.dedup();
    assert_eq!(ids.len(), n, "duplicate rule ids");
    let docs = include_str!("../../docs/lints.md");
    for r in rules::CATALOG {
        assert!(docs.contains(&format!("## {}", r.id)), "docs/lints.md missing section for {}", r.id);
    }
    assert!(docs.contains("## A1"), "docs/lints.md missing the A1 annotation rule");
    assert!(docs.contains("lint:allow"), "docs/lints.md must explain suppression");
}
