//! Lint fixture: rule D3 (NaN-unsafe float ordering). Never compiled —
//! linted under the pseudo-path rust/tests/fixture_d3.rs (outside
//! P1's scope, so the `.unwrap()` sites exercise D3 alone).

pub fn sort_scores(xs: &mut [f32]) {
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
}

pub fn sort_scores_legacy(xs: &mut [f64]) {
    // lint:allow(D3): fixture demonstrates suppression of the legacy form
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
}

pub struct Wrapper(pub f32);

impl PartialOrd for Wrapper {
    // a trait impl *defining* partial_cmp must not fire
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        self.0.partial_cmp(&other.0)
    }
}

impl PartialEq for Wrapper {
    fn eq(&self, other: &Self) -> bool {
        self.0 == other.0
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn d3_applies_in_test_code_too() {
        let mut v = vec![1.0f32, 0.5];
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    }
}
