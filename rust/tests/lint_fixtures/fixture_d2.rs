//! Lint fixture: rule D2 (wall clock outside allowlisted modules).
//! Never compiled — linted under the pseudo-path
//! rust/src/fl/fixture_d2.rs.

pub fn stamp_nanos() -> u128 {
    let t0 = std::time::Instant::now();
    t0.elapsed().as_nanos()
}

pub fn stamp_allowed() -> u64 {
    // lint:allow(D2): fixture demonstrates an annotated wall-clock read
    let _t = std::time::SystemTime::now();
    0
}
