//! Lint fixture: rule W1 (unchecked frame slicing in the wire
//! decoder). Never compiled — linted under the pseudo-path
//! rust/src/net/wire.rs, the only file in W1's scope.

pub fn decode_u32_bad(frame: &[u8]) -> u32 {
    let raw = &frame[0..4];
    u32::from_le_bytes([raw[0], raw[1], raw[2], raw[3]])
}

pub fn byte_at_checked(buf: &[u8], pos: usize) -> Option<u8> {
    if pos >= buf.len() {
        return None;
    }
    // lint:allow(W1): bounds checked on the line above
    Some(buf[pos])
}
