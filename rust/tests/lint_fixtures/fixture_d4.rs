//! Lint fixture: rule D4 (bare float->int cast on a codec path).
//! Never compiled — linted under the pseudo-path
//! rust/src/compress/fixture_d4.rs.

pub fn quantize_bad(v: f32, step: f32) -> u32 {
    (v / step).round() as u32
}

pub fn floor_allowed(v: f64) -> usize {
    // lint:allow(D4): fixture demonstrates suppression; v is pre-clamped
    v.floor() as usize
}

pub fn int_to_int_is_fine(v: u64) -> usize {
    v as usize
}
