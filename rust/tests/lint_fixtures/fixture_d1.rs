//! Lint fixture: rule D1 (unordered collections in determinism-
//! critical modules). Never compiled — integration_lint.rs feeds this
//! text to the linter under the pseudo-path rust/src/net/fixture_d1.rs.

use std::collections::HashMap;

pub fn histogram(xs: &[u32]) -> Vec<(u32, usize)> {
    let mut h = HashMap::new();
    for &x in xs {
        *h.entry(x).or_insert(0usize) += 1;
    }
    let mut out: Vec<(u32, usize)> = h.into_iter().collect();
    out.sort();
    out
}

// lint:allow(D1): scratch set is drained into a sorted Vec before any I/O
pub type ScratchSet = std::collections::HashSet<u32>;

#[cfg(test)]
mod tests {
    use std::collections::HashMap;

    #[test]
    fn test_code_is_exempt() {
        let _m: HashMap<u8, u8> = HashMap::new();
    }
}
