//! Lint fixture: rule P1 (panic paths in non-test library code) and
//! pseudo-rule A1 (malformed annotations). Never compiled — linted
//! under the pseudo-path rust/src/fl/fixture_p1.rs.

pub fn head(xs: &[u32]) -> u32 {
    *xs.first().unwrap()
}

pub fn must(flag: bool) {
    if !flag {
        panic!("fixture");
    }
}

pub fn head_allowed(xs: &[u32]) -> u32 {
    // lint:allow(P1): caller guarantees non-empty in this fixture
    *xs.first().unwrap()
}

// lint:allow(ZZ9): no such rule in the catalog
pub const A: u32 = 1;

// lint:allow(P1) forgot the colon and the reason
pub const B: u32 = 2;

#[cfg(test)]
mod tests {
    #[test]
    fn unwrap_in_tests_is_fine() {
        let v: Vec<u32> = vec![1];
        assert_eq!(*v.first().unwrap(), 1);
    }
}
