//! Runtime integration: load the real AOT artifacts and pin the whole
//! bridge — layer table, init params, train/eval/agg graph semantics —
//! against pure-Rust recomputation where possible.
//!
//! Requires `make artifacts` (skips loudly if missing).

use fedluar::data::{FedDataset, Features, SynthSpec};
use fedluar::model::{artifacts_dir, ModelMeta};
use fedluar::runtime::Engine;
use fedluar::tensor;

fn engine(model: &str) -> Option<Engine> {
    let meta = match ModelMeta::load(artifacts_dir(), model) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("SKIP: {e:#} (run `make artifacts`)");
            return None;
        }
    };
    Some(Engine::load(meta).expect("engine"))
}

fn dataset(eng: &Engine, difficulty: f32) -> FedDataset {
    let m = &eng.meta;
    let spec = if m.is_text() {
        SynthSpec::text(m.input_shape[0], 256, m.num_classes)
    } else {
        let (h, w, c) = match m.input_shape.len() {
            1 => (m.input_shape[0], 1, 1),
            _ => (m.input_shape[0], m.input_shape[1], m.input_shape[2]),
        };
        SynthSpec::vision(h, w, c, m.num_classes)
    }
    .with_difficulty(difficulty);
    FedDataset::new(spec, 8, 128, 1.0, 512, 99)
}

#[test]
fn init_params_match_sha() {
    let Some(eng) = engine("mlp") else { return };
    let init = eng.meta.load_init().unwrap();
    assert_eq!(init.len(), eng.meta.dim);
    // init must be finite and non-degenerate
    assert!(init.iter().all(|v| v.is_finite()));
    assert!(tensor::norm(&init) > 1.0);
}

#[test]
fn train_graph_returns_learning_delta() {
    let Some(eng) = engine("mlp") else { return };
    let ds = dataset(&eng, 1.0);
    let params = eng.meta.load_init().unwrap();
    let (feats, labels) = ds.client_batches(0, 0, eng.meta.tau, eng.meta.batch);
    let out = eng
        .train_round(&params, None, None, &feats, &labels, 0.05, 0.0, 0.0, 0.0)
        .unwrap();
    assert_eq!(out.delta.len(), eng.meta.dim);
    assert!(out.loss.is_finite() && out.loss > 0.0);
    assert!(tensor::norm(&out.delta) > 0.0, "zero delta");
}

#[test]
fn zero_lr_zero_delta() {
    let Some(eng) = engine("mlp") else { return };
    let ds = dataset(&eng, 1.0);
    let params = eng.meta.load_init().unwrap();
    let (feats, labels) = ds.client_batches(1, 0, eng.meta.tau, eng.meta.batch);
    let out = eng
        .train_round(&params, None, None, &feats, &labels, 0.0, 0.0, 0.0, 0.0)
        .unwrap();
    assert_eq!(tensor::norm(&out.delta), 0.0);
}

#[test]
fn repeated_rounds_reduce_loss() {
    let Some(eng) = engine("mlp") else { return };
    let ds = dataset(&eng, 1.0);
    let mut params = eng.meta.load_init().unwrap();
    let (feats, labels) = ds.client_batches(0, 0, eng.meta.tau, eng.meta.batch);
    let mut losses = Vec::new();
    for _ in 0..5 {
        let out = eng
            .train_round(&params, None, None, &feats, &labels, 0.05, 0.0, 0.0, 0.0)
            .unwrap();
        tensor::axpy(1.0, &out.delta, &mut params);
        losses.push(out.loss);
    }
    assert!(
        losses.last().unwrap() < losses.first().unwrap(),
        "losses {losses:?}"
    );
}

#[test]
fn prox_pull_is_directionally_correct() {
    let Some(eng) = engine("mlp") else { return };
    let ds = dataset(&eng, 1.0);
    let params = eng.meta.load_init().unwrap();
    let anchor: Vec<f32> = params.iter().map(|v| v + 1.0).collect();
    let (feats, labels) = ds.client_batches(0, 0, eng.meta.tau, eng.meta.batch);
    let d_prox = eng
        .train_round(&params, Some(&anchor), None, &feats, &labels, 0.01, 5.0, 0.0, 0.0)
        .unwrap()
        .delta;
    let d_none = eng
        .train_round(&params, Some(&anchor), None, &feats, &labels, 0.01, 0.0, 0.0, 0.0)
        .unwrap()
        .delta;
    let diff: Vec<f32> = d_prox.iter().zip(&d_none).map(|(a, b)| a - b).collect();
    let mean_diff: f32 = diff.iter().sum::<f32>() / diff.len() as f32;
    assert!(mean_diff > 0.01, "prox did not pull toward +1 anchor: {mean_diff}");
}

#[test]
fn eval_graph_counts_and_bounds() {
    let Some(eng) = engine("mlp") else { return };
    let ds = dataset(&eng, 1.0);
    let params = eng.meta.load_init().unwrap();
    let (loss, acc) = eng.eval_dataset(&params, &ds).unwrap();
    assert!(loss > 0.0 && loss.is_finite());
    assert!((0.0..=1.0).contains(&acc));
}

#[test]
fn agg_graph_matches_rust_mean_and_norms() {
    let Some(eng) = engine("mlp") else { return };
    let m_dim = eng.meta.dim;
    let a = eng.meta.agg_clients;
    let mut rng = fedluar::rng::Rng::seed_from_u64(5);
    let updates: Vec<Vec<f32>> = (0..a)
        .map(|_| (0..m_dim).map(|_| rng.normal_f32(0.0, 0.1)).collect())
        .collect();
    let params = eng.meta.load_init().unwrap();
    let refs: Vec<&[f32]> = updates.iter().map(|u| u.as_slice()).collect();
    let out = eng.aggregate(&refs, &params).unwrap();
    // Pallas kernel vs pure-Rust mean
    let mut want = vec![0.0f32; m_dim];
    tensor::mean_rows(&refs, &mut want);
    let max_err = out
        .mean
        .iter()
        .zip(&want)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0f32, f32::max);
    assert!(max_err < 1e-5, "pallas mean mismatch {max_err}");
    // per-layer norms vs rust recomputation
    assert_eq!(out.update_ssq.len(), eng.meta.num_layers());
    for l in 0..eng.meta.num_layers() {
        let lm = &eng.meta.layers[l];
        let want_ssq = tensor::ssq(&want[lm.offset..lm.offset + lm.size]) as f32;
        let got = out.update_ssq[l];
        assert!(
            (got - want_ssq).abs() <= 1e-3 * want_ssq.max(1e-3),
            "layer {l}: {got} vs {want_ssq}"
        );
        let want_w = tensor::ssq(&params[lm.offset..lm.offset + lm.size]) as f32;
        assert!((out.weight_ssq[l] - want_w).abs() <= 1e-3 * want_w.max(1e-3));
    }
}

#[test]
fn agg_rejects_wrong_client_count() {
    let Some(eng) = engine("mlp") else { return };
    let u = vec![0.0f32; eng.meta.dim];
    let refs: Vec<&[f32]> = vec![u.as_slice(); 3];
    let params = vec![0.0f32; eng.meta.dim];
    assert!(eng.aggregate(&refs, &params).is_err());
}

#[test]
fn text_model_roundtrip() {
    let Some(eng) = engine("transformer") else { return };
    let ds = dataset(&eng, 1.0);
    let params = eng.meta.load_init().unwrap();
    let (feats, labels) = ds.client_batches(0, 0, eng.meta.tau, eng.meta.batch);
    assert!(matches!(feats, Features::I32(_)));
    let out = eng
        .train_round(&params, None, None, &feats, &labels, 0.01, 0.0, 0.0, 0.0)
        .unwrap();
    assert!(out.loss.is_finite());
    assert!(tensor::norm(&out.delta) > 0.0);
}
